"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Roofline terms come from the
dry-run artifacts (launch/dryrun.py writes JSON; benchmarks/roofline.py
renders the table) since they require the 512-device process.

Every invocation also records one ``kind="bench"`` manifest (suite list,
per-suite seconds, failures, provenance) into the run store — disable via
``REPRO_RUNSTORE=0`` (see ``repro.obs.runstore``).
"""
from __future__ import annotations

import sys
import time


def _record_suite_manifest(suite_rows: list, total_s: float) -> None:
    """Best-effort run-store manifest for the whole suite invocation."""
    try:
        from repro.obs.runstore import default_store
        store = default_store()
        if store is None:
            return
        run_id = store.record({
            "kind": "bench",
            "label": "benchmarks.run suite",
            "suites": suite_rows,
            "total_s": total_s,
        })
        print(f"# recorded bench run {run_id} in {store.root}")
    except Exception as e:  # noqa: BLE001
        print(f"# runstore: suite manifest not recorded: {e}")


def main() -> None:
    from . import (bench_spectrum, bench_ridge, bench_lasso, bench_logistic,
                   bench_matrix_factorization, bench_kernels, bench_coded_lm,
                   bench_runtime, bench_encoding, bench_trials,
                   bench_experiments, bench_fused, bench_faults, perf_iter)
    print("name,us_per_call,derived")
    suites = [
        ("spectrum (paper Figs 5-6)", bench_spectrum.run),
        ("encoding operators (matrix-free, DESIGN §7)", bench_encoding.run),
        ("ridge L-BFGS (paper Fig 7)", bench_ridge.run),
        ("lasso proximal (paper Fig 14)", bench_lasso.run),
        ("logistic BCD (paper Figs 10-13)", bench_logistic.run),
        ("matrix factorization (paper Tables 2-3)",
         bench_matrix_factorization.run),
        ("coded-DP LM trainer (beyond-paper, DESIGN §4)", bench_coded_lm.run),
        ("kernels", bench_kernels.run),
        ("runtime scan-fused vs legacy loops", bench_runtime.run),
        ("batched trials vs sequential loop (DESIGN §9)", bench_trials.run),
        ("experiment placement axis single/vmap/sharded (DESIGN §10)",
         bench_experiments.run),
        ("fused masked-gradient path: kernel + cell-batched matrix "
         "(DESIGN §12)", bench_fused.run),
        ("fault-injection overhead: no-fault path + chaos cells "
         "(DESIGN §14)", bench_faults.run),
        ("perf-iter roofline dry-run (512-device subprocess)",
         perf_iter.run),
    ]
    t_all = time.time()
    suite_rows = []
    for title, fn in suites:
        print(f"# --- {title} ---", flush=True)
        t0 = time.time()
        status = "ok"
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            print(f"{title.split()[0]}_FAILED,0.0,{e!r}", flush=True)
            import traceback
            traceback.print_exc()
            status = f"failed: {e!r}"
        secs = time.time() - t0
        suite_rows.append({"suite": title, "seconds": secs,
                           "status": status})
        print(f"# ({title}: {secs:.1f}s)", flush=True)
    total_s = time.time() - t_all
    print(f"# total: {total_s:.1f}s")
    _record_suite_manifest(suite_rows, total_s)


if __name__ == "__main__":
    main()
