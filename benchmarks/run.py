"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Roofline terms come from the
dry-run artifacts (launch/dryrun.py writes JSON; benchmarks/roofline.py
renders the table) since they require the 512-device process.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (bench_spectrum, bench_ridge, bench_lasso, bench_logistic,
                   bench_matrix_factorization, bench_kernels, bench_coded_lm,
                   bench_runtime, bench_encoding, bench_trials,
                   bench_experiments, bench_fused)
    print("name,us_per_call,derived")
    suites = [
        ("spectrum (paper Figs 5-6)", bench_spectrum.run),
        ("encoding operators (matrix-free, DESIGN §7)", bench_encoding.run),
        ("ridge L-BFGS (paper Fig 7)", bench_ridge.run),
        ("lasso proximal (paper Fig 14)", bench_lasso.run),
        ("logistic BCD (paper Figs 10-13)", bench_logistic.run),
        ("matrix factorization (paper Tables 2-3)",
         bench_matrix_factorization.run),
        ("coded-DP LM trainer (beyond-paper, DESIGN §4)", bench_coded_lm.run),
        ("kernels", bench_kernels.run),
        ("runtime scan-fused vs legacy loops", bench_runtime.run),
        ("batched trials vs sequential loop (DESIGN §9)", bench_trials.run),
        ("experiment placement axis single/vmap/sharded (DESIGN §10)",
         bench_experiments.run),
        ("fused masked-gradient path: kernel + cell-batched matrix "
         "(DESIGN §12)", bench_fused.run),
    ]
    t_all = time.time()
    for title, fn in suites:
        print(f"# --- {title} ---", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            print(f"{title.split()[0]}_FAILED,0.0,{e!r}", flush=True)
            import traceback
            traceback.print_exc()
        print(f"# ({title}: {time.time() - t0:.1f}s)", flush=True)
    print(f"# total: {time.time() - t_all:.1f}s")


if __name__ == "__main__":
    main()
