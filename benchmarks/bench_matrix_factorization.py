"""Paper Tables 2-3: matrix factorization (MovieLens-protocol, synthetic).

MovieLens-1M is not redistributable offline, so the ``mf`` workload
generates a statistically matched stand-in (low-rank + bias + noise
ratings, 1-5 clipped), keeps the 80/20 split, and runs alternating coded
least squares: every ALS half-step is ONE joint ridge regression dispatched
through the strategy registry and the ``ClusterEngine`` (exp worker delays,
fresh realization per half-step).  This module only enumerates the paper's
encoder x k scheme table and emits CSV; it also prints the exact-ALS
reference RMSE from ``workloads.ground_truth``.
"""
from __future__ import annotations

import time

from repro.workloads import get_workload
from repro.workloads.ground_truth import als_reference

from .common import emit


def run(preset: str = "bench"):
    wl = get_workload("mf")
    ps = wl.preset(preset)
    data = wl.build(ps)
    m = ps.m

    ref_train, ref_test = als_reference(data.R, data.train, data.test,
                                        rank=ps.dims["rank"], lam=ps.lam,
                                        epochs=ps.dims["epochs"])
    emit("mf_exact_als_reference", 0.0,
         f"train_rmse={ref_train:.3f};test_rmse={ref_test:.3f}")

    schemes = [
        ("uncoded", "uncoded", {}),
        ("replication", "replication", {}),
        ("gaussian", "coded-lbfgs", {"encoder": "gaussian"}),
        ("paley", "coded-lbfgs", {"encoder": "paley"}),
        ("hadamard", "coded-lbfgs", {"encoder": "hadamard"}),
    ]
    results = []
    for k in [m // 4, m // 2]:
        for name, strategy, cfg in schemes:
            t0 = time.perf_counter()
            res = wl.run(strategy, engine=None, preset=ps, data=data,
                         k=k, **cfg)
            us = (time.perf_counter() - t0) * 1e6 / ps.dims["epochs"]
            train_rmse = res.meta["train_rmse"]
            emit(f"mf_{name}_k{k}", us,
                 f"train_rmse={train_rmse:.3f};"
                 f"test_rmse={res.final_metric:.3f};"
                 f"sim_wallclock_s={res.wallclock:.1f}")
            results.append((name, k, train_rmse, res.final_metric))
    return results


if __name__ == "__main__":
    run()
