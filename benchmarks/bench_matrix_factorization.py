"""Paper Tables 2-3: matrix factorization (MovieLens-protocol, synthetic).

MovieLens-1M is not redistributable offline, so we generate a statistically
matched stand-in (low-rank + bias + noise ratings, 1-5 clipped, ~5% density),
keep the paper's 80/20 split and alternating-ridge solver, and run each
alternating step as ONE joint ridge regression solved with distributed
encoded L-BFGS over m workers (the paper's coded solver), under exp(10ms)
worker delays.  Reports train/test RMSE per scheme and k, as in Tables 2-3.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import (make_encoder, pad_rows, make_encoded_problem,
                        run_encoded_lbfgs, exponential_delays)
from .common import emit, masks_from_delays


def _synthetic_ratings(users=120, movies=90, rank=4, density=0.08, seed=0):
    rng = np.random.default_rng(seed)
    U = rng.standard_normal((users, rank)) * 0.5
    V = rng.standard_normal((movies, rank)) * 0.5
    bu = rng.standard_normal(users) * 0.3
    bv = rng.standard_normal(movies) * 0.3
    R = 3.0 + U @ V.T + bu[:, None] + bv[None, :] + \
        0.3 * rng.standard_normal((users, movies))
    R = np.clip(np.round(R * 2) / 2, 1.0, 5.0)
    obs = rng.random((users, movies)) < density
    train = obs & (rng.random((users, movies)) < 0.8)
    test = obs & ~train
    return R, train, test


def _ridge_design(R, mask, fixed, p, reg_rows, side):
    """Joint LS design for updating one side given the other: rows =
    observed ratings, block features per row entity."""
    users, movies = R.shape
    n_ent = users if side == "u" else movies
    rows, cols, vals, targ = [], [], [], []
    idx = np.argwhere(mask)
    for r, (i, j) in enumerate(idx):
        ent = i if side == "u" else j
        other = fixed[j] if side == "u" else fixed[i]
        feat = np.concatenate([other, [1.0]])
        for c, v in enumerate(feat):
            rows.append(r)
            cols.append(ent * (p + 1) + c)
            vals.append(v)
        targ.append(R[i, j])
    A = np.zeros((len(idx), n_ent * (p + 1)), np.float32)
    A[rows, cols] = vals
    return A, np.asarray(targ, np.float32)


def run(epochs: int = 2, p: int = 4, m: int = 8, lam: float = 0.3,
        lbfgs_iters: int = 15):
    R, train, test = _synthetic_ratings()
    users, movies = R.shape
    rng = np.random.default_rng(1)
    schemes = [("uncoded", "uncoded", 2.0), ("replication", "replication",
                                             2.0),
               ("gaussian", "gaussian", 2.0), ("paley", "paley", 2.0),
               ("hadamard", "hadamard", 2.0)]
    results = []
    for k in [m // 4, m // 2]:
        for name, enc_name, beta in schemes:
            U = rng.standard_normal((users, p)).astype(np.float32) * 0.1
            V = rng.standard_normal((movies, p)).astype(np.float32) * 0.1
            Ub = np.concatenate([U, np.zeros((users, 1), np.float32)], 1)
            Vb = np.concatenate([V, np.zeros((movies, 1), np.float32)], 1)
            import time
            t0 = time.perf_counter()
            for _ in range(epochs):
                for side in ("u", "v"):
                    fixed = Vb[:, :p + 1] if side == "u" else Ub[:, :p + 1]
                    fixed_pb = np.concatenate(
                        [fixed[:, :p], np.ones((fixed.shape[0], 1),
                                               np.float32)], 1)
                    A, t = _ridge_design(R - 3.0, train,
                                         fixed[:, :p], p, lam, side)
                    n = A.shape[0]
                    pad = (-n) % m
                    if pad:
                        A = np.concatenate([A, np.zeros((pad, A.shape[1]),
                                                        np.float32)])
                        t = np.concatenate([t, np.zeros(pad, np.float32)])
                    b = 1.0 if enc_name == "uncoded" else beta
                    enc = pad_rows(make_encoder(enc_name, A.shape[0], beta=b, seed=3), m)
                    prob = make_encoded_problem(A, t, enc, m, lam=lam)
                    masks, _ = masks_from_delays(
                        exponential_delays(), m, k, lbfgs_iters, seed=5)
                    w0 = (Ub if side == "u" else Vb).reshape(-1)
                    w, _ = run_encoded_lbfgs(prob, masks, memory=8,
                                             w0=jnp.asarray(w0))
                    w = np.asarray(w).reshape(-1, p + 1)
                    if side == "u":
                        Ub = w
                    else:
                        Vb = w
            us = (time.perf_counter() - t0) * 1e6 / epochs

            pred = 3.0 + Ub[:, :p] @ Vb[:, :p].T + Ub[:, p:p + 1] \
                + Vb[:, p:p + 1].T
            rmse = lambda msk: float(np.sqrt(
                np.mean((pred[msk] - R[msk]) ** 2)))
            emit(f"mf_{name}_k{k}", us,
                 f"train_rmse={rmse(train):.3f};test_rmse={rmse(test):.3f}")
            results.append((name, k, rmse(train), rmse(test)))
    return results


if __name__ == "__main__":
    run()
