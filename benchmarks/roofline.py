"""Render the §Roofline table from dry-run JSON artifacts.

  PYTHONPATH=src python -m benchmarks.roofline --dir runs/dryrun [--md]

Reads every <arch>__<shape>__<mesh>.json emitted by repro.launch.dryrun and
prints the three roofline terms, dominant bottleneck, MODEL_FLOPS ratio and
memory footprint per combo.
"""
from __future__ import annotations

import argparse
import json
import os


def load_records(d: str):
    recs = []
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                recs.append(json.load(fh))
    return recs


def fmt_row(r: dict, md: bool = False):
    if "error" in r:
        cells = [r["arch"], r["shape"], r.get("mesh", "?"), "ERROR",
                 r["error"][:40], "", "", "", ""]
    else:
        rl = r["roofline"]
        mem_gb = (r["memory"]["argument_bytes_per_device"]
                  + r["memory"]["temp_bytes_per_device"]) / 2 ** 30
        cells = [
            r["arch"], r["shape"], r["mesh"],
            f"{rl['compute_s'] * 1e3:.2f}", f"{rl['memory_s'] * 1e3:.2f}",
            f"{rl['collective_s'] * 1e3:.2f}", rl["bottleneck"],
            f"{rl.get('useful_ratio', 0):.3f}", f"{mem_gb:.1f}",
        ]
    sep = " | " if md else ","
    line = sep.join(str(c) for c in cells)
    return ("| " + line + " |") if md else line


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    recs = load_records(args.dir)
    hdr = ["arch", "shape", "mesh", "compute_ms", "memory_ms",
           "collective_ms", "bottleneck", "useful_ratio", "mem_GB/dev"]
    if args.md:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    else:
        print(",".join(hdr))
    for r in recs:
        print(fmt_row(r, args.md))
    ok = sum(1 for r in recs if "error" not in r)
    print(f"{'<!-- ' if args.md else '# '}{ok}/{len(recs)} combos lowered "
          f"and compiled{' -->' if args.md else ''}")


if __name__ == "__main__":
    main()
