"""Render the §Roofline table from dry-run JSON artifacts, and the
achieved-vs-peak table for the fused masked-gradient path.

  PYTHONPATH=src python -m benchmarks.roofline --dir runs/dryrun [--md]
  PYTHONPATH=src python -m benchmarks.roofline --fused BENCH_fused.json

The first form reads every <arch>__<shape>__<mesh>.json emitted by
repro.launch.dryrun and prints the three roofline terms, dominant
bottleneck, MODEL_FLOPS ratio and memory footprint per combo.

The second reads ``benchmarks.bench_fused``'s kernel records (measured us
per call + analytic FLOPs and ideal HBM bytes) and prints achieved
GFLOP/s and GB/s against the backend's nominal peaks, plus the implied
arithmetic intensity and the roofline-predicted bound.  Interpret-mode
(CPU emulator) rows are marked — their utilization reflects the Pallas
interpreter, not the TPU dataflow.  Peaks are nominal per-backend
defaults, overridable with ``--peak-gflops`` / ``--peak-gbps``.
"""
from __future__ import annotations

import argparse
import json
import os

# nominal single-chip peaks; override per deployment with the CLI flags.
# TPU numbers are the v5e spec (bf16 MXU / HBM2e); CPU numbers a typical
# server core-complex — interpret-mode rows are denominated against them
# only to make the emulator overhead visible.
PEAKS = {
    "tpu": {"gflops": 394e3 / 2, "gbps": 819.0},   # f32 ~ half bf16 peak
    "cpu": {"gflops": 200.0, "gbps": 50.0},
    "gpu": {"gflops": 19.5e3, "gbps": 900.0},
}


def fused_table(path: str, *, peak_gflops: float | None = None,
                peak_gbps: float | None = None, md: bool = False) -> None:
    """Achieved-vs-peak rows for every kernel case in BENCH_fused.json."""
    with open(path) as f:
        data = json.load(f)
    backend = data.get("backend", "cpu")
    peaks = PEAKS.get(backend, PEAKS["cpu"])
    pg = peak_gflops or peaks["gflops"]
    pb = peak_gbps or peaks["gbps"]
    hdr = ["case", "mode", "m", "r", "p", "us", "GFLOP/s", "%peak",
           "GB/s", "%peak_bw", "intensity", "bound"]
    sep = " | " if md else ","
    if md:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    else:
        print(sep.join(hdr))
    for r in data.get("kernel", []):
        s = r["us_fused"] * 1e-6
        gflops = r["flops"] / s / 1e9
        gbps = r["bytes_ideal"] / s / 1e9
        intensity = r["flops"] / r["bytes_ideal"]
        bound = "compute" if intensity > pg / pb else "memory"
        cells = [r["case"], r["mode"], r["m"], r["r"], r["p"],
                 f"{r['us_fused']:.1f}", f"{gflops:.2f}",
                 f"{100 * gflops / pg:.2f}%", f"{gbps:.2f}",
                 f"{100 * gbps / pb:.2f}%", f"{intensity:.1f}", bound]
        line = sep.join(str(c) for c in cells)
        print(("| " + line + " |") if md else line)
    note = (f"backend={backend} peaks: {pg:.0f} GFLOP/s, {pb:.0f} GB/s"
            + (" (interpret rows measure the emulator)"
               if backend != "tpu" else ""))
    print(f"{'<!-- ' if md else '# '}{note}{' -->' if md else ''}")


def load_records(d: str):
    recs = []
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                recs.append(json.load(fh))
    return recs


def fmt_row(r: dict, md: bool = False):
    if "error" in r:
        cells = [r["arch"], r["shape"], r.get("mesh", "?"), "ERROR",
                 r["error"][:40], "", "", "", ""]
    else:
        rl = r["roofline"]
        mem_gb = (r["memory"]["argument_bytes_per_device"]
                  + r["memory"]["temp_bytes_per_device"]) / 2 ** 30
        cells = [
            r["arch"], r["shape"], r["mesh"],
            f"{rl['compute_s'] * 1e3:.2f}", f"{rl['memory_s'] * 1e3:.2f}",
            f"{rl['collective_s'] * 1e3:.2f}", rl["bottleneck"],
            f"{rl.get('useful_ratio', 0):.3f}", f"{mem_gb:.1f}",
        ]
    sep = " | " if md else ","
    line = sep.join(str(c) for c in cells)
    return ("| " + line + " |") if md else line


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--fused", default=None, metavar="BENCH_fused.json",
                    help="print achieved-vs-peak for the fused kernel "
                         "records instead of the dry-run table")
    ap.add_argument("--peak-gflops", type=float, default=None)
    ap.add_argument("--peak-gbps", type=float, default=None)
    args = ap.parse_args()
    if args.fused:
        fused_table(args.fused, peak_gflops=args.peak_gflops,
                    peak_gbps=args.peak_gbps, md=args.md)
        return
    recs = load_records(args.dir)
    hdr = ["arch", "shape", "mesh", "compute_ms", "memory_ms",
           "collective_ms", "bottleneck", "useful_ratio", "mem_GB/dev"]
    if args.md:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    else:
        print(",".join(hdr))
    for r in recs:
        print(fmt_row(r, args.md))
    ok = sum(1 for r in recs if "error" not in r)
    print(f"{'<!-- ' if args.md else '# '}{ok}/{len(recs)} combos lowered "
          f"and compiled{' -->' if args.md else ''}")


if __name__ == "__main__":
    main()
