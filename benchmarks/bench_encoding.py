"""Encode throughput: dense S @ X vs matrix-free operators (DESIGN §7).

Two regimes:

* feasible n — dense, fast-Hadamard (fused Pallas FWHT) and block-diagonal
  encoders encode the same (n, p) data; we report us/encode for each.
* infeasible n — an ``n`` whose dense ``(beta*n, n)`` float64 matrix would
  exceed 8 GB, where only the operators can run.  Correctness is checked
  via the tight-frame identity ||S x||^2 = beta ||x||^2 (exact for both
  constructions), and the block-diagonal encoder additionally streams the
  dataset worker-by-worker (``data.stream_worker_blocks``) so not even X
  has to be resident at once.
"""
from __future__ import annotations

import numpy as np

from repro.core import (BlockDiagonalEncoder, FastHadamardEncoder,
                        make_encoder)
from repro.data import lsq_rows, stream_worker_blocks

from .common import emit, time_us


def _feasible(n: int = 4096, p: int = 32):
    rng = np.random.default_rng(0)
    X = rng.standard_normal((n, p))
    dense = make_encoder("hadamard", n, beta=2.0)
    fast = FastHadamardEncoder(n, 2.0, seed=0)
    block = BlockDiagonalEncoder(n, 2.0, seed=0, block_size=64)
    for tag, enc in [("dense", dense), ("fast_hadamard", fast),
                     ("block_diagonal", block)]:
        us = time_us(enc.encode, X, iters=3)
        emit(f"encode_{tag}_n{n}", us,
             f"rows={enc.rows};beta={enc.beta:.2f}")
    return n


def _infeasible(p: int = 4, m: int = 16):
    n = 1 << 15                       # 32768
    dense_bytes = int(2 * n) * n * 8  # (beta*n, n) float64
    assert dense_bytes > 8 * 1024 ** 3, "demo must exceed 8 GB dense"
    rng = np.random.default_rng(1)
    X = rng.standard_normal((n, p))
    x = rng.standard_normal(n)

    fast = FastHadamardEncoder(n, 2.0, seed=0)
    us = time_us(fast.encode, X, iters=1)
    Sx = np.asarray(fast.encode(x), np.float64)
    tf_err = abs(Sx @ Sx / (fast.beta * x @ x) - 1.0)
    emit(f"encode_fast_hadamard_n{n}", us,
         f"dense_would_be={dense_bytes / 2 ** 30:.1f}GiB;"
         f"tight_frame_relerr={tf_err:.2e}")

    block = BlockDiagonalEncoder(n, 2.0, seed=0, block_size=64)
    us = time_us(block.encode, X, iters=1)
    Sx = block.encode(x)
    tf_err = abs(Sx @ Sx / (block.beta * x @ x) - 1.0)
    emit(f"encode_block_diagonal_n{n}", us,
         f"dense_would_be={dense_bytes / 2 ** 30:.1f}GiB;"
         f"tight_frame_relerr={tf_err:.2e}")

    # streaming: encode the virtual lsq dataset worker-by-worker; peak input
    # residency is one worker's shard, never the full X.
    benc = block.with_workers(m)
    peak = [0]

    def rows_fn(lo, hi):
        peak[0] = max(peak[0], hi - lo)
        return lsq_rows(lo, hi, p, seed=2)[0]

    def run():
        total = 0
        for _, SXi in stream_worker_blocks(benc, m, rows_fn):
            total += SXi.shape[0]
        return total

    us = time_us(run, iters=1)
    emit(f"encode_streamed_block_diagonal_n{n}", us,
         f"workers={m};peak_input_rows={peak[0]};of_n={n}")


def run():
    _feasible()
    _infeasible()


if __name__ == "__main__":
    run()
