"""Scan-fused runtime runners vs the legacy one-jit-call-per-step loops.

The seed repo dispatched one jitted step per iteration and synced the
objective to host every step; ``repro.runtime.runners`` fuses the whole
(T, m) schedule into a single ``lax.scan`` program.  This benchmark measures
the end-to-end speedup for GD and BCD at paper-native sizes.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (hadamard_encoder, make_encoded_problem, gd_step,
                        make_lifted_problem, original_objective,
                        phi_quadratic, pad_rows, bimodal_delays)
from repro.core.model_parallel import LiftedProblem
from repro.data import lsq_dataset
from repro.runtime.runners import scan_bcd, scan_gd
from .common import emit, masks_from_delays, time_us


def _legacy_gd(prob, masks, step_size):
    """The historical host loop: one dispatch + host sync per iteration."""
    w = jnp.zeros(prob.SX.shape[-1])
    trace = []
    for t in range(masks.shape[0]):
        w = gd_step(prob, w, jnp.asarray(masks[t]), step_size, h="l2")
        trace.append(float(original_objective(prob, w, h="l2")))
    return w, np.asarray(trace)


def _legacy_bcd(prob: LiftedProblem, masks, step_size):
    import jax

    @jax.jit
    def step(v, mask):
        z = jnp.einsum("mnb,mb->mn", prob.XS, v).sum(axis=0)
        d = -step_size * jnp.einsum("mnb,n->mb", prob.XS, prob.phi_grad(z))
        return v + mask[:, None] * d, prob.phi_val(z)

    v = jnp.zeros((prob.XS.shape[0], prob.XS.shape[2]))
    trace = []
    for t in range(masks.shape[0]):
        v, fval = step(v, jnp.asarray(masks[t]))
        trace.append(float(fval))
    return v, np.asarray(trace)


def run(n: int = 1024, p: int = 256, m: int = 16, k: int = 12,
        steps: int = 100):
    X, y, _ = lsq_dataset(n, p, noise=0.5, seed=0)
    L = float(np.linalg.eigvalsh(X.T @ X / n).max())
    step_size = 1.0 / (1.3 * L + 0.05)
    masks, _ = masks_from_delays(bimodal_delays(), m, k, steps, seed=2)
    masks_j = jnp.asarray(masks)

    enc = hadamard_encoder(n, 2.0)
    prob = make_encoded_problem(X, y, enc, m, lam=0.05)
    w0 = jnp.zeros(p)
    us_legacy = time_us(_legacy_gd, prob, masks, step_size, iters=3)
    us_scan = time_us(scan_gd, prob, masks_j, step_size, w0, h="l2", iters=3)
    emit("runtime_gd_legacy_loop", us_legacy, f"steps={steps}")
    emit("runtime_gd_scan_fused", us_scan,
         f"steps={steps};speedup={us_legacy / max(us_scan, 1e-9):.1f}x")

    enc_p = pad_rows(hadamard_encoder(p, 2.0), m)
    val, grad = phi_quadratic(y)
    lifted = make_lifted_problem(X, enc_p, m, val, grad)
    bcd_step = 0.9 / (L * 2.0)
    us_legacy = time_us(_legacy_bcd, lifted, masks, bcd_step, iters=3)
    v0 = jnp.zeros((lifted.XS.shape[0], lifted.XS.shape[2]))
    us_scan = time_us(scan_bcd, lifted, masks_j, bcd_step, v0, iters=3)
    emit("runtime_bcd_legacy_loop", us_legacy, f"steps={steps}")
    emit("runtime_bcd_scan_fused", us_scan,
         f"steps={steps};speedup={us_legacy / max(us_scan, 1e-9):.1f}x")


if __name__ == "__main__":
    run()
