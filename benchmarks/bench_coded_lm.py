"""Coded-DP LM trainer under stragglers (DESIGN §15).

The smoke LM trained through the ``coded-sgd`` strategy for each gradient
code family — exact FRC, exact cyclic-repetition, approximate stochastic —
against the uncoded baselines, all under the paper's bimodal delay model
with fastest-k barriers.  Rows report the host cost of one coded train
step with compile time excluded (``us_per_step`` is the gated number —
``repro.obs.diff --against-baseline BENCH_coded_lm.json`` in CI), plus the
final loss at equal STEPS and the simulated wall-clock — the LM analogue
of Fig 7.

    PYTHONPATH=src python -m benchmarks.bench_coded_lm            # full
    PYTHONPATH=src python -m benchmarks.bench_coded_lm --smoke    # CI preset
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.runtime import ClusterEngine, get_strategy, make_delay_model
from repro.train.coded import TrainProblem

from .common import bench_meta, emit

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(_ROOT, "BENCH_coded_lm.json")

M = 8
# (case, coded-sgd cfg): every code family at the same fastest-k barrier,
# plus the uncoded wait-for-all reference and the uncoded run that simply
# DROPS the stragglers' data (what the codes exist to avoid)
CASES = [
    ("frc_b2_k6", dict(code="frc", beta=2, k=6)),
    ("cyclic_b2_k6", dict(code="cyclic", beta=2, k=6)),
    ("stochastic_b2_k6", dict(code="stochastic", beta=2, k=6)),
    ("uncoded_waitall", dict(code="uncoded", beta=1, k=8)),
    ("uncoded_k6", dict(code="uncoded", beta=1, k=6)),
]


def run(steps: int = 30, seq_len: int = 64,
        out_json: str = DEFAULT_OUT) -> list[dict]:
    spec = TrainProblem(preset="smoke", seq_len=seq_len, vocab=512)
    strat = get_strategy("coded-sgd")
    results = []
    for name, cfg in CASES:
        eng = ClusterEngine(make_delay_model("bimodal"), M, seed=0)
        res = strat.run(spec, eng, steps=steps, **dict(cfg))
        meta = res.meta
        us = (meta["host_s"] - meta["compile_s"]) / steps * 1e6
        final = float(np.mean(np.asarray(res.objective)[-min(5, steps):]))
        sim = float(np.asarray(res.times)[-1])
        emit(f"coded_lm_{name}", us,
             f"final_loss={final:.3f};sim_wallclock_s={sim:.0f};"
             f"exact={meta['exact_fraction']:.2f}")
        results.append({
            "case": name, "steps": steps, "seq_len": seq_len, "m": M,
            "code": meta["code"], "beta": meta["beta"], "k": cfg["k"],
            "us_per_step": us, "compile_s": meta["compile_s"],
            "final_loss": final, "sim_wallclock": sim,
            "exact_fraction": meta["exact_fraction"],
            "mean_active": meta["mean_active"],
        })

    os.makedirs(os.path.dirname(os.path.abspath(out_json)), exist_ok=True)
    with open(out_json, "w") as f:
        json.dump({"bench": "coded-DP LM trainer (DESIGN §15)",
                   "meta": bench_meta(),
                   "results": results}, f, indent=1)
    print(f"# wrote {out_json}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(prog="benchmarks.bench_coded_lm")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq-len", type=int, default=64, dest="seq_len")
    ap.add_argument("--smoke", action="store_true",
                    help="CI preset: the baseline per-step shape (seq 64) "
                         "over 6 steps, so the gated us_per_step aligns "
                         "apples to apples with fewer amortizing steps")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    steps = 6 if args.smoke else args.steps
    print("name,us_per_call,derived")
    return run(steps=steps, seq_len=args.seq_len, out_json=args.out)


if __name__ == "__main__":
    main()
