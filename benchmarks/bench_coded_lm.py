"""Beyond-paper: the coded-DP LM trainer under stragglers (DESIGN §4).

A small LM trained with FRC-coded data parallelism (beta=2, fastest-k) vs
the uncoded wait-for-all baseline, under the paper's bimodal delay model.
Reports final loss at equal STEPS and the simulated wall-clock — the LM
analogue of Fig 7.
"""
from __future__ import annotations

import numpy as np

from repro.configs import ARCHS
from repro.core.straggler import bimodal_delays
from repro.train.trainer import Trainer, TrainerConfig
from .common import emit


def run(steps: int = 30, seq_len: int = 64):
    cfg = ARCHS["deepseek-7b"].smoke_variant().with_overrides(vocab=512)
    rows = []
    for name, beta, k, uncoded in [("coded_b2_k6", 2, 6, False),
                                   ("uncoded_waitall", 1, 8, True),
                                   ("uncoded_k6", 1, 6, True)]:
        tcfg = TrainerConfig(m_workers=8, beta=beta, wait_k=k,
                             rows_per_worker=1, seq_len=seq_len, steps=steps,
                             lr=3e-3, warmup=5, log_every=0, uncoded=uncoded)
        tr = Trainer(cfg, tcfg, delay_model=bimodal_delays())
        import time
        t0 = time.perf_counter()
        _, _, hist = tr.run()
        us = (time.perf_counter() - t0) / steps * 1e6
        final = float(np.mean([h["loss"] for h in hist[-5:]]))
        sim = hist[-1]["sim_time_s"]
        emit(f"coded_lm_{name}", us,
             f"final_loss={final:.3f};sim_wallclock_s={sim:.0f}")
        rows.append((name, final, sim))
    return rows


if __name__ == "__main__":
    run()
