"""Batched-trial execution: sequential per-realization loop vs one vmapped
program (DESIGN.md §9).

The paper's §5 figures average many delay realizations per cell; the
historical harness ran them one at a time — R separate ``scan_gd`` dispatches
with a host sync each.  ``batched_scan_gd`` runs the whole (R, T, m) schedule
stack inside one jit.  This benchmark measures that speedup on the ridge
smoke preset at R ∈ {1, 4, 16, 64}, verifies the per-realization traces
match sequential execution to 1e-5, and writes ``BENCH_trials.json`` at the
repo root so future PRs have a trajectory to compare against.

    PYTHONPATH=src python -m benchmarks.bench_trials            # full
    PYTHONPATH=src python -m benchmarks.bench_trials --smoke    # CI preset
"""
from __future__ import annotations

import argparse
import json
import os

import jax.numpy as jnp
import numpy as np

from repro.core import hadamard_encoder, make_encoded_problem, pad_rows
from repro.runtime import ClusterEngine, FastestK, make_delay_model
from repro.runtime.runners import batched_scan_gd, scan_gd
from repro.workloads import get_workload

from .common import bench_meta, emit, time_us

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(_ROOT, "BENCH_trials.json")


def _setup(preset: str = "smoke"):
    """The ridge workload preset, lowered once: encoded problem + engine."""
    wl = get_workload("ridge")
    ps = wl.preset(preset)
    data = wl.build(ps)
    spec = data.spec
    enc = pad_rows(hadamard_encoder(spec.n, 2.0), ps.m)
    prob = make_encoded_problem(spec.X, spec.y, enc, ps.m, lam=spec.lam)
    engine = ClusterEngine(make_delay_model(ps.delay), ps.m, seed=0)
    step_size = 1.0 / (1.3 * spec.lipschitz() + spec.lam)
    return ps, prob, engine, step_size


def _sequential(prob, masks, step_size, p):
    """The pre-batching harness: one fused scan per realization, host sync
    between realizations."""
    outs = []
    for r in range(masks.shape[0]):
        w, tr = scan_gd(prob, masks[r], step_size, jnp.zeros(p))
        outs.append((np.asarray(w), np.asarray(tr)))
    return outs


def _batched(prob, masks, step_size, p, eval_every=1):
    R = masks.shape[0]
    # fresh (R, p) start stack per call — the runner donates the carry
    return batched_scan_gd(prob, masks, step_size, jnp.zeros((R, p)),
                           eval_every=eval_every)


def run(trials=(1, 4, 16, 64), iters: int = 3, preset: str = "smoke",
        out_json: str = DEFAULT_OUT) -> list[dict]:
    ps, prob, engine, step_size = _setup(preset)
    p = prob.SX.shape[-1]
    results = []
    for R in trials:
        batch = engine.sample_schedules(ps.steps, FastestK(ps.k), R)
        masks = jnp.asarray(batch.masks)

        seq = _sequential(prob, masks, step_size, p)
        w_b, tr_b = _batched(prob, masks, step_size, p)
        err = max(float(np.abs(np.asarray(tr_b)[r] - seq[r][1]).max())
                  for r in range(R))
        match = err < 1e-5

        us_seq = time_us(_sequential, prob, masks, step_size, p, iters=iters)
        us_bat = time_us(_batched, prob, masks, step_size, p, iters=iters)
        us_strided = time_us(_batched, prob, masks, step_size, p,
                             eval_every=min(ps.steps, 10), iters=iters)
        speedup = us_seq / max(us_bat, 1e-9)
        emit(f"trials_sequential_R{R}", us_seq, f"steps={ps.steps}")
        emit(f"trials_batched_R{R}", us_bat,
             f"speedup={speedup:.1f}x;traces_match={match}")
        emit(f"trials_batched_eval10_R{R}", us_strided,
             f"speedup={us_seq / max(us_strided, 1e-9):.1f}x")
        results.append({
            "R": R, "preset": ps.name, "steps": ps.steps, "m": ps.m,
            "k": ps.k, "n": int(prob.n), "p": int(p),
            "us_sequential": us_seq, "us_batched": us_bat,
            "us_batched_eval_every_10": us_strided,
            "speedup": speedup, "traces_match": bool(match),
            "max_abs_trace_err": err,
        })
    os.makedirs(os.path.dirname(os.path.abspath(out_json)), exist_ok=True)
    with open(out_json, "w") as f:
        json.dump({"bench": "batched-trials (ridge smoke, scan_gd)",
                   "meta": bench_meta(),
                   "backend": _backend(), "results": results}, f, indent=1)
    print(f"# wrote {out_json}")
    return results


def _backend() -> str:
    import jax
    return jax.default_backend()


def main(argv=None):
    ap = argparse.ArgumentParser(prog="benchmarks.bench_trials")
    ap.add_argument("--trials", default="1,4,16,64",
                    help="comma list of realization counts R")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--preset", default="smoke",
                    choices=["smoke", "bench", "paper"])
    ap.add_argument("--smoke", action="store_true",
                    help="CI preset: R in {1, 4}, 2 timing iters")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    if args.smoke:
        trials, iters = (1, 4), 2
    else:
        trials = tuple(int(r) for r in args.trials.split(",") if r.strip())
        iters = args.iters
    print("name,us_per_call,derived")
    return run(trials=trials, iters=iters, preset=args.preset,
               out_json=args.out)


if __name__ == "__main__":
    main()
