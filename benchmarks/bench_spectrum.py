"""Paper Figs 5-6: subset eigenvalue spectra of S_A^T S_A per construction.

Reports the spread (q10/q50/q90, min/max) of the normalized subset Gram
eigenvalues — ETFs should concentrate around 1 far more tightly than
Gaussian, matching the figures.
"""
from __future__ import annotations

import numpy as np

from repro.core import make_encoder, pad_rows, subset_spectrum
from .common import emit, time_us


def run(n: int = 128, m: int = 16, k: int = 12, trials: int = 30):
    rows = []
    for name in ["hadamard", "paley", "steiner", "haar", "gaussian",
                 "replication"]:
        enc = pad_rows(make_encoder(name, n, beta=2.0), m)
        us = time_us(subset_spectrum, enc, m, k, trials=trials, iters=1)
        ev = subset_spectrum(enc, m, k, trials=trials)
        q10, q50, q90 = np.quantile(ev, [0.1, 0.5, 0.9])
        derived = (f"eig_q10={q10:.3f};q50={q50:.3f};q90={q90:.3f};"
                   f"min={ev.min():.3f};max={ev.max():.3f}")
        emit(f"spectrum_{name}", us, derived)
        rows.append((name, q10, q50, q90, ev.min(), ev.max()))
    return rows


if __name__ == "__main__":
    run()
