"""Fault-injection overhead (DESIGN.md §14).

The fault subsystem's contract is that it costs nothing when unused: with
``faults=None`` the engine's samplers run the exact pre-fault code path
behind a single is-None check, so the hot vectorized fastest-k sampler must
stay within noise of its pre-fault timing (``sample_nofault`` is the gated
number — ``repro.obs.diff --against-baseline BENCH_faults.json`` in CI).
The other rows price what faults DO cost when enabled:

  * ``sample_zero_fault_model`` — a fault model attached but realizing no
    faults: the per-step fault loop replaces the vectorized sampler (and
    must still reproduce the clean schedule bit for bit);
  * ``sample_chaos`` — crashes + blackouts + corruption composed;
  * ``cell_chaos_*`` — an end-to-end batched coded-gd cell under chaos for
    each degradation mode (renormalize / hold / backoff), against the
    clean-cell reference.

    PYTHONPATH=src python -m benchmarks.bench_faults            # full
    PYTHONPATH=src python -m benchmarks.bench_faults --smoke    # CI preset
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.runtime import (ClusterEngine, FastestK, ProblemSpec,
                           get_strategy, make_delay_model)

from .common import bench_meta, emit, time_us

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(_ROOT, "BENCH_faults.json")

CHAOS = ("crash:p=0.2,at=0.4;blackout:p=0.2,at=0.2,dur=0.5;"
         "corrupt:p=0.05")
M, K = 16, 12


def _engine(faults=None):
    return ClusterEngine(make_delay_model("bimodal"), M, seed=0,
                         faults=faults)


def run(steps: int = 200, trials: int = 16, iters: int = 5,
        out_json: str = DEFAULT_OUT) -> list[dict]:
    results = []

    def sample(eng):
        return eng.sample_schedules(steps, FastestK(K), trials)

    clean_eng, zerop_eng, chaos_eng = (_engine(), _engine("crash:p=0,at=0.5"),
                                       _engine(CHAOS))
    # correctness first: an attached-but-empty fault model must reproduce
    # the clean schedule bit for bit (tagged fault rng stream)
    clean, zerop = sample(clean_eng), sample(zerop_eng)
    identical = bool(np.array_equal(clean.masks, zerop.masks)
                     and np.array_equal(clean.times, zerop.times))

    us_clean = time_us(sample, clean_eng, iters=iters)
    us_zerop = time_us(sample, zerop_eng, iters=iters)
    us_chaos = time_us(sample, chaos_eng, iters=iters)
    emit("sample_nofault", us_clean, f"R={trials};T={steps};m={M}")
    emit("sample_zero_fault_model", us_zerop,
         f"vs_nofault={us_zerop / max(us_clean, 1e-9):.2f}x;"
         f"bit_identical={identical}")
    emit("sample_chaos", us_chaos,
         f"vs_nofault={us_chaos / max(us_clean, 1e-9):.2f}x")
    results.append({
        "case": "sampling", "R": trials, "T": steps, "m": M, "k": K,
        "us_nofault": us_clean, "us_zero_fault_model": us_zerop,
        "us_chaos": us_chaos, "zero_model_bit_identical": identical,
    })

    # end-to-end cells: one batched coded-gd matrix cell, clean vs chaos
    # under each degradation mode (schedule sampling + fused device scan)
    spec = ProblemSpec.synthetic(512, 128, seed=0)
    strat = get_strategy("coded-gd")

    def cell(eng, **cfg):
        return strat.run_batched(spec, eng, steps=steps, trials=trials,
                                 eval_every=10, k=K, **cfg)

    us_cell_clean = time_us(cell, clean_eng, iters=iters)
    emit("cell_clean", us_cell_clean, f"R={trials};T={steps}")
    row = {"case": "cell", "R": trials, "T": steps,
           "us_clean": us_cell_clean}
    for mode, cfg in [("renormalize", {}),
                      ("hold", {"degrade": "hold:shrink=0.5"}),
                      ("backoff", {"degrade": "backoff:base=0.05,retries=3"})]:
        us = time_us(cell, chaos_eng, iters=iters, **cfg)
        emit(f"cell_chaos_{mode}", us,
             f"vs_clean={us / max(us_cell_clean, 1e-9):.2f}x")
        row[f"us_chaos_{mode}"] = us
    results.append(row)

    os.makedirs(os.path.dirname(os.path.abspath(out_json)), exist_ok=True)
    with open(out_json, "w") as f:
        json.dump({"bench": "fault-injection overhead (DESIGN §14)",
                   "meta": bench_meta(),
                   "results": results}, f, indent=1)
    print(f"# wrote {out_json}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(prog="benchmarks.bench_faults")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--trials", type=int, default=16)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--smoke", action="store_true",
                    help="CI preset: baseline shape (T=200, R=16) with 2 "
                         "timing iters, so the regression gate aligns "
                         "apples to apples")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    if args.smoke:
        steps, trials, iters = 200, 16, 2
    else:
        steps, trials, iters = args.steps, args.trials, args.iters
    print("name,us_per_call,derived")
    return run(steps=steps, trials=trials, iters=iters, out_json=args.out)


if __name__ == "__main__":
    main()
