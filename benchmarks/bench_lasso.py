"""Paper Fig 14 + §5.4: LASSO sparsity recovery (F1) via encoded proximal
gradient under the multimodal delay distribution.

Schemes: uncoded k=m (slow, exact), uncoded k<m (fast, lossy), replication
k<m, Steiner/Hadamard-coded k<m (fast AND accurate), each also under the
adversarial rotation.  Data, ground truth (FISTA optimum + planted support)
and the F1 metric come from the ``lasso`` workload — this module only
enumerates the scheme table and emits CSV.
"""
from __future__ import annotations

import time

from repro.runtime import AdversarialRotation
from repro.workloads import get_workload

from .common import emit


def run(preset: str = "bench"):
    wl = get_workload("lasso")
    ps = wl.preset(preset)
    data = wl.build(ps)
    engine = wl.default_engine(ps)
    m = ps.m
    k = (3 * m) // 4

    schemes = [
        (f"uncoded_k{m}", "uncoded", {"k": m}),
        (f"uncoded_k{k}", "uncoded", {"k": k}),
        (f"replication_k{k}", "replication", {"k": k}),
        (f"steiner_k{k}", "coded-prox", {"k": k, "encoder": "steiner"}),
        (f"hadamard_k{k}", "coded-prox", {"k": k, "encoder": "hadamard"}),
        (f"uncoded_k{k}_adv", "uncoded", {"policy": AdversarialRotation(k)}),
        (f"replication_k{k}_adv", "replication",
         {"policy": AdversarialRotation(k)}),
        (f"steiner_k{k}_adv", "coded-prox",
         {"policy": AdversarialRotation(k), "encoder": "steiner"}),
        (f"hadamard_k{k}_adv", "coded-prox",
         {"policy": AdversarialRotation(k), "encoder": "hadamard"}),
    ]
    results = []
    for name, strategy, cfg in schemes:
        t0 = time.perf_counter()
        res = wl.run(strategy, engine, preset=ps, data=data, **cfg)
        us = (time.perf_counter() - t0) / ps.steps * 1e6
        emit(f"lasso_{name}", us,
             f"f1={res.final_metric:.3f};final_obj={res.final_objective:.4f};"
             f"sim_wallclock_s={res.wallclock:.1f}")
        results.append((name, res.final_metric, res.final_objective,
                        res.wallclock))
    return results


if __name__ == "__main__":
    run()
