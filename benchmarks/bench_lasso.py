"""Paper Fig 14 + §5.4: LASSO sparsity recovery (F1) via encoded proximal
gradient under the multimodal delay distribution.

Schemes: uncoded k=m (slow, exact), uncoded k<m (fast, lossy), replication
k<m, Steiner/Hadamard-coded k<m (fast AND accurate).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import (make_encoder, pad_rows, make_encoded_problem,
                        run_encoded_proximal, multimodal_delays)
from repro.data import lsq_dataset
from .common import emit, masks_from_delays


def _f1(w_hat, w_true, tol=1e-3):
    nz_hat = np.abs(w_hat) > tol
    nz_true = np.abs(w_true) > 0
    tp = (nz_hat & nz_true).sum()
    prec = tp / max(nz_hat.sum(), 1)
    rec = tp / max(nz_true.sum(), 1)
    return 2 * prec * rec / max(prec + rec, 1e-9)


def run(n: int = 1024, p: int = 512, s: int = 40, m: int = 32,
        steps: int = 250, lam: float = 0.08):
    X, y, w_true = lsq_dataset(n, p, noise=0.4, sparse=s, seed=0)
    L = np.linalg.eigvalsh(X.T @ X / n).max()
    results = []
    for name, enc_name, k, sched in [
            ("uncoded_k32", "uncoded", 32, "rand"),
            ("uncoded_k24", "uncoded", 24, "rand"),
            ("replication_k24", "replication", 24, "rand"),
            ("steiner_k24", "steiner", 24, "rand"),
            ("hadamard_k24", "hadamard", 24, "rand"),
            ("uncoded_k24_adv", "uncoded", 24, "adv"),
            ("replication_k24_adv", "replication", 24, "adv"),
            ("steiner_k24_adv", "steiner", 24, "adv"),
            ("hadamard_k24_adv", "hadamard", 24, "adv")]:
        enc = make_encoder(enc_name, n,
                           beta=1.0 if enc_name == "uncoded" else 2.0)
        enc = pad_rows(enc, m)
        prob = make_encoded_problem(X, y, enc, m, lam=lam)
        if sched == "adv":
            from repro.core import adversarial_sets, active_mask
            masks = np.stack([active_mask(m, A) for A in
                              adversarial_sets(m, k, steps)])
            times = np.cumsum(np.full(steps, 1.0))
        else:
            masks, times = masks_from_delays(multimodal_delays(), m, k,
                                             steps, seed=3)
        import time
        t0 = time.perf_counter()
        w, tr = run_encoded_proximal(prob, masks, step_size=0.5 / L)
        us = (time.perf_counter() - t0) / steps * 1e6
        f1 = _f1(np.asarray(w), w_true)
        emit(f"lasso_{name}", us,
             f"f1={f1:.3f};final_obj={tr[-1]:.4f};"
             f"sim_wallclock_s={times[-1]:.1f}")
        results.append((name, f1, tr[-1], times[-1]))
    return results


if __name__ == "__main__":
    run()
