"""Placement-axis benchmark: single vs vmap vs sharded trial execution
(DESIGN.md §10).

The same declarative experiment — the ridge workload's smoke preset,
coded-gd, one delay model, R delay realizations — run under each
``PlacementAxis`` mode, timed end-to-end through ``plan -> execute`` (so
schedule sampling, scoring and record building are all included, exactly
what a user of the matrix pays).  On a 1-device CPU host ``sharded`` falls
back to ``vmap`` (the record carries the device count, so trajectories
from multi-device hosts are distinguishable), and the traces of all three
placements are verified to agree to 1e-5.

Writes ``BENCH_experiments.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.bench_experiments            # full
    PYTHONPATH=src python -m benchmarks.bench_experiments --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.experiments import (DelayAxis, ExperimentSpec, PlacementAxis,
                               ProblemAxis, StrategyAxis, TrialsAxis,
                               execute, plan)

from .common import bench_meta, emit

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(_ROOT, "BENCH_experiments.json")

PLACEMENTS = ("single", "vmap", "sharded")


def _spec(placement: str, trials: int, steps: int) -> ExperimentSpec:
    return ExperimentSpec(
        problems=(ProblemAxis.from_workload("ridge", "smoke"),),
        strategies=(StrategyAxis("coded-gd"),),
        delays=DelayAxis.of("bimodal"),
        trials=TrialsAxis(trials=trials),
        placement=PlacementAxis(mode=placement), steps=steps)


def _time_execute(spec: ExperimentSpec, iters: int) -> tuple[float, list]:
    pl = plan(spec)
    # record_to=False keeps manifest I/O out of the timed loop
    execute(pl, record_to=False)              # warm the jit caches
    t0 = time.perf_counter()
    for _ in range(iters):
        result = execute(pl, record_to=False)
    return (time.perf_counter() - t0) / iters, result.records


def run(trials: int = 16, steps: int = 40, iters: int = 3,
        out_json: str = DEFAULT_OUT) -> list[dict]:
    import jax
    ndev = len(jax.devices())
    results, traces = [], {}
    base_s = None
    for placement in PLACEMENTS:
        secs, records = _time_execute(_spec(placement, trials, steps), iters)
        rec = records[0]
        traces[placement] = np.asarray(rec["objective"], dtype=float)
        base_s = secs if base_s is None else base_s
        speedup = base_s / max(secs, 1e-12)
        meta = rec.get("meta", {})
        emit(f"experiments_{placement}_R{trials}", secs * 1e6,
             f"speedup_vs_single={speedup:.1f}x;devices={ndev}")
        results.append({
            "placement": placement, "R": trials, "steps": steps,
            "devices": ndev,
            "placement_devices": meta.get("placement_devices"),
            "seconds_per_matrix": secs,
            "speedup_vs_single": speedup,
        })
    err = max(float(np.abs(traces[p] - traces["vmap"]).max())
              for p in PLACEMENTS)
    for r in results:
        r["traces_match"] = bool(err < 1e-5)
        r["max_abs_trace_err"] = err
    os.makedirs(os.path.dirname(os.path.abspath(out_json)), exist_ok=True)
    with open(out_json, "w") as f:
        json.dump({"bench": "experiment placement axis (ridge smoke, "
                            "coded-gd)",
                   "meta": bench_meta(),
                   "backend": jax.default_backend(), "devices": ndev,
                   "results": results}, f, indent=1)
    print(f"# wrote {out_json}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(prog="benchmarks.bench_experiments")
    ap.add_argument("--trials", type=int, default=16)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="CI preset: R=4, 12 steps, 1 timing iter")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    if args.smoke:
        trials, steps, iters = 4, 12, 1
    else:
        trials, steps, iters = args.trials, args.steps, args.iters
    print("name,us_per_call,derived")
    return run(trials=trials, steps=steps, iters=iters, out_json=args.out)


if __name__ == "__main__":
    main()
