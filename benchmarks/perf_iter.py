"""§Perf hillclimb driver: run one (arch, shape) dry-run with config
overrides and print the roofline terms compactly (+ hotspots on demand).

  PYTHONPATH=src python -m benchmarks.perf_iter --arch starcoder2-3b \
      --shape prefill_32k --override '{"seq_parallel_attn": true}' --hotspots

Appends one CSV row per invocation to runs/perf_log.csv.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import csv
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--override", default="{}")
    ap.add_argument("--tag", default="")
    ap.add_argument("--hotspots", action="store_true")
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--log", default="runs/perf_log.csv")
    args = ap.parse_args()

    from repro.launch.dryrun import dryrun_one
    rec = dryrun_one(args.arch, args.shape, args.multi, verbose=False,
                     extra_overrides=json.loads(args.override),
                     hotspots=args.hotspots)
    rl = rec["roofline"]
    row = {
        "tag": args.tag or args.override,
        "arch": args.arch, "shape": args.shape,
        "compute_s": round(rl["compute_s"], 3),
        "memory_s": round(rl["memory_s"], 3),
        "collective_s": round(rl["collective_s"], 3),
        "bottleneck": rl["bottleneck"],
        "useful_ratio": round(rl.get("useful_ratio", 0), 4),
        "allgather_GB": round(rec["collectives"]["all-gather"] / 1e9, 1),
        "allreduce_GB": round(rec["collectives"]["all-reduce"] / 1e9, 1),
        "a2a_GB": round(rec["collectives"]["all-to-all"] / 1e9, 1),
        "permute_GB": round(
            rec["collectives"]["collective-permute"] / 1e9, 1),
        "compile_s": rec["compile_s"],
    }
    print(json.dumps(row, indent=1))
    os.makedirs(os.path.dirname(args.log), exist_ok=True)
    exists = os.path.exists(args.log)
    with open(args.log, "a", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(row))
        if not exists:
            w.writeheader()
        w.writerow(row)


if __name__ == "__main__":
    main()
