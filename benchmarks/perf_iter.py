"""§Perf hillclimb driver: run one (arch, shape) dry-run with config
overrides and print the roofline terms compactly (+ hotspots on demand).

  PYTHONPATH=src python -m benchmarks.perf_iter --arch starcoder2-3b \
      --shape prefill_32k --override '{"seq_parallel_attn": true}' --hotspots

Appends one CSV row per invocation to runs/perf_log.csv.

The dry-run needs the 512-device host platform, which must be configured
before jax initializes — so ``main()`` sets ``XLA_FLAGS`` (and the
``benchmarks.run`` suite invokes this module as a subprocess rather than
in-process, where jax is long since initialized with the real device
count).
"""
import argparse
import csv
import json
import os

XLA_DEVICE_FLAGS = "--xla_force_host_platform_device_count=512"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--override", default="{}")
    ap.add_argument("--tag", default="")
    ap.add_argument("--hotspots", action="store_true")
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--log", default="runs/perf_log.csv")
    args = ap.parse_args(argv)

    # must land before the first jax import touches the backend
    os.environ["XLA_FLAGS"] = XLA_DEVICE_FLAGS

    from repro.launch.dryrun import dryrun_one
    rec = dryrun_one(args.arch, args.shape, args.multi, verbose=False,
                     extra_overrides=json.loads(args.override),
                     hotspots=args.hotspots)
    rl = rec["roofline"]
    row = {
        "tag": args.tag or args.override,
        "arch": args.arch, "shape": args.shape,
        "compute_s": round(rl["compute_s"], 3),
        "memory_s": round(rl["memory_s"], 3),
        "collective_s": round(rl["collective_s"], 3),
        "bottleneck": rl["bottleneck"],
        "useful_ratio": round(rl.get("useful_ratio", 0), 4),
        "allgather_GB": round(rec["collectives"]["all-gather"] / 1e9, 1),
        "allreduce_GB": round(rec["collectives"]["all-reduce"] / 1e9, 1),
        "a2a_GB": round(rec["collectives"]["all-to-all"] / 1e9, 1),
        "permute_GB": round(
            rec["collectives"]["collective-permute"] / 1e9, 1),
        "compile_s": rec["compile_s"],
    }
    print(json.dumps(row, indent=1))
    os.makedirs(os.path.dirname(args.log), exist_ok=True)
    exists = os.path.exists(args.log)
    with open(args.log, "a", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(row))
        if not exists:
            w.writeheader()
        w.writerow(row)
    return row


def run() -> None:
    """The ``benchmarks.run`` suite entry: one smoke (arch, shape) dry-run
    in a subprocess (the 512-device XLA flag cannot be applied to an
    already-initialized in-process jax), emitted in the suite's
    ``name,us_per_call,derived`` CSV convention."""
    import subprocess
    import sys
    import tempfile
    import time

    from .common import emit

    with tempfile.TemporaryDirectory() as td:
        log = os.path.join(td, "perf_log.csv")
        t0 = time.perf_counter()
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.perf_iter",
             "--arch", "xlstm-350m", "--shape", "train_4k",
             "--tag", "suite-smoke", "--log", log],
            capture_output=True, text=True, timeout=900,
            env={**os.environ,
                 "PYTHONPATH": os.pathsep.join(
                     p for p in (os.environ.get("PYTHONPATH"), "src") if p)})
        wall_us = (time.perf_counter() - t0) * 1e6
        if proc.returncode != 0:
            raise RuntimeError(
                f"perf_iter subprocess failed:\n{proc.stderr[-2000:]}")
        out = proc.stdout
        row = json.loads(out[out.index("{"):])
    emit("perf_iter_xlstm350m_train4k", wall_us,
         f"bottleneck={row['bottleneck']};compute_s={row['compute_s']};"
         f"useful_ratio={row['useful_ratio']}")


if __name__ == "__main__":
    main()
