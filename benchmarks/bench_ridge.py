"""Paper Fig 7: ridge regression with distributed encoded L-BFGS.

Schemes: uncoded (k=m and k<m), replication, Hadamard(FWHT)-coded; bimodal
delay distribution plus the deterministic adversarial rotation.  Problem
setup, ground truth and scoring come from the ``ridge`` workload
(``repro.workloads``) — this module only enumerates the scheme table and
emits CSV.  Reports iterations-to-tolerance, final suboptimality f/f* - 1,
and SIMULATED wall-clock.
"""
from __future__ import annotations

import time

import numpy as np

from repro.runtime import AdversarialRotation
from repro.workloads import get_workload

from .common import emit


def run(preset: str = "bench"):
    wl = get_workload("ridge")
    ps = wl.preset(preset)
    data = wl.build(ps)
    engine = wl.default_engine(ps)
    m = ps.m

    k_mid, k_lo = (3 * m) // 4, m // 2 - m // 8
    schemes = [
        (f"uncoded_k{m}", "uncoded", {"k": m}),
        (f"uncoded_k{k_mid}", "uncoded", {"k": k_mid}),
        (f"replication_k{k_mid}", "replication", {"k": k_mid}),
        (f"hadamard_k{k_mid}", "coded-lbfgs", {"k": k_mid}),
        (f"hadamard_k{k_lo}", "coded-lbfgs", {"k": k_lo}),
        # worst-case erasure schedule — the paper's deterministic guarantee
        (f"uncoded_k{k_mid}_adv", "uncoded",
         {"policy": AdversarialRotation(k_mid)}),
        (f"replication_k{k_mid}_adv", "replication",
         {"policy": AdversarialRotation(k_mid)}),
        (f"hadamard_k{k_mid}_adv", "coded-lbfgs",
         {"policy": AdversarialRotation(k_mid)}),
    ]
    results = []
    for name, strategy, cfg in schemes:
        t0 = time.perf_counter()
        res = wl.run(strategy, engine, preset=ps, data=data, **cfg)
        us = (time.perf_counter() - t0) / ps.steps * 1e6
        f_star = data.f_star
        subopt = res.final_objective / f_star - 1.0
        hits = np.nonzero(np.asarray(res.objective) <= 1.01 * f_star)[0]
        hit = int(hits[0]) if hits.size else -1
        derived = (f"subopt={subopt:.2e};iters_to_1pct={hit};"
                   f"sim_wallclock_s={res.times[hit]:.1f}" if hit >= 0
                   else f"subopt={subopt:.2e};iters_to_1pct=inf")
        emit(f"ridge_{name}", us, derived)
        results.append((name, subopt, hit,
                        res.times[hit] if hit >= 0 else np.inf))
    return results


if __name__ == "__main__":
    run()
