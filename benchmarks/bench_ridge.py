"""Paper Fig 7: ridge regression with distributed encoded L-BFGS.

Schemes: uncoded (k=m and k<m), replication, Hadamard(FWHT)-coded; bimodal
delay distribution.  Reports iterations-to-tolerance, final suboptimality
f/f* - 1, and SIMULATED wall-clock (k-th order statistic per iteration,
same accounting as the paper's runtime plots).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import (make_encoder, make_encoded_problem,
                        run_encoded_lbfgs, original_objective,
                        bimodal_delays, adversarial_sets, active_mask)
from repro.data import lsq_dataset
from .common import emit, masks_from_delays


def run(n: int = 1024, p: int = 512, m: int = 32, steps: int = 40,
        lam: float = 0.05):
    X, y, _ = lsq_dataset(n, p, noise=1.0, seed=0)
    w_star = np.linalg.solve(X.T @ X / n + lam * np.eye(p), X.T @ y / n)

    schemes = [
        ("uncoded_k32", "uncoded", 32, "bimodal"),
        ("uncoded_k24", "uncoded", 24, "bimodal"),
        ("replication_k24", "replication", 24, "bimodal"),
        ("hadamard_k24", "hadamard", 24, "bimodal"),
        ("hadamard_k12", "hadamard", 12, "bimodal"),
        # worst-case erasure schedule — the paper's deterministic guarantee
        ("uncoded_k24_adv", "uncoded", 24, "adversarial"),
        ("replication_k24_adv", "replication", 24, "adversarial"),
        ("hadamard_k24_adv", "hadamard", 24, "adversarial"),
    ]
    results = []
    for name, enc_name, k, sched in schemes:
        enc = make_encoder(enc_name, n, beta=1.0 if enc_name == "uncoded"
                           else 2.0, seed=1)
        prob = make_encoded_problem(X, y, enc, m, lam=lam)
        f_star = float(original_objective(prob, jnp.asarray(w_star), h="l2"))
        if sched == "adversarial":
            masks = np.stack([active_mask(m, A) for A in
                              adversarial_sets(m, k, steps)])
            times = np.cumsum(np.full(steps, 20.0))  # stragglers always slow
        else:
            masks, times = masks_from_delays(bimodal_delays(), m, k, steps,
                                             seed=2)
        import time
        t0 = time.perf_counter()
        _, tr = run_encoded_lbfgs(prob, masks, memory=10)
        us = (time.perf_counter() - t0) / steps * 1e6
        subopt = tr[-1] / f_star - 1.0
        # iterations to reach 1% suboptimality
        hit = np.argmax(tr <= 1.01 * f_star) if (tr <= 1.01 * f_star).any() \
            else -1
        derived = (f"subopt={subopt:.2e};iters_to_1pct={hit};"
                   f"sim_wallclock_s={times[min(hit, steps - 1)]:.1f}" if
                   hit >= 0 else f"subopt={subopt:.2e};iters_to_1pct=inf")
        emit(f"ridge_{name}", us, derived)
        results.append((name, subopt, hit,
                        times[min(hit, steps - 1)] if hit >= 0 else np.inf))
    return results


if __name__ == "__main__":
    run()
