"""Fused masked-gradient path benchmark (DESIGN.md §12).

Two parts, one JSON:

* **kernel** — ``fused_masked_gradient`` (one Pallas call: residual
  matvec, erasure mask, decode-weighted combine, VMEM accumulator)
  against the dense three-``einsum`` reference, on the real encoded
  ridge-smoke operands plus a compare-scale shape.  Records mean us per
  call, the analytic FLOP count (4 m r p: two matvecs at 2 flops/MAC)
  and the ideal HBM byte traffic — ``benchmarks.roofline --fused`` turns
  these into achieved-vs-peak utilization.  On CPU the kernel runs in
  interpret mode (recorded as such; interpret timings measure the
  emulator, not the TPU dataflow).

* **matrix** — the paper's R=16 ridge matrix, device-resident: the ridge
  smoke problem (same data/shape as ``BENCH_experiments.json``'s cell)
  as a C=8-cell coded-gd matrix (2 delay models x 4 step-size variants),
  run through ``plan -> execute`` per-cell and with
  ``PlacementAxis(cell_batch=True)`` (one compiled program per
  compatible group).  Reports seconds/cell for both, the cell-batch
  speedup, the speedup over the recorded vmap baseline in
  ``BENCH_experiments.json``, and the max objective-trace difference
  between the two paths (must be <= 1e-4; in practice bit-identical).

Writes ``BENCH_fused.json`` at the repo root.

    PYTHONPATH=src python -m benchmarks.bench_fused            # full
    PYTHONPATH=src python -m benchmarks.bench_fused --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from .common import emit, time_us

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(_ROOT, "BENCH_fused.json")
BASELINE_JSON = os.path.join(_ROOT, "BENCH_experiments.json")


# ---------------------------------------------------------------------------
# Part A: the fused kernel vs the dense reference
# ---------------------------------------------------------------------------

def _dense_reference(SX, Sy, w, mask, *, n, beta):
    import jax.numpy as jnp
    k = jnp.maximum(mask.sum(), 1.0)
    c = mask * (SX.shape[0] / k) / (n * beta)
    u = jnp.einsum("mrp,p->mr", SX, w) - Sy
    return jnp.einsum("m,mrp,mr->p", c, SX, u).astype(w.dtype)


def _kernel_cases(smoke: bool):
    """(label, m, r, p) shapes; the first is the REAL encoded ridge smoke
    problem (built below), the rest synthetic at compare scale."""
    cases = [("ridge_smoke", None)]          # filled from the workload
    if not smoke:
        cases.append(("compare_m16", (16, 64, 128)))
    return cases


def _ridge_encoded():
    """The actual encoded operands of the ridge smoke cell."""
    from repro.core.data_parallel import make_encoded_problem
    from repro.runtime.strategies import _resolve_encoder
    from repro.workloads import get_workload

    data = get_workload("ridge").build("smoke")
    spec = data.spec
    m = 8
    enc = _resolve_encoder("hadamard", spec.n, beta=2.0, seed=0, m=m)
    prob = make_encoded_problem(spec.X, spec.y, enc, m, lam=spec.lam)
    return prob


def bench_kernel(smoke: bool, iters: int) -> list[dict]:
    import jax
    import jax.numpy as jnp

    from repro.kernels.fused_step import (fused_masked_gradient,
                                          pick_fused_block_rows)

    interpret = jax.default_backend() != "tpu"
    rows = []
    for label, shape in _kernel_cases(smoke):
        if shape is None:
            prob = _ridge_encoded()
            SX, Sy = prob.SX, prob.Sy
            n, beta = prob.n, prob.beta
        else:
            m_, r_, p_ = shape
            rng = np.random.default_rng(0)
            SX = jnp.asarray(rng.standard_normal((m_, r_, p_)), jnp.float32)
            Sy = jnp.asarray(rng.standard_normal((m_, r_)), jnp.float32)
            n, beta = m_ * r_ // 2, 2.0
        m, r, p = SX.shape
        rng = np.random.default_rng(1)
        w = jnp.asarray(rng.standard_normal(p), jnp.float32)
        mask = jnp.asarray(rng.random(m) < 0.75, jnp.float32)

        fused = jax.jit(lambda SX, Sy, w, mask: fused_masked_gradient(
            SX, Sy, w, mask, n=n, beta=beta, interpret=interpret))
        dense = jax.jit(lambda SX, Sy, w, mask: _dense_reference(
            SX, Sy, w, mask, n=n, beta=beta))

        err = float(jnp.abs(fused(SX, Sy, w, mask)
                            - dense(SX, Sy, w, mask)).max())
        assert err <= 1e-4, f"fused kernel diverged: {err}"

        us_fused = time_us(fused, SX, Sy, w, mask, iters=iters)
        us_dense = time_us(dense, SX, Sy, w, mask, iters=iters)
        flops = 4 * m * r * p
        bytes_ideal = 4 * (m * r * p + m * r + p + p)
        mode = "interpret" if interpret else "compiled"
        emit(f"fused_kernel_{label}", us_fused,
             f"dense_us={us_dense:.1f};mode={mode};err={err:.2e}")
        rows.append({
            "case": label, "m": m, "r": r, "p": p,
            "block_rows": pick_fused_block_rows(r, p),
            "mode": mode,
            "us_fused": us_fused, "us_dense": us_dense,
            "flops": flops, "bytes_ideal": bytes_ideal,
            "max_abs_err": err,
        })
    return rows


# ---------------------------------------------------------------------------
# Part B: the R=16 ridge matrix, device-resident
# ---------------------------------------------------------------------------

def _matrix_spec(cell_batch: bool, trials: int, steps: int):
    from repro.experiments import (DelayAxis, ExperimentSpec, PlacementAxis,
                                   ProblemAxis, StrategyAxis, TrialsAxis)
    from repro.workloads import get_workload

    data = get_workload("ridge").build("smoke")
    strategies = tuple(
        StrategyAxis("coded-gd", k=6,
                     options=(() if s is None else (("step_size", s),)))
        for s in (None, 0.05, 0.02, 0.01))
    return ExperimentSpec(
        problems=(ProblemAxis.from_spec(data.spec),),
        strategies=strategies,
        delays=DelayAxis.of("bimodal", "power_law", m=8),
        trials=TrialsAxis(trials=trials),
        placement=PlacementAxis(mode="vmap", cell_batch=cell_batch),
        steps=steps)


def _time_matrix(spec, iters: int):
    """Best-of-``iters`` wall time for one warm ``execute`` of the matrix
    (min, not mean: the baseline in BENCH_experiments.json was recorded on
    an idle host, and min-of-N is the standard noise-robust estimator of
    that)."""
    from repro.experiments import execute, plan
    pl = plan(spec)
    # record_to=False: manifest writes (git subprocess + json) must not
    # land inside the timed loop or pollute the run store with warm-ups
    result = execute(pl, record_to=False)      # warm the jit caches
    secs = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        result = execute(pl, record_to=False)
        secs = min(secs, time.perf_counter() - t0)
    traces = np.stack([np.asarray(r["objective"], dtype=float)
                       for r in result.records])
    return secs, len(result.outcomes), traces


def bench_matrix(smoke: bool, iters: int) -> dict:
    import jax

    trials = 4 if smoke else 16
    steps = 40
    s_cell, C, tr_cell = _time_matrix(
        _matrix_spec(False, trials, steps), iters)
    s_batch, C2, tr_batch = _time_matrix(
        _matrix_spec(True, trials, steps), iters)
    assert C == C2
    trace_err = float(np.abs(tr_cell - tr_batch).max())
    assert trace_err <= 1e-4, f"cell-batched traces diverged: {trace_err}"

    baseline = None
    if os.path.exists(BASELINE_JSON):
        with open(BASELINE_JSON) as f:
            for row in json.load(f)["results"]:
                if row["placement"] == "vmap" and row["R"] == trials:
                    baseline = row["seconds_per_matrix"]
    speedup_batch = s_cell / max(s_batch, 1e-12)
    speedup_vs_baseline = (baseline / max(s_batch / C, 1e-12)
                           if baseline else None)
    derived = (f"percell_us={s_cell / C * 1e6:.1f};"
               f"cellbatch_speedup={speedup_batch:.2f}x")
    if speedup_vs_baseline:
        derived += f";vs_experiments_vmap={speedup_vs_baseline:.2f}x"
    emit(f"fused_matrix_R{trials}", s_batch / C * 1e6, derived)
    return {
        "R": trials, "steps": steps, "cells": C,
        "backend": jax.default_backend(),
        "seconds_per_cell_percell": s_cell / C,
        "seconds_per_cell_cellbatched": s_batch / C,
        "cellbatch_speedup": speedup_batch,
        "baseline_vmap_seconds_per_cell": baseline,
        "speedup_vs_experiments_vmap": speedup_vs_baseline,
        "max_abs_trace_err": trace_err,
    }


def run(smoke: bool = False, iters: int = 3,
        out_json: str = DEFAULT_OUT) -> dict:
    import jax
    from repro.kernels.fused_step import fused_enabled

    from .common import bench_meta

    kernel = bench_kernel(smoke, iters=max(iters, 3))
    matrix = bench_matrix(smoke, iters=iters)
    out = {
        "bench": "fused masked-gradient path (kernel + R=16 ridge matrix)",
        "meta": bench_meta(),
        "backend": jax.default_backend(),
        "fused_runner_path": fused_enabled(),
        "devices": len(jax.devices()),
        "kernel": kernel,
        "matrix": matrix,
    }
    with open(out_json, "w") as f:
        json.dump(out, f, indent=1)
    print(f"# wrote {out_json}")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.bench_fused")
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: R=4, one kernel case")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    run(smoke=args.smoke, iters=args.iters, out_json=args.out)


if __name__ == "__main__":
    main()
