"""Kernel micro-benchmarks: Pallas FWHT (interpret mode on CPU — numbers
measure the validation path, not TPU perf) vs dense-matmul and jnp-butterfly
encodes, plus the fused coded combine."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.encoding import hadamard_matrix
from repro.kernels.fwht import fwht_kernel_call
from repro.kernels.ref import fwht_ref
from repro.kernels.coded_reduce import coded_combine_call
from repro.kernels.ref import coded_combine_ref
from .common import emit, time_us


def run(rows: int = 64, n: int = 1024):
    x = jax.random.normal(jax.random.key(0), (rows, n))
    H = jnp.asarray(hadamard_matrix(n), jnp.float32)

    dense = jax.jit(lambda t: t @ H.T)
    ref = jax.jit(fwht_ref)
    pallas_i = lambda t: fwht_kernel_call(t, interpret=True)

    us_dense = time_us(dense, x)
    us_ref = time_us(ref, x)
    us_pallas = time_us(pallas_i, x, iters=2)
    flops_dense = 2 * rows * n * n
    ops_fwht = rows * n * np.log2(n)
    emit("fwht_dense_matmul", us_dense,
         f"gflops={flops_dense / us_dense / 1e3:.2f}")
    emit("fwht_jnp_butterfly", us_ref,
         f"gops={ops_fwht / us_ref / 1e3:.2f}")
    emit("fwht_pallas_interpret", us_pallas, "validation_path")

    g = jax.random.normal(jax.random.key(1), (16, 1 << 16))
    c = jax.random.uniform(jax.random.key(2), (16,))
    us_ref2 = time_us(jax.jit(coded_combine_ref), g, c)
    us_k = time_us(lambda a, b: coded_combine_call(a, b, interpret=True),
                   g, c, iters=2)
    emit("coded_combine_jnp", us_ref2,
         f"gbps={(g.size * 4) / us_ref2 / 1e3:.2f}")
    emit("coded_combine_pallas_interpret", us_k, "validation_path")
    return {}


if __name__ == "__main__":
    run()
