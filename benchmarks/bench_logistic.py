"""Paper Figs 10-13: l2-logistic regression via encoded block coordinate
descent (model parallelism), rcv1-like synthetic sparse features.

Schemes: Steiner-coded, Haar-coded, uncoded (k=m and k<m), replication —
each the same lifted-BCD lowering with a different feature encoder — under
the two straggler models of §5.3 (bimodal Gaussian mixture and power-law
background tasks).  Dataset, lowering and metrics come from the
``logistic`` workload; the asynchronous stale-gradient baseline lives in
the runtime's ``async`` strategy (data-parallel workloads) and is no longer
hand-rolled here.  Reports final train loss, held-out error and simulated
wall-clock.
"""
from __future__ import annotations

import time

from repro.runtime import ClusterEngine, make_delay_model
from repro.workloads import get_workload

from .common import emit


def run(preset: str = "bench"):
    wl = get_workload("logistic")
    ps = wl.preset(preset)
    data = wl.build(ps)
    m = ps.m
    k = (3 * m) // 4

    schemes = [
        (f"steiner_k{k}", "coded-bcd", {"k": k, "encoder": "steiner"}),
        (f"haar_k{k}", "coded-bcd", {"k": k, "encoder": "haar"}),
        (f"uncoded_k{m}", "uncoded", {"k": m}),
        (f"uncoded_k{k}", "uncoded", {"k": k}),
        (f"replication_k{k}", "replication", {"k": k}),
    ]
    results = []
    for delay_name in ("bimodal", "power_law"):
        engine = ClusterEngine(make_delay_model(delay_name), m, seed=7)
        for name, strategy, cfg in schemes:
            t0 = time.perf_counter()
            res = wl.run(strategy, engine, preset=ps, data=data, **cfg)
            us = (time.perf_counter() - t0) / ps.steps * 1e6
            emit(f"logistic_{delay_name}_{name}", us,
                 f"final_train_loss={res.final_objective:.4f};"
                 f"test_err={res.final_metric:.4f};"
                 f"sim_wallclock_s={res.wallclock:.1f}")
            results.append((delay_name, name, res.final_objective,
                            res.final_metric, res.wallclock))
    return results


if __name__ == "__main__":
    run()
