"""Paper Figs 10-13: l2-logistic regression via encoded block coordinate
descent (model parallelism), rcv1-like synthetic sparse features.

Schemes: Steiner-coded, Haar-coded, uncoded (k=m and k<m), replication, and
an ASYNCHRONOUS stale-gradient baseline.  Two straggler models from §5.3:
bimodal Gaussian mixture and power-law background tasks.  Reports final
train error and simulated wall-clock to target error.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (make_encoder, pad_rows, make_lifted_problem, phi_logistic,
                        run_encoded_bcd, bimodal_delays, power_law_delays)
from .common import emit, masks_from_delays


def _rcv1_like(n=512, p=256, density=0.1, seed=0):
    rng = np.random.default_rng(seed)
    X = (rng.random((n, p)) < density) * rng.exponential(1.0, (n, p))
    X = X / np.maximum(np.linalg.norm(X, axis=1, keepdims=True), 1e-9)
    w = rng.standard_normal(p)
    labels = np.sign(X @ w + 0.05 * rng.standard_normal(n))
    return X.astype(np.float32), labels


def _async_bcd(X, labels, m, steps, delay_model, seed, step_size):
    """Stale-gradient async baseline: each worker's block update is applied
    with a staleness drawn from the delay model (discretized)."""
    rng = np.random.default_rng(seed)
    n, p = X.shape
    pb = p // m
    w = np.zeros(p, np.float32)
    val, grad = phi_logistic(labels)
    staleness = np.maximum(
        1, (delay_model(rng, m) / delay_model(rng, m).min()).astype(int))
    w_hist = [w.copy()]
    t_elapsed = 0.0
    delays = delay_model(rng, m)
    for t in range(steps):
        for i in range(m):
            tau = min(staleness[i], len(w_hist))
            w_old = w_hist[-tau]
            z = jnp.asarray(X) @ jnp.asarray(w_old)
            g = np.asarray(jnp.asarray(X).T @ grad(z))
            sl = slice(i * pb, (i + 1) * pb)
            w[sl] -= step_size * g[sl]
        w_hist.append(w.copy())
        if len(w_hist) > 30:
            w_hist.pop(0)
        t_elapsed += float(np.mean(delays)) / m + 0.05
    z = jnp.asarray(X) @ jnp.asarray(w)
    return float(val(z)), t_elapsed


def run(steps: int = 120, m: int = 16):
    X, labels = _rcv1_like()
    n, p = X.shape
    val, gradfn = phi_logistic(labels)
    results = []
    for delay_name, model in [("bimodal", bimodal_delays()),
                              ("powerlaw", power_law_delays())]:
        for name, enc_name, k in [("steiner_k12", "steiner", 12),
                                  ("haar_k12", "haar", 12),
                                  ("uncoded_k16", "uncoded", 16),
                                  ("uncoded_k12", "uncoded", 12),
                                  ("replication_k12", "replication", 12)]:
            enc = make_encoder(enc_name, p,
                               beta=1.0 if enc_name == "uncoded" else 2.0)
            enc = pad_rows(enc, m)
            prob = make_lifted_problem(X, enc, m, val, gradfn)
            masks, times = masks_from_delays(model, m, k, steps, seed=7)
            import time
            t0 = time.perf_counter()
            v, tr = run_encoded_bcd(prob, masks, step_size=4.0)
            us = (time.perf_counter() - t0) / steps * 1e6
            emit(f"logistic_{delay_name}_{name}", us,
                 f"final_train_err={tr[-1]:.4f};"
                 f"sim_wallclock_s={times[-1]:.1f}")
            results.append((delay_name, name, tr[-1], times[-1]))
        # async baseline
        ferr, telap = _async_bcd(X, labels, m, steps // 4,
                                 model, 11, step_size=2.0)
        emit(f"logistic_{delay_name}_async", 0.0,
             f"final_train_err={ferr:.4f};sim_wallclock_s={telap:.1f}")
        results.append((delay_name, "async", ferr, telap))
    return results


if __name__ == "__main__":
    run()
