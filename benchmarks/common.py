"""Shared benchmark utilities: timing + CSV emission + scheme definitions.

The timing/blocking helpers live in :mod:`repro.obs.timing` (the ONE
clock/blocking discipline, DESIGN.md §11); this module re-exports them so
every ``benchmarks/bench_*.py`` keeps its historical import path.
:func:`bench_meta` is the provenance stamp every ``BENCH_*.json`` carries
so ``repro.obs.diff --against-baseline`` can say WHAT is being compared.
"""
from __future__ import annotations

from repro.obs.timing import block, emit, time_us

# historical alias — bench scripts (and out-of-tree users) call _block
_block = block

__all__ = ["block", "_block", "time_us", "emit", "masks_from_delays",
           "bench_meta"]


def bench_meta() -> dict:
    """The provenance stamp for a ``BENCH_*.json``: git sha, backend, jax
    version, device count, ISO-8601 UTC timestamp (``repro.obs.runstore``
    is the one definition).  ``repro.obs.diff`` skips the ``meta`` subtree
    when aligning time-like leaves, so restamping never flags."""
    from repro.obs.runstore import provenance
    return provenance()


def masks_from_delays(model, m, k, steps, seed=0):
    """Realize a fastest-k schedule via the cluster runtime; returns
    (masks (T, m), commit times (T,)) — same accounting as
    ``core.straggler.WallClock`` (k-th order statistic per barrier)."""
    from repro.runtime import ClusterEngine, FastestK
    sched = ClusterEngine(model, m, seed=seed).sample_schedule(
        steps, FastestK(k))
    return sched.masks, sched.times
