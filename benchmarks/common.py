"""Shared benchmark utilities: timing + CSV emission + scheme definitions."""
from __future__ import annotations

import time

import numpy as np


def time_us(fn, *args, iters: int = 5, warmup: int = 1, **kw) -> float:
    for _ in range(warmup):
        fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    # block on jax outputs if present
    try:
        import jax
        jax.block_until_ready(out)
    except Exception:
        pass
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def masks_from_delays(model, m, k, steps, seed=0):
    from repro.core import simulate_run, active_mask
    masks, times = [], []
    for _, A, t in simulate_run(model, m, k, steps, seed=seed):
        masks.append(active_mask(m, A))
        times.append(t)
    return np.stack(masks), np.asarray(times)
