"""Shared benchmark utilities: timing + CSV emission + scheme definitions."""
from __future__ import annotations

import time

import numpy as np


def _block(out):
    """block_until_ready on jax outputs; no-op for host values."""
    try:
        import jax
        return jax.block_until_ready(out)
    except Exception:
        return out


def time_us(fn, *args, iters: int = 5, warmup: int = 1, **kw) -> float:
    """Mean microseconds per call; blocks on device outputs INSIDE the timed
    loop (blocking only after the final call lets earlier dispatches overlap
    and under-reports per-iteration time)."""
    for _ in range(warmup):
        _block(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(iters):
        _block(fn(*args, **kw))
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def masks_from_delays(model, m, k, steps, seed=0):
    """Realize a fastest-k schedule via the cluster runtime; returns
    (masks (T, m), commit times (T,)) — same accounting as
    ``core.straggler.WallClock`` (k-th order statistic per barrier)."""
    from repro.runtime import ClusterEngine, FastestK
    sched = ClusterEngine(model, m, seed=seed).sample_schedule(
        steps, FastestK(k))
    return sched.masks, sched.times
