"""repro.experiments (DESIGN.md §10): spec validation, plan compilation
(skip materialization, up-front misconfig errors), execute equivalence with
the legacy harnesses, the placement axis (single / vmap / sharded incl. a
real multi-device mesh), eval_every=0, TrialsResult round-trips and the
unified CLI."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.experiments import (DelayAxis, ExperimentSpec, PlacementAxis,
                               ProblemAxis, StrategyAxis, TrialsAxis,
                               execute, plan, run)
from repro.runtime import ClusterEngine, ProblemSpec, get_strategy, \
    make_delay_model
from repro.runtime.strategies import check_trials, resolve_eval_every

N, P, M, K, T, R = 128, 32, 8, 6, 20, 3


def _synth_spec(strategies=("coded-gd", "uncoded"), delays=("bimodal",),
                trials=1, eval_every=1, placement="vmap", steps=T, **st_kw):
    return ExperimentSpec(
        problems=(ProblemAxis.synthetic(N, P),),
        strategies=tuple(StrategyAxis(s, **st_kw) for s in strategies),
        delays=DelayAxis(delays=tuple(delays), m=M),
        trials=TrialsAxis(trials=trials, eval_every=eval_every),
        placement=PlacementAxis(mode=placement), steps=steps)


# ---------------------------------------------------------------------------
# spec + plan
# ---------------------------------------------------------------------------

def test_plan_resolves_synthetic_defaults():
    pl = plan(_synth_spec(delays=("bimodal", "exponential")))
    assert len(pl.cells) == 4                     # 2 delays x 2 strategies
    c = pl.cells[0]
    assert (c.m, c.k, c.steps) == (M, max(1, 3 * M // 4), T)
    assert c.skip is None and c.placement == "vmap"
    # delays outer, strategies inner — the legacy compare order
    assert [(c.delay, c.resolved_strategy) for c in pl.cells] == [
        ("bimodal", "coded-gd"), ("bimodal", "uncoded"),
        ("exponential", "coded-gd"), ("exponential", "uncoded")]


def test_plan_materializes_workload_skips_up_front():
    spec = ExperimentSpec(
        problems=(ProblemAxis.from_workload("ridge", "smoke"),),
        strategies=(StrategyAxis("coded"), StrategyAxis("coded-prox"),
                    StrategyAxis("nosuch")),
        delays=DelayAxis(), steps=8)
    pl = plan(spec)
    assert len(pl.cells) == 3
    assert pl.cells[0].skip is None
    assert pl.cells[0].resolved_strategy == "coded-lbfgs"  # alias resolved
    assert "l1" in pl.cells[1].skip                        # unsupported
    assert "unknown strategy" in pl.cells[2].skip
    assert pl.cells[1].metric_name == "subopt_gap"
    assert len(pl.skipped) == 2
    assert "SKIP" in pl.describe()


def test_plan_rejects_bad_eval_every_up_front():
    with pytest.raises(ValueError, match=r"steps % eval_every == 3"):
        plan(_synth_spec(trials=2, eval_every=7, steps=24))


def test_spec_validation_errors():
    with pytest.raises(ValueError, match="at least one problem"):
        ExperimentSpec(problems=(), strategies=(StrategyAxis("x"),)
                       ).validate()
    with pytest.raises(ValueError, match="workload"):
        _synth_spec(delays=()).validate()         # synthetic needs delays
    with pytest.raises(ValueError, match="placement"):
        _synth_spec(placement="tpu-pod").validate()
    with pytest.raises(KeyError, match="nosuch"):
        plan(_synth_spec(strategies=("nosuch",)))  # synthetic: fail fast


# ---------------------------------------------------------------------------
# execute == the legacy harnesses
# ---------------------------------------------------------------------------

def test_execute_matches_legacy_run_matrix():
    from repro.runtime.compare import run_matrix
    legacy = run_matrix(["coded-gd", "async"], ["bimodal"], n=N, p=P, m=M,
                        steps=T)
    spec = ExperimentSpec(
        problems=(ProblemAxis.synthetic(N, P),),
        strategies=(StrategyAxis("coded-gd", encoder="hadamard",
                                 policy="fastest-k"),
                    StrategyAxis("async", encoder="hadamard",
                                 policy="fastest-k")),
        delays=DelayAxis(delays=("bimodal",), m=M), steps=T)
    assert execute(plan(spec)).records == legacy


def test_execute_matches_legacy_workload_matrix():
    from repro.workloads.runner import run_workload_matrix
    legacy = run_workload_matrix(["ridge"], ["coded", "coded-bcd"],
                                 preset="smoke", steps=8)
    spec = ExperimentSpec(
        problems=(ProblemAxis.from_workload("ridge", "smoke"),),
        strategies=(StrategyAxis("coded"), StrategyAxis("coded-bcd")),
        delays=DelayAxis(), steps=8)
    records = execute(plan(spec)).records
    assert records == legacy
    assert "skipped" in records[1]                # bcd can't score ridge


def test_outcomes_carry_raw_results():
    result = run(_synth_spec(strategies=("coded-gd",)))
    out = result.outcomes[0]
    assert out.result is not None and not out.skipped
    assert out.result.w.shape == (P,)
    assert out.record["final_objective"] == out.result.final_objective


# ---------------------------------------------------------------------------
# placement axis
# ---------------------------------------------------------------------------

def test_placement_single_matches_vmap():
    recs = {p: run(_synth_spec(strategies=("coded-gd", "async"), trials=R,
                               placement=p)).records
            for p in ("single", "vmap")}
    for rv, rs in zip(recs["vmap"], recs["single"]):
        np.testing.assert_allclose(rs["objective"], rv["objective"],
                                   atol=1e-5)
        np.testing.assert_allclose(rs["times"], rv["times"], atol=1e-9)
        assert rs["meta"]["batched"] is False
        assert rv["meta"]["batched"] is True


def test_placement_sharded_single_device_falls_back_to_vmap():
    rv = run(_synth_spec(strategies=("coded-gd",), trials=R)).records[0]
    rs = run(_synth_spec(strategies=("coded-gd",), trials=R,
                         placement="sharded")).records[0]
    np.testing.assert_array_equal(rs["objective"], rv["objective"])
    assert rs["meta"]["placement"] == "sharded"
    assert rs["meta"]["placement_devices"] >= 1


def test_placement_sharded_bcd_falls_back_with_note():
    rec = run(_synth_spec(strategies=("coded-bcd",), trials=R,
                          placement="sharded")).records[0]
    assert "placement_fallback" in rec["meta"]


_MULTIDEV_SCRIPT = textwrap.dedent("""
    import numpy as np, jax
    assert len(jax.devices()) == 4, jax.devices()
    from repro.experiments import (DelayAxis, ExperimentSpec, PlacementAxis,
                                   ProblemAxis, StrategyAxis, TrialsAxis,
                                   run)
    def rec(placement):
        return run(ExperimentSpec(
            problems=(ProblemAxis.synthetic(128, 32),),
            strategies=(StrategyAxis("coded-gd"),),
            delays=DelayAxis(delays=("bimodal",), m=8),
            trials=TrialsAxis(trials=8),
            placement=PlacementAxis(mode=placement), steps=12)).records[0]
    v, s = rec("vmap"), rec("sharded")
    assert s["meta"]["placement_devices"] == 4, s["meta"]
    err = np.abs(np.asarray(v["objective"]) -
                 np.asarray(s["objective"])).max()
    assert err < 1e-5, err
    print("SHARDED_OK", err)
""")


def test_placement_sharded_multidevice_matches_vmap():
    """R=8 realizations via shard_map on a forced 4-device CPU mesh match
    the vmap placement to 1e-5 (the ROADMAP multi-device-trials item)."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH="src" + os.pathsep + os.environ.get(
                   "PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))),
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARDED_OK" in out.stdout


# ---------------------------------------------------------------------------
# check_trials / eval_every=0
# ---------------------------------------------------------------------------

def test_check_trials_reports_remainder():
    with pytest.raises(ValueError) as e:
        check_trials(24, 2, 7)
    assert "steps % eval_every == 3" in str(e.value)
    with pytest.raises(ValueError, match=">= 0"):
        check_trials(24, 2, -1)


def test_eval_every_zero_means_final_only():
    check_trials(24, 2, 0)                        # accepted
    assert resolve_eval_every(24, 0) == 24
    assert resolve_eval_every(24, 4) == 4
    eng = ClusterEngine(make_delay_model("bimodal"), M, seed=0)
    spec = ProblemSpec.synthetic(N, P, seed=0)
    res0 = get_strategy("coded-gd").run_batched(spec, eng, steps=T, trials=R,
                                                eval_every=0, k=K)
    dense = get_strategy("coded-gd").run_batched(spec, eng, steps=T,
                                                 trials=R, eval_every=1, k=K)
    assert res0.objective.shape == (R, 1)
    assert res0.times.shape == (R, 1)
    np.testing.assert_allclose(res0.objective[:, -1], dense.objective[:, -1],
                               atol=1e-6)
    np.testing.assert_array_equal(res0.times[:, -1], dense.times[:, -1])


# ---------------------------------------------------------------------------
# TrialsResult.realization / to_record round-trips
# ---------------------------------------------------------------------------

def test_trialsresult_realization_matches_single_run():
    """Realization r of a batched run == the single-trial run on the same
    child seed (engine.trial(r)), trace, wall-clock and iterate."""
    eng = ClusterEngine(make_delay_model("bimodal"), M, seed=0)
    spec = ProblemSpec.synthetic(N, P, seed=0)
    batched = get_strategy("coded-gd").run_batched(spec, eng, steps=T,
                                                   trials=R, k=K)
    for r in range(R):
        single = get_strategy("coded-gd").run(spec, eng.trial(r), steps=T,
                                              k=K)
        real = batched.realization(r)
        np.testing.assert_array_equal(real.times, single.times)
        np.testing.assert_allclose(real.objective, single.objective,
                                   atol=1e-5)
        np.testing.assert_allclose(real.w, single.w, atol=1e-5)
        assert real.schedule is not None
        np.testing.assert_array_equal(real.schedule.masks,
                                      single.schedule.masks)


def test_trialsresult_to_record_roundtrip():
    eng = ClusterEngine(make_delay_model("bimodal"), M, seed=0)
    spec = ProblemSpec.synthetic(N, P, seed=0)
    batched = get_strategy("coded-gd").run_batched(spec, eng, steps=T,
                                                   trials=R, k=K)
    rec = json.loads(json.dumps(batched.to_record()))
    assert rec["trials"] == R
    np.testing.assert_allclose(rec["times"], np.asarray(batched.times))
    np.testing.assert_allclose(rec["objective"],
                               np.asarray(batched.objective), rtol=1e-7)
    assert rec["final_objective"] == pytest.approx(
        float(batched.final_objective.mean()))
    assert rec["summary"]["wallclock_s"]["p95"] >= \
        rec["summary"]["wallclock_s"]["p50"]
    # realization(r).to_record() is a plain single-trial record
    rec_r = batched.realization(1).to_record()
    np.testing.assert_allclose(rec_r["objective"], rec["objective"][1],
                               rtol=1e-7)


def test_workload_run_trials_realization_matches_single_incl_extras():
    """Workload trials: realization r (sequential fallback, mf) matches the
    single run on engine.trial(r) — including the extras payload."""
    from repro.workloads import get_workload
    wl = get_workload("mf")
    ps = wl.preset("smoke")
    data = wl.build(ps)
    eng = wl.default_engine(ps)
    results = wl.run_trials("coded", eng, preset=ps, data=data, trials=2,
                            steps=3)
    single = wl.run("coded", eng.trial(1), preset=ps, data=data, steps=3)
    np.testing.assert_allclose(results[1].metric, single.metric, atol=1e-6)
    np.testing.assert_allclose(results[1].times, single.times, atol=1e-9)
    assert results[1].extras == single.extras
    assert results[1].extras["half_steps"]       # non-trivial payload


# ---------------------------------------------------------------------------
# unified CLI
# ---------------------------------------------------------------------------

def test_experiments_cli_end_to_end(tmp_path):
    from repro.experiments.run import main
    out = tmp_path / "exp"
    result = main(["--strategies", "coded-gd,uncoded", "--delays", "bimodal",
                   "--n", str(N), "--p", str(P), "--m", str(M),
                   "--steps", "12", "--trials", "2", "--eval-every", "4",
                   "--out", str(out)])
    assert len(result.records) == 2
    data = json.loads((out / "experiments.json").read_text())
    assert data == result.records
    assert (out / "experiments.csv").exists()
    assert (out / "summary.csv").exists()
    for rec in data:
        assert rec["trials"] == 2
        assert len(rec["objective"][0]) == 3      # 12 steps / eval_every 4


def test_workload_cells_honor_strategy_axis_config():
    """StrategyAxis config set by the user must reach workload cells too:
    async staleness/updates and an explicit policy are forwarded, not
    silently dropped."""
    spec = ExperimentSpec(
        problems=(ProblemAxis.from_workload("ridge", "smoke"),),
        strategies=(StrategyAxis("async", staleness_bound=4,
                                 async_updates=64),
                    StrategyAxis("coded-gd", policy="adversarial", k=5)),
        delays=DelayAxis(), steps=8)
    recs = execute(plan(spec)).records
    assert recs[0]["meta"]["staleness_bound"] == 4
    assert recs[0]["meta"]["updates"] == 64
    assert recs[1]["meta"]["policy"] == "AdversarialRotation"


def test_cli_explicit_delays_win_over_workload_native():
    from repro.experiments.run import main
    result = main(["--workloads", "ridge", "--strategies", "coded",
                   "--delays", "bimodal,power_law,exponential",
                   "--plan-only"])
    assert [c.delay for c in result.plan.cells] == [
        "bimodal", "power_law", "exponential"]


def test_experiments_cli_plan_only(capsys):
    from repro.experiments.run import main
    result = main(["--workloads", "ridge", "--strategies", "coded,nosuch",
                   "--plan-only"])
    assert result.outcomes == []
    captured = capsys.readouterr().out
    assert "ExperimentPlan" in captured and "SKIP" in captured
