"""prefill + decode must reproduce the full forward pass (all 10 archs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import Model


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_matches_forward(arch):
    # high capacity factor: MoE capacity-dropping is the one legitimate
    # divergence between batched and incremental execution
    cfg = ARCHS[arch].smoke_variant().with_overrides(capacity_factor=4.0)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    B, S = 2, 64
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
    kw = {}
    if cfg.n_patches:
        kw["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_vision)) * 0.02,
            jnp.float32)
        kw["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S + 1)[None, None], (3, B, S + 1)).astype(jnp.int32)
    if cfg.n_enc_layers:
        kw["enc_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_enc_frames, cfg.d_model)) * 0.02,
            jnp.float32)

    full, _ = model(params, toks, **kw)
    kwp = dict(kw)
    if cfg.n_patches:
        kwp["mrope_positions"] = kw["mrope_positions"][:, :, :S]
    lg_pref, caches = model.prefill(params, toks[:, :S], cache_len=S + 8,
                                    **kwp)
    lg_dec, new_caches = model.decode(params, toks[:, S:S + 1], caches,
                                      jnp.int32(S))
    scale = float(jnp.abs(full).max())
    assert float(jnp.abs(lg_pref[:, 0] - full[:, S - 1]).max()) < 1e-3 * scale
    assert float(jnp.abs(lg_dec[:, 0] - full[:, S]).max()) < 1e-3 * scale
