"""Per-architecture smoke tests (task deliverable f): reduced variant of each
assigned family runs one forward AND one train step on CPU; output shapes
checked, no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import transformer as T
from repro.optim import adamw_init, cosine_schedule
from repro.train.steps import build_train_step


def _inputs(cfg, B, S, rng):
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    kw = {}
    if cfg.n_patches:
        kw["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_vision)) * 0.02,
            jnp.float32)
        kw["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    if cfg.n_enc_layers:
        kw["enc_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_enc_frames, cfg.d_model)) * 0.02,
            jnp.float32)
    return toks, kw


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_train_step(arch):
    cfg = ARCHS[arch].smoke_variant()
    rng = np.random.default_rng(0)
    B, S = 2, 64
    params = T.init_params(cfg, jax.random.key(0))
    toks, kw = _inputs(cfg, B, S, rng)

    logits, aux = T.forward(params, cfg, toks, **kw)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN logits"

    labels = jnp.roll(toks, -1, axis=1)   # next-token targets
    batch = {"tokens": toks, "labels": labels,
             "weights": jnp.ones((B,), jnp.float32), **kw}
    step = build_train_step(cfg, cosine_schedule(1e-3, 2, 100))
    opt = adamw_init(params)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: NaN loss"
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0.0, f"{arch}: zero gradient"
    # parameters actually moved
    delta = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(new_params), jax.tree.leaves(params)))
    assert delta > 0.0
    assert int(new_opt.count) == 1


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_decode_shapes(arch):
    cfg = ARCHS[arch].smoke_variant()
    rng = np.random.default_rng(1)
    B, S = 2, 32
    params = T.init_params(cfg, jax.random.key(0))
    caches = T.init_caches(cfg, B, S)
    token = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
    logits, new_caches = T.decode_step(params, cfg, token, caches,
                                       jnp.int32(S - 1))
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)
