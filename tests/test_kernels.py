"""Pallas kernel validation: shape/dtype sweeps vs jnp oracles + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.fwht import fwht_kernel_call, pick_block_rows
from repro.kernels.coded_reduce import coded_combine_call
from repro.kernels.ref import fwht_ref, fwht_matrix_ref, coded_combine_ref
from repro.kernels.ops import fwht, hadamard_encode, coded_combine


@pytest.mark.parametrize("rows", [1, 8, 32])
@pytest.mark.parametrize("n", [128, 256, 1024])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_shapes_dtypes(rows, n, dtype):
    x = jax.random.normal(jax.random.key(0), (rows, n)).astype(dtype)
    out = fwht_kernel_call(x, interpret=True)
    ref = fwht_ref(x).astype(dtype)
    assert out.shape == x.shape and out.dtype == dtype
    tol = 1e-4 if dtype == jnp.float32 else 0.5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol * np.sqrt(n), rtol=1e-2)


def test_fwht_vs_dense_matrix():
    x = jax.random.normal(jax.random.key(1), (4, 128))
    np.testing.assert_allclose(np.asarray(fwht_kernel_call(x)),
                               np.asarray(fwht_matrix_ref(x)),
                               rtol=1e-4, atol=1e-3)


def test_fwht_block_rows_sweep():
    x = jax.random.normal(jax.random.key(2), (16, 256))
    full = fwht_kernel_call(x, block_rows=16)
    for br in [1, 2, 4, 8]:
        np.testing.assert_allclose(np.asarray(fwht_kernel_call(
            x, block_rows=br)), np.asarray(full), rtol=1e-5, atol=1e-5)


def test_pick_block_rows_fits_budget():
    br = pick_block_rows(4096, 8192, 4, vmem_budget=8 * 2 ** 20)
    assert br * 2 * 8192 * 4 <= 8 * 2 ** 20
    assert br >= 8


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16), logn=st.integers(3, 9))
def test_fwht_involution_property(seed, logn):
    """H (H x) = n x — the defining FWHT property (hypothesis)."""
    n = 1 << logn
    x = jax.random.normal(jax.random.key(seed), (2, n))
    twice = fwht_kernel_call(fwht_kernel_call(x))
    np.testing.assert_allclose(np.asarray(twice), n * np.asarray(x),
                               rtol=1e-3, atol=1e-2 * n)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_fwht_linearity(seed):
    k1, k2 = jax.random.split(jax.random.key(seed))
    a = jax.random.normal(k1, (3, 128))
    b = jax.random.normal(k2, (3, 128))
    lhs = fwht_kernel_call(a + 2.0 * b)
    rhs = fwht_kernel_call(a) + 2.0 * fwht_kernel_call(b)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-4, atol=1e-3)


def test_fwht_rejects_non_pow2():
    with pytest.raises(ValueError):
        fwht_kernel_call(jnp.ones((4, 100)))


def test_fwht_axis_wrapper():
    x = jax.random.normal(jax.random.key(3), (128, 5))
    out = fwht(x, axis=0)
    ref = fwht_ref(x.T).T
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)


def test_hadamard_encode_matches_dense():
    import math
    from repro.core.encoding import hadamard_matrix
    rng = np.random.default_rng(1)
    n, p, N = 64, 8, 128
    cols = rng.choice(N, size=n, replace=False)
    signs = rng.choice([-1.0, 1.0], size=n)
    X = rng.standard_normal((n, p)).astype(np.float32)
    S = hadamard_matrix(N)[:, cols] * signs[None, :] / math.sqrt(n)
    out = hadamard_encode(jnp.asarray(X), cols, signs, N=N)
    np.testing.assert_allclose(np.asarray(out), S @ X, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,P", [(4, 128), (16, 2048), (32, 6144)])
def test_coded_combine(m, P):
    g = jax.random.normal(jax.random.key(4), (m, P))
    c = jax.random.uniform(jax.random.key(5), (m,))
    np.testing.assert_allclose(np.asarray(coded_combine_call(
        g, c, block=min(2048, P), interpret=True)),
        np.asarray(coded_combine_ref(g, c)), rtol=1e-5, atol=1e-5)


def test_coded_combine_wrapper_padding():
    g = jax.random.normal(jax.random.key(6), (8, 3000))
    c = jax.random.uniform(jax.random.key(7), (8,))
    np.testing.assert_allclose(np.asarray(coded_combine(g, c)),
                               np.asarray(coded_combine_ref(g, c)),
                               rtol=1e-5, atol=1e-5)
