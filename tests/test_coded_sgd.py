"""Coded-SGD subsystem (DESIGN §15): exact decode through the real train
step, the engine bridge, the strategy/experiments lowering, chaos presets,
and the fault counters the tail estimator now carries."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.gradient_coding import (make_code, make_cyclic,  # noqa: E402
                                        make_frc)
from repro.data.pipeline import GroupBatcher, TokenStream  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.obs.sketch import DelayTailEstimator  # noqa: E402
from repro.optim import adamw_init, cosine_schedule  # noqa: E402
from repro.runtime import (ClusterEngine, FastestK, get_strategy,  # noqa: E402
                           make_delay_model)
from repro.runtime.faults import FAULT_PRESETS, make_fault_model  # noqa: E402
from repro.train.coded import (CodedTrainer, TrainProblem,  # noqa: E402
                               TrainerConfig, build_coded_train_step,
                               run_coded_sgd)

M = 8


def _tiny_cfg():
    return TrainProblem(seq_len=16, vocab=64).build_cfg()


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


# ---------------------------------------------------------------------------
# exact decode through the REAL train step (Tandon, arXiv 1612.03301)
# ---------------------------------------------------------------------------

def test_frc_step_exact_under_per_cluster_erasures():
    """FRC (beta=2): any erasure pattern leaving >=1 survivor per cluster
    yields the identical update — bit for bit across patterns (the
    surviving replica computed the same shard), and equal to the full-mask
    update within fp tolerance."""
    cfg = _tiny_cfg()
    code = make_frc(M, 2)
    batcher = GroupBatcher(TokenStream(cfg.vocab, seed=0), code, 1, 16,
                           seed=0)
    tokens, labels, coeff = batcher.next_batch()
    step = jax.jit(build_coded_train_step(
        cfg, cosine_schedule(1e-3, 2, 10), rows_per_group=1,
        num_groups=code.num_groups))
    params = T.init_params(cfg, jax.random.key(0))
    opt = adamw_init(params)
    args = (jnp.asarray(tokens), jnp.asarray(labels), jnp.asarray(coeff))

    # clusters are interleaved (worker i -> cluster i % 4), so each of
    # these loses one replica of EVERY cluster — the worst exact case
    mask_a = np.array([1, 1, 1, 1, 0, 0, 0, 0], np.float64)
    mask_b = np.array([0, 0, 0, 0, 1, 1, 1, 1], np.float64)
    outs = {}
    for name, mask in [("a", mask_a), ("b", mask_b),
                       ("full", np.ones(M))]:
        assert code.decode_exact_possible(mask)
        d = jnp.asarray(code.decode_weights(mask))
        p, _, met = step(params, opt, *args, d)
        outs[name] = (_leaves(p), float(met["loss"]))

    for la, lb in zip(outs["a"][0], outs["b"][0]):
        np.testing.assert_array_equal(la, lb)
    assert outs["a"][1] == pytest.approx(outs["b"][1], rel=0, abs=0)
    for la, lf in zip(outs["a"][0], outs["full"][0]):
        np.testing.assert_allclose(la, lf, rtol=2e-5, atol=1e-7)
    assert outs["a"][1] == pytest.approx(outs["full"][1], rel=1e-5)


def test_cyclic_decode_recovers_full_gradient():
    """Cyclic repetition: for any <= beta-1 TOTAL erasures the decode
    weights satisfy B^T a = 1, so the combined gradient equals the
    full-batch mean exactly."""
    code = make_cyclic(M, beta=3, seed=0)
    rng = np.random.default_rng(0)
    g = rng.standard_normal((M, 5))          # one gradient row per group
    workers = np.asarray(code.B) @ g         # what each worker computes
    for erased in [(), (2,), (6, 1)]:
        mask = np.ones(M)
        mask[list(erased)] = 0.0
        assert code.decode_exact_possible(mask)
        a = np.asarray(code.decode_weights(mask))
        assert np.all(a[list(erased)] == 0.0)
        est = (a @ workers) / code.num_groups
        np.testing.assert_allclose(est, g.mean(axis=0), rtol=1e-5,
                                   atol=1e-7)
    # beyond the threshold: no exactness claim, but finite weights
    mask = np.ones(M)
    mask[[0, 3, 5]] = 0.0
    assert not code.decode_exact_possible(mask)
    assert np.all(np.isfinite(code.decode_weights(mask)))


# ---------------------------------------------------------------------------
# engine bridge + strategy interface
# ---------------------------------------------------------------------------

def test_coded_trainer_runs_off_engine_schedule():
    cfg = _tiny_cfg()
    tcfg = TrainerConfig(m_workers=M, beta=2, wait_k=6, rows_per_worker=1,
                         seq_len=16, steps=3, lr=1e-3, warmup=1,
                         log_every=0)
    eng = ClusterEngine(make_delay_model("bimodal"), M, seed=1,
                        faults=make_fault_model("preset:ec2-tail"))
    tr = CodedTrainer(cfg, tcfg, eng, policy=FastestK(6))
    _, _, hist = tr.run()
    assert len(hist) == 3
    assert all(np.isfinite(h["loss"]) for h in hist)
    times = [h["sim_time_s"] for h in hist]
    assert times == sorted(times)
    assert tr.last_schedule is not None
    # the loop consumed the engine's masks, not its own straggler model
    assert [h["active"] for h in hist] == \
        [int(m.sum()) for m in np.asarray(tr.last_schedule.masks) > 0]


def test_run_coded_sgd_strategy_surface():
    spec = TrainProblem(seq_len=16, vocab=64)
    eng = ClusterEngine(make_delay_model("bimodal"), M, seed=0)
    res = get_strategy("coded-sgd").run(spec, eng, steps=2, k=6,
                                        code="stochastic", warmup=1)
    assert res.strategy == "coded-sgd"
    assert len(res.objective) == 2 and np.all(np.isfinite(res.objective))
    assert res.meta["code"] == "stochastic"
    assert res.meta["exact_fraction"] == 0.0    # approximate code
    with pytest.raises(ValueError, match="unknown coded-sgd config"):
        run_coded_sgd(spec, eng, steps=2, nonsense=1)


def test_experiments_train_cell_plan_and_execute(tmp_path, monkeypatch):
    from repro.experiments.execute import execute
    from repro.experiments.plan import plan
    from repro.experiments.spec import (DelayAxis, ExperimentSpec, ObsAxis,
                                        PlacementAxis, ProblemAxis,
                                        StrategyAxis, TrialsAxis)
    monkeypatch.setenv("REPRO_RUNSTORE", str(tmp_path / "store"))
    spec = ExperimentSpec(
        problems=(ProblemAxis.train("deepseek-7b", seq_len=16, vocab=64),),
        strategies=(StrategyAxis(name="coded-sgd", k=6,
                                 options=(("code", "cyclic"),
                                          ("warmup", 1))),
                    StrategyAxis(name="uncoded", k=M),
                    StrategyAxis(name="coded-gd")),
        delays=DelayAxis(delays=("bimodal",), m=M),
        trials=TrialsAxis(trials=1, eval_every=1, seed=0),
        placement=PlacementAxis(mode="single"),
        steps=2, obs=ObsAxis())
    pl = plan(spec)
    assert len(pl.cells) == 3
    skips = {c.resolved_strategy: c.skip for c in pl.cells}
    assert skips["coded-sgd"] is None and skips["uncoded"] is None
    assert "train-kind" in skips["coded-gd"]
    result = execute(pl)
    recs = {r["strategy"]: r for r in result.records}
    assert "skipped" in recs["coded-gd"]
    for name, code in [("coded-sgd", "cyclic"), ("uncoded", "uncoded")]:
        rec = recs[name]
        assert rec["metric_name"] == "loss"
        assert np.isfinite(rec["final_metric"])
        assert rec["meta"]["code"] == code
    assert result.run_id is not None
    assert (tmp_path / "store" / result.run_id / "manifest.json").exists()


# ---------------------------------------------------------------------------
# chaos presets + fault counters
# ---------------------------------------------------------------------------

def test_fault_presets_parse_and_compose():
    for name in FAULT_PRESETS:
        fm = make_fault_model(f"preset:{name}")
        assert fm is not None and len(fm.injectors) >= 1
    composed = make_fault_model("preset:ec2-tail;crash:p=0.5,at=0.1")
    base = make_fault_model("preset:ec2-tail")
    assert len(composed.injectors) == len(base.injectors) + 1
    assert composed.spec == "preset:ec2-tail;crash:p=0.5,at=0.1"
    with pytest.raises(KeyError, match="ec2-tail"):
        make_fault_model("preset:no-such-preset")


def test_delay_tail_estimator_counts_faults():
    est = DelayTailEstimator(M)
    eng = ClusterEngine(make_delay_model("bimodal"), M, seed=3,
                        faults=make_fault_model("preset:zone-outage"),
                        tail_estimator=est)
    eng.sample_schedule(12, FastestK(6))
    snap = est.snapshot()
    assert snap["faults"]["schedules"] == 1
    assert snap["faults"]["crashes"] + snap["faults"]["blackouts"] > 0
    # clean engines keep the historical snapshot key set
    clean = DelayTailEstimator(M)
    ClusterEngine(make_delay_model("bimodal"), M, seed=3,
                  tail_estimator=clean).sample_schedule(12, FastestK(6))
    assert "faults" not in clean.snapshot()


def test_make_code_registry():
    assert make_code("uncoded", M).num_groups == M
    assert make_code("bernoulli", M, beta=2).stochastic
    with pytest.raises(KeyError, match="frc"):
        make_code("no-such-code", M)
