"""Encoding-matrix properties (paper §4): tightness, Welch bound, BRIP."""
import numpy as np
import pytest

from repro.core import (make_encoder, brip_constant, subset_spectrum,
                        hadamard_matrix, paley_etf_encoder,
                        steiner_etf_encoder, partition_rows)

TIGHT = ["hadamard", "haar", "steiner", "paley", "replication", "uncoded"]


@pytest.mark.parametrize("name", TIGHT)
def test_tight_frame(name):
    enc = make_encoder(name, 64, beta=2.0)
    G = enc.S.T @ enc.S
    assert np.abs(G - enc.beta * np.eye(enc.n)).max() < 1e-8


def test_hadamard_matrix_orthogonal():
    H = hadamard_matrix(64)
    assert np.abs(H @ H.T - 64 * np.eye(64)).max() == 0


def test_steiner_block_structure():
    enc = steiner_etf_encoder(None, v=8)
    # v^2 x v(v-1)/2, column norm^2 = 2v/(v-1)
    assert enc.S.shape == (64, 28)
    norms = (enc.S ** 2).sum(0)
    assert np.allclose(norms, 2 * 8 / 7)
    # block sparsity: each column has exactly 2v nonzeros
    assert ((enc.S != 0).sum(0) == 16).all()


def test_paley_welch_bound():
    """ETFs meet the Welch bound with equality (Prop 7).

    n = 31 hits p = 2n - 1 = 61 (prime, 1 mod 4) exactly, so no dimension
    subsampling happens and the frame is the genuine Paley ETF; for other n
    the projection onto fewer coordinates breaks equiangularity.
    """
    enc = paley_etf_encoder(31)
    # rows of S (frame vectors); normalize to unit norm
    F = enc.S / np.linalg.norm(enc.S, axis=1, keepdims=True)
    n_vec, dim = F.shape
    G = np.abs(F @ F.T - np.eye(n_vec))
    welch = np.sqrt((n_vec - dim) / (dim * (n_vec - 1)))
    off = G[~np.eye(n_vec, dtype=bool)]
    # equiangular: EVERY cross-correlation sits on the Welch bound
    np.testing.assert_allclose(off, welch, atol=1e-9)


def test_brip_gaussian_matches_theory():
    """Gaussian subset eigenvalues concentrate within the Marchenko-Pastur
    style edges of eq. (8)-(9)."""
    enc = make_encoder("gaussian", 128, beta=2.0, seed=3)
    ev = subset_spectrum(enc, 16, 12, trials=20, seed=1)
    edge_hi = (1 + np.sqrt(1 / (2 * 0.75))) ** 2
    edge_lo = (1 - np.sqrt(1 / (2 * 0.75))) ** 2
    assert ev.max() < 1.4 * edge_hi
    assert ev.min() > 0.25 * edge_lo


def test_etf_spectrum_flatter_than_gaussian():
    """Fig 5-6: ETF subset spectra concentrate around 1 more tightly."""
    had = subset_spectrum(make_encoder("hadamard", 128, 2.0), 16, 12, 20)
    gau = subset_spectrum(make_encoder("gaussian", 128, 2.0), 16, 12, 20)
    iqr = lambda e: np.quantile(e, 0.9) - np.quantile(e, 0.1)
    assert iqr(had) < iqr(gau)


def test_brip_constant_replication_degenerate():
    """Dropping both replicas of a block makes replication singular —
    the paper's argument for coding over replication."""
    eps = brip_constant(make_encoder("replication", 64, 2.0), 16, 8,
                        trials=200, seed=0)
    assert eps >= 1.0  # some subset is rank-deficient


def test_partition_rows_shape():
    enc = make_encoder("hadamard", 64, 2.0)
    blocks = partition_rows(enc, 8)
    assert blocks.shape == (8, 16, 64)
    assert np.allclose(blocks.reshape(-1, 64), enc.S)
