"""Convergence of the four encoded algorithms (paper Thms 2, 4, 5, 6),
including ADVERSARIAL straggler sequences — the paper's deterministic,
sample-path guarantee."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (make_encoder, hadamard_encoder, make_encoded_problem,
                        run_encoded_gd, run_encoded_lbfgs,
                        run_encoded_proximal, original_objective,
                        make_lifted_problem, phi_logistic, phi_quadratic,
                        run_encoded_bcd, adversarial_sets, active_mask,
                        bimodal_delays, simulate_run)

M_WORKERS, K_WAIT = 16, 12


def _ridge_problem(n=256, p=64, lam=0.05, seed=0, encoder="hadamard"):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, p))
    y = X @ rng.standard_normal(p) + 0.1 * rng.standard_normal(n)
    enc = make_encoder(encoder, n, beta=2.0, seed=seed)
    prob = make_encoded_problem(X, y, enc, M_WORKERS, lam=lam)
    w_star = np.linalg.solve(X.T @ X / n + lam * np.eye(p), X.T @ y / n)
    f_star = float(original_objective(prob, jnp.asarray(w_star), h="l2"))
    L = np.linalg.eigvalsh(X.T @ X / n).max()
    return prob, f_star, L


def _adversarial_masks(T):
    return np.stack([active_mask(M_WORKERS, A)
                     for A in adversarial_sets(M_WORKERS, K_WAIT, T)])


def _random_masks(T, seed=0):
    return np.stack([active_mask(M_WORKERS, A) for _, A, _ in
                     simulate_run(bimodal_delays(), M_WORKERS, K_WAIT, T,
                                  seed=seed)])


@pytest.mark.parametrize("masks_kind", ["adversarial", "random"])
def test_encoded_gd_converges_near_optimum(masks_kind):
    """Thm 2: linear convergence to a kappa-ball around f*."""
    prob, f_star, L = _ridge_problem()
    masks = (_adversarial_masks(200) if masks_kind == "adversarial"
             else _random_masks(200))
    w, tr = run_encoded_gd(prob, masks, step_size=1.0 / (1.3 * L + 0.05))
    assert tr[-1] <= 1.10 * f_star          # within kappa^2-style factor
    assert tr[-1] <= 0.05 * tr[0] + 1.10 * f_star
    assert np.isfinite(tr).all()


def test_encoded_gd_uncoded_baseline_worse_under_erasures():
    """With k < m and no redundancy, plain GD solves the WRONG (subsampled)
    problem each step; encoding closes the gap."""
    prob_c, f_star, L = _ridge_problem(encoder="hadamard")
    prob_u, _, _ = _ridge_problem(encoder="uncoded")
    masks = _adversarial_masks(200)
    _, tr_c = run_encoded_gd(prob_c, masks, step_size=1.0 / (1.3 * L + 0.05))
    _, tr_u = run_encoded_gd(prob_u, masks, step_size=1.0 / (1.3 * L + 0.05))
    # both bounded, but coded lands closer to f* on the worst-case schedule
    assert tr_c[-1] <= tr_u[-1] + 1e-6


def test_encoded_lbfgs_linear_convergence():
    """Thm 4: encoded L-BFGS reaches the kappa-ball quickly."""
    prob, f_star, _ = _ridge_problem()
    masks = _random_masks(60, seed=3)
    w, tr = run_encoded_lbfgs(prob, masks, memory=10)
    assert tr[-1] <= 1.05 * f_star
    # convergence should be fast (linear rate): most progress in 30 iters
    assert tr[29] <= 1.2 * f_star


def test_encoded_lbfgs_adversarial():
    prob, f_star, _ = _ridge_problem()
    masks = _adversarial_masks(60)
    _, tr = run_encoded_lbfgs(prob, masks, memory=10)
    assert tr[-1] <= 1.10 * f_star


def test_encoded_proximal_lasso_recovery():
    """Thm 5 + §5.4: ISTA on encoded data recovers the support."""
    rng = np.random.default_rng(0)
    n, p, s = 256, 64, 8
    X = rng.standard_normal((n, p))
    w_true = np.zeros(p)
    w_true[:s] = rng.standard_normal(s) * 2.0
    y = X @ w_true + 0.05 * rng.standard_normal(n)
    enc = hadamard_encoder(n, 2.0, seed=1)
    prob = make_encoded_problem(X, y, enc, M_WORKERS, lam=0.1)
    L = np.linalg.eigvalsh(X.T @ X / n).max()
    masks = _adversarial_masks(300)
    w, tr = run_encoded_proximal(prob, masks, step_size=0.5 / L)
    w = np.asarray(w)
    recovered = np.abs(w[:s]) > 1e-3
    spurious = np.abs(w[s:]) > 1e-3
    assert recovered.all()
    assert spurious.sum() <= 2
    # Thm 5 part 2: per-step objective never blows up by more than kappa
    ratios = tr[1:] / np.maximum(tr[:-1], 1e-12)
    assert ratios.max() < 2.0


def test_encoded_bcd_exact_convergence():
    """Thm 6: model parallelism converges to the EXACT optimum."""
    rng = np.random.default_rng(1)
    n, p = 256, 64
    X = rng.standard_normal((n, p))
    labels = np.sign(X @ rng.standard_normal(p) + 0.01)
    enc = hadamard_encoder(p, 2.0)
    val, grad = phi_logistic(labels)
    prob = make_lifted_problem(X, enc, M_WORKERS, val, grad)
    masks = _adversarial_masks(400)
    v, tr = run_encoded_bcd(prob, masks, step_size=2.0)
    assert tr[-1] < 0.1 * tr[0]
    assert (np.diff(tr) < 1e-6).all()  # monotone descent (smooth case)


def test_encoded_bcd_quadratic_matches_lstsq():
    rng = np.random.default_rng(2)
    n, p = 128, 32
    X = rng.standard_normal((n, p))
    y = X @ rng.standard_normal(p)
    enc = hadamard_encoder(p, 2.0)
    val, grad = phi_quadratic(y)
    prob = make_lifted_problem(X, enc, M_WORKERS, val, grad)
    masks = _random_masks(600, seed=5)
    L = np.linalg.eigvalsh(X.T @ X / n).max()
    v, tr = run_encoded_bcd(prob, masks, step_size=0.9 / (L * (1 + 0.5)))
    # exact interpolation possible -> objective to ~0
    assert tr[-1] < 1e-3 * tr[0]
