"""MoE dispatch unit tests + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS
from repro.models.common import tree_init
from repro.models.moe import moe_apply, moe_defs

CFG = ARCHS["phi3.5-moe-42b-a6.6b"].smoke_variant()


def _setup(capacity_factor=4.0, seed=0):
    cfg = CFG.with_overrides(capacity_factor=capacity_factor)
    p = tree_init(moe_defs(cfg), jax.random.key(seed))
    return cfg, p


def test_dense_equivalence_at_full_capacity():
    """With capacity >= S*k, sort-based dispatch must equal the naive
    per-token expert mixture."""
    cfg, p = _setup(capacity_factor=8.0)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model)) * 0.5
    out, _ = moe_apply(p, x, cfg)

    # naive reference
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    top_w, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    from repro.models.common import act_fn
    act = act_fn(cfg.act)

    def expert(e, xb):
        h = act(xb @ p["moe_wg"][e]) * (xb @ p["moe_wi"][e])
        return h @ p["moe_wo"][e]

    ref = jnp.zeros_like(x)
    for e in range(cfg.n_experts):
        w_e = jnp.where(top_e == e, top_w, 0.0).sum(-1)   # (B,S)
        ref = ref + w_e[..., None] * expert(e, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-2, atol=5e-4)


def test_capacity_drop_monotone():
    """Lower capacity can only drop tokens (output is damped, not corrupted)."""
    cfg_hi, p = _setup(capacity_factor=8.0)
    cfg_lo = cfg_hi.with_overrides(capacity_factor=0.5)
    x = jax.random.normal(jax.random.key(2), (1, 32, cfg_hi.d_model))
    hi, _ = moe_apply(p, x, cfg_hi)
    lo, _ = moe_apply(p, x, cfg_lo)
    assert float(jnp.abs(lo).sum()) <= float(jnp.abs(hi).sum()) + 1e-3


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 99))
def test_aux_losses_sane(seed):
    cfg, p = _setup(seed=seed)
    x = jax.random.normal(jax.random.key(seed), (2, 16, cfg.d_model))
    _, aux = moe_apply(p, x, cfg)
    lb = float(aux["load_balance"])
    assert 0.9 <= lb <= cfg.n_experts + 1e-3   # =1 when perfectly balanced
    assert float(aux["router_z"]) >= 0.0


def test_single_token_routing():
    cfg, p = _setup()
    x = jax.random.normal(jax.random.key(3), (4, 1, cfg.d_model))
    out, _ = moe_apply(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
