"""FRC gradient coding properties (hypothesis): exact decode under any mask
with surviving clusters; graceful degradation otherwise."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (make_frc, coded_weights, decode_exact_possible,
                        assignment_matrix)


@settings(max_examples=40, deadline=None)
@given(m_half=st.integers(2, 8), seed=st.integers(0, 999),
       drop=st.integers(0, 6))
def test_exact_decode_when_clusters_survive(m_half, seed, drop):
    m = 2 * m_half
    code = make_frc(m, 2)
    rng = np.random.default_rng(seed)
    mask = np.ones(m)
    mask[rng.choice(m, size=min(drop, m - 1), replace=False)] = 0.0
    c = np.asarray(coded_weights(code, jnp.asarray(mask)))
    G = assignment_matrix(code)
    per_cluster = c @ G
    if decode_exact_possible(code, mask):
        # every cluster's gradient enters with total weight exactly 1
        np.testing.assert_allclose(per_cluster, 1.0, atol=1e-6)
    else:
        # surviving clusters are rescaled uniformly; erased ones are 0
        alive = per_cluster > 0
        if alive.any():
            np.testing.assert_allclose(
                per_cluster[alive], per_cluster[alive][0], atol=1e-6)
        assert np.all(per_cluster[~alive] == 0.0)


def test_coded_gradient_equals_full_batch():
    """End-to-end: masked weighted gradient == full-batch gradient on a
    linear model when every cluster survives (the paper's erasure recovery
    for the general-loss extension, DESIGN §4)."""
    m, b = 8, 4
    code = make_frc(m, 2)
    rng = np.random.default_rng(0)
    # cluster data
    Xc = rng.standard_normal((b, 5, 3))   # 4 clusters x 5 samples x 3 feat
    yc = rng.standard_normal((b, 5))
    w = jnp.asarray(rng.standard_normal(3))

    def cluster_grad(j):
        X, y = jnp.asarray(Xc[j]), jnp.asarray(yc[j])
        return X.T @ (X @ w - y) / X.shape[0]

    full = sum(cluster_grad(j) for j in range(b)) / b
    mask = np.ones(m)
    mask[[0, 5]] = 0.0   # drops one replica of clusters 0 and 1
    assert decode_exact_possible(code, mask)
    c = np.asarray(coded_weights(code, jnp.asarray(mask)))
    agg = sum(c[i] * cluster_grad(code.clusters[i]) for i in range(m)) / b
    np.testing.assert_allclose(np.asarray(agg), np.asarray(full), rtol=1e-5)


def test_adversarial_tolerance_bound():
    """FRC with beta=2 tolerates ANY single-worker erasure pattern that
    leaves one replica per cluster — and the interleaved layout survives
    a contiguous block failure of m/2 - 1 neighbours."""
    m = 16
    code = make_frc(m, 2)
    for start in range(m):
        mask = np.ones(m)
        idx = (start + np.arange(m // 2 - 1)) % m
        mask[idx] = 0.0
        assert decode_exact_possible(code, mask)
