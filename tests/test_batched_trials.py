"""Batched-trial execution (DESIGN.md §9): vmapped runners vs sequential
scans, trial-seeded sampling, eval_every striding, Pallas combine routing,
and the --trials axis of both harnesses."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (bimodal_delays, hadamard_encoder, identity_encoder,
                        make_encoded_problem, make_lifted_problem, pad_rows,
                        phi_quadratic)
from repro.kernels.coded_reduce import coded_combine_call
from repro.runtime import (ClusterEngine, FastestK, ProblemSpec,
                           batched_scan_async, batched_scan_bcd,
                           batched_scan_gd, batched_scan_prox, get_strategy,
                           scan_async, scan_bcd, scan_gd, scan_prox)

M, K, P, N, T, R = 8, 6, 32, 128, 20, 3


@pytest.fixture(scope="module")
def spec():
    return ProblemSpec.synthetic(N, P, noise=0.5, lam=0.05, seed=0)


@pytest.fixture(scope="module")
def engine():
    return ClusterEngine(bimodal_delays(), M, seed=0)


@pytest.fixture(scope="module")
def batch(engine):
    return engine.sample_schedules(T, FastestK(K), R)


@pytest.fixture(scope="module")
def prob(spec):
    return make_encoded_problem(spec.X, spec.y,
                                pad_rows(hadamard_encoder(N, 2.0), M), M,
                                lam=spec.lam)


# ---------------------------------------------------------------------------
# engine: trial-seeded batch sampling
# ---------------------------------------------------------------------------

def test_sample_schedules_shapes_and_determinism(engine, batch):
    assert batch.masks.shape == (R, T, M)
    assert batch.times.shape == (R, T)
    again = engine.sample_schedules(T, FastestK(K), R)
    np.testing.assert_array_equal(batch.masks, again.masks)
    # realizations are genuinely distinct draws
    assert not np.array_equal(batch.masks[0], batch.masks[1])


def test_realization_r_is_trial_engine_r(engine, batch):
    """Batched realization r == the standalone engine.trial(r) run, so
    non-batchable cells can loop trials on identical realizations."""
    for r in range(R):
        sched = engine.trial(r).sample_schedule(T, FastestK(K))
        np.testing.assert_array_equal(batch.masks[r], sched.masks)
        np.testing.assert_array_equal(batch.times[r], sched.times)
    # realization 0 is the engine's own (single-trial) realization
    s0 = engine.sample_schedule(T, FastestK(K))
    np.testing.assert_array_equal(batch.masks[0], s0.masks)


def test_sample_asyncs_stacks_and_bounds(engine):
    ab = engine.sample_asyncs(100, 4, R)
    assert ab.workers.shape == ab.staleness.shape == ab.times.shape == (R, 100)
    assert ab.staleness.max() <= 4
    t0 = engine.sample_async(100, 4)
    np.testing.assert_array_equal(ab.workers[0], t0.workers)
    np.testing.assert_array_equal(ab.staleness[0], t0.staleness)


# ---------------------------------------------------------------------------
# batched runners match sequential execution on the same mask schedules
# ---------------------------------------------------------------------------

def test_batched_gd_matches_sequential(prob, batch):
    masks = jnp.asarray(batch.masks)
    w, tr = batched_scan_gd(prob, masks, 0.01, jnp.zeros((R, P)), h="l2")
    for r in range(R):
        ws, trs = scan_gd(prob, masks[r], 0.01, jnp.zeros(P), h="l2")
        np.testing.assert_allclose(np.asarray(tr[r]), np.asarray(trs),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(w[r]), np.asarray(ws),
                                   atol=1e-5)


def test_batched_prox_matches_sequential(prob, batch):
    masks = jnp.asarray(batch.masks)
    w, tr = batched_scan_prox(prob, masks, 0.005, jnp.zeros((R, P)))
    for r in range(R):
        ws, trs = scan_prox(prob, masks[r], 0.005, jnp.zeros(P))
        np.testing.assert_allclose(np.asarray(tr[r]), np.asarray(trs),
                                   atol=1e-5)


def test_batched_bcd_matches_sequential(spec, batch):
    enc = pad_rows(hadamard_encoder(P, 2.0), M)
    val, grad = phi_quadratic(spec.y)
    lifted = make_lifted_problem(spec.X, enc, M, val, grad)
    step = 0.9 / (spec.lipschitz() * 2.0)
    b = lifted.XS.shape[-1]
    masks = jnp.asarray(batch.masks)
    v, tr = batched_scan_bcd(lifted, masks, step, jnp.zeros((R, M, b)))
    for r in range(R):
        vs, trs = scan_bcd(lifted, masks[r], step, jnp.zeros((M, b)))
        # batched trace is post-commit == legacy trace[1:]
        np.testing.assert_allclose(np.asarray(tr[r]), np.asarray(trs)[1:],
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(v[r]), np.asarray(vs),
                                   atol=1e-5)


def test_batched_async_matches_sequential(spec, engine):
    prob = make_encoded_problem(spec.X, spec.y,
                                identity_encoder(N).with_workers(M), M,
                                lam=spec.lam)
    ab = engine.sample_asyncs(80, 4, R)
    w, tr = batched_scan_async(prob, jnp.asarray(ab.workers),
                               jnp.asarray(ab.staleness), 0.002,
                               jnp.zeros((R, P)), buffer_size=5)
    for r in range(R):
        ws, trs = scan_async(prob, jnp.asarray(ab.workers[r]),
                             jnp.asarray(ab.staleness[r]), 0.002,
                             jnp.zeros(P), buffer_size=5)
        np.testing.assert_allclose(np.asarray(tr[r]), np.asarray(trs),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# eval_every striding
# ---------------------------------------------------------------------------

def test_eval_every_is_dense_subsample(prob, batch):
    masks = jnp.asarray(batch.masks)
    wd, dense = batched_scan_gd(prob, masks, 0.01, jnp.zeros((R, P)))
    ws, strided = batched_scan_gd(prob, masks, 0.01, jnp.zeros((R, P)),
                                  eval_every=5)
    assert strided.shape == (R, T // 5)
    np.testing.assert_allclose(np.asarray(strided),
                               np.asarray(dense)[:, 4::5], atol=1e-6)
    # the iterate path is identical — only the objective pass is strided
    np.testing.assert_allclose(np.asarray(ws), np.asarray(wd), atol=1e-6)


def test_eval_every_must_divide(prob, batch):
    with pytest.raises(ValueError, match="eval_every"):
        batched_scan_gd(prob, jnp.asarray(batch.masks), 0.01,
                        jnp.zeros((R, P)), eval_every=7)


# ---------------------------------------------------------------------------
# Pallas combine kernel (interpret default + pad-to-block)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("P_", [128, 3000])
def test_pallas_combine_matches_einsum(P_):
    g = jax.random.normal(jax.random.key(0), (M, P_))
    mask = (jax.random.uniform(jax.random.key(1), (M,)) > 0.3)
    c = mask * (M / jnp.maximum(mask.sum(), 1.0))
    # interpret=None resolves from the backend (interpreted off-TPU);
    # P=3000 exercises the pad-to-block path that used to ValueError
    out = coded_combine_call(g, c)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.einsum("m,mp->p", c, g)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# strategy layer: run_batched
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["coded-gd", "uncoded", "coded-bcd",
                                  "coded-lbfgs", "async"])
def test_run_batched_realization0_matches_run(spec, engine, name):
    batched = get_strategy(name).run_batched(spec, engine, steps=T,
                                             trials=R, k=K)
    single = get_strategy(name).run(spec, engine, steps=T, k=K)
    assert batched.objective.shape[0] == R
    np.testing.assert_allclose(batched.objective[0],
                               np.asarray(single.objective), atol=2e-5)
    np.testing.assert_array_equal(batched.times[0], single.times)
    rec = batched.to_record()
    assert rec["trials"] == R
    for key in ("mean", "p50", "p95"):
        assert key in rec["summary"]["wallclock_s"]


def test_run_batched_eval_every_strides_times(spec, engine):
    dense = get_strategy("coded-gd").run_batched(spec, engine, steps=T,
                                                 trials=R, k=K)
    strided = get_strategy("coded-gd").run_batched(spec, engine, steps=T,
                                                   trials=R, k=K,
                                                   eval_every=5)
    np.testing.assert_allclose(strided.objective, dense.objective[:, 4::5],
                               atol=1e-6)
    np.testing.assert_array_equal(strided.times, dense.times[:, 4::5])


def test_run_batched_trials_result_realization(spec, engine):
    res = get_strategy("coded-gd").run_batched(spec, engine, steps=T,
                                               trials=R, k=K)
    one = res.realization(1)
    np.testing.assert_array_equal(one.objective, res.objective[1])
    assert one.schedule is res.schedules.realization(1)


# ---------------------------------------------------------------------------
# harnesses: --trials axis
# ---------------------------------------------------------------------------

def test_compare_matrix_with_trials(tmp_path):
    from repro.runtime.compare import main
    out = tmp_path / "cmp"
    records = main(["--strategies", "coded-gd,uncoded",
                    "--delays", "bimodal", "--n", "128", "--p", "32",
                    "--m", "8", "--k", "6", "--steps", "20",
                    "--trials", "3", "--out", str(out)])
    assert len(records) == 2
    for rec in records:
        assert rec["trials"] == 3
        assert len(rec["times"]) == 3 and len(rec["times"][0]) == 20
        assert rec["summary"]["trials"] == 3
    import csv as _csv
    rows = list(_csv.reader((out / "compare.csv").open()))
    # one row per (cell, trial, step) + header
    assert len(rows) - 1 == 2 * 3 * 20
    assert {row[3] for row in rows[1:]} == {"0", "1", "2"}


def test_workload_matrix_with_trials():
    from repro.workloads.runner import run_workload_matrix
    records = run_workload_matrix(["ridge"], ["uncoded"], preset="smoke",
                                  trials=2, steps=T)
    (rec,) = records
    assert rec["trials"] == 2
    assert len(rec["metric"]) == 2 and len(rec["metric"][0]) == T
    assert "final_metric" in rec["summary"]
    # batched fast path: realization 0 == the single-trial cell
    (single,) = run_workload_matrix(["ridge"], ["uncoded"], preset="smoke",
                                    steps=T)
    np.testing.assert_allclose(rec["metric"][0], single["metric"], atol=2e-5)
