"""Trainer integration + data pipeline + checkpoint roundtrip + straggler
models."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import (make_frc, bimodal_delays, power_law_delays,
                        exponential_delays, multimodal_delays, fastest_k,
                        adversarial_sets, simulate_run)
from repro.data import TokenStream, CodedBatcher
from repro.train.trainer import Trainer, TrainerConfig


def test_coded_batcher_replica_consistency():
    code = make_frc(8, 2)
    stream = TokenStream(128, seed=0)
    b = CodedBatcher(stream, code, rows_per_worker=2, seq_len=16)
    mask = np.ones(8)
    toks, labels, w = b.next_batch(mask)
    assert toks.shape == (16, 16) and labels.shape == (16, 16)
    np.testing.assert_array_equal(labels[:, :-1], toks[:, 1:])
    # replicas (workers i and i+4 share cluster i%4) carry identical rows
    t = toks.reshape(8, 2, 16)
    for i in range(4):
        np.testing.assert_array_equal(t[i], t[i + 4])
    # full mask -> every sample weight contributes 1/beta * rescale == 0.5*1
    np.testing.assert_allclose(w, 0.5)


def test_coded_batcher_masked_weights():
    code = make_frc(8, 2)
    b = CodedBatcher(TokenStream(128), code, 1, 8)
    mask = np.ones(8)
    mask[0] = 0.0   # cluster 0 survives via worker 4
    _, _, w = b.next_batch(mask)
    assert w[0] == 0.0
    assert w[4] == pytest.approx(1.0)   # lone replica carries full weight


def test_trainer_loss_decreases():
    cfg = ARCHS["deepseek-7b"].smoke_variant().with_overrides(
        n_layers=2, vocab=256)
    tcfg = TrainerConfig(m_workers=4, beta=2, wait_k=3, rows_per_worker=2,
                         seq_len=32, steps=25, lr=3e-3, warmup=5,
                         log_every=0)
    tr = Trainer(cfg, tcfg, delay_model=bimodal_delays())
    params, opt, hist = tr.run()
    losses = [h["loss"] for h in hist]
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
    assert hist[-1]["sim_time_s"] > 0


def test_trainer_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import save, restore, latest_step
    cfg = ARCHS["deepseek-7b"].smoke_variant().with_overrides(
        n_layers=2, vocab=128)
    tcfg = TrainerConfig(m_workers=2, beta=2, wait_k=1, seq_len=16, steps=3,
                         log_every=0)
    tr = Trainer(cfg, tcfg)
    params, opt, _ = tr.run()
    save(str(tmp_path), 3, (params, opt))
    assert latest_step(str(tmp_path)) == 3
    params2, opt2 = restore(str(tmp_path), 3, (params, opt))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("model", [bimodal_delays(), power_law_delays(),
                                   exponential_delays(), multimodal_delays()])
def test_delay_models_nonnegative(model):
    rng = np.random.default_rng(0)
    d = model(rng, 1000)
    assert d.shape == (1000,)
    assert (d >= 0).all()


def test_bimodal_has_heavy_mode():
    rng = np.random.default_rng(1)
    d = bimodal_delays()(rng, 4000)
    assert (d > 10).mean() == pytest.approx(0.5, abs=0.05)


def test_fastest_k_and_adversarial_coverage():
    rng = np.random.default_rng(2)
    d = rng.random(16)
    A = fastest_k(d, 4)
    assert len(A) == 4
    assert d[A].max() <= np.delete(d, A).min()
    # adversarial rotation erases every worker eventually
    erased = set()
    for keep in adversarial_sets(16, 12, 10):
        erased |= set(range(16)) - set(keep.tolist())
    assert erased == set(range(16))


def test_adaptive_k_overlap_guarantee():
    """Paper §3.3: adaptive k always yields |A_t ∩ A_{t-1}| > m/beta."""
    from repro.core import adaptive_k
    rng = np.random.default_rng(3)
    m, beta = 16, 2.0
    prev = None
    for _ in range(50):
        d = bimodal_delays()(rng, m)
        A = adaptive_k(d, prev, beta, k_min=8)
        assert len(A) >= 8
        if prev is not None:
            assert len(set(A) & set(prev)) > m / beta
        prev = A


def test_simulate_run_clock_monotone():
    times = [t for _, _, t in simulate_run(bimodal_delays(), 8, 6, 20)]
    assert all(b > a for a, b in zip(times, times[1:]))
