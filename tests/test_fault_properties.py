"""Property tests (hypothesis) for erasure-obliviousness of the FRC code
(DESIGN.md §4, §14): as long as every data cluster keeps >= 1 live replica
the decoded gradient — and hence the whole optimization trajectory — does
not depend on WHICH replicas were erased; below that threshold degradation
is graceful (an unbiased mean over surviving clusters, never corruption).

Skipped when ``hypothesis`` is unavailable (it is not shipped in the
accelerator image; CI installs it from requirements.txt)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.gradient_coding import (coded_weights, decode_exact_possible,
                                        make_frc)

P = 6          # parameter dim of the toy linear problem


def _cluster_masks(beta: int, clusters: int, *, allow_empty: bool):
    """Per-cluster replica-survival bitmask: 1..2^beta-1 keeps >= 1 replica
    alive; 0 (only with ``allow_empty``) erases the whole cluster."""
    lo = 0 if allow_empty else 1
    return st.lists(st.integers(lo, 2 ** beta - 1),
                    min_size=clusters, max_size=clusters)


def _expand(code, bits):
    """Cluster bitmasks -> (m,) worker 0/1 mask (replica j of cluster c is
    alive iff bit j of ``bits[c]`` is set)."""
    mask = np.zeros(code.m)
    seen = [0] * code.num_clusters
    for i in range(code.m):
        c = int(code.clusters[i])
        if (bits[c] >> seen[c]) & 1:
            mask[i] = 1.0
        seen[c] += 1
    return mask


def _decode(code, cluster_grads, mask):
    """Combine per-worker replica gradients with the code's decode weights,
    reducing WITHIN each cluster first (the grouped tree-reduce shape of the
    masked psum): replicas of a cluster hold bit-identical values, so a
    cluster with survivors contributes its gradient exactly."""
    c = np.asarray(coded_weights(code, mask), np.float64)
    out = np.zeros(cluster_grads.shape[1])
    for cl in range(code.num_clusters):
        members = np.nonzero(code.clusters == cl)[0]
        out += c[members].sum() * cluster_grads[cl]
    return out / code.num_clusters


def _problem(seed):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(4, P, P)), rng.normal(size=(4, P))  # (A_c, b_c)


@settings(max_examples=30, deadline=None)
@given(bits=_cluster_masks(2, 4, allow_empty=False),
       seed=st.integers(0, 2 ** 16))
def test_decode_exact_whenever_every_cluster_survives(bits, seed):
    code = make_frc(8, beta=2)
    mask = _expand(code, bits)
    assert decode_exact_possible(code, mask)
    grads = np.random.default_rng(seed).normal(size=(4, P))
    np.testing.assert_allclose(_decode(code, grads, mask), grads.mean(0),
                               rtol=1e-6, atol=1e-9)


@settings(max_examples=25, deadline=None)
@given(bits_a=st.lists(st.integers(1, 3), min_size=40, max_size=40),
       bits_b=st.lists(st.integers(1, 3), min_size=40, max_size=40),
       seed=st.integers(0, 2 ** 16))
def test_trajectory_oblivious_to_which_replica_erased(bits_a, bits_b, seed):
    """Two runs that erase DIFFERENT replicas every step (but always keep a
    survivor per cluster) produce bit-identical iterates: the erasure
    pattern is unobservable above the decode threshold."""
    code = make_frc(8, beta=2)
    A, b = _problem(seed)

    def run(step_bits):
        w = np.zeros(P)
        for t in range(10):
            grads = A @ w - b                        # (clusters, P)
            bits = step_bits[4 * t:4 * t + 4]
            g = _decode(code, grads, _expand(code, bits))
            w = w - 0.05 * g
        return w

    wa, wb = run(bits_a), run(bits_b)
    assert np.array_equal(wa, wb)                    # not merely close
    assert np.isfinite(wa).all()


@settings(max_examples=30, deadline=None)
@given(bits=_cluster_masks(2, 4, allow_empty=True),
       seed=st.integers(0, 2 ** 16))
def test_degradation_below_threshold_is_graceful(bits, seed):
    """With whole clusters erased the decode is still an unbiased mean over
    the SURVIVING clusters (rescaled, finite, never NaN) — and erasing more
    workers can only shrink the surviving-cluster set."""
    code = make_frc(8, beta=2)
    mask = _expand(code, bits)
    grads = np.random.default_rng(seed).normal(size=(4, P))
    out = _decode(code, grads, mask)
    assert np.isfinite(out).all()
    surviving = [cl for cl in range(4) if bits[cl]]
    if surviving:
        assert not decode_exact_possible(code, mask) or len(surviving) == 4
        np.testing.assert_allclose(
            out, grads[surviving].mean(0), rtol=1e-6, atol=1e-9)
    else:
        np.testing.assert_allclose(out, 0.0)         # all erased: hold still
    # monotonicity: any further erasure keeps coverage a subset
    fewer = [v & 0b01 for v in bits]                 # drop the high replica
    kept = {cl for cl in range(4) if fewer[cl]}
    assert kept <= set(surviving)


@settings(max_examples=20, deadline=None)
@given(bits=_cluster_masks(2, 4, allow_empty=True),
       seed=st.integers(0, 2 ** 16))
def test_subk_trajectory_still_descends_its_surviving_objective(bits, seed):
    """Below the decode threshold the iterate optimizes the SURVIVING
    data's objective — and with a step below 1/L that descent is monotone
    per iteration (degradation is objective-wise graceful, never a
    blow-up)."""
    code = make_frc(8, beta=2)
    rng = np.random.default_rng(seed)
    # per-cluster least squares: grad_c(w) = M_c w - r_c with M_c psd
    X = rng.normal(size=(4, 8, P))
    y = rng.normal(size=(4, 8))
    Ms = np.einsum("cnp,cnq->cpq", X, X) / 8.0
    rs = np.einsum("cnp,cn->cp", X, y) / 8.0
    surviving = [cl for cl in range(4) if bits[cl]]
    if not surviving:
        return                                   # all erased: iterate holds
    Msub = Ms[surviving].mean(0)
    rsub = rs[surviving].mean(0)

    def f_sub(w):        # surviving-subset objective (up to a constant)
        return 0.5 * w @ Msub @ w - rsub @ w

    lip = float(np.linalg.eigvalsh(Msub).max())
    step = 0.9 / max(lip, 1e-9)
    mask = _expand(code, bits)
    w = np.zeros(P)
    prev = f_sub(w)
    for _ in range(12):
        g = _decode(code, np.einsum("cpq,q->cp", Ms, w) - rs, mask)
        # decode over survivors == gradient of the surviving objective,
        # rescaled by the survivor fraction (the renormalized mean)
        np.testing.assert_allclose(g, Msub @ w - rsub, rtol=1e-5, atol=1e-8)
        w = w - step * g
        cur = f_sub(w)
        assert cur <= prev + 1e-12               # monotone descent
        prev = cur
