"""repro.workloads: registry round-trip, ground-truth solvers, the four
paper-§5 workloads end-to-end (MF ALS monotonicity, LASSO support F1,
logistic-BCD vs host Newton), data generators, and the CLI."""
import json
import os

import numpy as np
import pytest

from repro.core import constant_delays
from repro.data import (logreg_dataset, logreg_rows, lsq_dataset,
                        mf_ratings_dataset)
from repro.runtime import ClusterEngine
from repro.workloads import (UnsupportedStrategy, Workload,
                             available_workloads, get_workload,
                             ground_truth as gt)


def _full_participation_engine(m: int) -> ClusterEngine:
    return ClusterEngine(constant_delays(0.1), m, seed=0)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_round_trip():
    names = available_workloads()
    assert names == sorted(["ridge", "lasso", "logistic", "mf"])
    for name in names:
        wl = get_workload(name)
        assert isinstance(wl, Workload)
        assert wl.name == name
        assert wl.metric_name != "?"
        assert {"smoke", "bench", "paper"} <= set(wl.presets)
        # the 'coded' alias resolves to a workload-specific coded scheme
        assert wl.resolve_strategy("coded") == wl.canonical_coded
        assert wl.supports(wl.canonical_coded) is None


def test_registry_unknown_raises():
    with pytest.raises(KeyError, match="unknown workload"):
        get_workload("nope")


def test_unsupported_strategy_carries_reason():
    with pytest.raises(UnsupportedStrategy, match="l1"):
        get_workload("ridge").run("coded-prox", preset="smoke")


def test_paper_presets_match_published_dims():
    # the 'paper' preset is configs.paper_native verbatim
    ridge = get_workload("ridge")
    assert ridge.presets["paper"].dims["n"] == ridge.paper_config.n
    assert ridge.presets["paper"].dims["p"] == ridge.paper_config.p
    assert ridge.presets["paper"].m == ridge.paper_config.m


# ---------------------------------------------------------------------------
# Ground-truth solvers
# ---------------------------------------------------------------------------

def test_ridge_ground_truth_is_stationary():
    X, y, _ = lsq_dataset(128, 32, noise=0.5, seed=0)
    w = gt.ridge_solution(X, y, 0.05)
    grad = X.T @ (X @ w - y) / 128 + 0.05 * w
    assert np.abs(grad).max() < 1e-8


def test_lasso_fista_beats_planted_signal_objective():
    X, y, w_true = lsq_dataset(256, 64, noise=0.3, sparse=8, seed=0)
    w = gt.lasso_fista(X, y, 0.05)
    assert gt.lasso_objective(X, y, 0.05, w) <= \
        gt.lasso_objective(X, y, 0.05, w_true) + 1e-9
    assert gt.support_f1(w_true, w_true) == pytest.approx(1.0)


def test_logistic_newton_is_stationary():
    X, labels, _ = logreg_dataset(256, 32, noise=0.3, seed=0)
    w = gt.logistic_newton(X, labels)
    z = X @ w
    s = 1.0 / (1.0 + np.exp(labels * z))
    grad = -(X.T @ (labels * s)) / X.shape[0]
    assert np.abs(grad).max() < 1e-6


# ---------------------------------------------------------------------------
# Data generators (satellite): chunk-deterministic conventions
# ---------------------------------------------------------------------------

def test_logreg_rows_chunk_deterministic():
    X, labels, w = logreg_dataset(600, 24, seed=3)
    Xs, ls, ws = logreg_rows(100, 300, 24, seed=3)
    np.testing.assert_allclose(Xs, X[100:300])
    np.testing.assert_allclose(ls, labels[100:300])
    np.testing.assert_allclose(ws, w)
    assert set(np.unique(labels)) <= {-1.0, 1.0}
    rownorms = np.linalg.norm(X, axis=1)
    np.testing.assert_allclose(rownorms[rownorms > 1e-6], 1.0, atol=1e-9)


def test_mf_ratings_prefix_stable_and_split_disjoint():
    R1, tr1, te1 = mf_ratings_dataset(40, 30, rank=3, density=0.3, seed=5)
    R2, tr2, te2 = mf_ratings_dataset(64, 30, rank=3, density=0.3, seed=5)
    np.testing.assert_allclose(R2[:40], R1)
    np.testing.assert_array_equal(tr2[:40], tr1)
    assert not (tr1 & te1).any()
    assert R1.min() >= 1.0 and R1.max() <= 5.0


# ---------------------------------------------------------------------------
# Workloads end-to-end (smoke scale)
# ---------------------------------------------------------------------------

def test_ridge_gap_shrinks_and_traces_align():
    wl = get_workload("ridge")
    res = wl.run("coded", _full_participation_engine(8), preset="smoke",
                 k=8)
    assert res.metric_name == "subopt_gap"
    assert len(res.times) == len(res.objective) == len(res.metric)
    assert res.metric[-1] < 1e-2 * res.metric[0]
    assert (res.metric >= 0).all()


def test_lasso_support_recovery_f1_at_smoke_scale():
    wl = get_workload("lasso")
    res = wl.run("coded", preset="smoke")  # native engine, k < m
    assert res.metric_name == "support_f1"
    assert res.final_metric >= 0.85
    # F1 recorded at chunk boundaries, with matching time stamps
    assert len(res.metric_times) == len(res.metric) > 1
    assert res.metric_times[-1] == pytest.approx(res.times[-1])


def test_logistic_bcd_approaches_host_newton():
    """Full participation: encoded BCD converges to the SAME optimum family
    as the (sklearn-free) host Newton solve of the unregularized loss."""
    wl = get_workload("logistic")
    data = wl.build("smoke")
    res = wl.run("coded", _full_participation_engine(8), preset="smoke",
                 data=data, k=8, steps=600)
    f_newton = gt.logistic_objective(
        data.X_train, data.y_train,
        gt.logistic_newton(data.X_train, data.y_train))
    assert res.final_objective >= f_newton - 1e-6   # Newton is the optimum
    assert res.final_objective <= f_newton + 0.03   # ...and BCD approaches it
    assert res.final_metric < 0.45                  # held-out error beats coin
    # the objective is monotone under full participation (exact lifting)
    obj = np.asarray(res.objective)
    assert (np.diff(obj) <= 1e-6).all()


def test_mf_als_objective_monotone_under_full_participation():
    wl = get_workload("mf")
    res = wl.run("uncoded", _full_participation_engine(8), preset="smoke",
                 k=8)
    obj = np.asarray(res.objective)
    assert len(obj) == 2 * wl.presets["smoke"].dims["epochs"]
    assert (np.diff(obj) <= 1e-8).all(), f"ALS objective not monotone: {obj}"
    # every half-step routed through the engine: per-step active sets logged
    half_steps = res.extras["half_steps"]
    assert len(half_steps) == len(obj)
    for hs in half_steps:
        assert len(hs["active_sets"]) == wl.presets["smoke"].steps
        assert all(len(a) == 8 for a in hs["active_sets"])  # k = m = 8


def test_mf_coded_matches_exact_als_reference():
    wl = get_workload("mf")
    data = wl.build("smoke")
    ref_train, ref_test = gt.als_reference(
        data.R, data.train, data.test, rank=wl.presets["smoke"].dims["rank"],
        lam=wl.presets["smoke"].lam,
        epochs=wl.presets["smoke"].dims["epochs"])
    res = wl.run("coded", preset="smoke", data=data)
    assert abs(res.final_metric - ref_test) < 0.1


# ---------------------------------------------------------------------------
# CLI + compare integration (satellites)
# ---------------------------------------------------------------------------

def test_workloads_cli_smoke(tmp_path):
    from repro.workloads.runner import main
    out = str(tmp_path / "wl")
    records = main(["--workload", "ridge", "--preset", "smoke",
                    "--strategies", "coded,uncoded,coded-prox,coded-lbgfs",
                    "--steps", "8", "--out", out])
    ran = [r for r in records if "skipped" not in r]
    skipped = [r for r in records if "skipped" in r]
    assert {r["strategy"] for r in ran} == {"coded-lbfgs", "uncoded"}
    # incompatible AND typo'd strategies become skip-with-reason cells
    assert len(skipped) == 2
    reasons = {r["strategy"]: r["skipped"] for r in skipped}
    assert "l1" in reasons["coded-prox"]
    assert "unknown strategy" in reasons["coded-lbgfs"]
    with open(os.path.join(out, "workloads.json")) as f:
        on_disk = json.load(f)
    assert len(on_disk) == 4
    for rec in on_disk:
        if "skipped" in rec:
            continue
        assert rec["metric_name"] == "subopt_gap"
        assert len(rec["metric"]) == len(rec["metric_times"]) > 0
        assert isinstance(rec["final_metric"], float)
    assert os.path.exists(os.path.join(out, "summary.csv"))


def test_compare_workload_axis_records_metric_and_skips():
    from repro.runtime.compare import run_matrix
    recs = run_matrix(["coded", "uncoded", "async"], ["exponential"],
                      workload="lasso", preset="smoke", steps=24)
    by_strategy = {r["strategy"]: r for r in recs}
    assert "skipped" in by_strategy["async"]
    assert by_strategy["async"]["metric_name"] == "support_f1"
    ran = by_strategy["coded-prox"]
    assert ran["metric_name"] == "support_f1"
    assert 0.0 <= ran["final_metric"] <= 1.0


def test_compare_plain_cells_carry_metric_fields():
    from repro.runtime.compare import run_matrix
    recs = run_matrix(["uncoded"], ["exponential"], n=64, p=16, m=4, k=3,
                      steps=5)
    assert recs[0]["metric_name"] == "objective"
    assert recs[0]["final_metric"] == recs[0]["final_objective"]
