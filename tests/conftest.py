import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _isolated_runstore(tmp_path, monkeypatch):
    """Point the run store at a per-test tmp dir so executing experiments
    in tests never writes manifests into the repo's runs/store."""
    monkeypatch.setenv("REPRO_RUNSTORE", str(tmp_path / "runstore"))
