import numpy as np
import pytest


def pytest_collection_modifyitems(config, items):
    """Per-test wall-clock ceiling (a hung chaos/fault test must fail, not
    wedge the suite).  Applied only when pytest-timeout is installed (CI
    does, via requirements.txt); without the plugin the suite runs
    unchanged — no warnings, no dependency."""
    if not config.pluginmanager.hasplugin("timeout"):
        return
    for item in items:
        if item.get_closest_marker("timeout") is None:
            item.add_marker(pytest.mark.timeout(300))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _isolated_runstore(tmp_path, monkeypatch):
    """Point the run store at a per-test tmp dir so executing experiments
    in tests never writes manifests into the repo's runs/store."""
    monkeypatch.setenv("REPRO_RUNSTORE", str(tmp_path / "runstore"))
