"""repro.runtime: scan runners vs legacy loops, engine accounting, async
staleness bound, strategy registry, fixed points, CLI harness."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (gd_step, hadamard_encoder, identity_encoder,
                        make_encoded_problem, make_lifted_problem,
                        original_objective, phi_quadratic,
                        replication_encoder, pad_rows, bimodal_delays,
                        constant_delays)
from repro.core.data_parallel import prox_step
from repro.runtime import (AdversarialRotation, ClusterEngine, Deadline,
                           FastestK, ProblemSpec, available_strategies,
                           get_strategy, make_delay_model, make_policy,
                           scan_async, scan_bcd, scan_gd, scan_prox)

M, K, P, N = 16, 12, 64, 256


@pytest.fixture(scope="module")
def spec():
    return ProblemSpec.synthetic(N, P, noise=0.5, lam=0.05, seed=0)


@pytest.fixture(scope="module")
def engine():
    return ClusterEngine(bimodal_delays(), M, seed=0)


@pytest.fixture(scope="module")
def schedule(engine):
    return engine.sample_schedule(60, FastestK(K))


def _problem(spec, enc):
    return make_encoded_problem(spec.X, spec.y, pad_rows(enc, M), M,
                                lam=spec.lam)


# ---------------------------------------------------------------------------
# scan-fused runners reproduce the legacy per-step loops
# ---------------------------------------------------------------------------

def test_scan_gd_matches_legacy_loop(spec, schedule):
    prob = _problem(spec, hadamard_encoder(N, 2.0))
    step = 0.01
    w_scan, tr_scan = scan_gd(prob, jnp.asarray(schedule.masks), step,
                              jnp.zeros(P), h="l2")
    w = jnp.zeros(P)
    tr = []
    for t in range(schedule.steps):
        w = gd_step(prob, w, jnp.asarray(schedule.masks[t]), step, h="l2")
        tr.append(float(original_objective(prob, w, h="l2")))
    np.testing.assert_allclose(np.asarray(tr_scan), np.asarray(tr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(w_scan), np.asarray(w), atol=1e-5)


def test_scan_prox_matches_legacy_loop(spec, schedule):
    prob = _problem(spec, hadamard_encoder(N, 2.0))
    step = 0.005
    w_scan, tr_scan = scan_prox(prob, jnp.asarray(schedule.masks), step,
                                jnp.zeros(P))
    w = jnp.zeros(P)
    tr = []
    for t in range(schedule.steps):
        w = prox_step(prob, w, jnp.asarray(schedule.masks[t]), step)
        tr.append(float(original_objective(prob, w, h="l1")))
    np.testing.assert_allclose(np.asarray(tr_scan), np.asarray(tr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(w_scan), np.asarray(w), atol=1e-5)


def test_scan_bcd_matches_legacy_loop(spec, schedule):
    enc = pad_rows(hadamard_encoder(P, 2.0), M)
    val, grad = phi_quadratic(spec.y)
    prob = make_lifted_problem(spec.X, enc, M, val, grad)
    step = 0.9 / (spec.lipschitz() * 2.0)
    v0 = jnp.zeros((M, prob.XS.shape[-1]))
    v_scan, tr_scan = scan_bcd(prob, jnp.asarray(schedule.masks), step, v0)

    import jax

    @jax.jit
    def legacy_step(v, mask):
        z = jnp.einsum("mnb,mb->mn", prob.XS, v).sum(axis=0)
        d = -step * jnp.einsum("mnb,n->mb", prob.XS, prob.phi_grad(z))
        return v + mask[:, None] * d, prob.phi_val(z)

    v = v0
    tr = []
    for t in range(schedule.steps):
        v, fval = legacy_step(v, jnp.asarray(schedule.masks[t]))
        tr.append(float(fval))
    tr.append(float(val(jnp.einsum("mnb,mb->n", prob.XS, v))))
    np.testing.assert_allclose(np.asarray(tr_scan), np.asarray(tr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(v_scan), np.asarray(v), atol=1e-5)


def test_run_encoded_wrappers_use_scan(spec, schedule):
    """The legacy core entry points now delegate; traces stay identical."""
    from repro.core import run_encoded_gd
    prob = _problem(spec, hadamard_encoder(N, 2.0))
    w1, tr1 = run_encoded_gd(prob, schedule.masks, 0.01)
    w2, tr2 = scan_gd(prob, jnp.asarray(schedule.masks), 0.01,
                      jnp.zeros(P), h="l2")
    np.testing.assert_allclose(tr1, np.asarray(tr2), atol=1e-6)


# ---------------------------------------------------------------------------
# engine: schedules, policies, wall-clock accounting
# ---------------------------------------------------------------------------

def test_schedule_wallclock_matches_order_statistic():
    """Barrier accounting == k-th order statistic of (delay + compute)."""
    eng = ClusterEngine(bimodal_delays(), M, seed=7)
    sched = eng.sample_schedule(20, FastestK(K))
    assert (np.diff(sched.times) > 0).all()
    for ev in sched.events:
        kth = np.sort(ev.arrivals - ev.start)[K - 1]
        assert ev.commit - ev.start == pytest.approx(
            kth + eng.master_overhead)
        assert ev.active.size == K


def test_adversarial_policy_sweeps_all_workers():
    eng = ClusterEngine(constant_delays(1.0), M, seed=0)
    sched = eng.sample_schedule(2 * M, AdversarialRotation(K))
    erased = (sched.masks == 0.0)
    assert erased.any(axis=0).all(), "every worker must be erased at least once"
    assert (sched.masks == 1.0).any(axis=0).all()
    assert (sched.masks.sum(axis=1) == K).all()


def test_deadline_policy_bounds_and_floor():
    eng = ClusterEngine(bimodal_delays(), M, seed=3)
    sched = eng.sample_schedule(30, Deadline(deadline=2.0, k_min=4))
    assert (sched.masks.sum(axis=1) >= 4).all()
    for ev in sched.events:
        # every worker beyond the floor made the deadline
        if ev.active.size > 4:
            assert ((ev.arrivals - ev.start)[ev.active]
                    <= 2.0 + eng.compute_time + 1e-12).all()


def test_adaptive_k_policy_overlap():
    eng = ClusterEngine(bimodal_delays(), M, seed=5)
    policy = make_policy("adaptive-k", beta=2.0, k_min=4)
    sched = eng.sample_schedule(30, policy)
    need = int(np.floor(M / 2.0)) + 1
    for a, b in zip(sched.events[:-1], sched.events[1:]):
        assert np.intersect1d(a.active, b.active).size >= need


# ---------------------------------------------------------------------------
# async: staleness bound + per-arrival accounting
# ---------------------------------------------------------------------------

def test_async_staleness_bound_respected():
    eng = ClusterEngine(bimodal_delays(), M, seed=1)
    for bound in (0, 3, 8):
        tr = eng.sample_async(300, staleness_bound=bound)
        assert tr.staleness.max() <= bound
        assert (tr.staleness >= 0).all()
        assert (np.diff(tr.times) >= 0).all()
        # read version + staleness reconstructs the master version sequence
        np.testing.assert_array_equal(tr.read_versions + tr.staleness,
                                      np.arange(300))


def test_async_strategy_converges(spec, engine):
    res = get_strategy("async").run(spec, engine, steps=40,
                                    staleness_bound=8)
    assert res.meta["max_staleness"] <= 8
    assert res.objective[-1] < 0.2 * res.objective[0]
    assert np.isfinite(res.objective).all()


def test_scan_async_zero_staleness_is_sequential_sgd(spec):
    """With staleness 0 every update reads the CURRENT iterate: the ring
    buffer must be exact — cross-check against a plain host loop."""
    prob = _problem(spec, identity_encoder(N))
    U = 64
    rng = np.random.default_rng(0)
    workers = rng.integers(0, M, size=U)
    step = 0.002
    w_dev, tr = scan_async(prob, jnp.asarray(workers),
                           jnp.zeros(U, jnp.int32), step,
                           jnp.zeros(P), buffer_size=1, h="l2")
    w = np.zeros(P)
    SX, Sy = np.asarray(prob.SX), np.asarray(prob.Sy)
    for i in workers:
        g = SX[i].T @ (SX[i] @ w - Sy[i]) * (M / (prob.n * prob.beta))
        w = w - step * (g + prob.lam * w)
    np.testing.assert_allclose(np.asarray(w_dev), w, atol=1e-5)


# ---------------------------------------------------------------------------
# strategies: registry + fixed points
# ---------------------------------------------------------------------------

def test_registry_has_all_paper_strategies():
    names = available_strategies()
    for want in ["coded-gd", "coded-prox", "coded-lbfgs", "coded-bcd",
                 "uncoded", "replication", "async"]:
        assert want in names
    with pytest.raises(KeyError):
        get_strategy("nope")


@pytest.mark.parametrize("name", ["uncoded", "replication"])
def test_full_participation_recovers_ridge_optimum(spec, name):
    """With no erasures (k = m) uncoded/replication gradients are EXACT, so
    the run converges to the known closed-form ridge fixed point."""
    eng = ClusterEngine(constant_delays(0.1), M, seed=0)
    res = get_strategy(name).run(spec, eng, steps=400, k=M)
    w_star = spec.w_star()
    prob = _problem(spec, identity_encoder(N))
    f_star = float(original_objective(prob, jnp.asarray(w_star), h="l2"))
    assert res.final_objective == pytest.approx(f_star, rel=1e-3)
    np.testing.assert_allclose(res.w, w_star, atol=1e-2)


def test_coded_gd_near_optimum_under_erasures(spec, engine):
    res = get_strategy("coded-gd").run(spec, engine, steps=300, k=K)
    w_star = spec.w_star()
    prob = _problem(spec, hadamard_encoder(N, 2.0))
    f_star = float(original_objective(prob, jnp.asarray(w_star), h="l2"))
    assert res.final_objective <= 1.1 * f_star


def test_strategies_share_delay_realization(spec, engine):
    """Same engine => same schedule => identical wall-clock for sync runs."""
    r1 = get_strategy("coded-gd").run(spec, engine, steps=25, k=K)
    r2 = get_strategy("uncoded").run(spec, engine, steps=25, k=K)
    np.testing.assert_array_equal(r1.times, r2.times)


# ---------------------------------------------------------------------------
# compare harness
# ---------------------------------------------------------------------------

def test_compare_cli_writes_traces(tmp_path):
    from repro.runtime.compare import main
    out = tmp_path / "cmp"
    records = main(["--strategies", "coded-gd,uncoded,async",
                    "--delays", "bimodal,exponential",
                    "--n", "128", "--p", "32", "--m", "8", "--k", "6",
                    "--steps", "20", "--out", str(out)])
    assert len(records) == 6
    import csv as _csv
    import json as _json
    data = _json.loads((out / "compare.json").read_text())
    assert {r["strategy"] for r in data} == {"coded-gd", "uncoded", "async"}
    for rec in data:
        assert len(rec["times"]) == len(rec["objective"]) > 0
        assert rec["wallclock_s"] > 0
    rows = list(_csv.reader((out / "compare.csv").open()))
    assert rows[0] == ["workload", "strategy", "delay", "trial", "step",
                       "time_s", "objective", "metric_name", "final_metric",
                       "skipped"]
    assert len(rows) - 1 == sum(len(r["times"]) for r in data)


def test_delay_model_registry():
    for name in ["bimodal", "power_law", "exponential", "multimodal",
                 "constant"]:
        model = make_delay_model(name)
        d = model(np.random.default_rng(0), 8)
        assert d.shape == (8,) and (d >= 0).all()
    with pytest.raises(KeyError):
        make_delay_model("gaussian")
