"""Sharding rules (divisibility fallbacks) + loop-aware HLO analysis."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCHS
from repro.launch.hlo_analysis import analyze_hlo, _shape_bytes
from repro.models import transformer as T
from repro.sharding import spec_for_shape, make_specs


def _abstract_mesh(sizes, names):
    """AbstractMesh across JAX versions: <=0.4.x takes ((name, size), ...)
    pairs; newer releases take (sizes, names)."""
    try:
        return AbstractMesh(tuple(zip(names, sizes)))
    except TypeError:
        return AbstractMesh(tuple(sizes), tuple(names))


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH3 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_spec_divisible_dims_sharded():
    s = spec_for_shape(MESH, (5120, 13824), ("embed", "ff"))
    assert s == P(("data",), "model")


def test_spec_non_divisible_falls_back():
    # 28 heads % 16 != 0 -> replicated head dim
    s = spec_for_shape(MESH, (3584, 28, 128), ("embed", "heads", "head_dim"))
    assert s == P(("data",), None, None)


def test_spec_axis_used_once():
    # expert dim takes `model`; ff cannot reuse it
    s = spec_for_shape(MESH, (16, 4096, 6400), ("expert", "embed", "ff"))
    assert s == P("model", ("data",), None)


def test_spec_multipod_fsdp():
    s = spec_for_shape(MESH3, (8192, 24576), ("embed", "ff"))
    assert s == P(("pod", "data"), "model")


def test_make_specs_whole_model():
    cfg = ARCHS["phi3.5-moe-42b-a6.6b"]
    shapes = jax.eval_shape(lambda: T.init_params(cfg, jax.random.key(0)))
    specs = make_specs(MESH, shapes, T.param_axes(cfg))
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat) == len(jax.tree.leaves(shapes))
    # something must actually be sharded over each axis
    txt = str(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
    assert "model" in txt and "data" in txt


def test_shape_bytes():
    assert _shape_bytes("f32[8,128]") == 8 * 128 * 4
    assert _shape_bytes("bf16[2,2]{1,0}") == 8
    assert _shape_bytes("(f32[4], s32[2])") == 16 + 8


def test_analyze_hlo_scan_multiplier():
    """Loop-aware flops must be trip_count x body flops (cost_analysis is
    known to count while bodies once)."""
    def scanned(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=8)
        return y
    c = jax.jit(scanned).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax<=0.4.x: one dict per partition
        ca = ca[0]
    naive = ca["flops"]
    aware = analyze_hlo(c.as_text())["flops"]
    single = 2 * 128 ** 3
    assert naive < 1.01 * single      # XLA counts the body once
    assert aware == 8 * single        # we count trips


def test_analyze_hlo_collectives_in_loop():
    import os
    # uses however many local devices exist (1 is fine: no collectives then)
    txt = """
HloModule test
%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4] get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %ar = f32[4] all-reduce(%x), to_apply=%add
  ROOT %t = (s32[], f32[4]) tuple(%i2, %ar)
}
%cond (p2: (s32[], f32[4])) -> pred[] {
  %p2 = (s32[], f32[4]) parameter(0)
  %j = s32[] get-tuple-element(%p2), index=0
  %lim = s32[] constant(12)
  ROOT %lt = pred[] compare(%j, %lim), direction=LT
}
ENTRY %main (a: f32[4]) -> f32[4] {
  %a = f32[4] parameter(0)
  %zero = s32[] constant(0)
  %tup = (s32[], f32[4]) tuple(%zero, %a)
  %w = (s32[], f32[4]) while(%tup), condition=%cond, body=%body
  ROOT %out = f32[4] get-tuple-element(%w), index=1
}
"""
    t = analyze_hlo(txt)
    assert t["all-reduce"] == 12 * 16   # 12 trips x 16 bytes
