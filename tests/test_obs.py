"""repro.obs (DESIGN.md §11): recorder capture + JSONL/Perfetto round-trips,
deterministic event streams, per-realization lanes, metrics vs a
hand-computed schedule, async staleness clamping at the trace boundary,
the CompileWatch compile/execute split, the disabled-path no-op guarantee
(structure + overhead guard), the report CLI and the experiments wiring
(ObsAxis gating, --trace/--metrics-out end-to-end)."""
import contextlib
import csv
import json
import time

import numpy as np
import pytest

from repro.obs import (CompileWatch, Counter, Gauge, Histogram,
                       MetricsRegistry, TraceRecorder, async_metrics,
                       cell_summary, clamp_async_event, current_recorder,
                       schedule_metrics, span)
from repro.obs.report import main as report_main, phase_breakdown
from repro.obs.timing import _STATE as _timing_state
from repro.runtime import ClusterEngine, FastestK, make_delay_model
from repro.runtime.engine import AsyncTrace, IterationEvent, Schedule

M, K, T = 8, 6, 12


def _engine(seed=0, m=M):
    return ClusterEngine(make_delay_model("bimodal"), m, seed=seed)


def _hand_schedule():
    """3 iterations x 3 workers with known miss rates and latencies."""
    masks = np.asarray([[1, 1, 0], [1, 0, 1], [1, 1, 1]], dtype=np.float32)
    times = np.asarray([1.0, 2.5, 3.0])
    events, now = [], 0.0
    for t in range(3):
        active = np.flatnonzero(masks[t])
        events.append(IterationEvent(
            t=t, start=now, commit=float(times[t]), active=active,
            arrivals=np.full(3, float(times[t]))))
        now = float(times[t])
    return Schedule(3, masks, times, tuple(events))


# ---------------------------------------------------------------------------
# recorder capture
# ---------------------------------------------------------------------------

def test_disabled_path_is_noop():
    assert current_recorder() is None
    assert isinstance(span("x", a=1), contextlib.nullcontext)
    rec = TraceRecorder()
    _engine().sample_schedule(T, FastestK(K))
    assert rec.events() == []          # nothing recorded while inactive


def test_engine_schedule_capture_and_determinism():
    def capture():
        rec = TraceRecorder()
        with rec.activate():
            _engine().sample_schedule(T, FastestK(K))
        return rec
    a, b = capture(), capture()
    iters = a.iteration_events()
    assert len(iters) == T
    assert len(a.worker_events()) == T * M
    assert [e.name for e in a.spans()] == ["sample-schedule"]
    # fixed seed => bit-identical event streams
    assert [e.to_dict() for e in a.events() if e.kind != "span"] == \
        [e.to_dict() for e in b.events() if e.kind != "span"]
    # iter durations/commits mirror the schedule's wall-clock accounting
    sched = _engine().sample_schedule(T, FastestK(K))
    np.testing.assert_allclose([e.ts + e.dur for e in iters], sched.times)


def test_batched_lanes_one_per_realization():
    R = 3
    rec = TraceRecorder()
    with rec.activate():
        _engine().sample_schedules(T, FastestK(K), R)
    lanes = {e.realization for e in rec.iteration_events()}
    assert lanes == set(range(R))
    for r in range(R):
        assert sum(e.realization == r for e in rec.iteration_events()) == T


def test_trial_engines_land_on_their_lane():
    """Host-loop harnesses (engine.trial(r)) must hit the same lanes as the
    batched samplers."""
    eng = _engine()
    rec = TraceRecorder()
    with rec.activate():
        for r in range(3):
            eng.trial(r).sample_schedule(T, FastestK(K))
    assert {e.realization for e in rec.iteration_events()} == {0, 1, 2}


def test_async_capture_counts():
    rec = TraceRecorder()
    with rec.activate():
        tr = _engine().sample_async(30, 4)
    ups = [e for e in rec.events() if e.kind == "update"]
    assert len(ups) == tr.updates == 30
    summaries = [e for e in rec.events() if e.name == "async-summary"]
    assert len(summaries) == 1
    assert summaries[0].args["dropped"] == tr.dropped
    assert summaries[0].args["staleness_clamped"] == 0


# ---------------------------------------------------------------------------
# round-trips
# ---------------------------------------------------------------------------

def test_jsonl_roundtrip_and_perfetto(tmp_path):
    rec = TraceRecorder(meta={"suite": "test"})
    with rec.activate(), rec.cell("cellA"):
        with rec.span("encode", strategy="coded-gd"):
            pass
        _engine().sample_schedule(4, FastestK(K))
    path = tmp_path / "trace.jsonl"
    rec.to_jsonl(str(path))
    back = TraceRecorder.load(str(path))
    assert back.meta == {"suite": "test"}
    assert [e.to_dict() for e in back.events()] == \
        [e.to_dict() for e in rec.events()]

    pf = tmp_path / "trace.perfetto.json"
    back.to_perfetto(str(pf))
    doc = json.loads(pf.read_text())
    tev = doc["traceEvents"]
    names = {e.get("args", {}).get("name") for e in tev if e["ph"] == "M"}
    assert "host (phase spans)" in names
    assert "sim cellA [r0]" in names
    assert f"worker:{M - 1}" in names
    # complete events carry microsecond timestamps; at least the spans + iters
    assert sum(e["ph"] == "X" for e in tev) >= 1 + 4 + 4 * M


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_registry_counter_gauge_histogram():
    reg = MetricsRegistry()
    reg.counter("hits").inc()
    reg.counter("hits").inc(2)
    reg.gauge("m").set(8)
    reg.histogram("lat").observe_many([1.0, 2.0, 3.0, 4.0])
    s = reg.summary()
    assert s["hits"] == 3 and s["m"] == 8
    assert s["lat"]["count"] == 4 and s["lat"]["mean"] == 2.5
    assert s["lat"]["p50"] == 2.5
    with pytest.raises(TypeError):
        reg.counter("m")


def test_schedule_metrics_hand_computed():
    sm = schedule_metrics([_hand_schedule()])
    assert sm["iterations"] == 3 and sm["workers"] == 3
    np.testing.assert_allclose(sm["miss_rate"], [0.0, 1 / 3, 1 / 3])
    np.testing.assert_allclose(sm["mean_miss_rate"], 2 / 9)
    np.testing.assert_allclose(sm["max_miss_rate"], 1 / 3)
    assert sm["active_size"]["hist"] == {"2": 2, "3": 1}
    # barrier latencies diff([1.0, 2.5, 3.0], prepend 0) = [1.0, 1.5, 0.5]
    lat = sm["step_latency_s"]
    assert lat["count"] == 3
    np.testing.assert_allclose(lat["p50"], 1.0)
    np.testing.assert_allclose([lat["min"], lat["max"]], [0.5, 1.5])


def test_async_metrics_engine_trace_never_clamps():
    tr = _engine().sample_async(40, 5)
    am = async_metrics([tr])
    assert am["updates"] == 40
    assert am["staleness_clamped"] == 0
    assert am["dropped"] == tr.dropped
    assert am["staleness"]["max"] <= 5


def test_async_clamp_on_inconsistent_trace():
    # update u=1 claims read_version 5 with staleness 0: rv + tau != u and
    # rv >= total => must be snapped into range and counted
    bad = AsyncTrace(
        m=2, workers=np.asarray([0, 1], dtype=np.int32),
        staleness=np.asarray([0, 0], dtype=np.int32),
        read_versions=np.asarray([0, 5], dtype=np.int32),
        times=np.asarray([0.1, 0.2]), dropped=0)
    assert clamp_async_event(1, 0, 5, 2) == (0, 1, True)
    am = async_metrics([bad])
    assert am["staleness_clamped"] == 1
    rec = TraceRecorder()
    rec.record_async(bad)
    summary = [e for e in rec.events() if e.name == "async-summary"][0]
    assert summary.args["staleness_clamped"] == 1
    # the exported event stream carries the clamped values
    ups = [e for e in rec.events() if e.kind == "update"]
    assert ups[1].args == {"staleness": 0, "read_version": 1}


def test_cell_summary_dispatches_both_kinds():
    rec = TraceRecorder()
    with rec.activate():
        _engine().sample_schedule(5, FastestK(K))
        _engine().sample_async(10, 3)
    cs = cell_summary(rec.sources_since(0))
    assert cs["schedule"]["iterations"] == 5
    assert cs["async"]["updates"] == 10


# ---------------------------------------------------------------------------
# timing / compile split
# ---------------------------------------------------------------------------

def test_compile_watch_splits_compile_from_execute():
    jax = pytest.importorskip("jax")
    if not _timing_state["available"]:
        pytest.skip("jax.monitoring unavailable")
    import jax.numpy as jnp

    @jax.jit
    def f(x, c):
        return jnp.sin(x) * c

    x = jnp.arange(101.0)
    with CompileWatch() as cold:
        jax.block_until_ready(f(x, 2.0))
    assert cold.compiles >= 1
    assert cold.compile_s > 0.0
    with CompileWatch() as warm:
        jax.block_until_ready(f(x, 2.0))
    assert warm.compiles == 0 and warm.compile_s == 0.0
    for cw in (cold, warm):
        assert cw.execute_s >= 0.0
        np.testing.assert_allclose(cw.compile_s + cw.execute_s, cw.total_s)


def test_tracing_overhead_disabled_under_5_percent():
    """With no active recorder the hooks are one is-None check; budget 5%
    (plus absolute slack for timer noise) on an engine-sampling loop."""
    eng = _engine()

    def work():
        eng.sample_schedule(T, FastestK(K))

    def best_of(n=7):
        best = float("inf")
        for _ in range(n):
            t0 = time.perf_counter()
            work()
            best = min(best, time.perf_counter() - t0)
        return best

    best_of()                          # warm caches / allocator
    t_off = best_of()
    assert current_recorder() is None
    # absolute slack on BOTH sides: sub-millisecond work drifts either way
    # on a busy host, and a faster re-measure is not an overhead signal
    assert t_off * 0.95 - 2e-3 < best_of() < t_off * 1.05 + 2e-3


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

def test_report_renders_phases_and_lanes(tmp_path, capsys):
    rec = TraceRecorder()
    with rec.activate(), rec.cell("ridge/codedxbimodal"):
        with rec.span("encode"):
            pass
        _engine().sample_schedule(6, FastestK(K))
        _engine().sample_async(8, 3)
    path = tmp_path / "t.jsonl"
    rec.to_jsonl(str(path))
    text = report_main([str(path), "--max-steps", "4"])
    assert "phase breakdown" in text
    assert "straggler timeline — cell=ridge/codedxbimodal" in text
    assert "per-worker miss-rate" in text
    assert "staleness histogram" in text
    rows = phase_breakdown(rec.events())
    assert [r[0] for r in rows][:1] == ["encode"] or \
        "encode" in [r[0] for r in rows]


# ---------------------------------------------------------------------------
# experiments wiring (ObsAxis gating + CLI flags)
# ---------------------------------------------------------------------------

def _small_spec(obs=None, strategies=("coded-gd",)):
    from repro.experiments import (DelayAxis, ExperimentSpec, ObsAxis,
                                   PlacementAxis, ProblemAxis, StrategyAxis,
                                   TrialsAxis)
    return ExperimentSpec(
        problems=(ProblemAxis.synthetic(64, 16),),
        strategies=tuple(StrategyAxis(s) for s in strategies),
        delays=DelayAxis(delays=("bimodal",), m=M),
        trials=TrialsAxis(trials=2), placement=PlacementAxis(mode="vmap"),
        steps=8, obs=obs if obs is not None else ObsAxis())


def test_obs_axis_gates_record_fields():
    from repro.experiments import ObsAxis
    from repro.experiments.execute import run
    plain = run(_small_spec())
    assert plain.recorder is None
    for key in ("obs", "compile_s", "execute_s", "host_s", "compiles"):
        assert key not in plain.records[0]

    observed = run(_small_spec(obs=ObsAxis(metrics=True)))
    assert observed.recorder is not None
    rec = observed.records[0]
    assert rec["compiles"] >= 0
    np.testing.assert_allclose(rec["compile_s"] + rec["execute_s"],
                               rec["host_s"], rtol=1e-6)
    sm = rec["obs"]["schedule"]
    assert sm["workers"] == M and sm["iterations"] == 2 * 8
    # stripping the obs keys recovers the byte-identical default record
    stripped = {k: v for k, v in rec.items() if k not in
                ("obs", "compile_s", "execute_s", "host_s", "compiles")}
    assert stripped == plain.records[0]


def test_obs_trace_export_from_execute(tmp_path):
    from repro.experiments import ObsAxis
    from repro.experiments.execute import run
    prefix = tmp_path / "exp" / "trace"
    result = run(_small_spec(obs=ObsAxis(trace=str(prefix))))
    loaded = TraceRecorder.load(str(prefix) + ".jsonl")
    iters = loaded.iteration_events()
    assert len(iters) == 2 * 8
    assert {e.cell for e in iters} == {"coded-gdxbimodal"}
    assert {e.realization for e in iters} == {0, 1}
    doc = json.loads((tmp_path / "exp" / "trace.perfetto.json").read_text())
    assert len(doc["traceEvents"]) > 0
    assert result.recorder is not None


def test_metrics_csv_writer(tmp_path):
    from repro.experiments import ObsAxis, write_metrics_csv
    from repro.experiments.execute import run
    result = run(_small_spec(obs=ObsAxis(metrics=True),
                             strategies=("coded-gd", "async")))
    path = tmp_path / "metrics.csv"
    write_metrics_csv(result.records, str(path))
    rows = list(csv.DictReader(path.open()))
    assert len(rows) == 2
    sync = next(r for r in rows if r["strategy"] == "coded-gd")
    assert float(sync["mean_miss_rate"]) == pytest.approx(1 - K / M, abs=0.2)
    assert float(sync["compile_s"]) >= 0.0
    asyn = next(r for r in rows if r["strategy"] == "async")
    assert asyn["staleness_mean"] != ""
    assert asyn["staleness_clamped"] == "0"


def test_cli_trace_and_metrics_flags(tmp_path):
    from repro.experiments.run import main
    out = tmp_path / "out"
    trace = tmp_path / "trace"
    metrics = tmp_path / "metrics.csv"
    main(["--strategies", "coded-gd", "--delays", "bimodal", "--n", "64",
          "--p", "16", "--m", str(M), "--steps", "6", "--trials", "2",
          "--out", str(out), "--trace", str(trace),
          "--metrics-out", str(metrics)])
    assert (out / "experiments.json").exists()
    n_iter = sum(1 for line in open(str(trace) + ".jsonl")
                 if json.loads(line).get("kind") == "iter")
    assert n_iter == 2 * 6
    json.loads(open(str(trace) + ".perfetto.json").read())
    assert len(list(csv.DictReader(metrics.open()))) == 1


def test_workload_matrix_obs_kwarg():
    from repro.experiments import ObsAxis
    from repro.workloads.runner import run_workload_matrix
    records = run_workload_matrix(
        ["ridge"], ["uncoded"], steps=6, trials=2,
        obs=ObsAxis(metrics=True))
    assert "obs" in records[0] and "compile_s" in records[0]
    plain = run_workload_matrix(["ridge"], ["uncoded"], steps=6, trials=2)
    assert "obs" not in plain[0]
