"""Cross-run analytics (DESIGN.md §13): P² sketch accuracy at O(1)
memory, delay-tail estimators feeding the metrics CSV, run-store
manifest round-trips, and the diff CLI's regression gate (exit 0 on
identical runs, non-zero on an injected 2x slowdown)."""
import copy
import csv
import json
import os

import numpy as np
import pytest

from repro.obs.diff import main as diff_main
from repro.obs.runstore import (RunStore, provenance, record_experiment,
                                spec_hash)
from repro.obs.sketch import (DelayTailEstimator, Ewma, P2Quantile,
                              QuantileSketch)

# ---------------------------------------------------------------------------
# sketches
# ---------------------------------------------------------------------------


def test_sketch_exact_below_buffer():
    s = QuantileSketch(buffer_size=64)
    vals = [3.0, 1.0, 2.0, 5.0, 4.0]
    s.observe_many(vals)
    assert not s.spilled
    assert s.quantile(50) == np.percentile(vals, 50)
    assert s.summary()["count"] == 5
    assert "approx" not in s.summary()


@pytest.mark.parametrize("dist", ["lognormal", "uniform", "exponential"])
def test_sketch_within_1pct_of_exact_on_1e6_samples(dist):
    """The ISSUE-8 accuracy contract: p50/p95/p99 within 1% of exact
    np.percentile on 10^6 samples, while holding O(1) state (the raw
    buffer is dropped at the spill)."""
    rng = np.random.default_rng(7)
    n = 1_000_000 if dist == "lognormal" else 200_000
    x = {"lognormal": lambda: rng.lognormal(0.0, 1.0, n),
         "uniform": lambda: rng.random(n),
         "exponential": lambda: rng.exponential(1.0, n)}[dist]()
    s = QuantileSketch(buffer_size=4096)
    for chunk in np.array_split(x, 50):
        s.observe_many(chunk)
    assert s.spilled and s._buf is None         # O(1): no samples retained
    assert all(est._init is None for est in s._p2.values())
    for q in (50, 95, 99):
        exact = np.percentile(x, q)
        rel = abs(s.quantile(q) - exact) / abs(exact)
        assert rel < 0.01, f"p{q}: {s.quantile(q)} vs {exact} ({rel:.2%})"
    assert s.summary()["approx"] is True
    assert s.count == n
    np.testing.assert_allclose(s.summary()["mean"], x.mean(), rtol=1e-6)


def test_p2_small_sample_exact():
    p2 = P2Quantile(0.5)
    for v in [1.0, 9.0, 3.0]:
        p2.observe(v)
    assert p2.value == 3.0                     # exact below 5 observations


def test_sketch_untracked_percentile_after_spill_raises():
    s = QuantileSketch(percentiles=(50,), buffer_size=8)
    s.observe_many(range(20))
    assert s.spilled
    assert s.quantile(50) is not None
    with pytest.raises(KeyError):
        s.quantile(95)


def test_ewma_converges():
    e = Ewma(alpha=0.5)
    assert e.value is None
    e.update(10.0)
    assert e.value == 10.0                     # first update is exact
    for _ in range(40):
        e.update(2.0)
    assert abs(e.value - 2.0) < 1e-6


def test_delay_tail_estimator_per_worker():
    est = DelayTailEstimator(m=3, buffer_size=16)
    # worker 2 is the straggler: 10x the delay of workers 0/1
    for _ in range(50):
        est.observe(0, 1.0)
        est.observe(1, 1.0)
        est.observe(2, 10.0)
    snap = est.snapshot()
    assert snap["workers"] == 3
    assert snap["count"] == [50, 50, 50]
    assert snap["p99"][2] == pytest.approx(10.0)
    assert snap["p99_max"] == pytest.approx(10.0)
    assert snap["ewma"][2] == pytest.approx(10.0)
    assert snap["ewma"][0] == pytest.approx(1.0)


def test_delay_tail_engine_wiring():
    """ClusterEngine(tail_estimator=...) feeds every sampled schedule and
    async trace into the estimator in-stream."""
    from repro.runtime import ClusterEngine, FastestK, make_delay_model
    est = DelayTailEstimator(m=6)
    eng = ClusterEngine(make_delay_model("bimodal"), 6, tail_estimator=est)
    eng.sample_schedule(10, FastestK(4))
    assert all(c == 10 for c in est.snapshot()["count"])
    eng.sample_async(20, 3)
    assert sum(est.snapshot()["count"]) == 60 + 20


def test_metrics_csv_carries_delay_tail(tmp_path):
    """Acceptance criterion: delay_tail_p99 metrics appear in
    write_metrics_csv output for traced runs."""
    from repro.experiments.run import main as exp_main
    out = tmp_path / "out"
    met = tmp_path / "met.csv"
    exp_main(["--strategies", "coded-gd", "--delays", "bimodal",
              "--steps", "8", "--n", "32", "--p", "8", "--m", "4",
              "--metrics-out", str(met), "--out", str(out),
              "--formats", "json"])
    with open(met) as f:
        rows = list(csv.DictReader(f))
    assert rows and float(rows[0]["delay_tail_p99_max"]) > 0
    assert int(rows[0]["delay_tail_p99_workers"]) == 4


# ---------------------------------------------------------------------------
# run store
# ---------------------------------------------------------------------------


def _tiny_result(seed=0):
    from repro.experiments import (DelayAxis, ExperimentSpec, PlacementAxis,
                                   ProblemAxis, StrategyAxis, TrialsAxis,
                                   execute, plan)
    spec = ExperimentSpec(
        problems=(ProblemAxis.synthetic(32, 8),),
        strategies=(StrategyAxis("uncoded"),),
        delays=DelayAxis.of("bimodal", m=4),
        trials=TrialsAxis(trials=1, seed=seed),
        placement=PlacementAxis(mode="single"), steps=6)
    return spec, execute(plan(spec), record_to=False)


def test_manifest_roundtrip(tmp_path):
    spec, result = _tiny_result()
    store = RunStore(str(tmp_path / "store"))
    run_id = record_experiment(result, store=store,
                               artifacts={"records_json": "a.json"})
    m = store.load(run_id)
    assert m["run_id"] == run_id
    assert m["kind"] == "experiment"
    assert m["spec_hash"] == spec_hash(spec)
    assert m["git_sha"] and m["timestamp"] and m["backend"]
    assert m["artifacts"] == {"records_json": "a.json"}
    [cell] = m["cells"]
    assert cell["strategy"] == "uncoded" and cell["delay"] == "bimodal"
    assert cell["wallclock_s"] > 0
    # index + query API agree with the manifest
    assert [r["run_id"] for r in store.runs()] == [run_id]
    assert store.latest()["run_id"] == run_id
    assert store.latest(spec_hash=spec_hash(spec))["run_id"] == run_id
    assert store.latest(spec_hash="nope") is None
    assert store.resolve(run_id[:10])["run_id"] == run_id  # unique prefix


def test_spec_hash_stability():
    spec_a, _ = _tiny_result(seed=0)
    spec_b, _ = _tiny_result(seed=0)
    assert spec_hash(spec_a) == spec_hash(spec_b)
    spec_c, _ = _tiny_result(seed=1)
    assert spec_hash(spec_a) != spec_hash(spec_c)


def test_execute_records_by_default(tmp_path, monkeypatch):
    """execute() writes a manifest into the env-configured store; =0
    disables; record_to=False skips."""
    from repro.experiments import execute, plan
    root = tmp_path / "envstore"
    monkeypatch.setenv("REPRO_RUNSTORE", str(root))
    spec, _ = _tiny_result()
    result = execute(plan(spec))
    assert result.run_id is not None
    assert RunStore(str(root)).load(result.run_id)["spec_hash"] == \
        spec_hash(spec)
    monkeypatch.setenv("REPRO_RUNSTORE", "0")
    assert execute(plan(spec)).run_id is None


def test_provenance_fields():
    p = provenance()
    assert set(p) >= {"git_sha", "timestamp", "backend", "jax_version",
                      "device_count"}
    assert p["timestamp"].endswith("+00:00") or "T" in p["timestamp"]


# ---------------------------------------------------------------------------
# diff CLI / regression gate
# ---------------------------------------------------------------------------


def _two_runs(tmp_path, slowdown=1.0):
    store = RunStore(str(tmp_path / "store"))
    _, result = _tiny_result()
    a = record_experiment(result, store=store)
    manifest = store.load(a)
    b = copy.deepcopy(manifest)
    b.pop("run_id")
    for cell in b["cells"]:
        cell["wallclock_s"] *= slowdown
    b_id = store.record(b)
    return store, a, b_id


def test_diff_identical_runs_exit_zero(tmp_path, capsys):
    store, a, b = _two_runs(tmp_path, slowdown=1.0)
    rc = diff_main([a, b, "--store", store.root])
    assert rc == 0
    out = capsys.readouterr().out
    assert "RESULT: OK" in out and "spec hash match" in out


def test_diff_flags_2x_slowdown(tmp_path, capsys):
    store, a, b = _two_runs(tmp_path, slowdown=2.0)
    rc = diff_main([a, b, "--store", store.root])
    assert rc == 1
    out = capsys.readouterr().out
    assert "regression" in out and "2.00x" in out
    # the reverse direction is an improvement, not a regression
    assert diff_main([b, a, "--store", store.root]) == 0
    # a looser gate lets 2x through
    assert diff_main([a, b, "--store", store.root,
                      "--threshold", "3.0"]) == 0


def test_diff_latest_refs_and_reports(tmp_path, monkeypatch, capsys):
    store, a, b = _two_runs(tmp_path, slowdown=2.0)
    monkeypatch.setenv("REPRO_RUNSTORE", store.root)
    js = tmp_path / "d.json"
    html = tmp_path / "d.html"
    rc = diff_main(["latest~1", "latest", "--json", str(js),
                    "--html", str(html)])
    assert rc == 1
    rep = json.loads(js.read_text())
    assert rep["exit_code"] == 1 and rep["regressions"] == 1
    page = html.read_text()
    assert page.startswith("<!doctype html>") and "REGRESSION" in page


def test_diff_unknown_ref_exits_2(tmp_path, capsys):
    rc = diff_main(["nope-a", "nope-b", "--store", str(tmp_path / "s")])
    assert rc == 2


def test_diff_bench_baseline(tmp_path, capsys):
    base = {"bench": "x", "meta": {"git_sha": "a"},
            "results": [{"case": "r16", "us_per_call": 100.0,
                         "seconds_per_matrix": 1.0}]}
    cand = copy.deepcopy(base)
    cand["meta"]["git_sha"] = "b"              # meta never gates
    base_p = tmp_path / "base.json"
    cand_p = tmp_path / "cand.json"
    base_p.write_text(json.dumps(base))
    cand_p.write_text(json.dumps(cand))
    assert diff_main([str(cand_p), "--against-baseline",
                      str(base_p)]) == 0
    cand["results"][0]["us_per_call"] = 250.0
    cand_p.write_text(json.dumps(cand))
    rc = diff_main([str(cand_p), "--against-baseline", str(base_p)])
    assert rc == 1
    assert "us_per_call" in capsys.readouterr().out


def test_bench_meta_stamp():
    from benchmarks.common import bench_meta
    meta = bench_meta()
    assert set(meta) >= {"git_sha", "timestamp", "backend", "jax_version"}


# ---------------------------------------------------------------------------
# html report
# ---------------------------------------------------------------------------


def test_report_html_export(tmp_path):
    from repro.obs import TraceRecorder
    from repro.obs.report import main as report_main
    from repro.runtime import ClusterEngine, FastestK, make_delay_model
    rec = TraceRecorder()
    with rec.activate(), rec.cell("codedxbimodal"):
        with rec.span("solve"):
            pass
        eng = ClusterEngine(make_delay_model("bimodal"), 4)
        eng.sample_schedule(6, FastestK(3))
        eng.sample_async(8, 2)
    tr = tmp_path / "t.jsonl"
    rec.to_jsonl(str(tr))
    html = tmp_path / "r.html"
    report_main([str(tr), "--html", str(html)])
    page = html.read_text()
    assert page.startswith("<!doctype html>")
    assert "phase breakdown" in page
    assert "straggler timeline" in page and "codedxbimodal" in page
    assert "<pre class='lanes'>" in page
    assert "staleness" in page
