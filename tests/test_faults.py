"""Fault-injection subsystem (DESIGN.md §14): spec parsing, engine fault
paths, policy floors, empty-active-set safety, degradation modes, fault
metrics, and the resilient executor (retry / streamed cells / resume)."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (bimodal_delays, constant_delays, hadamard_encoder,
                        make_encoded_problem, pad_rows)
from repro.core.data_parallel import masked_gradient
from repro.core.gradient_coding import (coded_weights, decode_exact_possible,
                                        make_frc)
from repro.core.straggler import fastest_k
from repro.experiments import (DelayAxis, ExperimentSpec, ProblemAxis,
                               StrategyAxis, TrialsAxis, execute, plan)
from repro.obs import fault_metrics, schedule_metrics
from repro.obs.runstore import (RunStore, completed_cells, prune, record_cell)
from repro.runtime import (AdaptiveK, AdversarialRotation, ClusterEngine,
                           Deadline, FastestK, ProblemSpec, get_strategy)
from repro.runtime.faults import (FAULT_BLACKOUT, FAULT_CORRUPT,
                                  FAULT_CRASHED, FAULT_OK, BlackoutFault,
                                  CrashFault, DegradePolicy, FaultModel,
                                  make_degrade, make_fault_model)

M, K = 8, 5


def _engine(faults=None, *, m=M, seed=0, delay=None):
    return ClusterEngine(delay or bimodal_delays(), m, seed=seed,
                         faults=faults)


# ---------------------------------------------------------------------------
# spec parsing
# ---------------------------------------------------------------------------

def test_fault_spec_parsing_roundtrip():
    fm = make_fault_model("crash:p=0.2,at=0.5;corrupt:p=0.05")
    assert isinstance(fm, FaultModel) and len(fm.injectors) == 2
    assert fm.spec == "crash:p=0.2,at=0.5;corrupt:p=0.05"
    assert make_fault_model(fm) is fm               # passthrough
    assert make_fault_model(None) is None
    assert make_fault_model("") is None
    assert make_fault_model("none") is None


def test_fault_spec_zone_workers_and_errors():
    fm = make_fault_model("zone:workers=0-2+5,at=0.8,dur=1.5")
    (zone,) = fm.injectors
    assert zone.workers == (0, 1, 2, 5) and zone.dur == 1.5
    with pytest.raises(KeyError, match="unknown fault injector"):
        make_fault_model("meteor:p=1")
    with pytest.raises(ValueError, match="dur must be < period"):
        make_fault_model("blackout:p=1,at=0,dur=2,period=1")


def test_degrade_spec_parsing():
    assert make_degrade(None) is None
    assert make_degrade("renormalize") is None      # default math, no object
    pol = make_degrade("hold:shrink=0.25,k_min=4")
    assert pol.mode == "hold" and pol.shrink == 0.25 and pol.k_min == 4
    back = make_degrade("backoff:base=0.1,retries=3")
    assert back.mode == "backoff" and back.base == 0.1 and back.retries == 3
    assert make_degrade(pol) is pol
    with pytest.raises(KeyError, match="unknown degrade mode"):
        make_degrade("panic")


# ---------------------------------------------------------------------------
# fault realization
# ---------------------------------------------------------------------------

def test_realization_deterministic_and_delay_stream_untouched():
    fm = make_fault_model("crash:p=0.5,at=0.3;corrupt:p=0.1")
    a = fm.realize(M, trial_seed=7)
    b = fm.realize(M, trial_seed=7)
    np.testing.assert_array_equal(a.crash_time, b.crash_time)
    # a certainly-zero fault model must reproduce the no-fault schedule
    # bit for bit: fault draws live on a tagged child stream
    clean = _engine().sample_schedule(20, FastestK(K))
    nofault = _engine("crash:p=0,at=0.5").sample_schedule(20, FastestK(K))
    np.testing.assert_array_equal(nofault.masks, clean.masks)
    np.testing.assert_array_equal(nofault.times, clean.times)
    assert nofault.failed is not None and not nofault.failed.any()


def test_blackout_windows_and_recovery():
    fm = FaultModel((BlackoutFault(p=1.0, at=1.0, dur=0.5),))
    rz = fm.realize(4, trial_seed=0)
    assert not rz.blackout_at(0.9).any()
    assert rz.blackout_at(1.2).all()
    assert not rz.blackout_at(1.6).any()
    np.testing.assert_allclose(rz.recovery_time(1.2), 1.5)
    np.testing.assert_allclose(rz.recovery_time(0.5), 0.5)  # not dark now


def test_recurring_blackout_period():
    fm = make_fault_model("blackout:p=1,at=1,dur=0.5,period=2")
    rz = fm.realize(2, trial_seed=0)
    assert rz.blackout_at(1.2).all() and rz.blackout_at(3.2).all()
    assert not rz.blackout_at(2.2).any()


# ---------------------------------------------------------------------------
# engine fault paths (sync)
# ---------------------------------------------------------------------------

def test_faulted_schedule_invariants():
    eng = _engine("crash:p=0.3,at=0.4;blackout:p=0.3,at=0.2,dur=0.3;"
                  "corrupt:p=0.1")
    sched = eng.sample_schedule(30, FastestK(K))
    assert sched.failed.shape == sched.masks.shape
    assert set(np.unique(sched.failed)) <= {FAULT_OK, FAULT_CRASHED,
                                            FAULT_BLACKOUT, FAULT_CORRUPT}
    # an active (mask==1) worker is never a failed one
    assert not (sched.masks.astype(bool) & (sched.failed != FAULT_OK)).any()
    # times strictly increase and stay finite even with dead workers
    assert np.isfinite(sched.times).all()
    assert (np.diff(sched.times) > 0).all()
    # crashes are permanent: once CRASHED, CRASHED at every later step
    for w in range(M):
        hits = np.nonzero(sched.failed[:, w] == FAULT_CRASHED)[0]
        if hits.size:
            assert (sched.failed[hits[0]:, w] == FAULT_CRASHED).all()
    assert sched.fault_events                # realized faults are reported


def test_zone_kill_all_commits_empty_rounds():
    eng = _engine(f"zone:workers=0-{M - 1},at=0.2", delay=constant_delays(0.1))
    sched = eng.sample_schedule(10, FastestK(K))
    dead = sched.times > 0.2
    assert dead.any()
    # all-failed rounds: mask row is all zero, master idles one compute
    # window (heartbeat assumption) and the clock still advances
    t0 = int(np.nonzero(dead)[0][0]) + 1
    assert not sched.masks[t0:].any()
    np.testing.assert_allclose(
        np.diff(sched.times[t0:]),
        eng.compute_time + eng.master_overhead)


def test_deadline_policy_never_waits_on_dead_workers():
    eng = _engine("crash:p=0.6,at=0.1", delay=constant_delays(0.05))
    sched = eng.sample_schedule(20, Deadline(deadline=0.5, k_min=2))
    assert np.isfinite(sched.times).all()
    # survivors only in the active sets after the crash point
    crashed = sched.failed[-1] == FAULT_CRASHED
    assert not sched.masks[-1, crashed].any()


def test_corruption_charges_barrier_but_masks_out():
    # deterministic delays: the corrupt-only barrier equals the clean one
    eng = _engine("corrupt:p=0.3", delay=constant_delays(0.1))
    clean = _engine(delay=constant_delays(0.1)).sample_schedule(
        25, FastestK(K))
    sched = eng.sample_schedule(25, FastestK(K))
    np.testing.assert_allclose(sched.times, clean.times)
    corrupt = sched.failed == FAULT_CORRUPT
    assert corrupt.any()
    assert not sched.masks[corrupt].any()
    # some rounds therefore combine fewer than k gradients
    assert sched.masks.sum(axis=1).min() < K


def test_backoff_recovers_blacked_out_workers():
    # all workers dark over [0.1, 0.4): without backoff the rounds inside
    # the window are empty; with it the master extends its deadline and
    # the blacked-out workers rejoin
    spec = f"zone:workers=0-{M - 1},at=0.1,dur=0.3"
    plain = _engine(spec, delay=constant_delays(0.02)).sample_schedule(
        8, FastestK(K))
    back = _engine(spec, delay=constant_delays(0.02)).sample_schedule(
        8, FastestK(K),
        degrade=DegradePolicy(mode="backoff", base=0.2, retries=4))
    assert plain.masks.sum() < back.masks.sum()
    assert (back.masks.sum(axis=1) >= 1).all()


def test_batch_failed_stacks_and_matches_trials():
    eng = _engine("crash:p=0.3,at=0.3;corrupt:p=0.05")
    batch = eng.sample_schedules(12, FastestK(K), trials=3)
    assert batch.failed.shape == (3, 12, M)
    for r in range(3):
        solo = eng.trial(r).sample_schedule(12, FastestK(K))
        np.testing.assert_array_equal(batch.failed[r], solo.failed)
        np.testing.assert_array_equal(batch.masks[r], solo.masks)
        np.testing.assert_allclose(batch.times[r], solo.times)


# ---------------------------------------------------------------------------
# engine fault paths (async)
# ---------------------------------------------------------------------------

def test_async_crash_and_corruption_accounting():
    eng = _engine("crash:p=0.4,at=1.0;corrupt:p=0.1")
    tr = eng.sample_async(60, staleness_bound=8)
    assert tr.updates == 60
    assert tr.corrupted > 0
    assert tr.fault_events
    # crashed workers stop contributing after their crash time
    fr = eng.faults.realize(M, eng.seed)
    for w in np.nonzero(np.isfinite(fr.crash_time))[0]:
        late = tr.times[tr.workers == w]
        assert (late <= fr.crash_time[w] + 10.0).all()


def test_async_all_crashed_raises():
    eng = _engine(f"zone:workers=0-{M - 1},at=0.5",
                  delay=constant_delays(0.05))
    with pytest.raises(ValueError, match="async cluster died"):
        eng.sample_async(500, staleness_bound=4)


# ---------------------------------------------------------------------------
# policy floors + empty-active-set safety (satellite: hardening)
# ---------------------------------------------------------------------------

def test_policy_k_floors():
    with pytest.raises(ValueError, match="k >= 1"):
        FastestK(0)
    with pytest.raises(ValueError, match="k >= 1"):
        AdversarialRotation(-1)
    assert AdaptiveK(beta=2.0, k_min=0).k_min == 1
    assert Deadline(deadline=0.5, k_min=-3).k_min == 1


def test_fastest_k_clamps_bounds():
    d = np.asarray([3.0, 1.0, 2.0])
    assert fastest_k(d, 0).size == 0
    assert fastest_k(d, -2).size == 0
    np.testing.assert_array_equal(np.sort(fastest_k(d, 5)), [0, 1, 2])
    np.testing.assert_array_equal(np.sort(fastest_k(d, 2)), [1, 2])


def test_empty_active_set_gradients_are_finite_zero():
    spec = ProblemSpec.synthetic(64, 16, seed=0)
    prob = make_encoded_problem(spec.X, spec.y,
                                pad_rows(hadamard_encoder(64, 2.0), M), M,
                                lam=spec.lam)
    g = masked_gradient(prob, jnp.ones(16), jnp.zeros(M))
    np.testing.assert_allclose(np.asarray(g), 0.0)
    assert np.isfinite(np.asarray(g)).all()


def test_empty_active_set_fused_kernel_is_finite_zero():
    from repro.kernels.fused_step import fused_masked_gradient
    rng = np.random.default_rng(0)
    SX = jnp.asarray(rng.normal(size=(M, 8, 16)), jnp.float32)
    Sy = jnp.asarray(rng.normal(size=(M, 8)), jnp.float32)
    g = fused_masked_gradient(SX, Sy, jnp.ones(16, jnp.float32),
                              jnp.zeros(M, jnp.float32), n=64, beta=2.0)
    np.testing.assert_allclose(np.asarray(g), 0.0)
    assert np.isfinite(np.asarray(g)).all()


def test_empty_active_set_coded_weights_are_finite_zero():
    code = make_frc(M, beta=2)
    w = coded_weights(code, jnp.zeros(M))
    np.testing.assert_allclose(np.asarray(w), 0.0)
    assert not decode_exact_possible(code, np.zeros(M))


# ---------------------------------------------------------------------------
# degradation through the strategies
# ---------------------------------------------------------------------------

CHAOS = "crash:p=0.3,at=0.3;blackout:p=0.3,at=0.1,dur=0.4;corrupt:p=0.1"


@pytest.mark.parametrize("degrade", [None, "hold:shrink=0.25",
                                     "backoff:base=0.1,retries=3"])
def test_coded_gd_survives_chaos_under_each_degrade(degrade):
    spec = ProblemSpec.synthetic(128, 32, seed=0)
    res = get_strategy("coded-gd").run(
        spec, _engine(CHAOS), steps=25,
        **({} if degrade is None else {"degrade": degrade}))
    obj = np.asarray(res.objective)
    assert np.isfinite(obj).all()
    assert res.meta["faults"] == CHAOS
    # the default renormalize math carries no policy object -> no meta key
    assert res.meta.get("degrade") == (
        None if degrade is None else degrade.split(":")[0])
    assert 0.0 <= res.meta["subk_fraction"] <= 1.0


def test_batched_matches_sequential_under_faults():
    spec = ProblemSpec.synthetic(96, 24, seed=0)
    strat = get_strategy("coded-gd")
    eng = _engine(CHAOS)
    batched = strat.run_batched(spec, eng, steps=10, trials=2,
                                degrade="hold:shrink=0.5")
    for r in range(2):
        solo = strat.run(spec, eng.trial(r), steps=10,
                         degrade="hold:shrink=0.5")
        np.testing.assert_allclose(batched.realization(r).objective,
                                   solo.objective, rtol=1e-5)


def test_lbfgs_rejects_hold_degrade():
    spec = ProblemSpec.synthetic(96, 24, seed=0)
    with pytest.raises(ValueError, match="renormalize/backoff"):
        get_strategy("coded-lbfgs").run(spec, _engine(CHAOS), steps=8,
                                        degrade="hold")


# ---------------------------------------------------------------------------
# fault metrics
# ---------------------------------------------------------------------------

def test_fault_metrics_counts():
    scheds = [_engine(CHAOS, seed=s).sample_schedule(20, FastestK(K))
              for s in range(2)]
    fm = fault_metrics(scheds, k=K)
    assert fm["crashes"] >= 1 and fm["crashed_frac"] > 0
    assert fm["corrupt_count"] >= 1
    assert 0.0 <= fm["subk_fraction"] <= 1.0
    assert "faults" in schedule_metrics(scheds, k=K)
    # fault-free schedules contribute no fault block at all
    clean = [_engine().sample_schedule(20, FastestK(K))]
    assert fault_metrics(clean) == {}
    assert "faults" not in schedule_metrics(clean, k=K)


# ---------------------------------------------------------------------------
# resilient executor: streamed cells, resume, retry
# ---------------------------------------------------------------------------

def _matrix_spec():
    return ExperimentSpec(
        problems=(ProblemAxis.synthetic(96, 24),),
        strategies=(StrategyAxis("coded-gd", degrade="hold:shrink=0.5"),
                    StrategyAxis("uncoded")),
        delays=DelayAxis.of("bimodal", m=M,
                            faults="crash:p=0.3,at=0.4;corrupt:p=0.05"),
        trials=TrialsAxis(trials=2, eval_every=4), steps=12)


def test_execute_streams_cells_and_resumes_identically(tmp_path):
    store = RunStore(str(tmp_path / "runs"))
    full = execute(plan(_matrix_spec()), record_to=store)
    assert full.run_id is not None
    cells = store.cells_dir(full.run_id)
    assert sorted(os.listdir(cells)) == ["0000.json", "0001.json"]
    manifest = json.loads(
        open(os.path.join(store.root, full.run_id, "manifest.json")).read())
    assert manifest["status"] == "complete"

    # kill the matrix after cell 0: drop cell 1 and mark the run running
    os.remove(os.path.join(cells, "0001.json"))
    manifest["status"] = "running"
    with open(os.path.join(store.root, full.run_id, "manifest.json"),
              "w") as f:
        json.dump(manifest, f)

    resumed = execute(plan(_matrix_spec()), record_to=store,
                      resume=full.run_id)
    assert resumed.records == full.records     # bit-identical replay
    assert resumed.run_id == full.run_id


def test_resume_rejects_spec_mismatch(tmp_path):
    store = RunStore(str(tmp_path / "runs"))
    full = execute(plan(_matrix_spec()), record_to=store)
    other = ExperimentSpec(
        problems=(ProblemAxis.synthetic(64, 16),),
        strategies=(StrategyAxis("uncoded"),),
        delays=DelayAxis.of("bimodal", m=M), steps=8)
    with pytest.raises(ValueError, match="spec hash mismatch"):
        execute(plan(other), record_to=store, resume=full.run_id)
    with pytest.raises(KeyError, match="is empty"):
        execute(plan(other), record_to=RunStore(str(tmp_path / "empty")),
                resume="latest")


def test_retry_reruns_flaky_cell(tmp_path, monkeypatch, capsys):
    import importlib
    # the package re-exports the execute() function under the same name,
    # so fetch the module object from sys.modules explicitly
    ex = importlib.import_module("repro.experiments.execute")
    real = ex._execute_cell
    failures = {"left": 2}

    def flaky(cell, caches):
        if failures["left"] > 0:
            failures["left"] -= 1
            raise RuntimeError("transient device loss")
        return real(cell, caches)

    monkeypatch.setattr(ex, "_execute_cell", flaky)
    monkeypatch.setattr(ex.time, "sleep", lambda s: None)
    spec = ExperimentSpec(
        problems=(ProblemAxis.synthetic(64, 16),),
        strategies=(StrategyAxis("uncoded"),),
        delays=DelayAxis.of("bimodal", m=M), steps=8)
    result = execute(plan(spec), retries=3,
                     record_to=RunStore(str(tmp_path / "runs")))
    assert len(result.records) == 1 and failures["left"] == 0
    assert "retry" in capsys.readouterr().out

    # with retries exhausted the last error propagates (resume recovers)
    failures["left"] = 99
    with pytest.raises(RuntimeError, match="transient device loss"):
        execute(plan(spec), retries=1)


def test_retry_delay_capped_exponential_with_jitter():
    from repro.experiments.execute import _retry_delay
    d1, d2 = _retry_delay(0.5, 1, 0), _retry_delay(0.5, 2, 0)
    assert d1 == _retry_delay(0.5, 1, 0)        # deterministic
    assert 0.25 <= d1 <= 0.75 and 0.5 <= d2 <= 1.5
    assert _retry_delay(0.5, 30, 0) <= 30.0     # cap


# ---------------------------------------------------------------------------
# run-store cell records + prune
# ---------------------------------------------------------------------------

def test_record_cell_roundtrip_skips_corrupt(tmp_path):
    store = RunStore(str(tmp_path / "runs"))
    run_id = "run-test"
    record_cell(store, run_id, 0, {"strategy": "a", "final_metric": 1.0})
    record_cell(store, run_id, 3, {"strategy": "b", "final_metric": 2.0})
    with open(os.path.join(store.cells_dir(run_id), "0001.json"), "w") as f:
        f.write("{ torn write")
    done = completed_cells(store, run_id)
    assert sorted(done) == [0, 3]               # corrupt file skipped
    assert done[3]["strategy"] == "b"


def test_prune_keep_and_repair(tmp_path, monkeypatch):
    store = RunStore(str(tmp_path / "runs"))
    ids = []
    for s in range(3):
        spec = ExperimentSpec(
            problems=(ProblemAxis.synthetic(64, 16),),
            strategies=(StrategyAxis("uncoded"),),
            delays=DelayAxis.of("bimodal", m=M), steps=4,
            trials=TrialsAxis(seed=s))
        ids.append(execute(plan(spec), record_to=store).run_id)
    assert all(ids)
    out = prune(store, keep=1, dry_run=True)
    assert sorted(out["kept"] + out["removed"]) == sorted(ids)
    assert os.path.isdir(os.path.join(store.root, ids[0]))  # dry run
    out = prune(store, keep=1)
    # same-second stamps tie-break by run id; exactly one survivor either way
    (survivor,) = out["kept"]
    assert survivor in ids and len(out["removed"]) == 2
    for rid in out["removed"]:
        assert not os.path.isdir(os.path.join(store.root, rid))
    # index now lists exactly the survivors
    lines = [json.loads(l) for l in
             open(os.path.join(store.root, "index.jsonl"))]
    assert [l["run_id"] for l in lines] == [survivor]


def test_prune_cli(tmp_path, capsys):
    from repro.obs.runstore import main
    store = RunStore(str(tmp_path / "runs"))
    spec = ExperimentSpec(
        problems=(ProblemAxis.synthetic(64, 16),),
        strategies=(StrategyAxis("uncoded"),),
        delays=DelayAxis.of("bimodal", m=M), steps=4)
    execute(plan(spec), record_to=store)
    assert main(["--store", store.root, "list"]) == 0
    assert main(["--store", store.root, "prune", "--keep", "0"]) == 0
    out = capsys.readouterr().out
    assert "removed" in out
    assert completed_cells(store, "anything") == {}
