"""Unit tests for model building blocks: rope, attention (vs naive ref),
mamba/xlstm sequential equivalence, softcap."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.attention import attention, ring_slot_positions
from repro.models.common import softcap, rmsnorm, tree_init
from repro.models.rope import rope_angles, mrope_angles, apply_rope
import repro.models.mamba as MB
import repro.models.xlstm as XL


def _naive_attention(q, k, v, causal, window, cap):
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    kk = jnp.repeat(k, G, axis=2)
    vv = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(hd)
    s = softcap(s, cap)
    qpos = np.arange(Sq)[:, None]
    kpos = np.arange(Sq)[None, :]
    mask = np.ones((Sq, Sq), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(jnp.asarray(mask)[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


@pytest.mark.parametrize("causal,window,cap", [
    (True, None, None), (True, 16, None), (True, None, 50.0),
    (False, None, None), (True, 8, 30.0),
])
def test_attention_vs_naive(causal, window, cap):
    B, S, H, K, hd = 2, 64, 4, 2, 16
    q = jax.random.normal(jax.random.key(0), (B, S, H, hd))
    k = jax.random.normal(jax.random.key(1), (B, S, K, hd))
    v = jax.random.normal(jax.random.key(2), (B, S, K, hd))
    pos = jnp.arange(S, dtype=jnp.int32)
    out = attention(q, k, v, causal=causal, window=window, cap=cap,
                    qpos=pos, kpos=pos, kvalid=jnp.ones((S,), bool),
                    chunk=16)   # forces the online-softmax path
    ref = _naive_attention(q, k, v, causal, window, cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_attention_chunked_equals_direct():
    B, S, H, hd = 1, 128, 2, 8
    q = jax.random.normal(jax.random.key(3), (B, S, H, hd))
    k = jax.random.normal(jax.random.key(4), (B, S, H, hd))
    v = jax.random.normal(jax.random.key(5), (B, S, H, hd))
    pos = jnp.arange(S, dtype=jnp.int32)
    kw = dict(causal=True, window=None, cap=None, qpos=pos, kpos=pos,
              kvalid=jnp.ones((S,), bool))
    direct = attention(q, k, v, chunk=S, **kw)
    chunked = attention(q, k, v, chunk=32, **kw)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(direct),
                               rtol=1e-5, atol=1e-5)


def test_banded_attention_matches_naive():
    """O1 banded path (skip out-of-window KV blocks) must be exact."""
    B, S, H, K, hd, W, chunk = 1, 256, 4, 2, 16, 32, 16
    q = jax.random.normal(jax.random.key(10), (B, S, H, hd))
    k = jax.random.normal(jax.random.key(11), (B, S, K, hd))
    v = jax.random.normal(jax.random.key(12), (B, S, K, hd))
    pos = jnp.arange(S, dtype=jnp.int32)
    out = attention(q, k, v, causal=True, window=W, cap=None, qpos=pos,
                    kpos=pos, kvalid=jnp.ones((S,), bool), chunk=chunk,
                    banded=True)
    ref = _naive_attention(q, k, v, True, W, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_banded_attention_with_softcap():
    B, S, H, hd, W, chunk = 2, 128, 2, 8, 16, 8
    q = jax.random.normal(jax.random.key(13), (B, S, H, hd))
    k = jax.random.normal(jax.random.key(14), (B, S, H, hd))
    v = jax.random.normal(jax.random.key(15), (B, S, H, hd))
    pos = jnp.arange(S, dtype=jnp.int32)
    out = attention(q, k, v, causal=True, window=W, cap=30.0, qpos=pos,
                    kpos=pos, kvalid=jnp.ones((S,), bool), chunk=chunk,
                    banded=True)
    ref = _naive_attention(q, k, v, True, W, 30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_ring_slot_positions():
    # cache of 4 slots, 6 tokens written: slots hold positions 4,5,2,3
    pos, valid = ring_slot_positions(4, 6)
    np.testing.assert_array_equal(np.asarray(pos), [4, 5, 2, 3])
    assert np.asarray(valid).all()
    pos, valid = ring_slot_positions(4, 2)   # only 2 written
    np.testing.assert_array_equal(np.asarray(pos), [0, 1, -2, -1])
    np.testing.assert_array_equal(np.asarray(valid), [True, True, False,
                                                      False])


def test_rope_preserves_norm_and_relative_shift():
    cos, sin = rope_angles(jnp.arange(8), 16)
    x = jax.random.normal(jax.random.key(0), (1, 8, 2, 16))
    rx = apply_rope(x, cos[None], sin[None])
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(rx, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(jax.random.key(1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.key(2), (1, 1, 1, 16))
    def dot_at(p, d):
        cq, sq = rope_angles(jnp.array([p]), 16)
        ck, sk = rope_angles(jnp.array([p + d]), 16)
        return float(jnp.vdot(apply_rope(q, cq[None], sq[None]),
                              apply_rope(k, ck[None], sk[None])))
    assert abs(dot_at(0, 3) - dot_at(5, 3)) < 1e-4


def test_mrope_reduces_to_rope_for_text():
    """Identical (t,h,w) positions == standard 1-D RoPE."""
    S, hd = 8, 32
    pos3 = jnp.broadcast_to(jnp.arange(S)[None, None], (3, 1, S))
    cos_m, sin_m = mrope_angles(pos3, hd, (4, 6, 6))
    cos_r, sin_r = rope_angles(jnp.arange(S), hd)
    # mrope concatenates per-section frequencies in order -> same table
    np.testing.assert_allclose(np.asarray(cos_m[0]), np.asarray(cos_r),
                               rtol=1e-6)


def test_mamba_seq_equals_decode():
    cfg = ARCHS["jamba-1.5-large-398b"].smoke_variant()
    p = tree_init(MB.mamba_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model)) * 0.5
    yfull, cache_end = MB.mamba_apply(p, x, cfg, return_cache=True)
    cache = MB.init_mamba_cache(cfg, 2, x.dtype)
    ys = []
    for t in range(32):
        y1, cache = MB.mamba_decode(p, x[:, t:t + 1], cache, cfg)
        ys.append(y1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(yfull), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(cache.ssm),
                               np.asarray(cache_end.ssm), rtol=1e-3,
                               atol=1e-5)


@pytest.mark.parametrize("kind", ["mlstm", "slstm"])
def test_xlstm_seq_equals_decode(kind):
    cfg = ARCHS["xlstm-350m"].smoke_variant()
    defs = XL.mlstm_defs(cfg) if kind == "mlstm" else XL.slstm_defs(cfg)
    apply_fn = XL.mlstm_apply if kind == "mlstm" else XL.slstm_apply
    decode_fn = XL.mlstm_decode if kind == "mlstm" else XL.slstm_decode
    init_fn = (XL.init_mlstm_cache if kind == "mlstm"
               else XL.init_slstm_cache)
    p = tree_init(defs, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model)) * 0.5
    yfull = apply_fn(p, x, cfg)
    cache = init_fn(cfg, 2, x.dtype)
    ys = []
    for t in range(32):
        y1, cache = decode_fn(p, x[:, t:t + 1], cache, cfg)
        ys.append(y1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(yfull), rtol=1e-3, atol=1e-4)


def test_softcap_bounds():
    x = jnp.linspace(-1000, 1000, 101)
    y = softcap(x, 50.0)
    assert float(jnp.abs(y).max()) <= 50.0
    np.testing.assert_allclose(np.asarray(softcap(x, None)), np.asarray(x))


def test_rmsnorm_scale():
    x = jax.random.normal(jax.random.key(0), (4, 32)) * 10
    y = rmsnorm(x, jnp.zeros(32))
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-2)
