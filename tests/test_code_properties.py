"""Property tests for the gradient-code families (hypothesis; alongside
tests/test_fault_properties.py).

Exactness (Tandon, arXiv 1612.03301): FRC decodes the full-batch gradient
for ANY mask with a survivor per cluster; cyclic repetition for ANY
<= beta-1 total erasures.  The stochastic code (Bitar et al., arXiv
1905.05383) trades exactness for an UNBIASED estimate with variance
bounded by the fixed-degree sampling formula."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.gradient_coding import (make_cyclic, make_frc,  # noqa: E402
                                        make_stochastic)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 4), st.integers(2, 3), st.integers(0, 99),
       st.data())
def test_frc_exact_for_any_per_cluster_survivor_mask(clusters, beta, seed,
                                                     data):
    m = clusters * beta
    code = make_frc(m, beta)
    rng = np.random.default_rng(seed)
    g = rng.standard_normal(code.num_groups)      # one grad per data group
    # per cluster, keep a nonempty survivor subset (<= beta-1 erasures)
    mask = np.zeros(m)
    for c in range(code.num_clusters):
        members = np.flatnonzero(np.asarray(code.clusters) == c)
        keep = data.draw(st.integers(1, len(members)), label=f"keep{c}")
        mask[rng.permutation(members)[:keep]] = 1.0
    assert code.decode_exact_possible(mask)
    workers = g[np.asarray(code.clusters)]        # replica gradients
    a = np.asarray(code.decode_weights(mask))
    est = float(a @ workers) / code.num_groups
    np.testing.assert_allclose(est, g.mean(), rtol=1e-6, atol=1e-9)


@settings(max_examples=25, deadline=None)
@given(st.integers(4, 10), st.integers(2, 3), st.integers(0, 49),
       st.data())
def test_cyclic_exact_under_total_erasure_budget(m, beta, seed, data):
    code = make_cyclic(m, beta=beta, seed=seed)
    n_erase = data.draw(st.integers(0, beta - 1), label="n_erase")
    erased = data.draw(st.permutations(range(m)), label="erased")[:n_erase]
    mask = np.ones(m)
    mask[list(erased)] = 0.0
    assert code.decode_exact_possible(mask)
    a = np.asarray(code.decode_weights(mask))
    B = np.asarray(code.B)
    # B^T a = 1 <=> the combined worker gradients equal the full-batch sum
    resid = B.T @ a - np.ones(m)
    tol = 1e-6 * (1.0 + float(np.abs(B).max()) * float(np.abs(a).max()) * m)
    assert float(np.abs(resid).max()) <= tol


@settings(max_examples=5, deadline=None)
@given(st.integers(5, 8), st.integers(2, 3), st.integers(0, 9))
def test_stochastic_unbiased_with_bounded_variance(m, beta, seed):
    rng = np.random.default_rng(1000 + seed)
    g = rng.standard_normal(m)                    # scalar grad per group
    active = rng.choice(m, m - 2, replace=False)  # any fixed active set
    mask = np.zeros(m)
    mask[active] = 1.0
    base = make_stochastic(m, beta=beta, seed=seed)
    assert base.stochastic and not base.decode_exact_possible(np.ones(m))

    draws = 400
    ests = np.empty(draws)
    for t in range(draws):
        code = base.at_step(t)                    # fresh group assignment
        workers = g[np.asarray(code.groups)].sum(axis=1)
        c = np.asarray(code.decode_weights(mask))
        ests[t] = float(c @ workers) / code.num_groups

    # fixed-degree sampling without replacement: exact estimator variance
    n_act = int(mask.sum())
    var_exact = g.var() * (m - beta) / ((m - 1) * n_act * beta)
    se = np.sqrt(var_exact / draws)
    assert abs(ests.mean() - g.mean()) <= 5.0 * se + 1e-12
    assert ests.var() <= 1.6 * var_exact + 1e-12
