"""Property tests for the encoded data-parallel gradient machinery."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (hadamard_encoder, gaussian_encoder, identity_encoder,
                        make_encoded_problem, masked_gradient, gd_step,
                        original_objective)


def _problem(enc_fn, n=128, p=32, m=8, lam=0.0, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, p))
    y = X @ rng.standard_normal(p) + 0.1 * rng.standard_normal(n)
    return make_encoded_problem(X, y, enc_fn(n), m, lam=lam), X, y


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 999))
def test_full_mask_tight_frame_exact_gradient(seed):
    """With k = m and a tight frame, the encoded gradient EQUALS the true
    gradient of the original smooth loss (paper §4.1 optimality argument)."""
    prob, X, y = _problem(lambda n: hadamard_encoder(n, 2.0), seed=seed)
    rng = np.random.default_rng(seed + 1)
    w = jnp.asarray(rng.standard_normal(X.shape[1]))
    g_enc = masked_gradient(prob, w, jnp.ones(prob.m))
    g_true = jnp.asarray(X.T @ (X @ np.asarray(w) - y) / X.shape[0])
    np.testing.assert_allclose(np.asarray(g_enc), np.asarray(g_true),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999), drop=st.integers(1, 3))
def test_masked_gradient_bounded_error(seed, drop):
    """Fastest-k gradient error stays within the empirical BRIP envelope:
    ||g~ - g|| <= eps_hat * (||g|| + L ||w||)-ish; we assert the cheap form
    ||g~ - g|| <= 1.5 ||g|| + small for the Hadamard ensemble at eta=5/8."""
    prob, X, y = _problem(lambda n: hadamard_encoder(n, 2.0), seed=seed)
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal(X.shape[1]) * 0.5)
    mask = np.ones(prob.m)
    mask[rng.choice(prob.m, size=drop, replace=False)] = 0.0
    g_enc = np.asarray(masked_gradient(prob, w, jnp.asarray(mask)))
    g_true = X.T @ (X @ np.asarray(w) - y) / X.shape[0]
    err = np.linalg.norm(g_enc - g_true)
    scale = np.linalg.norm(g_true) + np.linalg.norm(
        X.T @ X / X.shape[0], 2) * np.linalg.norm(np.asarray(w))
    assert err <= 1.5 * scale


def test_uncoded_full_mask_also_exact():
    prob, X, y = _problem(identity_encoder)
    w = jnp.asarray(np.random.default_rng(0).standard_normal(X.shape[1]))
    g = masked_gradient(prob, w, jnp.ones(prob.m))
    g_true = X.T @ (X @ np.asarray(w) - y) / X.shape[0]
    np.testing.assert_allclose(np.asarray(g), g_true, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 99))
def test_gd_step_descends(seed):
    """A small encoded GD step never increases the encoded objective by
    more than the paper's kappa factor — and usually decreases f."""
    prob, X, y = _problem(lambda n: hadamard_encoder(n, 2.0), lam=0.05,
                          seed=seed)
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal(X.shape[1]))
    L = np.linalg.eigvalsh(X.T @ X / X.shape[0]).max()
    mask = np.ones(prob.m)
    mask[rng.integers(prob.m)] = 0.0
    f0 = float(original_objective(prob, w, h="l2"))
    w1 = gd_step(prob, w, jnp.asarray(mask), 0.2 / (L + 0.05), h="l2")
    f1 = float(original_objective(prob, w1, h="l2"))
    assert f1 <= 1.05 * f0


def test_adamw_quadratic_convergence():
    """Optimizer substrate sanity: AdamW minimizes a quadratic."""
    import jax
    from repro.optim import adamw_init, adamw_update
    A = jnp.asarray(np.diag(np.linspace(1, 5, 8)))
    b = jnp.asarray(np.random.default_rng(0).standard_normal(8))
    params = {"w": jnp.zeros(8)}
    opt = adamw_init(params)
    loss = lambda p: 0.5 * p["w"] @ A @ p["w"] - b @ p["w"]
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, lr=5e-2,
                                      weight_decay=0.0)
    w_star = jnp.linalg.solve(A, b)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(w_star),
                               atol=5e-2)
