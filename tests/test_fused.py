"""Fused masked-gradient path (DESIGN.md §12): kernel-vs-reference
equivalence, REPRO_FUSED scan routing, cell-batched matrix execution,
R==1 single-trial routing, combine layout, and the fastest-k sampler
fast path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (bimodal_delays, hadamard_encoder,
                        make_encoded_problem, pad_rows)
from repro.kernels.coded_reduce import coded_combine_call, combine_layout
from repro.kernels.fused_step import (fused_enabled, fused_masked_gradient,
                                      pick_fused_block_rows)
from repro.runtime import (ClusterEngine, FastestK, ProblemSpec,
                           batched_scan_gd, scan_gd)
from repro.runtime.engine import make_delay_model


def _reference(SX, Sy, w, mask, *, n, beta):
    k = jnp.maximum(mask.sum(), 1.0)
    c = mask * (SX.shape[0] / k) / (n * beta)
    u = jnp.einsum("mrp,p->mr", SX, w) - Sy
    return jnp.einsum("m,mrp,mr->p", c, SX, u).astype(w.dtype)


def _operands(m, r, p, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    SX = jnp.asarray(rng.standard_normal((m, r, p)), dtype)
    Sy = jnp.asarray(rng.standard_normal((m, r)), dtype)
    w = jnp.asarray(rng.standard_normal(p), dtype)
    mask = jnp.asarray(rng.random(m) < 0.7, jnp.float32)
    return SX, Sy, w, mask


# ---------------------------------------------------------------------------
# kernel vs dense reference (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [4, 32])
@pytest.mark.parametrize("p", [37, 63])          # odd p: no lane alignment
def test_fused_matches_reference_odd_p(m, p):
    SX, Sy, w, mask = _operands(m, 8, p)
    out = fused_masked_gradient(SX, Sy, w, mask, n=m * 8 // 2, beta=2.0,
                                interpret=True)
    ref = _reference(SX, Sy, w, mask, n=m * 8 // 2, beta=2.0)
    assert out.shape == (p,) and out.dtype == w.dtype
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_fused_bf16():
    SX, Sy, w, mask = _operands(8, 16, 64, dtype=jnp.bfloat16)
    out = fused_masked_gradient(SX, Sy, w, mask, n=64, beta=2.0,
                                interpret=True)
    ref = _reference(SX.astype(jnp.float32), Sy.astype(jnp.float32),
                     w.astype(jnp.float32), mask, n=64, beta=2.0)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=0.1, rtol=0.05)


def test_fused_block_rows_sweep():
    SX, Sy, w, mask = _operands(4, 12, 40)
    full = fused_masked_gradient(SX, Sy, w, mask, n=24, beta=2.0,
                                 interpret=True, block_rows=12)
    for br in (1, 2, 3, 4, 6):
        out = fused_masked_gradient(SX, Sy, w, mask, n=24, beta=2.0,
                                    interpret=True, block_rows=br)
        np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                                   atol=1e-5)


def test_fused_all_masked_out_is_zero_safe():
    SX, Sy, w, _ = _operands(4, 8, 16)
    out = fused_masked_gradient(SX, Sy, w, jnp.zeros(4), n=16, beta=2.0,
                                interpret=True)
    assert np.all(np.isfinite(np.asarray(out)))
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)


def test_pick_fused_block_rows_divides_and_fits():
    for r, p in [(64, 64), (96, 512), (4096, 128)]:
        br = pick_fused_block_rows(r, p)
        assert r % br == 0
        assert 2 * br * p * 4 <= 8 * 2 ** 20


# ---------------------------------------------------------------------------
# scan-level routing: REPRO_FUSED=1 vs the dense path
# ---------------------------------------------------------------------------

def test_scan_gd_fused_matches_dense(monkeypatch):
    """The full scan under the fused kernel equals the dense-einsum scan.

    ``fused_enabled`` is a trace-time branch, so each flag flip needs a
    fresh trace — hence ``jax.clear_caches`` around each run."""
    spec = ProblemSpec.synthetic(128, 48, noise=0.5, lam=0.05, seed=3)
    prob = make_encoded_problem(spec.X, spec.y,
                                pad_rows(hadamard_encoder(128, 2.0), 8), 8,
                                lam=spec.lam)
    sched = ClusterEngine(bimodal_delays(), 8, seed=1).sample_schedule(
        15, FastestK(6))
    masks = jnp.asarray(sched.masks)
    w0 = jnp.zeros(48)

    monkeypatch.setenv("REPRO_FUSED", "0")
    jax.clear_caches()
    assert not fused_enabled()
    w_d, tr_d = scan_gd(prob, masks, 0.05, w0)

    monkeypatch.setenv("REPRO_FUSED", "1")
    jax.clear_caches()
    assert fused_enabled()
    w_f, tr_f = scan_gd(prob, masks, 0.05, w0)
    jax.clear_caches()                      # don't leak fused traces

    np.testing.assert_allclose(np.asarray(w_f), np.asarray(w_d), atol=1e-4)
    np.testing.assert_allclose(np.asarray(tr_f), np.asarray(tr_d), atol=1e-4)


# ---------------------------------------------------------------------------
# R == 1 routes through the single-trial scan
# ---------------------------------------------------------------------------

def test_batched_r1_matches_single_bitwise():
    spec = ProblemSpec.synthetic(128, 32, noise=0.5, lam=0.05, seed=0)
    prob = make_encoded_problem(spec.X, spec.y,
                                pad_rows(hadamard_encoder(128, 2.0), 8), 8,
                                lam=spec.lam)
    sched = ClusterEngine(bimodal_delays(), 8, seed=0).sample_schedule(
        12, FastestK(6))
    masks = jnp.asarray(sched.masks)
    w_s, tr_s = scan_gd(prob, masks, 0.05, jnp.zeros(32))
    w_b, tr_b = batched_scan_gd(prob, masks[None], 0.05,
                                jnp.zeros((1, 32)))
    assert w_b.shape == (1, 32) and tr_b.shape == (1, 12)
    assert np.array_equal(np.asarray(w_b[0]), np.asarray(w_s))
    assert np.array_equal(np.asarray(tr_b[0]), np.asarray(tr_s))


# ---------------------------------------------------------------------------
# cell-batched matrix execution == per-cell execution
# ---------------------------------------------------------------------------

def _matrix_spec(cell_batch, trials=3):
    from repro.experiments import (DelayAxis, ExperimentSpec, PlacementAxis,
                                   ProblemAxis, StrategyAxis, TrialsAxis)
    return ExperimentSpec(
        problems=(ProblemAxis.synthetic(128, 32, lam=0.05, h="l2"),),
        strategies=(StrategyAxis("coded-gd"),
                    StrategyAxis("coded-gd",
                                 options=(("step_size", 0.02),)),
                    StrategyAxis("uncoded")),
        delays=DelayAxis.of("bimodal", "power_law", m=8),
        trials=TrialsAxis(trials=trials),
        placement=PlacementAxis(mode="vmap", cell_batch=cell_batch),
        steps=10)


def test_cellbatched_matrix_matches_percell():
    from repro.experiments import execute, plan
    per = execute(plan(_matrix_spec(False)))
    bat = execute(plan(_matrix_spec(True)))
    assert len(per.records) == len(bat.records) == 6
    batched_groups = 0
    for rp, rb in zip(per.records, bat.records):
        assert rp["strategy"] == rb["strategy"]
        assert rp["delay"] == rb["delay"]
        np.testing.assert_allclose(np.asarray(rb["objective"], float),
                                   np.asarray(rp["objective"], float),
                                   atol=1e-4)
        if rb["meta"].get("cell_batched", 0) > 1:
            batched_groups += 1
    # the 4 coded-gd cells and the 2 uncoded cells each share one program
    assert batched_groups == 6


def test_cellbatched_trials1_keeps_run_schema():
    from repro.experiments import execute, plan
    per = execute(plan(_matrix_spec(False, trials=1)))
    bat = execute(plan(_matrix_spec(True, trials=1)))
    for rp, rb in zip(per.records, bat.records):
        np.testing.assert_allclose(np.asarray(rb["objective"], float),
                                   np.asarray(rp["objective"], float),
                                   atol=1e-4)
        assert set(rp.keys()) <= set(rb.keys()) | {"meta"}


# ---------------------------------------------------------------------------
# combine layout: odd P without padding, 2-D weight acceptance
# ---------------------------------------------------------------------------

def test_combine_layout_divisor_over_pad():
    assert combine_layout(2048) == (2048, 0)
    assert combine_layout(37) == (37, 0)          # P <= block: one tile
    bp, pad = combine_layout(2085)                # 3 * 5 * 139
    assert pad == 0 and 2085 % bp == 0 and bp >= 128
    bp, pad = combine_layout(2053)                # prime: must pad
    assert pad == (-2053) % bp


@pytest.mark.parametrize("P_", [37, 2085])
def test_combine_call_odd_p_and_2d_weights(P_):
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((6, P_)), jnp.float32)
    c = jnp.asarray(rng.standard_normal(6), jnp.float32)
    ref = jnp.einsum("m,mp->p", c, g)
    out1 = coded_combine_call(g, c, interpret=True)
    out2 = coded_combine_call(g, c[:, None], interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    assert np.array_equal(np.asarray(out1), np.asarray(out2))


# ---------------------------------------------------------------------------
# fastest-k sampler fast path == reference loop, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["bimodal", "power_law", "constant"])
def test_sampler_fast_path_bit_identical(model):
    eng = ClusterEngine(make_delay_model(model), 16, seed=4)
    for r in (0, 2):
        fast = eng.sample_schedule(25, FastestK(12), realization=r)
        rng = np.random.default_rng(eng._trial_seed(r))
        slow = eng._sample_generic(rng, 25, FastestK(12))
        assert np.array_equal(fast.masks, slow.masks)
        assert np.array_equal(fast.times, slow.times)
        for ef, es in zip(fast.events, slow.events):
            assert ef.start == es.start and ef.commit == es.commit
            assert np.array_equal(ef.active, es.active)
            assert np.array_equal(ef.arrivals, es.arrivals)
