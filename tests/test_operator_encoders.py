"""Operator/dense equivalence for the matrix-free encoding layer.

Every ``LinearEncoder`` implementation must agree with its ``materialize()``-d
dense matrix on ``encode``/``decode_t`` (including the adjoint identity
<Sx, y> == <x, S'y>), build identical worker blocks, flow through the
spectrum diagnostics, the problem builders, the streaming encode, and the
full runtime compare harness.
"""
import numpy as np
import pytest

from repro.core import (BlockDiagonalEncoder, FastHadamardEncoder,
                        LinearEncoder, as_dense, brip_constant,
                        hadamard_encoder, make_encoded_problem, make_encoder,
                        masked_gradient, subset_spectrum)
from repro.data import lsq_rows, stream_worker_blocks

OPERATORS = {
    "fast-hadamard": lambda n, seed: FastHadamardEncoder(n, 2.0, seed=seed),
    "block-diagonal": lambda n, seed: BlockDiagonalEncoder(
        n, 2.0, seed=seed, block_size=16),
}


def _tol(enc):
    # FWHT runs in float32 on the kernel path; block-diagonal is exact f64.
    return 5e-5 if isinstance(enc, FastHadamardEncoder) else 1e-12


@pytest.mark.parametrize("name", sorted(OPERATORS))
def test_encode_matches_materialized(name):
    enc = OPERATORS[name](96, seed=3)
    S = enc.materialize()
    X = np.random.default_rng(0).standard_normal((96, 5))
    np.testing.assert_allclose(np.asarray(enc.encode(X)), S @ X,
                               atol=_tol(enc) * np.sqrt(S.shape[0]))


@pytest.mark.parametrize("name", sorted(OPERATORS))
def test_decode_t_matches_materialized(name):
    enc = OPERATORS[name](96, seed=3)
    S = enc.materialize()
    G = np.random.default_rng(1).standard_normal((enc.rows, 4))
    np.testing.assert_allclose(np.asarray(enc.decode_t(G)), S.T @ G,
                               atol=_tol(enc) * np.sqrt(S.shape[0]))


@pytest.mark.parametrize("name", sorted(OPERATORS))
def test_adjoint_identity(name):
    """<S x, y> == <x, S' y> — encode and decode_t are true adjoints."""
    enc = OPERATORS[name](64, seed=7)
    rng = np.random.default_rng(2)
    x = rng.standard_normal(64)
    y = rng.standard_normal(enc.rows)
    lhs = float(np.vdot(np.asarray(enc.encode(x), np.float64), y))
    rhs = float(np.vdot(x, np.asarray(enc.decode_t(y), np.float64)))
    assert lhs == pytest.approx(rhs, rel=1e-4, abs=1e-4)


def test_fast_hadamard_reproduces_dense_construction():
    """Same rng draws as hadamard_encoder: materialize() is bit-identical."""
    fh = FastHadamardEncoder(96, 2.0, seed=5)
    dh = hadamard_encoder(96, 2.0, seed=5)
    assert fh.beta == dh.beta
    np.testing.assert_array_equal(fh.materialize(), dh.S)


def test_fast_hadamard_tight_frame():
    S = FastHadamardEncoder(64, 2.0, seed=0).materialize()
    np.testing.assert_allclose(S.T @ S, 2.0 * np.eye(64), atol=1e-9)


def test_block_diagonal_tight_frame_and_structure():
    enc = BlockDiagonalEncoder(96, 2.0, seed=1, block_size=16)
    S = enc.materialize()
    np.testing.assert_allclose(S.T @ S, enc.beta * np.eye(96), atol=1e-9)
    # genuinely block diagonal: tile (j, j') is zero for j != j'
    rb, nb = enc.base.rows, enc.base.n
    for j in range(enc.B):
        off = S[j * rb:(j + 1) * rb].copy()
        off[:, j * nb:(j + 1) * nb] = 0.0
        assert np.abs(off).max() == 0.0


@pytest.mark.parametrize("name", sorted(OPERATORS))
@pytest.mark.parametrize("m", [8, 6])   # aligned (pow2) and padded fallback
def test_worker_blocks_tile_the_encode(name, m):
    enc = OPERATORS[name](96, seed=4).with_workers(m)
    S = enc.materialize()
    assert S.shape[0] == enc.rows and enc.rows % m == 0
    X = np.random.default_rng(3).standard_normal((96, 3))
    stacked = np.concatenate(
        [np.asarray(enc.worker_block(i, X)) for i in range(m)])
    np.testing.assert_allclose(stacked, S @ X,
                               atol=_tol(enc) * np.sqrt(S.shape[0]))


@pytest.mark.parametrize("name", sorted(OPERATORS))
@pytest.mark.parametrize("m", [8, 6])
def test_encode_partitioned_matches_worker_blocks(name, m):
    """The bulk builder path (one pass for FWHT) == per-worker blocks."""
    enc = OPERATORS[name](96, seed=6).with_workers(m)
    X = np.random.default_rng(8).standard_normal((96, 4))
    bulk = [np.asarray(b) for b in enc.encode_partitioned(X)]
    assert len(bulk) == m
    lazy = [np.asarray(enc.worker_block(i, X)) for i in range(m)]
    for b, l in zip(bulk, lazy):
        np.testing.assert_allclose(b, l, atol=1e-5)


def test_with_workers_idempotent_and_guarded():
    enc = FastHadamardEncoder(64, 2.0).with_workers(8)
    assert enc.with_workers(8) is enc
    with pytest.raises(ValueError):
        enc.with_workers(4)


@pytest.mark.parametrize("name", sorted(OPERATORS))
def test_spectrum_tools_accept_operators(name):
    enc = OPERATORS[name](96, seed=0)
    ev = subset_spectrum(enc, 8, 6, trials=5)
    assert ev.shape == (5, 96) and np.isfinite(ev).all()
    eps = brip_constant(enc, 8, 6, trials=5)
    assert 0.0 <= eps < 1.5


@pytest.mark.parametrize("name", sorted(OPERATORS))
def test_encoded_problem_operator_matches_dense(name):
    """make_encoded_problem via worker_block == the dense S route, and the
    masked gradients agree."""
    import jax.numpy as jnp
    rng = np.random.default_rng(5)
    n, p, m = 96, 24, 8
    X = rng.standard_normal((n, p))
    y = X @ rng.standard_normal(p)
    op = OPERATORS[name](n, seed=2)
    prob_op = make_encoded_problem(X, y, op, m, lam=0.01)
    prob_de = make_encoded_problem(X, y, as_dense(op.with_workers(m)), m,
                                   lam=0.01)
    np.testing.assert_allclose(np.asarray(prob_op.SX),
                               np.asarray(prob_de.SX), atol=1e-4)
    w = jnp.asarray(rng.standard_normal(p), jnp.float32)
    mask = jnp.asarray(np.r_[np.ones(m - 2), 0.0, 0.0], jnp.float32)
    np.testing.assert_allclose(np.asarray(masked_gradient(prob_op, w, mask)),
                               np.asarray(masked_gradient(prob_de, w, mask)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("encoder", ["fast-hadamard", "block-diagonal"])
def test_compare_harness_operator_trace_matches_dense(encoder):
    """Acceptance: operator encoders through runtime/compare.py reproduce the
    DenseEncoder objective trace to 1e-4 on a shared delay realization."""
    from repro.runtime.engine import ClusterEngine, make_delay_model
    from repro.runtime.strategies import ProblemSpec, get_strategy
    spec = ProblemSpec.synthetic(n=128, p=32, lam=0.05, seed=0)
    op = make_encoder(encoder, spec.n).with_workers(8)
    traces = {}
    for tag, enc in [("operator", op), ("dense", as_dense(op))]:
        engine = ClusterEngine(make_delay_model("bimodal"), 8, seed=0)
        res = get_strategy("coded-gd").run(spec, engine, steps=40, k=6,
                                           encoder=enc)
        traces[tag] = np.asarray(res.objective)
    np.testing.assert_allclose(traces["operator"], traces["dense"], atol=1e-4)


def test_compare_matrix_accepts_operator_encoders_by_name():
    from repro.runtime.compare import run_matrix
    recs = run_matrix(["coded-gd"], ["bimodal"], n=64, p=16, m=4, k=3,
                      steps=10, encoder="fast-hadamard", seed=1)
    assert len(recs) == 1
    assert recs[0]["meta"]["encoder"] == "fast-hadamard"
    assert np.isfinite(recs[0]["final_objective"])


def test_lsq_rows_deterministic_and_order_free():
    X_all, y_all, w = lsq_rows(0, 300, 8, seed=9)
    X_mid, y_mid, w2 = lsq_rows(100, 200, 8, seed=9)
    np.testing.assert_array_equal(X_mid, X_all[100:200])
    np.testing.assert_array_equal(y_mid, y_all[100:200])
    np.testing.assert_array_equal(w, w2)
    assert lsq_rows(5, 5, 8, seed=9)[0].shape == (0, 8)


def test_stream_worker_blocks_matches_bulk_encode():
    """Worker-by-worker streaming encode == one-shot encode of the full X;
    for block-diagonal each worker only ever pulls its own shard."""
    n, p, m = 128, 6, 8
    enc = BlockDiagonalEncoder(n, 2.0, seed=0, block_size=16).with_workers(m)
    X_full, _, _ = lsq_rows(0, n, p, seed=4)
    S = enc.materialize()
    pulls = []

    def rows_fn(lo, hi):
        pulls.append(hi - lo)
        return lsq_rows(lo, hi, p, seed=4)[0]

    blocks = dict(stream_worker_blocks(enc, m, rows_fn))
    stacked = np.concatenate([blocks[i] for i in range(m)])
    np.testing.assert_allclose(stacked, S @ X_full, atol=1e-10)
    assert max(pulls) < n            # never pulled the whole dataset at once


# ---------------------------------------------------------------------------
# Fused encode kernel (kernels/encode.py) — no hypothesis dependency, so these
# live here rather than in test_kernels.py (which importorskips hypothesis).
# ---------------------------------------------------------------------------

def _srht_oracle(n, p, N, seed):
    import math
    from repro.core.encoding import hadamard_matrix
    rng = np.random.default_rng(seed)
    cols = rng.choice(N, size=n, replace=False)
    signs = rng.choice([-1.0, 1.0], size=n)
    X = rng.standard_normal((n, p)).astype(np.float32)
    S = hadamard_matrix(N)[:, cols] * signs[None, :] / math.sqrt(n)
    return X, cols, signs, S


@pytest.mark.parametrize("lo,hi", [(0, 256), (0, 32), (96, 160), (224, 256)])
def test_srht_encode_row_windows(lo, hi):
    """The fused sign-flip + FWHT + gather kernel matches the dense slice."""
    import jax.numpy as jnp
    from repro.kernels.ops import srht_encode
    X, cols, signs, S = _srht_oracle(100, 7, 256, seed=11)
    out = srht_encode(jnp.asarray(X), cols, signs, 256, lo=lo, hi=hi)
    assert out.shape == (hi - lo, 7)
    np.testing.assert_allclose(np.asarray(out), (S @ X)[lo:hi],
                               rtol=1e-4, atol=1e-4)


def test_srht_encode_call_fuses_signs():
    """dsigns zeros must kill dead lanes even if the input has junk there."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.encode import srht_encode_call
    from repro.kernels.ref import fwht_ref
    rows, N = 8, 128
    x = jax.random.normal(jax.random.key(8), (rows, N))
    d = np.zeros((1, N), np.float32)
    d[0, np.arange(0, N, 2)] = 1.0
    out = srht_encode_call(x, jnp.asarray(d), lo=0, hi=N, scale=1.0,
                           interpret=True)
    ref = fwht_ref(x * jnp.asarray(d))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-3)


def test_srht_encode_call_validates():
    import jax.numpy as jnp
    from repro.kernels.encode import srht_encode_call
    x = jnp.ones((4, 128))
    d = jnp.ones((1, 128))
    with pytest.raises(ValueError):
        srht_encode_call(jnp.ones((4, 100)), jnp.ones((1, 100)), lo=0,
                         hi=100, scale=1.0, interpret=True)
    with pytest.raises(ValueError):
        srht_encode_call(x, d, lo=64, hi=32, scale=1.0, interpret=True)
    with pytest.raises(ValueError):
        srht_encode_call(x, jnp.ones((1, 64)), lo=0, hi=128, scale=1.0,
                         interpret=True)


def test_token_stream_vectorized_motifs():
    """Vectorized sampler: deterministic per seed, right shapes/dtype, and
    motifs actually appear as contiguous subsequences."""
    from repro.data import TokenStream
    ts = TokenStream(64, seed=0, motif_len=8, n_motifs=4)
    a = ts.sample(np.random.default_rng(7), 64, 24)
    b = ts.sample(np.random.default_rng(7), 64, 24)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (64, 25) and a.dtype == np.int32
    assert a.min() >= 0 and a.max() < 64
    hits = 0
    for row in a:
        for mo in ts._motifs:
            s = mo[:8].astype(np.int32)
            for start in range(25 - 8 + 1):
                if np.array_equal(row[start:start + 8], s):
                    hits += 1
                    break
            else:
                continue
            break
    assert 10 <= hits  # ~50% of 64 rows carry a motif
