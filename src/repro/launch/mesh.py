"""Production mesh construction (TPU v5e-256 pods).

A FUNCTION (not module-level constant) so importing never touches jax device
state; the dry-run sets XLA_FLAGS for 512 host devices before first jax init.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def _make_mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; Auto is the default there,
    # so older jax gets the same mesh by omitting the kwarg
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests / trainer)."""
    n = jax.device_count()
    data = data or (n // model)
    return _make_mesh((data, model), ("data", "model"))
