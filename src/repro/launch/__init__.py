"""Launchers: mesh construction, dry-run, CLI training driver.

NOTE: do not import .dryrun from library code — it sets XLA device-count
flags at import time and must run as its own process.
"""
from .mesh import make_production_mesh, make_local_mesh
