"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
mesh, prove it partitions, and extract the roofline terms (§Dry-run,
§Roofline in EXPERIMENTS.md).

MUST be run as its own process (the XLA_FLAGS below lock in 512 host
placeholder devices before any other jax import — do NOT import this module
from tests or benchmarks).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single --out runs/dryrun
  (--mesh single|multi|both; emits one JSON per combo)
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCHS, SHAPES
from ..models import transformer as T
from ..optim import adamw_init, cosine_schedule
from ..sharding import make_specs, batch_axes
from ..train.steps import build_train_step, build_prefill_step, \
    build_decode_step
from .hlo_analysis import analyze_hlo, roofline, top_hotspots
from .mesh import make_production_mesh
from .specs import input_specs, input_shardings, shape_config


def _param_structs(cfg):
    """ShapeDtypeStructs of params (+ opt state) — no allocation."""
    from ..models.common import Dtype
    defs = T.param_defs(cfg)
    params = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.key(0)))
    return params


def dryrun_one(arch: str, shape_name: str, multi_pod: bool,
               verbose: bool = True, extra_overrides: dict | None = None,
               hotspots: bool = False):
    """Lower + compile one (arch, shape, mesh) combo; return result record."""
    cfg = shape_config(ARCHS[arch], shape_name)
    if extra_overrides:
        cfg = cfg.with_overrides(**extra_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    kind, inputs = input_specs(cfg, shape_name)
    in_sh = input_shardings(cfg, shape_name, mesh)

    params = _param_structs(cfg)
    axes = T.param_axes(cfg)
    pspecs = make_specs(mesh, params, axes,
                        fsdp_min_elems=cfg.fsdp_min_elems)
    from jax.sharding import NamedSharding
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(
                           x, jax.sharding.PartitionSpec))

    t0 = time.time()
    with mesh:
        if kind == "train":
            opt = jax.eval_shape(lambda p: adamw_init(
                p, dtype=jnp.dtype(cfg.optstate_dtype)), params)
            osh = jax.tree.map(
                lambda l: NamedSharding(mesh, jax.sharding.PartitionSpec())
                if l.ndim == 0 else None, opt)
            # optimizer moments inherit param specs
            osh = type(opt)(m=psh, v=psh,
                            count=NamedSharding(
                                mesh, jax.sharding.PartitionSpec()))
            step = build_train_step(cfg, cosine_schedule(3e-4, 100, 10000),
                                    grad_specs=pspecs)
            jitted = jax.jit(step, in_shardings=(psh, osh, in_sh),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params, opt, inputs)
        elif kind == "prefill":
            S = SHAPES[shape_name]["seq_len"]
            step = build_prefill_step(cfg, cache_len=S)
            jitted = jax.jit(step, in_shardings=(psh, in_sh))
            lowered = jitted.lower(params, inputs)
        else:  # decode
            token, caches, index = inputs
            tok_sh, cache_sh, idx_sh = in_sh
            step = build_decode_step(cfg)
            jitted = jax.jit(step,
                             in_shardings=(psh, tok_sh, cache_sh, idx_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(params, token, caches, index)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):       # jax<=0.4.x wraps in a list
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()
    hlo_totals = analyze_hlo(hlo_text)
    if hotspots:
        print("--- top computations by (traffic + collectives) ---")
        for name, mult, fl, tr, cb, hint in top_hotspots(hlo_text, 18):
            print(f"  x{mult:<8.0f} flops={fl:.2e} traffic={tr:.2e} "
                  f"coll={cb:.2e}  {name[:40]:40s} {hint[:70]}")

    n_tokens = (SHAPES[shape_name]["global_batch"]
                * (SHAPES[shape_name]["seq_len"] if kind in ("train",
                                                             "prefill")
                   else 1))
    n_active = T.count_params(cfg, active_only=True)
    mult = 6.0 if kind == "train" else 2.0
    model_flops = mult * n_active * n_tokens
    rl = roofline(cost, hlo_totals, n_chips, model_flops=model_flops)
    coll = {k: hlo_totals.get(k, 0.0) for k in
            ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")}
    coll["count"] = hlo_totals.get("coll_count", 0)

    rec = {
        "arch": arch, "shape": shape_name, "kind": kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "param_count": T.count_params(cfg),
        "param_count_active": n_active,
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
        },
        "roofline": rl,
        "collectives": coll,
    }
    if verbose:
        print(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="directory for JSON records")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--override", default=None,
                    help="JSON dict of ArchConfig overrides (perf iteration)")
    ap.add_argument("--hotspots", action="store_true",
                    help="dump per-computation breakdown (perf debugging)")
    args = ap.parse_args()

    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    overrides = json.loads(args.override) if args.override else None

    failures = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}|{shape}|{'multi' if multi else 'single'}"
                try:
                    rec = dryrun_one(arch, shape, multi,
                                     verbose=not args.quiet,
                                     extra_overrides=overrides,
                                     hotspots=args.hotspots)
                    status = "OK"
                except Exception as e:  # noqa: BLE001 — report and continue
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if multi else "16x16",
                           "error": repr(e)}
                    failures.append(tag)
                    status = "FAIL"
                print(f"[{status}] {tag}", flush=True)
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    fname = tag.replace("|", "__") + ".json"
                    with open(os.path.join(args.out, fname), "w") as f:
                        json.dump(rec, f, indent=2)
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("all dry-runs passed")


if __name__ == "__main__":
    main()
