"""Post-SPMD HLO analysis: LOOP-AWARE flops / traffic / collective accounting.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE — with
scan-over-layers (and chunked attention / SSM scans) that undercounts by the
trip count, and the SPMD partitioner also places FSDP all-gathers INSIDE the
scanned body.  This module re-derives the counts from ``compiled.as_text()``:

  1. parse computations + instruction symbol tables (name -> shape),
  2. recover while trip counts from the loop-condition comparison constant,
  3. propagate multipliers ENTRY -> fusion/call/while-body,
  4. accumulate dot FLOPs (from contracting dims), collective output bytes
     by kind, and an HBM-traffic estimate (dot operands+outputs, KV-cache
     dynamic-(update-)slice, collective outputs).

The traffic estimate deliberately omits fused elementwise ops (they read/
write through the fusion's operands) — it is a documented LOWER-bound style
estimate; §Roofline reports both this and raw cost_analysis numbers.
"""
from __future__ import annotations

import math
import re
from typing import Dict

__all__ = ["collective_bytes", "analyze_hlo", "roofline", "V5E"]

V5E = {
    "peak_flops": 197e12,   # bf16 FLOP/s per chip
    "hbm_bw": 819e9,        # bytes/s per chip
    "link_bw": 50e9,        # bytes/s per ICI link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+"
                  r"\[[0-9,]*\](?:\{[^}]*\})?)\s+([\w.\-]+)\((.*)$")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str):
    m = _SHAPE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _parse_computations(text: str):
    comps: Dict[str, list] = {}
    entry = None
    current = None
    for raw in text.splitlines():
        ls = raw.strip()
        if current is None:
            m = _COMP_HDR.match(ls)
            if m:
                current = m.group(2)
                comps[current] = []
                if m.group(1):
                    entry = current
        else:
            if ls == "}":
                current = None
            else:
                comps[current].append(ls)
    return comps, entry


class _CompInfo:
    __slots__ = ("flops", "coll", "traffic", "calls", "whiles", "consts",
                 "coll_count")

    def __init__(self):
        self.flops = 0.0
        self.coll = {k: 0 for k in _COLLECTIVES}
        self.coll_count = 0
        self.traffic = 0.0
        self.calls = []    # (comp_name, multiplier_kind) with kind 'call'
        self.whiles = []   # (cond_name, body_name)
        self.consts = []


def _analyze_comp(lines) -> _CompInfo:
    info = _CompInfo()
    shapes: Dict[str, str] = {}
    for ls in lines:
        m = _DEF.match(ls)
        if not m:
            c = re.search(r"constant\((\d+)\)", ls)
            if c:
                info.consts.append(int(c.group(1)))
            continue
        name, shape_str, op, rest = m.groups()
        shapes[name] = shape_str
        c = re.search(r"constant\((\d+)\)", ls)
        if c:
            info.consts.append(int(c.group(1)))

        if op == "dot":
            out_elems = math.prod(_shape_dims(shape_str)) if _shape_dims(
                shape_str) else 1
            # Operand list up to the closing paren; some XLA versions print
            # operand shapes inline ("dot(f32[...] %a, f32[...] %b)"), so
            # match %names anywhere rather than anchoring at the start.
            opnames = re.findall(r"%([\w.\-]+)", rest.split(")")[0])[:2]
            k = 1
            cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ls)
            lhs_dims = None
            if opnames and opnames[0] in shapes:
                lhs_dims = _shape_dims(shapes[opnames[0]])
            elif "[" in rest:  # inline operand shape, first bracket is lhs
                lhs_dims = _shape_dims(rest)
            if cdims and lhs_dims:
                for ci in cdims.group(1).split(","):
                    if ci:
                        k *= lhs_dims[int(ci)]
            info.flops += 2.0 * out_elems * k
            tr = _shape_bytes(shape_str)
            for opn in opnames:
                if opn in shapes:
                    tr += _shape_bytes(shapes[opn])
            info.traffic += tr
        elif op in _COLLECTIVES or any(
                op == c + s for c in _COLLECTIVES for s in ("-start",)):
            base = op.replace("-start", "")
            if base in _COLLECTIVES:
                b = _shape_bytes(shape_str)
                info.coll[base] += b
                info.coll_count += 1
                info.traffic += b
        elif op == "dynamic-slice":
            info.traffic += _shape_bytes(shape_str)
        elif op == "dynamic-update-slice":
            # Output aliases the input buffer; only the update slice
            # (operand 1) actually moves.
            opnames = re.findall(r"%([\w.\-]+)", rest.split(")")[0])
            if len(opnames) >= 2 and opnames[1] in shapes:
                info.traffic += _shape_bytes(shapes[opnames[1]])
        elif op == "while":
            cond = re.search(r"condition=%([\w.\-]+)", ls)
            body = re.search(r"body=%([\w.\-]+)", ls)
            if cond and body:
                info.whiles.append((cond.group(1), body.group(1)))
        elif op == "conditional":
            for b in re.findall(r"%([\w.\-]+)",
                                re.search(r"branch_computations=\{([^}]*)\}",
                                          ls).group(1)) if \
                    "branch_computations" in ls else []:
                info.calls.append(b)
        if "calls=%" in ls:
            info.calls.append(re.search(r"calls=%([\w.\-]+)", ls).group(1))
        if "to_apply=%" in ls:
            info.calls.append(re.search(r"to_apply=%([\w.\-]+)", ls).group(1))
    return info


def analyze_hlo(text: str) -> dict:
    """Loop-aware totals over the whole module (per-device numbers)."""
    comps, entry = _parse_computations(text)
    infos = {name: _analyze_comp(lines) for name, lines in comps.items()}

    totals = {"flops": 0.0, "traffic": 0.0, "coll_count": 0,
              **{k: 0.0 for k in _COLLECTIVES}}
    unknown_trips = [0]

    import functools

    def trip_count(cond_name: str) -> int:
        info = infos.get(cond_name)
        if info and info.consts:
            return max(info.consts)
        # condition may delegate comparison to a fused computation
        if info:
            for c in info.calls:
                sub = infos.get(c)
                if sub and sub.consts:
                    return max(sub.consts)
        unknown_trips[0] += 1
        return 1

    def visit(name: str, mult: float, depth: int = 0):
        info = infos.get(name)
        if info is None or depth > 50:
            return
        totals["flops"] += mult * info.flops
        totals["traffic"] += mult * info.traffic
        totals["coll_count"] += mult * info.coll_count
        for k in _COLLECTIVES:
            totals[k] += mult * info.coll[k]
        for c in info.calls:
            visit(c, mult, depth + 1)
        for cond, body in info.whiles:
            t = trip_count(cond)
            visit(body, mult * t, depth + 1)
            visit(cond, mult * t, depth + 1)

    if entry:
        visit(entry, 1.0)
    totals["unknown_trip_counts"] = unknown_trips[0]
    return totals


def top_hotspots(text: str, n: int = 15) -> list:
    """Per-computation breakdown (multiplier-weighted) for perf debugging.

    Returns rows (comp, mult, flops, traffic, coll_bytes, hint) sorted by
    traffic; `hint` is a jax op_name fragment from the computation metadata.
    """
    comps, entry = _parse_computations(text)
    infos = {name: _analyze_comp(lines) for name, lines in comps.items()}
    mults: Dict[str, float] = {}

    def trip_count(cond_name):
        info = infos.get(cond_name)
        if info and info.consts:
            return max(info.consts)
        if info:
            for c in info.calls:
                sub = infos.get(c)
                if sub and sub.consts:
                    return max(sub.consts)
        return 1

    def visit(name, mult, depth=0):
        info = infos.get(name)
        if info is None or depth > 50:
            return
        mults[name] = mults.get(name, 0.0) + mult
        for c in info.calls:
            visit(c, mult, depth + 1)
        for cond, body in info.whiles:
            t = trip_count(cond)
            visit(body, mult * t, depth + 1)

    if entry:
        visit(entry, 1.0)
    rows = []
    for name, mult in mults.items():
        info = infos[name]
        coll = sum(info.coll.values())
        if info.flops == 0 and info.traffic == 0 and coll == 0:
            continue
        hint = ""
        for ls in comps[name]:
            m = re.search(r'op_name="([^"]{0,90})', ls)
            if m and ("dot" in m.group(1) or "while" in m.group(1)):
                hint = m.group(1)
                break
            if m and not hint:
                hint = m.group(1)
        rows.append((name, mult, mult * info.flops, mult * info.traffic,
                     mult * coll, hint))
    by_traffic = sorted(rows, key=lambda r: -(r[3] + r[4]))[:n]
    by_flops = sorted(rows, key=lambda r: -r[2])[:n // 2]
    seen = {r[0] for r in by_traffic}
    return by_traffic + [r for r in by_flops if r[0] not in seen]


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Loop-aware per-kind collective output bytes (per device)."""
    t = analyze_hlo(hlo_text)
    out = {k: t[k] for k in _COLLECTIVES}
    out["count"] = t["coll_count"]
    return out


def roofline(cost: dict, hlo_totals: dict, n_chips: int,
             model_flops: float | None = None) -> dict:
    """Three roofline terms (seconds) + bottleneck.

    Uses loop-aware HLO totals for compute/collective; the memory term takes
    max(cost_analysis bytes, loop-aware dot/cache traffic estimate).
    """
    flops = float(hlo_totals.get("flops", 0.0))
    bytes_cost = float(cost.get("bytes accessed", 0.0))
    bytes_est = float(hlo_totals.get("traffic", 0.0))
    bytes_acc = max(bytes_cost, bytes_est)
    cbytes = float(sum(hlo_totals.get(k, 0.0) for k in _COLLECTIVES))
    terms = {
        "compute_s": flops / V5E["peak_flops"],
        "memory_s": bytes_acc / V5E["hbm_bw"],
        "collective_s": cbytes / V5E["link_bw"],
    }
    bottleneck = max(terms, key=terms.get)
    out = {**terms, "bottleneck": bottleneck.replace("_s", ""),
           "hlo_flops_per_device": flops,
           "hlo_bytes_per_device": bytes_acc,
           "hlo_bytes_cost_analysis": bytes_cost,
           "hlo_bytes_traffic_est": bytes_est,
           "collective_bytes_per_device": cbytes,
           "collective_count": hlo_totals.get("coll_count", 0),
           "unknown_trip_counts": hlo_totals.get("unknown_trip_counts", 0),
           "n_chips": n_chips}
    if model_flops is not None:
        total_hlo = flops * n_chips
        out["model_flops"] = model_flops
        out["useful_ratio"] = model_flops / total_hlo if total_hlo else 0.0
    return out
