"""CLI training launcher: coded data-parallel training with straggler
simulation on local devices (CPU here; the same step function is what the
dry-run lowers for the production mesh).

  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --smoke \
      --steps 50 --m-workers 8 --wait-k 6 --delay bimodal
"""
from __future__ import annotations

import argparse
import json

from ..configs import ARCHS
from ..core.straggler import (bimodal_delays, power_law_delays,
                              exponential_delays, multimodal_delays,
                              constant_delays)
from ..train.trainer import Trainer, TrainerConfig

DELAYS = {
    "bimodal": bimodal_delays,
    "powerlaw": power_law_delays,
    "exponential": exponential_delays,
    "multimodal": multimodal_delays,
    "none": lambda: constant_delays(0.0),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--m-workers", type=int, default=8)
    ap.add_argument("--beta", type=int, default=2)
    ap.add_argument("--wait-k", type=int, default=6)
    ap.add_argument("--rows-per-worker", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--delay", default="bimodal", choices=sorted(DELAYS))
    ap.add_argument("--uncoded", action="store_true",
                    help="baseline without redundancy")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = cfg.smoke_variant()
    tcfg = TrainerConfig(
        m_workers=args.m_workers, beta=args.beta, wait_k=args.wait_k,
        rows_per_worker=args.rows_per_worker, seq_len=args.seq_len,
        steps=args.steps, lr=args.lr, checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=50 if args.checkpoint_dir else 0,
        uncoded=args.uncoded)
    trainer = Trainer(cfg, tcfg, delay_model=DELAYS[args.delay]())
    _, _, history = trainer.run()
    print(f"final loss: {history[-1]['loss']:.4f}; "
          f"simulated wall-clock: {history[-1]['sim_time_s']:.1f}s")
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(history, f)


if __name__ == "__main__":
    main()
