"""ShapeDtypeStruct input stands-ins + their shardings for the dry-run.

``input_specs(cfg, shape_name)`` returns (inputs, make_shardings(mesh)) for
each execution kind:

  train   -> {tokens, labels, weights, <modality extras>}
  prefill -> {tokens, <modality extras>}
  decode  -> (token, caches, index)  — ONE new token + KV cache of seq_len

Shardings: batch over ("pod","data") when divisible; for long_500k (batch 1)
the KV-cache SEQUENCE dim is sharded over the data axes instead (context
parallelism for decode, DESIGN §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import SHAPES, windowed_variant, needs_window_for_long
from ..configs.base import ArchConfig
from ..models import transformer as T
from ..models.common import Dtype
from ..sharding import batch_axes

__all__ = ["shape_config", "input_specs", "input_shardings", "cache_struct"]


def shape_config(cfg: ArchConfig, shape_name: str) -> ArchConfig:
    """Arch variant actually lowered for this input shape (DESIGN §4)."""
    shp = SHAPES[shape_name]
    if shape_name == "long_500k" and needs_window_for_long(cfg):
        cfg = windowed_variant(cfg)
    if shp["kind"] == "train":
        # Bigger scan chunks for training lower memory-proportionate HLO.
        return cfg
    return cfg


def _extras_struct(cfg: ArchConfig, B: int, S: int):
    dt = Dtype.of(cfg.dtype)
    out = {}
    if cfg.n_patches:
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_vision), dt)
        out["mrope_positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    if cfg.n_enc_layers:
        out["enc_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_enc_frames, cfg.d_model), dt)
    return out


def cache_struct(cfg: ArchConfig, B: int, cache_len: int):
    """ShapeDtypeStructs of the decode caches (no allocation)."""
    return jax.eval_shape(lambda: T.init_caches(cfg, B, cache_len))


def input_specs(cfg: ArchConfig, shape_name: str):
    """Returns (kind, inputs) with ShapeDtypeStruct leaves."""
    shp = SHAPES[shape_name]
    B, S, kind = shp["global_batch"], shp["seq_len"], shp["kind"]
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if kind == "train":
        inputs = {"tokens": tok, "labels": tok,
                  "weights": jax.ShapeDtypeStruct((B,), jnp.float32)}
        inputs.update(_extras_struct(cfg, B, S))
        return kind, inputs
    if kind == "prefill":
        inputs = {"tokens": tok}
        inputs.update(_extras_struct(cfg, B, S))
        return kind, inputs
    # decode: one token, cache of length S (position S-1 being generated)
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    caches = cache_struct(cfg, B, S)
    index = jax.ShapeDtypeStruct((), jnp.int32)
    return kind, (token, caches, index)


# ------------------------------------------------------------- shardings ---

def _batch_spec(mesh: Mesh, B: int, rest_ndim: int):
    ba = batch_axes(mesh)
    import math
    size = math.prod(mesh.shape[a] for a in ba)
    first = ba if B % size == 0 and B >= size else None
    return P(*((first,) + (None,) * rest_ndim))


def _cache_specs(cfg: ArchConfig, B: int, cache_len: int, mesh: Mesh):
    """PartitionSpec tree mirroring init_caches' structure."""
    import math
    ba = batch_axes(mesh)
    bsz = math.prod(mesh.shape[a] for a in ba)
    msz = mesh.shape["model"]
    bspec = ba if (B % bsz == 0 and B >= bsz) else None
    shard_seq = bspec is None  # context-parallel decode for batch-1

    def attn_spec(C):
        seq = ba if (shard_seq and C % bsz == 0) else None
        kv = "model" if cfg.n_kv % msz == 0 else None
        s = P(None, bspec, seq, kv, None)
        return T.attn.AttnCache(s, s)

    di = cfg.mamba_expand * cfg.d_model
    dp = int(cfg.xlstm_proj_factor * cfg.d_model)
    specs = []
    for spec in cfg.period:
        if spec.kind == "attn":
            C = min(cache_len, spec.window) if spec.window else cache_len
            s = attn_spec(C)
            if spec.cross_attn:
                s = (s, attn_spec(max(cfg.n_enc_frames, 1)))
        elif spec.kind == "mamba":
            dim = "model" if di % msz == 0 else None
            s = T.mb.MambaCache(P(None, bspec, None, dim),
                                P(None, bspec, dim, None))
        elif spec.kind == "mlstm":
            hdim = "model" if cfg.n_heads % msz == 0 else None
            s = T.xl.MLSTMCache(P(None, bspec, hdim, None, None),
                                P(None, bspec, hdim, None),
                                P(None, bspec, hdim))
        elif spec.kind == "slstm":
            hdim = "model" if cfg.n_heads % msz == 0 else None
            sp = P(None, bspec, hdim, None)
            s = T.xl.SLSTMCache(sp, sp, sp, sp)
        specs.append(s)
    return tuple(specs)


def input_shardings(cfg: ArchConfig, shape_name: str, mesh: Mesh):
    """NamedSharding tree matching input_specs' structure."""
    shp = SHAPES[shape_name]
    B, S, kind = shp["global_batch"], shp["seq_len"], shp["kind"]
    ns = lambda spec: NamedSharding(mesh, spec)

    def extras(specs):
        out = {}
        if cfg.n_patches:
            out["patch_embeds"] = ns(_batch_spec(mesh, B, 2))
            out["mrope_positions"] = ns(P(None, *_batch_spec(mesh, B, 1)))
        if cfg.n_enc_layers:
            out["enc_embeds"] = ns(_batch_spec(mesh, B, 2))
        return out

    tok = ns(_batch_spec(mesh, B, 1))
    if kind == "train":
        sh = {"tokens": tok, "labels": tok,
              "weights": ns(_batch_spec(mesh, B, 0))}
        sh.update(extras(None))
        return sh
    if kind == "prefill":
        sh = {"tokens": tok}
        sh.update(extras(None))
        return sh
    caches = jax.tree.map(
        lambda s: ns(s), _cache_specs(cfg, B, S, mesh),
        is_leaf=lambda x: isinstance(x, P))
    return (tok, caches, ns(P()))
