"""Sparse-recovery LASSO workload (paper §5.4, Fig 14).

Lowers to a data-parallel ``ProblemSpec`` (h='l1'); every data-parallel
registry strategy runs the proximal (ISTA) path on it.  Canonical coded
scheme: encoded proximal gradient.  Metric: F1 of the recovered support
against the planted sparse ground truth — it needs the iterate, so the run
is driven in chunks (exact same trajectory for these stateless strategies)
and F1 is recorded at each chunk boundary.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.paper_native import PAPER_LASSO
from repro.data import lsq_dataset
from repro.runtime.strategies import ProblemSpec

from .base import (Preset, Workload, WorkloadRunResult, register_workload,
                   run_strategy_chunked)
from . import ground_truth as gt


@dataclasses.dataclass(frozen=True)
class LassoData:
    spec: ProblemSpec
    w_true: np.ndarray          # planted sparse signal (the F1 reference)
    w_star: np.ndarray          # FISTA optimum of the composite objective
    f_star: float
    lipschitz: float            # smoothness of the data-fit term, once


_CFG = PAPER_LASSO


@register_workload("lasso")
class Lasso(Workload):
    metric_name = "support_f1"
    metric_goal = "max"
    paper_config = _CFG
    canonical_coded = "coded-prox"
    # lam: the paper's 0.6 belongs to its (130k x 100k, sigma=40) scale; the
    # scaled presets keep the same sparsity regime (~8% support) with lam
    # re-tuned so ISTA recovers the support within the step budget.
    presets = {
        "smoke": Preset("smoke", m=16, k=12, steps=240, lam=0.08,
                        delay=_CFG.delay_model,
                        dims={"n": 512, "p": 256, "sparse": 20,
                              "noise": 0.4, "records": 8}),
        "bench": Preset("bench", m=32, k=24, steps=250, lam=0.08,
                        delay=_CFG.delay_model,
                        dims={"n": 1024, "p": 512, "sparse": 40,
                              "noise": 0.4, "records": 10}),
        "paper": Preset("paper", m=_CFG.m, k=80, steps=500, lam=_CFG.lam,
                        delay=_CFG.delay_model,
                        dims={"n": _CFG.n, "p": _CFG.p, "sparse": 7695,
                              "noise": 40.0, "records": 20}),
    }

    def build(self, preset) -> LassoData:
        ps = self.preset(preset)
        X, y, w_true = lsq_dataset(ps.dims["n"], ps.dims["p"],
                                   noise=ps.dims["noise"],
                                   sparse=ps.dims["sparse"], seed=ps.seed)
        spec = ProblemSpec(X=X, y=y, lam=ps.lam, h="l1")
        w_star = gt.lasso_fista(X, y, ps.lam)
        return LassoData(spec, w_true, w_star,
                         gt.lasso_objective(X, y, ps.lam, w_star),
                         spec.lipschitz())

    def supports(self, strategy):
        if strategy == "coded-lbfgs":
            return "encoded L-BFGS assumes the smooth ridge objective " \
                   "(paper Thm 4); l1 is non-smooth"
        if strategy == "async":
            return "the async stale-gradient baseline covers smooth " \
                   "objectives only"
        if strategy == "coded-bcd":
            return "bcd solves the unregularized lifted problem; it cannot " \
                   "express the l1 penalty"
        return None

    def _run(self, strategy, engine, ps, data: LassoData,
             **cfg) -> WorkloadRunResult:
        cfg.setdefault("k", ps.k)
        # same formula as strategies._auto_step, but from the cached L so
        # the chunked driver does not redo the O(p^3) eig once per chunk
        cfg.setdefault("step_size",
                       1.0 / (1.3 * data.lipschitz + ps.lam))
        steps = cfg.pop("steps", ps.steps)
        records = cfg.pop("records", ps.dims["records"])
        times, objective, recs, result = run_strategy_chunked(
            strategy, data.spec, engine, steps=steps, records=records, **cfg)
        metric_times = np.asarray([t for t, _ in recs])
        f1 = np.asarray([gt.support_f1(w, data.w_true) for _, w in recs])
        return WorkloadRunResult(
            workload=self.name, strategy=strategy, preset=ps.name,
            metric_name=self.metric_name,
            times=times, objective=objective,
            metric_times=metric_times, metric=f1, w=recs[-1][1],
            meta={**result.meta, "f_star": data.f_star,
                  "final_subopt_gap": float(max(objective[-1] - data.f_star,
                                                0.0)),
                  "support_size": int((np.abs(data.w_true) > 0).sum())})
