"""Workload protocol + registry (DESIGN.md §8).

A ``Workload`` is the layer between the paper's problem definitions
(``configs/paper_native.py``) and the execution engine (``repro.runtime``).
Each workload knows three things:

  1. **data** — how to synthesize (or load) its dataset deterministically,
     at one of three presets (``smoke``/``bench``/``paper``) scaled down
     from the paper's published dimensions;
  2. **lowering** — how to hand itself to the strategy layer: ridge/LASSO
     lower to a data-parallel ``ProblemSpec``, logistic lowers to the lifted
     BCD path (``make_lifted_problem`` + ``phi_logistic``), and matrix
     factorization runs ALS with every half-step dispatched as a coded ridge
     solve through the ``ClusterEngine``;
  3. **scoring** — its paper metric against a ground-truth reference
     (``workloads.ground_truth``): suboptimality gap, support-recovery F1,
     held-out classification error, test RMSE.

New workloads register with ``@register_workload`` and immediately become
runnable from ``python -m repro.workloads.run``, ``runtime.compare
--workload`` and the benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.configs.paper_native import QuadraticProblemConfig
from repro.obs.trace import span as _obs_span
from repro.runtime.engine import ClusterEngine, make_delay_model
from repro.runtime.strategies import (RunResult, get_strategy,
                                      json_safe_meta)

__all__ = [
    "Preset", "Workload", "WorkloadRunResult", "UnsupportedStrategy",
    "register_workload", "get_workload", "available_workloads",
    "sub_engine", "chunk_sizes", "run_strategy_chunked",
]


PRESET_NAMES = ("smoke", "bench", "paper")


class UnsupportedStrategy(ValueError):
    """A strategy that cannot run a given workload — carries the reason, so
    harnesses (compare, the workloads runner) can skip-with-reason instead
    of aborting the matrix."""


@dataclasses.dataclass(frozen=True)
class Preset:
    """One scale point of a workload: dims + cluster + solver budget.

    ``paper``-preset fields are the published §5 settings verbatim (via
    ``configs.paper_native``); ``bench``/``smoke`` keep the paper's ratios
    (k/m, lam regime, delay model) while shrinking dimensions to laptop/CI
    budgets.
    """
    name: str
    m: int                   # workers
    k: int                   # fastest-k the master waits for
    steps: int               # outer iteration budget
    lam: float
    delay: str               # delay-model registry name
    seed: int = 0
    dims: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class WorkloadRunResult:
    """One (workload, strategy, engine) cell: wall-clock-vs-metric trace.

    ``times``/``objective`` are the full-resolution optimizer trace;
    ``metric_times``/``metric`` are the paper-metric record points (equal
    length to ``times`` when the metric is derivable per step, coarser when
    it needs the iterate).  ``extras`` is JSON-safe workload-specific
    payload — e.g. MF's per-half-step active sets.
    """
    workload: str
    strategy: str
    preset: str
    metric_name: str
    times: np.ndarray
    objective: np.ndarray
    metric_times: np.ndarray
    metric: np.ndarray
    w: np.ndarray | None = None
    meta: dict = dataclasses.field(default_factory=dict)
    extras: dict = dataclasses.field(default_factory=dict)

    @property
    def final_metric(self) -> float:
        return float(self.metric[-1])

    @property
    def final_objective(self) -> float:
        return float(self.objective[-1])

    @property
    def wallclock(self) -> float:
        return float(self.times[-1])

    def to_record(self) -> dict:
        """JSON-serializable record (iterate omitted)."""
        # np.asarray().tolist() converts whole traces in C instead of a
        # per-element float() loop (same fix as RunResult.to_record)
        return {
            "workload": self.workload,
            "strategy": self.strategy,
            "preset": self.preset,
            "metric_name": self.metric_name,
            "final_metric": self.final_metric,
            "final_objective": self.final_objective,
            "wallclock_s": self.wallclock,
            "times": np.asarray(self.times, dtype=float).tolist(),
            "objective": np.asarray(self.objective, dtype=float).tolist(),
            "metric_times": np.asarray(self.metric_times,
                                       dtype=float).tolist(),
            "metric": np.asarray(self.metric, dtype=float).tolist(),
            "meta": json_safe_meta(self.meta),
            "extras": self.extras,
        }


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_WORKLOADS: dict[str, type["Workload"]] = {}


def register_workload(name: str):
    def deco(cls):
        cls.name = name
        _WORKLOADS[name] = cls
        return cls
    return deco


def get_workload(name: str) -> "Workload":
    if name not in _WORKLOADS:
        raise KeyError(f"unknown workload '{name}'; have "
                       f"{available_workloads()}")
    return _WORKLOADS[name]()


def available_workloads() -> list[str]:
    return sorted(_WORKLOADS)


# ---------------------------------------------------------------------------
# Engine helpers
# ---------------------------------------------------------------------------

def sub_engine(engine: ClusterEngine, tag: int) -> ClusterEngine:
    """A fresh delay realization of the same cluster: identical delay model /
    size / overheads, seed offset by ``tag``.  Deterministic, so two
    strategies handed the same parent engine see the same sub-realizations
    (fair comparisons), yet no two chunks/half-steps share a draw."""
    return ClusterEngine(engine.delay_model, engine.m,
                         compute_time=engine.compute_time,
                         master_overhead=engine.master_overhead,
                         seed=engine.seed + 7919 * (tag + 1),
                         faults=engine.faults)


def chunk_sizes(steps: int, records: int) -> list[int]:
    """Split ``steps`` into ``records`` near-equal positive chunks."""
    records = max(1, min(int(records), int(steps)))
    base, extra = divmod(steps, records)
    return [base + (1 if i < extra else 0) for i in range(records)]


def run_strategy_chunked(strategy: str, spec, engine: ClusterEngine, *,
                         steps: int, records: int, w0=None, **cfg):
    """Drive a registry strategy in ``records`` chunks, threading the iterate.

    For stateless strategies (GD / prox / uncoded / replication) the iterate
    sequence is the same function of the realized masks as a single run —
    the chunking only exposes ``w_t`` at chunk boundaries, the hook
    workloads use for metrics that need the iterate (support F1) without
    touching the fused runners.  Note the realized SCHEDULE does depend on
    ``records``: each chunk draws a fresh delay realization via
    ``sub_engine``, and a stateful policy (e.g. ``AdversarialRotation``)
    restarts its sweep at each boundary.

    Returns (times, objective, record list of (elapsed, w), final RunResult).
    """
    times, objective, recs = [], [], []
    now = 0.0
    w = w0
    result: RunResult | None = None
    for c, chunk in enumerate(chunk_sizes(steps, records)):
        chunk_cfg = dict(cfg)
        if w is not None:
            chunk_cfg["w0"] = w
        with _obs_span("chunk", strategy=strategy, index=c, steps=chunk):
            result = get_strategy(strategy).run(spec, sub_engine(engine, c),
                                                steps=chunk, **chunk_cfg)
        times.extend((now + result.times).tolist())
        objective.extend(np.asarray(result.objective).tolist())
        now += result.wallclock
        w = np.asarray(result.w)
        recs.append((now, w))
    return np.asarray(times), np.asarray(objective), recs, result


# ---------------------------------------------------------------------------
# The protocol
# ---------------------------------------------------------------------------

class Workload:
    """One paper-§5 end-to-end workload.  Subclasses define the class
    attributes below plus ``build`` and ``_run``."""

    name = "?"
    metric_name = "?"
    metric_goal = "min"            # "min" | "max" — how to read the metric
    paper_config: QuadraticProblemConfig | None = None
    canonical_coded = "coded-gd"   # what the 'coded' alias resolves to
    presets: dict[str, Preset] = {}

    # -- presets ----------------------------------------------------------
    def preset(self, name: str | Preset) -> Preset:
        if isinstance(name, Preset):
            return name
        if name not in self.presets:
            raise KeyError(f"workload '{self.name}' has no preset '{name}'; "
                           f"have {sorted(self.presets)}")
        return self.presets[name]

    # -- data -------------------------------------------------------------
    def build(self, preset: str | Preset) -> Any:
        """Synthesize/load the dataset (and ground truth) for a preset.
        Deterministic given the preset's seed; reusable across strategies."""
        raise NotImplementedError

    # -- lowering + scoring ------------------------------------------------
    def supports(self, strategy: str) -> str | None:
        """None if ``strategy`` can run this workload, else the reason."""
        return None

    def resolve_strategy(self, strategy: str) -> str:
        """Map the generic 'coded' alias to this workload's canonical coded
        scheme (ridge -> coded-lbfgs, lasso -> coded-prox, ...)."""
        return self.canonical_coded if strategy == "coded" else strategy

    def default_engine(self, preset: str | Preset, *, delay: str | None = None,
                       seed: int | None = None) -> ClusterEngine:
        ps = self.preset(preset)
        return ClusterEngine(make_delay_model(delay or ps.delay), ps.m,
                             seed=ps.seed if seed is None else seed)

    def skip_reason(self, strategy: str) -> str | None:
        """The skip-with-reason message this workload would raise for
        ``strategy``, or None when the cell can run — lets planners
        (``repro.experiments.plan``) materialize skip cells up front with
        the exact message the record will carry."""
        try:
            self._resolve_checked(strategy)
        except UnsupportedStrategy as e:
            return str(e)
        return None

    def _resolve_checked(self, strategy: str) -> str:
        """Resolve the 'coded' alias and raise ``UnsupportedStrategy`` for
        unknown / unsupported strategies (shared by run and run_trials)."""
        from repro.runtime.strategies import available_strategies
        strategy = self.resolve_strategy(strategy)
        # every workload lowering speaks in registry strategy names, so a
        # typo becomes a skip-with-reason cell rather than a KeyError that
        # aborts a half-finished matrix
        if strategy not in available_strategies():
            raise UnsupportedStrategy(
                f"unknown strategy '{strategy}'; have "
                f"{available_strategies()} (or the 'coded' alias)")
        reason = self.supports(strategy)
        if reason is not None:
            raise UnsupportedStrategy(
                f"{strategy} cannot run workload '{self.name}': {reason}")
        return strategy

    def run(self, strategy: str, engine: ClusterEngine | None = None, *,
            preset: str | Preset = "smoke", data: Any = None,
            **cfg) -> WorkloadRunResult:
        """Run one strategy on this workload end-to-end and score it.

        Raises ``UnsupportedStrategy`` (with the reason) when the strategy
        cannot express this workload — harnesses turn that into a
        skip-with-reason cell.
        """
        strategy = self._resolve_checked(strategy)
        ps = self.preset(preset)
        if engine is None:
            engine = self.default_engine(ps)
        if data is None:
            data = self.build(ps)
        return self._run(strategy, engine, ps, data, **cfg)

    def run_trials(self, strategy: str, engine: ClusterEngine | None = None,
                   *, preset: str | Preset = "smoke", data: Any = None,
                   trials: int = 1, eval_every: int = 1,
                   placement: str = "vmap",
                   **cfg) -> list[WorkloadRunResult]:
        """``trials`` delay realizations of one cell (paper §5 Monte-Carlo
        protocol), one scored result per realization.

        The default drives ``run`` once per realization on
        ``engine.trial(r)`` — correct for every workload, including the
        chunked/ALS lowerings whose multi-dispatch structure cannot be
        vmapped (so ``placement`` is effectively ``'single'`` here whatever
        was requested).  Workloads whose lowering is a single strategy run
        (ridge) override this with the fused ``Strategy.run_batched`` path,
        where the whole realization stack is one compiled program, placed
        per ``placement`` (single / vmap / sharded).  ``eval_every`` is
        honored by the batched overrides; this sequential fallback records
        at full per-step resolution.
        """
        strategy = self._resolve_checked(strategy)
        ps = self.preset(preset)
        if engine is None:
            engine = self.default_engine(ps)
        if data is None:
            data = self.build(ps)
        return [self._run(strategy, engine.trial(r), ps, data, **dict(cfg))
                for r in range(trials)]

    def _run(self, strategy: str, engine: ClusterEngine, ps: Preset,
             data: Any, **cfg) -> WorkloadRunResult:
        raise NotImplementedError
