"""Experiment runner: workload x strategy (x delay) matrix -> JSON traces.

The paper's §5 evaluation protocol as one command: every requested workload
is built once per preset, every requested strategy runs on the SAME dataset
under the same cluster (shared engine seed -> comparable wall-clock), and
each cell emits its wall-clock-vs-paper-metric trace.  Strategies that
cannot express a workload become skip-with-reason records instead of
aborting the matrix.

    PYTHONPATH=src python -m repro.workloads.run \\
        --workload mf --preset smoke \\
        --strategies coded-lbfgs,replication,uncoded

``--strategies coded,...`` resolves 'coded' per workload (ridge ->
coded-lbfgs, lasso -> coded-prox, logistic -> coded-bcd, mf -> coded-lbfgs).
Outputs: ``<out>/workloads.json`` (full traces) and ``<out>/summary.csv``.
"""
from __future__ import annotations

import argparse
import csv
import json
import os
from typing import Sequence

from repro.runtime.engine import ClusterEngine, make_delay_model

from .base import (UnsupportedStrategy, available_workloads, get_workload)

__all__ = ["run_workload_matrix", "write_json", "write_summary_csv", "main"]


def run_workload_matrix(workloads: Sequence[str], strategies: Sequence[str],
                        *, preset: str = "smoke",
                        delays: Sequence[str] | None = None, seed: int = 0,
                        m: int | None = None, compute_time: float = 0.05,
                        **cfg) -> list[dict]:
    """Run every (workload, delay, strategy) cell; returns one record each.

    ``delays=None`` uses each workload's native paper delay model; ``m``
    overrides the preset's worker count.  Extra ``cfg`` (k=, encoder=,
    steps=, ...) is forwarded to every cell.
    """
    records = []
    for wl_name in workloads:
        wl = get_workload(wl_name)
        ps = wl.preset(preset)
        data = wl.build(ps)
        for delay in (delays or [ps.delay]):
            engine = ClusterEngine(make_delay_model(delay),
                                   ps.m if m is None else m,
                                   compute_time=compute_time, seed=seed)
            for strat in strategies:
                resolved = wl.resolve_strategy(strat)
                base = {"workload": wl.name, "strategy": resolved,
                        "delay": delay, "preset": ps.name, "seed": seed}
                cell_cfg = dict(cfg)
                if not resolved.startswith("coded"):
                    # --encoder targets the coded scheme; uncoded/replication
                    # keep their defining encoders.
                    cell_cfg.pop("encoder", None)
                try:
                    result = wl.run(strat, engine, preset=ps, data=data,
                                    **cell_cfg)
                except ValueError as e:
                    # UnsupportedStrategy, or a config clash (e.g. --m below
                    # the preset's k) — record the reason, keep the matrix
                    # going (same contract as the plain compare path)
                    if not isinstance(e, UnsupportedStrategy):
                        print(f"# skipping {resolved} x {delay}: {e}")
                    records.append({**base, "skipped": str(e),
                                    "metric_name": wl.metric_name})
                    continue
                rec = result.to_record()
                rec.update(delay=delay, seed=seed)
                records.append(rec)
    return records


def write_json(records: list[dict], path: str) -> None:
    with open(path, "w") as f:
        json.dump(records, f, indent=1)


def write_summary_csv(records: list[dict], path: str) -> None:
    """One row per cell: the paper-table view (final metric + wall-clock)."""
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["workload", "strategy", "delay", "preset", "metric_name",
                    "final_metric", "final_objective", "wallclock_s",
                    "skipped"])
        for r in records:
            if "skipped" in r:
                w.writerow([r["workload"], r["strategy"], r["delay"],
                            r["preset"], r.get("metric_name", ""), "", "", "",
                            r["skipped"]])
            else:
                w.writerow([r["workload"], r["strategy"], r["delay"],
                            r["preset"], r["metric_name"],
                            f"{r['final_metric']:.6g}",
                            f"{r['final_objective']:.6g}",
                            f"{r['wallclock_s']:.2f}", ""])


def main(argv: Sequence[str] | None = None) -> list[dict]:
    ap = argparse.ArgumentParser(
        prog="repro.workloads.run",
        description="paper-§5 workload zoo experiment runner")
    ap.add_argument("--workload", default="all",
                    help=f"comma list from {available_workloads()}, or 'all'")
    ap.add_argument("--preset", default="smoke",
                    choices=["smoke", "bench", "paper"])
    ap.add_argument("--strategies", default="coded,uncoded,replication",
                    help="comma list; 'coded' resolves per workload")
    ap.add_argument("--delays", default=None,
                    help="comma list of delay models (default: each "
                         "workload's native paper model)")
    ap.add_argument("--k", type=int, default=None,
                    help="fastest-k override (default: preset k)")
    ap.add_argument("--steps", type=int, default=None,
                    help="outer/inner step budget override")
    ap.add_argument("--encoder", default=None,
                    help="encoder override for the coded scheme")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="runs/workloads")
    ap.add_argument("--formats", default="json,csv")
    args = ap.parse_args(argv)

    workloads = (available_workloads() if args.workload == "all" else
                 [w.strip() for w in args.workload.split(",") if w.strip()])
    strategies = [s.strip() for s in args.strategies.split(",") if s.strip()]
    delays = ([d.strip() for d in args.delays.split(",") if d.strip()]
              if args.delays else None)
    cfg = {}
    if args.k is not None:
        cfg["k"] = args.k
    if args.steps is not None:
        cfg["steps"] = args.steps
    if args.encoder is not None:
        cfg["encoder"] = args.encoder

    records = run_workload_matrix(workloads, strategies, preset=args.preset,
                                  delays=delays, seed=args.seed, **cfg)

    os.makedirs(args.out, exist_ok=True)
    formats = {f.strip() for f in args.formats.split(",")}
    if "json" in formats:
        write_json(records, os.path.join(args.out, "workloads.json"))
    if "csv" in formats:
        write_summary_csv(records, os.path.join(args.out, "summary.csv"))

    print(f"{'workload':10s} {'strategy':14s} {'delay':12s} "
          f"{'metric':>12s} {'final':>10s} {'wallclock_s':>12s}")
    for r in records:
        if "skipped" in r:
            print(f"{r['workload']:10s} {r['strategy']:14s} "
                  f"{r['delay']:12s} {'skipped:':>12s} {r['skipped']}")
        else:
            print(f"{r['workload']:10s} {r['strategy']:14s} "
                  f"{r['delay']:12s} {r['metric_name']:>12s} "
                  f"{r['final_metric']:10.4g} {r['wallclock_s']:12.2f}")
    print(f"wrote {sorted(formats)} to {args.out}/")
    return records


if __name__ == "__main__":
    main()
