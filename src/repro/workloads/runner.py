"""Experiment runner: workload x strategy (x delay) matrix -> JSON traces.

The paper's §5 evaluation protocol as one command: every requested workload
is built once per preset, every requested strategy runs on the SAME dataset
under the same cluster (shared engine seed -> comparable wall-clock), and
each cell emits its wall-clock-vs-paper-metric trace.  Strategies that
cannot express a workload become skip-with-reason records instead of
aborting the matrix.

    PYTHONPATH=src python -m repro.workloads.run \\
        --workload mf --preset smoke \\
        --strategies coded-lbfgs,replication,uncoded

``--strategies coded,...`` resolves 'coded' per workload (ridge ->
coded-lbfgs, lasso -> coded-prox, logistic -> coded-bcd, mf -> coded-lbfgs).
Outputs: ``<out>/workloads.json`` (full traces) and ``<out>/summary.csv``.
"""
from __future__ import annotations

import argparse
import csv
import json
import os
from typing import Sequence

import numpy as np

from repro.runtime.engine import ClusterEngine, make_delay_model
from repro.runtime.strategies import (check_trials, json_safe_meta,
                                      summary_stats)

from .base import (UnsupportedStrategy, WorkloadRunResult,
                   available_workloads, get_workload)

__all__ = ["run_workload_matrix", "trials_record", "write_json",
           "write_summary_csv", "main"]


def trials_record(results: "list[WorkloadRunResult]", *,
                  delay: str, seed: int) -> dict:
    """Aggregate R per-realization workload results into ONE JSON record:
    stacked per-realization traces plus mean/p50/p95 wall-clock and metric
    summaries.  Scalar ``final_metric`` / ``final_objective`` /
    ``wallclock_s`` are across-trial means, so batched records drop into
    every single-trial consumer (summary CSV, tables)."""
    r0 = results[0]
    final_metric = [r.final_metric for r in results]
    final_obj = [r.final_objective for r in results]
    wallclock = [r.wallclock for r in results]
    return {
        "workload": r0.workload, "strategy": r0.strategy,
        "preset": r0.preset, "metric_name": r0.metric_name,
        "delay": delay, "seed": seed, "trials": len(results),
        "final_metric": float(np.mean(final_metric)),
        "final_objective": float(np.mean(final_obj)),
        "wallclock_s": float(np.mean(wallclock)),
        "summary": {"trials": len(results),
                    "wallclock_s": summary_stats(wallclock),
                    "final_metric": summary_stats(final_metric),
                    "final_objective": summary_stats(final_obj)},
        "times": [np.asarray(r.times, dtype=float).tolist()
                  for r in results],
        "objective": [np.asarray(r.objective, dtype=float).tolist()
                      for r in results],
        "metric_times": [np.asarray(r.metric_times, dtype=float).tolist()
                         for r in results],
        "metric": [np.asarray(r.metric, dtype=float).tolist()
                   for r in results],
        "extras": [r.extras for r in results],
        "meta": json_safe_meta(r0.meta),
    }


def run_workload_matrix(workloads: Sequence[str], strategies: Sequence[str],
                        *, preset: str = "smoke",
                        delays: Sequence[str] | None = None, seed: int = 0,
                        m: int | None = None, compute_time: float = 0.05,
                        trials: int = 1, eval_every: int = 1,
                        **cfg) -> list[dict]:
    """Run every (workload, delay, strategy) cell; returns one record each.

    ``delays=None`` uses each workload's native paper delay model; ``m``
    overrides the preset's worker count.  Extra ``cfg`` (k=, encoder=,
    steps=, ...) is forwarded to every cell.

    ``trials=R`` runs R delay realizations per cell (``Workload.run_trials``
    — a single compiled program where the lowering allows, sequential
    trial-seeded runs elsewhere); the cell's record then stacks the
    per-realization traces and carries mean/p50/p95 summaries.
    """
    records = []
    for wl_name in workloads:
        wl = get_workload(wl_name)
        ps = wl.preset(preset)
        # a bad trials/eval_every combination is a harness misconfiguration
        # — abort up front rather than emit a matrix of skipped cells
        check_trials(cfg.get("steps", ps.steps), trials, eval_every)
        data = wl.build(ps)
        for delay in (delays or [ps.delay]):
            engine = ClusterEngine(make_delay_model(delay),
                                   ps.m if m is None else m,
                                   compute_time=compute_time, seed=seed)
            for strat in strategies:
                resolved = wl.resolve_strategy(strat)
                base = {"workload": wl.name, "strategy": resolved,
                        "delay": delay, "preset": ps.name, "seed": seed}
                cell_cfg = dict(cfg)
                if not resolved.startswith("coded"):
                    # --encoder targets the coded scheme; uncoded/replication
                    # keep their defining encoders.
                    cell_cfg.pop("encoder", None)
                try:
                    if trials > 1:
                        results = wl.run_trials(strat, engine, preset=ps,
                                                data=data, trials=trials,
                                                eval_every=eval_every,
                                                **cell_cfg)
                        records.append({**base,
                                        **trials_record(results, delay=delay,
                                                        seed=seed)})
                        continue
                    result = wl.run(strat, engine, preset=ps, data=data,
                                    **cell_cfg)
                except ValueError as e:
                    # UnsupportedStrategy, or a config clash (e.g. --m below
                    # the preset's k) — record the reason, keep the matrix
                    # going (same contract as the plain compare path)
                    if not isinstance(e, UnsupportedStrategy):
                        print(f"# skipping {resolved} x {delay}: {e}")
                    records.append({**base, "skipped": str(e),
                                    "metric_name": wl.metric_name})
                    continue
                rec = result.to_record()
                rec.update(delay=delay, seed=seed)
                records.append(rec)
    return records


def write_json(records: list[dict], path: str) -> None:
    with open(path, "w") as f:
        json.dump(records, f, indent=1)


def write_summary_csv(records: list[dict], path: str) -> None:
    """One row per cell: the paper-table view (final metric + wall-clock)."""
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["workload", "strategy", "delay", "preset", "metric_name",
                    "final_metric", "final_objective", "wallclock_s",
                    "skipped"])
        for r in records:
            if "skipped" in r:
                w.writerow([r["workload"], r["strategy"], r["delay"],
                            r["preset"], r.get("metric_name", ""), "", "", "",
                            r["skipped"]])
            else:
                w.writerow([r["workload"], r["strategy"], r["delay"],
                            r["preset"], r["metric_name"],
                            f"{r['final_metric']:.6g}",
                            f"{r['final_objective']:.6g}",
                            f"{r['wallclock_s']:.2f}", ""])


def main(argv: Sequence[str] | None = None) -> list[dict]:
    ap = argparse.ArgumentParser(
        prog="repro.workloads.run",
        description="paper-§5 workload zoo experiment runner")
    ap.add_argument("--workload", default="all",
                    help=f"comma list from {available_workloads()}, or 'all'")
    ap.add_argument("--preset", default="smoke",
                    choices=["smoke", "bench", "paper"])
    ap.add_argument("--strategies", default="coded,uncoded,replication",
                    help="comma list; 'coded' resolves per workload")
    ap.add_argument("--delays", default=None,
                    help="comma list of delay models (default: each "
                         "workload's native paper model)")
    ap.add_argument("--k", type=int, default=None,
                    help="fastest-k override (default: preset k)")
    ap.add_argument("--steps", type=int, default=None,
                    help="outer/inner step budget override")
    ap.add_argument("--encoder", default=None,
                    help="encoder override for the coded scheme")
    ap.add_argument("--trials", type=int, default=1,
                    help="delay realizations per cell (one compiled program "
                         "where the lowering allows; records carry "
                         "per-realization traces + mean/p50/p95 summaries)")
    ap.add_argument("--eval-every", type=int, default=1,
                    help="record stride inside batched runs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="runs/workloads")
    ap.add_argument("--formats", default="json,csv")
    args = ap.parse_args(argv)

    workloads = (available_workloads() if args.workload == "all" else
                 [w.strip() for w in args.workload.split(",") if w.strip()])
    strategies = [s.strip() for s in args.strategies.split(",") if s.strip()]
    delays = ([d.strip() for d in args.delays.split(",") if d.strip()]
              if args.delays else None)
    cfg = {}
    if args.k is not None:
        cfg["k"] = args.k
    if args.steps is not None:
        cfg["steps"] = args.steps
    if args.encoder is not None:
        cfg["encoder"] = args.encoder

    records = run_workload_matrix(workloads, strategies, preset=args.preset,
                                  delays=delays, seed=args.seed,
                                  trials=args.trials,
                                  eval_every=args.eval_every, **cfg)

    os.makedirs(args.out, exist_ok=True)
    formats = {f.strip() for f in args.formats.split(",")}
    if "json" in formats:
        write_json(records, os.path.join(args.out, "workloads.json"))
    if "csv" in formats:
        write_summary_csv(records, os.path.join(args.out, "summary.csv"))

    print(f"{'workload':10s} {'strategy':14s} {'delay':12s} "
          f"{'metric':>12s} {'final':>10s} {'wallclock_s':>12s}")
    for r in records:
        if "skipped" in r:
            print(f"{r['workload']:10s} {r['strategy']:14s} "
                  f"{r['delay']:12s} {'skipped:':>12s} {r['skipped']}")
        else:
            print(f"{r['workload']:10s} {r['strategy']:14s} "
                  f"{r['delay']:12s} {r['metric_name']:>12s} "
                  f"{r['final_metric']:10.4g} {r['wallclock_s']:12.2f}")
    print(f"wrote {sorted(formats)} to {args.out}/")
    return records


if __name__ == "__main__":
    main()
