"""Workload experiment runner CLI — legacy front-end (DESIGN.md §10).

Historically this module owned the workload x strategy (x delay) cell loop;
it is now a thin shim that compiles its (unchanged) flags into a
``repro.experiments.ExperimentSpec`` and delegates to the unified ``plan ->
execute`` path.  Records, JSON and summary-CSV outputs are identical to
what this harness always produced; new code should use ``python -m
repro.experiments.run`` or the ``repro.experiments`` API directly.

    PYTHONPATH=src python -m repro.workloads.run \\
        --workload mf --preset smoke \\
        --strategies coded-lbfgs,replication,uncoded

``--strategies coded,...`` resolves 'coded' per workload (ridge ->
coded-lbfgs, lasso -> coded-prox, logistic -> coded-bcd, mf -> coded-lbfgs).
Outputs: ``<out>/workloads.json`` (full traces) and ``<out>/summary.csv``.
"""
from __future__ import annotations

import argparse
import os
from typing import Sequence

from repro.experiments import (DelayAxis, ExperimentSpec, ObsAxis,
                               PlacementAxis, ProblemAxis, StrategyAxis,
                               TrialsAxis, execute, plan, print_table,
                               trials_record, write_json, write_metrics_csv,
                               write_summary_csv)
from repro.workloads.base import available_workloads

__all__ = ["run_workload_matrix", "trials_record", "write_json",
           "write_summary_csv", "write_metrics_csv", "main"]


def run_workload_matrix(workloads: Sequence[str], strategies: Sequence[str],
                        *, preset: str = "smoke",
                        delays: Sequence[str] | None = None, seed: int = 0,
                        m: int | None = None, compute_time: float = 0.05,
                        trials: int = 1, eval_every: int = 1,
                        placement: str = "vmap",
                        obs: ObsAxis | None = None, **cfg) -> list[dict]:
    """Run every (workload, delay, strategy) cell; returns one record each.

    Legacy API shim over ``repro.experiments``: ``delays=None`` uses each
    workload's native paper delay model, ``m`` overrides the preset's
    worker count, and extra ``cfg`` (``k=``, ``encoder=``, ``steps=``, and
    any strategy kwargs) is forwarded to every cell.  ``trials=R`` stacks R
    delay realizations per cell (fused into one compiled program where the
    lowering allows, with ``placement`` choosing single/vmap/sharded
    execution) and the record carries mean/p50/p95 summaries.  ``obs`` is
    the optional observability axis (trace export / per-cell metrics);
    default None keeps the legacy record schema byte-for-byte.
    """
    cfg = dict(cfg)
    k = cfg.pop("k", None)
    steps = cfg.pop("steps", None)
    encoder = cfg.pop("encoder", None)
    spec = ExperimentSpec(
        problems=tuple(ProblemAxis.from_workload(w, preset)
                       for w in workloads),
        strategies=tuple(StrategyAxis(name=s, encoder=encoder, k=k,
                                      options=tuple(cfg.items()))
                         for s in strategies),
        delays=DelayAxis(delays=tuple(delays or ()), m=m,
                         compute_time=compute_time),
        trials=TrialsAxis(trials=trials, eval_every=eval_every, seed=seed),
        placement=PlacementAxis(mode=placement), steps=steps,
        obs=obs if obs is not None else ObsAxis())
    return execute(plan(spec)).records


def main(argv: Sequence[str] | None = None) -> list[dict]:
    ap = argparse.ArgumentParser(
        prog="repro.workloads.run",
        description="paper-§5 workload zoo experiment runner (legacy "
                    "front-end over repro.experiments)")
    ap.add_argument("--workload", default="all",
                    help=f"comma list from {available_workloads()}, or 'all'")
    ap.add_argument("--preset", default="smoke",
                    choices=["smoke", "bench", "paper"])
    ap.add_argument("--strategies", default="coded,uncoded,replication",
                    help="comma list; 'coded' resolves per workload")
    ap.add_argument("--delays", default=None,
                    help="comma list of delay models (default: each "
                         "workload's native paper model)")
    ap.add_argument("--k", type=int, default=None,
                    help="fastest-k override (default: preset k)")
    ap.add_argument("--steps", type=int, default=None,
                    help="outer/inner step budget override")
    ap.add_argument("--encoder", default=None,
                    help="encoder override for the coded scheme")
    ap.add_argument("--trials", type=int, default=1,
                    help="delay realizations per cell (one compiled program "
                         "where the lowering allows; records carry "
                         "per-realization traces + mean/p50/p95 summaries)")
    ap.add_argument("--eval-every", type=int, default=1,
                    help="record stride inside batched runs (0 = final "
                         "objective only)")
    ap.add_argument("--placement", default="vmap",
                    choices=["single", "vmap", "sharded"],
                    help="how the realization axis executes (with --trials)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="runs/workloads")
    ap.add_argument("--formats", default="json,csv")
    ap.add_argument("--trace", default=None, metavar="PREFIX",
                    help="write <PREFIX>.jsonl + <PREFIX>.perfetto.json "
                         "straggler traces (repro.obs)")
    ap.add_argument("--metrics-out", default=None, metavar="CSV",
                    help="write the per-cell obs metrics CSV")
    args = ap.parse_args(argv)

    workloads = (available_workloads() if args.workload == "all" else
                 [w.strip() for w in args.workload.split(",") if w.strip()])
    strategies = [s.strip() for s in args.strategies.split(",") if s.strip()]
    delays = ([d.strip() for d in args.delays.split(",") if d.strip()]
              if args.delays else None)
    cfg = {}
    if args.k is not None:
        cfg["k"] = args.k
    if args.steps is not None:
        cfg["steps"] = args.steps
    if args.encoder is not None:
        cfg["encoder"] = args.encoder

    obs = (ObsAxis(trace=args.trace, metrics=bool(args.metrics_out))
           if (args.trace or args.metrics_out) else None)
    records = run_workload_matrix(workloads, strategies, preset=args.preset,
                                  delays=delays, seed=args.seed,
                                  trials=args.trials,
                                  eval_every=args.eval_every,
                                  placement=args.placement, obs=obs, **cfg)

    os.makedirs(args.out, exist_ok=True)
    formats = {f.strip() for f in args.formats.split(",")}
    if "json" in formats:
        write_json(records, os.path.join(args.out, "workloads.json"))
    if "csv" in formats:
        write_summary_csv(records, os.path.join(args.out, "summary.csv"))
    if args.metrics_out:
        d = os.path.dirname(args.metrics_out)
        if d:
            os.makedirs(d, exist_ok=True)
        write_metrics_csv(records, args.metrics_out)
        print(f"wrote obs metrics to {args.metrics_out}")
    print_table(records)
    print(f"wrote {sorted(formats)} to {args.out}/")
    return records


if __name__ == "__main__":
    main()
