"""``python -m repro.workloads.run`` — CLI entry for the workload zoo.

Thin shim over ``repro.workloads.runner`` (which holds the machinery), so
the module path in the docs stays short.
"""
from .runner import main

if __name__ == "__main__":
    main()
