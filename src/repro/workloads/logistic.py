"""rcv1-style logistic regression workload (paper §5.3, Figs 10-13).

Lowers to the lifted MODEL-parallel path: the feature dimension is encoded
(``make_lifted_problem`` + ``phi_logistic``) and every scheme — coded,
uncoded, replication — is a choice of feature encoder running encoded block
coordinate descent.  Data-parallel strategies (coded-gd/prox/lbfgs, async)
implement the quadratic loss only, so they are skip-with-reason here.

Metric: held-out classification error.  It needs the decoded iterate
w = S^T v, so the schedule is driven in chunks (v threaded through, one
fresh delay realization per chunk) and the error is recorded at each chunk
boundary.  The objective trace is the train logistic loss phi from the
fused runner, at full per-iteration resolution.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.configs.paper_native import PAPER_LOGISTIC
from repro.core.encoding import make_encoder
from repro.core.model_parallel import make_lifted_problem, phi_logistic
from repro.data import logreg_dataset
from repro.runtime.engine import FastestK
from repro.runtime.runners import scan_bcd

from .base import (Preset, Workload, WorkloadRunResult, register_workload,
                   chunk_sizes, sub_engine)
from . import ground_truth as gt


@dataclasses.dataclass(frozen=True)
class LogisticData:
    X_train: np.ndarray
    y_train: np.ndarray
    X_test: np.ndarray
    y_test: np.ndarray


_CFG = PAPER_LOGISTIC

# strategy name -> (encoder registry name, redundancy beta)
_ENCODER_OF = {
    "coded-bcd": ("hadamard", 2.0),
    "uncoded": ("uncoded", 1.0),
    "replication": ("replication", 2.0),
}

_DATA_PARALLEL = ("coded-gd", "coded-prox", "coded-lbfgs", "async")


@register_workload("logistic")
class Logistic(Workload):
    metric_name = "test_error"
    metric_goal = "min"
    paper_config = _CFG
    canonical_coded = "coded-bcd"
    presets = {
        "smoke": Preset("smoke", m=8, k=6, steps=80, lam=_CFG.lam,
                        delay=_CFG.delay_model,
                        dims={"n": 512, "p": 128, "density": 0.1,
                              "noise": 0.7, "test_frac": 0.2,
                              "records": 8}),
        "bench": Preset("bench", m=16, k=12, steps=120, lam=_CFG.lam,
                        delay=_CFG.delay_model,
                        dims={"n": 640, "p": 256, "density": 0.1,
                              "noise": 0.7, "test_frac": 0.2,
                              "records": 10}),
        # published §5.3 dims; k = 80 is the paper's middle cell
        "paper": Preset("paper", m=_CFG.m, k=80, steps=300, lam=_CFG.lam,
                        delay=_CFG.delay_model,
                        dims={"n": _CFG.n, "p": _CFG.p, "density": 0.1,
                              "noise": 0.3, "test_frac": 0.2,
                              "records": 20}),
    }

    def build(self, preset) -> LogisticData:
        ps = self.preset(preset)
        n, p = ps.dims["n"], ps.dims["p"]
        n_test = int(round(n * ps.dims["test_frac"]))
        X, labels, _ = logreg_dataset(n, p, density=ps.dims["density"],
                                      noise=ps.dims["noise"], seed=ps.seed)
        return LogisticData(X[:-n_test], labels[:-n_test],
                            X[-n_test:], labels[-n_test:])

    def supports(self, strategy):
        if strategy in _DATA_PARALLEL:
            return "logistic lowers to the lifted BCD path; the " \
                   "data-parallel strategies implement the quadratic loss " \
                   "only"
        if strategy not in _ENCODER_OF:
            return f"no BCD lowering for '{strategy}'"
        return None

    def _run(self, strategy, engine, ps, data: LogisticData,
             **cfg) -> WorkloadRunResult:
        X, labels = data.X_train, data.y_train
        n, p = X.shape
        enc_default, beta_default = _ENCODER_OF[strategy]
        enc = make_encoder(cfg.pop("encoder", enc_default), p,
                           beta=cfg.pop("beta", beta_default),
                           seed=cfg.pop("encoder_seed", 0)).with_workers(
                               engine.m)
        val, grad = phi_logistic(labels)
        prob = make_lifted_problem(X, enc, engine.m, val, grad)
        # Hessian of phi is X^T D X / n with D <= 1/4; lifting multiplies the
        # spectral bound by beta (||S||^2 = beta for tight frames).
        L = float(np.linalg.eigvalsh(X.T @ X / n).max()) / 4.0
        step_size = cfg.pop("step_size", None) or 0.9 / (L * float(enc.beta))
        k = cfg.pop("k", ps.k)
        policy = cfg.pop("policy", None) or FastestK(k)
        steps = cfg.pop("steps", ps.steps)
        records = cfg.pop("records", ps.dims["records"])

        v = jnp.zeros((engine.m, prob.XS.shape[-1]), jnp.float32)
        times, objective, metric_times, metric = [], [], [], []
        mean_active, now = [], 0.0
        for c, chunk in enumerate(chunk_sizes(steps, records)):
            sched = sub_engine(engine, c).sample_schedule(chunk, policy)
            v, tr = scan_bcd(prob, jnp.asarray(sched.masks), step_size, v)
            times.extend((now + sched.times).tolist())
            # tr[t+1] = phi AFTER commit t — aligns with sched.times
            objective.extend(np.asarray(tr)[1:].tolist())
            now += float(sched.times[-1])
            w = np.asarray(enc.decode_t(np.asarray(v).reshape(-1, 1)))[:, 0]
            metric_times.append(now)
            metric.append(gt.classification_error(data.X_test, data.y_test,
                                                  w))
            mean_active.append(float(sched.masks.sum(1).mean()))
        return WorkloadRunResult(
            workload=self.name, strategy=strategy, preset=ps.name,
            metric_name=self.metric_name,
            times=np.asarray(times), objective=np.asarray(objective),
            metric_times=np.asarray(metric_times), metric=np.asarray(metric),
            w=w,
            meta={"encoder": enc.name, "beta": float(enc.beta),
                  "step_size": float(step_size), "k": k,
                  "objective": "train logistic loss phi",
                  "train_error": gt.classification_error(X, labels, w),
                  "mean_active": float(np.mean(mean_active))})
