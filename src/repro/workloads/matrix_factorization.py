"""Matrix-factorization workload: MovieLens-protocol alternating coded
least squares (paper §5.2, Tables 2-3).

ALS over biased factors ``[U | bu]``, ``[V | bv]`` (ratings centered at
3.0): each half-step is ONE joint ridge regression over every observed
rating, lowered to a data-parallel ``ProblemSpec`` and dispatched through
the strategy registry — so every half-step routes through the
``ClusterEngine`` with a FRESH delay realization, exactly like the paper's
coded L-BFGS inner solver on EC2.  The result trace records the realized
per-iteration active sets of every half-step (``extras['half_steps']``).

Metric: held-out (test) RMSE after each half-step; the objective trace is
the penalized ALS objective, which warm-started monotone inner solvers
decrease monotonically under full participation.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.paper_native import PAPER_MF
from repro.data import mf_ratings_dataset
from repro.runtime.strategies import ProblemSpec, get_strategy

from .base import (Preset, Workload, WorkloadRunResult, register_workload,
                   sub_engine)
from . import ground_truth as gt


@dataclasses.dataclass(frozen=True)
class MFData:
    R: np.ndarray
    train: np.ndarray
    test: np.ndarray


_CFG = PAPER_MF


def _half_step_design(Rc, mask, fixed, side):
    """Joint ridge design for one ALS half-step, vectorized.

    One row per observed training rating; solving side ``side`` ('u'|'v')
    with the other side ``fixed`` = (n_other, rank+1) ``[factors | bias]``
    held constant.  The fixed bias moves into the target, so the LS solution
    is the exact biased-ALS update.  Returns (A, target).
    """
    rank = fixed.shape[1] - 1
    idx = np.argwhere(mask)                              # (nobs, 2) = (i, j)
    ent = idx[:, 0] if side == "u" else idx[:, 1]
    oth = idx[:, 1] if side == "u" else idx[:, 0]
    n_ent = mask.shape[0] if side == "u" else mask.shape[1]
    nobs = idx.shape[0]
    cells = nobs * n_ent * (rank + 1)
    if cells > 500_000_000:     # ~2 GiB of float32 — refuse before the OOM
        raise MemoryError(
            f"dense joint ALS design would be {nobs} x {n_ent * (rank + 1)} "
            f"(~{cells * 4 / 2**30:.0f} GiB); the 'paper' preset records the "
            f"published protocol — run 'smoke'/'bench', or shrink "
            f"users/movies/density")
    feat = np.concatenate([fixed[oth, :rank], np.ones((nobs, 1))], axis=1)
    targ = Rc[idx[:, 0], idx[:, 1]] - fixed[oth, rank]
    A = np.zeros((nobs, n_ent * (rank + 1)), np.float32)
    cols = ent[:, None] * (rank + 1) + np.arange(rank + 1)[None, :]
    A[np.arange(nobs)[:, None], cols] = feat
    return A, targ.astype(np.float32)


@register_workload("mf")
class MatrixFactorization(Workload):
    metric_name = "test_rmse"
    metric_goal = "min"
    paper_config = _CFG
    canonical_coded = "coded-lbfgs"
    # Preset.steps = inner solver iterations per half-step; dims['epochs']
    # counts full (u, v) alternations.
    presets = {
        "smoke": Preset("smoke", m=8, k=6, steps=12, lam=0.3,
                        delay=_CFG.delay_model,
                        dims={"users": 48, "movies": 36, "rank": 3,
                              "density": 0.25, "epochs": 2}),
        "bench": Preset("bench", m=8, k=4, steps=15, lam=0.3,
                        delay=_CFG.delay_model,
                        dims={"users": 120, "movies": 90, "rank": 4,
                              "density": 0.08, "epochs": 2}),
        # published protocol: MovieLens-1M dims, p=15 embedding, m=24.
        # Reference settings — the dense joint-design builder targets
        # smoke/bench scale and refuses (clear MemoryError) at these dims.
        "paper": Preset("paper", m=_CFG.m, k=12, steps=25, lam=_CFG.lam,
                        delay=_CFG.delay_model,
                        dims={"users": 6040, "movies": 3706, "rank": 15,
                              "density": 0.045, "epochs": 10}),
    }

    def build(self, preset) -> MFData:
        ps = self.preset(preset)
        R, train, test = mf_ratings_dataset(
            ps.dims["users"], ps.dims["movies"], rank=ps.dims["rank"],
            density=ps.dims["density"], seed=ps.seed)
        return MFData(R, train, test)

    def supports(self, strategy):
        if strategy == "coded-prox":
            return "the ALS half-steps are ridge solves (l2); coded-prox " \
                   "requires l1"
        if strategy == "coded-bcd":
            return "bcd returns lifted block parameters, not the ridge " \
                   "iterate the ALS outer loop needs"
        if strategy == "async":
            return "each ALS half-step is a fresh problem; the async " \
                   "per-arrival stream assumes one persistent problem"
        return None

    def _run(self, strategy, engine, ps, data: MFData,
             **cfg) -> WorkloadRunResult:
        rank = ps.dims["rank"]
        epochs = cfg.pop("epochs", ps.dims["epochs"])
        inner_steps = cfg.pop("steps", ps.steps)
        lam = cfg.pop("lam", ps.lam)
        cfg.setdefault("k", ps.k)

        users, movies = data.R.shape
        rng = np.random.default_rng(ps.seed + 1)
        Ub = np.concatenate([rng.standard_normal((users, rank)) * 0.1,
                             np.zeros((users, 1))], axis=1).astype(np.float32)
        Vb = np.concatenate([rng.standard_normal((movies, rank)) * 0.1,
                             np.zeros((movies, 1))], axis=1).astype(np.float32)
        Rc = data.R - 3.0

        def predict():
            return (3.0 + Ub[:, :rank] @ Vb[:, :rank].T
                    + Ub[:, rank:] + Vb[:, rank:].T)

        times, objective, metric, half_steps = [], [], [], []
        now = 0.0
        step = 0
        for epoch in range(epochs):
            for side in ("u", "v"):
                fixed = Vb if side == "u" else Ub
                A, targ = _half_step_design(Rc, data.train, fixed, side)
                spec = ProblemSpec(X=A, y=targ, lam=lam, h="l2")
                w0 = (Ub if side == "u" else Vb).reshape(-1)
                res = get_strategy(strategy).run(
                    spec, sub_engine(engine, step), steps=inner_steps,
                    w0=w0, **dict(cfg))
                w = np.asarray(res.w, np.float32).reshape(-1, rank + 1)
                if side == "u":
                    Ub = w
                else:
                    Vb = w
                t0, now = now, now + res.wallclock
                pred = predict()
                # penalized ALS objective: fit + l2 on BOTH factor blocks —
                # constant in the fixed side, so exact/monotone inner solves
                # make it non-increasing across half-steps.
                fit = 0.5 * np.sum((pred[data.train]
                                    - data.R[data.train]) ** 2) / A.shape[0]
                als_obj = float(fit + 0.5 * lam * (np.sum(Ub ** 2)
                                                   + np.sum(Vb ** 2)))
                train_rmse = gt.masked_rmse(pred, data.R, data.train)
                test_rmse = gt.masked_rmse(pred, data.R, data.test)
                times.append(now)
                objective.append(als_obj)
                metric.append(test_rmse)
                half_steps.append({
                    "epoch": epoch, "side": side,
                    "t_start": float(t0), "t_end": float(now),
                    "active_sets": [ev.active.tolist()
                                    for ev in res.schedule.events],
                    "train_rmse": train_rmse, "test_rmse": test_rmse,
                    "als_objective": als_obj,
                })
                step += 1
        times = np.asarray(times)
        return WorkloadRunResult(
            workload=self.name, strategy=strategy, preset=ps.name,
            metric_name=self.metric_name,
            times=times, objective=np.asarray(objective),
            metric_times=times, metric=np.asarray(metric),
            w=np.concatenate([Ub.reshape(-1), Vb.reshape(-1)]),
            meta={"encoder": res.meta.get("encoder", ""),
                  "rank": rank, "epochs": epochs,
                  "inner_steps": inner_steps, "lam": lam,
                  "train_rmse": half_steps[-1]["train_rmse"],
                  "objective": "penalized ALS objective"},
            extras={"half_steps": half_steps})
