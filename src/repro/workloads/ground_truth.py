"""Ground-truth solvers + paper metrics for the workload zoo (§5).

Every workload scores itself against a reference computed HERE, on the host,
with dense numpy — no sklearn, no coded machinery:

  * ridge     — closed-form normal-equations optimum (paper Fig 7 plots
                suboptimality against it);
  * LASSO     — high-precision FISTA on the composite objective, plus the
                support-recovery F1 of Fig 14;
  * logistic  — damped Newton on the unregularized logistic loss (the lifted
                BCD problem's exact-optimum family), plus held-out
                classification error (Figs 10-13);
  * MF        — exact alternating ridge (per-entity closed form) as the
                reference test-RMSE for Tables 2-3.

These run at ``smoke``/``bench`` scales (dense solves); ``paper``-preset
callers should expect them to be expensive and can pass ``iters`` down.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "ridge_solution", "ridge_objective", "lasso_fista", "lasso_objective",
    "logistic_newton", "logistic_objective", "classification_error",
    "support_f1", "masked_rmse", "als_reference",
]


# ---------------------------------------------------------------------------
# Ridge
# ---------------------------------------------------------------------------

def ridge_objective(X, y, lam: float, w) -> float:
    """f(w) = 1/(2n)||Xw - y||^2 + lam/2 ||w||^2 — the repo's l2 convention
    (matches ``core.data_parallel.original_objective`` with h='l2')."""
    n = X.shape[0]
    r = X @ w - y
    return float(0.5 * r @ r / n + 0.5 * lam * w @ w)


def ridge_solution(X, y, lam: float) -> np.ndarray:
    """Closed-form ridge optimum (X^T X / n + lam I)^-1 X^T y / n."""
    n, p = X.shape
    return np.linalg.solve(X.T @ X / n + lam * np.eye(p), X.T @ y / n)


# ---------------------------------------------------------------------------
# LASSO
# ---------------------------------------------------------------------------

def lasso_objective(X, y, lam: float, w) -> float:
    """f(w) = 1/(2n)||Xw - y||^2 + lam ||w||_1."""
    n = X.shape[0]
    r = X @ w - y
    return float(0.5 * r @ r / n + lam * np.abs(w).sum())


def lasso_fista(X, y, lam: float, *, iters: int = 4000,
                tol: float = 1e-12) -> np.ndarray:
    """High-precision FISTA reference solve of the composite objective."""
    n, p = X.shape
    L = float(np.linalg.eigvalsh(X.T @ X / n).max())
    step = 1.0 / L
    w = np.zeros(p)
    z = w.copy()
    t = 1.0
    f_prev = lasso_objective(X, y, lam, w)
    for _ in range(iters):
        g = X.T @ (X @ z - y) / n
        v = z - step * g
        w_new = np.sign(v) * np.maximum(np.abs(v) - step * lam, 0.0)
        t_new = 0.5 * (1.0 + np.sqrt(1.0 + 4.0 * t * t))
        z = w_new + ((t - 1.0) / t_new) * (w_new - w)
        w, t = w_new, t_new
        f = lasso_objective(X, y, lam, w)
        if abs(f_prev - f) < tol * max(1.0, abs(f)):
            break
        f_prev = f
    return w


def support_f1(w_hat, w_true, tol: float = 1e-3) -> float:
    """F1 of the recovered support {|w_i| > tol} vs the true support."""
    nz_hat = np.abs(np.asarray(w_hat)) > tol
    nz_true = np.abs(np.asarray(w_true)) > 0
    tp = float((nz_hat & nz_true).sum())
    prec = tp / max(nz_hat.sum(), 1)
    rec = tp / max(nz_true.sum(), 1)
    return 2 * prec * rec / max(prec + rec, 1e-9)


# ---------------------------------------------------------------------------
# Logistic
# ---------------------------------------------------------------------------

def logistic_objective(X, labels, w) -> float:
    """phi(Xw) = mean log(1 + exp(-l_i x_i^T w)), labels in {-1, +1} —
    identical to ``core.model_parallel.phi_logistic``'s value."""
    z = np.asarray(X) @ np.asarray(w)
    return float(np.mean(np.logaddexp(0.0, -np.asarray(labels) * z)))


def logistic_newton(X, labels, *, iters: int = 50, ridge: float = 1e-8,
                    tol: float = 1e-10) -> np.ndarray:
    """Damped-Newton minimizer of the unregularized logistic loss.

    ``ridge`` is a tiny Hessian jitter for conditioning only (the data the
    logistic workload generates is non-separable, so the minimizer is
    finite).  Halves the step until the objective decreases.
    """
    X = np.asarray(X, np.float64)
    l = np.asarray(labels, np.float64)
    n, p = X.shape
    w = np.zeros(p)
    f = logistic_objective(X, l, w)
    for _ in range(iters):
        z = X @ w
        s = 0.5 * (1.0 - np.tanh(0.5 * l * z))   # sigma(-l z), overflow-safe
        g = -(X.T @ (l * s)) / n
        d = s * (1.0 - s)                        # sigma'(l z)
        H = (X.T * d) @ X / n + ridge * np.eye(p)
        step = np.linalg.solve(H, g)
        alpha = 1.0
        while alpha > 1e-8:
            w_new = w - alpha * step
            f_new = logistic_objective(X, l, w_new)
            if f_new <= f:
                break
            alpha *= 0.5
        if abs(f - f_new) < tol * max(1.0, abs(f)):
            w = w_new
            break
        w, f = w_new, f_new
    return w


def classification_error(X, labels, w) -> float:
    """Fraction of sign disagreements — the paper's held-out error metric."""
    pred = np.sign(np.asarray(X) @ np.asarray(w))
    pred[pred == 0] = 1.0
    return float(np.mean(pred != np.asarray(labels)))


# ---------------------------------------------------------------------------
# Matrix factorization
# ---------------------------------------------------------------------------

def masked_rmse(pred, R, mask) -> float:
    return float(np.sqrt(np.mean((pred[mask] - R[mask]) ** 2)))


def als_reference(R, train, test, *, rank: int = 4, lam: float = 0.3,
                  epochs: int = 8, seed: int = 1):
    """Exact (per-entity closed-form ridge) alternating least squares.

    Centers at 3.0 and fits biased factors ``[U | bu]``, ``[V | bv]`` like
    the MF workload; the reference every coded inner solver is judged
    against.  Returns (train_rmse, test_rmse).
    """
    users, movies = R.shape
    rng = np.random.default_rng(seed)
    Ub = np.concatenate([rng.standard_normal((users, rank)) * 0.1,
                         np.zeros((users, 1))], axis=1)
    Vb = np.concatenate([rng.standard_normal((movies, rank)) * 0.1,
                         np.zeros((movies, 1))], axis=1)
    Rc = R - 3.0
    for _ in range(epochs):
        for side in ("u", "v"):
            fixed = Vb if side == "u" else Ub
            mask = train if side == "u" else train.T
            targ = Rc if side == "u" else Rc.T
            out = Ub if side == "u" else Vb
            F = np.concatenate([fixed[:, :rank], np.ones((fixed.shape[0], 1))],
                               axis=1)
            for i in range(out.shape[0]):
                obs = np.nonzero(mask[i])[0]
                if obs.size == 0:
                    continue
                Fi = F[obs]
                nobs = mask.sum()  # global count: matches the joint solve
                A = Fi.T @ Fi / nobs + lam * np.eye(rank + 1)
                out[i] = np.linalg.solve(A, Fi.T @ targ[i, obs] / nobs)
    pred = 3.0 + Ub[:, :rank] @ Vb[:, :rank].T + Ub[:, rank:] + Vb[:, rank:].T
    return masked_rmse(pred, R, train), masked_rmse(pred, R, test)
