"""repro.workloads — the paper-§5 workload zoo (DESIGN.md §8).

The layer between problem definitions and the execution engine: each
workload (ridge, LASSO, logistic, matrix factorization) knows how to build
its dataset at ``smoke``/``bench``/``paper`` presets, lower itself to the
runtime's strategy layer, and score itself with its paper metric against a
ground-truth reference.

    from repro.workloads import get_workload
    result = get_workload("ridge").run("coded", preset="smoke")

CLI:  PYTHONPATH=src python -m repro.workloads.run \\
          --workload mf --preset smoke \\
          --strategies coded-lbfgs,replication,uncoded
"""
from .base import (Preset, UnsupportedStrategy, Workload, WorkloadRunResult,
                   available_workloads, get_workload, register_workload)
from . import ground_truth
# Importing the workload modules registers them.
from . import ridge, lasso, logistic, matrix_factorization  # noqa: F401

__all__ = [
    "Preset", "UnsupportedStrategy", "Workload", "WorkloadRunResult",
    "available_workloads", "get_workload", "register_workload",
    "ground_truth", "run_workload_matrix",
]


def __getattr__(name):
    # Lazy: importing .runner eagerly would shadow `python -m
    # repro.workloads.run` (runpy warns about double import).
    if name == "run_workload_matrix":
        from .runner import run_workload_matrix
        return run_workload_matrix
    raise AttributeError(name)
