"""Ridge regression workload (paper §5.1, Fig 7).

Lowers to a data-parallel ``ProblemSpec`` (h='l2') and runs any registry
strategy as-is; the canonical coded scheme is encoded L-BFGS, exactly the
paper's Fig-7 solver.  Metric: suboptimality gap f(w_t) - f* against the
closed-form ground truth — derivable from the objective trace, so the
metric trace has full per-iteration resolution.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.paper_native import PAPER_RIDGE
from repro.data import lsq_dataset
from repro.runtime.strategies import ProblemSpec, get_strategy

from .base import Preset, Workload, WorkloadRunResult, register_workload
from . import ground_truth as gt


@dataclasses.dataclass(frozen=True)
class RidgeData:
    spec: ProblemSpec
    w_star: np.ndarray
    f_star: float


_CFG = PAPER_RIDGE


@register_workload("ridge")
class Ridge(Workload):
    metric_name = "subopt_gap"
    metric_goal = "min"
    paper_config = _CFG
    canonical_coded = "coded-lbfgs"
    presets = {
        "smoke": Preset("smoke", m=8, k=6, steps=40, lam=_CFG.lam,
                        delay=_CFG.delay_model,
                        dims={"n": 256, "p": 64, "noise": 1.0}),
        "bench": Preset("bench", m=_CFG.m, k=24, steps=40, lam=_CFG.lam,
                        delay=_CFG.delay_model,
                        dims={"n": 1024, "p": 512, "noise": 1.0}),
        # the published Fig-7 dimensions; k = 24 is the paper's middle cell
        "paper": Preset("paper", m=_CFG.m, k=24, steps=100, lam=_CFG.lam,
                        delay=_CFG.delay_model,
                        dims={"n": _CFG.n, "p": _CFG.p, "noise": 1.0}),
    }

    def build(self, preset) -> RidgeData:
        ps = self.preset(preset)
        X, y, _ = lsq_dataset(ps.dims["n"], ps.dims["p"],
                              noise=ps.dims["noise"], seed=ps.seed)
        spec = ProblemSpec(X=X, y=y, lam=ps.lam, h="l2")
        w_star = gt.ridge_solution(X, y, ps.lam)
        return RidgeData(spec, w_star, gt.ridge_objective(X, y, ps.lam,
                                                          w_star))

    def supports(self, strategy):
        if strategy in ("coded-prox",):
            return "coded-prox requires the l1 objective (use the lasso " \
                   "workload)"
        if strategy in ("coded-bcd",):
            return "bcd reports the unregularized lifted objective phi, " \
                   "not the ridge objective (use the logistic workload)"
        return None

    def _score(self, strategy, ps, data: RidgeData, result) -> \
            WorkloadRunResult:
        gap = np.maximum(np.asarray(result.objective) - data.f_star, 0.0)
        return WorkloadRunResult(
            workload=self.name, strategy=strategy, preset=ps.name,
            metric_name=self.metric_name,
            times=np.asarray(result.times),
            objective=np.asarray(result.objective),
            metric_times=np.asarray(result.times), metric=gap,
            w=result.w,
            meta={**result.meta, "f_star": data.f_star,
                  "final_rel_subopt": float(gap[-1] / max(abs(data.f_star),
                                                          1e-12))})

    @staticmethod
    def _cell_cfg(strategy, ps, cfg) -> tuple[int, dict]:
        cfg.setdefault("k", ps.k)
        if strategy == "async":
            cfg.pop("k", None)
        return cfg.pop("steps", ps.steps), cfg

    def _run(self, strategy, engine, ps, data: RidgeData,
             **cfg) -> WorkloadRunResult:
        steps, cfg = self._cell_cfg(strategy, ps, cfg)
        result = get_strategy(strategy).run(data.spec, engine, steps=steps,
                                            **cfg)
        return self._score(strategy, ps, data, result)

    def run_trials(self, strategy, engine=None, *, preset="smoke", data=None,
                   trials=1, eval_every=1, placement="vmap", **cfg):
        """Fused Monte-Carlo path: ridge lowers to ONE strategy run, so the
        whole realization stack executes as a single compiled program via
        ``Strategy.run_batched`` (one encode, one (R, T, m) schedule draw,
        one vmapped — or ``placement='sharded'``, shard_map-ped — scan) and
        each realization is scored independently."""
        strategy = self._resolve_checked(strategy)
        ps = self.preset(preset)
        if engine is None:
            engine = self.default_engine(ps)
        if data is None:
            data = self.build(ps)
        steps, cfg = self._cell_cfg(strategy, ps, dict(cfg))
        batched = get_strategy(strategy).run_batched(
            data.spec, engine, steps=steps, trials=trials,
            eval_every=eval_every, placement=placement, **cfg)
        return [self._score(strategy, ps, data, batched.realization(r))
                for r in range(trials)]
