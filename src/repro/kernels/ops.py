"""Jitted public wrappers around the Pallas kernels.

``on_tpu()`` flips interpret mode automatically: interpret=True on CPU
(validation), compiled Mosaic on real TPUs.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from .coded_reduce import coded_combine_call
from .fwht import fwht_kernel_call

__all__ = ["on_tpu", "fwht", "hadamard_encode", "coded_combine"]


def on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def fwht(x: jax.Array, axis: int = -1) -> jax.Array:
    """Fast Walsh-Hadamard transform along ``axis`` (power-of-two length)."""
    interpret = not on_tpu()
    x = jnp.moveaxis(x, axis, -1)
    lead = x.shape[:-1]
    flat = x.reshape(-1, x.shape[-1])
    out = fwht_kernel_call(flat, interpret=interpret)
    return jnp.moveaxis(out.reshape(lead + (x.shape[-1],)), -1, axis)


def hadamard_encode(X: jax.Array, cols: np.ndarray, signs: np.ndarray,
                    N: int | None = None) -> jax.Array:
    """Encode data X (n, p) with the randomized Hadamard ensemble:

        S X = H_N[:, cols] diag(signs) X / sqrt(n)

    computed as FWHT over the zero-padded, sign-flipped rows (paper §4.2.2) —
    no S materialization.  Returns (N, p).
    """
    n, p = X.shape
    N = N or 1 << (2 * n - 1).bit_length()  # default beta ~= 2 padding
    padded = jnp.zeros((N, p), X.dtype)
    padded = padded.at[jnp.asarray(cols)].set(
        X * jnp.asarray(signs, X.dtype)[:, None])
    return fwht(padded, axis=0) / math.sqrt(n)


def coded_combine(g: jax.Array, c: jax.Array) -> jax.Array:
    """Fused coded gradient combine: sum_i c_i g_i for (m, P) grads."""
    interpret = not on_tpu()
    m, P = g.shape
    # pad P to the block multiple
    block = 2048 if P >= 2048 else P
    pad = (-P) % block
    if pad:
        g = jnp.pad(g, ((0, 0), (0, pad)))
    out = coded_combine_call(g, c, block=block, interpret=interpret)
    return out[:P]
