"""Jitted public wrappers around the Pallas kernels.

``on_tpu()`` flips interpret mode automatically: interpret=True on CPU
(validation), compiled Mosaic on real TPUs.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from .coded_reduce import coded_combine_call
from .encode import srht_encode_call
from .fused_step import fused_enabled, fused_masked_gradient
from .fwht import fwht_kernel_call

__all__ = ["on_tpu", "fwht", "hadamard_encode", "srht_encode",
           "coded_combine", "fused_masked_gradient", "fused_enabled"]


def on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def fwht(x: jax.Array, axis: int = -1) -> jax.Array:
    """Fast Walsh-Hadamard transform along ``axis`` (power-of-two length)."""
    interpret = not on_tpu()
    x = jnp.moveaxis(x, axis, -1)
    lead = x.shape[:-1]
    flat = x.reshape(-1, x.shape[-1])
    out = fwht_kernel_call(flat, interpret=interpret)
    return jnp.moveaxis(out.reshape(lead + (x.shape[-1],)), -1, axis)


def srht_encode(X: jax.Array, cols: np.ndarray, signs: np.ndarray, N: int,
                lo: int = 0, hi: int | None = None) -> jax.Array:
    """Rows [lo, hi) of  S X = H_N[:, cols] diag(signs) X / sqrt(n)  for data
    X (n, p) — the matrix-free SRHT encode (paper §4.2.2).

    One XLA scatter places the data columns into their N transform positions;
    the fused Pallas kernel (kernels/encode.py) then does sign-flip + all
    FWHT butterfly stages + the contiguous row gather in a single pass.
    Returns (hi - lo, p); S is never formed.
    """
    n, p = X.shape
    hi = N if hi is None else hi
    xt = jnp.zeros((p, N), X.dtype).at[:, jnp.asarray(cols)].set(X.T)
    dsigns = jnp.zeros((1, N), X.dtype).at[0, jnp.asarray(cols)].set(
        jnp.asarray(signs, X.dtype))
    # pad the grid axis (data columns) up to a whole number of row blocks:
    # the budget-limited block (pick_block_rows with an always-divisible row
    # count) capped at the next power of two covering p
    from .fwht import pick_block_rows
    br = min(pick_block_rows(1 << 30, N, xt.dtype.itemsize),
             1 << max(p - 1, 1).bit_length())
    pad = (-p) % br
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    out = srht_encode_call(xt, dsigns, lo=lo, hi=hi,
                           scale=1.0 / math.sqrt(n), block_rows=br,
                           interpret=not on_tpu())
    return out[:p].T


def hadamard_encode(X: jax.Array, cols: np.ndarray, signs: np.ndarray,
                    N: int | None = None) -> jax.Array:
    """Encode data X (n, p) with the randomized Hadamard ensemble:

        S X = H_N[:, cols] diag(signs) X / sqrt(n)

    via the fused sign-flip + FWHT + gather kernel (paper §4.2.2) —
    no S materialization.  Returns (N, p).
    """
    n, p = X.shape
    N = N or 1 << (2 * n - 1).bit_length()  # default beta ~= 2 padding
    return srht_encode(X, cols, signs, N)


def coded_combine(g: jax.Array, c: jax.Array) -> jax.Array:
    """Fused coded gradient combine: sum_i c_i g_i for (m, P) grads.

    The kernel itself pads P to a block multiple and resolves interpret
    mode from the backend, so this is a plain alias kept for API stability.
    """
    return coded_combine_call(g, c)
