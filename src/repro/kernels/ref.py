"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fwht_ref(x: jax.Array) -> jax.Array:
    """Recursive FWHT along the last axis (no normalization)."""
    n = x.shape[-1]
    if n & (n - 1):
        raise ValueError("power of two required")
    x = x.astype(jnp.float32)
    h = 1
    while h < n:
        y = x.reshape(x.shape[:-1] + (n // (2 * h), 2, h))
        a, b = y[..., 0, :], y[..., 1, :]
        x = jnp.stack([a + b, a - b], axis=-2).reshape(x.shape)
        h *= 2
    return x


def fwht_matrix_ref(x: jax.Array) -> jax.Array:
    """Dense H @ x oracle (independent of the butterfly formulation)."""
    n = x.shape[-1]
    H = jnp.array([[1.0]])
    while H.shape[0] < n:
        H = jnp.block([[H, H], [H, -H]])
    return jnp.einsum("nm,...m->...n", H, x.astype(jnp.float32))


def coded_combine_ref(g: jax.Array, c: jax.Array) -> jax.Array:
    return jnp.einsum("m,mp->p", c.astype(jnp.float32),
                      g.astype(jnp.float32)).astype(g.dtype)
