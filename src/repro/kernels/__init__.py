"""Pallas TPU kernels (validated in interpret mode on CPU).

fwht: the paper's FWHT encoder (§4.2.2); coded_reduce: fused coded gradient
combine.  ops.py holds the jit'd public wrappers; ref.py the jnp oracles.
"""
from .ops import fwht, hadamard_encode, coded_combine, on_tpu
