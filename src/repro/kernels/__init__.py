"""Pallas TPU kernels (validated in interpret mode on CPU).

fwht: the paper's FWHT transform (§4.2.2); encode: the fused sign-flip +
FWHT + row-gather SRHT encode; coded_reduce: fused coded gradient combine;
fused_step: the fused masked-gradient megakernel (matvec + erasure mask +
decode-weighted combine in one pass).  ops.py holds the jit'd public
wrappers; ref.py the jnp oracles.
"""
from .ops import (fwht, hadamard_encode, srht_encode, coded_combine, on_tpu,
                  fused_masked_gradient, fused_enabled)
