"""Pallas TPU megakernel: fused masked-gradient step (paper Algorithm 1).

One pallas_call per iteration computes the WHOLE master-side hot path —

    g~ = sum_i c_i * (S_i X)^T (S_i X w - S_i y),
    c_i = mask_i * (m / k) / (n * beta),  k = |active set|

— per worker block: the residual matvec, the erasure mask (a zero decode
weight) and the decode-weighted combine all happen on the same VMEM tile.
The unfused path materializes the (m, p) per-worker gradient stack in HBM
and re-reads it for the combine; here each (br, p) slab of S_i X is
streamed through VMEM exactly once (grid over worker x row blocks, with
Pallas's automatic pipelining double-buffering the slab loads) and only the
(1, p) accumulator ever lives across grid steps.  HBM traffic drops from
~2 m r p + 2 m p to ~m r p elements per step.

Dispatch policy (``fused_enabled``): default on real TPUs only — the
interpreted kernel is slower than XLA's fused einsums on CPU/GPU, so those
backends keep the dense path in ``core.data_parallel._masked_mean``.  The
``REPRO_FUSED`` env var forces it either way (=1 exercises the kernel in
interpret mode for CI trace-equality guards; =0 pins the dense path on TPU).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fwht import default_interpret

__all__ = ["fused_masked_gradient", "fused_enabled", "pick_fused_block_rows"]


def fused_enabled() -> bool:
    """Should the runners take the fused megakernel path?  Checked at trace
    time (it is a Python-level branch, not a jaxpr one), so flipping
    ``REPRO_FUSED`` between calls of one jitted runner with identical shapes
    will NOT retrace — tests use fresh shapes or subprocesses."""
    env = os.environ.get("REPRO_FUSED")
    if env is not None:
        return env.strip().lower() not in ("", "0", "false", "no")
    return jax.default_backend() == "tpu"


def pick_fused_block_rows(r: int, p: int, dtype_bytes: int = 4,
                          vmem_budget: int = 8 * 1024 * 1024) -> int:
    """Largest divisor of the per-worker row count ``r`` whose (br, p) slab
    plus its pipeline double-buffer fits the VMEM budget.  A divisor means
    no row padding: the grid tiles ``r`` exactly."""
    cap = max(1, vmem_budget // max(1, 2 * p * dtype_bytes))
    best = 1
    d = 1
    while d * d <= r:
        if r % d == 0:
            for cand in (d, r // d):
                if best < cand <= cap:
                    best = cand
        d += 1
    return best


def _fused_body(sx_ref, sy_ref, w_ref, c_ref, o_ref):
    """Grid (m, r // br): worker i, row block j.

    Residual matvec + rank-br gradient contribution + weighted accumulate,
    all on the current slab.  The (1, p) output block has a constant index
    map, so it stays pinned in VMEM across every grid step; TPU grid
    iteration is sequential, so the (0, 0) zero-init runs first.
    """
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    sx = sx_ref[0].astype(jnp.float32)                      # (br, p)
    w = w_ref[...].astype(jnp.float32)                      # (1, p)
    u = jax.lax.dot_general(w, sx, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    u = u - sy_ref[...].astype(jnp.float32)                 # (1, br) residual
    g = jax.lax.dot_general(u, sx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (1, p)
    o_ref[...] += c_ref[0, 0].astype(jnp.float32) * g


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def _fused_call(SX: jax.Array, Sy: jax.Array, w: jax.Array, c: jax.Array, *,
                interpret: bool, block_rows: int | None = None) -> jax.Array:
    m, r, p = SX.shape
    br = block_rows or pick_fused_block_rows(r, p, SX.dtype.itemsize)
    out = pl.pallas_call(
        _fused_body,
        grid=(m, r // br),
        in_specs=[pl.BlockSpec((1, br, p), lambda i, j: (i, j, 0)),
                  pl.BlockSpec((1, br), lambda i, j: (i, j)),
                  pl.BlockSpec((1, p), lambda i, j: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((1, p), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, p), jnp.float32),
        interpret=interpret,
    )(SX, Sy, w[None, :], c)
    return out[0].astype(w.dtype)


def fused_masked_gradient(SX: jax.Array, Sy: jax.Array, w: jax.Array,
                          mask: jax.Array, *, n: int, beta: float,
                          interpret: bool | None = None,
                          block_rows: int | None = None) -> jax.Array:
    """The fused (1/eta)-scaled masked gradient, (p,).

    SX (m, r, p) / Sy (m, r) are the worker-stacked encoded blocks, w the
    iterate, mask the (m,) {0,1} active set.  Equals
    ``masked_gradient(prob, w, mask)`` from ``core.data_parallel`` to float
    rounding (the trace-equality tests enforce <= 1e-4).  Raw-array API on
    purpose: kernels/ never imports problem containers.

    interpret=None resolves from the backend (compiled Mosaic on TPU,
    interpreted elsewhere — the ``coded_reduce.py`` policy).  Composes with
    ``vmap`` (the batched-trial runners): the batched axis becomes a leading
    grid axis, and the shared SX/Sy operands are NOT broadcast.
    """
    if interpret is None:
        interpret = default_interpret()
    m = SX.shape[0]
    k = jnp.maximum(mask.sum(), 1.0)
    c = (mask * (m / k) / (n * beta)).astype(jnp.float32)[:, None]  # (m, 1)
    return _fused_call(SX, Sy, w, c, interpret=interpret,
                       block_rows=block_rows)
