"""Pallas TPU kernel: fused SRHT-style encode (sign-flip + FWHT + row gather).

The paper's efficient encoder (§4.2.2) is  S X = H_N[:, cols] diag(signs) X
/ sqrt(n)  — never materialized.  Written along the transform axis this is

    (S X)[lo:hi] = (FWHT(D_pad · X_pad))[lo:hi] / sqrt(n)

where ``X_pad`` is the data scattered into its N padded positions and
``D_pad`` is the sign vector scattered likewise (zero on dead rows).  The
kernel fuses the three post-scatter stages into ONE pallas_call, one HBM
round-trip per tile:

  1. sign-flip: multiply the (BLOCK_ROWS, N) tile by the broadcast sign row
     (zeros kill any stray values in dead lanes — the zero-pad is enforced
     here, not trusted from the caller);
  2. all log2(N) FWHT butterfly stages in VMEM (same layout as fwht.py);
  3. row gather: only the contiguous encoded-row window [lo, hi) — a worker
     block, or the full frame — is scaled and written back to HBM.

The transform axis is the trailing (lane) axis: callers pass X^T so encoded
ROWS become output lanes and the gather is a static lane slice.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fwht import butterfly, default_interpret, pick_block_rows

__all__ = ["srht_encode_call"]


def _srht_body(x_ref, d_ref, o_ref, *, n: int, lo: int, hi: int,
               scale: float):
    x = butterfly(x_ref[...].astype(jnp.float32) *
                  d_ref[...].astype(jnp.float32), n)
    o_ref[...] = (x[:, lo:hi] * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("lo", "hi", "scale",
                                             "block_rows", "interpret"))
def srht_encode_call(xt: jax.Array, dsigns: jax.Array, *, lo: int, hi: int,
                     scale: float, block_rows: int | None = None,
                     interpret: bool | None = None) -> jax.Array:
    """Fused sign-flip + FWHT + row-window encode.

    xt:     (p, N) — data columns as rows, already scattered into the N
            padded transform positions (zeros elsewhere).
    dsigns: (1, N) — random signs at live positions, ZERO at dead ones.
    Returns (p, hi - lo): encoded rows [lo, hi) of S X, transposed.
    """
    if interpret is None:
        interpret = default_interpret()
    rows, n = xt.shape
    if n & (n - 1):
        raise ValueError(f"transform length {n} is not a power of two")
    if not (0 <= lo < hi <= n):
        raise ValueError(f"row window [{lo}, {hi}) outside [0, {n})")
    if dsigns.shape != (1, n):
        raise ValueError(f"dsigns shape {dsigns.shape} != (1, {n})")
    br = block_rows or pick_block_rows(rows, n, xt.dtype.itemsize)
    if rows % br:
        raise ValueError(f"rows {rows} not divisible by block_rows {br}")
    return pl.pallas_call(
        functools.partial(_srht_body, n=n, lo=lo, hi=hi, scale=scale),
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0)),
                  pl.BlockSpec((1, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((br, hi - lo), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, hi - lo), xt.dtype),
        interpret=interpret,
    )(xt, dsigns)
