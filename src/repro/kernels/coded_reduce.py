"""Pallas TPU kernel: fused coded gradient combine.

The per-step aggregation  g~ = sum_i c_i * g_i  over the worker-stacked
gradient block (m, P) with FRC decode weights c (m,) — the master-side
hot path of every iteration (paper Algorithm 1 line 7).  Fusing the mask,
scale and reduction avoids materializing the (m, P) weighted intermediate
in HBM: the tile is weighted and reduced in VMEM in one pass.

Grid over P blocks; the worker axis (m <= 32) rides along the sublane dim.

Layout is resolved ONCE per gradient width (``combine_layout``, lru-cached):
instead of zero-padding P up to a block multiple on every call, the block
width snaps to the largest divisor of P under the cap, so for any realistic
P the kernel tiles the array exactly and the per-step pad disappears from
the traced program.  Padding only survives as a last resort when the best
divisor is lane-hostile (< 128) — e.g. a large prime P.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fwht import default_interpret

__all__ = ["coded_combine_call", "combine_layout"]


@functools.lru_cache(maxsize=None)
def combine_layout(P: int, block: int = 2048) -> tuple[int, int]:
    """(block_width, pad) for a width-P combine.  pad == 0 whenever P has a
    divisor in [128, block] (always true for the power-of-two-ish widths
    encoders produce) — the pad then never enters the traced program."""
    bp = min(block, P)
    if P % bp == 0:
        return bp, 0
    d = bp
    while P % d:
        d -= 1
    if d >= 128:
        return d, 0
    return bp, (-P) % bp


def _combine_body(g_ref, c_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)        # (m, BP)
    c = c_ref[...].astype(jnp.float32)        # (m, 1)
    o_ref[...] = jnp.sum(g * c, axis=0, keepdims=True).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def coded_combine_call(g: jax.Array, c: jax.Array, *, block: int = 2048,
                       interpret: bool | None = None) -> jax.Array:
    """g: (m, P) worker gradients; c: (m,) or (m, 1) decode weights -> (P,).

    interpret=None (default) picks the mode from the backend: compiled
    Mosaic on TPU, interpreted elsewhere (the ``fwht.py`` policy).  Callers
    on the hot path (``core.data_parallel._masked_mean``) hand c already
    shaped (m, 1) so no per-step reshape is traced; the 1-D form is kept
    for API compatibility.
    """
    if interpret is None:
        interpret = default_interpret()
    m, P = g.shape
    if c.ndim == 1:
        c = c[:, None]
    bp, pad = combine_layout(P, block)
    if pad:
        g = jnp.pad(g, ((0, 0), (0, pad)))
    padded = P + pad
    out = pl.pallas_call(
        _combine_body,
        grid=(padded // bp,),
        in_specs=[pl.BlockSpec((m, bp), lambda i: (0, i)),
                  pl.BlockSpec((m, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, bp), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, padded), g.dtype),
        interpret=interpret,
    )(g, c)
    return out[0, :P] if pad else out[0]
