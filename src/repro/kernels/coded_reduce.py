"""Pallas TPU kernel: fused coded gradient combine.

The per-step aggregation  g~ = sum_i c_i * g_i  over the worker-stacked
gradient block (m, P) with FRC decode weights c (m,) — the master-side
hot path of every iteration (paper Algorithm 1 line 7).  Fusing the mask,
scale and reduction avoids materializing the (m, P) weighted intermediate
in HBM: the tile is weighted and reduced in VMEM in one pass.

Grid over P blocks; the worker axis (m <= 32) rides along the sublane dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["coded_combine_call"]


def _combine_body(g_ref, c_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)        # (m, BP)
    c = c_ref[...].astype(jnp.float32)        # (m, 1)
    o_ref[...] = jnp.sum(g * c, axis=0, keepdims=True).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def coded_combine_call(g: jax.Array, c: jax.Array, *, block: int = 2048,
                       interpret: bool = True) -> jax.Array:
    """g: (m, P) worker gradients; c: (m,) decode weights -> (P,)."""
    m, P = g.shape
    bp = min(block, P)
    if P % bp:
        raise ValueError(f"P={P} not divisible by block {bp}")
    out = pl.pallas_call(
        _combine_body,
        grid=(P // bp,),
        in_specs=[pl.BlockSpec((m, bp), lambda i: (0, i)),
                  pl.BlockSpec((m, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, bp), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, P), g.dtype),
        interpret=interpret,
    )(g, c[:, None])
    return out[0]
