"""Pallas TPU kernel: fused coded gradient combine.

The per-step aggregation  g~ = sum_i c_i * g_i  over the worker-stacked
gradient block (m, P) with FRC decode weights c (m,) — the master-side
hot path of every iteration (paper Algorithm 1 line 7).  Fusing the mask,
scale and reduction avoids materializing the (m, P) weighted intermediate
in HBM: the tile is weighted and reduced in VMEM in one pass.

Grid over P blocks; the worker axis (m <= 32) rides along the sublane dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fwht import default_interpret

__all__ = ["coded_combine_call"]


def _combine_body(g_ref, c_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)        # (m, BP)
    c = c_ref[...].astype(jnp.float32)        # (m, 1)
    o_ref[...] = jnp.sum(g * c, axis=0, keepdims=True).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def coded_combine_call(g: jax.Array, c: jax.Array, *, block: int = 2048,
                       interpret: bool | None = None) -> jax.Array:
    """g: (m, P) worker gradients; c: (m,) decode weights -> (P,).

    interpret=None (default) picks the mode from the backend: compiled
    Mosaic on TPU, interpreted elsewhere (the ``fwht.py`` policy).  A P that
    is not a block multiple is zero-padded to one — the pad lanes combine to
    zeros that are sliced away, so any gradient width is accepted.
    """
    if interpret is None:
        interpret = default_interpret()
    m, P = g.shape
    bp = min(block, P)
    pad = (-P) % bp
    if pad:
        g = jnp.pad(g, ((0, 0), (0, pad)))
    padded = P + pad
    out = pl.pallas_call(
        _combine_body,
        grid=(padded // bp,),
        in_specs=[pl.BlockSpec((m, bp), lambda i: (0, i)),
                  pl.BlockSpec((m, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, bp), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, padded), g.dtype),
        interpret=interpret,
    )(g, c[:, None])
    return out[0, :P]
