"""Pallas TPU kernel: blocked Fast Walsh-Hadamard Transform.

The paper's efficient encoder (§4.2.2) is FWHT over the (zero-padded, sign-
flipped) data — the dominant encode cost.  GPU implementations make log2(N)
passes over global memory; the TPU-native layout instead keeps a (BLOCK_ROWS,
N) tile resident in VMEM across ALL butterfly stages (one HBM round-trip
total), with the pairwise add/sub running on the VPU lanes.  The transform
axis is the trailing (lane) axis, padded to multiples of 128 by construction
(N is a power of two >= 128 in every production encode).

Grid: one program per row block.  BLOCK_ROWS is chosen so the tile plus its
double-buffer fits an 8 MB VMEM budget (half of the ~16 MB per core, leaving
headroom for the compiler's own buffers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fwht_kernel_call", "pick_block_rows", "butterfly",
           "default_interpret"]


def pick_block_rows(rows: int, n: int, dtype_bytes: int = 4,
                    vmem_budget: int = 8 * 1024 * 1024) -> int:
    """Largest power-of-two row block whose tile (+ double buffer) fits the
    VMEM budget and divides ``rows``."""
    br = 1
    while (br * 2 <= rows and (br * 2) * 2 * n * dtype_bytes <= vmem_budget):
        br *= 2
    while rows % br:
        br //= 2
    return max(br, 1)


def butterfly(x: jax.Array, n: int) -> jax.Array:
    """All log2(n) FWHT butterfly stages over the trailing axis of a
    (rows, n) float32 tile — shared by every kernel body that transforms
    in VMEM (fwht.py, encode.py)."""
    br = x.shape[0]
    h = 1
    while h < n:
        # pairs: (BR, n/2h, 2, h) -> (a+b, a-b)
        y = x.reshape(br, n // (2 * h), 2, h)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2).reshape(br, n)
        h *= 2
    return x


def default_interpret() -> bool:
    """Interpret everywhere but real TPUs — the kernels assume the TPU
    lane layout, so GPU backends validate in interpret mode like CPU (the
    same policy as ops.on_tpu)."""
    return jax.default_backend() != "tpu"


def _fwht_body(x_ref, o_ref, *, n: int):
    """In-VMEM butterfly over the trailing axis (length n, power of two)."""
    x = butterfly(x_ref[...].astype(jnp.float32), n)    # (BR, n)
    o_ref[...] = x.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def fwht_kernel_call(x: jax.Array, *, block_rows: int | None = None,
                     interpret: bool | None = None) -> jax.Array:
    """FWHT along the last axis of x: (rows, n) -> (rows, n).

    n must be a power of two.  interpret=None (default) picks the mode from
    the backend: compiled Mosaic on TPU, interpreted elsewhere.
    """
    if interpret is None:
        interpret = default_interpret()
    rows, n = x.shape
    if n & (n - 1):
        raise ValueError(f"FWHT length {n} is not a power of two")
    br = block_rows or pick_block_rows(rows, n, x.dtype.itemsize)
    if rows % br:
        raise ValueError(f"rows {rows} not divisible by block_rows {br}")
    return pl.pallas_call(
        functools.partial(_fwht_body, n=n),
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), x.dtype),
        interpret=interpret,
    )(x)
