"""Config registry: the 10 assigned architectures + paper-native problems.

``get_config(name)`` returns the full ArchConfig; ``windowed_variant``
produces the sliding-window long-context variant used by dense archs for the
``long_500k`` shape (DESIGN §4, 'long_500k policy').
"""
from __future__ import annotations

import dataclasses

from .base import ArchConfig, BlockSpec, attn_block, mamba_block, \
    mlstm_block, slstm_block
from .stablelm_12b import CONFIG as _stablelm
from .qwen2_vl_7b import CONFIG as _qwen2vl
from .jamba_1_5_large_398b import CONFIG as _jamba
from .whisper_small import CONFIG as _whisper
from .starcoder2_3b import CONFIG as _starcoder2
from .phi3_5_moe_42b import CONFIG as _phi35
from .deepseek_7b import CONFIG as _deepseek
from .dbrx_132b import CONFIG as _dbrx
from .xlstm_350m import CONFIG as _xlstm
from .gemma2_27b import CONFIG as _gemma2

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in [
        _stablelm, _qwen2vl, _jamba, _whisper, _starcoder2, _phi35,
        _deepseek, _dbrx, _xlstm, _gemma2,
    ]
}

# Input shapes assigned to this paper (seq_len, global_batch, kind).
SHAPES = {
    "train_4k":    dict(seq_len=4096,    global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768,   global_batch=32,  kind="prefill"),
    "decode_32k":  dict(seq_len=32768,   global_batch=128, kind="decode"),
    "long_500k":   dict(seq_len=524288,  global_batch=1,   kind="decode"),
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; have {sorted(ARCHS)}")
    return ARCHS[name]


def windowed_variant(cfg: ArchConfig) -> ArchConfig:
    """Replace full-attention blocks with sliding-window ones (long_500k)."""
    W = cfg.long_context_window
    period = tuple(
        dataclasses.replace(b, window=b.window or W) if b.kind == "attn" else b
        for b in cfg.period)
    return cfg.with_overrides(period=period)


def needs_window_for_long(cfg: ArchConfig) -> bool:
    """True if the arch has any full-attention block (quadratic at 524k)."""
    return any(b.kind == "attn" and b.window is None for b in cfg.period)
