"""whisper-small [audio] — 12L(+12 enc) d_model=768 12H (kv=12) d_ff=3072
vocab=51865; encoder-decoder; mel-spectrogram + conv frontend STUBBED —
input_specs provides (B, 1500, d_model) frame embeddings (the carve-out in
the task spec).  Positions are sinusoidal (computed on the fly; whisper's
learned decoder table would not extend to the assigned 32k/524k decode
shapes — noted deviation). [arXiv:2212.04356]"""
from .base import ArchConfig, attn_block

CONFIG = ArchConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv=12, d_ff=3072, vocab=51865,
    period=(attn_block(cross_attn=True),),
    n_enc_layers=12, n_enc_frames=1500,
    learned_pos=True,            # additive (sinusoidal) positions, no rope
    norm="layernorm", act="gelu",
    source="arXiv:2212.04356",
)
