"""gemma2-27b [dense] — 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000; alternating local (sliding-window 4096) / global attention,
attention + final logit soft-capping, post-block norms. [arXiv:2408.00118]"""
from .base import ArchConfig, attn_block

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv=16, d_ff=36864, vocab=256000,
    period=(attn_block(window=4096), attn_block()),   # local, global
    head_dim=128,
    attn_softcap=50.0, final_softcap=30.0,
    post_block_norm=True,
    act="gelu",
    source="arXiv:2408.00118",
)
