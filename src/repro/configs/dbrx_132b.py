"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352; 16 experts top-4, fine-grained. [hf:databricks/dbrx-base]

Dry-run note: bf16 optimizer moments (132B params; DESIGN §8)."""
from .base import ArchConfig, attn_block

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv=8, d_ff=10752, vocab=100352,
    period=(attn_block(moe=True),),
    n_experts=16, top_k=4,
    optstate_dtype="bfloat16",
    source="hf:databricks/dbrx-base",
)
