"""xlstm-350m [ssm] — 24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304;
alternating sLSTM + mLSTM blocks (the blocks carry their own projections —
d_ff=0 at the config level). [arXiv:2405.04517]"""
from .base import ArchConfig, mlstm_block, slstm_block

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv=4, d_ff=0, vocab=50304,
    period=(mlstm_block(), slstm_block()),
    xlstm_proj_factor=2.0,
    source="arXiv:2405.04517",
)
