"""Architecture configuration system.

Every assigned architecture is a declarative ``ArchConfig``; the model code in
``repro/models`` interprets it.  Layers are grouped into a homogeneous *period*
(a short list of block specs) that repeats ``n_periods`` times — the model
stacks period parameters with a leading ``n_periods`` axis and scans over it,
keeping HLO size ~one period regardless of depth (DESIGN §5).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

__all__ = ["BlockSpec", "ArchConfig", "attn_block", "mamba_block",
           "mlstm_block", "slstm_block"]


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One sublayer position within the repeating period."""
    kind: str                   # "attn" | "mamba" | "mlstm" | "slstm"
    moe: bool = False           # MoE MLP instead of dense MLP
    window: Optional[int] = None  # sliding-window size for attn (None = full)
    cross_attn: bool = False    # decoder cross-attention (enc-dec only)
    mlp: bool = True            # xLSTM blocks carry their own projections


def attn_block(moe: bool = False, window: Optional[int] = None,
               cross_attn: bool = False) -> BlockSpec:
    return BlockSpec("attn", moe=moe, window=window, cross_attn=cross_attn)


def mamba_block(moe: bool = False) -> BlockSpec:
    return BlockSpec("mamba", moe=moe)


def mlstm_block() -> BlockSpec:
    return BlockSpec("mlstm", mlp=False)


def slstm_block() -> BlockSpec:
    return BlockSpec("slstm", mlp=False)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    period: Tuple[BlockSpec, ...]          # decoder period (repeats)
    head_dim: Optional[int] = None         # default d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- attention extras ---
    rope_theta: float = 10000.0
    attn_softcap: Optional[float] = None   # gemma2: 50.0
    final_softcap: Optional[float] = None  # gemma2: 30.0
    # sliding-window size used when a long-context windowed variant is
    # requested (dense archs on long_500k; DESIGN §4 'long_500k policy')
    long_context_window: int = 4096
    # --- M-RoPE (qwen2-vl) ---
    mrope_sections: Optional[Tuple[int, int, int]] = None  # fractions of hd/2
    n_patches: int = 0                     # VLM stub patch embeds
    d_vision: int = 0                      # stub vision embedding width
    # --- Mamba ---
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_conv: int = 4
    mamba_dt_rank: int = 0                 # 0 -> ceil(d_model/16)
    mamba_chunk: int = 128
    # --- xLSTM ---
    xlstm_proj_factor: float = 2.0         # mLSTM up-projection factor
    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    n_enc_frames: int = 0                  # stub conv/mel frontend length
    causal_encoder: bool = False
    learned_pos: bool = False              # learned positional embeddings
    # --- norm / act ---
    norm: str = "rmsnorm"                  # rmsnorm | layernorm
    act: str = "silu"                      # silu | gelu
    post_block_norm: bool = False          # gemma2-style extra norms
    # --- numerics / distribution ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    optstate_dtype: str = "float32"        # bf16 for the >=100B configs
    remat_policy: str = "full"             # full | dots | none  (hillclimb lever)
    attn_chunk: int = 1024                 # KV chunk for online-softmax attention
    # --- beyond-paper perf levers (§Perf; default off = paper baseline) ---
    banded_window: bool = False            # O1: skip out-of-window KV blocks
    seq_parallel_attn: bool = False        # O2: shard q-seq over `model` when
    #     heads % model_axis != 0 (keeps the MXU busy for 24/28/12-head archs)
    fsdp_min_elems: int = 0                # O3: replicate params smaller than
    #     this (stops per-scan-chunk FSDP all-gathers of tiny weights)
    moe_local_dispatch: bool = False       # O5: batch-local MoE gather/scatter
    slstm_shard_batch: bool = False        # O6: pin sLSTM scan inputs/carry to
    #     batch sharding (stops per-timestep SPMD reshards, 49k collectives)
    seq_parallel_mlp: bool = False         # O4: Megatron-SP style — keep the
    #     residual stream sequence-sharded over `model` through norms + MLP
    #     (turns TP partial-sum all-reduces into cheap boundary reshards)
    # --- coded data parallelism (the paper's technique; DESIGN §4) ---
    coded_dp_beta: int = 2                 # gradient-coding replication factor
    source: str = ""                       # citation

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.period) == 0, \
            f"{self.name}: {self.n_layers} layers not divisible by period {len(self.period)}"
        return self.n_layers // len(self.period)

    def with_overrides(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def smoke_variant(self) -> "ArchConfig":
        """Reduced config for CPU smoke tests: 1 period (>=1 layer... up to
        period length), d_model<=256, <=4 experts, small vocab."""
        hd = 32
        n_heads = max(2, min(4, self.n_heads))
        n_kv = max(1, min(self.n_kv, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        period = self.period[:2] if len(self.period) > 2 else self.period
        # Keep one of each block kind present so the smoke exercises them all.
        kinds = {b.kind for b in self.period}
        if {b.kind for b in period} != kinds:
            period = tuple(dict.fromkeys(
                [next(b for b in self.period if b.kind == k) for k in sorted(kinds)]))
        return dataclasses.replace(
            self,
            n_layers=2 * len(period), d_model=128, n_heads=n_heads, n_kv=n_kv,
            d_ff=256, vocab=512, head_dim=hd, period=tuple(period),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_enc_layers=2 if self.n_enc_layers else 0,
            n_enc_frames=16 if self.n_enc_frames else 0,
            n_patches=8 if self.n_patches else 0,
            d_vision=64 if self.d_vision else 0,
            mamba_chunk=16, attn_chunk=64,
            dtype="float32", param_dtype="float32",
            mrope_sections=(4, 6, 6) if self.mrope_sections else None,
        )
