"""The paper's own experiment configurations (§5) — the four problems it
evaluates on EC2, with the published dimensions, regularization, delay
models and schemes.  benchmarks/ uses scaled-down variants of these (CPU
budget); the full settings are kept here as the reference protocol.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class QuadraticProblemConfig:
    name: str
    n: int                    # samples (rows of X)
    p: int                    # features
    m: int                    # workers
    k: Tuple[int, ...]        # fastest-k settings evaluated
    lam: float
    beta: float = 2.0
    regularizer: str = "l2"   # l2 | l1 | none
    algorithm: str = "lbfgs"  # gd | lbfgs | prox | bcd
    encoders: Tuple[str, ...] = ("uncoded", "replication", "hadamard")
    delay_model: str = "bimodal"
    instance_note: str = ""


PAPER_RIDGE = QuadraticProblemConfig(
    name="ridge_s5_1", n=4096, p=6000, m=32, k=(12, 24, 32), lam=0.05,
    algorithm="lbfgs", encoders=("uncoded", "replication", "hadamard"),
    delay_model="bimodal",
    instance_note="EC2: 32x m1.small workers + c3.8xlarge master (Fig 7)")

PAPER_MF = QuadraticProblemConfig(
    name="matrix_factorization_s5_2", n=1_000_000, p=15, m=24, k=(3, 12, 24),
    lam=10.0, algorithm="lbfgs",
    encoders=("uncoded", "replication", "gaussian", "paley", "hadamard"),
    delay_model="exponential",
    instance_note="MovieLens-1M, p=15 embedding, b=3, ALS (Tables 2-3)")

PAPER_LOGISTIC = QuadraticProblemConfig(
    name="logistic_s5_3", n=597_641, p=32_500, m=128, k=(64, 80, 128),
    lam=1e-5, regularizer="l2", algorithm="bcd",
    encoders=("uncoded", "replication", "steiner", "haar"),
    delay_model="bimodal",
    instance_note="rcv1.binary; 128x t2.medium + c3.4xlarge (Figs 10-13); "
                  "second delay model: power-law background tasks")

PAPER_LASSO = QuadraticProblemConfig(
    name="lasso_s5_4", n=130_000, p=100_000, m=128, k=(80, 128), lam=0.6,
    regularizer="l1", algorithm="prox",
    encoders=("uncoded", "replication", "steiner"),
    delay_model="multimodal",
    instance_note="7695-sparse ground truth, sigma=40 noise, F1 metric "
                  "(Fig 14)")

PAPER_PROBLEMS = {c.name: c for c in
                  [PAPER_RIDGE, PAPER_MF, PAPER_LOGISTIC, PAPER_LASSO]}
