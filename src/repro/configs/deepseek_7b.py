"""deepseek-7b [dense] — 30L d_model=4096 32H (kv=32, i.e. MHA) d_ff=11008
vocab=102400; llama architecture. [arXiv:2401.02954]"""
from .base import ArchConfig, attn_block

CONFIG = ArchConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv=32, d_ff=11008, vocab=102400,
    period=(attn_block(),),
    rope_theta=10000.0,
    source="arXiv:2401.02954",
)
