"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064; M-RoPE + dynamic-resolution vision (stubbed: patch embeddings
are provided by input_specs, per the modality-frontend carve-out).
[arXiv:2409.12191]"""
from .base import ArchConfig, attn_block

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv=4, d_ff=18944, vocab=152064,
    period=(attn_block(),),
    rope_theta=1000000.0,
    mrope_sections=(16, 24, 24),   # over head_dim/2 = 64 frequencies
    n_patches=1024, d_vision=1280,
    source="arXiv:2409.12191",
)
