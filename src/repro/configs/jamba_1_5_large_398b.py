"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536; Mamba+attention 1:7 interleave (1 attention layer per
8-layer period), MoE 16 experts top-2 on every other layer.
[arXiv:2403.19887]

Dry-run note: optimizer moments kept in bf16 so Adam state fits the v5e
16 GB/chip budget at 398B params (DESIGN §8)."""
from .base import ArchConfig, attn_block, mamba_block

# 8-layer period: position 0 = attention, rest Mamba; MoE on odd positions.
_PERIOD = tuple(
    (attn_block(moe=(i % 2 == 1)) if i == 0 else mamba_block(moe=(i % 2 == 1)))
    for i in range(8)
)

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv=8, d_ff=24576, vocab=65536,
    period=_PERIOD,
    n_experts=16, top_k=2,
    mamba_d_state=16, mamba_expand=2, mamba_conv=4,
    optstate_dtype="bfloat16",
    source="arXiv:2403.19887",
)
