"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152; GQA + RoPE, sliding-window 4096 (as published).
[arXiv:2402.19173]"""
from .base import ArchConfig, attn_block

CONFIG = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv=2, d_ff=12288, vocab=49152,
    period=(attn_block(window=4096),),
    rope_theta=100000.0,
    norm="layernorm", act="gelu",
    source="arXiv:2402.19173",
)
