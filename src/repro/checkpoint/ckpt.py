"""Minimal-dependency checkpointing: flattened pytree -> npz + json manifest.

Path layout:  <dir>/step_<n>.npz  (+ .manifest.json with treedef + dtypes).
Restore rebuilds the exact pytree (dict/tuple/NamedTuple nesting preserved
via jax.tree flatten paths).
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step"]


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path_dir: str, step: int, tree) -> str:
    os.makedirs(path_dir, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    path = os.path.join(path_dir, f"step_{step}.npz")
    np.savez(path, **arrays)
    manifest = {"step": step, "n_leaves": len(leaves),
                "treedef": str(treedef)}
    with open(path + ".manifest.json", "w") as f:
        json.dump(manifest, f)
    return path


def restore(path_dir: str, step: int, like):
    """Restore into the structure of ``like`` (shape/dtype template)."""
    path = os.path.join(path_dir, f"step_{step}.npz")
    data = np.load(path)
    leaves, treedef = _flatten(like)
    assert len(leaves) == len(data.files), \
        f"checkpoint has {len(data.files)} leaves, template {len(leaves)}"
    new_leaves = [jax.numpy.asarray(data[f"leaf_{i}"]).astype(l.dtype)
                  for i, l in enumerate(leaves)]
    return jax.tree.unflatten(treedef, new_leaves)


def latest_step(path_dir: str) -> int | None:
    if not os.path.isdir(path_dir):
        return None
    steps = [int(f[5:-4]) for f in os.listdir(path_dir)
             if f.startswith("step_") and f.endswith(".npz")]
    return max(steps) if steps else None
