"""AdamW with dtype-configurable moments (bf16 for the >=100B configs) and
global-norm gradient clipping.  Pure functional, pytree-shaped like params,
so optimizer state inherits the parameter shardings under pjit.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm"]


class AdamWState(NamedTuple):
    m: dict
    v: dict
    count: jax.Array


def adamw_init(params, dtype=jnp.float32) -> AdamWState:
    zeros = lambda t: jax.tree.map(
        lambda x: jnp.zeros_like(x, dtype=dtype), t)
    return AdamWState(zeros(params), zeros(params),
                      jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.vdot(x.astype(jnp.float32),
                                 x.astype(jnp.float32))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, clip_norm=1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        step = lr * (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
        step = step + lr * weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - step).astype(p.dtype),
                m_new.astype(m.dtype), v_new.astype(v.dtype))

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(new_m, new_v, count), {"grad_norm": gnorm}


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * (step + 1.0) / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr
