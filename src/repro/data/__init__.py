from .pipeline import TokenStream, CodedBatcher, lsq_dataset
