from .pipeline import (TokenStream, CodedBatcher, lsq_dataset, lsq_rows,
                       logreg_dataset, logreg_rows, mf_ratings_dataset,
                       stream_worker_blocks)
