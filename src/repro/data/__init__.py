from .pipeline import (TokenStream, CodedBatcher, lsq_dataset, lsq_rows,
                       stream_worker_blocks)
