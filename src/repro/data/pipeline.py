"""Synthetic data pipeline with coded (redundant) sharding.

Produces LM token batches laid out for coded data parallelism (DESIGN §4):
the global batch of ``m * rows`` sequences is organized as m worker shards;
under the FRC code, replica workers receive IDENTICAL microbatches (cluster
data), and per-sample weights are derived from the straggler mask via
``core.gradient_coding.coded_weights`` so that the masked, weighted loss
gradient equals the full-batch gradient whenever every cluster survives.

Synthetic text: a mixture of Zipfian unigrams and deterministic motifs so a
~100M model shows a real, declining loss curve (examples/train_lm.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.gradient_coding import FRCode, coded_weights

__all__ = ["TokenStream", "CodedBatcher", "lsq_dataset"]


@dataclasses.dataclass
class TokenStream:
    """Zipf + motif synthetic token stream (deterministic per seed)."""
    vocab: int
    seed: int = 0
    motif_len: int = 16
    n_motifs: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        ranks = np.arange(1, self.vocab + 1)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._motifs = rng.integers(0, self.vocab,
                                    (self.n_motifs, self.motif_len))

    def sample(self, rng: np.random.Generator, n: int, seq: int) -> np.ndarray:
        toks = rng.choice(self.vocab, size=(n, seq + 1), p=self._probs)
        # Insert learnable motifs with 50% probability per sequence.
        L = min(self.motif_len, seq + 1)
        for i in range(n):
            if rng.random() < 0.5:
                m = self._motifs[rng.integers(self.n_motifs)][:L]
                start = rng.integers(0, seq + 2 - L)
                toks[i, start:start + L] = m
        return toks.astype(np.int32)


@dataclasses.dataclass
class CodedBatcher:
    """Yields (tokens, labels, weights) with FRC-coded worker layout.

    tokens: (m * rows, seq) — worker i owns rows [i*rows, (i+1)*rows);
    replicas of a cluster carry identical rows.  weights: (m * rows,) decode
    weights (uniform 1 when mask is all-ones).
    """
    stream: TokenStream
    code: FRCode
    rows_per_worker: int
    seq_len: int
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def next_batch(self, mask: np.ndarray):
        b = self.code.num_clusters
        cluster_data = self.stream.sample(
            self._rng, b * self.rows_per_worker, self.seq_len)
        cluster_data = cluster_data.reshape(b, self.rows_per_worker, -1)
        per_worker = cluster_data[self.code.clusters]     # (m, rows, seq+1)
        toks = per_worker.reshape(-1, self.seq_len + 1)
        w = np.asarray(coded_weights(self.code, mask))    # (m,)
        weights = np.repeat(w, self.rows_per_worker).astype(np.float32)
        return toks[:, :-1], toks[:, 1:], weights


def lsq_dataset(n: int, p: int, *, noise: float = 0.1, sparse: int = 0,
                seed: int = 0):
    """Least-squares data for the paper-native problems (ridge / LASSO)."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, p))
    if sparse:
        w = np.zeros(p)
        idx = rng.choice(p, size=sparse, replace=False)
        w[idx] = rng.standard_normal(sparse) * 2.0
    else:
        w = rng.standard_normal(p)
    y = X @ w + noise * rng.standard_normal(n)
    return X, y, w
