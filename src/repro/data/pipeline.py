"""Synthetic data pipeline with coded (redundant) sharding.

Produces LM token batches laid out for coded data parallelism (DESIGN §4):
the global batch of ``m * rows`` sequences is organized as m worker shards;
under the FRC code, replica workers receive IDENTICAL microbatches (cluster
data), and per-sample weights are derived from the straggler mask via
``core.gradient_coding.coded_weights`` so that the masked, weighted loss
gradient equals the full-batch gradient whenever every cluster survives.

Synthetic text: a mixture of Zipfian unigrams and deterministic motifs so a
~100M model shows a real, declining loss curve (examples/train_lm.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.gradient_coding import FRCode, GradientCode, coded_weights

__all__ = ["TokenStream", "CodedBatcher", "GroupBatcher", "lsq_dataset",
           "lsq_rows", "logreg_dataset", "logreg_rows", "mf_ratings_dataset",
           "stream_worker_blocks"]


@dataclasses.dataclass
class TokenStream:
    """Zipf + motif synthetic token stream (deterministic per seed)."""
    vocab: int
    seed: int = 0
    motif_len: int = 16
    n_motifs: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        ranks = np.arange(1, self.vocab + 1)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._motifs = rng.integers(0, self.vocab,
                                    (self.n_motifs, self.motif_len))

    def sample(self, rng: np.random.Generator, n: int, seq: int) -> np.ndarray:
        toks = rng.choice(self.vocab, size=(n, seq + 1), p=self._probs)
        # Insert learnable motifs with 50% probability per sequence —
        # vectorized (one fancy-indexed write for the whole batch; the
        # per-sequence Python loop dominated CodedBatcher hot paths).
        L = min(self.motif_len, seq + 1)
        insert = rng.random(n) < 0.5
        motif_ids = rng.integers(0, self.n_motifs, size=n)
        starts = rng.integers(0, seq + 2 - L, size=n)
        rows = np.nonzero(insert)[0]
        if rows.size:
            cols = starts[rows, None] + np.arange(L)[None, :]
            toks[rows[:, None], cols] = self._motifs[motif_ids[rows], :L]
        return toks.astype(np.int32)


@dataclasses.dataclass
class CodedBatcher:
    """Yields (tokens, labels, weights) with FRC-coded worker layout.

    tokens: (m * rows, seq) — worker i owns rows [i*rows, (i+1)*rows);
    replicas of a cluster carry identical rows.  weights: (m * rows,) decode
    weights (uniform 1 when mask is all-ones).
    """
    stream: TokenStream
    code: FRCode
    rows_per_worker: int
    seq_len: int
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def next_batch(self, mask: np.ndarray):
        b = self.code.num_clusters
        cluster_data = self.stream.sample(
            self._rng, b * self.rows_per_worker, self.seq_len)
        cluster_data = cluster_data.reshape(b, self.rows_per_worker, -1)
        per_worker = cluster_data[self.code.clusters]     # (m, rows, seq+1)
        toks = per_worker.reshape(-1, self.seq_len + 1)
        w = np.asarray(coded_weights(self.code, mask))    # (m,)
        weights = np.repeat(w, self.rows_per_worker).astype(np.float32)
        return toks[:, :-1], toks[:, 1:], weights


@dataclasses.dataclass
class GroupBatcher:
    """Group-major batches for ANY :class:`GradientCode` (DESIGN §15).

    Where :class:`CodedBatcher` bakes in the FRC replica layout and folds
    decode weights into per-sample loss weights, ``GroupBatcher`` keeps the
    two stages of the coded train step separate: it draws the
    ``num_groups * rows`` data rows ONCE per step and lays them out
    worker-major by the code's assignment —

      tokens/labels: (m, slots * rows, seq)  where worker i's slots are its
        ``worker_groups[i]`` (replicas/overlaps share bit-identical rows);
      coeff: (m, slots * rows) float32 combine coefficients
        (``worker_coeffs`` repeated over rows) — the B[i, j] each worker
        applies LOCALLY before the decode-weighted combine.

    Decode weights are NOT applied here: the trainer gets them from
    ``code.decode_weights(mask)`` per step so the same batch serves any
    erasure pattern.  Stochastic codes pass their per-step re-draw via the
    ``code=`` override; the data draw count is identical either way, so
    trajectories across codes with equal (num_groups, rows) consume the
    same token stream.
    """
    stream: TokenStream
    code: GradientCode
    rows_per_worker: int
    seq_len: int
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def next_batch(self, code: GradientCode | None = None):
        code = self.code if code is None else code
        b, rows = code.num_groups, self.rows_per_worker
        data = self.stream.sample(self._rng, b * rows, self.seq_len)
        data = data.reshape(b, rows, -1)
        per_worker = data[code.worker_groups]      # (m, slots, rows, seq+1)
        m = per_worker.shape[0]
        per_worker = per_worker.reshape(m, -1, self.seq_len + 1)
        coeff = np.repeat(np.asarray(code.worker_coeffs, np.float32),
                          rows, axis=1)            # (m, slots * rows)
        return (per_worker[..., :-1], per_worker[..., 1:], coeff)


def lsq_dataset(n: int, p: int, *, noise: float = 0.1, sparse: int = 0,
                seed: int = 0):
    """Least-squares data for the paper-native problems (ridge / LASSO)."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, p))
    if sparse:
        w = np.zeros(p)
        idx = rng.choice(p, size=sparse, replace=False)
        w[idx] = rng.standard_normal(sparse) * 2.0
    else:
        w = rng.standard_normal(p)
    y = X @ w + noise * rng.standard_normal(n)
    return X, y, w


# ---------------------------------------------------------------------------
# Streaming blocked encode (DESIGN §7): data larger than host memory
# ---------------------------------------------------------------------------

_LSQ_CHUNK = 4096  # virtual-dataset chunk size; any row range assembles from
                   # whole chunks, so generation is deterministic per (seed,
                   # chunk) regardless of access order or range boundaries.


def lsq_rows(lo: int, hi: int, p: int, *, noise: float = 0.1,
             sparse: int = 0, seed: int = 0):
    """Rows [lo, hi) of a VIRTUAL least-squares dataset, in O(hi - lo) memory.

    Unlike ``lsq_dataset`` (one rng stream — rows depend on everything
    before them), every ``_LSQ_CHUNK``-row chunk here gets its own
    counter-keyed generator, so any shard of an arbitrarily large dataset
    can be produced independently: the enabler for streaming blocked encode.
    Returns (X_rows, y_rows, w) with the SAME ground-truth w for every call.
    """
    rng_w = np.random.default_rng([seed, 0])
    if sparse:
        w = np.zeros(p)
        idx = rng_w.choice(p, size=sparse, replace=False)
        w[idx] = rng_w.standard_normal(sparse) * 2.0
    else:
        w = rng_w.standard_normal(p)
    xs, ys = [], []
    for c in range(lo // _LSQ_CHUNK, -(-hi // _LSQ_CHUNK) if hi > lo else 0):
        rng = np.random.default_rng([seed, 1 + c])
        Xc = rng.standard_normal((_LSQ_CHUNK, p))
        yc = Xc @ w + noise * rng.standard_normal(_LSQ_CHUNK)
        a = max(lo - c * _LSQ_CHUNK, 0)
        b = min(hi - c * _LSQ_CHUNK, _LSQ_CHUNK)
        xs.append(Xc[a:b])
        ys.append(yc[a:b])
    if not xs:
        return np.zeros((0, p)), np.zeros(0), w
    return np.concatenate(xs), np.concatenate(ys), w


def logreg_rows(lo: int, hi: int, p: int, *, density: float = 0.1,
                noise: float = 0.1, seed: int = 0):
    """Rows [lo, hi) of a VIRTUAL rcv1-like sparse logistic dataset.

    Same chunk-deterministic convention as ``lsq_rows``: every
    ``_LSQ_CHUNK``-row chunk gets its own counter-keyed generator, so any
    shard can be produced independently of access order.  Features are
    sparse-exponential (density ``density``), row-normalized to unit norm;
    labels are ``sign(X w + noise * eps)`` in {-1, +1} for a fixed
    ground-truth ``w``.  Returns (X_rows, labels_rows, w).
    """
    rng_w = np.random.default_rng([seed, 0])
    w = rng_w.standard_normal(p)
    xs, ls = [], []
    for c in range(lo // _LSQ_CHUNK, -(-hi // _LSQ_CHUNK) if hi > lo else 0):
        rng = np.random.default_rng([seed, 1 + c])
        Xc = ((rng.random((_LSQ_CHUNK, p)) < density)
              * rng.exponential(1.0, (_LSQ_CHUNK, p)))
        Xc = Xc / np.maximum(np.linalg.norm(Xc, axis=1, keepdims=True), 1e-9)
        lc = np.sign(Xc @ w + noise * rng.standard_normal(_LSQ_CHUNK))
        lc[lc == 0] = 1.0
        a = max(lo - c * _LSQ_CHUNK, 0)
        b = min(hi - c * _LSQ_CHUNK, _LSQ_CHUNK)
        xs.append(Xc[a:b])
        ls.append(lc[a:b])
    if not xs:
        return np.zeros((0, p)), np.zeros(0), w
    return np.concatenate(xs), np.concatenate(ls), w


def logreg_dataset(n: int, p: int, *, density: float = 0.1,
                   noise: float = 0.1, seed: int = 0):
    """Sparse logistic-regression data (rcv1-like) for the paper's §5.3
    workload; thin whole-dataset wrapper over ``logreg_rows``."""
    return logreg_rows(0, n, p, density=density, noise=noise, seed=seed)


_MF_USER_CHUNK = 512  # user-chunk size for deterministic ratings generation


def mf_ratings_dataset(users: int, movies: int, *, rank: int = 4,
                       density: float = 0.08, train_frac: float = 0.8,
                       noise: float = 0.3, seed: int = 0):
    """MovieLens-protocol synthetic ratings (paper §5.2, Tables 2-3).

    Low-rank + user/movie bias + noise, rounded to half-stars and clipped to
    [1, 5]; ~``density`` of entries observed, split ``train_frac``/rest.
    Movie factors come from one counter-keyed stream and every
    ``_MF_USER_CHUNK`` block of users from its own — the same
    chunk-deterministic convention as ``lsq_rows``, so a prefix of users is
    stable under growth of ``users``.  Returns (R, train_mask, test_mask).
    """
    rng_v = np.random.default_rng([seed, 0])
    V = rng_v.standard_normal((movies, rank)) * 0.5
    bv = rng_v.standard_normal(movies) * 0.3
    R = np.zeros((users, movies))
    obs = np.zeros((users, movies), dtype=bool)
    train = np.zeros((users, movies), dtype=bool)
    for c in range(-(-users // _MF_USER_CHUNK)):
        rng = np.random.default_rng([seed, 1 + c])
        rows = min(users - c * _MF_USER_CHUNK, _MF_USER_CHUNK)
        U = rng.standard_normal((_MF_USER_CHUNK, rank))[:rows] * 0.5
        bu = rng.standard_normal(_MF_USER_CHUNK)[:rows] * 0.3
        Rc = (3.0 + U @ V.T + bu[:, None] + bv[None, :]
              + noise * rng.standard_normal((_MF_USER_CHUNK, movies))[:rows])
        sl = slice(c * _MF_USER_CHUNK, c * _MF_USER_CHUNK + rows)
        R[sl] = np.clip(np.round(Rc * 2) / 2, 1.0, 5.0)
        obs[sl] = rng.random((_MF_USER_CHUNK, movies))[:rows] < density
        train[sl] = obs[sl] & (
            rng.random((_MF_USER_CHUNK, movies))[:rows] < train_frac)
    return R, train, obs & ~train


def stream_worker_blocks(enc, m: int, rows_fn):
    """Encode worker-by-worker without ever holding the full dataset.

    ``enc`` is any ``LinearEncoder``; ``rows_fn(lo, hi)`` returns the raw
    data rows [lo, hi) as an ``(hi - lo, q)`` array.  For each worker the
    generator materializes ONLY the input coordinates that worker's encoded
    rows depend on (``enc.input_slice``) and yields
    ``(i, S_i X)``.  With a block-diagonal encoder each worker touches one
    shard, so peak memory is one shard + one encoded block — data whose
    dense encoding matrix (or even X itself) exceeds host memory streams
    through.  Mixing encoders (dense, fast-hadamard) declare a full-width
    input slice and degrade to whole-dataset pulls.
    """
    enc = enc.with_workers(m)
    for i in range(m):
        sl = enc.input_slice(i)
        yield i, np.asarray(enc.worker_block_local(i, rows_fn(sl.start,
                                                              sl.stop)))
