"""Shared model utilities: parameter-definition trees, norms, activations.

Parameters are declared once as ``pdef(shape, axes)`` descriptor trees; the
same tree yields (a) initialized jnp arrays and (b) logical-axis trees that
``repro.sharding`` maps to mesh ``PartitionSpec``s.  Logical axis vocabulary:

    vocab, embed, heads, kv, head_dim, ff, expert, d_inner, d_state, dt_rank,
    conv, stack (the scanned period-repeat axis), None (replicated)
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["pdef", "tree_init", "tree_axes", "stack_defs", "rmsnorm",
           "layernorm", "act_fn", "softcap", "Dtype", "cast"]

_PARAM = "__pdef__"


def pdef(shape, axes, init: str = "normal", scale: float | None = None,
         fan_in: int | None = None):
    """Declare a parameter: shape, logical axes (len == ndim), init kind.

    ``fan_in`` overrides the default (= prod(shape[:-1])) used for the
    1/sqrt(fan_in) normal init — needed for layouts like (embed, heads, hd)
    where the contraction dim is only ``embed``.
    """
    assert len(shape) == len(axes), (shape, axes)
    return {_PARAM: True, "shape": tuple(int(s) for s in shape),
            "axes": tuple(axes), "init": init, "scale": scale,
            "fan_in": fan_in}


def _is_def(x) -> bool:
    return isinstance(x, dict) and x.get(_PARAM) is True


def _materialize(d, key, dtype):
    shape, init, scale = d["shape"], d["init"], d["scale"]
    if init == "zeros":
        return jnp.zeros(shape, dtype)
    if init == "ones":
        return jnp.ones(shape, dtype)
    if init == "normal":
        fan = d["fan_in"] or int(math.prod(shape[:-1])) or 1
        s = scale if scale is not None else 1.0 / math.sqrt(max(fan, 1))
        return (s * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    if init == "mamba_dt_bias":
        # softplus^-1 of dt in [1e-3, 0.1], standard mamba init
        u = jax.random.uniform(key, shape, jnp.float32,
                               math.log(1e-3), math.log(1e-1))
        dt = jnp.exp(u)
        return (dt + jnp.log1p(-jnp.exp(-dt))).astype(dtype)
    if init == "mamba_A_log":
        # A = -(1..d_state) broadcast: log of it
        n = shape[-1]
        a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), shape)
        return jnp.log(a).astype(dtype)
    raise ValueError(f"unknown init {init}")


def tree_init(defs: Any, key: jax.Array, dtype=jnp.float32):
    """Materialize a descriptor tree into a parameter pytree."""
    leaves = []

    def walk(d, path):
        if _is_def(d):
            leaves.append((path, d))
        elif isinstance(d, dict):
            for k in sorted(d):
                if k == _PARAM:
                    continue
                walk(d[k], path + (k,))
        else:
            raise TypeError(f"bad def node at {path}: {type(d)}")

    walk(defs, ())
    keys = jax.random.split(key, max(len(leaves), 1))
    out: dict = {}
    for (path, d), k in zip(leaves, keys):
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = _materialize(d, k, dtype)
    return out


def tree_axes(defs: Any):
    """Extract the logical-axes tree (same structure, tuples at leaves)."""
    if _is_def(defs):
        return defs["axes"]
    return {k: tree_axes(v) for k, v in defs.items() if k != _PARAM}


def stack_defs(defs: Any, n: int):
    """Prepend a scanned 'stack' axis of size n to every param in the tree."""
    if _is_def(defs):
        return pdef((n,) + defs["shape"], ("stack",) + defs["axes"],
                    init=defs["init"], scale=defs["scale"],
                    fan_in=defs["fan_in"])
    return {k: stack_defs(v, n) for k, v in defs.items() if k != _PARAM}


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))
            + bias.astype(jnp.float32)).astype(dt)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    """Gemma2-style logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


class Dtype:
    @staticmethod
    def of(name: str):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
                "float16": jnp.float16}[name]


def cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(
        x.dtype, jnp.floating) else x, tree)
