"""Rotary position embeddings, including Qwen2-VL M-RoPE (arXiv:2409.12191).

M-RoPE splits the head_dim/2 rotary frequencies into (temporal, height,
width) sections, each rotated by its own position stream.  Text tokens carry
identical (t, h, w) positions, reducing to standard 1-D RoPE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rope_angles", "apply_rope", "mrope_angles", "sinusoidal_positions"]


def rope_angles(positions: jax.Array, head_dim: int,
                theta: float = 10000.0) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for integer positions.

    positions: (...,) int32 -> cos, sin each (..., head_dim // 2) float32.
    """
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(positions: jax.Array, head_dim: int,
                 sections: tuple[int, int, int],
                 theta: float = 10000.0) -> tuple[jax.Array, jax.Array]:
    """M-RoPE cos/sin. positions: (3, B, S) int32 for (t, h, w) streams.

    sections are sizes over the head_dim/2 frequency axis, sum == head_dim/2.
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang_all = positions.astype(jnp.float32)[..., None] * freqs  # (3, B, S, half)
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        parts.append(ang_all[i, ..., start:start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)                       # (B, S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); cos/sin: (B, S, hd/2) -> rotated x (same dtype)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # broadcast over heads
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dt)


def sinusoidal_positions(positions: jax.Array, d_model: int) -> jax.Array:
    """Additive sinusoidal embeddings (whisper-style stub frontend)."""
    half = d_model // 2
    freqs = 10000.0 ** (-jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
