"""Model driver: builds any assigned architecture from its ArchConfig.

Decoder-only, MoE, hybrid (attn+mamba), xLSTM, encoder-decoder (whisper) and
VLM (qwen2-vl) all share the same machinery:

  * parameters: descriptor trees (models.common) — one period of blocks,
    stacked over ``n_periods`` and scanned (DESIGN §5);
  * three execution paths: ``forward`` (full-seq, train), ``prefill``
    (full-seq + cache build), ``decode_step`` (one token + cache);
  * logits are tied to the token embedding.

Caches are per-period-position NamedTuples stacked over n_periods, matching
the scan layout.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, BlockSpec
from . import attention as attn
from . import mamba as mb
from . import xlstm as xl
from .common import (pdef, tree_init, tree_axes, stack_defs, rmsnorm,
                     layernorm, softcap, Dtype)
from .mlp import mlp_defs, mlp_apply
from .moe import moe_defs, moe_apply
from .rope import rope_angles, mrope_angles, apply_rope, sinusoidal_positions

__all__ = ["param_defs", "init_params", "param_axes", "forward", "prefill",
           "decode_step", "init_caches", "lm_loss", "Model"]


# ------------------------------------------------------------ param defs ---

def _norm_defs(cfg, name):
    d = cfg.d_model
    if cfg.norm == "rmsnorm":
        return {name: pdef((d,), ("embed",), init="zeros")}
    return {name: pdef((d,), ("embed",), init="zeros"),
            name + "_b": pdef((d,), ("embed",), init="zeros")}


def _apply_norm(cfg, p, name, x):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p[name])
    return layernorm(x, p[name], p[name + "_b"])


def _block_defs(cfg, spec: BlockSpec):
    d = {}
    d.update(_norm_defs(cfg, "norm1"))
    if spec.kind == "attn":
        d.update(attn.attn_defs(cfg))
        if spec.cross_attn:
            d.update(_norm_defs(cfg, "normc"))
            d.update(attn.attn_defs(cfg, cross=True))
    elif spec.kind == "mamba":
        d.update(mb.mamba_defs(cfg))
    elif spec.kind == "mlstm":
        d.update(xl.mlstm_defs(cfg))
    elif spec.kind == "slstm":
        d.update(xl.slstm_defs(cfg))
    else:
        raise ValueError(spec.kind)
    if spec.mlp:
        d.update(_norm_defs(cfg, "norm2"))
        d.update(moe_defs(cfg) if spec.moe else mlp_defs(cfg))
    if cfg.post_block_norm:
        d.update(_norm_defs(cfg, "postn1"))
        if spec.mlp:
            d.update(_norm_defs(cfg, "postn2"))
    return d


def param_defs(cfg: ArchConfig):
    defs: dict = {
        "embed": pdef((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                      scale=1.0),
        "blocks": {str(i): stack_defs(_block_defs(cfg, s), cfg.n_periods)
                   for i, s in enumerate(cfg.period)},
    }
    defs.update(_norm_defs(cfg, "final_norm"))
    if cfg.n_enc_layers:
        enc_spec = BlockSpec("attn")
        defs["encoder"] = {
            "blocks": stack_defs(_block_defs(cfg, enc_spec), cfg.n_enc_layers),
        }
        defs["encoder"].update(_norm_defs(cfg, "enc_norm"))
    if cfg.n_patches:
        defs["projector"] = pdef((cfg.d_vision, cfg.d_model),
                                 (None, "embed"))
    return defs


def init_params(cfg: ArchConfig, key: jax.Array):
    return tree_init(param_defs(cfg), key, Dtype.of(cfg.param_dtype))


def param_axes(cfg: ArchConfig):
    return tree_axes(param_defs(cfg))


# ------------------------------------------------------------- rope ctx ----

def _rope_ctx(cfg: ArchConfig, positions: jax.Array,
              mrope_positions: Optional[jax.Array]):
    """cos/sin for the given positions; positions: (S,) or scalar decode."""
    if cfg.mrope_sections is not None and mrope_positions is not None:
        return mrope_angles(mrope_positions, cfg.hd, cfg.mrope_sections,
                            cfg.rope_theta)                # (B, S, half)
    if cfg.learned_pos:  # whisper-style: additive sinusoidal, no rotary
        return None
    cos, sin = rope_angles(positions, cfg.hd, cfg.rope_theta)  # (S, half)
    return cos[None], sin[None]


def _make_rope_fn(ctx):
    if ctx is None:
        return lambda t, pos=None: t
    cos, sin = ctx
    return lambda t, pos=None: apply_rope(t, cos, sin)


# ----------------------------------------------------------- block apply ---

def _attn_full(bp, spec, x, cfg, rope_ctx, causal, want_cache, enc_out,
               cache_len=None):
    """Full-sequence attention sublayer. Returns (delta, cache|None)."""
    B, S, _ = x.shape
    q, k, v = attn.qkv_proj(bp, x)
    rope_fn = _make_rope_fn(rope_ctx)
    q, k = rope_fn(q), rope_fn(k)
    if cfg.seq_parallel_attn:
        # O2 (§Perf): when heads don't divide the model axis, shard the
        # QUERY SEQUENCE over `model` instead — attention compute stays
        # 256-way parallel for 24/28/12-head archs.
        from jax.sharding import PartitionSpec as P
        q = jax.lax.with_sharding_constraint(q, P(None, "model", None, None))
    pos = jnp.arange(S, dtype=jnp.int32)
    valid = jnp.ones((S,), bool)
    o = attn.attention(q, k, v, causal=causal, window=spec.window,
                       cap=cfg.attn_softcap, qpos=pos, kpos=pos, kvalid=valid,
                       chunk=cfg.attn_chunk, banded=cfg.banded_window)
    if cfg.seq_parallel_attn:
        from jax.sharding import PartitionSpec as P
        o = jax.lax.with_sharding_constraint(o, P(None, "model", None, None))
    delta = attn.out_proj(bp, o)
    cache = None
    if want_cache:
        W = spec.window
        if W is not None and S > W:
            assert S % W == 0, "ring-buffer prefill needs S % window == 0"
            k, v = k[:, S - W:], v[:, S - W:]
        else:
            # Pre-allocate decode headroom (ring size capped at the window).
            target = cache_len if cache_len is not None else S
            if W is not None:
                target = min(target, W)
            if target > S:
                pad = ((0, 0), (0, target - S), (0, 0), (0, 0))
                k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        cache = attn.AttnCache(k, v)
    if spec.cross_attn:
        xc = _apply_norm(cfg, bp, "normc", x)
        qc, _, _ = attn.qkv_proj(bp, xc, pre="c")
        F = enc_out.shape[1]
        ck = jnp.einsum("bfd,dhk->bfhk", enc_out, bp["cwk"])
        cv = jnp.einsum("bfd,dhk->bfhk", enc_out, bp["cwv"])
        oc = attn.attention(qc, ck, cv, causal=False, window=None, cap=None,
                            qpos=pos, kpos=jnp.arange(F, dtype=jnp.int32),
                            kvalid=jnp.ones((F,), bool), chunk=cfg.attn_chunk)
        delta = delta + attn.out_proj(bp, oc, pre="c")
        if want_cache:
            cache = (cache, attn.AttnCache(ck, cv))
    return delta, cache


def _block_full(bp, spec: BlockSpec, x, cfg, rope_ctx, aux, *, causal=True,
                want_cache=False, enc_out=None, cache_len=None):
    """One block, full-sequence. Returns (x, cache, aux)."""
    if cfg.seq_parallel_mlp:
        from jax.sharding import PartitionSpec as P
        x = jax.lax.with_sharding_constraint(x, P(None, "model", None))
    h = _apply_norm(cfg, bp, "norm1", x)
    cache = None
    if spec.kind == "attn":
        delta, cache = _attn_full(bp, spec, h, cfg, rope_ctx, causal,
                                  want_cache, enc_out, cache_len=cache_len)
    elif spec.kind == "mamba":
        out = mb.mamba_apply(bp, h, cfg, return_cache=want_cache)
        delta, cache = out if want_cache else (out, None)
    elif spec.kind == "mlstm":
        out = xl.mlstm_apply(bp, h, cfg, return_cache=want_cache)
        delta, cache = out if want_cache else (out, None)
    elif spec.kind == "slstm":
        out = xl.slstm_apply(bp, h, cfg, return_cache=want_cache)
        delta, cache = out if want_cache else (out, None)
    if cfg.post_block_norm:
        delta = _apply_norm(cfg, bp, "postn1", delta)
    x = x + delta
    if spec.mlp:
        h2 = _apply_norm(cfg, bp, "norm2", x)
        if spec.moe:
            delta2, losses = moe_apply(bp, h2, cfg)
            aux = {k: aux.get(k, 0.0) + v for k, v in losses.items()}
        else:
            delta2 = mlp_apply(bp, h2, cfg)
        if cfg.post_block_norm:
            delta2 = _apply_norm(cfg, bp, "postn2", delta2)
        x = x + delta2
    return x, cache, aux


def _block_decode(bp, spec: BlockSpec, x, cfg, cache, index, rope_decode):
    """One block, single-token decode. Returns (x, new_cache)."""
    h = _apply_norm(cfg, bp, "norm1", x)
    if spec.kind == "attn":
        if spec.cross_attn:
            self_cache, cross_cache = cache
        else:
            self_cache = cache
        delta, new_self = attn.decode_attend(
            bp, h, self_cache, index, cfg=cfg, window=spec.window,
            cap=cfg.attn_softcap, rope_fn=rope_decode)
        if spec.cross_attn:
            xc = _apply_norm(cfg, bp, "normc", x)
            qc = jnp.einsum("bsd,dhk->bshk", xc, bp["cwq"])
            F = cross_cache.k.shape[1]
            oc = attn.attention(
                qc, cross_cache.k, cross_cache.v, causal=False, window=None,
                cap=None, qpos=jnp.zeros((1,), jnp.int32),
                kpos=jnp.arange(F, dtype=jnp.int32),
                kvalid=jnp.ones((F,), bool), chunk=cfg.attn_chunk)
            delta = delta + attn.out_proj(bp, oc, pre="c")
            new_cache = (new_self, cross_cache)
        else:
            new_cache = new_self
    elif spec.kind == "mamba":
        delta, new_cache = mb.mamba_decode(bp, h, cache, cfg)
    elif spec.kind == "mlstm":
        delta, new_cache = xl.mlstm_decode(bp, h, cache, cfg)
    elif spec.kind == "slstm":
        delta, new_cache = xl.slstm_decode(bp, h, cache, cfg)
    if cfg.post_block_norm:
        delta = _apply_norm(cfg, bp, "postn1", delta)
    x = x + delta
    if spec.mlp:
        h2 = _apply_norm(cfg, bp, "norm2", x)
        if spec.moe:
            delta2, _ = moe_apply(bp, h2, cfg)
        else:
            delta2 = mlp_apply(bp, h2, cfg)
        if cfg.post_block_norm:
            delta2 = _apply_norm(cfg, bp, "postn2", delta2)
        x = x + delta2
    return x, new_cache


# ------------------------------------------------------------ remat glue ---

def _maybe_remat(cfg, fn):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# -------------------------------------------------------------- encoder ----

def _encode(params, cfg: ArchConfig, enc_embeds):
    """Whisper-style encoder over stub frame embeddings (B, F, d)."""
    ep = params["encoder"]
    B, F, _ = enc_embeds.shape
    x = enc_embeds + sinusoidal_positions(
        jnp.arange(F), cfg.d_model)[None].astype(enc_embeds.dtype)
    spec = BlockSpec("attn")

    def body(x, bp):
        x, _, _ = _block_full(bp, spec, x, cfg, None, {},
                              causal=cfg.causal_encoder, want_cache=False)
        return x, None

    x, _ = jax.lax.scan(_maybe_remat(cfg, body), x, ep["blocks"])
    return _apply_norm(cfg, ep, "enc_norm", x)


# ---------------------------------------------------------- embed/logits ---

def _embed_inputs(params, cfg: ArchConfig, tokens, patch_embeds,
                  positions=None):
    dt = Dtype.of(cfg.dtype)
    x = params["embed"][tokens].astype(dt)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    if cfg.n_patches and patch_embeds is not None:
        proj = jnp.einsum("bnv,vd->bnd", patch_embeds.astype(dt),
                          params["projector"].astype(dt))
        # patches occupy the first n_patches positions of the stream
        x = jnp.concatenate([proj, x[:, cfg.n_patches:]], axis=1)
    if cfg.learned_pos:
        if positions is None:
            positions = jnp.arange(x.shape[1])
        x = x + sinusoidal_positions(positions, cfg.d_model)[None].astype(dt)
    return x


def _logits(params, cfg: ArchConfig, x):
    x = _apply_norm(cfg, params, "final_norm", x)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"],
                        preferred_element_type=jnp.float32)
    return softcap(logits, cfg.final_softcap)


# ------------------------------------------------------------ main paths ---

def forward(params, cfg: ArchConfig, tokens, *, patch_embeds=None,
            mrope_positions=None, enc_embeds=None):
    """Full-sequence forward -> (logits (B, S, V) f32, aux dict)."""
    x = _embed_inputs(params, cfg, tokens, patch_embeds)
    S = x.shape[1]
    rope_ctx = _rope_ctx(cfg, jnp.arange(S, dtype=jnp.int32), mrope_positions)
    enc_out = _encode(params, cfg, enc_embeds) if cfg.n_enc_layers else None
    specs = cfg.period

    def period_body(carry, bps):
        x, aux = carry
        for i, spec in enumerate(specs):
            x, _, aux = _block_full(bps[str(i)], spec, x, cfg, rope_ctx, aux,
                                    want_cache=False, enc_out=enc_out)
        return (x, aux), None

    aux0 = {"load_balance": jnp.zeros((), jnp.float32),
            "router_z": jnp.zeros((), jnp.float32)}
    (x, aux), _ = jax.lax.scan(_maybe_remat(cfg, period_body), (x, aux0),
                               params["blocks"])
    return _logits(params, cfg, x), aux


def prefill(params, cfg: ArchConfig, tokens, *, patch_embeds=None,
            mrope_positions=None, enc_embeds=None, cache_len=None):
    """Full-sequence forward building caches -> (last-pos logits, caches).

    ``cache_len`` > S pre-allocates decode headroom in non-windowed caches.
    """
    x = _embed_inputs(params, cfg, tokens, patch_embeds)
    S = x.shape[1]
    rope_ctx = _rope_ctx(cfg, jnp.arange(S, dtype=jnp.int32), mrope_positions)
    enc_out = _encode(params, cfg, enc_embeds) if cfg.n_enc_layers else None
    specs = cfg.period

    def period_body(x, bps):
        caches = []
        for i, spec in enumerate(specs):
            x, cache, _ = _block_full(bps[str(i)], spec, x, cfg, rope_ctx, {},
                                      want_cache=True, enc_out=enc_out,
                                      cache_len=cache_len)
            caches.append(cache)
        return x, tuple(caches)

    x, caches = jax.lax.scan(period_body, x, params["blocks"])
    logits = _logits(params, cfg, x[:, -1:])
    return logits, caches


def decode_step(params, cfg: ArchConfig, token, caches, index, *,
                mrope_positions=None):
    """One decode step. token: (B, 1) int32; index: scalar current position.

    Returns (logits (B, 1, V), new caches).
    """
    x = _embed_inputs(params, cfg, token, None,
                      positions=jnp.asarray(index)[None])

    if cfg.mrope_sections is not None:
        pos3 = jnp.broadcast_to(jnp.asarray(index, jnp.int32),
                                (3, token.shape[0], 1))
        rope_ctx = mrope_angles(pos3, cfg.hd, cfg.mrope_sections,
                                cfg.rope_theta)
        rope_decode = _make_rope_fn(rope_ctx)
    elif cfg.learned_pos:
        rope_decode = lambda t, pos=None: t
    else:
        def rope_decode(t, pos):
            cos, sin = rope_angles(pos, cfg.hd, cfg.rope_theta)
            return apply_rope(t, cos[None], sin[None])

    specs = cfg.period

    def period_body(x, xs):
        bps, caches_p = xs
        new = []
        for i, spec in enumerate(specs):
            x, nc = _block_decode(bps[str(i)], spec, x, cfg, caches_p[i],
                                  index, rope_decode)
            new.append(nc)
        return x, tuple(new)

    x, new_caches = jax.lax.scan(period_body, x, (params["blocks"], caches))
    return _logits(params, cfg, x), new_caches


def init_caches(cfg: ArchConfig, B: int, cache_len: int):
    """Zero caches matching prefill's structure (stacked over n_periods)."""
    dt = Dtype.of(cfg.dtype)
    per_pos = []
    for spec in cfg.period:
        if spec.kind == "attn":
            C = min(cache_len, spec.window) if spec.window else cache_len
            c = attn.init_kv_cache(B, C, cfg.n_kv, cfg.hd, dt)
            if spec.cross_attn:
                c = (c, attn.init_kv_cache(B, max(cfg.n_enc_frames, 1),
                                           cfg.n_kv, cfg.hd, dt))
        elif spec.kind == "mamba":
            c = mb.init_mamba_cache(cfg, B, dt)
        elif spec.kind == "mlstm":
            c = xl.init_mlstm_cache(cfg, B, dt)
        elif spec.kind == "slstm":
            c = xl.init_slstm_cache(cfg, B, dt)
        per_pos.append(c)
    stack = lambda t: jnp.broadcast_to(t[None], (cfg.n_periods,) + t.shape)
    return jax.tree.map(stack, tuple(per_pos))


# ---------------------------------------------------------- param counts ---

def count_params(cfg: ArchConfig, active_only: bool = False) -> float:
    """Total (or MoE-active) parameter count from the descriptor tree.

    active_only scales expert weights by top_k / n_experts — the N used in
    MODEL_FLOPS = 6 N D for MoE (§Roofline).
    """
    import math as _math

    total = 0.0

    def walk(d):
        nonlocal total
        if isinstance(d, dict) and d.get("__pdef__") is True:
            return
        for k, v in d.items():
            if k == "__pdef__":
                continue
            if isinstance(v, dict) and v.get("__pdef__") is True:
                n = float(_math.prod(v["shape"]))
                if active_only and k.startswith("moe_w") and cfg.n_experts:
                    n *= cfg.top_k / cfg.n_experts
                total += n
            else:
                walk(v)

    walk(param_defs(cfg))
    return total


# ------------------------------------------------------------------ loss ---

def lm_loss(logits, labels, weights=None):
    """Weighted next-token cross entropy. logits: (B,S,V) f32; labels (B,S)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if weights is None:
        weights = jnp.ones_like(ll)
    return -(ll * weights).sum() / jnp.maximum(weights.sum(), 1.0)


@dataclasses.dataclass(frozen=True)
class Model:
    """Convenience bundle of the functional API for one architecture."""
    cfg: ArchConfig

    def init(self, key):
        return init_params(self.cfg, key)

    def axes(self):
        return param_axes(self.cfg)

    forward = staticmethod(forward)

    def __call__(self, params, tokens, **kw):
        return forward(params, self.cfg, tokens, **kw)

    def prefill(self, params, tokens, **kw):
        return prefill(params, self.cfg, tokens, **kw)

    def decode(self, params, token, caches, index, **kw):
        return decode_step(params, self.cfg, token, caches, index, **kw)
