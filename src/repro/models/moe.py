"""Mixture-of-Experts layer: top-k token-choice routing with sort-based
capacity dispatch (no one-hot dispatch matmuls — gather/scatter only, so
compiled FLOPs track ACTIVE parameters, which matters for the §Roofline
'useful compute' ratio).

Dispatch is vmapped over the batch dim: each batch row sorts its own S*k
assignments, so under data-parallel sharding the sort stays device-local and
the only cross-device traffic is the (B, E, C, d) expert all-to-all that XLA
inserts when experts are sharded over the ``model`` axis (DESIGN §5).

Aux losses: Switch-style load-balance + router z-loss, returned for logging
and added to the training objective with cfg.router_aux_weight.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import pdef, act_fn

__all__ = ["moe_defs", "moe_apply"]


def moe_defs(cfg):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": pdef((d, E), ("embed", None), scale=0.02),
        "moe_wi": pdef((E, d, f), ("expert", "embed", "ff"), fan_in=d),
        "moe_wg": pdef((E, d, f), ("expert", "embed", "ff"), fan_in=d),
        "moe_wo": pdef((E, f, d), ("expert", "ff", "embed"), fan_in=f),
    }


def _dispatch_one(x, expert_ids, weights, E: int, C: int):
    """Per-batch-row dispatch. x: (S, d); expert_ids/weights: (S, k).

    Returns (buffer (E, C, d), combine metadata) using argsort grouping.
    """
    S, k = expert_ids.shape
    flat_e = expert_ids.reshape(-1)                     # (S*k,)
    flat_w = weights.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(S), k)             # token index per slot

    order = jnp.argsort(flat_e)                         # group by expert
    e_sorted = flat_e[order]
    t_sorted = flat_tok[order]
    w_sorted = flat_w[order]

    # Rank of each assignment within its expert group.
    counts = jnp.bincount(flat_e, length=E)             # (E,)
    offsets = jnp.cumsum(counts) - counts               # exclusive prefix
    rank = jnp.arange(S * k) - offsets[e_sorted]
    keep = rank < C                                     # capacity drop
    rank_c = jnp.where(keep, rank, 0)

    buf = jnp.zeros((E, C, x.shape[-1]), x.dtype)
    buf = buf.at[e_sorted, rank_c].add(
        jnp.where(keep[:, None], x[t_sorted], 0.0))
    return buf, (e_sorted, rank_c, t_sorted, w_sorted, keep)


def _combine_one(y, meta, S: int):
    """y: (E, C, d) expert outputs -> (S, d) weighted combine."""
    e_sorted, rank_c, t_sorted, w_sorted, keep = meta
    gathered = y[e_sorted, rank_c]                      # (S*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0.0) * w_sorted[:, None]
    out = jnp.zeros((S, y.shape[-1]), y.dtype)
    return out.at[t_sorted].add(gathered)


def moe_apply(p, x, cfg):
    """x: (B, S, d) -> (out (B, S, d), aux_losses dict)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(int(cfg.capacity_factor * S * k / E), 1)

    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)              # (B, S, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # Aux losses (Switch): load balance over expert fractions x router probs.
    me = jnp.mean(probs, axis=(0, 1))                   # (E,)
    ce = jnp.mean(
        (jax.nn.one_hot(top_e[..., 0], E)), axis=(0, 1))
    aux_lb = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    buf, meta = jax.vmap(
        lambda xb, eb, wb: _dispatch_one(xb, eb, wb, E, C))(
            x, top_e, top_w.astype(x.dtype))            # buf: (B, E, C, d)

    if getattr(cfg, "moe_local_dispatch", False):
        # §Perf B5: keep the data-dependent gather/scatter local to the
        # batch shard; only the expert einsum below moves data (one clean
        # all-to-all) instead of SPMD permute-chains through the scatter.
        from jax.sharding import PartitionSpec as P
        buf = jax.lax.with_sharding_constraint(
            buf, P(("data",), None, None, None))

    act = act_fn(cfg.act)
    h = act(jnp.einsum("becd,edf->becf", buf, p["moe_wg"])) * jnp.einsum(
        "becd,edf->becf", buf, p["moe_wi"])
    y = jnp.einsum("becf,efd->becd", h, p["moe_wo"])        # (B, E, C, d)

    out = jax.vmap(lambda yb, mb: _combine_one(yb, mb, S))(y, meta)
    return out, {"load_balance": aux_lb, "router_z": z_loss}
