"""Mamba-1 selective SSM block (Jamba's recurrent layer), TPU-adapted.

The CUDA reference is a fused recurrent kernel holding state in SRAM.  The
TPU-native adaptation (DESIGN §3) is a CHUNKED PARALLEL SCAN: the sequence is
split into chunks of ``cfg.mamba_chunk``; a `lax.scan` carries the (B, d_inner,
d_state) state across chunks while `lax.associative_scan` parallelizes within
a chunk (materializing only (B, Q, d_inner, d_state) per chunk, which is
sharded over `model` via the d_inner dim).  The recurrence

    h_t = a_t * h_{t-1} + b_t,   a_t = exp(dt_t A),  b_t = dt_t B_t x_t

composes associatively as (a2*a1, a2*b1 + b2).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import pdef

__all__ = ["mamba_defs", "mamba_apply", "mamba_decode", "MambaCache",
           "init_mamba_cache"]


def _dims(cfg):
    di = cfg.mamba_expand * cfg.d_model
    dtr = cfg.mamba_dt_rank or max(cfg.d_model // 16, 1)
    return di, cfg.mamba_d_state, dtr, cfg.mamba_conv


def mamba_defs(cfg):
    d = cfg.d_model
    di, ds, dtr, k = _dims(cfg)
    return {
        "in_proj": pdef((d, 2 * di), ("embed", "d_inner")),
        "conv_w": pdef((k, di), (None, "d_inner"), scale=1.0 / math.sqrt(k)),
        "conv_b": pdef((di,), ("d_inner",), init="zeros"),
        "x_proj": pdef((di, dtr + 2 * ds), ("d_inner", None)),
        "dt_w": pdef((dtr, di), (None, "d_inner")),
        "dt_b": pdef((di,), ("d_inner",), init="mamba_dt_bias"),
        "A_log": pdef((di, ds), ("d_inner", "d_state"), init="mamba_A_log"),
        "D": pdef((di,), ("d_inner",), init="ones"),
        "out_proj": pdef((di, d), ("d_inner", "embed")),
    }


class MambaCache(NamedTuple):
    conv: jax.Array  # (B, k-1, d_inner) last inputs for the causal conv
    ssm: jax.Array   # (B, d_inner, d_state) recurrent state


def init_mamba_cache(cfg, B: int, dtype) -> MambaCache:
    di, ds, _, k = _dims(cfg)
    return MambaCache(jnp.zeros((B, k - 1, di), dtype),
                      jnp.zeros((B, di, ds), jnp.float32))


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, S, di); w: (k, di) -> (B, S, di)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, j:j + x.shape[1], :] * w[j] for j in range(k))
    return out + b


def _ssm_inputs(p, x_conv):
    """Common selective-SSM input computation. x_conv: (..., di)."""
    di, ds = p["A_log"].shape
    dtr = p["dt_w"].shape[0]
    xdb = jnp.einsum("...d,dk->...k", x_conv, p["x_proj"])
    dt_raw, Bm, Cm = jnp.split(xdb, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("...r,rd->...d", dt_raw, p["dt_w"]).astype(jnp.float32)
        + p["dt_b"].astype(jnp.float32))                # (..., di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))        # (di, ds)
    a = jnp.exp(dt[..., None] * A)                      # (..., di, ds)
    b = (dt[..., None] * Bm.astype(jnp.float32)[..., None, :]
         * x_conv.astype(jnp.float32)[..., None])       # (..., di, ds)
    return a, b, Cm.astype(jnp.float32)


def _scan_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a2 * a1, a2 * b1 + b2


def mamba_apply(p, x, cfg, return_cache: bool = False):
    """Full-sequence forward. x: (B, S, d) -> (B, S, d) [, MambaCache]."""
    B, S, d = x.shape
    di, ds, _, k = _dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_conv = jax.nn.silu(_causal_conv(x_in, p["conv_w"], p["conv_b"]))

    Q = min(cfg.mamba_chunk, S)
    Sp = ((S + Q - 1) // Q) * Q          # pad tail (causal: outputs unaffected)
    if Sp != S:
        # Padded steps would decay the carried state (dt(0) != 0), so the
        # final state is only returned for divisible lengths.
        assert not return_cache, "prefill length must be divisible by chunk"
        x_conv = jnp.pad(x_conv, ((0, 0), (0, Sp - S), (0, 0)))
    nc = Sp // Q
    xc = x_conv.reshape(B, nc, Q, di).transpose(1, 0, 2, 3)  # (nc,B,Q,di)

    def chunk_body(h, xq):
        a, b, Cm = _ssm_inputs(p, xq)                   # (B,Q,di,ds)
        Ac, Bc = jax.lax.associative_scan(_scan_combine, (a, b), axis=1)
        hs = Ac * h[:, None] + Bc                       # (B,Q,di,ds)
        y = jnp.einsum("bqds,bqs->bqd", hs, Cm)         # (B,Q,di)
        return hs[:, -1], y

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    h_last, yc = jax.lax.scan(chunk_body, h0, xc)       # yc: (nc,B,Q,di)
    y = yc.transpose(1, 0, 2, 3).reshape(B, Sp, di)[:, :S]
    x_conv = x_conv[:, :S]
    y = y + p["D"].astype(jnp.float32) * x_conv.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    if return_cache:
        conv_state = x_in[:, S - (k - 1):, :] if S >= k - 1 else jnp.pad(
            x_in, ((0, 0), (k - 1 - S, 0), (0, 0)))
        return out, MambaCache(conv_state, h_last)
    return out


def mamba_decode(p, x, cache: MambaCache, cfg):
    """Single-token step. x: (B, 1, d) -> ((B, 1, d), new cache)."""
    B = x.shape[0]
    di, ds, _, k = _dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)                 # (B,1,di)
    window = jnp.concatenate([cache.conv, x_in], axis=1)  # (B,k,di)
    x_conv = jax.nn.silu(
        jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"])[:, None]
    a, b, Cm = _ssm_inputs(p, x_conv[:, 0])             # (B,di,ds)
    h = a * cache.ssm + b
    y = jnp.einsum("bds,bs->bd", h, Cm)[:, None]        # (B,1,di)
    y = y + p["D"].astype(jnp.float32) * x_conv.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    return out, MambaCache(window[:, 1:], h)
