"""Model zoo: one functional implementation per architecture family."""
from .transformer import (Model, init_params, param_axes, param_defs, forward,
                          prefill, decode_step, init_caches, lm_loss)
from .common import pdef, tree_init, tree_axes
