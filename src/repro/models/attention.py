"""GQA attention with chunked online-softmax, sliding windows, soft-capping,
ring-buffer KV caches, and cross-attention — all pure JAX (jnp/lax).

Memory-efficient attention: KV is processed in chunks of ``cfg.attn_chunk``
with a running (max, denom, acc) carry — the flash-attention recurrence —
so prefill at 32k/524k never materializes an (Sq, Skv) score matrix bigger
than (Sq, chunk).  This is also what keeps the dry-run's HLO temp memory
honest (DESIGN §5).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .common import pdef, softcap

__all__ = ["attn_defs", "qkv_proj", "out_proj", "attention", "init_kv_cache",
           "ring_slot_positions", "decode_attend", "AttnCache"]

_NEG = -0.7 * float(jnp.finfo(jnp.float32).max)


def attn_defs(cfg, cross: bool = False):
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    pre = "c" if cross else ""
    return {
        pre + "wq": pdef((d, H, hd), ("embed", "heads", "head_dim"), fan_in=d),
        pre + "wk": pdef((d, K, hd), ("embed", "kv", "head_dim"), fan_in=d),
        pre + "wv": pdef((d, K, hd), ("embed", "kv", "head_dim"), fan_in=d),
        pre + "wo": pdef((H, hd, d), ("heads", "head_dim", "embed"),
                         fan_in=H * hd),
    }


def qkv_proj(p, x, pre: str = ""):
    """x: (B, S, d) -> q (B,S,H,hd), k (B,S,K,hd), v (B,S,K,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p[pre + "wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p[pre + "wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p[pre + "wv"])
    return q, k, v


def out_proj(p, o, pre: str = ""):
    return jnp.einsum("bshk,hkd->bsd", o, p[pre + "wo"])


def _mask(qpos, kpos, kvalid, causal: bool, window: Optional[int]):
    """(Sq, Skv) boolean mask from integer positions."""
    m = jnp.broadcast_to(kvalid[None, :], (qpos.shape[0], kpos.shape[0]))
    if causal:
        m = m & (kpos[None, :] <= qpos[:, None])
    if window is not None:
        m = m & (kpos[None, :] > qpos[:, None] - window)
    return m


def _scores(q, k, scale, cap):
    """q: (B,K,G,Sq,hd), k: (B,C,K,hd) -> (B,K,G,Sq,C) float32."""
    s = jnp.einsum("bkgsh,bckh->bkgsc", q, k,
                   preferred_element_type=jnp.float32) * scale
    return softcap(s, cap)


def attention(q, k, v, *, causal: bool, window: Optional[int],
              cap: Optional[float], qpos, kpos, kvalid,
              chunk: int = 1024, banded: bool = False) -> jax.Array:
    """Online-softmax GQA attention.

    q: (B, Sq, H, hd);  k, v: (B, Skv, K, hd);  qpos: (Sq,) int32;
    kpos, kvalid: (Skv,).  Returns (B, Sq, H, hd) in q.dtype.
    """
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    scale = hd ** -0.5
    qh = q.reshape(B, Sq, K, G, hd).transpose(0, 2, 3, 1, 4)  # (B,K,G,Sq,hd)

    if Skv <= chunk or Skv % chunk:
        s = _scores(qh, k, scale, cap)
        m = _mask(qpos, kpos, kvalid, causal, window)
        s = jnp.where(m[None, None, None], s, _NEG)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgsc,bckh->bkgsh", p, v.astype(jnp.float32))
        return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)

    if (banded and window is not None and causal and Sq == Skv
            and Skv >= 4 * window and window % chunk == 0):
        return _banded_attention(qh, k, v, window=window, cap=cap,
                                 scale=scale, chunk=chunk, qpos=qpos,
                                 out_dtype=q.dtype)

    n_chunks = Skv // chunk
    kc = k.reshape(B, n_chunks, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    kposc = kpos.reshape(n_chunks, chunk)
    kvalc = kvalid.reshape(n_chunks, chunk)

    def body(carry, xs):
        m_run, l_run, acc = carry
        kb, vb, kp, kv_ok = xs
        s = _scores(qh, kb, scale, cap)                    # (B,K,G,Sq,C)
        msk = _mask(qpos, kp, kv_ok, causal, window)
        s = jnp.where(msk[None, None, None], s, _NEG)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        r = jnp.exp(m_run - m_new)
        # Explicitly zero masked entries: when a whole chunk is masked,
        # s - m_new == 0 would otherwise give weight exp(0) = 1.
        p = jnp.exp(s - m_new[..., None]) * msk[None, None, None]
        l_new = l_run * r + p.sum(axis=-1)
        acc = acc * r[..., None] + jnp.einsum(
            "bkgsc,bckh->bkgsh", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc), None

    init = (jnp.full((B, K, G, Sq), _NEG, jnp.float32),
            jnp.zeros((B, K, G, Sq), jnp.float32),
            jnp.zeros((B, K, G, Sq, hd), jnp.float32))
    (m_run, l_run, acc), _ = jax.lax.scan(body, init, (kc, vc, kposc, kvalc))
    o = acc / jnp.maximum(l_run, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)


def _banded_attention(qh, k, v, *, window, cap, scale, chunk, qpos,
                      out_dtype):
    """Sliding-window self-attention without the O(S^2) masked waste.

    q blocks of size ``chunk`` only visit the ``window/chunk + 1`` KV blocks
    that can fall inside the window — compute drops from S*S to
    S*(window+chunk) (§Perf optimization O1, beyond-paper).

    qh: (B, K, G, S, hd) grouped queries; k, v: (B, S, K, hd).
    """
    B, K, G, S, hd = qh.shape
    nq = S // chunk
    nb = window // chunk + 1                     # KV blocks per q block
    qb = qh.reshape(B, K, G, nq, chunk, hd)
    kb = k.reshape(B, nq, chunk, K, hd)
    vb = v.reshape(B, nq, chunk, K, hd)
    # for q block i, kv blocks i-nb+1 .. i (clamped; out-of-range masked)
    offs = jnp.arange(nq)[:, None] - jnp.arange(nb - 1, -1, -1)[None, :]
    valid_blk = offs >= 0
    gather = jnp.clip(offs, 0, nq - 1)                   # (nq, nb)
    kg = kb[:, gather]                                   # (B, nq, nb, C, K, hd)
    vg = vb[:, gather]
    s = jnp.einsum("bkgiqh,binckh->bkgiqnc", qb, kg,
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, cap)                                  # (B,K,G,nq,Cq,nb,Ckv)
    qp = qpos.reshape(nq, chunk)[:, :, None, None]       # (nq, Cq, 1, 1)
    kp = (gather[:, :, None] * chunk
          + jnp.arange(chunk)[None, None, :])            # (nq, nb, Ckv)
    kp = kp[:, None, :, :]                               # (nq, 1, nb, Ckv)
    msk = ((kp <= qp) & (kp > qp - window)
           & valid_blk[:, None, :, None])                # (nq, Cq, nb, Ckv)
    s = jnp.where(msk[None, None, None], s, _NEG)
    sh = s.shape
    p = jax.nn.softmax(s.reshape(sh[:-2] + (nb * chunk,)),
                       axis=-1).reshape(sh)
    o = jnp.einsum("bkgiqnc,binckh->bkgiqh", p, vg.astype(jnp.float32))
    o = o.reshape(B, K, G, S, hd).transpose(0, 3, 1, 2, 4)
    return o.reshape(B, S, K * G, hd).astype(out_dtype)


class AttnCache(NamedTuple):
    """KV cache for one attention layer (ring buffer when windowed)."""
    k: jax.Array   # (B, C, K, hd)
    v: jax.Array   # (B, C, K, hd)


def init_kv_cache(B: int, cache_len: int, K: int, hd: int,
                  dtype) -> AttnCache:
    return AttnCache(jnp.zeros((B, cache_len, K, hd), dtype),
                     jnp.zeros((B, cache_len, K, hd), dtype))


def ring_slot_positions(cache_len: int, index) -> tuple[jax.Array, jax.Array]:
    """Positions and validity of ring-buffer slots given current length.

    Slot s holds the largest position p < index with p ≡ s (mod cache_len);
    valid iff p >= 0.  For a non-ring (full) cache this reduces to
    pos = s, valid = s < index.
    """
    s = jnp.arange(cache_len, dtype=jnp.int32)
    idx = jnp.asarray(index, jnp.int32)
    p = idx - 1 - jnp.mod(idx - 1 - s, cache_len)
    return p, p >= 0


def decode_attend(p, x, cache: AttnCache, index, *, cfg, window, cap,
                  rope_fn, pre: str = "") -> tuple[jax.Array, AttnCache]:
    """Single-token decode: write (k, v) at slot index % C, attend over cache.

    x: (B, 1, d); index: scalar int32 current position. rope_fn(q_or_k, pos)
    applies rotary for this arch (identity for non-rope archs).
    """
    q, k_new, v_new = qkv_proj(p, x, pre)
    q = rope_fn(q, jnp.asarray(index)[None])
    k_new = rope_fn(k_new, jnp.asarray(index)[None])
    C = cache.k.shape[1]
    slot = jnp.mod(jnp.asarray(index, jnp.int32), C)
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype), slot, axis=1)
    kpos, kvalid = ring_slot_positions(C, index + 1)
    o = attention(q, k, v, causal=True, window=window, cap=cap,
                  qpos=jnp.asarray(index, jnp.int32)[None], kpos=kpos,
                  kvalid=kvalid, chunk=cfg.attn_chunk)
    return out_proj(p, o, pre), AttnCache(k, v)
