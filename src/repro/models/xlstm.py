"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable) and
sLSTM (scalar memory with recurrent gate connections, inherently sequential).

mLSTM uses the stabilized CHUNKWISE form (the TPU-native adaptation of the
fused CUDA kernel): a lax.scan carries the per-head matrix state
(C: dk x dv, n: dk, log-scale m) across chunks; within a chunk the output is
computed in quadratic attention form with exponential-gating decay weights —
all matmuls, MXU-friendly.  sLSTM has genuine recurrent weights R h_{t-1} in
every gate, so it runs as a sequential lax.scan over time (the paper itself
notes sLSTM is not parallelizable).

Stabilization follows the xLSTM appendix: every exponential is taken relative
to a running max m; the hidden read is h = num / max(|den|, exp(-m*)).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import pdef, rmsnorm

__all__ = ["mlstm_defs", "mlstm_apply", "mlstm_decode", "MLSTMCache",
           "init_mlstm_cache", "slstm_defs", "slstm_apply", "slstm_decode",
           "SLSTMCache", "init_slstm_cache"]


# ---------------------------------------------------------------- mLSTM ----

def _mdims(cfg):
    dp = int(cfg.xlstm_proj_factor * cfg.d_model)
    H = cfg.n_heads
    dk = dp // H
    return dp, H, dk


def mlstm_defs(cfg):
    d = cfg.d_model
    dp, H, dk = _mdims(cfg)
    return {
        "up": pdef((d, 2 * dp), ("embed", "d_inner")),
        "wq": pdef((dp, H, dk), ("d_inner", "heads", "head_dim"), fan_in=dp),
        "wk": pdef((dp, H, dk), ("d_inner", "heads", "head_dim"), fan_in=dp),
        "wv": pdef((dp, H, dk), ("d_inner", "heads", "head_dim"), fan_in=dp),
        "wi": pdef((dp, H), ("d_inner", None), scale=0.02),
        "wf": pdef((dp, H), ("d_inner", None), scale=0.02),
        "bi": pdef((H,), (None,), init="zeros"),
        "bf": pdef((H,), (None,), init="ones"),  # bias toward remembering
        "gn": pdef((dp,), ("d_inner",), init="zeros"),
        "down": pdef((dp, d), ("d_inner", "embed")),
    }


class MLSTMCache(NamedTuple):
    C: jax.Array  # (B, H, dk, dk) matrix memory (dv == dk here)
    n: jax.Array  # (B, H, dk) normalizer state
    m: jax.Array  # (B, H) running log-scale


def init_mlstm_cache(cfg, B: int, dtype) -> MLSTMCache:
    _, H, dk = _mdims(cfg)
    return MLSTMCache(jnp.zeros((B, H, dk, dk), jnp.float32),
                      jnp.zeros((B, H, dk), jnp.float32),
                      jnp.full((B, H), -1e30, jnp.float32))


def _mlstm_qkvg(p, x):
    """x: (B, S, d) -> q,k,v (B,S,H,dk) f32, li/lf (B,S,H) f32, z (B,S,dp)."""
    xz = jnp.einsum("bsd,de->bse", x, p["up"])
    xm, z = jnp.split(xz, 2, axis=-1)
    q = jnp.einsum("bse,ehk->bshk", xm, p["wq"]).astype(jnp.float32)
    k = jnp.einsum("bse,ehk->bshk", xm, p["wk"]).astype(jnp.float32)
    k = k / math.sqrt(k.shape[-1])
    v = jnp.einsum("bse,ehk->bshk", xm, p["wv"]).astype(jnp.float32)
    li = (jnp.einsum("bse,eh->bsh", xm, p["wi"])
          + p["bi"]).astype(jnp.float32)                       # log input gate
    lf = jax.nn.log_sigmoid(
        (jnp.einsum("bse,eh->bsh", xm, p["wf"]) + p["bf"]).astype(jnp.float32))
    return q, k, v, li, lf, z, xm


def mlstm_apply(p, x, cfg, return_cache: bool = False):
    """Full-sequence chunkwise mLSTM. x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    dp, H, dk = _mdims(cfg)
    q, k, v, li, lf, z, _ = _mlstm_qkvg(p, x)

    Q = min(cfg.mamba_chunk, S)
    Sp = ((S + Q - 1) // Q) * Q          # pad tail (causal: outputs unaffected)
    if Sp != S:
        assert not return_cache, "prefill length must be divisible by chunk"
        pad = Sp - S
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for t in (q, k, v))
        li, lf = (jnp.pad(t, ((0, 0), (0, pad), (0, 0))) for t in (li, lf))
        z = jnp.pad(z, ((0, 0), (0, pad), (0, 0)))
    nc = Sp // Q

    def cs(t):  # (B,S,...) -> (nc, B, Q, ...)
        return t.reshape((B, nc, Q) + t.shape[2:]).swapaxes(0, 1)

    def body(carry, xs):
        C, n, m = carry                                  # (B,H,dk,dk) etc.
        qc, kc, vc, lic, lfc = xs                        # (B,Q,H,*)
        F = jnp.cumsum(lfc, axis=1)                      # (B,Q,H) log decay
        # intra-chunk log weights: w[t,s] = F_t - F_s + li_s  (s <= t)
        wl = (F[:, :, None] - F[:, None, :]
              + lic[:, None, :, :])                      # (B,Qt,Qs,H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        wl = jnp.where(tri[None, :, :, None], wl, -jnp.inf)
        # inter: log weight of carried state at t: F_t + m
        inter_l = F + m[:, None]                         # (B,Q,H)
        mstar = jnp.maximum(wl.max(axis=2), inter_l)     # (B,Q,H)
        wts = jnp.exp(wl - mstar[:, :, None])            # (B,Qt,Qs,H)
        scores = jnp.einsum("bthk,bshk->btsh", qc, kc) * wts
        num = jnp.einsum("btsh,bshv->bthv", scores, vc)
        den = scores.sum(axis=2)          # q.n intra part: sum_s w_ts (q.k_s)
        w_int = jnp.exp(inter_l - mstar)                 # (B,Q,H)
        num = num + w_int[..., None] * jnp.einsum("bthk,bhkv->bthv", qc, C)
        den = den + w_int * jnp.einsum("bthk,bhk->bth", qc, n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-mstar))[..., None]
        # state update to end of chunk
        total = F[:, -1]                                 # (B,H)
        upd_l = total[:, None] - F + lic                 # (B,Q,H) weight of s
        m_new = jnp.maximum(total + m, upd_l.max(axis=1))
        wu = jnp.exp(upd_l - m_new[:, None])             # (B,Q,H)
        carryw = jnp.exp(total + m - m_new)              # (B,H)
        C_new = carryw[..., None, None] * C + jnp.einsum(
            "bshk,bsh,bshv->bhkv", kc, wu, vc)
        n_new = carryw[..., None] * n + jnp.einsum("bshk,bsh->bhk", kc, wu)
        return (C_new, n_new, m_new), h

    cache0 = init_mlstm_cache(cfg, B, x.dtype)
    xs = (cs(q), cs(k), cs(v), cs(li), cs(lf))
    carry0 = (cache0.C, cache0.n, cache0.m)
    if getattr(cfg, "slstm_shard_batch", False):
        # §Perf O6 (same fix as the sLSTM scan): keep chunked inputs and the
        # matrix-memory carry batch-sharded across chunk iterations.
        from jax.sharding import PartitionSpec as P
        con = lambda t: jax.lax.with_sharding_constraint(
            t, P(*((None, ("data",)) + (None,) * (t.ndim - 2))))
        xs = tuple(con(t) for t in xs)
        carry0 = tuple(jax.lax.with_sharding_constraint(
            t, P(*((("data",),) + (None,) * (t.ndim - 1)))) for t in carry0)
    (C, n, m), hc = jax.lax.scan(body, carry0, xs)
    h = hc.swapaxes(0, 1).reshape(B, Sp, dp)[:, :S]      # (B,S,dp)
    h = rmsnorm(h, p["gn"])                              # per-channel norm
    h = h * jax.nn.silu(z[:, :S])
    out = jnp.einsum("bse,ed->bsd", h.astype(x.dtype), p["down"])
    if return_cache:
        return out, MLSTMCache(C, n, m)
    return out


def mlstm_decode(p, x, cache: MLSTMCache, cfg):
    """Single-step mLSTM. x: (B, 1, d)."""
    B = x.shape[0]
    dp, H, dk = _mdims(cfg)
    q, k, v, li, lf, z, _ = _mlstm_qkvg(p, x)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                  # (B,H,dk)
    li, lf = li[:, 0], lf[:, 0]                          # (B,H)
    m_new = jnp.maximum(lf + cache.m, li)
    fw = jnp.exp(lf + cache.m - m_new)
    iw = jnp.exp(li - m_new)
    C = fw[..., None, None] * cache.C + iw[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = fw[..., None] * cache.n + iw[..., None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, C)
    den = jnp.einsum("bhk,bhk->bh", q, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    h = h.reshape(B, 1, dp)
    h = rmsnorm(h, p["gn"]) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", h.astype(x.dtype), p["down"])
    return out, MLSTMCache(C, n, m_new)


# ---------------------------------------------------------------- sLSTM ----

def _sdims(cfg):
    H = cfg.n_heads
    dh = cfg.d_model // H
    fs = ((4 * cfg.d_model // 3 + 63) // 64) * 64  # post-up-projection 4/3
    return H, dh, fs


def slstm_defs(cfg):
    d = cfg.d_model
    H, dh, fs = _sdims(cfg)
    gates = {}
    for g in "zifo":
        gates[f"w{g}"] = pdef((d, H, dh), ("embed", "heads", "head_dim"),
                              fan_in=d)
        gates[f"r{g}"] = pdef((H, dh, dh), ("heads", "head_dim", None),
                              fan_in=dh, scale=0.5 / math.sqrt(dh))
        gates[f"b{g}"] = pdef((H, dh), ("heads", "head_dim"),
                              init="ones" if g == "f" else "zeros")
    return {
        **gates,
        "gn": pdef((d,), ("embed",), init="zeros"),
        "up": pdef((d, fs), ("embed", "ff")),
        "gate": pdef((d, fs), ("embed", "ff")),
        "down": pdef((fs, d), ("ff", "embed")),
    }


class SLSTMCache(NamedTuple):
    c: jax.Array  # (B, H, dh)
    n: jax.Array  # (B, H, dh)
    m: jax.Array  # (B, H, dh) stabilizer
    h: jax.Array  # (B, H, dh) previous hidden (for recurrent gates)


def init_slstm_cache(cfg, B: int, dtype) -> SLSTMCache:
    H, dh, _ = _sdims(cfg)
    zero = jnp.zeros((B, H, dh), jnp.float32)
    return SLSTMCache(zero, zero, jnp.full((B, H, dh), -1e30, jnp.float32),
                      zero)


def _slstm_cell(p, xz, xi, xf, xo, state: SLSTMCache) -> SLSTMCache:
    """One recurrence step; x*: (B, H, dh) precomputed input projections."""
    h = state.h
    rec = lambda g: jnp.einsum("bhd,hde->bhe", h, p[f"r{g}"])
    z = jnp.tanh(xz + rec("z") + p["bz"])
    li = xi + rec("i") + p["bi"]
    lf = jax.nn.log_sigmoid(xf + rec("f") + p["bf"])
    o = jax.nn.sigmoid(xo + rec("o") + p["bo"])
    m_new = jnp.maximum(lf + state.m, li)
    fw = jnp.exp(lf + state.m - m_new)
    iw = jnp.exp(li - m_new)
    c = fw * state.c + iw * z
    n = jnp.maximum(fw * state.n + iw, jnp.exp(-m_new))
    h_new = o * c / n
    return SLSTMCache(c, n, m_new, h_new)


def _slstm_inputs(p, x):
    """x: (B, S, d) -> per-gate projections, each (B, S, H, dh) f32."""
    proj = lambda g: jnp.einsum(
        "bsd,dhe->bshe", x, p[f"w{g}"]).astype(jnp.float32)
    return proj("z"), proj("i"), proj("f"), proj("o")


def _slstm_post(p, h, x, cfg):
    """GroupNorm + gated post-up-projection; h: (B, S, d)-shaped hidden."""
    h = rmsnorm(h.astype(jnp.float32), p["gn"]).astype(x.dtype)
    u = jax.nn.silu(jnp.einsum("bsd,df->bsf", h, p["gate"])) * jnp.einsum(
        "bsd,df->bsf", h, p["up"])
    return jnp.einsum("bsf,fd->bsd", u, p["down"])


def slstm_apply(p, x, cfg, return_cache: bool = False):
    """Full-sequence sLSTM via sequential scan. x: (B, S, d)."""
    B, S, d = x.shape
    H, dh, _ = _sdims(cfg)
    xz, xi, xf, xo = _slstm_inputs(p, x)
    if getattr(cfg, "slstm_shard_batch", False):
        # §Perf O6: pin the scanned gate projections (and the carry, via
        # state0) to pure batch sharding so the per-timestep dynamic-slice
        # does not reshard on every step.
        from jax.sharding import PartitionSpec as P
        con = lambda t: jax.lax.with_sharding_constraint(
            t, P(("data",), None, None, None))
        xz, xi, xf, xo = con(xz), con(xi), con(xf), con(xo)

    def body(state, xs):
        state = _slstm_cell(p, *xs, state)
        return state, state.h

    state0 = init_slstm_cache(cfg, B, x.dtype)
    if getattr(cfg, "slstm_shard_batch", False):
        from jax.sharding import PartitionSpec as P
        state0 = SLSTMCache(*(jax.lax.with_sharding_constraint(
            t, P(("data",), None, None)) for t in state0))
    state, hs = jax.lax.scan(
        body, state0, (xz.swapaxes(0, 1), xi.swapaxes(0, 1),
                       xf.swapaxes(0, 1), xo.swapaxes(0, 1)))
    h = hs.swapaxes(0, 1).reshape(B, S, d)
    out = _slstm_post(p, h, x, cfg)
    if return_cache:
        return out, state
    return out


def slstm_decode(p, x, cache: SLSTMCache, cfg):
    B = x.shape[0]
    xz, xi, xf, xo = _slstm_inputs(p, x)
    state = _slstm_cell(p, xz[:, 0], xi[:, 0], xf[:, 0], xo[:, 0], cache)
    h = state.h.reshape(B, 1, -1)
    return _slstm_post(p, h, x, cfg), state
