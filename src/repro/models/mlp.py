"""Gated MLP (SwiGLU/GeGLU-style) used by all dense blocks."""
from __future__ import annotations

import jax.numpy as jnp

from .common import pdef, act_fn

__all__ = ["mlp_defs", "mlp_apply"]


def mlp_defs(cfg):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "ffn_wi": pdef((d, f), ("embed", "ff")),
        "ffn_wg": pdef((d, f), ("embed", "ff")),
        "ffn_wo": pdef((f, d), ("ff", "embed")),
    }


def mlp_apply(p, x, cfg):
    act = act_fn(cfg.act)
    h = act(jnp.einsum("bsd,df->bsf", x, p["ffn_wg"])) * jnp.einsum(
        "bsd,df->bsf", x, p["ffn_wi"])
    return jnp.einsum("bsf,fd->bsd", h, p["ffn_wo"])
