from .rules import (logical_rules, make_specs, make_shardings, batch_axes,
                    spec_for_shape)
