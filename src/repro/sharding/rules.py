"""Logical-axis -> mesh PartitionSpec rules (MaxText-style, divisibility-aware).

Every parameter/activation dim carries a logical name (models.common.pdef);
``make_specs`` maps names to mesh axes, silently falling back to replication
when the dim is not divisible by the mesh-axis size (e.g. qwen2's 28 heads on
a 16-way model axis) or when the mesh axis was already consumed by an earlier
dim of the same tensor (e.g. expert weights take `model` for the expert dim,
so their ff dim stays unsharded).
"""
from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["logical_rules", "make_specs", "make_shardings", "batch_axes",
           "spec_for_shape"]


def logical_rules(mesh: Mesh) -> dict:
    """Logical axis -> mesh axis (or tuple of axes for FSDP)."""
    fsdp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return {
        "vocab": "model",
        "ff": "model",
        "heads": "model",
        "kv": "model",
        "expert": "model",
        "d_inner": "model",
        "embed": fsdp,           # FSDP: weight-shard the d_model dim
    }


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh: Mesh, entry) -> int:
    if isinstance(entry, tuple):
        return math.prod(mesh.shape[a] for a in entry)
    return mesh.shape[entry]


def spec_for_shape(mesh: Mesh, shape, axes, rules=None,
                   fsdp_min_elems: int = 0) -> P:
    """Build a PartitionSpec for one tensor given logical axes per dim.

    ``fsdp_min_elems`` (§Perf O3): parameters smaller than this stay
    replicated instead of FSDP-sharded — gathering a 2 MB tensor inside a
    scanned chunk loop costs more in collectives than it saves in HBM.
    """
    rules = rules or logical_rules(mesh)
    import math as _math
    n_elems = int(_math.prod(shape)) if shape else 1
    used: set = set()
    entries = []
    for dim, name in zip(shape, axes):
        entry = rules.get(name) if name else None
        if entry is None:
            entries.append(None)
            continue
        if (isinstance(entry, tuple) and fsdp_min_elems
                and n_elems < fsdp_min_elems):
            entries.append(None)
            continue
        flat = set(entry) if isinstance(entry, tuple) else {entry}
        if flat & used or dim % _axis_size(mesh, entry):
            entries.append(None)
            continue
        used |= flat
        entries.append(entry)
    return P(*entries)


def make_specs(mesh: Mesh, shapes_tree: Any, axes_tree: Any,
               fsdp_min_elems: int = 0) -> Any:
    """Tree of PartitionSpecs for a (shape-tree, logical-axes-tree) pair.

    shapes_tree leaves can be arrays or ShapeDtypeStructs; axes_tree is the
    matching models.common.tree_axes output (tuples of names at leaves).
    """
    flat_s, tdef = jax.tree.flatten(shapes_tree)
    flat_a = jax.tree.flatten(
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))[0]
    assert len(flat_s) == len(flat_a), (len(flat_s), len(flat_a))
    specs = [spec_for_shape(mesh, s.shape, a,
                            fsdp_min_elems=fsdp_min_elems)
             for s, a in zip(flat_s, flat_a)]
    return jax.tree.unflatten(tdef, specs)


def make_shardings(mesh: Mesh, shapes_tree: Any, axes_tree: Any,
                   fsdp_min_elems: int = 0) -> Any:
    """NamedSharding tree for params (used as pjit in_shardings)."""
    specs = make_specs(mesh, shapes_tree, axes_tree,
                       fsdp_min_elems=fsdp_min_elems)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
