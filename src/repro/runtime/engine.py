"""Discrete-event cluster simulator for straggler experiments (DESIGN.md §5-6).

The engine owns everything about *time*: it samples per-worker delays from a
``core.straggler`` delay model, decides which workers the master waits for
(pluggable active-set policies), and charges wall-clock correctly for both
execution modes the paper compares (§5):

  * **bulk-synchronous** strategies pay a *barrier* per iteration — the master
    commits when the slowest worker in the active set arrives
    (``sample_schedule``; for fastest-k this is the k-th order statistic, the
    same accounting as ``core.straggler.WallClock``);
  * **asynchronous** strategies pay *per arrival* — every worker gradient is
    applied the moment it lands on the master, so a single straggler delays
    only its own (stale) update (``sample_async``).

Everything here is host-side numpy; the resulting mask / event arrays are fed
into the device-resident ``lax.scan`` runners (``runtime.runners``).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Sequence

import numpy as np

from repro.core.straggler import (DelayModel, adaptive_k, bimodal_delays,
                                  constant_delays, exponential_delays,
                                  fastest_k, multimodal_delays,
                                  power_law_delays)
# obs hooks: with no active TraceRecorder, each is a single None-check
from repro.obs.trace import current_recorder as _obs_recorder
from repro.obs.trace import span as _obs_span

__all__ = [
    "DELAY_MODELS", "make_delay_model", "ActiveSetPolicy", "FastestK",
    "AdaptiveK", "Deadline", "AdversarialRotation", "POLICIES", "make_policy",
    "IterationEvent", "Schedule", "AsyncTrace", "ScheduleBatch", "AsyncBatch",
    "ClusterEngine",
]


DELAY_MODELS = {
    "bimodal": bimodal_delays,
    "power_law": power_law_delays,
    "exponential": exponential_delays,
    "multimodal": multimodal_delays,
    "constant": constant_delays,
}


def make_delay_model(name: str, **kw) -> DelayModel:
    if name not in DELAY_MODELS:
        raise KeyError(f"unknown delay model '{name}'; have "
                       f"{sorted(DELAY_MODELS)}")
    return DELAY_MODELS[name](**kw)


# ---------------------------------------------------------------------------
# Active-set policies: which workers does the master wait for at iteration t?
# ---------------------------------------------------------------------------

class ActiveSetPolicy:
    """Selects the active set A_t from this iteration's delay draw."""

    def reset(self) -> None:
        """Called once per schedule; clear any cross-iteration state."""

    def select(self, t: int, delays: np.ndarray,
               prev_active: np.ndarray | None) -> np.ndarray:
        raise NotImplementedError


class FastestK(ActiveSetPolicy):
    """Wait for the k smallest delays — the paper's default master (§3.1)."""

    def __init__(self, k: int):
        self.k = int(k)

    def select(self, t, delays, prev_active):
        return np.sort(fastest_k(delays, self.k))


class AdaptiveK(ActiveSetPolicy):
    """Paper §3.3: grow k until the overlap with A_{t-1} exceeds m/beta, so
    the L-BFGS overlap matrix stays full rank."""

    def __init__(self, beta: float, k_min: int = 1):
        self.beta = float(beta)
        self.k_min = int(k_min)

    def select(self, t, delays, prev_active):
        return adaptive_k(delays, prev_active, self.beta, self.k_min)


class Deadline(ActiveSetPolicy):
    """Wait a fixed time budget per iteration: every worker whose delay is
    within ``deadline`` makes the cut; fall back to fastest-``k_min`` when
    the round was universally slow."""

    def __init__(self, deadline: float, k_min: int = 1):
        self.deadline = float(deadline)
        self.k_min = int(k_min)

    def select(self, t, delays, prev_active):
        active = np.nonzero(delays <= self.deadline)[0]
        if active.size < self.k_min:
            active = fastest_k(delays, self.k_min)
        return np.sort(active)


class AdversarialRotation(ActiveSetPolicy):
    """Deterministic worst-case rotation (ignores delays): the erased set
    sweeps all workers with maximal churn — the paper's 'arbitrary {A_t}'
    sample-path guarantee (same sequence as ``core.adversarial_sets``)."""

    def __init__(self, k: int):
        self.k = int(k)

    def select(self, t, delays, prev_active):
        m = delays.shape[0]
        drop = m - self.k
        start = (t * drop) % m
        erased = (start + np.arange(drop)) % m
        return np.setdiff1d(np.arange(m), erased)


POLICIES = {
    "fastest-k": FastestK,
    "adaptive-k": AdaptiveK,
    "deadline": Deadline,
    "adversarial": AdversarialRotation,
}


def make_policy(name: str, **kw) -> ActiveSetPolicy:
    if name not in POLICIES:
        raise KeyError(f"unknown policy '{name}'; have {sorted(POLICIES)}")
    return POLICIES[name](**kw)


# ---------------------------------------------------------------------------
# Event records
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IterationEvent:
    """One bulk-synchronous iteration of the simulated cluster."""
    t: int
    start: float              # master broadcast time
    commit: float             # master update time (barrier + overhead)
    active: np.ndarray        # sorted worker indices in A_t
    arrivals: np.ndarray      # (m,) absolute arrival time of every worker


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A realized synchronous straggler schedule: masks + wall-clock.

    ``_events`` is either the materialized event tuple or a zero-arg
    thunk producing it — the batched samplers hand a thunk so matrix
    cells that never inspect per-iteration events (the hot path) skip
    building R x T ``IterationEvent`` objects; the first ``.events``
    access materializes and caches.
    """
    m: int
    masks: np.ndarray         # (T, m) float32 0/1 erasure masks
    times: np.ndarray         # (T,) elapsed seconds at each commit
    _events: object           # tuple[IterationEvent, ...] | () -> tuple

    @property
    def events(self) -> tuple:
        ev = self._events
        if callable(ev):
            ev = ev()
            object.__setattr__(self, "_events", ev)
        return ev

    @property
    def steps(self) -> int:
        return self.masks.shape[0]


@dataclasses.dataclass(frozen=True)
class AsyncTrace:
    """A realized asynchronous run: one entry per APPLIED master update."""
    m: int
    workers: np.ndarray        # (U,) int32   worker that produced update u
    staleness: np.ndarray      # (U,) int32   master_version - read_version
    read_versions: np.ndarray  # (U,) int32   parameter timestamp worker read
    times: np.ndarray          # (U,) float64 elapsed seconds at apply
    dropped: int               # gradients discarded for exceeding the bound

    @property
    def updates(self) -> int:
        return self.workers.shape[0]


@dataclasses.dataclass(frozen=True)
class ScheduleBatch:
    """R independent synchronous realizations, stacked along a leading trial
    axis — the input of the batched (``jax.vmap``) runners.  Realization r is
    exactly ``engine.trial(r).sample_schedule(...)``, so batched and
    sequential execution see identical delay draws."""
    m: int
    masks: np.ndarray         # (R, T, m) float32 0/1 erasure masks
    times: np.ndarray         # (R, T) elapsed seconds at each commit
    schedules: tuple          # tuple[Schedule, ...], one per realization

    @property
    def trials(self) -> int:
        return self.masks.shape[0]

    @property
    def steps(self) -> int:
        return self.masks.shape[1]

    def realization(self, r: int) -> Schedule:
        return self.schedules[r]


@dataclasses.dataclass(frozen=True)
class AsyncBatch:
    """R independent asynchronous realizations (same trial-seed convention
    as ``ScheduleBatch``).  Every realization applies the same number of
    updates U, so the event streams stack into rectangular (R, U) arrays."""
    m: int
    workers: np.ndarray        # (R, U) int32
    staleness: np.ndarray      # (R, U) int32
    times: np.ndarray          # (R, U) float64 elapsed seconds at apply
    dropped: np.ndarray        # (R,) gradients discarded per realization
    traces: tuple              # tuple[AsyncTrace, ...], one per realization

    @property
    def trials(self) -> int:
        return self.workers.shape[0]

    @property
    def updates(self) -> int:
        return self.workers.shape[1]

    def realization(self, r: int) -> AsyncTrace:
        return self.traces[r]


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class ClusterEngine:
    """Simulates an m-worker cluster under a delay model.

    One engine instance = one delay environment; strategies ask it for either
    a synchronous ``Schedule`` or an asynchronous ``AsyncTrace``.  Sampling is
    deterministic given ``seed`` (each ``sample_*`` call re-seeds, so two
    strategies handed the same engine config see the same delay realization —
    fair wall-clock comparisons).
    """

    def __init__(self, delay_model: DelayModel, m: int, *,
                 compute_time: float = 0.05, master_overhead: float = 0.01,
                 seed: int = 0, tail_estimator=None):
        self.delay_model = delay_model
        self.m = int(m)
        self.compute_time = float(compute_time)
        self.master_overhead = float(master_overhead)
        self.seed = int(seed)
        # online delay-tail sensing (repro.obs.sketch.DelayTailEstimator):
        # when set, every realized schedule / async trace updates it
        # in-stream — the adaptive-redundancy controller's input.  None
        # (the default) keeps sampling on the zero-overhead path.
        self.tail_estimator = tail_estimator
        # which realization lane this engine's samples record under when an
        # obs TraceRecorder is active; engine.trial(r) children carry r so
        # host-loop harnesses land on the same lanes as batched samplers
        self._obs_realization = 0

    # -- trial seeding ---------------------------------------------------

    def _trial_seed(self, realization: int) -> int:
        """Seed of delay realization ``realization``, derived from the ONE
        engine seed.  Realization 0 is the engine's own seed (so single-trial
        runs are unchanged); realization r > 0 is the (seed, r) child stream
        — stable no matter how many trials are drawn alongside it."""
        if realization == 0:
            return self.seed
        return int(np.random.SeedSequence(
            [self.seed, realization]).generate_state(1)[0])

    def trial(self, realization: int) -> "ClusterEngine":
        """Delay realization ``realization`` as its own engine: identical
        cluster, trial-r seed.  ``engine.trial(r).sample_schedule(...)``
        equals realization r of ``engine.sample_schedules(...)`` — the
        bridge harnesses use to run non-batchable cells (host-loop solvers,
        chunked workloads) trial by trial on the same realizations."""
        if realization == 0:
            return self
        child = ClusterEngine(self.delay_model, self.m,
                              compute_time=self.compute_time,
                              master_overhead=self.master_overhead,
                              seed=self._trial_seed(realization),
                              tail_estimator=self.tail_estimator)
        child._obs_realization = self._obs_realization + realization
        return child

    # -- synchronous (barrier) mode -------------------------------------

    def sample_schedule(self, steps: int, policy: ActiveSetPolicy, *,
                        realization: int = 0) -> Schedule:
        """Realize ``steps`` BSP iterations under ``policy``.

        Iteration t starts at the previous commit; worker i's gradient
        arrives ``compute_time + delay_i`` later; the master commits at the
        latest arrival over A_t plus ``master_overhead``.
        """
        with _obs_span("sample-schedule", steps=steps, m=self.m):
            rng = np.random.default_rng(self._trial_seed(realization))
            policy.reset()
            if type(policy) is FastestK:
                sched = self._sample_fastest_k(rng, steps, policy.k)
            else:
                sched = self._sample_generic(rng, steps, policy)
        if self.tail_estimator is not None:
            self.tail_estimator.observe_schedule(sched)
        rec = _obs_recorder()
        if rec is not None:
            rec.record_schedule(
                sched, realization=self._obs_realization + realization)
        return sched

    def _sample_generic(self, rng, steps: int,
                        policy: ActiveSetPolicy) -> Schedule:
        """The reference per-step loop: any policy, any cross-iteration
        state (the fast path below must stay bit-identical to this)."""
        now = 0.0
        prev_active: np.ndarray | None = None
        masks = np.zeros((steps, self.m), dtype=np.float32)
        times = np.zeros(steps)
        events = []
        for t in range(steps):
            delays = np.asarray(self.delay_model(rng, self.m),
                                dtype=float)
            arrivals = now + self.compute_time + delays
            active = np.asarray(policy.select(t, delays, prev_active))
            commit = float(arrivals[active].max()) + self.master_overhead
            masks[t, active] = 1.0
            times[t] = commit
            events.append(IterationEvent(t=t, start=now, commit=commit,
                                         active=active,
                                         arrivals=arrivals))
            now = commit
            prev_active = active
        return Schedule(self.m, masks, times, tuple(events))

    def _sample_fastest_k(self, rng, steps: int, k: int) -> Schedule:
        """Vectorized fastest-k sampling — the hot path of every batched
        matrix (R x T selections dominated per-cell dispatch cost).

        Bit-identical to ``_sample_generic`` with a ``FastestK`` policy: the
        delay draws keep the exact per-step rng call sequence, the row-wise
        ``argpartition``/``sort`` match the per-row calls, and the commit
        recursion preserves the reference float associativity
        ``((now + compute) + max_delay) + overhead``.
        """
        m, ct, oh = self.m, self.compute_time, self.master_overhead
        # per-step draws (NOT one (T, m) draw): the rng stream must match
        # the reference loop call for call
        delays = np.stack([np.asarray(self.delay_model(rng, m), dtype=float)
                           for _ in range(steps)])
        order = np.argpartition(delays, k - 1, axis=1)[:, :k]
        actives = np.sort(order, axis=1)
        masks = np.zeros((steps, m), dtype=np.float32)
        np.put_along_axis(masks, actives, 1.0, axis=1)
        dmax = np.take_along_axis(delays, order, axis=1).max(axis=1)
        times = np.zeros(steps)
        starts = np.zeros(steps)
        now = 0.0
        for t in range(steps):      # scalar recursion, reference rounding
            starts[t] = now
            now = ((now + ct) + dmax[t]) + oh
            times[t] = now
        def events():            # lazy: most matrix cells never look
            arrivals = (starts[:, None] + ct) + delays
            return tuple(
                IterationEvent(t=t, start=starts[t], commit=times[t],
                               active=actives[t], arrivals=arrivals[t])
                for t in range(steps))
        return Schedule(self.m, masks, times, events)

    def sample_schedules(self, steps: int, policy: ActiveSetPolicy,
                         trials: int) -> ScheduleBatch:
        """Realize ``trials`` independent schedules as one (R, T, m) stack.

        The realization axis is the Monte-Carlo axis of the paper's §5
        protocol (sample-path guarantees hold for EVERY delay realization,
        so figures average many).  Each realization replays the exact rng
        stream of ``sample_schedule`` under its trial seed — batched runs
        are bit-identical to looping ``engine.trial(r)`` — and stateful
        policies are reset at every realization boundary.
        """
        if trials < 1:
            raise ValueError("trials must be >= 1")
        scheds = tuple(self.sample_schedule(steps, policy, realization=r)
                       for r in range(trials))
        return ScheduleBatch(
            m=self.m,
            masks=np.stack([s.masks for s in scheds]),
            times=np.stack([s.times for s in scheds]),
            schedules=scheds)

    # -- asynchronous (per-arrival) mode --------------------------------

    def sample_async(self, updates: int, staleness_bound: int, *,
                     realization: int = 0) -> AsyncTrace:
        """Realize an async run until ``updates`` gradients are APPLIED.

        Every worker loops {read w, compute for compute_time + delay, send};
        the master applies each arriving gradient immediately (per-arrival
        accounting — no barrier) and bumps its version counter.  A gradient
        whose staleness ``master_version - read_version`` exceeds
        ``staleness_bound`` is discarded (the worker's time is still spent:
        bounded-staleness wastes work instead of corrupting the iterate),
        so every APPLIED update satisfies the bound.
        """
        if staleness_bound < 0:
            raise ValueError("staleness_bound must be >= 0")
        with _obs_span("sample-async", updates=updates, m=self.m):
            rng = np.random.default_rng(self._trial_seed(realization))
            read_version = np.zeros(self.m, dtype=np.int64)  # per-worker ts
            version = 0
            heap: list[tuple[float, int]] = []
            first = np.asarray(self.delay_model(rng, self.m), dtype=float)
            for i in range(self.m):
                heapq.heappush(heap, (self.compute_time + first[i], i))

            workers, stale, reads, times = [], [], [], []
            dropped = 0
            while len(workers) < updates:
                arrival, i = heapq.heappop(heap)
                tau = version - read_version[i]
                if tau <= staleness_bound:
                    workers.append(i)
                    stale.append(tau)
                    reads.append(read_version[i])
                    times.append(arrival + self.master_overhead)
                    version += 1
                else:
                    dropped += 1
                # worker re-reads the (possibly updated) parameters, restarts
                read_version[i] = version
                delay = float(np.asarray(self.delay_model(rng, 1))[0])
                heapq.heappush(heap, (arrival + self.compute_time + delay, i))
            trace = AsyncTrace(
                m=self.m,
                workers=np.asarray(workers, dtype=np.int32),
                staleness=np.asarray(stale, dtype=np.int32),
                read_versions=np.asarray(reads, dtype=np.int32),
                times=np.asarray(times),
                dropped=dropped,
            )
        if self.tail_estimator is not None:
            self.tail_estimator.observe_async(trace)
        rec = _obs_recorder()
        if rec is not None:
            rec.record_async(
                trace, realization=self._obs_realization + realization)
        return trace

    def sample_asyncs(self, updates: int, staleness_bound: int,
                      trials: int) -> AsyncBatch:
        """Realize ``trials`` independent async event streams, stacked
        (R, U) — every realization runs until the same ``updates`` gradients
        are applied, so the streams are rectangular.  Same trial-seed
        convention as ``sample_schedules``."""
        if trials < 1:
            raise ValueError("trials must be >= 1")
        traces = tuple(self.sample_async(updates, staleness_bound,
                                         realization=r)
                       for r in range(trials))
        return AsyncBatch(
            m=self.m,
            workers=np.stack([t.workers for t in traces]),
            staleness=np.stack([t.staleness for t in traces]),
            times=np.stack([t.times for t in traces]),
            dropped=np.asarray([t.dropped for t in traces]),
            traces=traces)
