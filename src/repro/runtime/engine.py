"""Discrete-event cluster simulator for straggler experiments (DESIGN.md §5-6).

The engine owns everything about *time*: it samples per-worker delays from a
``core.straggler`` delay model, decides which workers the master waits for
(pluggable active-set policies), and charges wall-clock correctly for both
execution modes the paper compares (§5):

  * **bulk-synchronous** strategies pay a *barrier* per iteration — the master
    commits when the slowest worker in the active set arrives
    (``sample_schedule``; for fastest-k this is the k-th order statistic, the
    same accounting as ``core.straggler.WallClock``);
  * **asynchronous** strategies pay *per arrival* — every worker gradient is
    applied the moment it lands on the master, so a single straggler delays
    only its own (stale) update (``sample_async``).

Everything here is host-side numpy; the resulting mask / event arrays are fed
into the device-resident ``lax.scan`` runners (``runtime.runners``).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Sequence

import numpy as np

from repro.core.straggler import (DelayModel, adaptive_k, bimodal_delays,
                                  constant_delays, exponential_delays,
                                  fastest_k, multimodal_delays,
                                  power_law_delays)
from repro.runtime.faults import (FAULT_BLACKOUT, FAULT_CORRUPT,
                                  FAULT_CRASHED, FaultEvent,
                                  make_fault_model)
# obs hooks: with no active TraceRecorder, each is a single None-check
from repro.obs.trace import current_recorder as _obs_recorder
from repro.obs.trace import span as _obs_span

__all__ = [
    "DELAY_MODELS", "make_delay_model", "ActiveSetPolicy", "FastestK",
    "AdaptiveK", "Deadline", "AdversarialRotation", "POLICIES", "make_policy",
    "IterationEvent", "Schedule", "AsyncTrace", "ScheduleBatch", "AsyncBatch",
    "ClusterEngine",
]


DELAY_MODELS = {
    "bimodal": bimodal_delays,
    "power_law": power_law_delays,
    "exponential": exponential_delays,
    "multimodal": multimodal_delays,
    "constant": constant_delays,
}


def make_delay_model(name: str, **kw) -> DelayModel:
    if name not in DELAY_MODELS:
        raise KeyError(f"unknown delay model '{name}'; have "
                       f"{sorted(DELAY_MODELS)}")
    return DELAY_MODELS[name](**kw)


# ---------------------------------------------------------------------------
# Active-set policies: which workers does the master wait for at iteration t?
# ---------------------------------------------------------------------------

class ActiveSetPolicy:
    """Selects the active set A_t from this iteration's delay draw."""

    def reset(self) -> None:
        """Called once per schedule; clear any cross-iteration state."""

    def select(self, t: int, delays: np.ndarray,
               prev_active: np.ndarray | None) -> np.ndarray:
        raise NotImplementedError


class FastestK(ActiveSetPolicy):
    """Wait for the k smallest delays — the paper's default master (§3.1)."""

    def __init__(self, k: int):
        if int(k) < 1:
            raise ValueError(f"fastest-k needs k >= 1, got {k}")
        self.k = int(k)

    def select(self, t, delays, prev_active):
        return np.sort(fastest_k(delays, self.k))


class AdaptiveK(ActiveSetPolicy):
    """Paper §3.3: grow k until the overlap with A_{t-1} exceeds m/beta, so
    the L-BFGS overlap matrix stays full rank."""

    def __init__(self, beta: float, k_min: int = 1):
        self.beta = float(beta)
        # floor of 1: a 0/negative k_min would let the policy return an
        # empty set on a quiet round, which only the fault paths expect
        self.k_min = max(1, int(k_min))

    def select(self, t, delays, prev_active):
        return adaptive_k(delays, prev_active, self.beta, self.k_min)


class Deadline(ActiveSetPolicy):
    """Wait a fixed time budget per iteration: every worker whose delay is
    within ``deadline`` makes the cut; fall back to fastest-``k_min`` when
    the round was universally slow."""

    def __init__(self, deadline: float, k_min: int = 1):
        self.deadline = float(deadline)
        self.k_min = max(1, int(k_min))   # same floor as AdaptiveK

    def select(self, t, delays, prev_active):
        active = np.nonzero(delays <= self.deadline)[0]
        if active.size < self.k_min:
            active = fastest_k(delays, self.k_min)
        return np.sort(active)


class AdversarialRotation(ActiveSetPolicy):
    """Deterministic worst-case rotation (ignores delays): the erased set
    sweeps all workers with maximal churn — the paper's 'arbitrary {A_t}'
    sample-path guarantee (same sequence as ``core.adversarial_sets``)."""

    def __init__(self, k: int):
        if int(k) < 1:
            raise ValueError(f"adversarial rotation needs k >= 1, got {k}")
        self.k = int(k)

    def select(self, t, delays, prev_active):
        m = delays.shape[0]
        drop = m - self.k
        start = (t * drop) % m
        erased = (start + np.arange(drop)) % m
        return np.setdiff1d(np.arange(m), erased)


POLICIES = {
    "fastest-k": FastestK,
    "adaptive-k": AdaptiveK,
    "deadline": Deadline,
    "adversarial": AdversarialRotation,
}


def make_policy(name: str, **kw) -> ActiveSetPolicy:
    if name not in POLICIES:
        raise KeyError(f"unknown policy '{name}'; have {sorted(POLICIES)}")
    return POLICIES[name](**kw)


def _policy_k_min(policy: ActiveSetPolicy) -> int:
    """The decode threshold a policy aims for — ``k`` for fastest-k /
    adversarial, ``k_min`` for adaptive-k / deadline — used as the
    survivor floor that triggers degradation under faults."""
    for attr in ("k", "k_min"):
        if hasattr(policy, attr):
            return max(1, int(getattr(policy, attr)))
    return 1


# ---------------------------------------------------------------------------
# Event records
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IterationEvent:
    """One bulk-synchronous iteration of the simulated cluster."""
    t: int
    start: float              # master broadcast time
    commit: float             # master update time (barrier + overhead)
    active: np.ndarray        # sorted worker indices in A_t
    arrivals: np.ndarray      # (m,) absolute arrival time of every worker


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A realized synchronous straggler schedule: masks + wall-clock.

    ``_events`` is either the materialized event tuple or a zero-arg
    thunk producing it — the batched samplers hand a thunk so matrix
    cells that never inspect per-iteration events (the hot path) skip
    building R x T ``IterationEvent`` objects; the first ``.events``
    access materializes and caches.
    """
    m: int
    masks: np.ndarray         # (T, m) float32 0/1 erasure masks
    times: np.ndarray         # (T,) elapsed seconds at each commit
    _events: object           # tuple[IterationEvent, ...] | () -> tuple
    # fault lane (repro.runtime.faults): per-(t, worker) int8 codes —
    # FAULT_OK covers active AND healthy-but-slow (the mask disambiguates);
    # crashed/blackout/corrupt name genuine failures, distinct from "slow".
    # None = sampled without a fault model (the default, zero-cost path).
    failed: np.ndarray | None = None   # (T, m) int8 fault codes
    fault_events: tuple = ()           # tuple[FaultEvent, ...]

    @property
    def events(self) -> tuple:
        ev = self._events
        if callable(ev):
            ev = ev()
            object.__setattr__(self, "_events", ev)
        return ev

    @property
    def steps(self) -> int:
        return self.masks.shape[0]


@dataclasses.dataclass(frozen=True)
class AsyncTrace:
    """A realized asynchronous run: one entry per APPLIED master update."""
    m: int
    workers: np.ndarray        # (U,) int32   worker that produced update u
    staleness: np.ndarray      # (U,) int32   master_version - read_version
    read_versions: np.ndarray  # (U,) int32   parameter timestamp worker read
    times: np.ndarray          # (U,) float64 elapsed seconds at apply
    dropped: int               # gradients discarded for exceeding the bound
    corrupted: int = 0         # arrivals discarded as corrupt (fault lane)
    fault_events: tuple = ()   # tuple[FaultEvent, ...]

    @property
    def updates(self) -> int:
        return self.workers.shape[0]


@dataclasses.dataclass(frozen=True)
class ScheduleBatch:
    """R independent synchronous realizations, stacked along a leading trial
    axis — the input of the batched (``jax.vmap``) runners.  Realization r is
    exactly ``engine.trial(r).sample_schedule(...)``, so batched and
    sequential execution see identical delay draws."""
    m: int
    masks: np.ndarray         # (R, T, m) float32 0/1 erasure masks
    times: np.ndarray         # (R, T) elapsed seconds at each commit
    schedules: tuple          # tuple[Schedule, ...], one per realization
    failed: np.ndarray | None = None   # (R, T, m) int8, None without faults

    @property
    def trials(self) -> int:
        return self.masks.shape[0]

    @property
    def steps(self) -> int:
        return self.masks.shape[1]

    def realization(self, r: int) -> Schedule:
        return self.schedules[r]


@dataclasses.dataclass(frozen=True)
class AsyncBatch:
    """R independent asynchronous realizations (same trial-seed convention
    as ``ScheduleBatch``).  Every realization applies the same number of
    updates U, so the event streams stack into rectangular (R, U) arrays."""
    m: int
    workers: np.ndarray        # (R, U) int32
    staleness: np.ndarray      # (R, U) int32
    times: np.ndarray          # (R, U) float64 elapsed seconds at apply
    dropped: np.ndarray        # (R,) gradients discarded per realization
    traces: tuple              # tuple[AsyncTrace, ...], one per realization
    corrupted: np.ndarray | None = None   # (R,) corrupt arrivals discarded

    @property
    def trials(self) -> int:
        return self.workers.shape[0]

    @property
    def updates(self) -> int:
        return self.workers.shape[1]

    def realization(self, r: int) -> AsyncTrace:
        return self.traces[r]


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class ClusterEngine:
    """Simulates an m-worker cluster under a delay model.

    One engine instance = one delay environment; strategies ask it for either
    a synchronous ``Schedule`` or an asynchronous ``AsyncTrace``.  Sampling is
    deterministic given ``seed`` (each ``sample_*`` call re-seeds, so two
    strategies handed the same engine config see the same delay realization —
    fair wall-clock comparisons).
    """

    def __init__(self, delay_model: DelayModel, m: int, *,
                 compute_time: float = 0.05, master_overhead: float = 0.01,
                 seed: int = 0, tail_estimator=None, faults=None):
        self.delay_model = delay_model
        self.m = int(m)
        self.compute_time = float(compute_time)
        self.master_overhead = float(master_overhead)
        self.seed = int(seed)
        # fault injection (repro.runtime.faults): a FaultModel or spec
        # string composes crashes / blackouts / zone loss / corruption on
        # top of the delay model.  None (the default) keeps every sampler
        # on the exact pre-fault code path — a single is-None check.
        self.faults = make_fault_model(faults)
        # online delay-tail sensing (repro.obs.sketch.DelayTailEstimator):
        # when set, every realized schedule / async trace updates it
        # in-stream — the adaptive-redundancy controller's input.  None
        # (the default) keeps sampling on the zero-overhead path.
        self.tail_estimator = tail_estimator
        # which realization lane this engine's samples record under when an
        # obs TraceRecorder is active; engine.trial(r) children carry r so
        # host-loop harnesses land on the same lanes as batched samplers
        self._obs_realization = 0

    # -- trial seeding ---------------------------------------------------

    def _trial_seed(self, realization: int) -> int:
        """Seed of delay realization ``realization``, derived from the ONE
        engine seed.  Realization 0 is the engine's own seed (so single-trial
        runs are unchanged); realization r > 0 is the (seed, r) child stream
        — stable no matter how many trials are drawn alongside it."""
        if realization == 0:
            return self.seed
        return int(np.random.SeedSequence(
            [self.seed, realization]).generate_state(1)[0])

    def trial(self, realization: int) -> "ClusterEngine":
        """Delay realization ``realization`` as its own engine: identical
        cluster, trial-r seed.  ``engine.trial(r).sample_schedule(...)``
        equals realization r of ``engine.sample_schedules(...)`` — the
        bridge harnesses use to run non-batchable cells (host-loop solvers,
        chunked workloads) trial by trial on the same realizations."""
        if realization == 0:
            return self
        child = ClusterEngine(self.delay_model, self.m,
                              compute_time=self.compute_time,
                              master_overhead=self.master_overhead,
                              seed=self._trial_seed(realization),
                              tail_estimator=self.tail_estimator,
                              faults=self.faults)
        child._obs_realization = self._obs_realization + realization
        return child

    # -- synchronous (barrier) mode -------------------------------------

    def sample_schedule(self, steps: int, policy: ActiveSetPolicy, *,
                        realization: int = 0, degrade=None) -> Schedule:
        """Realize ``steps`` BSP iterations under ``policy``.

        Iteration t starts at the previous commit; worker i's gradient
        arrives ``compute_time + delay_i`` later; the master commits at the
        latest arrival over A_t plus ``master_overhead``.  With a fault
        model attached the schedule additionally carries a ``failed`` code
        array and fault events; ``degrade`` (a ``backoff``-mode
        :class:`~repro.runtime.faults.DegradePolicy`) lets the master
        extend its deadline when survivors fall below the threshold.
        """
        with _obs_span("sample-schedule", steps=steps, m=self.m):
            trial_seed = self._trial_seed(realization)
            rng = np.random.default_rng(trial_seed)
            policy.reset()
            if self.faults is not None:
                sched = self._sample_faulted(rng, steps, policy,
                                             trial_seed, degrade)
            elif type(policy) is FastestK:
                sched = self._sample_fastest_k(rng, steps, policy.k)
            else:
                sched = self._sample_generic(rng, steps, policy)
        if self.tail_estimator is not None:
            self.tail_estimator.observe_schedule(sched)
        rec = _obs_recorder()
        if rec is not None:
            rec.record_schedule(
                sched, realization=self._obs_realization + realization)
        return sched

    def _sample_generic(self, rng, steps: int,
                        policy: ActiveSetPolicy) -> Schedule:
        """The reference per-step loop: any policy, any cross-iteration
        state (the fast path below must stay bit-identical to this)."""
        now = 0.0
        prev_active: np.ndarray | None = None
        masks = np.zeros((steps, self.m), dtype=np.float32)
        times = np.zeros(steps)
        events = []
        for t in range(steps):
            delays = np.asarray(self.delay_model(rng, self.m),
                                dtype=float)
            arrivals = now + self.compute_time + delays
            active = np.asarray(policy.select(t, delays, prev_active))
            commit = float(arrivals[active].max()) + self.master_overhead
            masks[t, active] = 1.0
            times[t] = commit
            events.append(IterationEvent(t=t, start=now, commit=commit,
                                         active=active,
                                         arrivals=arrivals))
            now = commit
            prev_active = active
        return Schedule(self.m, masks, times, tuple(events))

    def _sample_fastest_k(self, rng, steps: int, k: int) -> Schedule:
        """Vectorized fastest-k sampling — the hot path of every batched
        matrix (R x T selections dominated per-cell dispatch cost).

        Bit-identical to ``_sample_generic`` with a ``FastestK`` policy: the
        delay draws keep the exact per-step rng call sequence, the row-wise
        ``argpartition``/``sort`` match the per-row calls, and the commit
        recursion preserves the reference float associativity
        ``((now + compute) + max_delay) + overhead``.
        """
        m, ct, oh = self.m, self.compute_time, self.master_overhead
        # per-step draws (NOT one (T, m) draw): the rng stream must match
        # the reference loop call for call
        delays = np.stack([np.asarray(self.delay_model(rng, m), dtype=float)
                           for _ in range(steps)])
        order = np.argpartition(delays, k - 1, axis=1)[:, :k]
        actives = np.sort(order, axis=1)
        masks = np.zeros((steps, m), dtype=np.float32)
        np.put_along_axis(masks, actives, 1.0, axis=1)
        dmax = np.take_along_axis(delays, order, axis=1).max(axis=1)
        times = np.zeros(steps)
        starts = np.zeros(steps)
        now = 0.0
        for t in range(steps):      # scalar recursion, reference rounding
            starts[t] = now
            now = ((now + ct) + dmax[t]) + oh
            times[t] = now
        def events():            # lazy: most matrix cells never look
            arrivals = (starts[:, None] + ct) + delays
            return tuple(
                IterationEvent(t=t, start=starts[t], commit=times[t],
                               active=actives[t], arrivals=arrivals[t])
                for t in range(steps))
        return Schedule(self.m, masks, times, events)

    def _sample_faulted(self, rng, steps: int, policy: ActiveSetPolicy,
                        trial_seed: int, degrade) -> Schedule:
        """The fault-aware per-step loop (only reached when a fault model
        is attached; the no-fault paths above stay byte-identical).

        Per iteration: crashed workers are permanently gone, blacked-out
        workers are unavailable for rounds that start inside their window
        (both are given infinite delay BEFORE policy selection and filtered
        from its pick — ``Deadline``'s fastest-k fallback must never wait
        on a dead worker); corrupt results arrive (the barrier pays for
        them) but are flagged and masked out of the combine.  The master
        detects failures instantly (a heartbeat assumption, DESIGN.md §14),
        so an all-failed round commits after one idle compute window.
        """
        fr = self.faults.realize(self.m, trial_seed)
        ct, oh = self.compute_time, self.master_overhead
        backoff = (degrade if degrade is not None
                   and degrade.mode == "backoff" else None)
        k_floor = _policy_k_min(policy)
        if backoff is not None and backoff.k_min is not None:
            k_floor = int(backoff.k_min)
        now = 0.0
        prev_active: np.ndarray | None = None
        masks = np.zeros((steps, self.m), dtype=np.float32)
        failed = np.zeros((steps, self.m), dtype=np.int8)
        times = np.zeros(steps)
        events, corrupt_events = [], []
        for t in range(steps):
            delays = np.asarray(self.delay_model(rng, self.m), dtype=float)
            crashed = fr.crashed_at(now)
            dark = fr.blackout_at(now) & ~crashed
            failed[t, crashed] = FAULT_CRASHED
            failed[t, dark] = FAULT_BLACKOUT
            avail = ~(crashed | dark)
            eff = np.where(avail, delays, np.inf)
            active = np.asarray(policy.select(t, eff, prev_active),
                                dtype=int)
            active = active[avail[active]]
            arrivals = now + ct + delays
            if backoff is not None and active.size < k_floor:
                # deadline extension: wait up to base * 2^j for blacked-out
                # workers to recover, restart, and report in
                recov = fr.recovery_time(now)
                rec_arrivals = recov + ct + delays
                window = backoff.base
                for _ in range(max(1, int(backoff.retries))):
                    rejoin = np.nonzero(dark & (recov <= now + window))[0]
                    extra = np.setdiff1d(rejoin, active)
                    if extra.size:
                        arrivals = arrivals.copy()
                        arrivals[extra] = rec_arrivals[extra]
                        active = np.sort(np.concatenate([active, extra]))
                    if active.size >= k_floor:
                        break
                    window *= 2.0
            if active.size:
                commit = float(arrivals[active].max()) + oh
                corrupt = fr.corrupt_draw(active.size)
                if corrupt.any():
                    for w in active[corrupt]:
                        failed[t, w] = FAULT_CORRUPT
                        corrupt_events.append(FaultEvent(
                            "corrupt", int(w), float(arrivals[w]), t=t))
                    active = active[~corrupt]
                masks[t, active] = 1.0
            else:
                # every worker failed: the master idles one compute window
                # and commits an empty round (mask row all-zero)
                commit = now + ct + oh
            times[t] = commit
            events.append(IterationEvent(t=t, start=now, commit=commit,
                                         active=active, arrivals=arrivals))
            now = commit
            prev_active = active
        horizon = float(times[-1]) if steps else 0.0
        fault_events = sorted(fr.static_events(horizon) + corrupt_events,
                              key=lambda e: (e.time, e.worker))
        return Schedule(self.m, masks, times, tuple(events),
                        failed=failed, fault_events=tuple(fault_events))

    def sample_schedules(self, steps: int, policy: ActiveSetPolicy,
                         trials: int, *, degrade=None) -> ScheduleBatch:
        """Realize ``trials`` independent schedules as one (R, T, m) stack.

        The realization axis is the Monte-Carlo axis of the paper's §5
        protocol (sample-path guarantees hold for EVERY delay realization,
        so figures average many).  Each realization replays the exact rng
        stream of ``sample_schedule`` under its trial seed — batched runs
        are bit-identical to looping ``engine.trial(r)`` — and stateful
        policies are reset at every realization boundary.
        """
        if trials < 1:
            raise ValueError("trials must be >= 1")
        scheds = tuple(self.sample_schedule(steps, policy, realization=r,
                                            degrade=degrade)
                       for r in range(trials))
        return ScheduleBatch(
            m=self.m,
            masks=np.stack([s.masks for s in scheds]),
            times=np.stack([s.times for s in scheds]),
            schedules=scheds,
            failed=(np.stack([s.failed for s in scheds])
                    if scheds[0].failed is not None else None))

    # -- asynchronous (per-arrival) mode --------------------------------

    def sample_async(self, updates: int, staleness_bound: int, *,
                     realization: int = 0) -> AsyncTrace:
        """Realize an async run until ``updates`` gradients are APPLIED.

        Every worker loops {read w, compute for compute_time + delay, send};
        the master applies each arriving gradient immediately (per-arrival
        accounting — no barrier) and bumps its version counter.  A gradient
        whose staleness ``master_version - read_version`` exceeds
        ``staleness_bound`` is discarded (the worker's time is still spent:
        bounded-staleness wastes work instead of corrupting the iterate),
        so every APPLIED update satisfies the bound.
        """
        if staleness_bound < 0:
            raise ValueError("staleness_bound must be >= 0")
        with _obs_span("sample-async", updates=updates, m=self.m):
            trial_seed = self._trial_seed(realization)
            rng = np.random.default_rng(trial_seed)
            # fault realization (None = the exact pre-fault event loop):
            # crashed workers take their in-flight gradient down with them
            # and never re-queue; blacked-out workers restart at window
            # end; corrupt arrivals are discarded without a version bump.
            fr = (self.faults.realize(self.m, trial_seed)
                  if self.faults is not None else None)
            read_version = np.zeros(self.m, dtype=np.int64)  # per-worker ts
            version = 0
            heap: list[tuple[float, int]] = []
            first = np.asarray(self.delay_model(rng, self.m), dtype=float)
            start0 = fr.recovery_time(0.0) if fr is not None else None
            for i in range(self.m):
                if start0 is None:
                    heapq.heappush(heap, (self.compute_time + first[i], i))
                elif np.isfinite(start0[i]):
                    heapq.heappush(
                        heap, (start0[i] + self.compute_time + first[i], i))

            workers, stale, reads, times = [], [], [], []
            dropped = corrupted = 0
            while len(workers) < updates:
                if not heap:
                    raise ValueError(
                        f"async cluster died: every worker crashed after "
                        f"{len(workers)} of {updates} updates")
                arrival, i = heapq.heappop(heap)
                if fr is not None and fr.crash_time[i] <= arrival:
                    continue   # worker died mid-compute; result lost
                if fr is not None and fr.corrupt_draw(1)[0]:
                    corrupted += 1
                else:
                    tau = version - read_version[i]
                    if tau <= staleness_bound:
                        workers.append(i)
                        stale.append(tau)
                        reads.append(read_version[i])
                        times.append(arrival + self.master_overhead)
                        version += 1
                    else:
                        dropped += 1
                # worker re-reads the (possibly updated) parameters, restarts
                read_version[i] = version
                delay = float(np.asarray(self.delay_model(rng, 1))[0])
                restart = arrival
                if fr is not None:
                    restart = float(fr.recovery_time(arrival)[i])
                heapq.heappush(heap, (restart + self.compute_time + delay, i))
            trace = AsyncTrace(
                m=self.m,
                workers=np.asarray(workers, dtype=np.int32),
                staleness=np.asarray(stale, dtype=np.int32),
                read_versions=np.asarray(reads, dtype=np.int32),
                times=np.asarray(times),
                dropped=dropped,
                corrupted=corrupted,
                fault_events=(tuple(fr.static_events(
                    float(times[-1]) if times else 0.0))
                    if fr is not None else ()),
            )
        if self.tail_estimator is not None:
            self.tail_estimator.observe_async(trace)
        rec = _obs_recorder()
        if rec is not None:
            rec.record_async(
                trace, realization=self._obs_realization + realization)
        return trace

    def sample_asyncs(self, updates: int, staleness_bound: int,
                      trials: int) -> AsyncBatch:
        """Realize ``trials`` independent async event streams, stacked
        (R, U) — every realization runs until the same ``updates`` gradients
        are applied, so the streams are rectangular.  Same trial-seed
        convention as ``sample_schedules``."""
        if trials < 1:
            raise ValueError("trials must be >= 1")
        traces = tuple(self.sample_async(updates, staleness_bound,
                                         realization=r)
                       for r in range(trials))
        return AsyncBatch(
            m=self.m,
            workers=np.stack([t.workers for t in traces]),
            staleness=np.stack([t.staleness for t in traces]),
            times=np.stack([t.times for t in traces]),
            dropped=np.asarray([t.dropped for t in traces]),
            traces=traces,
            corrupted=np.asarray([t.corrupted for t in traces]))
