"""repro.runtime — event-driven straggler cluster runtime (DESIGN.md §5).

Four parts:
  * ``engine``     — discrete-event cluster simulator: delay sampling,
                     pluggable active-set policies, barrier vs per-arrival
                     wall-clock accounting;
  * ``strategies`` — one ``Strategy`` interface + registry over every scheme
                     the paper compares (encoded GD/prox/L-BFGS/BCD, uncoded,
                     replication, async stale-gradient SGD);
  * ``runners``    — ``lax.scan``-fused device-resident iteration loops,
                     batched (vmap) and sharded (shard_map over a 'trials'
                     mesh axis) trial variants;
  * ``compare``    — legacy strategy x delay-model CLI, now a thin
                     front-end over ``repro.experiments`` (DESIGN.md §10).
"""
from .engine import (DELAY_MODELS, POLICIES, ActiveSetPolicy, AdaptiveK,
                     AdversarialRotation, AsyncBatch, AsyncTrace,
                     ClusterEngine, Deadline, FastestK, IterationEvent,
                     Schedule, ScheduleBatch, make_delay_model, make_policy)
from .faults import (FAULT_KINDS, BlackoutFault, CorruptionFault, CrashFault,
                     DegradePolicy, FaultEvent, FaultModel, ZoneFault,
                     make_degrade, make_fault_model)
from .runners import (batched_scan_async, batched_scan_bcd, batched_scan_gd,
                      batched_scan_prox, scan_async, scan_bcd, scan_gd,
                      scan_prox, sharded_scan_async, sharded_scan_gd,
                      sharded_scan_prox, trials_device_count)
from .strategies import (ProblemSpec, RunResult, Strategy, TrialsResult,
                         available_strategies, check_trials, get_strategy,
                         register_strategy, resolve_eval_every,
                         summary_stats)
__all__ = [
    "DELAY_MODELS", "POLICIES", "ActiveSetPolicy", "AdaptiveK",
    "AdversarialRotation", "AsyncBatch", "AsyncTrace", "ClusterEngine",
    "Deadline", "FastestK", "IterationEvent", "Schedule", "ScheduleBatch",
    "make_delay_model", "make_policy", "scan_async", "scan_bcd", "scan_gd",
    "scan_prox", "batched_scan_async", "batched_scan_bcd", "batched_scan_gd",
    "batched_scan_prox", "sharded_scan_async", "sharded_scan_gd",
    "sharded_scan_prox", "trials_device_count", "ProblemSpec", "RunResult",
    "Strategy", "TrialsResult", "available_strategies", "check_trials",
    "get_strategy", "register_strategy", "resolve_eval_every",
    "summary_stats", "run_matrix",
    "FAULT_KINDS", "BlackoutFault", "CorruptionFault", "CrashFault",
    "DegradePolicy", "FaultEvent", "FaultModel", "ZoneFault", "make_degrade",
    "make_fault_model",
]


def __getattr__(name):
    # Lazy: importing .compare eagerly would shadow `python -m
    # repro.runtime.compare` (runpy warns about double import).
    if name == "run_matrix":
        from .compare import run_matrix
        return run_matrix
    raise AttributeError(name)
