"""Unified strategy interface + registry for straggler-mitigation schemes.

Every scheme the paper compares (§5) — encoded GD / proximal / L-BFGS / BCD,
uncoded synchronous, beta-replication, and asynchronous stale-gradient SGD —
lives behind one ``Strategy`` interface: build the worker-resident problem
for a shared ``ProblemSpec``, ask the ``ClusterEngine`` for a delay
realization, run the fused runner, and return a wall-clock-vs-objective
``RunResult``.  New schemes register themselves with ``@register_strategy``
and become available to ``runtime.compare`` and the benchmarks for free.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core.data_parallel import (make_encoded_problem,
                                      original_objective)
from repro.core.encoding import LinearEncoder, make_encoder
from repro.core import operators  # noqa: F401  (registers matrix-free encoders)
from repro.core.lbfgs import run_encoded_lbfgs
from repro.core.model_parallel import make_lifted_problem, phi_quadratic
from repro.obs.trace import span as _obs_span

from .engine import (ActiveSetPolicy, AsyncTrace, ClusterEngine, FastestK,
                     _policy_k_min)
from .faults import make_degrade
from .runners import (batched_scan_async, batched_scan_bcd, batched_scan_gd,
                      batched_scan_prox, scan_async, scan_bcd, scan_gd,
                      scan_prox, sharded_scan_async, sharded_scan_gd,
                      sharded_scan_prox)

__all__ = [
    "ProblemSpec", "RunResult", "TrialsResult", "Strategy",
    "register_strategy", "get_strategy", "available_strategies",
    "json_safe_meta", "summary_stats", "check_trials", "resolve_eval_every",
]


def json_safe_meta(meta: dict) -> dict:
    """JSON-serializable view of a meta dict: primitives pass through,
    everything else (arrays, policies, ...) is stringified.  Shared by every
    ``to_record`` (RunResult here, WorkloadRunResult in repro.workloads)."""
    return {k: (v if isinstance(v, (int, float, str, bool)) else str(v))
            for k, v in meta.items()}


# ---------------------------------------------------------------------------
# Shared problem description
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ProblemSpec:
    """The ORIGINAL (uncoded) problem every strategy is solving:
    f(w) = 1/(2n) ||X w - y||^2 + lam * h(w)."""
    X: np.ndarray
    y: np.ndarray
    lam: float = 0.05
    h: str = "l2"            # "l2" (ridge), "l1" (lasso), "none"

    @staticmethod
    def synthetic(n: int = 512, p: int = 128, *, noise: float = 0.5,
                  sparse: int = 0, lam: float = 0.05, h: str = "l2",
                  seed: int = 0) -> "ProblemSpec":
        from repro.data import lsq_dataset
        X, y, _ = lsq_dataset(n, p, noise=noise, sparse=sparse, seed=seed)
        return ProblemSpec(X=X, y=y, lam=lam, h=h)

    @property
    def n(self) -> int:
        return self.X.shape[0]

    @property
    def p(self) -> int:
        return self.X.shape[1]

    def lipschitz(self) -> float:
        """Smoothness constant of the data-fit term, max eig of X^T X / n."""
        return float(np.linalg.eigvalsh(self.X.T @ self.X / self.n).max())

    def w_star(self) -> np.ndarray:
        """Closed-form ridge optimum (h == 'l2' only)."""
        if self.h != "l2":
            raise ValueError("closed form only for the ridge objective")
        p = self.p
        return np.linalg.solve(self.X.T @ self.X / self.n +
                               self.lam * np.eye(p), self.X.T @ self.y / self.n)


@dataclasses.dataclass
class RunResult:
    """Wall-clock-vs-objective trace for one (strategy, delay model) cell."""
    strategy: str
    times: np.ndarray       # (T,) elapsed simulated seconds per record point
    objective: np.ndarray   # (T,) objective at each record point
    w: np.ndarray | None = None
    meta: dict = dataclasses.field(default_factory=dict)
    # The realized engine Schedule (or AsyncTrace) behind this run, so callers
    # (repro.workloads) can inspect per-iteration active sets.  Host-side
    # object; deliberately NOT serialized by ``to_record``.
    schedule: Any = None

    @property
    def final_objective(self) -> float:
        return float(self.objective[-1])

    @property
    def wallclock(self) -> float:
        return float(self.times[-1])

    def to_record(self) -> dict:
        """JSON-serializable record (traces included, iterate omitted)."""
        # np.asarray().tolist() converts the whole trace in C — the
        # per-element float() loop was measurable at T=10k x R trials
        return {
            "strategy": self.strategy,
            "times": np.asarray(self.times, dtype=float).tolist(),
            "objective": np.asarray(self.objective, dtype=float).tolist(),
            "final_objective": self.final_objective,
            "wallclock_s": self.wallclock,
            "meta": json_safe_meta(self.meta),
        }


def summary_stats(values) -> dict:
    """mean/p50/p95 of a per-realization vector (the Monte-Carlo summary
    attached to every batched record)."""
    a = np.asarray(values, dtype=float)
    return {"mean": float(a.mean()), "p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95))}


@dataclasses.dataclass
class TrialsResult:
    """R delay realizations of one (strategy, delay model) cell, executed as
    a single compiled program (DESIGN.md §9).

    ``times``/``objective`` carry the per-realization traces stacked along
    the leading trial axis; ``summary()`` reduces them to the Monte-Carlo
    view (mean/p50/p95 wall-clock and final objective) the paper's figures
    are built from.
    """
    strategy: str
    times: np.ndarray       # (R, T') elapsed simulated seconds per record
    objective: np.ndarray   # (R, T') objective at each record point
    w: np.ndarray | None = None     # (R, p) final iterates
    meta: dict = dataclasses.field(default_factory=dict)
    # The realized ScheduleBatch / AsyncBatch; host-side, NOT serialized.
    schedules: Any = None

    @property
    def trials(self) -> int:
        return self.times.shape[0]

    @property
    def final_objective(self) -> np.ndarray:
        return np.asarray(self.objective)[:, -1]

    @property
    def wallclock(self) -> np.ndarray:
        return np.asarray(self.times)[:, -1]

    def realization(self, r: int) -> RunResult:
        """Realization r as a plain single-trial RunResult."""
        sched = None
        if self.schedules is not None:
            sched = self.schedules.realization(r)
        return RunResult(
            strategy=self.strategy, times=np.asarray(self.times)[r],
            objective=np.asarray(self.objective)[r],
            w=None if self.w is None else np.asarray(self.w)[r],
            meta=dict(self.meta), schedule=sched)

    def summary(self) -> dict:
        return {"trials": int(self.trials),
                "wallclock_s": summary_stats(self.wallclock),
                "final_objective": summary_stats(self.final_objective)}

    def to_record(self) -> dict:
        """JSON record: per-realization traces + the Monte-Carlo summary.
        Scalar ``final_objective`` / ``wallclock_s`` are the across-trial
        means, so batched records drop into every single-trial consumer."""
        return {
            "strategy": self.strategy,
            "trials": int(self.trials),
            "times": np.asarray(self.times, dtype=float).tolist(),
            "objective": np.asarray(self.objective, dtype=float).tolist(),
            "final_objective": float(self.final_objective.mean()),
            "wallclock_s": float(self.wallclock.mean()),
            "summary": self.summary(),
            "meta": json_safe_meta(self.meta),
        }


# The BCD runners (_bcd_runner / _bcd_batched_runner in runtime.runners)
# cache compiled executables per (phi_val, phi_grad) CLOSURE IDENTITY, so
# building fresh phi closures per cell would recompile every cell of a
# matrix despite identical shapes.  Key the closures by the target data
# instead: every cell solving the same y shares one closure pair and hence
# one executable per shape.  Bounded like the runner caches (each entry
# pins the y copy its closures capture).
@lru_cache(maxsize=8)
def _phi_quadratic_cached(y_bytes: bytes, dtype: str, shape: tuple):
    return phi_quadratic(np.frombuffer(y_bytes, dtype=dtype).reshape(shape))


def _phi_quadratic(y) -> tuple:
    a = np.ascontiguousarray(np.asarray(y))
    return _phi_quadratic_cached(a.tobytes(), str(a.dtype), a.shape)


def _auto_step(spec: ProblemSpec) -> float:
    """Safe GD step for the (possibly encoded, eps<=0.3) smooth part."""
    return 1.0 / (1.3 * spec.lipschitz() + spec.lam)


def _default_k(m: int) -> int:
    return max(1, (3 * m) // 4)


def _resolve_encoder(encoder, n: int, *, beta: float, seed: int,
                     m: int) -> LinearEncoder:
    """Accept an encoder by registry name OR as a LinearEncoder instance
    (operator encoders flow through the strategy layer unchanged), bound to
    the engine's worker count."""
    if isinstance(encoder, LinearEncoder):
        if encoder.n != n:
            raise ValueError(f"encoder dim {encoder.n} != problem dim {n}")
        return encoder.with_workers(m)
    return make_encoder(encoder, n, beta=beta, seed=seed).with_workers(m)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type["Strategy"]] = {}


def register_strategy(name: str):
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_strategy(name: str) -> "Strategy":
    if name not in _REGISTRY:
        raise KeyError(f"unknown strategy '{name}'; have "
                       f"{available_strategies()}")
    return _REGISTRY[name]()


def available_strategies() -> list[str]:
    return sorted(_REGISTRY)


class Strategy:
    """One straggler-mitigation scheme. Subclasses implement ``run`` and
    (for the Monte-Carlo protocol) ``run_batched``."""

    name = "?"

    def run(self, spec: ProblemSpec, engine: ClusterEngine, *,
            steps: int = 200, **cfg: Any) -> RunResult:
        raise NotImplementedError

    def run_batched(self, spec: ProblemSpec, engine: ClusterEngine, *,
                    steps: int = 200, trials: int = 1, eval_every: int = 1,
                    placement: str = "vmap", **cfg: Any) -> TrialsResult:
        """R delay realizations of this cell in one compiled program.

        Realization r is bit-identical to ``run(spec, engine.trial(r), ...)``
        up to vmap reduction rounding; ``eval_every=s`` records the
        objective every s steps (s must divide the schedule length; 0 keeps
        the final objective only).  ``placement`` decides where the
        realization axis lives: ``'single'`` (host loop), ``'vmap'`` (one
        program, one device), ``'sharded'`` (``shard_map`` over the local
        device mesh, vmap fallback on one device).  This base implementation
        is the fallback for schemes with host-side outer loops — it builds
        the problem per realization and loops sequentially, whatever the
        placement.
        """
        check_trials(steps, trials, eval_every)
        stride_every = resolve_eval_every(steps, eval_every)
        results = [self.run(spec, engine.trial(r), steps=steps, **dict(cfg))
                   for r in range(trials)]
        stride = slice(stride_every - 1, None, stride_every)
        return TrialsResult(
            strategy=self.name,
            times=np.stack([np.asarray(r.times) for r in results])[:, stride],
            objective=np.stack([np.asarray(r.objective)
                                for r in results])[:, stride],
            w=np.stack([np.asarray(r.w) for r in results]),
            meta={**results[0].meta, "trials": trials,
                  "eval_every": eval_every, "batched": False})


def check_trials(steps: int, trials: int, eval_every: int) -> None:
    """Validate a (steps, trials, eval_every) combination up front.

    ``eval_every=0`` is accepted and means "record the final objective
    only" (callers resolve it to ``steps`` via ``resolve_eval_every``).
    """
    if trials < 1:
        raise ValueError("trials must be >= 1")
    if eval_every < 0:
        raise ValueError(f"eval_every={eval_every} must be >= 0 "
                         f"(0 = final objective only)")
    if eval_every and steps % eval_every:
        raise ValueError(
            f"eval_every={eval_every} must divide steps={steps} "
            f"(steps % eval_every == {steps % eval_every}); use "
            f"eval_every=0 to record the final objective only")


def resolve_eval_every(steps: int, eval_every: int) -> int:
    """The effective record stride: ``eval_every=0`` ("final objective
    only") becomes a stride of the full schedule length."""
    return steps if eval_every == 0 else eval_every


# ---------------------------------------------------------------------------
# Synchronous data-parallel family (encoded / uncoded / replication)
# ---------------------------------------------------------------------------

def _resolve_degrade(policy: ActiveSetPolicy, cfg: dict):
    """Pop + parse the ``degrade`` config key; an unset ``k_min`` is bound
    to the policy's decode threshold (``repro.runtime.faults``)."""
    deg = make_degrade(cfg.pop("degrade", None))
    if deg is not None and deg.k_min is None:
        deg = dataclasses.replace(deg, k_min=_policy_k_min(policy))
    return deg


def _fault_meta(engine: ClusterEngine, policy, degrade, masks) -> dict:
    """Fault-lane record fields: injected fault spec, degrade mode, and the
    realized sub-k iteration fraction (empty when faults are off)."""
    meta: dict = {}
    if degrade is not None:
        meta["degrade"] = degrade.mode
    if getattr(engine, "faults", None) is not None:
        meta["faults"] = engine.faults.spec
        k_floor = (degrade.k_min if degrade is not None
                   and degrade.k_min is not None else _policy_k_min(policy))
        meta["subk_fraction"] = float(
            (np.asarray(masks).sum(-1) < k_floor).mean())
    return meta


class _SyncGradientStrategy(Strategy):
    """Common machinery: encode rows, realize a schedule, run the fused scan."""

    encoder_name = "hadamard"
    encoder_beta = 2.0

    def _policy(self, engine: ClusterEngine, cfg: dict) -> ActiveSetPolicy:
        policy = cfg.pop("policy", None)
        k = cfg.pop("k", None)
        if policy is not None:
            return policy
        return FastestK(k if k is not None else _default_k(engine.m))

    def _problem(self, spec: ProblemSpec, engine: ClusterEngine, cfg: dict):
        with _obs_span("encode", strategy=self.name, n=spec.n, m=engine.m):
            enc = _resolve_encoder(cfg.pop("encoder", self.encoder_name),
                                   spec.n,
                                   beta=cfg.pop("beta", self.encoder_beta),
                                   seed=cfg.pop("encoder_seed", 0),
                                   m=engine.m)
            prob = make_encoded_problem(spec.X, spec.y, enc, engine.m,
                                        lam=spec.lam)
        return enc, prob

    def run(self, spec, engine, *, steps=200, **cfg):
        policy = self._policy(engine, cfg)
        degrade = _resolve_degrade(policy, cfg)
        enc, prob = self._problem(spec, engine, cfg)
        step_size = cfg.pop("step_size", None) or _auto_step(spec)
        w0 = jnp.asarray(cfg.pop("w0", np.zeros(spec.p)), jnp.float32)
        sched = engine.sample_schedule(steps, policy, degrade=degrade)
        masks = jnp.asarray(sched.masks)
        if spec.h == "l1":
            w, tr = scan_prox(prob, masks, step_size, w0, degrade=degrade)
        else:
            w, tr = scan_gd(prob, masks, step_size, w0, h=spec.h,
                            degrade=degrade)
        return RunResult(
            strategy=self.name, times=sched.times, objective=np.asarray(tr),
            w=np.asarray(w),
            meta={"encoder": enc.name, "beta": enc.beta,
                  "policy": type(policy).__name__, "step_size": step_size,
                  "mean_active": float(sched.masks.sum(1).mean()),
                  **_fault_meta(engine, policy, degrade, sched.masks)},
            schedule=sched)

    def run_batched(self, spec, engine, *, steps=200, trials=1, eval_every=1,
                    placement="vmap", **cfg):
        """R realizations as ONE compiled program: encode once, draw the
        (R, T, m) schedule stack, run the batched runner — vmapped on one
        device or ``shard_map``-ped across the trials mesh (``placement=
        'sharded'``).  ``placement='single'`` takes the sequential host
        loop instead."""
        if placement == "single":
            return Strategy.run_batched(self, spec, engine, steps=steps,
                                        trials=trials, eval_every=eval_every,
                                        **cfg)
        check_trials(steps, trials, eval_every)
        stride_every = resolve_eval_every(steps, eval_every)
        policy = self._policy(engine, cfg)
        degrade = _resolve_degrade(policy, cfg)
        enc, prob = self._problem(spec, engine, cfg)
        step_size = cfg.pop("step_size", None) or _auto_step(spec)
        w0 = jnp.asarray(cfg.pop("w0", np.zeros(spec.p)), jnp.float32)
        w0 = jnp.tile(w0[None], (trials, 1))       # donated by the runner
        batch = engine.sample_schedules(steps, policy, trials,
                                        degrade=degrade)
        masks = jnp.asarray(batch.masks)
        meta = {"encoder": enc.name, "beta": enc.beta,
                "policy": type(policy).__name__, "step_size": step_size,
                "trials": trials, "eval_every": eval_every,
                "batched": True,
                "mean_active": float(batch.masks.sum(-1).mean()),
                **_fault_meta(engine, policy, degrade, batch.masks)}
        if placement == "sharded":
            if spec.h == "l1":
                w, tr, ndev = sharded_scan_prox(prob, masks, step_size, w0,
                                                eval_every=stride_every,
                                                degrade=degrade)
            else:
                w, tr, ndev = sharded_scan_gd(prob, masks, step_size, w0,
                                              h=spec.h,
                                              eval_every=stride_every,
                                              degrade=degrade)
            meta.update(placement="sharded", placement_devices=ndev)
        elif spec.h == "l1":
            w, tr = batched_scan_prox(prob, masks, step_size, w0,
                                      eval_every=stride_every,
                                      degrade=degrade)
        else:
            w, tr = batched_scan_gd(prob, masks, step_size, w0, h=spec.h,
                                    eval_every=stride_every, degrade=degrade)
        return TrialsResult(
            strategy=self.name,
            times=batch.times[:, stride_every - 1::stride_every],
            objective=np.asarray(tr), w=np.asarray(w),
            meta=meta, schedules=batch)

    def run_cellbatched(self, spec, engines, *, steps=200, trials=1,
                        eval_every=1, cfgs=None):
        """C compatible cells of a matrix as ONE compiled program.

        ``engines[ci]`` / ``cfgs[ci]`` carry cell ci's cluster (delay model,
        seed) and config; cells may differ in policy, delay, and
        ``step_size`` but must share the problem, encoder config, worker
        count and step budget (``experiments.execute`` groups under exactly
        those rules).  The problem is encoded ONCE, the C x R schedule
        stacks are concatenated along the realization axis, and one
        ``batched_scan_*`` call runs the whole stack — step sizes ride a
        per-realization vector through the runner's vmap.  Returns one
        ``TrialsResult`` per cell (meta gains ``cell_batched: C``), each
        matching its ``run_batched`` equivalent to float rounding.
        """
        C = len(engines)
        cfgs = [dict(c) for c in (cfgs if cfgs is not None else [{}] * C)]
        if len(cfgs) != C:
            raise ValueError(f"{C} engines but {len(cfgs)} cfgs")
        check_trials(steps, trials, eval_every)
        stride_every = resolve_eval_every(steps, eval_every)
        ms = {e.m for e in engines}
        if len(ms) > 1:
            raise ValueError(f"cell batch mixes worker counts {sorted(ms)}")
        policies = [self._policy(e, cfg) for e, cfg in zip(engines, cfgs)]
        degrades = [_resolve_degrade(pol, cfg)
                    for pol, cfg in zip(policies, cfgs)]
        # the runner's degrade config is static for the whole stacked
        # program, so a batch must be degrade-homogeneous (the executor's
        # compat key groups on the degrade spec — this is a backstop)
        if len({d for d in degrades}) > 1:
            raise ValueError("cell batch mixes degrade policies "
                             f"{sorted({str(d) for d in degrades})}")
        degrade = degrades[0]
        enc, prob = self._problem(spec, engines[0], cfgs[0])
        for cfg in cfgs[1:]:     # the shared encode consumed cfgs[0]'s keys
            for key in ("encoder", "beta", "encoder_seed"):
                cfg.pop(key, None)
        step_sizes = [cfg.pop("step_size", None) or _auto_step(spec)
                      for cfg in cfgs]
        w0s = [jnp.asarray(cfg.pop("w0", np.zeros(spec.p)), jnp.float32)
               for cfg in cfgs]
        batches = [e.sample_schedules(steps, pol, trials, degrade=degrade)
                   for e, pol in zip(engines, policies)]
        masks = jnp.concatenate([jnp.asarray(b.masks) for b in batches])
        w0 = jnp.concatenate([jnp.tile(w[None], (trials, 1)) for w in w0s])
        step_vec = jnp.repeat(jnp.asarray(step_sizes, jnp.float32), trials)
        if spec.h == "l1":
            w, tr = batched_scan_prox(prob, masks, step_vec, w0,
                                      eval_every=stride_every,
                                      degrade=degrade)
        else:
            w, tr = batched_scan_gd(prob, masks, step_vec, w0, h=spec.h,
                                    eval_every=stride_every, degrade=degrade)
        w, tr = np.asarray(w), np.asarray(tr)
        results = []
        for ci in range(C):
            sl = slice(ci * trials, (ci + 1) * trials)
            batch = batches[ci]
            results.append(TrialsResult(
                strategy=self.name,
                times=batch.times[:, stride_every - 1::stride_every],
                objective=tr[sl], w=w[sl],
                meta={"encoder": enc.name, "beta": enc.beta,
                      "policy": type(policies[ci]).__name__,
                      "step_size": step_sizes[ci], "trials": trials,
                      "eval_every": eval_every, "batched": True,
                      "cell_batched": C,
                      "mean_active": float(batch.masks.sum(-1).mean()),
                      **_fault_meta(engines[ci], policies[ci], degrade,
                                    batch.masks)},
                schedules=batch))
        return results


@register_strategy("coded-gd")
class CodedGD(_SyncGradientStrategy):
    """Encoded gradient descent / ISTA (paper §2.1, Algorithms 1-2)."""


@register_strategy("coded-prox")
class CodedProx(_SyncGradientStrategy):
    """Encoded proximal gradient for the l1 objective (paper Thm 5)."""

    def run(self, spec, engine, *, steps=200, **cfg):
        if spec.h != "l1":
            raise ValueError("coded-prox requires an l1 ProblemSpec")
        return super().run(spec, engine, steps=steps, **cfg)

    def run_batched(self, spec, engine, *, steps=200, trials=1, eval_every=1,
                    **cfg):
        if spec.h != "l1":
            raise ValueError("coded-prox requires an l1 ProblemSpec")
        return super().run_batched(spec, engine, steps=steps, trials=trials,
                                   eval_every=eval_every, **cfg)

    def run_cellbatched(self, spec, engines, *, steps=200, trials=1,
                        eval_every=1, cfgs=None):
        if spec.h != "l1":
            raise ValueError("coded-prox requires an l1 ProblemSpec")
        return super().run_cellbatched(spec, engines, steps=steps,
                                       trials=trials, eval_every=eval_every,
                                       cfgs=cfgs)


@register_strategy("uncoded")
class UncodedSync(_SyncGradientStrategy):
    """Synchronous uncoded baseline: S = I, fastest-k drops data (§5)."""
    encoder_name = "uncoded"
    encoder_beta = 1.0


@register_strategy("replication")
class Replication(_SyncGradientStrategy):
    """beta-fold data replication baseline: S = [I; ...; I] (§5)."""
    encoder_name = "replication"
    encoder_beta = 2.0


@register_strategy("coded-lbfgs")
class CodedLBFGS(_SyncGradientStrategy):
    """Encoded L-BFGS (paper Thm 4); Python-loop outer iteration (the
    two-loop memory is host state), masks/wall-clock from the engine."""

    def run(self, spec, engine, *, steps=200, **cfg):
        if spec.h != "l2":
            raise ValueError("coded-lbfgs requires the ridge objective")
        policy = self._policy(engine, cfg)
        degrade = _resolve_degrade(policy, cfg)
        if degrade is not None and degrade.mode == "hold":
            raise ValueError("coded-lbfgs supports renormalize/backoff "
                             "degrade only (the two-loop memory is host "
                             "state; see DESIGN.md §14)")
        enc, prob = self._problem(spec, engine, cfg)
        memory = cfg.pop("memory", 10)
        w0 = cfg.pop("w0", None)
        if w0 is not None:
            w0 = jnp.asarray(w0, jnp.float32)
        sched = engine.sample_schedule(steps, policy, degrade=degrade)
        with _obs_span("runner:lbfgs", steps=steps):
            w, tr = run_encoded_lbfgs(prob, sched.masks, memory=memory,
                                      w0=w0)
        return RunResult(
            strategy=self.name, times=sched.times, objective=np.asarray(tr),
            w=np.asarray(w),
            meta={"encoder": enc.name, "beta": enc.beta, "memory": memory,
                  "policy": type(policy).__name__,
                  **_fault_meta(engine, policy, degrade, sched.masks)},
            schedule=sched)

    def run_batched(self, spec, engine, *, steps=200, trials=1, eval_every=1,
                    placement="vmap", **cfg):
        """The two-loop L-BFGS memory is host state, so realizations run
        sequentially whatever the requested ``placement`` — but the encode
        and the schedule stack are built once, and the trace is strided
        like the fused runners."""
        if spec.h != "l2":
            raise ValueError("coded-lbfgs requires the ridge objective")
        check_trials(steps, trials, eval_every)
        stride_every = resolve_eval_every(steps, eval_every)
        policy = self._policy(engine, cfg)
        degrade = _resolve_degrade(policy, cfg)
        if degrade is not None and degrade.mode == "hold":
            raise ValueError("coded-lbfgs supports renormalize/backoff "
                             "degrade only (the two-loop memory is host "
                             "state; see DESIGN.md §14)")
        enc, prob = self._problem(spec, engine, cfg)
        memory = cfg.pop("memory", 10)
        w0 = cfg.pop("w0", None)
        if w0 is not None:
            w0 = jnp.asarray(w0, jnp.float32)
        batch = engine.sample_schedules(steps, policy, trials,
                                        degrade=degrade)
        ws, trs = [], []
        for r in range(trials):
            with _obs_span("runner:lbfgs", steps=steps, realization=r):
                w, tr = run_encoded_lbfgs(prob, batch.masks[r],
                                          memory=memory, w0=w0)
            ws.append(np.asarray(w))
            trs.append(np.asarray(tr))
        stride = slice(stride_every - 1, None, stride_every)
        return TrialsResult(
            strategy=self.name, times=batch.times[:, stride],
            objective=np.stack(trs)[:, stride], w=np.stack(ws),
            meta={"encoder": enc.name, "beta": enc.beta, "memory": memory,
                  "policy": type(policy).__name__, "trials": trials,
                  "eval_every": eval_every, "batched": False,
                  **_fault_meta(engine, policy, degrade, batch.masks)},
            schedules=batch)


@register_strategy("coded-bcd")
class CodedBCD(_SyncGradientStrategy):
    """Encoded block coordinate descent (model parallelism, paper §2.2).

    Encodes the FEATURE dimension and minimizes phi(Xw) = 1/(2n)||Xw - y||^2
    (no regularizer — the lifted geometry is exact, Thm 6); the reported
    objective is phi, noted in ``meta``.
    """

    def run(self, spec, engine, *, steps=200, **cfg):
        policy = self._policy(engine, cfg)
        degrade = _resolve_degrade(policy, cfg)
        if degrade is not None and degrade.mode == "hold":
            raise ValueError("coded-bcd supports renormalize/backoff degrade "
                             "only (an erased block simply holds its "
                             "coordinates; see DESIGN.md §14)")
        with _obs_span("encode", strategy=self.name, p=spec.p, m=engine.m):
            enc = _resolve_encoder(cfg.pop("encoder", "hadamard"), spec.p,
                                   beta=cfg.pop("beta", 2.0),
                                   seed=cfg.pop("encoder_seed", 0),
                                   m=engine.m)
            val, grad = _phi_quadratic(spec.y)
            prob = make_lifted_problem(spec.X, enc, engine.m, val, grad)
        # Hessian of the lifted quadratic is S X^T X S^T / n, norm <= beta * L
        step_size = cfg.pop("step_size", None) or \
            0.9 / (spec.lipschitz() * float(enc.beta))
        v0 = jnp.zeros((engine.m, prob.XS.shape[-1]), jnp.float32)
        sched = engine.sample_schedule(steps, policy, degrade=degrade)
        v, tr = scan_bcd(prob, jnp.asarray(sched.masks), step_size, v0)
        # align: tr[t+1] is the objective AFTER commit t (length T+1)
        return RunResult(
            strategy=self.name, times=sched.times,
            objective=np.asarray(tr)[1:], w=np.asarray(v),
            meta={"encoder": enc.name, "beta": enc.beta,
                  "objective": "phi(Xw) (unregularized, exact-optimum family)",
                  "step_size": step_size,
                  **_fault_meta(engine, policy, degrade, sched.masks)},
            schedule=sched)

    def run_batched(self, spec, engine, *, steps=200, trials=1, eval_every=1,
                    placement="vmap", **cfg):
        if placement == "single":
            return Strategy.run_batched(self, spec, engine, steps=steps,
                                        trials=trials, eval_every=eval_every,
                                        **cfg)
        check_trials(steps, trials, eval_every)
        stride_every = resolve_eval_every(steps, eval_every)
        policy = self._policy(engine, cfg)
        degrade = _resolve_degrade(policy, cfg)
        if degrade is not None and degrade.mode == "hold":
            raise ValueError("coded-bcd supports renormalize/backoff degrade "
                             "only (an erased block simply holds its "
                             "coordinates; see DESIGN.md §14)")
        with _obs_span("encode", strategy=self.name, p=spec.p, m=engine.m):
            enc = _resolve_encoder(cfg.pop("encoder", "hadamard"), spec.p,
                                   beta=cfg.pop("beta", 2.0),
                                   seed=cfg.pop("encoder_seed", 0),
                                   m=engine.m)
            val, grad = _phi_quadratic(spec.y)
            prob = make_lifted_problem(spec.X, enc, engine.m, val, grad)
        step_size = cfg.pop("step_size", None) or \
            0.9 / (spec.lipschitz() * float(enc.beta))
        batch = engine.sample_schedules(steps, policy, trials,
                                        degrade=degrade)
        v0 = jnp.zeros((trials, engine.m, prob.XS.shape[-1]), jnp.float32)
        v, tr = batched_scan_bcd(prob, jnp.asarray(batch.masks), step_size,
                                 v0, eval_every=stride_every)
        meta = {"encoder": enc.name, "beta": enc.beta,
                "objective": "phi(Xw) (unregularized, exact-optimum family)",
                "step_size": step_size, "trials": trials,
                "eval_every": eval_every, "batched": True,
                **_fault_meta(engine, policy, degrade, batch.masks)}
        if placement == "sharded":
            # the lifted problem carries host phi callables, which shard_map
            # cannot partition — realizations stay vmapped on one device
            meta.update(placement="vmap",
                        placement_fallback="sharded unsupported for the "
                                           "lifted BCD problem")
        # batched bcd traces are post-commit (== scan_bcd's tr[1:] at s=1)
        return TrialsResult(
            strategy=self.name,
            times=batch.times[:, stride_every - 1::stride_every],
            objective=np.asarray(tr), w=np.asarray(v),
            meta=meta, schedules=batch)


# ---------------------------------------------------------------------------
# Coded SGD on the neural model zoo (train-kind cells; DESIGN §15)
# ---------------------------------------------------------------------------

@register_strategy("coded-sgd")
class CodedSGD(Strategy):
    """Gradient-coded data-parallel SGD training a real LM (train/coded.py).

    ``spec`` is a ``repro.train.TrainProblem`` (not a ``ProblemSpec``);
    the ``objective`` trace is the decoded training loss, times come from
    the engine schedule.  cfg: code ("frc" | "cyclic" | "stochastic" |
    "uncoded"), beta, policy/k, lr, warmup, degrade, log_every.  The train
    module is imported lazily so registry load never pulls the model zoo.
    """

    def run(self, spec, engine, *, steps=100, **cfg):
        from repro.train.coded import run_coded_sgd
        return run_coded_sgd(spec, engine, steps=steps, **cfg)

    def run_batched(self, spec, engine, *, steps=100, trials=1, eval_every=1,
                    placement="vmap", **cfg):
        """Sequential trial loop (each trial jit-caches the same step
        program); the base implementation would stack the absent iterate."""
        check_trials(steps, trials, eval_every)
        stride_every = resolve_eval_every(steps, eval_every)
        results = [self.run(spec, engine.trial(r), steps=steps, **dict(cfg))
                   for r in range(trials)]
        stride = slice(stride_every - 1, None, stride_every)
        return TrialsResult(
            strategy=self.name,
            times=np.stack([np.asarray(r.times) for r in results])[:, stride],
            objective=np.stack([np.asarray(r.objective)
                                for r in results])[:, stride],
            w=None,
            meta={**results[0].meta, "trials": trials,
                  "eval_every": eval_every, "batched": False})


# ---------------------------------------------------------------------------
# Asynchronous stale-gradient SGD (the missing baseline from the abstract)
# ---------------------------------------------------------------------------

@register_strategy("async")
class AsyncSGD(Strategy):
    """Asynchronous stale-gradient SGD with bounded staleness (paper §5).

    Uncoded row partition; every arriving worker gradient is applied
    immediately (per-arrival wall-clock — no barrier), computed at the iterate
    that worker last read (per-worker parameter timestamps).  Gradients staler
    than ``staleness_bound`` are discarded by the engine, so the device runner
    only ever sees bounded staleness.
    """

    def run(self, spec, engine, *, steps=200, **cfg):
        if spec.h == "l1":
            raise ValueError("async baseline covers smooth objectives only")
        m = engine.m
        # per-arrival accounting has no barrier to degrade: crashed workers
        # simply stop contributing and corrupt arrivals are discarded by
        # the engine, so any requested degrade mode is a no-op here
        cfg.pop("degrade", None)
        bound = int(cfg.pop("staleness_bound", 2 * m))
        updates = int(cfg.pop("updates", steps * m))
        step_size = (cfg.pop("step_size", None) or _auto_step(spec)) / m
        with _obs_span("encode", strategy=self.name, n=spec.n, m=m):
            enc = make_encoder("uncoded", spec.n, beta=1.0).with_workers(m)
            prob = make_encoded_problem(spec.X, spec.y, enc, m, lam=spec.lam)
        trace: AsyncTrace = engine.sample_async(updates, bound)
        w0 = jnp.asarray(cfg.pop("w0", np.zeros(spec.p)), jnp.float32)
        w, tr = scan_async(prob, jnp.asarray(trace.workers),
                           jnp.asarray(trace.staleness), step_size, w0,
                           buffer_size=bound + 1, h=spec.h)
        meta = {"staleness_bound": bound, "updates": updates,
                "dropped": trace.dropped,
                "mean_staleness": float(trace.staleness.mean()),
                "max_staleness": int(trace.staleness.max()),
                "step_size": step_size}
        if engine.faults is not None:
            meta["faults"] = engine.faults.spec
            meta["corrupted"] = int(trace.corrupted)
        return RunResult(
            strategy=self.name, times=trace.times, objective=np.asarray(tr),
            w=np.asarray(w),
            meta=meta,
            schedule=trace)

    def run_batched(self, spec, engine, *, steps=200, trials=1, eval_every=1,
                    placement="vmap", **cfg):
        if spec.h == "l1":
            raise ValueError("async baseline covers smooth objectives only")
        m = engine.m
        cfg.pop("degrade", None)   # no barrier to degrade (see run())
        bound = int(cfg.pop("staleness_bound", 2 * m))
        updates = int(cfg.pop("updates", steps * m))
        check_trials(updates, trials, eval_every)
        stride_every = resolve_eval_every(updates, eval_every)
        if placement == "single":
            results = [self.run(spec, engine.trial(r), steps=steps,
                                staleness_bound=bound, updates=updates,
                                **dict(cfg))
                       for r in range(trials)]
            stride = slice(stride_every - 1, None, stride_every)
            return TrialsResult(
                strategy=self.name,
                times=np.stack([np.asarray(r.times)
                                for r in results])[:, stride],
                objective=np.stack([np.asarray(r.objective)
                                    for r in results])[:, stride],
                w=np.stack([np.asarray(r.w) for r in results]),
                meta={**results[0].meta, "trials": trials,
                      "eval_every": eval_every, "batched": False})
        step_size = (cfg.pop("step_size", None) or _auto_step(spec)) / m
        with _obs_span("encode", strategy=self.name, n=spec.n, m=m):
            enc = make_encoder("uncoded", spec.n, beta=1.0).with_workers(m)
            prob = make_encoded_problem(spec.X, spec.y, enc, m, lam=spec.lam)
        batch = engine.sample_asyncs(updates, bound, trials)
        w0 = jnp.asarray(cfg.pop("w0", np.zeros(spec.p)), jnp.float32)
        w0 = jnp.tile(w0[None], (trials, 1))       # donated by the runner
        meta = {"staleness_bound": bound, "updates": updates,
                "dropped": [int(d) for d in batch.dropped],
                "mean_staleness": float(batch.staleness.mean()),
                "max_staleness": int(batch.staleness.max()),
                "step_size": step_size, "trials": trials,
                "eval_every": eval_every, "batched": True}
        if engine.faults is not None:
            meta["faults"] = engine.faults.spec
            if batch.corrupted is not None:
                meta["corrupted"] = [int(c) for c in batch.corrupted]
        if placement == "sharded":
            w, tr, ndev = sharded_scan_async(
                prob, jnp.asarray(batch.workers),
                jnp.asarray(batch.staleness), step_size, w0,
                buffer_size=bound + 1, h=spec.h, eval_every=stride_every)
            meta.update(placement="sharded", placement_devices=ndev)
        else:
            w, tr = batched_scan_async(
                prob, jnp.asarray(batch.workers),
                jnp.asarray(batch.staleness), step_size, w0,
                buffer_size=bound + 1, h=spec.h, eval_every=stride_every)
        return TrialsResult(
            strategy=self.name,
            times=batch.times[:, stride_every - 1::stride_every],
            objective=np.asarray(tr), w=np.asarray(w),
            meta=meta, schedules=batch)
