"""Unified strategy interface + registry for straggler-mitigation schemes.

Every scheme the paper compares (§5) — encoded GD / proximal / L-BFGS / BCD,
uncoded synchronous, beta-replication, and asynchronous stale-gradient SGD —
lives behind one ``Strategy`` interface: build the worker-resident problem
for a shared ``ProblemSpec``, ask the ``ClusterEngine`` for a delay
realization, run the fused runner, and return a wall-clock-vs-objective
``RunResult``.  New schemes register themselves with ``@register_strategy``
and become available to ``runtime.compare`` and the benchmarks for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core.data_parallel import (make_encoded_problem,
                                      original_objective)
from repro.core.encoding import LinearEncoder, make_encoder
from repro.core import operators  # noqa: F401  (registers matrix-free encoders)
from repro.core.lbfgs import run_encoded_lbfgs
from repro.core.model_parallel import make_lifted_problem, phi_quadratic

from .engine import ActiveSetPolicy, AsyncTrace, ClusterEngine, FastestK
from .runners import scan_async, scan_bcd, scan_gd, scan_prox

__all__ = [
    "ProblemSpec", "RunResult", "Strategy", "register_strategy",
    "get_strategy", "available_strategies", "json_safe_meta",
]


def json_safe_meta(meta: dict) -> dict:
    """JSON-serializable view of a meta dict: primitives pass through,
    everything else (arrays, policies, ...) is stringified.  Shared by every
    ``to_record`` (RunResult here, WorkloadRunResult in repro.workloads)."""
    return {k: (v if isinstance(v, (int, float, str, bool)) else str(v))
            for k, v in meta.items()}


# ---------------------------------------------------------------------------
# Shared problem description
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ProblemSpec:
    """The ORIGINAL (uncoded) problem every strategy is solving:
    f(w) = 1/(2n) ||X w - y||^2 + lam * h(w)."""
    X: np.ndarray
    y: np.ndarray
    lam: float = 0.05
    h: str = "l2"            # "l2" (ridge), "l1" (lasso), "none"

    @staticmethod
    def synthetic(n: int = 512, p: int = 128, *, noise: float = 0.5,
                  sparse: int = 0, lam: float = 0.05, h: str = "l2",
                  seed: int = 0) -> "ProblemSpec":
        from repro.data import lsq_dataset
        X, y, _ = lsq_dataset(n, p, noise=noise, sparse=sparse, seed=seed)
        return ProblemSpec(X=X, y=y, lam=lam, h=h)

    @property
    def n(self) -> int:
        return self.X.shape[0]

    @property
    def p(self) -> int:
        return self.X.shape[1]

    def lipschitz(self) -> float:
        """Smoothness constant of the data-fit term, max eig of X^T X / n."""
        return float(np.linalg.eigvalsh(self.X.T @ self.X / self.n).max())

    def w_star(self) -> np.ndarray:
        """Closed-form ridge optimum (h == 'l2' only)."""
        if self.h != "l2":
            raise ValueError("closed form only for the ridge objective")
        p = self.p
        return np.linalg.solve(self.X.T @ self.X / self.n +
                               self.lam * np.eye(p), self.X.T @ self.y / self.n)


@dataclasses.dataclass
class RunResult:
    """Wall-clock-vs-objective trace for one (strategy, delay model) cell."""
    strategy: str
    times: np.ndarray       # (T,) elapsed simulated seconds per record point
    objective: np.ndarray   # (T,) objective at each record point
    w: np.ndarray | None = None
    meta: dict = dataclasses.field(default_factory=dict)
    # The realized engine Schedule (or AsyncTrace) behind this run, so callers
    # (repro.workloads) can inspect per-iteration active sets.  Host-side
    # object; deliberately NOT serialized by ``to_record``.
    schedule: Any = None

    @property
    def final_objective(self) -> float:
        return float(self.objective[-1])

    @property
    def wallclock(self) -> float:
        return float(self.times[-1])

    def to_record(self) -> dict:
        """JSON-serializable record (traces included, iterate omitted)."""
        return {
            "strategy": self.strategy,
            "times": [float(t) for t in self.times],
            "objective": [float(v) for v in self.objective],
            "final_objective": self.final_objective,
            "wallclock_s": self.wallclock,
            "meta": json_safe_meta(self.meta),
        }


def _auto_step(spec: ProblemSpec) -> float:
    """Safe GD step for the (possibly encoded, eps<=0.3) smooth part."""
    return 1.0 / (1.3 * spec.lipschitz() + spec.lam)


def _default_k(m: int) -> int:
    return max(1, (3 * m) // 4)


def _resolve_encoder(encoder, n: int, *, beta: float, seed: int,
                     m: int) -> LinearEncoder:
    """Accept an encoder by registry name OR as a LinearEncoder instance
    (operator encoders flow through the strategy layer unchanged), bound to
    the engine's worker count."""
    if isinstance(encoder, LinearEncoder):
        if encoder.n != n:
            raise ValueError(f"encoder dim {encoder.n} != problem dim {n}")
        return encoder.with_workers(m)
    return make_encoder(encoder, n, beta=beta, seed=seed).with_workers(m)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type["Strategy"]] = {}


def register_strategy(name: str):
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_strategy(name: str) -> "Strategy":
    if name not in _REGISTRY:
        raise KeyError(f"unknown strategy '{name}'; have "
                       f"{available_strategies()}")
    return _REGISTRY[name]()


def available_strategies() -> list[str]:
    return sorted(_REGISTRY)


class Strategy:
    """One straggler-mitigation scheme. Subclasses implement ``run``."""

    name = "?"

    def run(self, spec: ProblemSpec, engine: ClusterEngine, *,
            steps: int = 200, **cfg: Any) -> RunResult:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Synchronous data-parallel family (encoded / uncoded / replication)
# ---------------------------------------------------------------------------

class _SyncGradientStrategy(Strategy):
    """Common machinery: encode rows, realize a schedule, run the fused scan."""

    encoder_name = "hadamard"
    encoder_beta = 2.0

    def _policy(self, engine: ClusterEngine, cfg: dict) -> ActiveSetPolicy:
        policy = cfg.pop("policy", None)
        k = cfg.pop("k", None)
        if policy is not None:
            return policy
        return FastestK(k if k is not None else _default_k(engine.m))

    def _problem(self, spec: ProblemSpec, engine: ClusterEngine, cfg: dict):
        enc = _resolve_encoder(cfg.pop("encoder", self.encoder_name), spec.n,
                               beta=cfg.pop("beta", self.encoder_beta),
                               seed=cfg.pop("encoder_seed", 0),
                               m=engine.m)
        return enc, make_encoded_problem(spec.X, spec.y, enc, engine.m,
                                         lam=spec.lam)

    def run(self, spec, engine, *, steps=200, **cfg):
        policy = self._policy(engine, cfg)
        enc, prob = self._problem(spec, engine, cfg)
        step_size = cfg.pop("step_size", None) or _auto_step(spec)
        w0 = jnp.asarray(cfg.pop("w0", np.zeros(spec.p)), jnp.float32)
        sched = engine.sample_schedule(steps, policy)
        masks = jnp.asarray(sched.masks)
        if spec.h == "l1":
            w, tr = scan_prox(prob, masks, step_size, w0)
        else:
            w, tr = scan_gd(prob, masks, step_size, w0, h=spec.h)
        return RunResult(
            strategy=self.name, times=sched.times, objective=np.asarray(tr),
            w=np.asarray(w),
            meta={"encoder": enc.name, "beta": enc.beta,
                  "policy": type(policy).__name__, "step_size": step_size,
                  "mean_active": float(sched.masks.sum(1).mean())},
            schedule=sched)


@register_strategy("coded-gd")
class CodedGD(_SyncGradientStrategy):
    """Encoded gradient descent / ISTA (paper §2.1, Algorithms 1-2)."""


@register_strategy("coded-prox")
class CodedProx(_SyncGradientStrategy):
    """Encoded proximal gradient for the l1 objective (paper Thm 5)."""

    def run(self, spec, engine, *, steps=200, **cfg):
        if spec.h != "l1":
            raise ValueError("coded-prox requires an l1 ProblemSpec")
        return super().run(spec, engine, steps=steps, **cfg)


@register_strategy("uncoded")
class UncodedSync(_SyncGradientStrategy):
    """Synchronous uncoded baseline: S = I, fastest-k drops data (§5)."""
    encoder_name = "uncoded"
    encoder_beta = 1.0


@register_strategy("replication")
class Replication(_SyncGradientStrategy):
    """beta-fold data replication baseline: S = [I; ...; I] (§5)."""
    encoder_name = "replication"
    encoder_beta = 2.0


@register_strategy("coded-lbfgs")
class CodedLBFGS(_SyncGradientStrategy):
    """Encoded L-BFGS (paper Thm 4); Python-loop outer iteration (the
    two-loop memory is host state), masks/wall-clock from the engine."""

    def run(self, spec, engine, *, steps=200, **cfg):
        if spec.h != "l2":
            raise ValueError("coded-lbfgs requires the ridge objective")
        policy = self._policy(engine, cfg)
        enc, prob = self._problem(spec, engine, cfg)
        memory = cfg.pop("memory", 10)
        w0 = cfg.pop("w0", None)
        if w0 is not None:
            w0 = jnp.asarray(w0, jnp.float32)
        sched = engine.sample_schedule(steps, policy)
        w, tr = run_encoded_lbfgs(prob, sched.masks, memory=memory, w0=w0)
        return RunResult(
            strategy=self.name, times=sched.times, objective=np.asarray(tr),
            w=np.asarray(w),
            meta={"encoder": enc.name, "beta": enc.beta, "memory": memory,
                  "policy": type(policy).__name__},
            schedule=sched)


@register_strategy("coded-bcd")
class CodedBCD(_SyncGradientStrategy):
    """Encoded block coordinate descent (model parallelism, paper §2.2).

    Encodes the FEATURE dimension and minimizes phi(Xw) = 1/(2n)||Xw - y||^2
    (no regularizer — the lifted geometry is exact, Thm 6); the reported
    objective is phi, noted in ``meta``.
    """

    def run(self, spec, engine, *, steps=200, **cfg):
        policy = self._policy(engine, cfg)
        enc = _resolve_encoder(cfg.pop("encoder", "hadamard"), spec.p,
                               beta=cfg.pop("beta", 2.0),
                               seed=cfg.pop("encoder_seed", 0), m=engine.m)
        val, grad = phi_quadratic(spec.y)
        prob = make_lifted_problem(spec.X, enc, engine.m, val, grad)
        # Hessian of the lifted quadratic is S X^T X S^T / n, norm <= beta * L
        step_size = cfg.pop("step_size", None) or \
            0.9 / (spec.lipschitz() * float(enc.beta))
        v0 = jnp.zeros((engine.m, prob.XS.shape[-1]), jnp.float32)
        sched = engine.sample_schedule(steps, policy)
        v, tr = scan_bcd(prob, jnp.asarray(sched.masks), step_size, v0)
        # align: tr[t+1] is the objective AFTER commit t (length T+1)
        return RunResult(
            strategy=self.name, times=sched.times,
            objective=np.asarray(tr)[1:], w=np.asarray(v),
            meta={"encoder": enc.name, "beta": enc.beta,
                  "objective": "phi(Xw) (unregularized, exact-optimum family)",
                  "step_size": step_size},
            schedule=sched)


# ---------------------------------------------------------------------------
# Asynchronous stale-gradient SGD (the missing baseline from the abstract)
# ---------------------------------------------------------------------------

@register_strategy("async")
class AsyncSGD(Strategy):
    """Asynchronous stale-gradient SGD with bounded staleness (paper §5).

    Uncoded row partition; every arriving worker gradient is applied
    immediately (per-arrival wall-clock — no barrier), computed at the iterate
    that worker last read (per-worker parameter timestamps).  Gradients staler
    than ``staleness_bound`` are discarded by the engine, so the device runner
    only ever sees bounded staleness.
    """

    def run(self, spec, engine, *, steps=200, **cfg):
        if spec.h == "l1":
            raise ValueError("async baseline covers smooth objectives only")
        m = engine.m
        bound = int(cfg.pop("staleness_bound", 2 * m))
        updates = int(cfg.pop("updates", steps * m))
        step_size = (cfg.pop("step_size", None) or _auto_step(spec)) / m
        enc = make_encoder("uncoded", spec.n, beta=1.0).with_workers(m)
        prob = make_encoded_problem(spec.X, spec.y, enc, m, lam=spec.lam)
        trace: AsyncTrace = engine.sample_async(updates, bound)
        w0 = jnp.asarray(cfg.pop("w0", np.zeros(spec.p)), jnp.float32)
        w, tr = scan_async(prob, jnp.asarray(trace.workers),
                           jnp.asarray(trace.staleness), step_size, w0,
                           buffer_size=bound + 1, h=spec.h)
        return RunResult(
            strategy=self.name, times=trace.times, objective=np.asarray(tr),
            w=np.asarray(w),
            meta={"staleness_bound": bound, "updates": updates,
                  "dropped": trace.dropped,
                  "mean_staleness": float(trace.staleness.mean()),
                  "max_staleness": int(trace.staleness.max()),
                  "step_size": step_size},
            schedule=trace)
