"""repro.runtime.faults — failure injection beyond delay-only stragglers
(DESIGN.md §14).

The paper's sample-path guarantees treat stragglers as *erasures*: a slow
worker is simply absent from A_t and every worker eventually returns.  Real
clusters fail harder — workers crash and never return, racks black out for
a window and come back, a zone takes out a correlated group at once, and a
worker can return a *wrong* answer (bit-flip, torn write) that must be
detected and discarded rather than waited out.  This module gives the
cluster engine that vocabulary while keeping the delay models untouched:

  * a :class:`FaultModel` is a composition of independent injectors
    (:class:`CrashFault`, :class:`BlackoutFault`, :class:`ZoneFault`,
    :class:`CorruptionFault`) realized per delay realization from the ONE
    trial seed (a tagged child stream, so fault draws never perturb the
    delay rng — a fault model with zero realized faults reproduces the
    no-fault schedule bit for bit);
  * the engine stamps ``Schedule.failed`` with per-(iteration, worker)
    fault codes **distinct from "slow"**: ``mask == 0 and failed == OK``
    means erased-but-healthy (the paper's straggler), anything else names
    the failure (see the code table below);
  * a :class:`DegradePolicy` says what the optimizer does when the
    survivor set falls below the decode threshold k — renormalize over
    survivors (default, the existing m/|A_t| math), hold the last good
    gradient with a shrunk step, or have the master extend its deadline
    with exponential backoff so blacked-out workers can rejoin.

Spec strings (the ``--faults`` / ``--degrade`` CLI surface)::

    crash:p=0.2,at=0.5            each worker iid w.p. p crashes at t=0.5
    blackout:p=0.3,at=0.4,dur=0.6 window [0.4, 1.0) for sampled workers
    blackout:...,period=2.0       ...recurring every 2.0 sim-seconds
    zone:workers=0-3,at=0.8       correlated permanent loss of workers 0..3
    zone:workers=0-3,at=0.8,dur=1 ...transient (a zone blackout)
    corrupt:p=0.05                each arrival iid w.p. p is corrupt
    crash:p=0.2,at=0.5;corrupt:p=0.01      compose with ';'

    preset:<name>                 named chaos preset (``FAULT_PRESETS``):
      preset:ec2-tail             recurring short blackouts + rare corrupt
                                  arrivals — the EC2 delay-tail chaos the
                                  paper's wall-clocks were measured under
      preset:zone-outage          a correlated zone (workers 0-3) down for a
                                  window + an independent crash per worker
      preset:flaky-rack           one rack (workers 0-1) in periodic
                                  blackout with corrupt re-arrivals
    Presets expand to ordinary chunks and compose with them:
    ``preset:ec2-tail;crash:p=0.1,at=0.8`` is valid.

    renormalize                   DegradePolicy (default)
    hold:shrink=0.5               reuse last gradient at half step below k
    backoff:base=0.05,retries=4   deadline extension, capped exponential

All times are simulated seconds on the engine's wall clock.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "FAULT_OK", "FAULT_CRASHED", "FAULT_BLACKOUT", "FAULT_CORRUPT",
    "FAULT_KINDS", "FaultEvent", "CrashFault", "BlackoutFault", "ZoneFault",
    "CorruptionFault", "FaultModel", "FaultRealization", "make_fault_model",
    "FAULT_PRESETS", "DegradePolicy", "DEGRADE_MODES", "make_degrade",
]

# ``Schedule.failed`` codes.  OK covers both "active" and "healthy but
# slow" — the mask disambiguates; the other codes name a genuine failure.
FAULT_OK = 0        # healthy (active, or merely slow/erased)
FAULT_CRASHED = 1   # permanently dead at this iteration's start
FAULT_BLACKOUT = 2  # inside a transient blackout window
FAULT_CORRUPT = 3   # arrived (wall-clock charged) but result discarded

FAULT_KINDS = {FAULT_OK: "ok", FAULT_CRASHED: "crashed",
               FAULT_BLACKOUT: "blackout", FAULT_CORRUPT: "corrupt"}

# fault rng tag: keeps fault structure on a child stream of the trial seed
# so delay draws are untouched (see module docstring)
_FAULT_STREAM_TAG = 0xFA017


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One realized fault occurrence, the obs trace's fault lane unit."""
    kind: str          # "crash" | "blackout" | "corrupt"
    worker: int        # worker index
    time: float        # sim-seconds the fault takes effect
    duration: float = 0.0   # blackout window length (0 for crash/corrupt)
    t: int = -1        # iteration index for corruption, -1 for timed faults


def _parse_workers(spec: str, m_hint: int | None = None) -> tuple:
    """``"0-3"`` | ``"0,2,5"`` | ``"0-1,4"`` -> sorted tuple of indices."""
    out: set[int] = set()
    for part in str(spec).split("+"):
        for piece in part.split("/"):
            piece = piece.strip()
            if not piece:
                continue
            if "-" in piece:
                lo, hi = piece.split("-", 1)
                out.update(range(int(lo), int(hi) + 1))
            else:
                out.add(int(piece))
    return tuple(sorted(out))


@dataclasses.dataclass(frozen=True)
class CrashFault:
    """Each worker independently crashes (permanently) w.p. ``p`` at time
    ``at`` (+ Uniform(0, jitter) so crashes need not be simultaneous)."""
    p: float = 0.1
    at: float = 0.5
    jitter: float = 0.0

    def apply(self, rz: "FaultRealization", rng) -> None:
        hit = rng.random(rz.m) < self.p
        when = self.at + (rng.uniform(0.0, self.jitter, rz.m)
                          if self.jitter > 0 else 0.0)
        rz.crash_time = np.where(hit, np.minimum(rz.crash_time, when),
                                 rz.crash_time)


@dataclasses.dataclass(frozen=True)
class BlackoutFault:
    """Each worker independently (w.p. ``p``) goes dark over
    ``[at, at + dur)``; with ``period`` set the window recurs every
    ``period`` sim-seconds (dur < period required)."""
    p: float = 0.2
    at: float = 0.3
    dur: float = 0.5
    period: float | None = None

    def __post_init__(self):
        if self.period is not None and self.dur >= self.period:
            raise ValueError("blackout dur must be < period")

    def apply(self, rz: "FaultRealization", rng) -> None:
        members = rng.random(rz.m) < self.p
        if members.any():
            rz.windows.append((float(self.at), float(self.dur),
                               None if self.period is None
                               else float(self.period), members))


@dataclasses.dataclass(frozen=True)
class ZoneFault:
    """Correlated failure: the named worker group goes down together at
    ``at`` — permanently when ``dur`` is inf (a zone crash), else for a
    shared window (a zone blackout)."""
    workers: tuple = (0,)
    at: float = 0.5
    dur: float = float("inf")

    def apply(self, rz: "FaultRealization", rng) -> None:
        idx = np.asarray([w for w in self.workers if 0 <= w < rz.m],
                         dtype=int)
        if idx.size == 0:
            return
        if np.isinf(self.dur):
            rz.crash_time[idx] = np.minimum(rz.crash_time[idx], self.at)
        else:
            members = np.zeros(rz.m, dtype=bool)
            members[idx] = True
            rz.windows.append((float(self.at), float(self.dur), None,
                               members))


@dataclasses.dataclass(frozen=True)
class CorruptionFault:
    """Each *arrival* is independently corrupt w.p. ``p``: the master
    waited for it (wall-clock charged) but discards the result."""
    p: float = 0.05

    def apply(self, rz: "FaultRealization", rng) -> None:
        rz.corrupt_p = 1.0 - (1.0 - rz.corrupt_p) * (1.0 - self.p)


_INJECTORS = {"crash": CrashFault, "blackout": BlackoutFault,
              "zone": ZoneFault, "corrupt": CorruptionFault}

# Named chaos presets for the workload zoo (``--faults preset:<name>``);
# each expands to ordinary spec chunks, so presets compose with explicit
# injectors and with each other via ';'.
FAULT_PRESETS = {
    # the EC2 delay-tail story (paper §5): machines fall out for short
    # recurring windows and an occasional arrival is garbage
    "ec2-tail": "blackout:p=0.3,at=0.4,dur=0.4,period=2.5;corrupt:p=0.02",
    # a correlated availability-zone outage plus independent attrition
    "zone-outage": "zone:workers=0-3,at=0.6,dur=1.5;crash:p=0.1,at=1.0",
    # one flaky rack: periodic blackout of a fixed pair with corrupt
    # re-arrivals as it flaps
    "flaky-rack": "zone:workers=0-1,at=0.2,dur=0.3;"
                  "blackout:p=0.15,at=0.8,dur=0.4,period=3.0;corrupt:p=0.05",
}


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """A composition of fault injectors; ``realize`` instantiates the
    realization-specific fault structure from the trial seed."""
    injectors: tuple
    spec: str = ""     # the originating spec string (meta / provenance)

    def realize(self, m: int, trial_seed: int) -> "FaultRealization":
        rng = np.random.default_rng(
            np.random.SeedSequence([int(trial_seed) & 0xFFFFFFFF,
                                    _FAULT_STREAM_TAG]))
        rz = FaultRealization(m=int(m), rng=rng)
        for inj in self.injectors:
            inj.apply(rz, rng)
        return rz


class FaultRealization:
    """Per-realization fault structure: crash times, blackout windows and
    the corruption stream.  All queries are vectorized over workers."""

    def __init__(self, m: int, rng):
        self.m = int(m)
        self.rng = rng
        self.crash_time = np.full(self.m, np.inf)
        # (start, dur, period|None, member mask (m,)) per blackout spec
        self.windows: list[tuple] = []
        self.corrupt_p = 0.0

    # -- point-in-time queries ------------------------------------------

    def crashed_at(self, time: float) -> np.ndarray:
        return self.crash_time <= time

    def blackout_at(self, time: float) -> np.ndarray:
        dark = np.zeros(self.m, dtype=bool)
        for start, dur, period, members in self.windows:
            if period is None:
                inside = start <= time < start + dur
            else:
                inside = time >= start and ((time - start) % period) < dur
            if inside:
                dark |= members
        return dark

    def recovery_time(self, time: float) -> np.ndarray:
        """Earliest instant >= ``time`` each worker is out of blackout
        (inf for crashed workers, ``time`` for workers not dark now) —
        the master's lookup for deadline-extension backoff."""
        rec = np.full(self.m, time)
        for start, dur, period, members in self.windows:
            if period is None:
                inside = start <= time < start + dur
                end = start + dur
            else:
                inside = time >= start and ((time - start) % period) < dur
                end = (start + np.floor((time - start) / period) * period
                       + dur) if time >= start else start + dur
            if inside:
                rec = np.where(members, np.maximum(rec, end), rec)
        return np.where(self.crashed_at(time), np.inf, rec)

    def corrupt_draw(self, count: int) -> np.ndarray:
        """Bernoulli(corrupt_p) over ``count`` arrivals, consuming the
        realization's fault stream (deterministic given the sample path)."""
        if self.corrupt_p <= 0.0 or count == 0:
            return np.zeros(count, dtype=bool)
        return self.rng.random(count) < self.corrupt_p

    def any_timed(self) -> bool:
        return bool(np.isfinite(self.crash_time).any() or self.windows)

    # -- obs events ------------------------------------------------------

    def static_events(self, horizon: float, max_events: int = 1024) -> list:
        """Crash and blackout :class:`FaultEvent` rows within the realized
        schedule's horizon (corruption events are appended by the engine
        as they occur)."""
        events: list[FaultEvent] = []
        for i in np.nonzero(np.isfinite(self.crash_time))[0]:
            if self.crash_time[i] <= horizon:
                events.append(FaultEvent("crash", int(i),
                                         float(self.crash_time[i])))
        for start, dur, period, members in self.windows:
            starts = [start] if period is None else [
                start + j * period
                for j in range(int(max(0.0, horizon - start) // period) + 1)]
            for s in starts:
                if s > horizon or len(events) >= max_events:
                    break
                for i in np.nonzero(members)[0]:
                    if self.crash_time[i] <= s:
                        continue   # already dead; crash event covers it
                    events.append(FaultEvent("blackout", int(i), float(s),
                                             duration=float(dur)))
        events.sort(key=lambda e: (e.time, e.worker))
        return events[:max_events]


def _coerce(val: str):
    if val == "inf":
        return float("inf")
    try:
        return int(val)
    except ValueError:
        try:
            return float(val)
        except ValueError:
            return val


def make_fault_model(spec) -> FaultModel | None:
    """Parse a ``--faults`` spec string (see module docstring) into a
    :class:`FaultModel`; passes through None / FaultModel unchanged."""
    if spec is None or isinstance(spec, FaultModel):
        return spec
    spec = str(spec).strip()
    if not spec or spec in ("none", "0"):
        return None
    injectors = []
    chunks = []
    for chunk in spec.split(";"):
        chunk = chunk.strip()
        if not chunk:
            continue
        name, _, arg = chunk.partition(":")
        if name.strip() == "preset":
            key = arg.strip()
            if key not in FAULT_PRESETS:
                raise KeyError(f"unknown fault preset '{key}'; have "
                               f"{sorted(FAULT_PRESETS)}")
            chunks.extend(p.strip() for p in FAULT_PRESETS[key].split(";"))
        else:
            chunks.append(chunk)
    for chunk in chunks:
        name, _, argstr = chunk.partition(":")
        name = name.strip()
        if name not in _INJECTORS:
            raise KeyError(f"unknown fault injector '{name}'; have "
                           f"{sorted(_INJECTORS)}")
        kw = {}
        for pair in filter(None, (p.strip() for p in argstr.split(","))):
            key, _, val = pair.partition("=")
            key = key.strip()
            if name == "zone" and key == "workers":
                kw[key] = _parse_workers(val)
            else:
                kw[key] = _coerce(val.strip())
        injectors.append(_INJECTORS[name](**kw))
    if not injectors:
        return None
    return FaultModel(tuple(injectors), spec=spec)


# ---------------------------------------------------------------------------
# Degradation policies: what happens below the decode threshold k?
# ---------------------------------------------------------------------------

DEGRADE_MODES = ("renormalize", "hold", "backoff")


@dataclasses.dataclass(frozen=True)
class DegradePolicy:
    """What the optimizer/master does when |survivors| < k (DESIGN.md §14).

    * ``renormalize`` — decode weights renormalize over the survivor set
      (the existing m/|A_t| masked-mean math; an empty set yields a zero
      gradient, i.e. the iterate holds still).  Pure math, no state.
    * ``hold`` — runner-side: below ``k_min`` survivors reuse the last
      full-rank gradient at ``shrink``x the step size (momentum-free
      Polyak-style damping); needs a gradient carry in the scan.
    * ``backoff`` — engine-side: the master extends its deadline in
      capped exponential windows (``base * 2^j``, ``retries`` attempts)
      so blacked-out workers can rejoin before the round commits.
    """
    mode: str = "renormalize"
    k_min: int | None = None   # decode threshold; None = policy's k
    shrink: float = 0.5        # hold-mode step multiplier below k
    base: float = 0.05         # backoff first window (sim-seconds)
    retries: int = 4           # backoff attempts (cap of the exponential)

    def __post_init__(self):
        if self.mode not in DEGRADE_MODES:
            raise KeyError(f"unknown degrade mode '{self.mode}'; have "
                           f"{DEGRADE_MODES}")

    @property
    def is_default(self) -> bool:
        return self.mode == "renormalize"


def make_degrade(spec) -> DegradePolicy | None:
    """Parse ``--degrade`` specs: ``hold``, ``hold:shrink=0.25,k_min=4``,
    ``backoff:base=0.1,retries=3``; None/''/'renormalize' -> None (the
    default math needs no policy object)."""
    if spec is None or isinstance(spec, DegradePolicy):
        return spec
    spec = str(spec).strip()
    if not spec or spec == "none":
        return None
    mode, _, argstr = spec.partition(":")
    kw = {}
    for pair in filter(None, (p.strip() for p in argstr.split(","))):
        key, _, val = pair.partition("=")
        kw[key.strip()] = _coerce(val.strip())
    pol = DegradePolicy(mode=mode.strip(), **kw)
    return None if pol.is_default and not kw else pol
