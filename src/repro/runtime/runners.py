"""Device-resident iteration loops: one ``lax.scan`` fusion per run.

The seed repo ran every strategy as a host loop — one jitted step per
iteration, a host sync to append ``float(objective)`` to a Python list, and a
fresh dispatch per step.  These runners keep the entire (T, m) mask schedule
AND the objective trace on device: a single compiled program scans over the
schedule and returns the full trace.  ``core.data_parallel`` /
``core.model_parallel`` ``run_*`` entry points are now thin wrappers over
these (identical math, identical op order, so traces agree to float rounding).

``scan_async`` is the new asynchronous stale-gradient SGD runner: it consumes
a per-arrival event stream from ``runtime.engine`` and maintains a circular
buffer of the last ``staleness_bound + 1`` iterates, indexing it with each
update's staleness — bounded-staleness semantics with per-worker parameter
timestamps, fully fused on device.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.data_parallel import (EncodedProblem, masked_gradient,
                                      original_objective, prox_l1)
from repro.core.model_parallel import LiftedProblem

__all__ = ["scan_gd", "scan_prox", "scan_bcd", "scan_async"]


@partial(jax.jit, static_argnames=("h",))
def scan_gd(prob: EncodedProblem, masks: jax.Array, step_size,
            w0: jax.Array, h: str = "l2"):
    """Encoded GD over a (T, m) mask schedule, fused into one scan.

    Returns (w_T, trace) with trace[t] = f(w_{t+1}) on the original problem —
    the same convention as the legacy per-step loop.
    """
    def body(w, mask):
        g = masked_gradient(prob, w, mask)
        if h == "l2":
            g = g + prob.lam * w
        w = w - step_size * g
        return w, original_objective(prob, w, h=h)

    return lax.scan(body, w0, masks)


@jax.jit
def scan_prox(prob: EncodedProblem, masks: jax.Array, step_size,
              w0: jax.Array):
    """Encoded proximal gradient (ISTA, l1) over a mask schedule."""
    def body(w, mask):
        g = masked_gradient(prob, w, mask)
        w = prox_l1(w - step_size * g, step_size * prob.lam)
        return w, original_objective(prob, w, h="l1")

    return lax.scan(body, w0, masks)


# LiftedProblem carries Python callables (phi), so the scan cannot be jitted
# on the problem pytree; cache one compiled runner per (phi_val, phi_grad)
# pair (hashed by closure identity) so repeated runs on the same problem skip
# retracing.  Bounded: each entry pins an XLA executable + the arrays the phi
# closures capture, and fresh phi closures never hit, so old entries must be
# evicted.
@lru_cache(maxsize=8)
def _bcd_runner(phi_val, phi_grad):
    @jax.jit
    def run(XS, masks, step_size, v0):
        def body(v, mask):
            u = jnp.einsum("mnb,mb->mn", XS, v)
            z = u.sum(axis=0)
            gphi = phi_grad(z)
            d = -step_size * jnp.einsum("mnb,n->mb", XS, gphi)
            return v + mask[:, None] * d, phi_val(z)

        vT, trace = lax.scan(body, v0, masks)
        z_final = jnp.einsum("mnb,mb->n", XS, vT)
        return vT, jnp.concatenate([trace, phi_val(z_final)[None]])

    return run


def scan_bcd(prob: LiftedProblem, masks: jax.Array, step_size,
             v0: jax.Array):
    """Encoded BCD (model parallelism) over a mask schedule.

    Trace convention matches the legacy loop: trace[t] = phi(z_t) BEFORE the
    t-th commit, with the final objective appended (length T + 1).
    """
    run = _bcd_runner(prob.phi_val, prob.phi_grad)
    return run(prob.XS, masks, jnp.asarray(step_size, prob.XS.dtype), v0)


@partial(jax.jit, static_argnames=("buffer_size", "h"))
def scan_async(prob: EncodedProblem, workers: jax.Array, staleness: jax.Array,
               step_size, w0: jax.Array, buffer_size: int, h: str = "l2"):
    """Asynchronous stale-gradient SGD over a per-arrival event stream.

    workers[u]   — which worker's gradient lands at update u;
    staleness[u] — how many master updates happened since that worker read w.

    The carry holds a ring buffer of the last ``buffer_size`` iterates
    (buffer_size must exceed the engine's staleness bound); update u computes
    worker i's block gradient at the stale iterate and applies it
    immediately.  The per-worker gradient is scaled by m so it is an unbiased
    estimate of the full gradient.
    """
    m = prob.SX.shape[0]

    def body(carry, ev):
        w, buf, head = carry
        i, tau = ev
        w_stale = buf[jnp.mod(head - tau, buffer_size)]
        SXi = prob.SX[i]                       # (r, p) block of worker i
        r = SXi @ w_stale - prob.Sy[i]
        g = (SXi.T @ r) * (m / (prob.n * prob.beta))
        if h == "l2":
            g = g + prob.lam * w_stale
        w_new = w - step_size * g
        head_new = head + 1
        buf = buf.at[jnp.mod(head_new, buffer_size)].set(w_new)
        return (w_new, buf, head_new), original_objective(prob, w_new, h=h)

    buf0 = jnp.tile(w0[None], (buffer_size, 1))
    (w_final, _, _), trace = lax.scan(
        body, (w0, buf0, jnp.int32(0)),
        (workers.astype(jnp.int32), staleness.astype(jnp.int32)))
    return w_final, trace
