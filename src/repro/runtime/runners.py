"""Device-resident iteration loops: one ``lax.scan`` fusion per run.

The seed repo ran every strategy as a host loop — one jitted step per
iteration, a host sync to append ``float(objective)`` to a Python list, and a
fresh dispatch per step.  These runners keep the entire (T, m) mask schedule
AND the objective trace on device: a single compiled program scans over the
schedule and returns the full trace.  ``core.data_parallel`` /
``core.model_parallel`` ``run_*`` entry points are now thin wrappers over
these (identical math, identical op order, so traces agree to float rounding).

``scan_async`` is the asynchronous stale-gradient SGD runner: it consumes
a per-arrival event stream from ``runtime.engine`` and maintains a circular
buffer of the last ``staleness_bound + 1`` iterates, indexing it with each
update's staleness — bounded-staleness semantics with per-worker parameter
timestamps, fully fused on device.

``batched_scan_*`` are the Monte-Carlo variants (DESIGN.md §9): ``jax.vmap``
over a leading realization axis inside ONE jit, so "R delay realizations of
one cell" is a single compiled program — every per-step op carries the whole
realization batch instead of dispatching R separate scans.  The carry buffer
is donated (callers hand a fresh (R, ...) stack per call) and ``eval_every``
strides the O(n·p) ``original_objective`` pass: with ``eval_every=s`` the
trace holds f after steps s, 2s, ..., i.e. every s-th entry of the dense
trace.  The jit cache is the cell-level executable cache: every cell of a
comparison matrix with the same (R, T, m, p) shape reuses one executable.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from repro.core.data_parallel import (EncodedProblem, masked_gradient,
                                      original_objective, prox_l1)
from repro.core.model_parallel import LiftedProblem
from repro.kernels.fused_step import fused_enabled, fused_masked_gradient
from repro.obs.trace import current_recorder as _obs_recorder

__all__ = [
    "scan_gd", "scan_prox", "scan_bcd", "scan_async",
    "batched_scan_gd", "batched_scan_prox", "batched_scan_bcd",
    "batched_scan_async",
    "sharded_scan_gd", "sharded_scan_prox", "sharded_scan_async",
    "trials_device_count",
]


def _traced_call(name: str, fn, *args, **kw):
    """Dispatch a runner; under an active obs ``TraceRecorder`` the call is
    wrapped in a host-clock span and blocked on every output leaf so the
    span covers the real device execute time.  With tracing off this is one
    module-global check and the dispatch stays asynchronous."""
    rec = _obs_recorder()
    if rec is None:
        return fn(*args, **kw)
    with rec.span(name):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return out


# ---------------------------------------------------------------------------
# Shared per-step math (single source of truth for fused + batched runners)
# ---------------------------------------------------------------------------

def _masked_grad(prob: EncodedProblem, w, mask):
    """The per-step masked gradient: the fused Pallas megakernel
    (``kernels/fused_step.py`` — matvec + erasure + combine in one VMEM
    pass) when ``fused_enabled()`` (TPU default, ``REPRO_FUSED`` override),
    the dense-einsum path of ``core.data_parallel`` everywhere else.  The
    branch is trace-time, so each compiled runner bakes in one path."""
    if fused_enabled():
        return fused_masked_gradient(prob.SX, prob.Sy, w, mask,
                                     n=prob.n, beta=prob.beta)
    return masked_gradient(prob, w, mask)


def _runner_name(base: str) -> str:
    """Obs span name for a runner dispatch; the fused megakernel path is
    called out so traces distinguish it from the dense step."""
    return base + ":fused" if fused_enabled() else base


def _gd_step(prob: EncodedProblem, w, mask, step_size, h: str):
    g = _masked_grad(prob, w, mask)
    if h == "l2":
        g = g + prob.lam * w
    return w - step_size * g


def _prox_step(prob: EncodedProblem, w, mask, step_size):
    g = _masked_grad(prob, w, mask)
    return prox_l1(w - step_size * g, step_size * prob.lam)


# -- sub-k degradation (repro.runtime.faults, DESIGN.md §14) ----------------
#
# ``degrade`` reaches the runners as a static hashable tuple
# ("hold", k_min, shrink) or None; only hold-mode needs runner support (a
# gradient carry), renormalize is the default masked-mean math and backoff
# lives in the engine.  None keeps every runner on its pre-fault trace.

def _degrade_tuple(degrade):
    """Normalize DegradePolicy | tuple | None to the static runner arg."""
    if degrade is None or isinstance(degrade, tuple):
        return degrade
    if getattr(degrade, "mode", None) == "hold":
        return ("hold", int(degrade.k_min or 1), float(degrade.shrink))
    return None


def _hold_gd_step(prob: EncodedProblem, carry, mask, step_size, h: str,
                  k_min: int, shrink: float):
    """GD step on a (w, g_prev) carry: below ``k_min`` survivors the last
    good gradient is reused at ``shrink`` x its previous scale, and the
    shrunk gradient re-enters the carry — consecutive sub-k rounds decay
    geometrically (total held displacement <= step * shrink/(1-shrink) *
    ||g_last||, so a long blackout can never run away on a stale
    direction).  An initial sub-k round holds still (g_prev0 = 0)."""
    w, g_prev = carry
    g_raw = _masked_grad(prob, w, mask)
    if h == "l2":
        g_raw = g_raw + prob.lam * w
    subk = mask.sum() < k_min
    g = jnp.where(subk, shrink * g_prev, g_raw)
    return (w - step_size * g, g)


def _hold_prox_step(prob: EncodedProblem, carry, mask, step_size,
                    k_min: int, shrink: float):
    w, g_prev = carry
    g_raw = _masked_grad(prob, w, mask)
    subk = mask.sum() < k_min
    g = jnp.where(subk, shrink * g_prev, g_raw)
    return (prox_l1(w - step_size * g, step_size * prob.lam), g)


def _async_step(prob: EncodedProblem, carry, ev, step_size, buffer_size: int,
                h: str):
    """One applied update of stale-gradient SGD on the ring-buffer carry."""
    m = prob.SX.shape[0]
    w, buf, head = carry
    i, tau = ev
    w_stale = buf[jnp.mod(head - tau, buffer_size)]
    SXi = prob.SX[i]                       # (r, p) block of worker i
    r = SXi @ w_stale - prob.Sy[i]
    g = (SXi.T @ r) * (m / (prob.n * prob.beta))
    if h == "l2":
        g = g + prob.lam * w_stale
    w_new = w - step_size * g
    head_new = head + 1
    buf = buf.at[jnp.mod(head_new, buffer_size)].set(w_new)
    return (w_new, buf, head_new)


def _strided_scan(step, evalf, carry0, xs, eval_every: int):
    """Scan ``step`` over ``xs`` emitting ``evalf(carry)`` every
    ``eval_every`` steps (a nested scan, so the stride stays on device).
    With ``eval_every=1`` this is the plain fused scan; otherwise the trace
    has length T // eval_every with trace[j] = evalf after step (j+1)*s.
    """
    length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    if eval_every == 1:
        def body(c, x):
            c = step(c, x)
            return c, evalf(c)
        return lax.scan(body, carry0, xs)
    if eval_every < 1 or length % eval_every:
        raise ValueError(f"eval_every={eval_every} must be a positive "
                         f"divisor of the {length}-step schedule")
    blocks = jax.tree_util.tree_map(
        lambda a: a.reshape((length // eval_every, eval_every) + a.shape[1:]),
        xs)

    def outer(c, xb):
        c = lax.scan(lambda c2, x: (step(c2, x), None), c, xb)[0]
        return c, evalf(c)

    return lax.scan(outer, carry0, blocks)


# ---------------------------------------------------------------------------
# Single-realization fused runners
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("h", "eval_every", "degrade"))
def _scan_gd(prob: EncodedProblem, masks: jax.Array, step_size,
             w0: jax.Array, h: str = "l2", eval_every: int = 1,
             degrade=None):
    if degrade is not None:
        _, k_min, shrink = degrade
        (wT, _), trace = _strided_scan(
            lambda c, mask: _hold_gd_step(prob, c, mask, step_size, h,
                                          k_min, shrink),
            lambda c: original_objective(prob, c[0], h=h),
            (w0, jnp.zeros_like(w0)), masks, eval_every)
        return wT, trace
    return _strided_scan(lambda w, mask: _gd_step(prob, w, mask, step_size, h),
                         lambda w: original_objective(prob, w, h=h),
                         w0, masks, eval_every)


def scan_gd(prob: EncodedProblem, masks: jax.Array, step_size,
            w0: jax.Array, h: str = "l2", eval_every: int = 1,
            degrade=None):
    """Encoded GD over a (T, m) mask schedule, fused into one scan.

    Returns (w_T, trace) with trace[t] = f(w_{t+1}) on the original problem —
    the same convention as the legacy per-step loop (``eval_every=s``
    strides the trace like the batched runners).  ``degrade`` selects the
    sub-k behavior (hold-mode gradient carry); None is the default
    renormalized math.
    """
    return _traced_call(_runner_name("runner:gd"), _scan_gd, prob, masks,
                        step_size, w0, h=h, eval_every=eval_every,
                        degrade=_degrade_tuple(degrade))


@partial(jax.jit, static_argnames=("eval_every", "degrade"))
def _scan_prox(prob: EncodedProblem, masks: jax.Array, step_size,
               w0: jax.Array, eval_every: int = 1, degrade=None):
    if degrade is not None:
        _, k_min, shrink = degrade
        (wT, _), trace = _strided_scan(
            lambda c, mask: _hold_prox_step(prob, c, mask, step_size,
                                            k_min, shrink),
            lambda c: original_objective(prob, c[0], h="l1"),
            (w0, jnp.zeros_like(w0)), masks, eval_every)
        return wT, trace
    return _strided_scan(lambda w, mask: _prox_step(prob, w, mask, step_size),
                         lambda w: original_objective(prob, w, h="l1"),
                         w0, masks, eval_every)


def scan_prox(prob: EncodedProblem, masks: jax.Array, step_size,
              w0: jax.Array, eval_every: int = 1, degrade=None):
    """Encoded proximal gradient (ISTA, l1) over a mask schedule."""
    return _traced_call(_runner_name("runner:prox"), _scan_prox, prob, masks,
                        step_size, w0, eval_every=eval_every,
                        degrade=_degrade_tuple(degrade))


# LiftedProblem carries Python callables (phi), so the scan cannot be jitted
# on the problem pytree; cache one compiled runner per (phi_val, phi_grad)
# pair (hashed by closure identity) so repeated runs on the same problem skip
# retracing.  Bounded: each entry pins an XLA executable + the arrays the phi
# closures capture, and fresh phi closures never hit, so old entries must be
# evicted.
@lru_cache(maxsize=8)
def _bcd_runner(phi_val, phi_grad):
    @jax.jit
    def run(XS, masks, step_size, v0):
        def body(v, mask):
            u = jnp.einsum("mnb,mb->mn", XS, v)
            z = u.sum(axis=0)
            gphi = phi_grad(z)
            d = -step_size * jnp.einsum("mnb,n->mb", XS, gphi)
            return v + mask[:, None] * d, phi_val(z)

        vT, trace = lax.scan(body, v0, masks)
        z_final = jnp.einsum("mnb,mb->n", XS, vT)
        return vT, jnp.concatenate([trace, phi_val(z_final)[None]])

    return run


def scan_bcd(prob: LiftedProblem, masks: jax.Array, step_size,
             v0: jax.Array):
    """Encoded BCD (model parallelism) over a mask schedule.

    Trace convention matches the legacy loop: trace[t] = phi(z_t) BEFORE the
    t-th commit, with the final objective appended (length T + 1).
    """
    run = _bcd_runner(prob.phi_val, prob.phi_grad)
    return _traced_call("runner:bcd", run, prob.XS, masks,
                        jnp.asarray(step_size, prob.XS.dtype), v0)


@partial(jax.jit, static_argnames=("buffer_size", "h", "eval_every"))
def _scan_async(prob: EncodedProblem, workers: jax.Array,
                staleness: jax.Array, step_size, w0: jax.Array,
                buffer_size: int, h: str = "l2", eval_every: int = 1):
    buf0 = jnp.tile(w0[None], (buffer_size, 1))
    (w_final, _, _), trace = _strided_scan(
        lambda c, ev: _async_step(prob, c, ev, step_size, buffer_size, h),
        lambda c: original_objective(prob, c[0], h=h),
        (w0, buf0, jnp.int32(0)),
        (workers.astype(jnp.int32), staleness.astype(jnp.int32)), eval_every)
    return w_final, trace


def scan_async(prob: EncodedProblem, workers: jax.Array, staleness: jax.Array,
               step_size, w0: jax.Array, buffer_size: int, h: str = "l2",
               eval_every: int = 1):
    """Asynchronous stale-gradient SGD over a per-arrival event stream.

    workers[u]   — which worker's gradient lands at update u;
    staleness[u] — how many master updates happened since that worker read w.

    The carry holds a ring buffer of the last ``buffer_size`` iterates
    (buffer_size must exceed the engine's staleness bound); update u computes
    worker i's block gradient at the stale iterate and applies it
    immediately.  The per-worker gradient is scaled by m so it is an unbiased
    estimate of the full gradient.
    """
    return _traced_call("runner:async", _scan_async, prob, workers, staleness,
                        step_size, w0, buffer_size=buffer_size, h=h,
                        eval_every=eval_every)


# ---------------------------------------------------------------------------
# Batched-trial runners: vmap over the leading realization axis
# ---------------------------------------------------------------------------

def _step_vector(step_size, R: int):
    """Per-realization step sizes: a scalar broadcasts to all R, a (R,)
    vector (the cell-batching path — C cells x R trials stacked) passes
    through.  Scalar broadcast is value-identical to the old closed-over
    Python float (same f32 rounding in ``w - step * g``)."""
    return jnp.broadcast_to(jnp.asarray(step_size, jnp.float32), (R,))


def _batched_gd(prob: EncodedProblem, masks: jax.Array, step_size,
                w0: jax.Array, h: str = "l2", eval_every: int = 1,
                degrade=None):
    def one(masks_r, w0_r, step_r):
        if degrade is not None:
            _, k_min, shrink = degrade
            (wT, _), trace = _strided_scan(
                lambda c, mask: _hold_gd_step(prob, c, mask, step_r, h,
                                              k_min, shrink),
                lambda c: original_objective(prob, c[0], h=h),
                (w0_r, jnp.zeros_like(w0_r)), masks_r, eval_every)
            return wT, trace
        return _strided_scan(
            lambda w, mask: _gd_step(prob, w, mask, step_r, h),
            lambda w: original_objective(prob, w, h=h),
            w0_r, masks_r, eval_every)

    return jax.vmap(one)(masks, w0, _step_vector(step_size, masks.shape[0]))


@partial(jax.jit, static_argnames=("h", "eval_every", "degrade"),
         donate_argnums=(3,))
def _batched_scan_gd(prob: EncodedProblem, masks: jax.Array, step_size,
                     w0: jax.Array, h: str = "l2", eval_every: int = 1,
                     degrade=None):
    return _batched_gd(prob, masks, step_size, w0, h, eval_every, degrade)


# R == 1 wrappers: the squeeze/unsqueeze happens INSIDE one traced program
# (free at runtime) — host-side masks[0] / w[None] reshapes around _scan_gd
# would cost several extra dispatches per call, eating the win
@partial(jax.jit, static_argnames=("h", "eval_every", "degrade"),
         donate_argnums=(3,))
def _scan_gd_r1(prob: EncodedProblem, masks: jax.Array, step_size,
                w0: jax.Array, h: str = "l2", eval_every: int = 1,
                degrade=None):
    w, tr = _scan_gd(prob, masks[0], jnp.asarray(step_size).reshape(()),
                     w0[0], h=h, eval_every=eval_every, degrade=degrade)
    return w[None], tr[None]


@partial(jax.jit, static_argnames=("eval_every", "degrade"),
         donate_argnums=(3,))
def _scan_prox_r1(prob: EncodedProblem, masks: jax.Array, step_size,
                  w0: jax.Array, eval_every: int = 1, degrade=None):
    w, tr = _scan_prox(prob, masks[0], jnp.asarray(step_size).reshape(()),
                       w0[0], eval_every=eval_every, degrade=degrade)
    return w[None], tr[None]


def batched_scan_gd(prob: EncodedProblem, masks: jax.Array, step_size,
                    w0: jax.Array, h: str = "l2", eval_every: int = 1,
                    degrade=None):
    """R realizations of encoded GD in one compiled program.

    masks: (R, T, m) stacked schedules; w0: (R, p) per-realization starts
    (donated — hand a fresh stack per call).  ``step_size`` may be a scalar
    or a per-realization (R,) vector.  Returns (w (R, p),
    trace (R, T // eval_every)) with trace[r, j] = f(w after step
    (j+1)*eval_every) of realization r.

    R == 1 routes through the single-trial scan (no vmap axis): batching a
    lone realization only adds overhead (BENCH_trials.json showed 0.79x),
    and the result is identical by construction.
    """
    degrade = _degrade_tuple(degrade)
    if masks.shape[0] == 1:
        return _traced_call(_runner_name("runner:gd"), _scan_gd_r1, prob,
                            masks, step_size, w0, h=h,
                            eval_every=eval_every, degrade=degrade)
    return _traced_call(_runner_name("runner:batched_gd"), _batched_scan_gd,
                        prob, masks, step_size, w0, h=h,
                        eval_every=eval_every, degrade=degrade)


def _batched_prox(prob: EncodedProblem, masks: jax.Array, step_size,
                  w0: jax.Array, eval_every: int = 1, degrade=None):
    def one(masks_r, w0_r, step_r):
        if degrade is not None:
            _, k_min, shrink = degrade
            (wT, _), trace = _strided_scan(
                lambda c, mask: _hold_prox_step(prob, c, mask, step_r,
                                                k_min, shrink),
                lambda c: original_objective(prob, c[0], h="l1"),
                (w0_r, jnp.zeros_like(w0_r)), masks_r, eval_every)
            return wT, trace
        return _strided_scan(
            lambda w, mask: _prox_step(prob, w, mask, step_r),
            lambda w: original_objective(prob, w, h="l1"),
            w0_r, masks_r, eval_every)

    return jax.vmap(one)(masks, w0, _step_vector(step_size, masks.shape[0]))


@partial(jax.jit, static_argnames=("eval_every", "degrade"),
         donate_argnums=(3,))
def _batched_scan_prox(prob: EncodedProblem, masks: jax.Array, step_size,
                       w0: jax.Array, eval_every: int = 1, degrade=None):
    return _batched_prox(prob, masks, step_size, w0, eval_every, degrade)


def batched_scan_prox(prob: EncodedProblem, masks: jax.Array, step_size,
                      w0: jax.Array, eval_every: int = 1, degrade=None):
    """R realizations of encoded ISTA in one compiled program (see
    ``batched_scan_gd`` for the axis/donation/eval_every/R==1
    conventions)."""
    degrade = _degrade_tuple(degrade)
    if masks.shape[0] == 1:
        return _traced_call(_runner_name("runner:prox"), _scan_prox_r1,
                            prob, masks, step_size, w0,
                            eval_every=eval_every, degrade=degrade)
    return _traced_call(_runner_name("runner:batched_prox"),
                        _batched_scan_prox, prob, masks, step_size, w0,
                        eval_every=eval_every, degrade=degrade)


@lru_cache(maxsize=8)
def _bcd_batched_runner(phi_val, phi_grad):
    @partial(jax.jit, static_argnames=("eval_every",), donate_argnums=(3,))
    def run(XS, masks, step_size, v0, eval_every=1):
        def step(v, mask):
            z = jnp.einsum("mnb,mb->mn", XS, v).sum(axis=0)
            d = -step_size * jnp.einsum("mnb,n->mb", XS, phi_grad(z))
            return v + mask[:, None] * d

        def evalf(v):
            return phi_val(jnp.einsum("mnb,mb->n", XS, v))

        def one(masks_r, v0_r):
            return _strided_scan(step, evalf, v0_r, masks_r, eval_every)

        return jax.vmap(one)(masks, v0)

    return run


def batched_scan_bcd(prob: LiftedProblem, masks: jax.Array, step_size,
                     v0: jax.Array, eval_every: int = 1):
    """R realizations of encoded BCD in one compiled program.

    masks: (R, T, m); v0: (R, m, b) (donated).  Unlike ``scan_bcd``'s
    legacy pre-commit trace, the batched trace is POST-commit:
    trace[r, j] = phi(z after commit (j+1)*eval_every), i.e. with
    eval_every=1 it equals ``scan_bcd``'s trace[1:] — the slice every
    strategy reports anyway.

    R == 1 (at eval_every=1, where the trace conventions coincide) routes
    through the single-trial scan like ``batched_scan_gd``.
    """
    if masks.shape[0] == 1 and eval_every == 1:
        v, tr = scan_bcd(prob, masks[0], step_size, v0[0])
        return v[None], tr[None, 1:]
    run = _bcd_batched_runner(prob.phi_val, prob.phi_grad)
    return _traced_call("runner:batched_bcd", run, prob.XS, masks,
                        jnp.asarray(step_size, prob.XS.dtype), v0,
                        eval_every=eval_every)


def _batched_async(prob: EncodedProblem, workers: jax.Array,
                   staleness: jax.Array, step_size, w0: jax.Array,
                   buffer_size: int = 1, h: str = "l2", eval_every: int = 1):
    def one(workers_r, staleness_r, w0_r):
        buf0 = jnp.tile(w0_r[None], (buffer_size, 1))
        (w_final, _, _), trace = _strided_scan(
            lambda c, ev: _async_step(prob, c, ev, step_size, buffer_size, h),
            lambda c: original_objective(prob, c[0], h=h),
            (w0_r, buf0, jnp.int32(0)),
            (workers_r.astype(jnp.int32), staleness_r.astype(jnp.int32)),
            eval_every)
        return w_final, trace

    return jax.vmap(one)(workers, staleness, w0)


@partial(jax.jit, static_argnames=("buffer_size", "h", "eval_every"),
         donate_argnums=(4,))
def _batched_scan_async(prob: EncodedProblem, workers: jax.Array,
                        staleness: jax.Array, step_size, w0: jax.Array,
                        buffer_size: int, h: str = "l2", eval_every: int = 1):
    return _batched_async(prob, workers, staleness, step_size, w0,
                          buffer_size, h, eval_every)


def batched_scan_async(prob: EncodedProblem, workers: jax.Array,
                       staleness: jax.Array, step_size, w0: jax.Array,
                       buffer_size: int, h: str = "l2", eval_every: int = 1):
    """R realizations of async stale-gradient SGD in one compiled program.

    workers/staleness: (R, U) stacked event streams; w0: (R, p) (donated).
    Returns (w (R, p), trace (R, U // eval_every)).  R == 1 routes through
    the single-trial scan (see ``batched_scan_gd``).
    """
    if workers.shape[0] == 1:
        w, tr = _traced_call("runner:async", _scan_async, prob, workers[0],
                             staleness[0], step_size, w0[0],
                             buffer_size=buffer_size, h=h,
                             eval_every=eval_every)
        return w[None], tr[None]
    return _traced_call("runner:batched_async", _batched_scan_async, prob,
                        workers, staleness, step_size, w0,
                        buffer_size=buffer_size, h=h, eval_every=eval_every)


# ---------------------------------------------------------------------------
# Sharded-trial runners: shard_map over a 'trials' mesh axis (DESIGN.md §10)
# ---------------------------------------------------------------------------

def trials_device_count(trials: int) -> int:
    """Devices the 'trials' mesh axis can use for R realizations: every
    local device when R divides evenly across them, else 1 (= the vmap
    fallback — sharding cannot help a single device, and a ragged split
    would need padding that changes the executable shape)."""
    ndev = len(jax.devices())
    return ndev if ndev > 1 and trials % ndev == 0 else 1


@lru_cache(maxsize=16)
def _sharded_fn(kind: str, ndev: int, h: str, eval_every: int,
                buffer_size: int, degrade=None):
    """One compiled shard_map executable per (runner kind, mesh size,
    static config).  Each mesh shard runs the plain vmapped body over its
    R/ndev local realizations — realizations are independent, so there are
    no collectives and per-realization results match the vmap placement
    (bitwise in practice; the suite enforces 1e-5)."""
    mesh = Mesh(np.asarray(jax.devices()[:ndev]), ("trials",))
    P, Pt = PartitionSpec(), PartitionSpec("trials")
    if kind == "gd":
        impl = partial(_batched_gd, h=h, eval_every=eval_every,
                       degrade=degrade)
        in_specs = (P, Pt, P, Pt)
    elif kind == "prox":
        impl = partial(_batched_prox, eval_every=eval_every,
                       degrade=degrade)
        in_specs = (P, Pt, P, Pt)
    elif kind == "async":
        impl = partial(_batched_async, buffer_size=buffer_size, h=h,
                       eval_every=eval_every)
        in_specs = (P, Pt, Pt, P, Pt)
    else:
        raise KeyError(f"unknown sharded runner kind '{kind}'")
    return jax.jit(shard_map(impl, mesh=mesh, in_specs=in_specs,
                             out_specs=(Pt, Pt), check_rep=False))


def sharded_scan_gd(prob: EncodedProblem, masks: jax.Array, step_size,
                    w0: jax.Array, h: str = "l2", eval_every: int = 1,
                    degrade=None):
    """``batched_scan_gd`` with the realization axis sharded across the
    local device mesh.  Returns (w, trace, ndev); ndev == 1 means the vmap
    fallback ran (single device, or R not divisible by the device count).
    """
    degrade = _degrade_tuple(degrade)
    ndev = trials_device_count(masks.shape[0])
    if ndev == 1:
        w, tr = batched_scan_gd(prob, masks, step_size, w0, h=h,
                                eval_every=eval_every, degrade=degrade)
        return w, tr, 1
    fn = _sharded_fn("gd", ndev, h, eval_every, 0, degrade)
    w, tr = _traced_call("runner:sharded_gd", fn, prob, masks,
                         jnp.asarray(step_size, jnp.float32), w0)
    return w, tr, ndev


def sharded_scan_prox(prob: EncodedProblem, masks: jax.Array, step_size,
                      w0: jax.Array, eval_every: int = 1, degrade=None):
    """``batched_scan_prox`` sharded over the trials mesh axis (see
    ``sharded_scan_gd``)."""
    degrade = _degrade_tuple(degrade)
    ndev = trials_device_count(masks.shape[0])
    if ndev == 1:
        w, tr = batched_scan_prox(prob, masks, step_size, w0,
                                  eval_every=eval_every, degrade=degrade)
        return w, tr, 1
    fn = _sharded_fn("prox", ndev, "l1", eval_every, 0, degrade)
    w, tr = _traced_call("runner:sharded_prox", fn, prob, masks,
                         jnp.asarray(step_size, jnp.float32), w0)
    return w, tr, ndev


def sharded_scan_async(prob: EncodedProblem, workers: jax.Array,
                       staleness: jax.Array, step_size, w0: jax.Array,
                       buffer_size: int, h: str = "l2", eval_every: int = 1):
    """``batched_scan_async`` sharded over the trials mesh axis (see
    ``sharded_scan_gd``)."""
    ndev = trials_device_count(workers.shape[0])
    if ndev == 1:
        w, tr = batched_scan_async(prob, workers, staleness, step_size, w0,
                                   buffer_size, h=h, eval_every=eval_every)
        return w, tr, 1
    fn = _sharded_fn("async", ndev, h, eval_every, buffer_size)
    w, tr = _traced_call("runner:sharded_async", fn, prob, workers, staleness,
                         jnp.asarray(step_size, jnp.float32), w0)
    return w, tr, ndev
