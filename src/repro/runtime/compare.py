"""Strategy x delay-model comparison CLI (paper §5 plots) — legacy front-end.

Historically this module owned the matrix loop; it is now a thin shim that
parses its (unchanged) flags into a declarative
``repro.experiments.ExperimentSpec`` and delegates to the unified
``plan -> execute`` path (DESIGN.md §10).  Records, JSON and CSV outputs
are identical to what this harness always produced; new code should use
``python -m repro.experiments.run`` or the ``repro.experiments`` API
directly.

    PYTHONPATH=src python -m repro.runtime.compare \\
        --strategies coded-gd,uncoded,replication,async \\
        --delays bimodal,power_law,exponential

``--encoder`` accepts any registry name including the matrix-free operator
encoders ('fast-hadamard', 'block-diagonal'); ``--trials R`` adds the
Monte-Carlo axis (one compiled program per cell, DESIGN.md §9) with
``--placement`` choosing single/vmap/sharded execution; ``--workload``
swaps the synthetic quadratic for a paper-§5 workload, whose preset then
owns problem shape, objective and policy.
"""
from __future__ import annotations

import argparse
import os
from typing import Sequence

from repro.experiments import (DelayAxis, ExperimentSpec, PlacementAxis,
                               ProblemAxis, StrategyAxis, TrialsAxis,
                               execute, plan, print_table, trace_rows,
                               write_json)
from repro.experiments import write_trace_csv as write_csv  # noqa: F401

__all__ = ["run_matrix", "write_json", "write_csv", "trace_rows", "main"]


def run_matrix(strategies: Sequence[str], delays: Sequence[str], *,
               n: int = 512, p: int = 128, m: int | None = None,
               k: int | None = None,
               steps: int | None = None, lam: float = 0.05, h: str = "l2",
               encoder: str = "hadamard", policy: str = "fastest-k",
               compute_time: float = 0.05, seed: int = 0,
               staleness_bound: int | None = None,
               async_updates: int | None = None,
               deadline: float = 1.0, policy_beta: float = 2.0,
               noise: float = 0.5, workload: str | None = None,
               preset: str = "smoke", trials: int = 1,
               eval_every: int = 1, placement: str = "vmap") -> list[dict]:
    """Run the full comparison matrix; returns one record per cell.

    Legacy API shim: the kwargs are compiled into an ``ExperimentSpec``
    and executed by ``repro.experiments`` — see that package for the
    record schema (``metric_name`` / ``final_metric`` on every cell,
    skip-with-reason records, (R, T) trace stacks + mean/p50/p95 summaries
    when ``trials > 1``).
    """
    if workload is not None:
        ignored = [flag for flag, val, default in [
            ("--policy", policy, "fastest-k"), ("--h", h, "l2"),
            ("--lam", lam, 0.05), ("--n", n, 512), ("--p", p, 128),
            ("--noise", noise, 0.5), ("--deadline", deadline, 1.0),
            ("--policy-beta", policy_beta, 2.0),
            ("--staleness-bound", staleness_bound, None),
            ("--async-updates", async_updates, None)] if val != default]
        if ignored:
            print(f"# --workload: {', '.join(ignored)} ignored — the "
                  f"workload preset owns problem shape, objective and "
                  f"policy; use repro.workloads.Workload.run(**cfg) for "
                  f"fine-grained control")
        problems = (ProblemAxis.from_workload(workload, preset),)
        strategy_axes = tuple(StrategyAxis(name=s, encoder=encoder, k=k)
                              for s in strategies)
    else:
        problems = (ProblemAxis.synthetic(n, p, noise=noise, lam=lam, h=h),)
        strategy_axes = tuple(
            StrategyAxis(name=s, encoder=encoder, policy=policy, k=k,
                         deadline=deadline, policy_beta=policy_beta,
                         staleness_bound=staleness_bound,
                         async_updates=async_updates)
            for s in strategies)
    spec = ExperimentSpec(
        problems=problems, strategies=strategy_axes,
        delays=DelayAxis(delays=tuple(delays), m=m,
                         compute_time=compute_time),
        trials=TrialsAxis(trials=trials, eval_every=eval_every, seed=seed),
        placement=PlacementAxis(mode=placement), steps=steps)
    return execute(plan(spec)).records


def main(argv: Sequence[str] | None = None) -> list[dict]:
    ap = argparse.ArgumentParser(
        prog="repro.runtime.compare",
        description="strategy x delay-model wall-clock comparison harness "
                    "(legacy front-end over repro.experiments)")
    from repro.experiments.run import add_axis_flags
    add_axis_flags(ap, encoder="hadamard", policy="fastest-k")
    ap.add_argument("--workload", default=None,
                    help="run a paper-§5 workload from repro.workloads "
                         "(ridge/lasso/logistic/mf) instead of the default "
                         "synthetic quadratic; cells score the workload's "
                         "paper metric")
    ap.add_argument("--preset", default="smoke",
                    choices=["smoke", "bench", "paper"],
                    help="workload scale preset (with --workload)")
    ap.add_argument("--out", default="runs/compare")
    ap.add_argument("--formats", default="json,csv")
    args = ap.parse_args(argv)

    records = run_matrix(
        [s.strip() for s in args.strategies.split(",") if s.strip()],
        [d.strip() for d in args.delays.split(",") if d.strip()],
        n=args.n, p=args.p, m=args.m, k=args.k, steps=args.steps,
        lam=args.lam, h=args.h, encoder=args.encoder, policy=args.policy,
        compute_time=args.compute_time, seed=args.seed,
        staleness_bound=args.staleness_bound,
        async_updates=args.async_updates,
        deadline=args.deadline, policy_beta=args.policy_beta,
        noise=args.noise, workload=args.workload, preset=args.preset,
        trials=args.trials, eval_every=args.eval_every,
        placement=args.placement)

    os.makedirs(args.out, exist_ok=True)
    formats = {f.strip() for f in args.formats.split(",")}
    if "json" in formats:
        write_json(records, os.path.join(args.out, "compare.json"))
    if "csv" in formats:
        write_csv(records, os.path.join(args.out, "compare.csv"))
    print_table(records)
    print(f"wrote {sorted(formats)} to {args.out}/")
    return records


if __name__ == "__main__":
    main()
