"""Strategy x delay-model x encoder comparison harness (paper §5 plots).

Runs every requested straggler-mitigation strategy under every requested
delay distribution ON THE SAME delay realization (shared engine seed) and
emits wall-clock-vs-objective traces as JSON and CSV — the inputs for the
paper's headline comparison figures.  ``benchmarks/`` and ``examples/``
consume ``run_matrix`` / the emitted files instead of hand-rolling loops.

    PYTHONPATH=src python -m repro.runtime.compare \\
        --strategies coded-gd,uncoded,replication,async \\
        --delays bimodal,power_law,exponential

``--encoder`` accepts any registry name, including the matrix-free operator
encoders ('fast-hadamard', 'block-diagonal') — those encode without ever
materializing S, so the same matrix runs at data sizes where the dense
``(beta*n, n)`` construction cannot be allocated.
"""
from __future__ import annotations

import argparse
import csv
import json
import os
from typing import Sequence

import numpy as np

from repro.core.encoding import available_encoders

from .engine import ClusterEngine, make_delay_model, make_policy
from .strategies import ProblemSpec, RunResult, available_strategies, \
    get_strategy

__all__ = ["run_matrix", "write_json", "write_csv", "main"]


def run_matrix(strategies: Sequence[str], delays: Sequence[str], *,
               n: int = 512, p: int = 128, m: int = 16, k: int | None = None,
               steps: int = 200, lam: float = 0.05, h: str = "l2",
               encoder: str = "hadamard", policy: str = "fastest-k",
               compute_time: float = 0.05, seed: int = 0,
               staleness_bound: int | None = None,
               async_updates: int | None = None,
               deadline: float = 1.0, policy_beta: float = 2.0,
               noise: float = 0.5) -> list[dict]:
    """Run the full comparison matrix; returns one record per cell.

    A strategy incompatible with the objective (e.g. ``async`` with h='l1')
    is skipped with a warning record instead of aborting the matrix.
    """
    spec = ProblemSpec.synthetic(n, p, noise=noise, lam=lam, h=h, seed=seed)
    k = k if k is not None else max(1, (3 * m) // 4)
    records = []
    for delay_name in delays:
        engine = ClusterEngine(make_delay_model(delay_name), m,
                               compute_time=compute_time, seed=seed)
        for strat_name in strategies:
            cfg: dict = {}
            if strat_name == "async":
                if staleness_bound is not None:
                    cfg["staleness_bound"] = staleness_bound
                if async_updates is not None:
                    cfg["updates"] = async_updates
            else:
                if strat_name.startswith("coded"):
                    cfg["encoder"] = encoder
                cfg["policy"] = _make_policy(policy, m, k,
                                             deadline=deadline,
                                             beta=policy_beta)
            try:
                result: RunResult = get_strategy(strat_name).run(
                    spec, engine, steps=steps, **cfg)
            except ValueError as e:
                print(f"# skipping {strat_name} x {delay_name}: {e}")
                continue
            rec = result.to_record()
            rec.update(delay=delay_name, n=n, p=p, m=m, k=k, seed=seed)
            records.append(rec)
    return records


def _make_policy(name: str, m: int, k: int, *, deadline: float = 1.0,
                 beta: float = 2.0):
    if name in ("fastest-k", "adversarial"):
        return make_policy(name, k=k)
    if name == "adaptive-k":
        # k acts as the floor; the policy grows the set per the overlap rule
        return make_policy(name, beta=beta, k_min=k)
    if name == "deadline":
        return make_policy(name, deadline=deadline, k_min=max(1, m // 4))
    raise KeyError(f"unknown policy '{name}'")


def write_json(records: list[dict], path: str) -> None:
    with open(path, "w") as f:
        json.dump(records, f, indent=1)


def write_csv(records: list[dict], path: str) -> None:
    """Long-format trace table: one row per recorded (strategy, delay, step)."""
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["strategy", "delay", "step", "time_s", "objective"])
        for rec in records:
            for i, (t, obj) in enumerate(zip(rec["times"], rec["objective"])):
                w.writerow([rec["strategy"], rec["delay"], i,
                            f"{t:.6f}", f"{obj:.8e}"])


def main(argv: Sequence[str] | None = None) -> list[dict]:
    ap = argparse.ArgumentParser(
        prog="repro.runtime.compare",
        description="strategy x delay-model wall-clock comparison harness")
    ap.add_argument("--strategies", default="coded-gd,uncoded,replication,async",
                    help=f"comma list from {available_strategies()}")
    ap.add_argument("--delays", default="bimodal,power_law,exponential",
                    help="comma list of delay models")
    ap.add_argument("--n", type=int, default=512)
    ap.add_argument("--p", type=int, default=128)
    ap.add_argument("--m", type=int, default=16, help="workers")
    ap.add_argument("--k", type=int, default=None, help="fastest-k (default 3m/4)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lam", type=float, default=0.05)
    ap.add_argument("--h", default="l2", choices=["l2", "l1", "none"])
    ap.add_argument("--encoder", default="hadamard",
                    help=f"encoder for coded strategies, from "
                         f"{available_encoders()} (operator encoders are "
                         f"matrix-free)")
    ap.add_argument("--policy", default="fastest-k",
                    choices=["fastest-k", "adaptive-k", "deadline",
                             "adversarial"])
    ap.add_argument("--compute-time", type=float, default=0.05)
    ap.add_argument("--deadline", type=float, default=1.0,
                    help="time budget for --policy deadline (sim seconds)")
    ap.add_argument("--policy-beta", type=float, default=2.0,
                    help="overlap beta for --policy adaptive-k")
    ap.add_argument("--staleness-bound", type=int, default=None)
    ap.add_argument("--async-updates", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="runs/compare")
    ap.add_argument("--formats", default="json,csv")
    args = ap.parse_args(argv)

    records = run_matrix(
        [s.strip() for s in args.strategies.split(",") if s.strip()],
        [d.strip() for d in args.delays.split(",") if d.strip()],
        n=args.n, p=args.p, m=args.m, k=args.k, steps=args.steps,
        lam=args.lam, h=args.h, encoder=args.encoder, policy=args.policy,
        compute_time=args.compute_time, seed=args.seed,
        staleness_bound=args.staleness_bound,
        async_updates=args.async_updates,
        deadline=args.deadline, policy_beta=args.policy_beta)

    os.makedirs(args.out, exist_ok=True)
    formats = {f.strip() for f in args.formats.split(",")}
    if "json" in formats:
        write_json(records, os.path.join(args.out, "compare.json"))
    if "csv" in formats:
        write_csv(records, os.path.join(args.out, "compare.csv"))

    print(f"{'strategy':14s} {'delay':12s} {'final f':>12s} "
          f"{'wallclock_s':>12s} {'records':>8s}")
    for rec in records:
        print(f"{rec['strategy']:14s} {rec['delay']:12s} "
              f"{rec['final_objective']:12.5f} {rec['wallclock_s']:12.2f} "
              f"{len(rec['objective']):8d}")
    print(f"wrote {sorted(formats)} to {args.out}/")
    return records


if __name__ == "__main__":
    main()
