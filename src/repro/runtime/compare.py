"""Strategy x delay-model x encoder comparison harness (paper §5 plots).

Runs every requested straggler-mitigation strategy under every requested
delay distribution ON THE SAME delay realization (shared engine seed) and
emits wall-clock-vs-objective traces as JSON and CSV — the inputs for the
paper's headline comparison figures.  ``benchmarks/`` and ``examples/``
consume ``run_matrix`` / the emitted files instead of hand-rolling loops.

    PYTHONPATH=src python -m repro.runtime.compare \\
        --strategies coded-gd,uncoded,replication,async \\
        --delays bimodal,power_law,exponential

``--encoder`` accepts any registry name, including the matrix-free operator
encoders ('fast-hadamard', 'block-diagonal') — those encode without ever
materializing S, so the same matrix runs at data sizes where the dense
``(beta*n, n)`` construction cannot be allocated.

``--trials R`` adds the paper's Monte-Carlo axis: every cell runs R delay
realizations as ONE compiled program (``Strategy.run_batched``, DESIGN.md
§9) and its record carries the (R, T) trace stack plus mean/p50/p95
wall-clock and final-objective summaries.  ``--eval-every s`` strides the
objective evaluation inside the compiled loop.

``--workload`` swaps the default synthetic quadratic for a paper-§5 workload
from ``repro.workloads`` (ridge / lasso / logistic / mf): the workload owns
dataset synthesis, lowering, and its paper metric, and every cell's record
carries ``metric_name`` / ``final_metric``.  Cells whose strategy cannot run
a given workload (or objective) become skip-with-reason records instead of
silently vanishing from the matrix.
"""
from __future__ import annotations

import argparse
import csv
import json
import os
from typing import Sequence

import numpy as np

from repro.core.encoding import available_encoders

from .engine import ClusterEngine, make_delay_model, make_policy
from .strategies import ProblemSpec, RunResult, available_strategies, \
    check_trials, get_strategy

__all__ = ["run_matrix", "write_json", "write_csv", "main"]


def run_matrix(strategies: Sequence[str], delays: Sequence[str], *,
               n: int = 512, p: int = 128, m: int | None = None,
               k: int | None = None,
               steps: int | None = None, lam: float = 0.05, h: str = "l2",
               encoder: str = "hadamard", policy: str = "fastest-k",
               compute_time: float = 0.05, seed: int = 0,
               staleness_bound: int | None = None,
               async_updates: int | None = None,
               deadline: float = 1.0, policy_beta: float = 2.0,
               noise: float = 0.5, workload: str | None = None,
               preset: str = "smoke", trials: int = 1,
               eval_every: int = 1) -> list[dict]:
    """Run the full comparison matrix; returns one record per cell.

    Every record carries ``metric_name`` / ``final_metric`` (the plain
    quadratic path scores the objective itself; a ``workload`` cell scores
    its paper metric).  A strategy incompatible with the objective or
    workload becomes a skip-with-reason record instead of aborting the
    matrix — downstream tables can show WHY the cell is empty.

    ``trials=R`` runs R delay realizations per cell as ONE compiled program
    (``Strategy.run_batched``); the record then carries the (R, T) trace
    stack plus mean/p50/p95 wall-clock and final-objective summaries, and
    scalar ``final_metric`` / ``wallclock_s`` become across-trial means.
    ``eval_every=s`` records the objective every s steps (s | steps).
    """
    if workload is not None:
        ignored = [flag for flag, val, default in [
            ("--policy", policy, "fastest-k"), ("--h", h, "l2"),
            ("--lam", lam, 0.05), ("--n", n, 512), ("--p", p, 128),
            ("--noise", noise, 0.5), ("--deadline", deadline, 1.0),
            ("--policy-beta", policy_beta, 2.0),
            ("--staleness-bound", staleness_bound, None),
            ("--async-updates", async_updates, None)] if val != default]
        if ignored:
            print(f"# --workload: {', '.join(ignored)} ignored — the "
                  f"workload preset owns problem shape, objective and "
                  f"policy; use repro.workloads.Workload.run(**cfg) for "
                  f"fine-grained control")
        return _run_workload_matrix(workload, strategies, delays,
                                    preset=preset, m=m, k=k, steps=steps,
                                    encoder=encoder, seed=seed,
                                    compute_time=compute_time, trials=trials,
                                    eval_every=eval_every)
    m = 16 if m is None else m          # workload presets own m/steps when
    steps = 200 if steps is None else steps  # --workload is given
    # a bad trials/eval_every combination is a harness misconfiguration, not
    # a per-cell incompatibility — fail the matrix up front instead of
    # letting the skip-with-reason handler turn every cell into a skip
    check_trials(steps, trials, eval_every)
    spec = ProblemSpec.synthetic(n, p, noise=noise, lam=lam, h=h, seed=seed)
    k = k if k is not None else max(1, (3 * m) // 4)
    records = []
    for delay_name in delays:
        engine = ClusterEngine(make_delay_model(delay_name), m,
                               compute_time=compute_time, seed=seed)
        for strat_name in strategies:
            cfg: dict = {}
            if strat_name == "async":
                if staleness_bound is not None:
                    cfg["staleness_bound"] = staleness_bound
                if async_updates is not None:
                    cfg["updates"] = async_updates
            else:
                if strat_name.startswith("coded"):
                    cfg["encoder"] = encoder
                cfg["policy"] = _make_policy(policy, m, k,
                                             deadline=deadline,
                                             beta=policy_beta)
            base = {"strategy": strat_name, "delay": delay_name, "n": n,
                    "p": p, "m": m, "k": k, "seed": seed}
            try:
                if trials > 1:
                    result = get_strategy(strat_name).run_batched(
                        spec, engine, steps=steps, trials=trials,
                        eval_every=eval_every, **cfg)
                else:
                    result: RunResult = get_strategy(strat_name).run(
                        spec, engine, steps=steps, **cfg)
            except ValueError as e:
                print(f"# skipping {strat_name} x {delay_name}: {e}")
                records.append({**base, "skipped": str(e),
                                "metric_name": "objective"})
                continue
            rec = result.to_record()
            rec.update(base, metric_name="objective",
                       final_metric=rec["final_objective"])
            records.append(rec)
    return records


def _run_workload_matrix(workload: str, strategies: Sequence[str],
                         delays: Sequence[str], *, preset: str,
                         m: int | None, k: int | None, steps: int | None,
                         encoder: str, seed: int, compute_time: float,
                         trials: int = 1, eval_every: int = 1) -> list[dict]:
    """The ``--workload`` axis: delegate to the workloads experiment runner
    (ONE cell loop for both harnesses), constrained to a single workload."""
    from repro.workloads.runner import run_workload_matrix
    cfg: dict = {"encoder": encoder}
    if k is not None:
        cfg["k"] = k
    if steps is not None:
        cfg["steps"] = steps
    return run_workload_matrix([workload], strategies, preset=preset,
                               delays=list(delays), seed=seed, m=m,
                               compute_time=compute_time, trials=trials,
                               eval_every=eval_every, **cfg)


def _make_policy(name: str, m: int, k: int, *, deadline: float = 1.0,
                 beta: float = 2.0):
    if name in ("fastest-k", "adversarial"):
        return make_policy(name, k=k)
    if name == "adaptive-k":
        # k acts as the floor; the policy grows the set per the overlap rule
        return make_policy(name, beta=beta, k_min=k)
    if name == "deadline":
        return make_policy(name, deadline=deadline, k_min=max(1, m // 4))
    raise KeyError(f"unknown policy '{name}'")


def write_json(records: list[dict], path: str) -> None:
    with open(path, "w") as f:
        json.dump(records, f, indent=1)


def trace_rows(rec: dict):
    """Yield (trial, step, time, objective) rows from a record's traces —
    single-trial records carry flat (T,) lists (trial 0), batched records a
    (R, T) nesting."""
    times, obj = rec["times"], rec["objective"]
    if times and isinstance(times[0], (list, tuple)):
        for r, (ts, os_) in enumerate(zip(times, obj)):
            for i, (t, o) in enumerate(zip(ts, os_)):
                yield r, i, t, o
    else:
        for i, (t, o) in enumerate(zip(times, obj)):
            yield 0, i, t, o


def write_csv(records: list[dict], path: str) -> None:
    """Long-format trace table: one row per recorded (strategy, delay,
    trial, step).

    Every row repeats the cell's ``metric_name`` / ``final_metric`` so the
    CSV is self-describing; a skipped cell contributes a single row whose
    ``skipped`` column carries the reason.
    """
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["workload", "strategy", "delay", "trial", "step",
                    "time_s", "objective", "metric_name", "final_metric",
                    "skipped"])
        for rec in records:
            wl = rec.get("workload", "")
            metric_name = rec.get("metric_name", "objective")
            if "skipped" in rec:
                w.writerow([wl, rec["strategy"], rec["delay"], "", "", "",
                            "", metric_name, "", rec["skipped"]])
                continue
            final_metric = f"{rec['final_metric']:.8e}"
            for r, i, t, obj in trace_rows(rec):
                w.writerow([wl, rec["strategy"], rec["delay"], r, i,
                            f"{t:.6f}", f"{obj:.8e}", metric_name,
                            final_metric, ""])


def main(argv: Sequence[str] | None = None) -> list[dict]:
    ap = argparse.ArgumentParser(
        prog="repro.runtime.compare",
        description="strategy x delay-model wall-clock comparison harness")
    ap.add_argument("--strategies", default="coded-gd,uncoded,replication,async",
                    help=f"comma list from {available_strategies()}")
    ap.add_argument("--delays", default="bimodal,power_law,exponential",
                    help="comma list of delay models")
    ap.add_argument("--n", type=int, default=512)
    ap.add_argument("--p", type=int, default=128)
    ap.add_argument("--m", type=int, default=None,
                    help="workers (default 16; --workload presets own this)")
    ap.add_argument("--k", type=int, default=None, help="fastest-k (default 3m/4)")
    ap.add_argument("--steps", type=int, default=None,
                    help="iterations (default 200; --workload presets own "
                         "this)")
    ap.add_argument("--lam", type=float, default=0.05)
    ap.add_argument("--h", default="l2", choices=["l2", "l1", "none"])
    ap.add_argument("--encoder", default="hadamard",
                    help=f"encoder for coded strategies, from "
                         f"{available_encoders()} (operator encoders are "
                         f"matrix-free)")
    ap.add_argument("--policy", default="fastest-k",
                    choices=["fastest-k", "adaptive-k", "deadline",
                             "adversarial"])
    ap.add_argument("--compute-time", type=float, default=0.05)
    ap.add_argument("--deadline", type=float, default=1.0,
                    help="time budget for --policy deadline (sim seconds)")
    ap.add_argument("--policy-beta", type=float, default=2.0,
                    help="overlap beta for --policy adaptive-k")
    ap.add_argument("--staleness-bound", type=int, default=None)
    ap.add_argument("--async-updates", type=int, default=None)
    ap.add_argument("--workload", default=None,
                    help="run a paper-§5 workload from repro.workloads "
                         "(ridge/lasso/logistic/mf) instead of the default "
                         "synthetic quadratic; cells score the workload's "
                         "paper metric")
    ap.add_argument("--preset", default="smoke",
                    choices=["smoke", "bench", "paper"],
                    help="workload scale preset (with --workload)")
    ap.add_argument("--trials", type=int, default=1,
                    help="delay realizations per cell; > 1 runs the whole "
                         "stack as one compiled program (records carry "
                         "per-realization traces + mean/p50/p95 summaries)")
    ap.add_argument("--eval-every", type=int, default=1,
                    help="record the objective every s steps in batched "
                         "runs (s must divide the schedule length)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="runs/compare")
    ap.add_argument("--formats", default="json,csv")
    args = ap.parse_args(argv)

    records = run_matrix(
        [s.strip() for s in args.strategies.split(",") if s.strip()],
        [d.strip() for d in args.delays.split(",") if d.strip()],
        n=args.n, p=args.p, m=args.m, k=args.k, steps=args.steps,
        lam=args.lam, h=args.h, encoder=args.encoder, policy=args.policy,
        compute_time=args.compute_time, seed=args.seed,
        staleness_bound=args.staleness_bound,
        async_updates=args.async_updates,
        deadline=args.deadline, policy_beta=args.policy_beta,
        workload=args.workload, preset=args.preset, trials=args.trials,
        eval_every=args.eval_every)

    os.makedirs(args.out, exist_ok=True)
    formats = {f.strip() for f in args.formats.split(",")}
    if "json" in formats:
        write_json(records, os.path.join(args.out, "compare.json"))
    if "csv" in formats:
        write_csv(records, os.path.join(args.out, "compare.csv"))

    print(f"{'strategy':14s} {'delay':12s} {'final f':>12s} "
          f"{'metric':>22s} {'wallclock_s':>12s} {'trialsxT':>9s}")
    for rec in records:
        if "skipped" in rec:
            print(f"{rec['strategy']:14s} {rec['delay']:12s} "
                  f"{'skipped:':>12s} {rec['skipped']}")
            continue
        metric = f"{rec['metric_name']}={rec['final_metric']:.5g}"
        obj = rec["objective"]
        shape = (f"{len(obj)}x{len(obj[0])}"
                 if obj and isinstance(obj[0], (list, tuple))
                 else f"1x{len(obj)}")
        print(f"{rec['strategy']:14s} {rec['delay']:12s} "
              f"{rec['final_objective']:12.5f} {metric:>22s} "
              f"{rec['wallclock_s']:12.2f} {shape:>9s}")
    print(f"wrote {sorted(formats)} to {args.out}/")
    return records


if __name__ == "__main__":
    main()
