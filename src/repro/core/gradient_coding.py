"""Gradient codes for coded data parallelism (beyond-paper, DESIGN §4, §15).

The paper's data-parallel theory encodes (X, y) inside a quadratic loss.  For
non-quadratic losses (e.g. LM cross-entropy) the gradient is still LINEAR in
per-group loss weights, so the paper's erasure-robustness transfers to the
microbatch->worker ASSIGNMENT: worker i computes

    g_i = sum_j  B[i, j] * grad l_j(w)

for a coefficient matrix B (m workers x b microbatch groups) and the master
combines  g~ = (1/b) sum_{i in A_t} c_i(A_t) g_i  with decode weights c
(``decode_weights``) chosen so g~ reproduces — exactly or in expectation —
the full-batch mean gradient.  The mask-as-erasure convention is DESIGN §3:
``mask[i] == 0`` means worker i's result never reaches the combine.

Three code families behind one :class:`GradientCode` surface:

  * :class:`FRCode` — FRACTIONAL REPETITION (Tandon et al., arXiv
    1612.03301 §III; the block layout matching the paper's Steiner §4.2.1):
    b = m/beta disjoint clusters, replicas carry identical data.  Exact
    whenever every cluster keeps >= 1 survivor, i.e. under ANY
    (beta-1)-per-group erasure pattern — and because replicas are
    bit-identical the decoded gradient is bit-for-bit the full-batch one.
  * :class:`CyclicRepetitionCode` — Tandon's cyclic code: b = m groups,
    worker i carries groups {i, .., i+beta-1} (mod m) with the randomized
    coefficient construction of arXiv 1612.03301 Alg. 1 (rows of B span the
    all-ones vector from ANY m-(beta-1) survivors).  Exact under any
    <= beta-1 TOTAL erasures, graceful (least-squares) beyond.
  * :class:`StochasticCode` — pair-wise balanced random assignment per
    Bitar et al. (arXiv 1905.05383): worker i carries ``beta`` of the m
    groups drawn uniformly, pair-inclusion probability q = beta/m, decode
    weight 1/(|A_t| q) per survivor.  Never exact, but an UNBIASED
    estimator of the full-batch gradient over the assignment randomness
    for every fixed mask, with variance bounded by
    sum_j ||grad_j||^2 / (b^2 |A_t| q) per coordinate (property-tested).
    ``at_step(t)`` re-draws the assignment per step (the SGC convention).

``make_code(name, m, beta)`` is the registry factory ("frc" | "cyclic" |
"stochastic" | "uncoded"); ``coded_weights`` keeps the jit-safe FRC fast
path the train step and data pipeline have always used.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["GradientCode", "FRCode", "CyclicRepetitionCode",
           "StochasticCode", "GRADIENT_CODES", "make_code", "make_frc",
           "make_cyclic", "make_stochastic", "coded_weights",
           "decode_exact_possible", "assignment_matrix"]


class GradientCode:
    """Shared surface of every gradient code (DESIGN §15).

    A code is (a) an assignment of ``num_groups`` data groups to ``m``
    workers with per-slot combine coefficients, and (b) a decode rule
    mapping an erasure mask to per-worker weights.  The aggregation
    contract every consumer relies on::

        g~ = (1/num_groups) * sum_i  decode_weights(mask)[i] * g_i,
        g_i = sum_s worker_coeffs[i, s] * grad(group worker_groups[i, s])

    equals the full-batch mean gradient exactly (exact codes, above their
    erasure threshold) or in expectation (stochastic codes).
    """

    codename = "?"
    stochastic = False       # True -> re-draw the assignment per step

    # -- assignment -----------------------------------------------------

    @property
    def num_groups(self) -> int:
        raise NotImplementedError

    @property
    def worker_groups(self) -> np.ndarray:
        """(m, g) group ids worker i computes (g slots per worker)."""
        raise NotImplementedError

    @property
    def worker_coeffs(self) -> np.ndarray:
        """(m, g) combine coefficient of each slot (B[i, group])."""
        raise NotImplementedError

    # -- decode ---------------------------------------------------------

    def decode_weights(self, mask: np.ndarray) -> np.ndarray:
        """Per-worker decode weights c (m,) for one erasure mask."""
        raise NotImplementedError

    def decode_exact_possible(self, mask: np.ndarray) -> bool:
        """True iff this mask is inside the code's exact-recovery region."""
        raise NotImplementedError

    def at_step(self, t: int) -> "GradientCode":
        """The code used at step t (stochastic codes re-draw; exact codes
        are static)."""
        return self


@dataclasses.dataclass(frozen=True)
class FRCode(GradientCode):
    m: int        # workers (data-axis shards)
    beta: int     # replication degree
    clusters: np.ndarray  # (m,) cluster id of each worker

    codename = "frc"

    @property
    def num_clusters(self) -> int:
        return self.m // self.beta

    @property
    def num_groups(self) -> int:
        return self.num_clusters

    @property
    def worker_groups(self) -> np.ndarray:
        return np.asarray(self.clusters, dtype=int)[:, None]

    @property
    def worker_coeffs(self) -> np.ndarray:
        return np.ones((self.m, 1), dtype=np.float32)

    def decode_weights(self, mask: np.ndarray) -> np.ndarray:
        return np.asarray(coded_weights(self, np.asarray(mask, np.float32)))

    def decode_exact_possible(self, mask: np.ndarray) -> bool:
        return decode_exact_possible(self, mask)


def make_frc(m: int, beta: int = 2) -> FRCode:
    if m % beta:
        raise ValueError(f"m={m} not divisible by beta={beta}")
    # Interleaved assignment: replicas of a cluster are far apart in the mesh
    # (worker i -> cluster i mod b), so correlated failures of neighbouring
    # hosts do not take out both replicas.
    b = m // beta
    return FRCode(m, beta, np.arange(m) % b)


def assignment_matrix(code: GradientCode) -> np.ndarray:
    """B (m x b): combine coefficients of each (worker, group) pair.

    For the FRC this is the historical 0/1 cluster one-hot; for the cyclic
    code the Tandon coefficient matrix; for the stochastic code the 0/1
    random membership."""
    if isinstance(code, CyclicRepetitionCode):
        return np.asarray(code.B, dtype=float).copy()
    G = np.zeros((code.m, code.num_groups))
    wg, wc = code.worker_groups, code.worker_coeffs
    for i in range(code.m):
        np.add.at(G[i], wg[i], np.asarray(wc[i], dtype=float))
    return G


def decode_exact_possible(code, mask: np.ndarray) -> bool:
    """True iff every cluster has at least one active replica (FRC), or —
    for the other code families — the mask is inside their exact region."""
    if not isinstance(code, FRCode):
        return code.decode_exact_possible(mask)
    active_per_cluster = np.zeros(code.num_clusters)
    np.add.at(active_per_cluster, code.clusters, np.asarray(mask, float))
    return bool((active_per_cluster > 0).all())


def coded_weights(code, mask: jax.Array) -> jax.Array:
    """Per-worker decode weights c_i(A_t), shape (m,).

    FRC keeps the historical jit-safe closed form: c_i = mask_i / (#active
    replicas in cluster(i)); fully-erased clusters get 0 and the result is
    rescaled by  b / #surviving_clusters  so the aggregate stays an
    unbiased mean over surviving data.  Other code families dispatch to
    their (host-side) ``decode_weights``.
    """
    if not isinstance(code, FRCode):
        return jnp.asarray(code.decode_weights(np.asarray(mask)),
                           jnp.float32)
    mask = jnp.asarray(mask, jnp.float32)
    onehot = jnp.asarray(
        np.eye(code.num_clusters, dtype=np.float32)[code.clusters])  # (m, b)
    active = onehot.T @ mask                               # (b,) replicas alive
    alive = active > 0
    per_cluster = jnp.where(alive, 1.0 / jnp.maximum(active, 1.0), 0.0)
    c = mask * (onehot @ per_cluster)                      # (m,)
    surviving = jnp.maximum(jnp.sum(alive.astype(jnp.float32)), 1.0)
    return c * (code.num_clusters / surviving)


def coded_microbatch_index(code: FRCode) -> np.ndarray:
    """For worker i, the cluster (data shard) index it loads: (m,).

    The data pipeline uses this to hand replica workers identical microbatches
    (data/pipeline.py); with the assigned shapes the global batch is
    interpreted as beta x effective-batch coded slots (DESIGN §4)."""
    return code.clusters.copy()


# ---------------------------------------------------------------------------
# Cyclic repetition code (Tandon et al., arXiv 1612.03301 Alg. 1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CyclicRepetitionCode(GradientCode):
    """b = m groups; worker i carries groups {i, .., i+beta-1} (mod m) with
    randomized coefficients B such that any m-(beta-1) rows of B span the
    all-ones row — so the master can solve  c^T B_A = 1^T  exactly under
    any <= beta-1 TOTAL erasures.  Note the contrast with the FRC: the
    cyclic support overlap buys a denser layout (b == m groups) at a
    STRICTER threshold (total, not per-group, erasures); naive 0/1 cyclic
    coefficients are NOT exactly decodable, hence the solved B."""
    m: int
    beta: int
    B: np.ndarray          # (m, m) Tandon coefficient matrix
    supports: np.ndarray   # (m, beta) group ids of worker i (cyclic window)

    codename = "cyclic"

    @property
    def num_groups(self) -> int:
        return self.m

    @property
    def worker_groups(self) -> np.ndarray:
        return np.asarray(self.supports, dtype=int)

    @property
    def worker_coeffs(self) -> np.ndarray:
        return np.take_along_axis(
            np.asarray(self.B, np.float32), self.worker_groups, axis=1)

    def decode_weights(self, mask: np.ndarray) -> np.ndarray:
        mask = np.asarray(mask, float).ravel()
        active = np.nonzero(mask > 0)[0]
        c = np.zeros(self.m, dtype=np.float32)
        if active.size == 0:
            return c
        # min ||B_A^T a - 1||: exact (residual ~0) whenever |erased| <=
        # beta-1 by the spanning property; the least-squares projection
        # degrades gracefully beyond.
        a, *_ = np.linalg.lstsq(np.asarray(self.B, float)[active].T,
                                np.ones(self.m), rcond=None)
        c[active] = a.astype(np.float32)
        return c

    def decode_exact_possible(self, mask: np.ndarray) -> bool:
        mask = np.asarray(mask, float).ravel()
        return bool((mask > 0).sum() >= self.m - (self.beta - 1))


def make_cyclic(m: int, beta: int = 2, seed: int = 0,
                _tries: int = 8) -> CyclicRepetitionCode:
    """Tandon's randomized construction: H (s x m) random normal with zero
    row sums (so 1 is in its null space), row i of B supported on the
    cyclic window {i, .., i+s} with the head coefficient pinned to 1 and
    the tail solving  H[:, tail] x = -H[:, head]  — making every row of B
    orthogonal to H, hence any m-s rows of B a basis of null(H) ∋ 1."""
    if not 1 <= beta <= m:
        raise ValueError(f"beta={beta} must be in [1, m={m}]")
    s = beta - 1
    supports = (np.arange(m)[:, None] + np.arange(s + 1)[None, :]) % m
    if s == 0:
        return CyclicRepetitionCode(m, beta, np.eye(m), supports)
    for attempt in range(_tries):
        rng = np.random.default_rng([seed, attempt, m, beta, 0xC7C11C])
        H = rng.standard_normal((s, m))
        H[:, -1] = -H[:, :-1].sum(axis=1)
        B = np.zeros((m, m))
        try:
            for i in range(m):
                head, tail = supports[i, 0], supports[i, 1:]
                B[i, head] = 1.0
                B[i, tail] = -np.linalg.solve(H[:, tail], H[:, head])
        except np.linalg.LinAlgError:   # singular window: re-draw H
            continue
        if np.isfinite(B).all():
            return CyclicRepetitionCode(m, beta, B, supports)
    raise RuntimeError(f"cyclic code construction failed for m={m}, "
                       f"beta={beta} after {_tries} draws")


# ---------------------------------------------------------------------------
# Stochastic (pair-wise balanced) code (Bitar et al., arXiv 1905.05383)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StochasticCode(GradientCode):
    """b = m groups; worker i carries ``beta`` groups drawn uniformly
    without replacement (pair-inclusion probability q = beta/m, the
    pair-wise balanced flavor of Bitar et al.).  Decode needs NO solve:
    every survivor is weighted  1/(|A_t| q), so for any FIXED mask

        E_code[ g~ ]  =  (1/b) sum_j E[#active holders of j]/(|A| q) grad_j
                      =  mean_j grad_j

    exactly — unbiased whatever the (even adversarial) erasure pattern,
    because the mask cannot depend on the fresh per-step assignment.
    Per-coordinate variance is bounded by sum_j grad_j^2 / (b^2 |A| q)
    (holders are Bernoulli(q) independent across workers, negatively
    correlated across groups)."""
    m: int
    beta: int
    groups: np.ndarray     # (m, beta) group ids of worker i
    seed: int = 0

    codename = "stochastic"
    stochastic = True

    @property
    def num_groups(self) -> int:
        return self.m

    @property
    def worker_groups(self) -> np.ndarray:
        return np.asarray(self.groups, dtype=int)

    @property
    def worker_coeffs(self) -> np.ndarray:
        return np.ones((self.m, self.beta), dtype=np.float32)

    def decode_weights(self, mask: np.ndarray) -> np.ndarray:
        mask = np.asarray(mask, np.float32).ravel()
        n_act = float((mask > 0).sum())
        if n_act == 0:
            return np.zeros(self.m, dtype=np.float32)
        q = self.beta / self.m
        return (mask / (n_act * q)).astype(np.float32)

    def decode_exact_possible(self, mask: np.ndarray) -> bool:
        return False          # approximate by design (unbiased, not exact)

    def at_step(self, t: int) -> "StochasticCode":
        return make_stochastic(self.m, self.beta, seed=self.seed, step=t)


def make_stochastic(m: int, beta: int = 2, seed: int = 0,
                    step: int = 0) -> StochasticCode:
    if not 1 <= beta <= m:
        raise ValueError(f"beta={beta} must be in [1, m={m}]")
    rng = np.random.default_rng([seed, step, m, beta, 0x5C0DE])
    groups = np.stack([rng.choice(m, size=beta, replace=False)
                       for _ in range(m)])
    return StochasticCode(m, beta, groups, seed=seed)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

class _UncodedCode(FRCode):
    """Identity assignment (beta=1 FRC) under its own codename, so records
    and bench rows report the baseline as 'uncoded', not 'frc'."""
    codename = "uncoded"


def _make_uncoded(m: int, beta: int = 1, seed: int = 0) -> FRCode:
    base = make_frc(m, 1)     # identity assignment, no redundancy
    return _UncodedCode(m=base.m, beta=base.beta, clusters=base.clusters)


GRADIENT_CODES = {
    "frc": lambda m, beta=2, seed=0: make_frc(m, beta),
    "cyclic": lambda m, beta=2, seed=0: make_cyclic(m, beta, seed=seed),
    "stochastic": lambda m, beta=2, seed=0: make_stochastic(m, beta,
                                                            seed=seed),
    "bernoulli": lambda m, beta=2, seed=0: make_stochastic(m, beta,
                                                           seed=seed),
    "uncoded": _make_uncoded,
}


def make_code(name, m: int, beta: int = 2, seed: int = 0) -> GradientCode:
    """Build a gradient code by registry name; passes GradientCode
    instances through unchanged."""
    if isinstance(name, GradientCode):
        return name
    key = str(name).strip().lower()
    if key not in GRADIENT_CODES:
        raise KeyError(f"unknown gradient code '{name}'; have "
                       f"{sorted(GRADIENT_CODES)}")
    return GRADIENT_CODES[key](m, beta=beta, seed=seed)
