"""Coded data parallelism for GENERAL losses (beyond-paper extension, DESIGN §4).

The paper's data-parallel theory encodes (X, y) inside a quadratic loss.  For
non-quadratic losses (e.g. LM cross-entropy) the gradient is still LINEAR in
per-sample loss weights, so the paper's erasure-robustness transfers to the
microbatch->worker ASSIGNMENT: worker i computes

    g_i = sum_j  G[i, j] * grad l_j(w)

for an assignment matrix G (m workers x b microbatch groups) and the master
combines  g~ = sum_{i in A_t} c_i(A_t) g_i  with decode weights c.

We implement the FRACTIONAL REPETITION code (FRC) — the block-structured
special case matching the paper's Steiner layout (§4.2.1, each data block
served by beta workers): workers are grouped into b = m / beta clusters that
share a cluster-worth of data.  Decode: each cluster's contribution is the
mean of its ACTIVE replicas.  Properties (property-tested):

  * exact full-batch gradient whenever every cluster has >= 1 active worker
    (i.e. tolerates any beta-1 erasures per cluster, adversarially);
  * graceful degradation otherwise: the aggregate equals the full gradient
    restricted to surviving clusters, rescaled — never corrupted.

`coded_weights` produces per-WORKER scalar weights that multiply each worker's
mean-loss contribution; the trainer folds them into a masked psum over the
``data`` mesh axis (train/steps.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FRCode", "make_frc", "coded_weights", "decode_exact_possible",
           "assignment_matrix"]


@dataclasses.dataclass(frozen=True)
class FRCode:
    m: int        # workers (data-axis shards)
    beta: int     # replication degree
    clusters: np.ndarray  # (m,) cluster id of each worker

    @property
    def num_clusters(self) -> int:
        return self.m // self.beta


def make_frc(m: int, beta: int = 2) -> FRCode:
    if m % beta:
        raise ValueError(f"m={m} not divisible by beta={beta}")
    # Interleaved assignment: replicas of a cluster are far apart in the mesh
    # (worker i -> cluster i mod b), so correlated failures of neighbouring
    # hosts do not take out both replicas.
    b = m // beta
    return FRCode(m, beta, np.arange(m) % b)


def assignment_matrix(code: FRCode) -> np.ndarray:
    """G (m x b): worker i computes the mean gradient of its cluster's data."""
    G = np.zeros((code.m, code.num_clusters))
    G[np.arange(code.m), code.clusters] = 1.0
    return G


def decode_exact_possible(code: FRCode, mask: np.ndarray) -> bool:
    """True iff every cluster has at least one active replica."""
    active_per_cluster = np.zeros(code.num_clusters)
    np.add.at(active_per_cluster, code.clusters, np.asarray(mask, float))
    return bool((active_per_cluster > 0).all())


def coded_weights(code: FRCode, mask: jax.Array) -> jax.Array:
    """Per-worker decode weights c_i(A_t), shape (m,), jit-safe.

    c_i = mask_i / (#active replicas in cluster(i)); fully-erased clusters get
    0 and the result is rescaled by  b / #surviving_clusters  so the aggregate
    stays an unbiased mean over surviving data.
    """
    mask = jnp.asarray(mask, jnp.float32)
    onehot = jnp.asarray(
        np.eye(code.num_clusters, dtype=np.float32)[code.clusters])  # (m, b)
    active = onehot.T @ mask                               # (b,) replicas alive
    alive = active > 0
    per_cluster = jnp.where(alive, 1.0 / jnp.maximum(active, 1.0), 0.0)
    c = mask * (onehot @ per_cluster)                      # (m,)
    surviving = jnp.maximum(jnp.sum(alive.astype(jnp.float32)), 1.0)
    return c * (code.num_clusters / surviving)


def coded_microbatch_index(code: FRCode) -> np.ndarray:
    """For worker i, the cluster (data shard) index it loads: (m,).

    The data pipeline uses this to hand replica workers identical microbatches
    (data/pipeline.py); with the assigned shapes the global batch is
    interpreted as beta x effective-batch coded slots (DESIGN §4)."""
    return code.clusters.copy()
