"""Matrix-free encoders: fast Hadamard and block-diagonal ETF (paper §4.2.2).

These implement the ``LinearEncoder`` protocol without ever forming the
``(beta*n, n)`` matrix — the paper's "efficient mechanisms for encoding
large-scale data":

* ``FastHadamardEncoder`` — the randomized (subsampled) Hadamard ensemble
  S = H_N[:, cols] diag(signs) / sqrt(n).  Encode is one fused Pallas pass
  (sign-flip + FWHT + row gather, ``kernels/encode.py``): O(N log N) per
  data column instead of O(N n).  Same column/sign sampling as the dense
  ``hadamard_encoder``, so ``materialize()`` reproduces it exactly.
* ``BlockDiagonalEncoder`` — a small base ETF S_b of size (r_b, n_b) tiled
  block-diagonally, S = I_B (x) S_b.  Each diagonal tile touches one input
  shard of n_b coordinates, so workers encode their own shards
  independently (``input_slice``) and data larger than host memory streams
  through worker-by-worker.  S^T S = I_B (x) S_b^T S_b = beta I, and any
  row subset's Gram is block-diagonal in the tiles, so the composition
  preserves Block-RIP up to the base frame's epsilon for erasure patterns
  that hit every tile proportionally (see DESIGN §7 for the caveat when a
  tile loses all its rows).

Both register with the encoder registry ('fast-hadamard',
'block-diagonal') so strategies, the compare CLI, and benchmarks select
them by name.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from .encoding import (LinearEncoder, hadamard_ensemble, hadamard_matrix,
                       make_encoder, register_encoder)

__all__ = ["FastHadamardEncoder", "BlockDiagonalEncoder"]


def _hadamard_row(i: int, m: int) -> np.ndarray:
    """Row i of the order-m Sylvester Hadamard matrix: H[i, j] =
    (-1)^popcount(i & j).  O(m) — never forms H."""
    return np.array([1.0 - 2.0 * (bin(i & j).count("1") & 1)
                     for j in range(m)])


class FastHadamardEncoder(LinearEncoder):
    """SRHT-style randomized Hadamard encoder, computed by FWHT.

    Identical ensemble to ``hadamard_encoder`` (same rng draws for the
    column subset and signs), but the matrix is implicit: ``encode`` runs
    the fused Pallas kernel, ``decode_t`` uses H^T = H, and aligned
    ``worker_block`` calls use the Kronecker split
    H_N = H_m (x) H_r  (N = m * r, all powers of two): worker i's block is
    FWHT_r over a signed sum of the m input chunks — O(N + r log r) per
    column, embarrassingly parallel across workers.
    """

    name = "fast-hadamard"
    tight = True

    def __init__(self, n: int, beta: float = 2.0, seed: int = 0):
        self._n = int(n)
        self.N, self.cols, self.signs = hadamard_ensemble(n, beta, seed)
        self.beta = self.N / n
        self.seed = seed

    @property
    def n(self) -> int:
        return self._n

    @property
    def rows(self) -> int:
        return self.N + self._pad

    # -- helpers ------------------------------------------------------------
    def _scatter_signed(self, X2) -> jnp.ndarray:
        """(N, q) transform input: sign-flipped data at its padded slots."""
        X2 = jnp.asarray(X2, jnp.float32)
        out = jnp.zeros((self.N, X2.shape[1]), jnp.float32)
        return out.at[jnp.asarray(self.cols)].set(
            X2 * jnp.asarray(self.signs, jnp.float32)[:, None])

    def _append_pad(self, out2):
        if self._pad:
            out2 = jnp.concatenate(
                [out2, jnp.zeros((self._pad, out2.shape[1]), out2.dtype)])
        return out2

    # -- LinearEncoder protocol ---------------------------------------------
    def encode(self, X):
        from repro.kernels.ops import srht_encode
        X2, squeeze = self._as_2d(X)
        out = srht_encode(jnp.asarray(X2, jnp.float32), self.cols,
                          self.signs, self.N)
        out = self._append_pad(out)
        return out[:, 0] if squeeze else out

    def decode_t(self, G):
        from repro.kernels.ops import fwht
        G2, squeeze = self._as_2d(G)
        G2 = jnp.asarray(G2, jnp.float32)[:self.N]   # pad rows of S are zero
        HG = fwht(G2, axis=0)
        out = (HG[jnp.asarray(self.cols)] *
               jnp.asarray(self.signs, jnp.float32)[:, None] /
               math.sqrt(self.n))
        return out[:, 0] if squeeze else out

    def worker_block_local(self, i: int, X_local):
        from repro.kernels.ops import fwht, srht_encode
        m = self._require_workers()
        X2, squeeze = self._as_2d(X_local)
        lo, hi = self.worker_rows(i)
        live_hi = min(hi, self.N)                     # rows >= N are padding
        if lo >= self.N:
            out = jnp.zeros((hi - lo, X2.shape[1]), jnp.float32)
            return out[:, 0] if squeeze else out
        if self._pad == 0 and (m & (m - 1)) == 0 and m <= self.N:
            # Kronecker split: rows [i*r, (i+1)*r) of H_N x equal
            # H_r @ sum_j H_m[i, j] x_chunk_j  for x reshaped (m, r, q).
            r = self.N // m
            chunks = self._scatter_signed(X2).reshape(m, r, X2.shape[1])
            hrow = jnp.asarray(_hadamard_row(i, m), jnp.float32)
            combined = jnp.tensordot(hrow, chunks, axes=1)   # (r, q)
            out = fwht(combined, axis=0) / math.sqrt(self.n)
        else:
            out = srht_encode(jnp.asarray(X2, jnp.float32), self.cols,
                              self.signs, self.N, lo=lo, hi=live_hi)
            if hi > live_hi:
                out = jnp.concatenate(
                    [out, jnp.zeros((hi - live_hi, out.shape[1]), out.dtype)])
        return out[:, 0] if squeeze else out

    def encode_partitioned(self, X) -> list:
        """One fused full transform, sliced into worker blocks.

        Every worker's rows come out of the same FWHT, so the bulk build
        costs one O(N log N) pass instead of m per-block transforms (the
        misaligned ``worker_block`` fallback would redo the full butterfly
        per worker, with a fresh jit specialization per row window).
        ``worker_block`` stays the entry point for streaming / distributed
        per-worker encode, where blocks are NOT built on one host.
        """
        m = self._require_workers()
        out = self.encode(X)                 # pad rows already appended
        r = self.rows_per_worker
        return [out[i * r:(i + 1) * r] for i in range(m)]

    def materialize(self) -> np.ndarray:
        S = (hadamard_matrix(self.N)[:, self.cols] * self.signs[None, :]
             / math.sqrt(self.n))
        if self._pad:
            S = np.concatenate([S, np.zeros((self._pad, self.n))], axis=0)
        return S


class BlockDiagonalEncoder(LinearEncoder):
    """Block-diagonal composition of a small base frame: S = I_B (x) S_b.

    ``block_size`` picks the base dimension n_b (must divide n; default the
    largest power-of-two divisor capped at 64); ``base`` names any dense
    construction in the registry.  Worker i's rows depend only on the input
    shards of the tiles it overlaps (``input_slice``), which is what makes
    streaming encode of out-of-core data possible.
    """

    name = "block-diagonal"

    def __init__(self, n: int, beta: float = 2.0, seed: int = 0, *,
                 base: str = "hadamard", block_size: int | None = None):
        nb = block_size or self._default_block(n)
        if n % nb:
            raise ValueError(f"block_size {nb} does not divide n={n}")
        self.base = make_encoder(base, nb, beta=beta, seed=seed)
        if not isinstance(self.base.S, np.ndarray):  # pragma: no cover
            raise TypeError("base encoder must be dense")
        self._n = int(n)
        self.B = n // nb
        self.beta = self.base.beta
        self.tight = self.base.tight
        self.seed = seed

    @staticmethod
    def _default_block(n: int) -> int:
        for cand in (64, 32, 16, 8, 4, 2):
            if n % cand == 0:
                return cand
        return n  # odd n: degenerate single tile

    @property
    def n(self) -> int:
        return self._n

    @property
    def base_rows(self) -> int:
        return self.base.rows

    @property
    def rows(self) -> int:
        return self.B * self.base.rows + self._pad

    # -- LinearEncoder protocol ---------------------------------------------
    def _tile_encode(self, X2, Sb) -> np.ndarray:
        """Apply one (rb, nb) map per tile of X2 ((B', nb, q) flattened)."""
        nb, q = Sb.shape[1], X2.shape[1]
        shards = np.asarray(X2).reshape(-1, nb, q)
        return np.einsum("rk,bkq->brq", Sb, shards).reshape(-1, q)

    def encode(self, X):
        X2, squeeze = self._as_2d(X)
        out = self._tile_encode(X2, self.base.S)
        if self._pad:
            out = np.concatenate(
                [out, np.zeros((self._pad, out.shape[1]), out.dtype)])
        return out[:, 0] if squeeze else out

    def decode_t(self, G):
        G2, squeeze = self._as_2d(G)
        G2 = np.asarray(G2)[:self.B * self.base.rows]
        rb, q = self.base.rows, G2.shape[1]
        tiles = G2.reshape(self.B, rb, q)
        out = np.einsum("rk,brq->bkq", self.base.S, tiles).reshape(-1, q)
        return out[:, 0] if squeeze else out

    def _tile_range(self, i: int) -> tuple[int, int, int, int]:
        """(lo, hi, j0, j1): worker row window and overlapped tile range."""
        lo, hi = self.worker_rows(i)
        rb, live = self.base.rows, self.B * self.base.rows
        j0 = min(lo // rb, self.B)
        j1 = min(-(-min(hi, live) // rb), self.B)
        return lo, hi, j0, j1

    def input_slice(self, i: int) -> slice:
        _, _, j0, j1 = self._tile_range(i)
        nb = self.base.n
        return slice(j0 * nb, j1 * nb)

    def worker_block_local(self, i: int, X_local):
        X2, squeeze = self._as_2d(X_local)
        lo, hi, j0, j1 = self._tile_range(i)
        rb = self.base.rows
        if j1 <= j0:                                  # pure padding rows
            out = np.zeros((hi - lo, X2.shape[1]))
        else:
            enc = self._tile_encode(X2, self.base.S)  # tiles j0..j1
            out = enc[lo - j0 * rb: hi - j0 * rb]
            if out.shape[0] < hi - lo:                # trailing pad rows
                out = np.concatenate(
                    [out, np.zeros((hi - lo - out.shape[0], out.shape[1]))])
        return out[:, 0] if squeeze else out

    def materialize(self) -> np.ndarray:
        S = np.kron(np.eye(self.B), self.base.S)
        if self._pad:
            S = np.concatenate([S, np.zeros((self._pad, self.n))], axis=0)
        return S


register_encoder(
    "fast-hadamard",
    lambda n, beta=2.0, seed=0, **kw: FastHadamardEncoder(n, beta=beta,
                                                          seed=seed))
register_encoder(
    "block-diagonal",
    lambda n, beta=2.0, seed=0, **kw: BlockDiagonalEncoder(n, beta=beta,
                                                           seed=seed, **kw))
