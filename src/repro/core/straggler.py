"""Straggler / delay models and active-set sampling (paper §5).

The paper's master waits for the fastest ``k`` of ``m`` workers per iteration.
On a bulk-synchronous TPU mesh we realize the same erasure semantics with a
per-step mask (see DESIGN.md §3).  This module provides:

  * the paper's delay distributions (bimodal Gaussian mixture §5.3,
    power-law background tasks §5.3, exponential §5.2, multimodal §5.4),
  * fastest-k active-set sampling and adversarial set sequences,
  * simulated wall-clock accounting (k-th order statistic per iteration),

all host-side numpy — masks are fed into jitted steps as inputs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator

import numpy as np

__all__ = [
    "DelayModel", "bimodal_delays", "power_law_delays", "exponential_delays",
    "multimodal_delays", "constant_delays", "fastest_k", "active_mask",
    "adversarial_sets", "WallClock", "simulate_run",
]

DelayModel = Callable[[np.random.Generator, int], np.ndarray]


def bimodal_delays(q: float = 0.5, mu1: float = 0.5, sig1: float = 0.2,
                   mu2: float = 20.0, sig2: float = 5.0) -> DelayModel:
    """Gaussian mixture delay (paper §5.3 logistic regression, model 1)."""
    def sample(rng: np.random.Generator, m: int) -> np.ndarray:
        slow = rng.random(m) > q
        d = rng.normal(mu1, sig1, size=m)
        d[slow] = rng.normal(mu2, sig2, size=slow.sum())
        return np.maximum(d, 0.0)
    return sample


def power_law_delays(alpha: float = 1.5, cap: int = 50,
                     per_task: float = 0.35) -> DelayModel:
    """#background tasks ~ power law (cap 50), delay ∝ tasks (paper §5.3 model 2)."""
    def sample(rng: np.random.Generator, m: int) -> np.ndarray:
        tasks = np.minimum(rng.pareto(alpha, size=m) + 1.0, cap)
        return per_task * tasks
    return sample


def exponential_delays(scale: float = 0.010) -> DelayModel:
    """exp(10ms) communication latency (paper §5.2 matrix factorization)."""
    def sample(rng: np.random.Generator, m: int) -> np.ndarray:
        return rng.exponential(scale, size=m)
    return sample


def multimodal_delays() -> DelayModel:
    """Three-component mixture used for LASSO (paper §5.4)."""
    qs = np.array([0.8, 0.1, 0.1])
    mus = np.array([0.2, 0.6, 1.0])
    sigs = np.array([0.1, 0.2, 0.4])
    def sample(rng: np.random.Generator, m: int) -> np.ndarray:
        comp = rng.choice(3, size=m, p=qs)
        return np.maximum(rng.normal(mus[comp], sigs[comp]), 0.0)
    return sample


def constant_delays(value: float = 1.0) -> DelayModel:
    def sample(rng: np.random.Generator, m: int) -> np.ndarray:
        return np.full(m, value)
    return sample


def fastest_k(delays: np.ndarray, k: int) -> np.ndarray:
    """Indices of the k smallest delays (the active set A_t).

    ``k`` is clamped into [0, m]: k <= 0 selects nobody (the empty active
    set the fault-degradation paths must survive) and k >= m selects
    everyone — both without tripping ``argpartition``'s bounds."""
    m = delays.shape[0]
    if k <= 0:
        return np.zeros(0, dtype=np.intp)
    if k >= m:
        return np.arange(m)
    return np.argpartition(delays, k - 1)[:k]


def active_mask(m: int, active: np.ndarray) -> np.ndarray:
    mask = np.zeros(m, dtype=np.float32)
    mask[np.asarray(active)] = 1.0
    return mask


def adversarial_sets(m: int, k: int, steps: int) -> Iterator[np.ndarray]:
    """Deterministic worst-case rotation: the erased set sweeps all workers.

    Exercises the paper's 'arbitrary / adversarial {A_t}' guarantee — every
    worker is repeatedly erased, with maximal churn between iterations.
    """
    drop = m - k
    for t in range(steps):
        start = (t * drop) % m
        erased = (start + np.arange(drop)) % m
        keep = np.setdiff1d(np.arange(m), erased)
        yield keep


def adaptive_k(delays: np.ndarray, prev_active: np.ndarray | None,
               beta: float, k_min: int) -> np.ndarray:
    """Paper §3.3: the smallest fastest-k whose overlap with A_{t-1} exceeds
    m/beta — guarantees the L-BFGS overlap matrix S̆_t is full rank (eq. 7).

    Returns the active set (sorted worker indices).
    """
    m = delays.shape[0]
    order = np.argsort(delays)
    need = int(np.floor(m / beta)) + 1
    if prev_active is None:
        # first iteration: make the overlap condition satisfiable next step
        return np.sort(order[:max(k_min, need)])
    prev = set(np.asarray(prev_active).tolist())
    overlap = 0
    for k, w in enumerate(order, start=1):
        if int(w) in prev:
            overlap += 1
        if k >= k_min and overlap >= need:
            return np.sort(order[:k])
    return np.sort(order)  # worst case: wait for everyone


@dataclasses.dataclass
class WallClock:
    """Simulated wall-clock: each iteration costs the k-th order statistic of
    per-worker (delay + compute) plus a master overhead."""
    compute_time: float = 0.05
    master_overhead: float = 0.01
    elapsed: float = 0.0

    def tick(self, delays: np.ndarray, k: int) -> float:
        total = np.sort(delays + self.compute_time)[k - 1] + self.master_overhead
        self.elapsed += float(total)
        return self.elapsed


def simulate_run(model: DelayModel, m: int, k: int, steps: int, seed: int = 0,
                 compute_time: float = 0.05):
    """Yield (t, active_set, elapsed_seconds) for a straggler realization."""
    rng = np.random.default_rng(seed)
    clock = WallClock(compute_time=compute_time)
    for t in range(steps):
        d = model(rng, m)
        A = fastest_k(d, k)
        yield t, np.sort(A), clock.tick(d, k)
