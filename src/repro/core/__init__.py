"""Core library: the paper's encoded distributed optimization framework.

Encoding matrices (ETF/Hadamard/Haar/Gaussian), straggler delay models,
and the four encoded algorithms (GD, L-BFGS, proximal gradient, BCD) with
fastest-k erasure semantics.
"""
from .encoding import (LinearEncoder, Encoder, DenseEncoder, as_dense,
                       make_encoder, register_encoder, available_encoders,
                       gaussian_encoder, hadamard_encoder, haar_encoder,
                       paley_etf_encoder, steiner_etf_encoder,
                       replication_encoder, identity_encoder, partition_rows,
                       pad_rows, brip_constant, subset_spectrum,
                       hadamard_matrix)
from .operators import FastHadamardEncoder, BlockDiagonalEncoder
from .straggler import (bimodal_delays, power_law_delays, exponential_delays,
                        multimodal_delays, constant_delays, fastest_k,
                        active_mask, adversarial_sets, simulate_run, WallClock,
                        adaptive_k)
from .data_parallel import (EncodedProblem, make_encoded_problem,
                            encoded_gradients, masked_gradient, gd_step,
                            run_encoded_gd, prox_l1, run_encoded_proximal,
                            original_objective)
from .lbfgs import LBFGSState, lbfgs_direction, run_encoded_lbfgs
from .model_parallel import (LiftedProblem, make_lifted_problem, phi_quadratic,
                             phi_logistic, run_encoded_bcd)
from .gradient_coding import (GradientCode, FRCode, CyclicRepetitionCode,
                              StochasticCode, GRADIENT_CODES, make_code,
                              make_frc, make_cyclic, make_stochastic,
                              coded_weights, decode_exact_possible,
                              assignment_matrix)
