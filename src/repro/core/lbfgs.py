"""Encoded limited-memory BFGS (paper §2.1 'Limited-memory-BFGS', Thm 4).

Key paper-specific ingredients, all implemented:
  * gradient differences r_t are computed ONLY from workers in the overlap
    A_t ∩ A_{t-1} (rescaled by m / |A_t ∩ A_{t-1}|)  — required for Lemma 3;
  * the descent direction uses the fastest-k aggregated gradient g~_t;
  * the step size comes from EXACT LINE SEARCH over a second fastest-k set
    D_t:  alpha = -rho * (d^T g~) / (d^T X~_D^T X~_D d), 0 < rho < 1 (eq. 3);
  * inverse-Hessian estimate via the standard (u_j, r_j) two-loop recursion
    with initial scaling u^T r / r^T r.  (The paper writes B_t^(0) =
    (r^T r / r^T u) I, which is the Hessian rather than inverse-Hessian
    scale — we use the standard Nocedal inverse scaling.)

Regularizer is h(w) = ||w||^2 (ridge), as the paper assumes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .data_parallel import (EncodedProblem, encoded_gradients, _masked_mean,
                            original_objective)

__all__ = ["LBFGSState", "lbfgs_direction", "run_encoded_lbfgs"]


@dataclasses.dataclass
class LBFGSState:
    u: list  # iterate differences  w_t - w_{t-1}
    r: list  # overlap-set gradient differences
    memory: int

    def push(self, u: jax.Array, r: jax.Array) -> None:
        # Curvature safeguard (standard): skip pairs with tiny u^T r.
        if float(jnp.vdot(u, r)) > 1e-10 * float(jnp.vdot(u, u) + 1e-30):
            self.u.append(u)
            self.r.append(r)
            if len(self.u) > self.memory:
                self.u.pop(0)
                self.r.pop(0)


def lbfgs_direction(state: LBFGSState, grad: jax.Array) -> jax.Array:
    """Two-loop recursion: d = -B_t g~_t."""
    q = grad
    alphas = []
    for u, r in zip(reversed(state.u), reversed(state.r)):
        rho = 1.0 / jnp.vdot(r, u)
        a = rho * jnp.vdot(u, q)
        alphas.append((a, rho, u, r))
        q = q - a * r
    if state.u:
        u0, r0 = state.u[-1], state.r[-1]
        q = q * (jnp.vdot(u0, r0) / jnp.vdot(r0, r0))
    for a, rho, u, r in reversed(alphas):
        b = rho * jnp.vdot(r, q)
        q = q + (a - b) * u
    return -q


def _full_gradient(prob: EncodedProblem, w: jax.Array, mask: jax.Array,
                   lam: float) -> jax.Array:
    return _masked_mean(encoded_gradients(prob, w), mask) + lam * w


def run_encoded_lbfgs(prob: EncodedProblem, masks_A: np.ndarray,
                      masks_D: np.ndarray | None = None, memory: int = 10,
                      rho: float = 0.9, w0: jax.Array | None = None):
    """Run encoded L-BFGS over mask schedules.

    masks_A: (T, m) 0/1 — gradient active sets A_t.
    masks_D: (T, m) 0/1 — line-search active sets D_t (defaults to A_t).

    Returns (w_T, f-trace on the original ridge objective).
    """
    if masks_D is None:
        masks_D = masks_A
    T, m = masks_A.shape
    p = prob.SX.shape[-1]
    w = jnp.zeros(p) if w0 is None else w0
    lam = prob.lam
    state = LBFGSState([], [], memory)
    prev_w, prev_mask = None, None
    trace = []

    grad_blocks = jax.jit(encoded_gradients)

    for t in range(T):
        mask = jnp.asarray(masks_A[t])
        g_blocks = grad_blocks(prob, w)                 # (m, p)
        g = _masked_mean(g_blocks, mask) + lam * w

        if prev_w is not None:
            overlap = mask * prev_mask                  # A_t ∩ A_{t-1}
            novl = jnp.maximum(overlap.sum(), 1.0)
            g_ovl_now = jnp.einsum("m,mp->p", overlap, g_blocks) * (m / novl)
            g_ovl_prev = jnp.einsum("m,mp->p", overlap,
                                    grad_blocks(prob, prev_w)) * (m / novl)
            u_t = w - prev_w
            r_t = (g_ovl_now - g_ovl_prev) + lam * u_t
            state.push(u_t, r_t)

        d = lbfgs_direction(state, g)
        # Exact line search on the encoded quadratic over fastest-k set D_t
        # (paper eq. 3): worker i contributes ||S_i X d||^2.
        maskD = jnp.asarray(masks_D[t])
        Xd = jnp.einsum("mrp,p->mr", prob.SX, d)        # (m, r)
        quad = jnp.einsum("m,mr->", maskD, Xd ** 2) / (prob.n * prob.beta)
        quad = quad * (m / jnp.maximum(maskD.sum(), 1.0)) + lam * jnp.vdot(d, d)
        alpha = -rho * jnp.vdot(d, g) / jnp.maximum(quad, 1e-30)

        prev_w, prev_mask = w, mask
        w = w + alpha * d
        trace.append(float(original_objective(prob, w, h="l2")))
    return w, np.asarray(trace)
