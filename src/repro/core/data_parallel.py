"""Encoded data-parallel optimization (paper §2.1, Algorithms 1-2).

Objective:   f(w) = 1/(2n) ||X w - y||^2 + lam * h(w)
Encoded:     f~(w) = 1/(2 n beta) ||S (X w - y)||^2 + lam * h(w)

Worker i stores (S_i X, S_i y); at iteration t the master combines the
gradients of the fastest ``k`` workers (erasure mask), rescaled by 1/eta.
With the repo convention S^T S = beta I (see core/encoding.py) the masked
gradient estimates  (1/n) X^T (X w - y)  with BRIP error eps.

Everything here is a pure-JAX reference implementation operating on stacked
worker blocks ``(m, rows_per_worker, p)`` — the same functions run unsharded
on CPU (tests, benchmarks) and under pjit with the leading axis mapped onto
the ``data`` mesh axis (launch/).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .encoding import LinearEncoder

__all__ = [
    "EncodedProblem", "make_encoded_problem", "encoded_gradients",
    "masked_gradient", "gd_step", "run_encoded_gd", "prox_l1",
    "run_encoded_proximal", "original_objective",
]


@partial(jax.tree_util.register_dataclass,
         data_fields=("SX", "Sy", "X", "y"),
         meta_fields=("lam", "beta", "n"))
@dataclasses.dataclass
class EncodedProblem:
    """Worker-stacked encoded least-squares problem (a jit-able pytree)."""
    SX: jax.Array      # (m, r, p)   encoded data blocks
    Sy: jax.Array      # (m, r)      encoded responses
    X: jax.Array       # (n, p)      original data (for evaluating f)
    y: jax.Array       # (n,)
    lam: float
    beta: float
    n: int

    @property
    def m(self) -> int:
        return self.SX.shape[0]


def make_encoded_problem(X: np.ndarray, y: np.ndarray, enc: LinearEncoder,
                         m: int, lam: float = 0.0,
                         dtype=jnp.float32) -> EncodedProblem:
    """Build the worker-stacked encoded problem from any encoding operator.

    Per-worker blocks are built via ``enc.encode_partitioned`` (by default
    one lazy ``worker_block`` per worker) — S is never materialized and
    structured encoders only touch the input coordinates each worker's
    rows depend on (``input_slice``).  X and y are encoded jointly as one
    (n, p+1) pass, since the operator acts columnwise.
    """
    enc = enc.with_workers(m)
    Xy = np.concatenate([np.asarray(X, np.float64),
                         np.asarray(y, np.float64)[:, None]], axis=1)
    SXy = np.stack([np.asarray(b, np.float64)
                    for b in enc.encode_partitioned(Xy)])  # (m, r, p+1)
    return EncodedProblem(
        SX=jnp.asarray(SXy[..., :-1], dtype), Sy=jnp.asarray(SXy[..., -1], dtype),
        X=jnp.asarray(X, dtype), y=jnp.asarray(y, dtype),
        lam=float(lam), beta=float(enc.beta), n=X.shape[0])


def original_objective(prob: EncodedProblem, w: jax.Array,
                       h: str = "l2") -> jax.Array:
    """f(w) on the ORIGINAL (uncoded) problem — convergence is measured here."""
    r = prob.X @ w - prob.y
    loss = 0.5 * jnp.vdot(r, r) / prob.n
    if h == "l2":
        reg = 0.5 * jnp.vdot(w, w)
    elif h == "l1":
        reg = jnp.sum(jnp.abs(w))
    elif h == "none":
        reg = 0.0
    else:
        raise ValueError(h)
    return loss + prob.lam * reg


def encoded_gradients(prob: EncodedProblem, w: jax.Array) -> jax.Array:
    """Per-worker gradients of the smooth part, (m, p).

    grad_i = 1/(n beta) (S_i X)^T (S_i X w - S_i y).
    """
    r = jnp.einsum("mrp,p->mr", prob.SX, w) - prob.Sy
    return jnp.einsum("mrp,mr->mp", prob.SX, r) / (prob.n * prob.beta)


def _masked_mean(g: jax.Array, mask: jax.Array) -> jax.Array:
    """(1/eta) sum_{i in A} g_i with eta = k/m — the paper's 1/(2 n eta) scaling.

    On TPU the weighted reduction runs through the fused Pallas combine
    kernel (``kernels/coded_reduce.py``): the (m, p) weighted intermediate
    never round-trips HBM.  Elsewhere the dense einsum is faster than the
    interpreted kernel, so it stays the fallback.
    """
    k = jnp.maximum(mask.sum(), 1.0)
    from repro.kernels.ops import on_tpu
    if on_tpu():
        # weights go in pre-shaped (m, 1): the kernel's sublane layout,
        # built here so no per-step reshape survives into the kernel call
        from repro.kernels.coded_reduce import coded_combine_call
        return coded_combine_call(g, mask[:, None] * (g.shape[0] / k))
    return jnp.einsum("m,mp->p", mask * (g.shape[0] / k), g)


def masked_gradient(prob: EncodedProblem, w: jax.Array,
                    mask: jax.Array) -> jax.Array:
    """Fastest-k aggregation of per-worker encoded gradients."""
    return _masked_mean(encoded_gradients(prob, w), mask)


@partial(jax.jit, static_argnames=("h",))
def gd_step(prob: EncodedProblem, w: jax.Array, mask: jax.Array,
            step_size: float, h: str = "l2") -> jax.Array:
    """Encoded gradient descent step (paper §2.1) with smooth regularizer."""
    g = masked_gradient(prob, w, mask)
    if h == "l2":
        g = g + prob.lam * w
    return w - step_size * g


def run_encoded_gd(prob: EncodedProblem, masks: np.ndarray, step_size: float,
                   w0: jax.Array | None = None, h: str = "l2"):
    """Run GD over a precomputed (T, m) mask schedule; returns (w_T, f-trace).

    Thin wrapper over the scan-fused runner (runtime/runners.py): the whole
    schedule and objective trace stay on device — one compiled program
    instead of one dispatch + host sync per step.  Same math and op order as
    the historical per-step ``gd_step`` loop.
    """
    from repro.runtime.runners import scan_gd
    w = jnp.zeros(prob.SX.shape[-1]) if w0 is None else w0
    w, trace = scan_gd(prob, jnp.asarray(masks, jnp.float32), step_size, w,
                       h=h)
    return w, np.asarray(trace)


def prox_l1(v: jax.Array, thresh: float) -> jax.Array:
    """Soft-thresholding operator (ISTA)."""
    return jnp.sign(v) * jnp.maximum(jnp.abs(v) - thresh, 0.0)


@jax.jit
def prox_step(prob: EncodedProblem, w: jax.Array, mask: jax.Array,
              step_size: float) -> jax.Array:
    """Encoded proximal gradient step for l1 regularizer (paper §2.1, Thm 5)."""
    g = masked_gradient(prob, w, mask)
    return prox_l1(w - step_size * g, step_size * prob.lam)


def run_encoded_proximal(prob: EncodedProblem, masks: np.ndarray,
                         step_size: float, w0: jax.Array | None = None):
    """Encoded ISTA over a mask schedule; returns (w_T, f-trace with h=l1).

    Thin wrapper over the scan-fused runner (runtime/runners.py)."""
    from repro.runtime.runners import scan_prox
    w = jnp.zeros(prob.SX.shape[-1]) if w0 is None else w0
    w, trace = scan_prox(prob, jnp.asarray(masks, jnp.float32), step_size, w)
    return w, np.asarray(trace)
