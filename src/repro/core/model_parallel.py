"""Encoded model parallelism: block coordinate descent on the lifted problem
(paper §2.2, Algorithms 3-4; Thm 6).

Original:  min_w g(w) = phi(X w),   X column-partitioned across m workers.
Encoded:   w = S^T v,  min_v g~(v) = phi(X S^T v) = phi(sum_i X S_i^T v_i).

Worker i stores the column block X S_i^T and its parameter slice v_i; the
master maintains the summed activations z = sum_i u_i with u_i = X S_i^T v_i.
Per iteration only workers in A_t apply their step (line 4-8 of Alg. 3 keeps
consistency: an erased worker's step is discarded, v_i stays put).

Unlike data parallelism this converges to the EXACT optimum of the original
problem — the geometry is preserved under lifting (paper Lemma 15).

phi is supplied as a (value, grad) pair acting on the n-vector of activations;
built-ins: quadratic phi(z) = 1/2||z - y||^2 and logistic with labels.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .encoding import LinearEncoder

__all__ = ["LiftedProblem", "make_lifted_problem", "phi_quadratic",
           "phi_logistic", "run_encoded_bcd"]


@dataclasses.dataclass
class LiftedProblem:
    XS: jax.Array          # (m, n, p_block)  worker column blocks X S_i^T
    phi_val: Callable      # z (n,) -> scalar
    phi_grad: Callable     # z (n,) -> (n,)
    beta: float

    @property
    def m(self) -> int:
        return self.XS.shape[0]


def make_lifted_problem(X: np.ndarray, enc: LinearEncoder, m: int, phi_val,
                        phi_grad, dtype=jnp.float32) -> LiftedProblem:
    # S is (beta*p, p) here: encoding acts on the FEATURE dimension.
    p = X.shape[1]
    if enc.n != p:
        raise ValueError(f"encoder dim {enc.n} != feature dim {p}")
    enc = enc.with_workers(m)
    # X S_i^T = (S_i X^T)^T — each worker's column block from the
    # partitioned encode of X^T, matrix-free.
    XS = np.stack([np.asarray(b, np.float64).T
                   for b in enc.encode_partitioned(np.asarray(X).T)])
    return LiftedProblem(jnp.asarray(XS, dtype), phi_val, phi_grad,
                         float(enc.beta))


def phi_quadratic(y: np.ndarray):
    yj = jnp.asarray(y)
    def val(z):
        r = z - yj
        return 0.5 * jnp.vdot(r, r) / yj.shape[0]
    def grad(z):
        return (z - yj) / yj.shape[0]
    return val, grad


def phi_logistic(labels: np.ndarray, lam: float = 0.0):
    """phi(z) = mean log(1 + exp(-l_i z_i)); labels in {-1, +1}."""
    lj = jnp.asarray(labels, jnp.float32)
    def val(z):
        return jnp.mean(jnp.logaddexp(0.0, -lj * z))
    def grad(z):
        return -lj * jax.nn.sigmoid(-lj * z) / lj.shape[0]
    return val, grad


def run_encoded_bcd(prob: LiftedProblem, masks: np.ndarray, step_size: float,
                    v0: jax.Array | None = None):
    """Run encoded BCD over a (T, m) mask schedule.

    Follows Algorithms 3-4: at iteration t every worker computes its step from
    the CURRENT global activations, but only workers in A_t commit it.

    Returns (v_T, w_T = S^T v_T implicit activations, objective trace).

    Thin wrapper over the scan-fused runner (runtime/runners.py): the per
    iteration update d_i = -alpha (X S_i^T)^T grad phi(z) with erased workers
    masked to a no-op, the whole schedule scanned in one compiled program.
    """
    from repro.runtime.runners import scan_bcd
    m, n, pb = prob.XS.shape
    v = jnp.zeros((m, pb)) if v0 is None else v0
    v, trace = scan_bcd(prob, jnp.asarray(masks, jnp.float32), step_size, v)
    return v, np.asarray(trace)
