"""Encoding matrices for encoded distributed optimization (paper §4).

Convention used throughout this repo
------------------------------------
An encoder for data dimension ``n`` with redundancy ``beta`` is a tall matrix
``S`` of shape ``(beta * n, n)`` normalized so that a *tight frame* satisfies

    S.T @ S = beta * I_n            (exactly, for ETF / Hadamard / Haar / FRC)

and a generic (e.g. Gaussian) encoder satisfies it approximately.  With this
convention the Block-RIP condition (paper Def. 1) reads: for every worker
subset ``A`` of fraction ``eta``,

    (1 - eps) I  <=  (1 / (eta * beta)) S_A.T S_A  <=  (1 + eps) I .

Row blocks are assigned to ``m`` workers contiguously (``partition_rows``).
All constructions are host-side numpy; iteration code consumes jnp arrays.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "Encoder",
    "gaussian_encoder",
    "hadamard_encoder",
    "haar_encoder",
    "paley_etf_encoder",
    "steiner_etf_encoder",
    "replication_encoder",
    "identity_encoder",
    "partition_rows",
    "brip_constant",
    "subset_spectrum",
    "hadamard_matrix",
    "make_encoder",
]


@dataclasses.dataclass(frozen=True)
class Encoder:
    """A realized encoding matrix together with its metadata."""

    name: str
    S: np.ndarray  # (beta*n, n), float64
    beta: float    # redundancy factor = rows / cols
    tight: bool    # whether S.T S == beta I exactly

    @property
    def n(self) -> int:
        return self.S.shape[1]

    @property
    def rows(self) -> int:
        return self.S.shape[0]


def hadamard_matrix(n: int) -> np.ndarray:
    """Sylvester Hadamard matrix with +-1 entries; n must be a power of two."""
    if n & (n - 1) or n <= 0:
        raise ValueError(f"Hadamard order must be a power of two, got {n}")
    H = np.array([[1.0]])
    while H.shape[0] < n:
        H = np.block([[H, H], [H, -H]])
    return H


def _next_pow2(x: int) -> int:
    return 1 << (int(x) - 1).bit_length()


def gaussian_encoder(n: int, beta: float = 2.0, seed: int = 0) -> Encoder:
    """i.i.d. Gaussian ensemble (paper §4.1 'random matrices')."""
    rows = int(round(beta * n))
    rng = np.random.default_rng(seed)
    S = rng.standard_normal((rows, n)) / math.sqrt(n)
    return Encoder("gaussian", S, rows / n, tight=False)


def hadamard_encoder(n: int, beta: float = 2.0, seed: int = 0) -> Encoder:
    """Column-subsampled (randomized) Hadamard ensemble (paper §4.2.2, FWHT).

    S = H_N[:, cols] * D / sqrt(n), N = next_pow2(beta*n), |cols| = n, D random
    signs.  Equivalent to inserting zero rows into the data then FWHT-ing.
    """
    N = _next_pow2(int(round(beta * n)))
    rng = np.random.default_rng(seed)
    cols = rng.choice(N, size=n, replace=False)
    signs = rng.choice([-1.0, 1.0], size=n)
    H = hadamard_matrix(N)
    S = H[:, cols] * signs[None, :] / math.sqrt(n)
    # S.T S = (N / n) I exactly -> rescale to beta = N/n convention.
    return Encoder("hadamard", S, N / n, tight=True)


def haar_encoder(n: int, beta: float = 2.0, seed: int = 0) -> Encoder:
    """Column-subsampled Haar wavelet matrix (paper §4.2.1, sparse)."""
    N = _next_pow2(int(round(beta * n)))
    # Recursive orthonormal Haar: H_{2k} = 1/sqrt(2) [[H_k (x) [1,1]], [I_k (x) [1,-1]]]
    H = np.array([[1.0]])
    while H.shape[0] < N:
        k = H.shape[0]
        top = np.kron(H, np.array([[1.0, 1.0]]))
        bot = np.kron(np.eye(k), np.array([[1.0, -1.0]]))
        H = np.concatenate([top, bot], axis=0) / math.sqrt(2.0)
    rng = np.random.default_rng(seed)
    cols = rng.choice(N, size=n, replace=False)
    S = H[:, cols] * math.sqrt(N / n)  # make S.T S = (N/n) I
    return Encoder("haar", S, N / n, tight=True)


def _jacobsthal(p: int) -> np.ndarray:
    """Jacobsthal matrix Q_ij = chi(i - j) for prime p (quadratic character)."""
    residues = set((x * x) % p for x in range(1, p))
    chi = np.zeros(p)
    for a in range(1, p):
        chi[a] = 1.0 if a in residues else -1.0
    idx = np.arange(p)
    return chi[(idx[:, None] - idx[None, :]) % p]


def is_prime(x: int) -> bool:
    if x < 2:
        return False
    for d in range(2, int(math.isqrt(x)) + 1):
        if x % d == 0:
            return False
    return True


def paley_etf_encoder(n: int, seed: int = 0) -> Encoder:
    """Real Paley ETF with redundancy beta = 2 (paper §4.1, Paley 1933).

    Needs a prime p with p ≡ 1 (mod 4) and (p+1)/2 >= n; the frame lives in
    R^{(p+1)/2} and has p+1 vectors.  We build the conference-matrix projection
    P = (I + C / sqrt(p)) / 2 (rank (p+1)/2), take an orthonormal column basis
    U of P ((p+1) x (p+1)/2), and subsample n columns.  Rows of sqrt(2) U form
    a unit-norm tight frame; the column-subsampled version stays tight.
    """
    p = 2 * n - 1
    while not (is_prime(p) and p % 4 == 1):
        p += 2
    q = _jacobsthal(p)
    C = np.zeros((p + 1, p + 1))
    C[0, 1:] = 1.0
    C[1:, 0] = 1.0
    C[1:, 1:] = q
    # Symmetric conference matrix: C^T C = p I, diag 0.
    P = (np.eye(p + 1) + C / math.sqrt(p)) / 2.0
    evals, evecs = np.linalg.eigh(P)
    U = evecs[:, evals > 0.5]  # eigenvalue-1 eigenspace, (p+1) x (p+1)/2
    rng = np.random.default_rng(seed)
    cols = rng.choice(U.shape[1], size=n, replace=False)
    # Columns of U are orthonormal, so (sqrt(2) U_cols)^T (sqrt(2) U_cols) = 2I.
    # Rescale to the repo convention S^T S = beta I with beta = rows/n.
    beta = (p + 1) / n
    S = math.sqrt(beta) * U[:, cols]
    return Encoder("paley", S, beta, tight=True)


def steiner_etf_encoder(n: int, v: int | None = None) -> Encoder:
    """Steiner ETF from (2,2,v)-Steiner systems (paper §4.2.1, Fickus et al.).

    S is v^2 x v(v-1)/2 with redundancy beta = 2v/(v-1); each 'block' (v rows
    arising from one row of the incidence matrix V) holds v-1 distinct
    (non-constant) columns of the order-v Hadamard matrix, scaled 1/sqrt(v-1).
    If ``n`` is given, v is chosen so v(v-1)/2 >= n and columns subsampled.
    """
    if v is None:
        v = 4
        while v * (v - 1) // 2 < n:
            v *= 2
    H = hadamard_matrix(v)
    ncols = v * (v - 1) // 2
    pairs = [(a, b) for a in range(v) for b in range(a + 1, v)]
    S = np.zeros((v * v, ncols))
    # ones_in_row[r] enumerates columns whose pair contains r, in order.
    counter = np.zeros(v, dtype=int)
    for j, (a, b) in enumerate(pairs):
        for r in (a, b):
            ell = counter[r]
            counter[r] += 1
            S[r * v:(r + 1) * v, j] = H[:, ell + 1]  # skip all-ones column h_1
    S /= math.sqrt(v - 1)
    if n is not None and n < ncols:
        cols = np.random.default_rng(0).choice(ncols, size=n, replace=False)
        S = S[:, np.sort(cols)]
    # Column subsampling preserves S^T S = beta I with the FRAME constant
    # beta = 2v/(v-1) (column norm^2); storage redundancy rows/n can be larger.
    beta = 2.0 * v / (v - 1.0)
    return Encoder("steiner", S, beta, tight=True)


def replication_encoder(n: int, beta: int = 2) -> Encoder:
    """beta-fold replication: S = [I; I; ...] (baseline, paper §5)."""
    S = np.concatenate([np.eye(n)] * int(beta), axis=0)
    return Encoder("replication", S, float(beta), tight=True)


def identity_encoder(n: int) -> Encoder:
    """Uncoded baseline: S = I."""
    return Encoder("uncoded", np.eye(n), 1.0, tight=True)


_FACTORIES = {
    "gaussian": gaussian_encoder,
    "hadamard": hadamard_encoder,
    "haar": haar_encoder,
    "paley": lambda n, beta=2.0, seed=0: paley_etf_encoder(n, seed),
    "steiner": lambda n, beta=2.0, seed=0: steiner_etf_encoder(n),
    "replication": lambda n, beta=2.0, seed=0: replication_encoder(n, int(beta)),
    "uncoded": lambda n, beta=1.0, seed=0: identity_encoder(n),
}


def make_encoder(name: str, n: int, beta: float = 2.0, seed: int = 0) -> Encoder:
    if name not in _FACTORIES:
        raise KeyError(f"unknown encoder '{name}'; have {sorted(_FACTORIES)}")
    return _FACTORIES[name](n, beta=beta, seed=seed)


def pad_rows(enc: Encoder, m: int) -> Encoder:
    """Zero-pad S with extra rows so m divides the row count.

    Zero rows carry no data (a worker block just has a few dead rows);
    S^T S — and hence tightness/BRIP — is unchanged.
    """
    pad = (-enc.rows) % m
    if pad == 0:
        return enc
    S = np.concatenate([enc.S, np.zeros((pad, enc.n))], axis=0)
    return Encoder(enc.name, S, enc.beta, enc.tight)


def partition_rows(enc: Encoder, m: int) -> np.ndarray:
    """Split S row-wise into m contiguous worker blocks, shape (m, rows/m, n)."""
    rows = enc.rows
    if rows % m:
        raise ValueError(f"{rows} encoded rows not divisible by m={m}")
    return enc.S.reshape(m, rows // m, enc.n)


def subset_spectrum(enc: Encoder, m: int, k: int, trials: int = 50,
                    seed: int = 0) -> np.ndarray:
    """Eigenvalues of (1/(eta*beta)) S_A^T S_A over random k-subsets (Fig 5-6)."""
    blocks = partition_rows(enc, m)
    eta = k / m
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(trials):
        A = rng.choice(m, size=k, replace=False)
        SA = blocks[A].reshape(-1, enc.n)
        G = SA.T @ SA / (eta * enc.beta)
        out.append(np.linalg.eigvalsh(G))
    return np.asarray(out)


def brip_constant(enc: Encoder, m: int, k: int, trials: int = 50,
                  seed: int = 0) -> float:
    """Empirical BRIP epsilon over sampled subsets: max |eig - 1|."""
    ev = subset_spectrum(enc, m, k, trials=trials, seed=seed)
    return float(np.max(np.abs(ev - 1.0)))
