"""Encoding operators for encoded distributed optimization (paper §4).

Convention used throughout this repo
------------------------------------
An encoder for data dimension ``n`` with redundancy ``beta`` is a linear
OPERATOR whose action is that of a tall matrix ``S`` of shape
``(beta * n, n)``, normalized so that a *tight frame* satisfies

    S.T @ S = beta * I_n            (exactly, for ETF / Hadamard / Haar / FRC)

and a generic (e.g. Gaussian) encoder satisfies it approximately.  With this
convention the Block-RIP condition (paper Def. 1) reads: for every worker
subset ``A`` of fraction ``eta``,

    (1 - eps) I  <=  (1 / (eta * beta)) S_A.T S_A  <=  (1 + eps) I .

Encoders expose ``encode`` (S @ X), ``decode_t`` (the adjoint S.T @ G),
``worker_block`` (rows of S X owned by one worker), and ``materialize``
(the dense S, for tests and spectrum diagnostics) — see ``LinearEncoder``.
Consumers never form S themselves: the dense constructions in this module
carry an explicit matrix, while the matrix-free operators in
``core.operators`` (fast Hadamard / block-diagonal) compute the same maps
in O(N log N) / per-shard time and unlock ``n`` where ``(beta*n, n)``
cannot even be allocated.

Row blocks are assigned to ``m`` workers contiguously (``with_workers`` /
``partition_rows``).  Dense constructions are host-side numpy; iteration
code consumes jnp arrays.
"""
from __future__ import annotations

import copy
import dataclasses
import math

import numpy as np

__all__ = [
    "LinearEncoder",
    "Encoder",
    "DenseEncoder",
    "as_dense",
    "gaussian_encoder",
    "hadamard_encoder",
    "haar_encoder",
    "paley_etf_encoder",
    "steiner_etf_encoder",
    "replication_encoder",
    "identity_encoder",
    "partition_rows",
    "pad_rows",
    "brip_constant",
    "subset_spectrum",
    "hadamard_matrix",
    "hadamard_ensemble",
    "make_encoder",
    "register_encoder",
    "available_encoders",
]


class LinearEncoder:
    """A matrix-free encoding operator S of shape ``(rows, n)``.

    Subclasses provide ``name``, ``n``, ``rows``, ``beta``, ``tight`` and the
    linear maps; this base supplies the worker-partition machinery.  The
    operator is *unpartitioned* until ``with_workers(m)`` binds it to ``m``
    workers (zero-padding the row count to a multiple of ``m`` — zero rows
    carry no data, so S^T S, tightness and BRIP are unchanged).

    ``encode``/``decode_t``/``worker_block`` accept 1-D ``(n,)`` or 2-D
    ``(n, q)`` inputs and return numpy or jax arrays depending on the
    backing implementation — callers that need host arrays ``np.asarray``
    the result.
    """

    # subclasses define ``name`` (str); worker partition state below.  Plain
    # class attributes (not annotated) so the dataclass machinery of dense
    # subclasses does not absorb them as implicit field defaults.
    m = None                 # worker count once partitioned
    _pad = 0                 # trailing zero rows added by with_workers

    # -- shape/metadata (subclass responsibility) ---------------------------
    @property
    def n(self) -> int:
        raise NotImplementedError

    @property
    def rows(self) -> int:
        raise NotImplementedError

    # -- linear maps (subclass responsibility) ------------------------------
    def encode(self, X):
        """S @ X: (n, q) -> (rows, q)."""
        raise NotImplementedError

    def decode_t(self, G):
        """Adjoint S.T @ G: (rows, q) -> (n, q)."""
        raise NotImplementedError

    def worker_block_local(self, i: int, X_local):
        """Worker ``i``'s rows of S X, given only ``X[input_slice(i)]``.

        Default delegates to ``encode`` on the (full-slice) input and takes
        the worker's row window; implementations with structure (block
        diagonal, aligned FWHT) override with a cheaper per-block map.
        """
        lo, hi = self.worker_rows(i)
        out = self.encode(X_local)
        return out[lo:hi]

    def materialize(self) -> np.ndarray:
        """The dense ``(rows, n)`` matrix — tests / spectrum tools only."""
        return np.asarray(self.encode(np.eye(self.n)), dtype=np.float64)

    # -- worker partition ---------------------------------------------------
    def with_workers(self, m: int) -> "LinearEncoder":
        """Bind the operator to ``m`` workers (idempotent), zero-padding the
        row count to a multiple of ``m``."""
        if self.m == m:
            return self
        if self.m is not None:
            raise ValueError(
                f"encoder already partitioned for m={self.m}, asked m={m}")
        new = copy.copy(self)
        new._pad = self._pad + ((-self.rows) % m)
        new.m = int(m)
        return new

    def _require_workers(self) -> int:
        if self.m is None:
            raise ValueError("encoder not partitioned; call with_workers(m)")
        return self.m

    @property
    def rows_per_worker(self) -> int:
        return self.rows // self._require_workers()

    def worker_rows(self, i: int) -> tuple[int, int]:
        """Contiguous encoded-row range [lo, hi) owned by worker ``i``."""
        r = self.rows_per_worker
        return i * r, (i + 1) * r

    def input_slice(self, i: int) -> slice:
        """The input coordinates worker ``i``'s rows depend on.  Structured
        encoders narrow this (block-diagonal: one shard) so data can be
        streamed in worker-by-worker; dense/FWHT mixing needs everything."""
        return slice(0, self.n)

    def worker_block(self, i: int, X):
        """Worker ``i``'s rows of S X from the FULL data array."""
        return self.worker_block_local(i, X[self.input_slice(i)])

    def encode_partitioned(self, X) -> list:
        """All m worker blocks of S X — the bulk entry the problem builders
        use.  Default builds each block via ``worker_block`` (shard-local
        for structured encoders, so nothing global is redone);
        implementations whose per-block map repeats global work (the
        misaligned FWHT fallback) override with one full-encode pass."""
        m = self._require_workers()
        return [self.worker_block(i, X) for i in range(m)]

    # -- shared small helpers ----------------------------------------------
    @staticmethod
    def _as_2d(X):
        if getattr(X, "ndim", None) == 1:
            return X[:, None], True
        return X, False


@dataclasses.dataclass(frozen=True)
class Encoder(LinearEncoder):
    """A realized (dense) encoding matrix together with its metadata.

    The reference ``LinearEncoder`` implementation: every current
    construction (Gaussian / Hadamard / Haar / Paley / Steiner / replication
    / identity) materializes S and wraps it here.  ``DenseEncoder`` is an
    alias for this class.
    """

    name: str
    S: np.ndarray  # (beta*n, n), float64
    beta: float    # redundancy factor = rows / cols
    tight: bool    # whether S.T S == beta I exactly
    m: int | None = None  # worker partition (set by with_workers)

    @property
    def n(self) -> int:
        return self.S.shape[1]

    @property
    def rows(self) -> int:
        return self.S.shape[0]

    def encode(self, X):
        return self.S @ np.asarray(X)

    def decode_t(self, G):
        return self.S.T @ np.asarray(G)

    def worker_block_local(self, i: int, X_local):
        lo, hi = self.worker_rows(i)
        return self.S[lo:hi] @ np.asarray(X_local)

    def materialize(self) -> np.ndarray:
        return self.S

    def with_workers(self, m: int) -> "Encoder":
        if self.m == m:
            return self
        if self.m is not None:
            raise ValueError(
                f"encoder already partitioned for m={self.m}, asked m={m}")
        pad = (-self.rows) % m
        S = (np.concatenate([self.S, np.zeros((pad, self.n))], axis=0)
             if pad else self.S)
        return Encoder(self.name, S, self.beta, self.tight, m=int(m))


DenseEncoder = Encoder


def as_dense(enc: LinearEncoder) -> Encoder:
    """Dense-matrix view of any operator (equivalence tests, diagnostics)."""
    if isinstance(enc, Encoder):
        return enc
    return Encoder(enc.name, enc.materialize(), enc.beta, enc.tight, m=enc.m)


def hadamard_matrix(n: int) -> np.ndarray:
    """Sylvester Hadamard matrix with +-1 entries; n must be a power of two."""
    if n & (n - 1) or n <= 0:
        raise ValueError(f"Hadamard order must be a power of two, got {n}")
    H = np.array([[1.0]])
    while H.shape[0] < n:
        H = np.block([[H, H], [H, -H]])
    return H


def _next_pow2(x: int) -> int:
    return 1 << (int(x) - 1).bit_length()


def gaussian_encoder(n: int, beta: float = 2.0, seed: int = 0) -> Encoder:
    """i.i.d. Gaussian ensemble (paper §4.1 'random matrices')."""
    rows = int(round(beta * n))
    rng = np.random.default_rng(seed)
    S = rng.standard_normal((rows, n)) / math.sqrt(n)
    return Encoder("gaussian", S, rows / n, tight=False)


def hadamard_ensemble(n: int, beta: float, seed: int):
    """The randomized-Hadamard draws (N, cols, signs) — the ONE sampling
    used by both the dense ``hadamard_encoder`` and the matrix-free
    ``FastHadamardEncoder``, so the two are the same matrix by
    construction, not by parallel rng bookkeeping."""
    N = _next_pow2(int(round(beta * n)))
    rng = np.random.default_rng(seed)
    cols = rng.choice(N, size=n, replace=False)
    signs = rng.choice([-1.0, 1.0], size=n)
    return N, cols, signs


def hadamard_encoder(n: int, beta: float = 2.0, seed: int = 0) -> Encoder:
    """Column-subsampled (randomized) Hadamard ensemble (paper §4.2.2, FWHT).

    S = H_N[:, cols] * D / sqrt(n), N = next_pow2(beta*n), |cols| = n, D random
    signs.  Equivalent to inserting zero rows into the data then FWHT-ing.
    """
    N, cols, signs = hadamard_ensemble(n, beta, seed)
    H = hadamard_matrix(N)
    S = H[:, cols] * signs[None, :] / math.sqrt(n)
    # S.T S = (N / n) I exactly -> rescale to beta = N/n convention.
    return Encoder("hadamard", S, N / n, tight=True)


def haar_encoder(n: int, beta: float = 2.0, seed: int = 0) -> Encoder:
    """Column-subsampled Haar wavelet matrix (paper §4.2.1, sparse)."""
    N = _next_pow2(int(round(beta * n)))
    # Recursive orthonormal Haar: H_{2k} = 1/sqrt(2) [[H_k (x) [1,1]], [I_k (x) [1,-1]]]
    H = np.array([[1.0]])
    while H.shape[0] < N:
        k = H.shape[0]
        top = np.kron(H, np.array([[1.0, 1.0]]))
        bot = np.kron(np.eye(k), np.array([[1.0, -1.0]]))
        H = np.concatenate([top, bot], axis=0) / math.sqrt(2.0)
    rng = np.random.default_rng(seed)
    cols = rng.choice(N, size=n, replace=False)
    S = H[:, cols] * math.sqrt(N / n)  # make S.T S = (N/n) I
    return Encoder("haar", S, N / n, tight=True)


def _jacobsthal(p: int) -> np.ndarray:
    """Jacobsthal matrix Q_ij = chi(i - j) for prime p (quadratic character)."""
    residues = set((x * x) % p for x in range(1, p))
    chi = np.zeros(p)
    for a in range(1, p):
        chi[a] = 1.0 if a in residues else -1.0
    idx = np.arange(p)
    return chi[(idx[:, None] - idx[None, :]) % p]


def is_prime(x: int) -> bool:
    if x < 2:
        return False
    for d in range(2, int(math.isqrt(x)) + 1):
        if x % d == 0:
            return False
    return True


def paley_etf_encoder(n: int, seed: int = 0) -> Encoder:
    """Real Paley ETF with redundancy beta = 2 (paper §4.1, Paley 1933).

    Needs a prime p with p ≡ 1 (mod 4) and (p+1)/2 >= n; the frame lives in
    R^{(p+1)/2} and has p+1 vectors.  We build the conference-matrix projection
    P = (I + C / sqrt(p)) / 2 (rank (p+1)/2), take an orthonormal column basis
    U of P ((p+1) x (p+1)/2), and subsample n columns.  Rows of sqrt(2) U form
    a unit-norm tight frame; the column-subsampled version stays tight.
    """
    p = 2 * n - 1
    while not (is_prime(p) and p % 4 == 1):
        p += 2
    q = _jacobsthal(p)
    C = np.zeros((p + 1, p + 1))
    C[0, 1:] = 1.0
    C[1:, 0] = 1.0
    C[1:, 1:] = q
    # Symmetric conference matrix: C^T C = p I, diag 0.
    P = (np.eye(p + 1) + C / math.sqrt(p)) / 2.0
    evals, evecs = np.linalg.eigh(P)
    U = evecs[:, evals > 0.5]  # eigenvalue-1 eigenspace, (p+1) x (p+1)/2
    rng = np.random.default_rng(seed)
    cols = rng.choice(U.shape[1], size=n, replace=False)
    # Columns of U are orthonormal, so (sqrt(2) U_cols)^T (sqrt(2) U_cols) = 2I.
    # Rescale to the repo convention S^T S = beta I with beta = rows/n.
    beta = (p + 1) / n
    S = math.sqrt(beta) * U[:, cols]
    return Encoder("paley", S, beta, tight=True)


def steiner_etf_encoder(n: int, v: int | None = None) -> Encoder:
    """Steiner ETF from (2,2,v)-Steiner systems (paper §4.2.1, Fickus et al.).

    S is v^2 x v(v-1)/2 with redundancy beta = 2v/(v-1); each 'block' (v rows
    arising from one row of the incidence matrix V) holds v-1 distinct
    (non-constant) columns of the order-v Hadamard matrix, scaled 1/sqrt(v-1).
    If ``n`` is given, v is chosen so v(v-1)/2 >= n and columns subsampled.
    """
    if v is None:
        v = 4
        while v * (v - 1) // 2 < n:
            v *= 2
    H = hadamard_matrix(v)
    ncols = v * (v - 1) // 2
    pairs = [(a, b) for a in range(v) for b in range(a + 1, v)]
    S = np.zeros((v * v, ncols))
    # ones_in_row[r] enumerates columns whose pair contains r, in order.
    counter = np.zeros(v, dtype=int)
    for j, (a, b) in enumerate(pairs):
        for r in (a, b):
            ell = counter[r]
            counter[r] += 1
            S[r * v:(r + 1) * v, j] = H[:, ell + 1]  # skip all-ones column h_1
    S /= math.sqrt(v - 1)
    if n is not None and n < ncols:
        cols = np.random.default_rng(0).choice(ncols, size=n, replace=False)
        S = S[:, np.sort(cols)]
    # Column subsampling preserves S^T S = beta I with the FRAME constant
    # beta = 2v/(v-1) (column norm^2); storage redundancy rows/n can be larger.
    beta = 2.0 * v / (v - 1.0)
    return Encoder("steiner", S, beta, tight=True)


def replication_encoder(n: int, beta: int = 2) -> Encoder:
    """beta-fold replication: S = [I; I; ...] (baseline, paper §5)."""
    S = np.concatenate([np.eye(n)] * int(beta), axis=0)
    return Encoder("replication", S, float(beta), tight=True)


def identity_encoder(n: int) -> Encoder:
    """Uncoded baseline: S = I."""
    return Encoder("uncoded", np.eye(n), 1.0, tight=True)


_FACTORIES = {
    "gaussian": gaussian_encoder,
    "hadamard": hadamard_encoder,
    "haar": haar_encoder,
    "paley": lambda n, beta=2.0, seed=0: paley_etf_encoder(n, seed),
    "steiner": lambda n, beta=2.0, seed=0: steiner_etf_encoder(n),
    "replication": lambda n, beta=2.0, seed=0: replication_encoder(n, int(beta)),
    "uncoded": lambda n, beta=1.0, seed=0: identity_encoder(n),
    # core.operators registers the matrix-free entries ('fast-hadamard',
    # 'block-diagonal') on import — see register_encoder below.
}


def register_encoder(name: str, factory) -> None:
    """Register an encoder factory ``f(n, beta=..., seed=..., **kw)``."""
    _FACTORIES[name] = factory


def make_encoder(name: str, n: int, beta: float = 2.0, seed: int = 0,
                 **kw) -> LinearEncoder:
    """Build an encoder by registry name.

    Dense constructions return an ``Encoder``; the matrix-free operators
    registered by ``core.operators`` ('fast-hadamard', 'block-diagonal')
    return their ``LinearEncoder`` implementations.  Extra keyword arguments
    are passed to the factory (e.g. ``block_size=`` for 'block-diagonal').
    """
    if name not in _FACTORIES:
        raise KeyError(f"unknown encoder '{name}'; have {sorted(_FACTORIES)}")
    return _FACTORIES[name](n, beta=beta, seed=seed, **kw)


def available_encoders() -> list[str]:
    return sorted(_FACTORIES)


def pad_rows(enc: LinearEncoder, m: int) -> LinearEncoder:
    """Zero-pad with extra rows so m divides the row count, binding the
    worker partition (alias of ``enc.with_workers(m)``).

    Zero rows carry no data (a worker block just has a few dead rows);
    S^T S — and hence tightness/BRIP — is unchanged.
    """
    return enc.with_workers(m)


def partition_rows(enc: LinearEncoder, m: int) -> np.ndarray:
    """Split S row-wise into m contiguous worker blocks, shape (m, rows/m, n).

    Materializes the operator — diagnostics and tests only; production
    consumers use ``worker_block`` and never form S.
    """
    rows = enc.rows
    if rows % m:
        raise ValueError(f"{rows} encoded rows not divisible by m={m}")
    return enc.materialize().reshape(m, rows // m, enc.n)


def subset_spectrum(enc: LinearEncoder, m: int, k: int, trials: int = 50,
                    seed: int = 0) -> np.ndarray:
    """Eigenvalues of (1/(eta*beta)) S_A^T S_A over random k-subsets (Fig 5-6).

    Accepts dense and matrix-free encoders alike (rows auto-padded to m)."""
    if enc.rows % m and enc.m is None:
        enc = enc.with_workers(m)
    blocks = partition_rows(enc, m)
    eta = k / m
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(trials):
        A = rng.choice(m, size=k, replace=False)
        SA = blocks[A].reshape(-1, enc.n)
        G = SA.T @ SA / (eta * enc.beta)
        out.append(np.linalg.eigvalsh(G))
    return np.asarray(out)


def brip_constant(enc: LinearEncoder, m: int, k: int, trials: int = 50,
                  seed: int = 0) -> float:
    """Empirical BRIP epsilon over sampled subsets: max |eig - 1|."""
    ev = subset_spectrum(enc, m, k, trials=trials, seed=seed)
    return float(np.max(np.abs(ev - 1.0)))
