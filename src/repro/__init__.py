"""repro: encoded distributed optimization (Karakus et al., 2018) as a
production-grade JAX framework — core coded-optimization library, 10
assigned architectures, coded data-parallel trainer, multi-pod dry-run and
roofline tooling, Pallas TPU encode kernels."""

__version__ = "0.1.0"
