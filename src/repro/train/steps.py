"""Step builders: coded-DP train step, prefill step, decode step.

The paper's technique enters ``train_step`` through the per-sample weight
vector: the host computes FRC decode weights from the straggler mask
(core.gradient_coding) and the weighted loss makes the gradient a masked,
rescaled sum over surviving workers' shards — the erasure-robust aggregation
of DESIGN §3-4.  Everything is a pure function of (params, opt_state, batch),
so the same builder serves the CPU trainer and the 512-device dry-run.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import transformer as T
from ..optim import adamw_update

__all__ = ["build_train_step", "build_prefill_step", "build_decode_step",
           "batch_extras"]


def batch_extras(cfg: ArchConfig, batch: dict) -> dict:
    kw = {}
    if cfg.n_patches:
        kw["patch_embeds"] = batch["patch_embeds"]
        kw["mrope_positions"] = batch["mrope_positions"]
    if cfg.n_enc_layers:
        kw["enc_embeds"] = batch["enc_embeds"]
    return kw


def build_train_step(cfg: ArchConfig, lr_fn: Callable,
                     weight_decay: float = 0.1,
                     z_loss_weight: float = 1e-3,
                     grad_specs=None) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    batch: tokens (B,S) int32, labels (B,S) int32, weights (B,) f32 coded
    decode weights, plus modality extras (patch/enc embeddings).

    grad_specs (§Perf B4): PartitionSpec tree matching params — constraining
    gradients to the parameter sharding lets the SPMD partitioner emit
    reduce-scatters instead of full-size all-reduces for the data-axis
    gradient reduction.
    """

    def step(params, opt_state, batch):
        def loss_fn(p):
            logits, aux = T.forward(p, cfg, batch["tokens"],
                                    **batch_extras(cfg, batch))
            w = batch["weights"][:, None] * jnp.ones_like(
                batch["labels"], jnp.float32)
            if cfg.n_patches:  # patch positions carry no next-token target
                w = w.at[:, :cfg.n_patches].set(0.0)
            loss = T.lm_loss(logits, batch["labels"], w)
            total = (loss
                     + cfg.router_aux_weight * aux.get("load_balance", 0.0)
                     + z_loss_weight * aux.get("router_z", 0.0))
            return total, (loss, aux)

        grads, (loss, aux) = jax.grad(loss_fn, has_aux=True)(params)
        if grad_specs is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_specs)
        lr = lr_fn(opt_state.count)
        params, opt_state, om = adamw_update(
            grads, opt_state, params, lr=lr, weight_decay=weight_decay)
        metrics = {"loss": loss, "lr": lr, **om,
                   **{k: v for k, v in aux.items()}}
        return params, opt_state, metrics

    return step


def build_prefill_step(cfg: ArchConfig,
                       cache_len: Optional[int] = None) -> Callable:
    """(params, batch) -> (last-position logits, caches)."""

    def step(params, batch):
        return T.prefill(params, cfg, batch["tokens"], cache_len=cache_len,
                         **batch_extras(cfg, batch))

    return step


def build_decode_step(cfg: ArchConfig) -> Callable:
    """(params, token (B,1), caches, index) -> (logits, new caches)."""

    def step(params, token, caches, index):
        return T.decode_step(params, cfg, token, caches, index)

    return step
