from .steps import build_train_step, build_prefill_step, build_decode_step
from .trainer import Trainer, TrainerConfig
