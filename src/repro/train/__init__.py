from .steps import build_train_step, build_prefill_step, build_decode_step
from .coded import (CodedTrainer, TrainProblem, build_coded_train_step,
                    run_coded_sgd)
from .trainer import Trainer, TrainerConfig
