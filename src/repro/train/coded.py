"""repro.train.coded — coded SGD bridging the model zoo to the runtime.

The subsystem DESIGN §15 describes: per-worker minibatch gradients of a real
neural LM flow through the gradient-coding combine, and the training loop is
driven by the SAME ``ClusterEngine`` schedules, active-set policies, fault
injectors and wall-clock accounting as every convex strategy — the legacy
self-contained loop in ``train/trainer.py`` is now a thin adapter over
:class:`CodedTrainer`.

Dataflow per step t (one jitted program after the first step):

    GroupBatcher ----> tokens/labels (m, g*rows, S), coeff (m, g*rows)
    Schedule.masks[t] -> code.decode_weights(mask)        (host, tiny)
    vmap(value_and_grad(worker_loss)) over the worker axis
        worker i: sum_r coeff[i,r] * CE_row_r / (rows * S)   [+ aux]
    flatten grads -> ONE (m, P_total) block
    kernels.coded_reduce.coded_combine_call(block, decode) / num_groups
    optim.adamw_update

The per-row cross entropy uses a FIXED denominator (rows * S tokens), not
the self-normalizing ``lm_loss`` weight sum: gradients stay LINEAR in the
combine coefficients, so with an exact code the decoded update equals the
full-batch update bit-for-bit (tests/test_coded_sgd.py) and a stochastic
code is unbiased (tests/test_code_properties.py).

``run_coded_sgd`` adapts the trainer to the Strategy interface
(``RunResult`` with engine times as the x-axis); ``runtime.strategies``
registers it as ``coded-sgd``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.gradient_coding import GradientCode, make_code
from ..data.pipeline import GroupBatcher, TokenStream
from ..kernels.coded_reduce import coded_combine_call
from ..obs.timing import CompileWatch, block
from ..obs.trace import span as _obs_span
from ..optim import adamw_init, adamw_update, cosine_schedule
from ..runtime.engine import ClusterEngine, FastestK, _policy_k_min

__all__ = ["TrainerConfig", "TrainProblem", "build_coded_train_step",
           "CodedTrainer", "run_coded_sgd"]


@dataclasses.dataclass
class TrainerConfig:
    """Loop configuration (canonical home; ``train.trainer`` re-exports)."""
    m_workers: int = 8            # coded-DP worker shards
    beta: int = 2                 # code redundancy degree
    wait_k: int = 6               # fastest-k the master waits for
    rows_per_worker: int = 1      # sequences per data GROUP (per slot)
    seq_len: int = 128
    steps: int = 100
    lr: float = 3e-4
    warmup: int = 20
    seed: int = 0
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0
    log_every: int = 10
    uncoded: bool = False         # baseline: no redundancy (beta=1)
    code: Optional[str] = None    # gradient code name; None -> frc/uncoded


@dataclasses.dataclass(frozen=True)
class TrainProblem:
    """The ``ProblemSpec`` analogue for ``train``-kind cells: which LM to
    train on the synthetic token stream (experiments/spec.py builds one per
    ``ProblemAxis(kind='train')``)."""
    arch: str = "deepseek-7b"
    preset: str = "smoke"         # "smoke" | "100m"
    seq_len: int = 64
    rows_per_worker: int = 1
    vocab: int = 512

    def build_cfg(self) -> ArchConfig:
        from ..configs import ARCHS
        base = ARCHS[self.arch]
        if self.preset == "100m":
            # ~100M params: 12L x 768, tied embeddings (examples/train_lm.py)
            return base.with_overrides(
                n_layers=12, d_model=768, n_heads=12, n_kv=12, d_ff=2048,
                vocab=16384, head_dim=64, dtype="float32",
                param_dtype="float32", attn_chunk=256)
        if self.preset == "smoke":
            return base.smoke_variant().with_overrides(vocab=self.vocab)
        raise ValueError(f"unknown train preset '{self.preset}' "
                         f"(have: smoke, 100m)")


def build_coded_train_step(cfg: ArchConfig, lr_fn: Callable, *,
                           rows_per_group: int, num_groups: int,
                           weight_decay: float = 0.1,
                           z_loss_weight: float = 1e-3) -> Callable:
    """(params, opt_state, tokens, labels, coeff, decode) ->
    (params, opt_state, metrics).

    tokens/labels: (m, g, S) int32 — worker-major coded layout from
    ``GroupBatcher``; coeff: (m, g) f32 LOCAL combine coefficients
    (B[i, group_of_row]); decode: (m,) f32 decode weights c(A_t).

    The full-gradient estimate is  (1/num_groups) sum_i c_i grad_i  with
    grad_i the gradient of worker i's coefficient-weighted fixed-denominator
    CE — computed as one vmap over the worker axis and ONE fused
    ``coded_combine_call`` over the flattened (m, P_total) gradient block.
    Router aux losses ride along scaled by the mean local coefficient, so
    they pass through the same (unbiased) combine.
    """
    if cfg.n_patches or cfg.n_enc_layers:
        raise ValueError("coded-sgd covers token-only LMs (no patch/encoder "
                         "modalities in the coded worker layout)")
    from ..models import transformer as T

    def worker_loss(params, tokens, labels, coeff):
        # tokens/labels (g, S); coeff (g,) — one worker's shard
        logits, aux = T.forward(params, cfg, tokens)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        denom = float(rows_per_group * labels.shape[-1])
        ce = -(ll * coeff[:, None]).sum() / denom
        scale = coeff.mean()
        total = ce + scale * (
            cfg.router_aux_weight * aux.get("load_balance", 0.0)
            + z_loss_weight * aux.get("router_z", 0.0))
        return total, ce

    def step(params, opt_state, tokens, labels, coeff, decode):
        (losses_all, losses_ce), grads = jax.vmap(
            jax.value_and_grad(worker_loss, has_aux=True),
            in_axes=(None, 0, 0, 0))(params, tokens, labels, coeff)
        del losses_all
        m = tokens.shape[0]
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        flat = jnp.concatenate(
            [l.reshape(m, -1).astype(jnp.float32) for l in leaves], axis=1)
        combined = coded_combine_call(flat, decode) / num_groups
        out, off = [], 0
        for l in leaves:
            size = l[0].size
            out.append(combined[off:off + size].reshape(l.shape[1:])
                       .astype(l.dtype))
            off += size
        grads = jax.tree_util.tree_unflatten(treedef, out)
        loss = jnp.dot(decode, losses_ce) / num_groups
        lr = lr_fn(opt_state.count)
        params, opt_state, om = adamw_update(
            grads, opt_state, params, lr=lr, weight_decay=weight_decay)
        return params, opt_state, {"loss": loss, "lr": lr, **om}

    return step


class CodedTrainer:
    """Engine-driven coded training loop (DESIGN §15).

    Straggler/fault realization, active-set policy and wall-clock all come
    from one pre-sampled ``ClusterEngine`` schedule (so runs are resumable
    and bit-reproducible per engine seed); per-step host time is split into
    compile/execute via ``obs.timing.CompileWatch``; the realized schedule
    lands on the active obs recorder and is kept as ``last_schedule``.
    """

    def __init__(self, cfg: ArchConfig, tcfg: TrainerConfig,
                 engine: ClusterEngine, policy=None, degrade=None):
        self.cfg, self.tcfg = cfg, tcfg
        if engine.m != tcfg.m_workers:
            raise ValueError(f"engine has m={engine.m} workers but "
                             f"TrainerConfig.m_workers={tcfg.m_workers}")
        name = tcfg.code or ("uncoded" if tcfg.uncoded else "frc")
        beta = 1 if tcfg.uncoded else tcfg.beta
        self.code: GradientCode = make_code(name, tcfg.m_workers, beta=beta,
                                            seed=tcfg.seed)
        self.stream = TokenStream(cfg.vocab, seed=tcfg.seed)
        self.batcher = GroupBatcher(self.stream, self.code,
                                    tcfg.rows_per_worker, tcfg.seq_len,
                                    seed=tcfg.seed)
        self.engine = engine
        self.policy = policy if policy is not None else FastestK(tcfg.wait_k)
        if degrade is not None and degrade.mode == "hold":
            raise ValueError("coded-sgd supports renormalize/backoff degrade "
                             "only (the decode weights renormalize over the "
                             "active set by construction; see DESIGN.md §15)")
        self.degrade = degrade
        lr_fn = cosine_schedule(tcfg.lr, tcfg.warmup, tcfg.steps)
        self._step = jax.jit(build_coded_train_step(
            cfg, lr_fn, rows_per_group=tcfg.rows_per_worker,
            num_groups=self.code.num_groups))
        self.last_schedule = None

    def init_state(self, key=None):
        from ..models import transformer as T
        key = key if key is not None else jax.random.key(self.tcfg.seed)
        params = T.init_params(self.cfg, key)
        opt = adamw_init(params, dtype=jnp.dtype(self.cfg.optstate_dtype))
        return params, opt

    def run(self, params=None, opt=None, callback: Optional[Callable] = None):
        if params is None:
            params, opt = self.init_state()
        tc = self.tcfg
        sched = self.engine.sample_schedule(tc.steps, self.policy,
                                            degrade=self.degrade)
        self.last_schedule = sched
        history = []
        with _obs_span("train:coded", code=self.code.codename,
                       steps=tc.steps, m=tc.m_workers):
            for t in range(tc.steps):
                code_t = self.code.at_step(t)
                tokens, labels, coeff = self.batcher.next_batch(code_t)
                mask = np.asarray(sched.masks[t])
                decode = code_t.decode_weights(mask)
                with CompileWatch() as cw:
                    params, opt, metrics = block(self._step(
                        params, opt, jnp.asarray(tokens),
                        jnp.asarray(labels), jnp.asarray(coeff),
                        jnp.asarray(decode)))
                rec = {"step": t, "loss": float(metrics["loss"]),
                       "grad_norm": float(metrics["grad_norm"]),
                       "sim_time_s": float(sched.times[t]),
                       "active": int((mask > 0).sum()),
                       "exact": bool(code_t.decode_exact_possible(mask)),
                       "host_s": cw.total_s, "compile_s": cw.compile_s,
                       "execute_s": cw.execute_s, "compiles": cw.compiles}
                history.append(rec)
                if callback:
                    callback(rec)
                if tc.log_every and t % tc.log_every == 0:
                    print(f"step {t:5d} loss {rec['loss']:.4f} "
                          f"gnorm {rec['grad_norm']:.3f} "
                          f"active {rec['active']}/{tc.m_workers} "
                          f"simtime {rec['sim_time_s']:.1f}s", flush=True)
                if (tc.checkpoint_dir and tc.checkpoint_every
                        and (t + 1) % tc.checkpoint_every == 0):
                    from ..checkpoint import save
                    save(tc.checkpoint_dir, t + 1, (params, opt))
        return params, opt, history


def run_coded_sgd(spec: TrainProblem, engine: ClusterEngine, *,
                  steps: int = 100, **cfg):
    """Strategy-interface adapter: one coded-SGD run as a ``RunResult``
    whose times axis is the engine's simulated wall-clock.

    cfg keys: policy (ActiveSetPolicy), k (FastestK shorthand), code
    (gradient code name), beta, lr, warmup, log_every, seed, degrade
    (parsed ``DegradePolicy``), checkpoint_dir/checkpoint_every.  Unknown
    keys raise ``ValueError`` (the executor's skip path).
    """
    from ..runtime.strategies import RunResult, _fault_meta, _resolve_degrade

    policy = cfg.pop("policy", None)
    k = cfg.pop("k", None)
    if policy is None:
        policy = FastestK(k if k is not None else max(1, (3 * engine.m) // 4))
    degrade = _resolve_degrade(policy, cfg)
    code = cfg.pop("code", None) or "frc"
    beta = int(cfg.pop("beta", 2))
    tcfg = TrainerConfig(
        m_workers=engine.m, beta=beta, wait_k=_policy_k_min(policy),
        rows_per_worker=spec.rows_per_worker, seq_len=spec.seq_len,
        steps=steps, lr=float(cfg.pop("lr", 3e-3)),
        warmup=int(cfg.pop("warmup", min(10, max(1, steps // 5)))),
        seed=int(cfg.pop("seed", engine.seed)),
        checkpoint_dir=cfg.pop("checkpoint_dir", None),
        checkpoint_every=int(cfg.pop("checkpoint_every", 0)),
        log_every=int(cfg.pop("log_every", 0)),
        uncoded=(str(code).lower() in ("uncoded", "none")), code=str(code))
    if cfg:
        raise ValueError(f"unknown coded-sgd config keys {sorted(cfg)}")
    trainer = CodedTrainer(spec.build_cfg(), tcfg, engine, policy=policy,
                           degrade=degrade)
    _, _, hist = trainer.run()
    sched = trainer.last_schedule
    meta = {"arch": spec.arch, "preset": spec.preset,
            "code": trainer.code.codename, "beta": trainer.code.beta
            if hasattr(trainer.code, "beta") else beta,
            "policy": type(policy).__name__,
            "seq_len": spec.seq_len, "rows_per_worker": spec.rows_per_worker,
            "mean_active": float(np.mean([r["active"] for r in hist])),
            "exact_fraction": float(np.mean([r["exact"] for r in hist])),
            "host_s": float(sum(r["host_s"] for r in hist)),
            "compile_s": float(sum(r["compile_s"] for r in hist)),
            "compiles": int(sum(r["compiles"] for r in hist)),
            **_fault_meta(engine, policy, degrade, sched.masks)}
    return RunResult(
        strategy="coded-sgd",
        times=np.asarray([r["sim_time_s"] for r in hist]),
        objective=np.asarray([r["loss"] for r in hist]),
        w=None, meta=meta, schedule=sched)
