"""Training loop with coded data parallelism + straggler simulation.

The host-side loop per iteration (mirrors paper Algorithm 1):
  1. sample per-worker delays from the configured DelayModel,
  2. take the fastest-k active set A_t, build the erasure mask,
  3. fetch the FRC-coded batch + decode weights from the data pipeline,
  4. run the jitted coded train step (masked, rescaled gradient),
  5. account simulated wall-clock as the k-th order statistic.

Runs unsharded on CPU (tests/examples) or under a mesh via pjit shardings.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.gradient_coding import FRCode, make_frc
from ..core.straggler import DelayModel, constant_delays, fastest_k, \
    active_mask, WallClock
from ..data.pipeline import CodedBatcher, TokenStream
from ..optim import adamw_init, cosine_schedule
from .steps import build_train_step

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    m_workers: int = 8            # coded-DP worker shards
    beta: int = 2                 # FRC replication factor
    wait_k: int = 6               # fastest-k the master waits for
    rows_per_worker: int = 1
    seq_len: int = 128
    steps: int = 100
    lr: float = 3e-4
    warmup: int = 20
    seed: int = 0
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0
    log_every: int = 10
    uncoded: bool = False         # baseline: no redundancy (beta=1)


class Trainer:
    def __init__(self, cfg: ArchConfig, tcfg: TrainerConfig,
                 delay_model: Optional[DelayModel] = None):
        self.cfg, self.tcfg = cfg, tcfg
        beta = 1 if tcfg.uncoded else tcfg.beta
        self.code: FRCode = make_frc(tcfg.m_workers, beta)
        self.stream = TokenStream(cfg.vocab, seed=tcfg.seed)
        self.batcher = CodedBatcher(self.stream, self.code,
                                    tcfg.rows_per_worker, tcfg.seq_len,
                                    seed=tcfg.seed)
        self.delay_model = delay_model or constant_delays(0.0)
        self.rng = np.random.default_rng(tcfg.seed)
        lr_fn = cosine_schedule(tcfg.lr, tcfg.warmup, tcfg.steps)
        self._step = jax.jit(build_train_step(cfg, lr_fn))
        self.clock = WallClock(compute_time=0.05)

    def init_state(self, key=None):
        from ..models import transformer as T
        key = key if key is not None else jax.random.key(self.tcfg.seed)
        params = T.init_params(self.cfg, key)
        opt = adamw_init(params, dtype=jnp.dtype(self.cfg.optstate_dtype))
        return params, opt

    def run(self, params=None, opt=None, callback: Optional[Callable] = None):
        if params is None:
            params, opt = self.init_state()
        tc = self.tcfg
        history = []
        for t in range(tc.steps):
            delays = self.delay_model(self.rng, tc.m_workers)
            A = fastest_k(delays, tc.wait_k)
            mask = active_mask(tc.m_workers, A)
            tokens, labels, weights = self.batcher.next_batch(mask)
            batch = {"tokens": jnp.asarray(tokens),
                     "labels": jnp.asarray(labels),
                     "weights": jnp.asarray(weights)}
            params, opt, metrics = self._step(params, opt, batch)
            elapsed = self.clock.tick(delays, tc.wait_k)
            rec = {"step": t, "loss": float(metrics["loss"]),
                   "grad_norm": float(metrics["grad_norm"]),
                   "sim_time_s": elapsed}
            history.append(rec)
            if callback:
                callback(rec)
            if tc.log_every and t % tc.log_every == 0:
                print(f"step {t:5d} loss {rec['loss']:.4f} "
                      f"gnorm {rec['grad_norm']:.3f} "
                      f"simtime {elapsed:.1f}s", flush=True)
            if (tc.checkpoint_dir and tc.checkpoint_every
                    and (t + 1) % tc.checkpoint_every == 0):
                from ..checkpoint import save
                save(tc.checkpoint_dir, t + 1, (params, opt))
        return params, opt, history
