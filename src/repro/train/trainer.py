"""Legacy-facing trainer API, now a thin adapter over ``train.coded``.

Through PR 9 this module owned a self-contained loop: it sampled its own
per-step delays from ``core.straggler``, took fastest-k, and accounted
wall-clock with a private ``WallClock`` — a parallel universe to the
``ClusterEngine`` every other strategy runs on.  DESIGN §15's migration
table maps the old loop onto the new subsystem:

    legacy (PR 0-9)                     now
    ---------------------------------   ----------------------------------
    core.straggler delay sampling       ClusterEngine.sample_schedule
    fastest_k + active_mask per step    ActiveSetPolicy (FastestK(wait_k))
    WallClock.tick k-th order stat      Schedule.times (engine-accounted)
    CodedBatcher weight folding         GroupBatcher + code.decode_weights
    lm_loss weight-normalized CE        fixed-denominator CE (exact decode)
    no faults / no obs / no store       --faults, CompileWatch, runstore

``Trainer(cfg, tcfg, delay_model=...)`` keeps the historical signature for
tests/examples: it builds the engine + policy from the config and defers to
:class:`repro.train.coded.CodedTrainer` (same ``run()`` return shape; the
history records additionally carry active/exact/compile-split fields).
"""
from __future__ import annotations

from typing import Optional

from ..configs.base import ArchConfig
from ..core.straggler import DelayModel, constant_delays
from ..runtime.engine import ClusterEngine, FastestK
from .coded import CodedTrainer, TrainerConfig

__all__ = ["TrainerConfig", "Trainer"]


class Trainer(CodedTrainer):
    """Back-compat constructor: delay model in, engine-driven loop out."""

    def __init__(self, cfg: ArchConfig, tcfg: TrainerConfig,
                 delay_model: Optional[DelayModel] = None):
        engine = ClusterEngine(delay_model or constant_delays(0.0),
                               tcfg.m_workers, compute_time=0.05,
                               seed=tcfg.seed)
        super().__init__(cfg, tcfg, engine,
                         policy=FastestK(tcfg.wait_k))
