"""The ONE clock/blocking discipline (DESIGN.md §11).

Every timed region in the repo — benchmarks, the experiment executor, the
obs span recorder — goes through these helpers so numbers are comparable:

  * :func:`block`      — ``jax.block_until_ready`` on EVERY output leaf of a
    pytree (blocking only the first leaf lets later dispatches overlap the
    clock and under-reports);
  * :func:`time_us`    — mean microseconds per call, blocking INSIDE the
    timed loop (ported from ``benchmarks/common.py``, which now re-exports
    these);
  * :class:`CompileWatch` — splits jit compile time out of a timed region
    via ``jax.monitoring``'s compile-duration events, so ``execute_s`` never
    silently includes a retrace/recompile and cache misses are countable.
"""
from __future__ import annotations

import time

__all__ = ["block", "time_us", "emit", "CompileWatch"]


def block(out):
    """``jax.block_until_ready`` on every leaf of ``out``; no-op for host
    values (and for environments without jax)."""
    try:
        import jax
        return jax.block_until_ready(out)
    except Exception:
        return out


def time_us(fn, *args, iters: int = 5, warmup: int = 1, **kw) -> float:
    """Mean microseconds per call; blocks on device outputs INSIDE the timed
    loop (blocking only after the final call lets earlier dispatches overlap
    and under-reports per-iteration time)."""
    for _ in range(warmup):
        block(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(iters):
        block(fn(*args, **kw))
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """One benchmark CSV line on stdout (shared by every ``benchmarks.*``)."""
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


# ---------------------------------------------------------------------------
# Compile-time accounting
# ---------------------------------------------------------------------------

# jax.monitoring duration events that make up one jit compilation; the
# backend_compile event fires exactly once per XLA compilation, so it doubles
# as the recompile counter.
_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_COMPILE_KEYS = (
    "/jax/core/compile/jaxpr_trace_duration",
    "/jax/core/compile/jaxpr_to_mlir_module_duration",
    _COMPILE_EVENT,
)

_STATE = {"secs": 0.0, "compiles": 0, "registered": False, "available": True}


def _on_event(event: str, duration_secs: float, **kw) -> None:
    if event in _COMPILE_KEYS:
        _STATE["secs"] += float(duration_secs)
        if event == _COMPILE_EVENT:
            _STATE["compiles"] += 1


def _ensure_listener() -> bool:
    """Register the (process-global, idempotent) compile listener."""
    if _STATE["registered"]:
        return True
    if not _STATE["available"]:
        return False
    try:
        import jax.monitoring
        jax.monitoring.register_event_duration_secs_listener(_on_event)
        _STATE["registered"] = True
        return True
    except Exception:
        # old/stripped jax: compile_s degrades to 0 rather than breaking
        _STATE["available"] = False
        return False


class CompileWatch:
    """Measure a region, splitting jit compile time from execute time.

    ``with CompileWatch() as cw: ...`` leaves ``cw.total_s`` (wall),
    ``cw.compile_s`` (trace + lower + XLA compile seconds inside the
    region), ``cw.execute_s`` (the remainder) and ``cw.compiles`` (number
    of fresh XLA compilations — 0 means every dispatch hit the jit cache).
    The split comes from ``jax.monitoring`` events, so no warm-up call or
    AOT ``lower().compile()`` is needed and module-level jit caches keep
    working as the cross-cell executable cache.
    """

    def __enter__(self) -> "CompileWatch":
        _ensure_listener()
        self._s0 = _STATE["secs"]
        self._n0 = _STATE["compiles"]
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.total_s = time.perf_counter() - self._t0
        # clamp: monitoring durations are measured independently of our wall
        # clock, so rounding can nudge the sum past total on tiny regions
        self.compile_s = min(max(_STATE["secs"] - self._s0, 0.0),
                             self.total_s)
        self.compiles = _STATE["compiles"] - self._n0
        self.execute_s = max(self.total_s - self.compile_s, 0.0)
