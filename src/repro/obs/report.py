"""``python -m repro.obs.report`` — text report over a saved trace.

Renders the two views the paper's tail-latency story needs from a
``TraceRecorder`` JSONL export (``repro.experiments.run --trace``):

  * **phase breakdown** — host-clock span totals by name (calls, total
    seconds, mean, share), so "where does per-step time go" (encode vs
    solve vs sampling) is one glance;
  * **straggler timeline** — per (cell, realization) lane group: per-worker
    miss counts with a bar chart, active-set-size stats, and the first
    iterations as an ASCII lane diagram (``#`` active, ``.`` erased);
  * **async summary** — staleness histogram + drop/clamp counts for
    per-arrival cells.

    PYTHONPATH=src python -m repro.obs.report runs/exp/trace.jsonl \\
        [--max-steps 24] [--cell SUBSTR]
"""
from __future__ import annotations

import argparse
from collections import defaultdict
from typing import Sequence

import numpy as np

from .trace import TraceRecorder

__all__ = ["phase_breakdown", "render_report", "main"]

_BAR = 28


def _bar(frac: float, width: int = _BAR) -> str:
    n = int(round(max(0.0, min(1.0, frac)) * width))
    return "#" * n + "." * (width - n)


def phase_breakdown(events) -> list[tuple]:
    """Aggregate span events by name -> sorted [(name, calls, total_s,
    mean_s, share)] rows (share of the summed span time; spans nest, so
    shares can exceed 1 in total)."""
    agg: dict = defaultdict(lambda: [0, 0.0])
    for ev in events:
        if ev.kind == "span":
            agg[ev.name][0] += 1
            agg[ev.name][1] += ev.dur
    total = sum(v[1] for v in agg.values()) or 1.0
    rows = [(name, calls, secs, secs / calls, secs / total)
            for name, (calls, secs) in agg.items()]
    return sorted(rows, key=lambda r: -r[2])


def _lane_groups(events) -> dict:
    """(cell, realization) -> {"iter": [...], "worker": [...], ...}."""
    groups: dict = defaultdict(lambda: defaultdict(list))
    for ev in events:
        if ev.kind in ("iter", "worker", "update", "instant"):
            groups[(ev.cell, ev.realization)][ev.kind].append(ev)
    return groups


def _render_sync_group(out, iters, workers, max_steps: int) -> None:
    m = 1 + max(int(ev.lane.split(":", 1)[1]) for ev in workers)
    steps = sorted({ev.step for ev in iters})
    active = np.zeros((len(steps), m), dtype=bool)
    index = {t: j for j, t in enumerate(steps)}
    for ev in workers:
        active[index[ev.step], int(ev.lane.split(":", 1)[1])] = \
            bool(ev.args.get("active", True))
    miss = 1.0 - active.mean(axis=0)
    sizes = active.sum(axis=1)
    durs = [ev.dur for ev in iters]
    out.append(f"  iterations={len(steps)} workers={m} "
               f"active_size mean={sizes.mean():.2f} "
               f"min={sizes.min()} max={sizes.max()}")
    out.append(f"  step latency s: p50={np.percentile(durs, 50):.4f} "
               f"p95={np.percentile(durs, 95):.4f} "
               f"p99={np.percentile(durs, 99):.4f}")
    out.append("  per-worker miss-rate:")
    for i in range(m):
        out.append(f"    worker {i:3d} {_bar(miss[i])} {miss[i]:6.1%}")
    shown = steps[:max_steps]
    out.append(f"  lanes (first {len(shown)} iterations; # active, "
               f". erased):")
    for t in shown:
        row = "".join("#" if active[index[t], i] else "."
                      for i in range(m))
        out.append(f"    iter {t:4d} |{row}|")


def _render_async_group(out, updates, instants) -> None:
    stale = np.asarray([ev.args.get("staleness", 0) for ev in updates])
    out.append(f"  updates={stale.size} mean_staleness={stale.mean():.2f} "
               f"max={stale.max()}")
    vals, cnts = np.unique(stale, return_counts=True)
    peak = cnts.max()
    out.append("  staleness histogram:")
    for v, c in zip(vals, cnts):
        out.append(f"    tau={int(v):3d} {_bar(c / peak)} {int(c)}")
    for ev in instants:
        if ev.name == "async-summary":
            out.append(f"  dropped={ev.args.get('dropped', 0)} "
                       f"staleness_clamped="
                       f"{ev.args.get('staleness_clamped', 0)}")


def render_report(rec: TraceRecorder, *, max_steps: int = 24,
                  cell: str | None = None) -> str:
    """The full text report for a loaded trace."""
    events = rec.events()
    out: list[str] = []
    if rec.meta:
        out.append(f"trace meta: {rec.meta}")
    rows = phase_breakdown(events)
    if rows:
        out.append("")
        out.append("phase breakdown (host spans):")
        out.append(f"  {'phase':24s} {'calls':>6s} {'total_s':>10s} "
                   f"{'mean_ms':>9s} {'share':>7s}")
        for name, calls, secs, mean, share in rows:
            out.append(f"  {name:24s} {calls:6d} {secs:10.4f} "
                       f"{mean * 1e3:9.3f} {share:7.1%}")
    for (cell_name, r), kinds in sorted(
            _lane_groups(events).items(),
            key=lambda kv: (str(kv[0][0]), kv[0][1])):
        if cell is not None and cell not in str(cell_name):
            continue
        out.append("")
        out.append(f"straggler timeline — cell={cell_name or 'run'} "
                   f"realization={r}")
        if kinds.get("iter"):
            _render_sync_group(out, kinds["iter"], kinds.get("worker", []),
                               max_steps)
        if kinds.get("update"):
            _render_async_group(out, kinds["update"],
                                kinds.get("instant", []))
    if len(out) <= 1 and not rows:
        out.append("(trace contains no span or simulation events)")
    return "\n".join(out)


def main(argv: Sequence[str] | None = None) -> str:
    ap = argparse.ArgumentParser(
        prog="repro.obs.report",
        description="straggler-timeline + phase-breakdown report from a "
                    "saved obs trace (JSONL)")
    ap.add_argument("trace", help="path to a TraceRecorder JSONL export")
    ap.add_argument("--max-steps", type=int, default=24,
                    help="iterations to draw per lane diagram")
    ap.add_argument("--cell", default=None,
                    help="only render timelines whose cell label contains "
                         "this substring")
    args = ap.parse_args(argv)
    text = render_report(TraceRecorder.load(args.trace),
                         max_steps=args.max_steps, cell=args.cell)
    print(text)
    return text


if __name__ == "__main__":
    main()
