"""``python -m repro.obs.report`` — text report over a saved trace.

Renders the two views the paper's tail-latency story needs from a
``TraceRecorder`` JSONL export (``repro.experiments.run --trace``):

  * **phase breakdown** — host-clock span totals by name (calls, total
    seconds, mean, share), so "where does per-step time go" (encode vs
    solve vs sampling) is one glance;
  * **straggler timeline** — per (cell, realization) lane group: per-worker
    miss counts with a bar chart, active-set-size stats, and the first
    iterations as an ASCII lane diagram (``#`` active, ``.`` erased);
  * **async summary** — staleness histogram + drop/clamp counts for
    per-arrival cells;
  * **fault timeline** — for fault-injected runs (``--faults``): per-kind
    event counts (crash / blackout / corrupt), the failed-entry share of
    the (iteration, worker) grid, and the first fault events in time
    order.

  * ``--html OUT.html`` — the same views as one self-contained HTML page
    (inline CSS, no external assets): phase-breakdown table, per-worker
    miss-rate bar charts, lane diagrams, staleness histograms — plus a
    cross-run comparison table when ``--compare RUN_A RUN_B`` references
    two stored runs (see ``repro.obs.runstore``).

    PYTHONPATH=src python -m repro.obs.report runs/exp/trace.jsonl \\
        [--max-steps 24] [--cell SUBSTR] [--html report.html] \\
        [--compare latest latest~1]
"""
from __future__ import annotations

import argparse
import html as _html
import os
from collections import defaultdict
from typing import Sequence

import numpy as np

from .trace import TraceRecorder

__all__ = ["phase_breakdown", "render_report", "render_html_report",
           "main"]

_BAR = 28


def _bar(frac: float, width: int = _BAR) -> str:
    n = int(round(max(0.0, min(1.0, frac)) * width))
    return "#" * n + "." * (width - n)


def phase_breakdown(events) -> list[tuple]:
    """Aggregate span events by name -> sorted [(name, calls, total_s,
    mean_s, share)] rows (share of the summed span time; spans nest, so
    shares can exceed 1 in total)."""
    agg: dict = defaultdict(lambda: [0, 0.0])
    for ev in events:
        if ev.kind == "span":
            agg[ev.name][0] += 1
            agg[ev.name][1] += ev.dur
    total = sum(v[1] for v in agg.values()) or 1.0
    rows = [(name, calls, secs, secs / calls, secs / total)
            for name, (calls, secs) in agg.items()]
    return sorted(rows, key=lambda r: -r[2])


def _lane_groups(events) -> dict:
    """(cell, realization) -> {"iter": [...], "worker": [...], ...}."""
    groups: dict = defaultdict(lambda: defaultdict(list))
    for ev in events:
        if ev.kind in ("iter", "worker", "update", "instant"):
            groups[(ev.cell, ev.realization)][ev.kind].append(ev)
    return groups


def _render_sync_group(out, iters, workers, max_steps: int) -> None:
    m = 1 + max(int(ev.lane.split(":", 1)[1]) for ev in workers)
    steps = sorted({ev.step for ev in iters})
    active = np.zeros((len(steps), m), dtype=bool)
    index = {t: j for j, t in enumerate(steps)}
    for ev in workers:
        active[index[ev.step], int(ev.lane.split(":", 1)[1])] = \
            bool(ev.args.get("active", True))
    miss = 1.0 - active.mean(axis=0)
    sizes = active.sum(axis=1)
    durs = [ev.dur for ev in iters]
    out.append(f"  iterations={len(steps)} workers={m} "
               f"active_size mean={sizes.mean():.2f} "
               f"min={sizes.min()} max={sizes.max()}")
    out.append(f"  step latency s: p50={np.percentile(durs, 50):.4f} "
               f"p95={np.percentile(durs, 95):.4f} "
               f"p99={np.percentile(durs, 99):.4f}")
    out.append("  per-worker miss-rate:")
    for i in range(m):
        out.append(f"    worker {i:3d} {_bar(miss[i])} {miss[i]:6.1%}")
    shown = steps[:max_steps]
    out.append(f"  lanes (first {len(shown)} iterations; # active, "
               f". erased):")
    for t in shown:
        row = "".join("#" if active[index[t], i] else "."
                      for i in range(m))
        out.append(f"    iter {t:4d} |{row}|")


def _fault_summary(workers, instants):
    """Fault view of one lane group: per-kind event counts, the event
    timeline, and the failed-entry share of the (iteration, worker) grid.
    Everything is empty when the trace carries no fault lane."""
    events = [ev for ev in instants if ev.name.startswith("fault:")]
    counts: dict = {}
    for ev in events:
        kind = ev.args.get("fault", ev.name.split(":", 1)[1])
        counts[kind] = counts.get(kind, 0) + 1
    frac: dict = {}
    if workers:
        by_kind: dict = {}
        for ev in workers:
            code = ev.args.get("failed")
            if code is not None:
                by_kind[code] = by_kind.get(code, 0) + 1
        frac = {k: v / len(workers) for k, v in sorted(by_kind.items())}
    return counts, events, frac


def _render_fault_group(out, workers, instants, max_events: int = 12) -> None:
    counts, events, frac = _fault_summary(workers, instants)
    if not counts and not frac:
        return
    head = " ".join(f"{k}={v}" for k, v in sorted(counts.items()))
    out.append(f"  faults: {head or '(failed codes only)'}")
    if frac:
        out.append("  failed share of (iteration, worker) grid: "
                   + " ".join(f"{k}={v:.1%}" for k, v in frac.items()))
    for ev in sorted(events, key=lambda e: e.ts)[:max_events]:
        dur = ev.args.get("duration_s")
        tail = f" dur={dur:.2f}s" if dur else ""
        out.append(f"    t={ev.ts:8.3f} {ev.lane:10s} "
                   f"{ev.args.get('fault', ev.name)}{tail}")
    if len(events) > max_events:
        out.append(f"    ... {len(events) - max_events} more fault events")


def _render_async_group(out, updates, instants) -> None:
    stale = np.asarray([ev.args.get("staleness", 0) for ev in updates])
    out.append(f"  updates={stale.size} mean_staleness={stale.mean():.2f} "
               f"max={stale.max()}")
    vals, cnts = np.unique(stale, return_counts=True)
    peak = cnts.max()
    out.append("  staleness histogram:")
    for v, c in zip(vals, cnts):
        out.append(f"    tau={int(v):3d} {_bar(c / peak)} {int(c)}")
    for ev in instants:
        if ev.name == "async-summary":
            out.append(f"  dropped={ev.args.get('dropped', 0)} "
                       f"staleness_clamped="
                       f"{ev.args.get('staleness_clamped', 0)}")


def render_report(rec: TraceRecorder, *, max_steps: int = 24,
                  cell: str | None = None) -> str:
    """The full text report for a loaded trace."""
    events = rec.events()
    out: list[str] = []
    if rec.meta:
        out.append(f"trace meta: {rec.meta}")
    rows = phase_breakdown(events)
    if rows:
        out.append("")
        out.append("phase breakdown (host spans):")
        out.append(f"  {'phase':24s} {'calls':>6s} {'total_s':>10s} "
                   f"{'mean_ms':>9s} {'share':>7s}")
        for name, calls, secs, mean, share in rows:
            out.append(f"  {name:24s} {calls:6d} {secs:10.4f} "
                       f"{mean * 1e3:9.3f} {share:7.1%}")
    for (cell_name, r), kinds in sorted(
            _lane_groups(events).items(),
            key=lambda kv: (str(kv[0][0]), kv[0][1])):
        if cell is not None and cell not in str(cell_name):
            continue
        out.append("")
        out.append(f"straggler timeline — cell={cell_name or 'run'} "
                   f"realization={r}")
        if kinds.get("iter"):
            _render_sync_group(out, kinds["iter"], kinds.get("worker", []),
                               max_steps)
        if kinds.get("update"):
            _render_async_group(out, kinds["update"],
                                kinds.get("instant", []))
        _render_fault_group(out, kinds.get("worker", []),
                            kinds.get("instant", []))
    if len(out) <= 1 and not rows:
        out.append("(trace contains no span or simulation events)")
    return "\n".join(out)


# ---------------------------------------------------------------------------
# HTML export
# ---------------------------------------------------------------------------

def _html_bar(frac: float, *, miss: bool = False,
              width: int = 160) -> str:
    px = int(round(max(0.0, min(1.0, frac)) * width))
    cls = "bar miss" if miss else "bar"
    return f"<span class='{cls}' style='width:{px}px'></span>"


def _html_phase_section(rows) -> str:
    body = "".join(
        f"<tr><td>{_html.escape(name)}</td><td>{calls}</td>"
        f"<td>{secs:.4f}</td><td>{mean * 1e3:.3f}</td>"
        f"<td>{_html_bar(share)} {share:.1%}</td></tr>"
        for name, calls, secs, mean, share in rows)
    return ("<h2>phase breakdown (host spans)</h2>"
            "<table><tr><th>phase</th><th>calls</th><th>total_s</th>"
            "<th>mean_ms</th><th>share</th></tr>" + body + "</table>")


def _html_sync_group(iters, workers, max_steps: int) -> str:
    m = 1 + max(int(ev.lane.split(":", 1)[1]) for ev in workers)
    steps = sorted({ev.step for ev in iters})
    active = np.zeros((len(steps), m), dtype=bool)
    index = {t: j for j, t in enumerate(steps)}
    for ev in workers:
        active[index[ev.step], int(ev.lane.split(":", 1)[1])] = \
            bool(ev.args.get("active", True))
    miss = 1.0 - active.mean(axis=0)
    sizes = active.sum(axis=1)
    durs = [ev.dur for ev in iters]
    out = [f"<p>iterations={len(steps)} workers={m} "
           f"active_size mean={sizes.mean():.2f} min={sizes.min()} "
           f"max={sizes.max()} &middot; step latency s: "
           f"p50={np.percentile(durs, 50):.4f} "
           f"p95={np.percentile(durs, 95):.4f} "
           f"p99={np.percentile(durs, 99):.4f}</p>",
           "<table><tr><th>worker</th><th>miss-rate</th></tr>"]
    out += [f"<tr><td>{i}</td><td>{_html_bar(miss[i], miss=True)} "
            f"{miss[i]:.1%}</td></tr>" for i in range(m)]
    out.append("</table>")
    shown = steps[:max_steps]
    lanes = "\n".join(
        f"iter {t:4d} |" + "".join("#" if active[index[t], i] else "."
                                   for i in range(m)) + "|"
        for t in shown)
    out.append(f"<p>lanes (first {len(shown)} iterations; # active, "
               f". erased):</p><pre class='lanes'>{lanes}</pre>")
    return "".join(out)


def _html_async_group(updates, instants) -> str:
    stale = np.asarray([ev.args.get("staleness", 0) for ev in updates])
    vals, cnts = np.unique(stale, return_counts=True)
    peak = cnts.max()
    out = [f"<p>updates={stale.size} "
           f"mean_staleness={stale.mean():.2f} max={stale.max()}</p>",
           "<table><tr><th>staleness &tau;</th><th>count</th></tr>"]
    out += [f"<tr><td>{int(v)}</td><td>{_html_bar(c / peak)} {int(c)}"
            f"</td></tr>" for v, c in zip(vals, cnts)]
    out.append("</table>")
    for ev in instants:
        if ev.name == "async-summary":
            out.append(f"<p>dropped={ev.args.get('dropped', 0)} "
                       f"staleness_clamped="
                       f"{ev.args.get('staleness_clamped', 0)}</p>")
    return "".join(out)


def _html_fault_group(workers, instants, max_events: int = 12) -> str:
    counts, events, frac = _fault_summary(workers, instants)
    if not counts and not frac:
        return ""
    head = " ".join(f"{_html.escape(str(k))}={v}"
                    for k, v in sorted(counts.items()))
    out = [f"<p><b>faults:</b> {head or '(failed codes only)'}</p>"]
    if frac:
        out.append("<table><tr><th>failed code</th><th>share of "
                   "(iteration, worker) grid</th></tr>")
        out += [f"<tr><td>{_html.escape(str(k))}</td>"
                f"<td>{_html_bar(v, miss=True)} {v:.1%}</td></tr>"
                for k, v in frac.items()]
        out.append("</table>")
    if events:
        rows = "".join(
            f"<tr><td>{ev.ts:.3f}</td><td>{_html.escape(ev.lane)}</td>"
            f"<td>{_html.escape(str(ev.args.get('fault', ev.name)))}</td>"
            f"<td>{ev.args.get('duration_s', '')}</td></tr>"
            for ev in sorted(events, key=lambda e: e.ts)[:max_events])
        out.append("<table><tr><th>t (sim s)</th><th>lane</th>"
                   "<th>fault</th><th>duration_s</th></tr>"
                   + rows + "</table>")
        if len(events) > max_events:
            out.append(f"<p><small>... {len(events) - max_events} more "
                       f"fault events</small></p>")
    return "".join(out)


def render_html_report(rec: TraceRecorder, *, max_steps: int = 24,
                       cell: str | None = None,
                       extra_sections: list[str] | None = None) -> str:
    """One self-contained HTML page with the same views as the text
    report (plus optional pre-rendered extra sections, e.g. a cross-run
    comparison table from ``repro.obs.analyze``)."""
    from .analyze import render_html_page
    events = rec.events()
    sections: list[str] = []
    if rec.meta:
        sections.append(
            f"<p><small>trace meta: {_html.escape(str(rec.meta))}"
            f"</small></p>")
    rows = phase_breakdown(events)
    if rows:
        sections.append(_html_phase_section(rows))
    for (cell_name, r), kinds in sorted(
            _lane_groups(events).items(),
            key=lambda kv: (str(kv[0][0]), kv[0][1])):
        if cell is not None and cell not in str(cell_name):
            continue
        sections.append(f"<h2>straggler timeline — "
                        f"cell={_html.escape(str(cell_name or 'run'))} "
                        f"realization={r}</h2>")
        if kinds.get("iter"):
            sections.append(_html_sync_group(
                kinds["iter"], kinds.get("worker", []), max_steps))
        if kinds.get("update"):
            sections.append(_html_async_group(kinds["update"],
                                              kinds.get("instant", [])))
        fault_html = _html_fault_group(kinds.get("worker", []),
                                       kinds.get("instant", []))
        if fault_html:
            sections.append(fault_html)
    if not sections:
        sections.append("<p>(trace contains no span or simulation "
                        "events)</p>")
    sections.extend(extra_sections or [])
    return render_html_page("repro straggler report", sections)


def _compare_section(refs: list[str]) -> str:
    """Cross-run comparison table for two stored-run references."""
    from .analyze import diff_manifests
    from .runstore import default_store
    store = default_store()
    if store is None:
        raise SystemExit("--compare needs an enabled run store "
                         "(REPRO_RUNSTORE)")
    a, b = (store.resolve(r) for r in refs)
    rep = diff_manifests(a, b, a_label=a.get("run_id", refs[0]),
                         b_label=b.get("run_id", refs[1]))
    return rep.render_html_section()


def main(argv: Sequence[str] | None = None) -> str:
    ap = argparse.ArgumentParser(
        prog="repro.obs.report",
        description="straggler-timeline + phase-breakdown report from a "
                    "saved obs trace (JSONL)")
    ap.add_argument("trace", help="path to a TraceRecorder JSONL export")
    ap.add_argument("--max-steps", type=int, default=24,
                    help="iterations to draw per lane diagram")
    ap.add_argument("--cell", default=None,
                    help="only render timelines whose cell label contains "
                         "this substring")
    ap.add_argument("--html", default=None, metavar="OUT",
                    help="also write the report as one self-contained "
                         "HTML page")
    ap.add_argument("--compare", nargs=2, default=None,
                    metavar=("RUN_A", "RUN_B"),
                    help="embed a cross-run comparison table for two "
                         "stored-run references (HTML output only)")
    args = ap.parse_args(argv)
    rec = TraceRecorder.load(args.trace)
    text = render_report(rec, max_steps=args.max_steps, cell=args.cell)
    print(text)
    if args.html:
        extra = [_compare_section(args.compare)] if args.compare else None
        page = render_html_report(rec, max_steps=args.max_steps,
                                  cell=args.cell, extra_sections=extra)
        d = os.path.dirname(args.html)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.html, "w") as f:
            f.write(page)
        print(f"wrote html report to {args.html}")
    return text


if __name__ == "__main__":
    main()
