"""repro.obs — structured tracing, straggler metrics, profiling hooks.

The observability substrate under every execution layer (DESIGN.md §11):

  * :mod:`repro.obs.trace`   — :class:`TraceRecorder`: per-iteration
    straggler timelines from the ``ClusterEngine`` + host-clock phase spans,
    exported as JSONL and Chrome/Perfetto ``trace_event`` JSON;
  * :mod:`repro.obs.metrics` — counter/gauge/histogram registry + the
    per-cell summarizers (miss-rate, active-set distribution, step-latency
    percentiles, staleness histogram + clamp counts);
  * :mod:`repro.obs.timing`  — the ONE clock/blocking discipline
    (``block`` / ``time_us``) and :class:`CompileWatch`, which splits jit
    compile time out of execute time via ``jax.monitoring``;
  * :mod:`repro.obs.profile` — opt-in ``jax.profiler`` capture and
    device-memory high-water marks;
  * ``python -m repro.obs.report`` — text straggler-timeline /
    phase-breakdown reports from a saved trace.

Design rule: with no active recorder every hook is a single ``is None``
check — observability off is a zero-cost no-op path.
"""
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      async_metrics, cell_summary, clamp_async_event,
                      schedule_metrics)
from .profile import memory_high_water, memory_stats, profile_region
from .timing import CompileWatch, block, emit, time_us
from .trace import TraceEvent, TraceRecorder, current_recorder, span

__all__ = [
    "TraceEvent", "TraceRecorder", "current_recorder", "span",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "schedule_metrics", "async_metrics", "cell_summary",
    "clamp_async_event",
    "CompileWatch", "block", "time_us", "emit",
    "profile_region", "memory_stats", "memory_high_water",
]
