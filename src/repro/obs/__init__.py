"""repro.obs — structured tracing, straggler metrics, profiling hooks.

The observability substrate under every execution layer (DESIGN.md §11):

  * :mod:`repro.obs.trace`   — :class:`TraceRecorder`: per-iteration
    straggler timelines from the ``ClusterEngine`` + host-clock phase spans,
    exported as JSONL and Chrome/Perfetto ``trace_event`` JSON;
  * :mod:`repro.obs.metrics` — counter/gauge/histogram registry + the
    per-cell summarizers (miss-rate, active-set distribution, step-latency
    percentiles, staleness histogram + clamp counts);
  * :mod:`repro.obs.timing`  — the ONE clock/blocking discipline
    (``block`` / ``time_us``) and :class:`CompileWatch`, which splits jit
    compile time out of execute time via ``jax.monitoring``;
  * :mod:`repro.obs.profile` — opt-in ``jax.profiler`` capture and
    device-memory high-water marks;
  * :mod:`repro.obs.sketch`  — O(1)-memory streaming estimators (P²
    quantiles, EWMA, per-worker :class:`DelayTailEstimator` — the
    sensing interface for adaptive redundancy);
  * :mod:`repro.obs.runstore` — indexed run-manifest store (spec hash,
    git sha, backend, artifact paths) every execute/bench run records to;
  * ``python -m repro.obs.diff`` — cross-run regression gate: aligns two
    stored runs (or a bench json vs its committed baseline) cell-by-cell
    and exits non-zero on wall-clock/convergence regressions;
  * ``python -m repro.obs.report`` — text straggler-timeline /
    phase-breakdown reports from a saved trace, plus a self-contained
    ``--html`` export.

Design rule: with no active recorder every hook is a single ``is None``
check — observability off is a zero-cost no-op path.
"""
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      async_metrics, cell_summary, clamp_async_event,
                      fault_metrics, schedule_metrics)
from .profile import memory_high_water, memory_stats, profile_region
from .runstore import (RunStore, begin_experiment, completed_cells,
                       default_store, finish_experiment, provenance,
                       record_cell, record_experiment, runstore_enabled,
                       spec_hash)
from .sketch import DelayTailEstimator, Ewma, P2Quantile, QuantileSketch
from .timing import CompileWatch, block, emit, time_us
from .trace import TraceEvent, TraceRecorder, current_recorder, span

__all__ = [
    "TraceEvent", "TraceRecorder", "current_recorder", "span",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "schedule_metrics", "async_metrics", "fault_metrics", "cell_summary",
    "clamp_async_event",
    "P2Quantile", "QuantileSketch", "Ewma", "DelayTailEstimator",
    "RunStore", "default_store", "runstore_enabled", "provenance",
    "spec_hash", "record_experiment", "begin_experiment",
    "finish_experiment", "record_cell", "completed_cells",
    "CompileWatch", "block", "time_us", "emit",
    "profile_region", "memory_stats", "memory_high_water",
]
