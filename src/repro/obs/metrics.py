"""Lightweight metrics: counters/gauges/histograms + per-cell summaries.

Two layers (DESIGN.md §11):

  * a tiny process-local :class:`MetricsRegistry` (counter / gauge /
    histogram) for code that wants to count things as it goes — no
    background threads, no exporters, ``summary()`` renders the whole
    registry as a JSON-safe dict;
  * pure summarizers over the engine's realized artifacts —
    :func:`schedule_metrics` (per-worker miss-rate, active-set-size
    distribution, p50/p95/p99 step latency) and :func:`async_metrics`
    (staleness histogram, drop/clamp counts) — which
    ``repro.experiments.execute`` attaches to the canonical record as the
    ``obs`` key and ``write_metrics_csv`` flattens to the per-cell CSV.

Metric names are stable identifiers (the report CLI and tests key on
them): ``miss_rate``, ``active_size``, ``step_latency_s``, ``staleness``,
``staleness_clamped``, ``dropped``, ``compile_s``, ``execute_s``,
``compiles``.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "schedule_metrics", "async_metrics", "clamp_async_event",
    "cell_summary",
]


class Counter:
    """A monotonically increasing count."""

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """A last-write-wins scalar."""

    def __init__(self):
        self.value = None

    def set(self, v) -> None:
        self.value = v


class Histogram:
    """An exact-sample histogram (cells record at most a few thousand
    observations, so percentiles are computed from the raw samples instead
    of fixed buckets)."""

    def __init__(self):
        self._samples: list = []

    def observe(self, v) -> None:
        self._samples.append(float(v))

    def observe_many(self, vs) -> None:
        self._samples.extend(np.asarray(vs, dtype=float).ravel().tolist())

    @property
    def count(self) -> int:
        return len(self._samples)

    def summary(self, percentiles=(50, 95, 99)) -> dict:
        if not self._samples:
            return {"count": 0}
        a = np.asarray(self._samples)
        out = {"count": int(a.size), "mean": float(a.mean()),
               "min": float(a.min()), "max": float(a.max())}
        for q in percentiles:
            out[f"p{q}"] = float(np.percentile(a, q))
        return out

    def counts(self) -> dict:
        """Integer-bucket view ``{str(value): occurrences}`` — the natural
        rendering for discrete quantities (active-set sizes, staleness)."""
        vals, cnts = np.unique(np.asarray(self._samples, dtype=int),
                               return_counts=True)
        return {str(int(v)): int(c) for v, c in zip(vals, cnts)}


class MetricsRegistry:
    """Name -> metric map with one-line accessors; ``summary()`` is the
    JSON-safe snapshot every consumer (records, report CLI) reads."""

    def __init__(self):
        self._metrics: dict = {}

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls()
        elif not isinstance(m, cls):
            raise TypeError(f"metric '{name}' is {type(m).__name__}, "
                            f"not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def summary(self) -> dict:
        out = {}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Histogram):
                out[name] = m.summary()
            else:
                out[name] = m.value
        return out


# ---------------------------------------------------------------------------
# Engine-artifact summarizers
# ---------------------------------------------------------------------------

def schedule_metrics(schedules) -> dict:
    """Summarize realized synchronous ``Schedule``s (one or many — batched
    cells pass all R realizations, chunked workloads every sub-solve).

    Returns per-worker ``miss_rate`` (fraction of iterations worker i was
    erased), the ``active_size`` distribution, and per-iteration
    ``step_latency_s`` (commit-to-commit barrier time) percentiles.
    Schedules whose worker count differs from the first are skipped (a
    matrix cell never mixes cluster sizes).
    """
    schedules = [s for s in schedules if s is not None]
    if not schedules:
        return {}
    m = schedules[0].m
    masks = np.concatenate([np.asarray(s.masks, dtype=float)
                            for s in schedules if s.m == m], axis=0)
    lat = Histogram()
    active = Histogram()
    for s in schedules:
        if s.m != m:
            continue
        times = np.asarray(s.times, dtype=float)
        lat.observe_many(np.diff(times, prepend=0.0))
        active.observe_many(np.asarray(s.masks).sum(axis=1))
    miss = 1.0 - masks.mean(axis=0)
    return {
        "iterations": int(masks.shape[0]),
        "workers": int(m),
        "miss_rate": [float(x) for x in miss],
        "mean_miss_rate": float(miss.mean()),
        "max_miss_rate": float(miss.max()),
        "active_size": {**active.summary(), "hist": active.counts()},
        "step_latency_s": lat.summary(),
    }


def clamp_async_event(u: int, tau: int, rv: int, total: int) -> tuple:
    """Snap one async (update index, staleness, read_version) triple into
    range; returns ``(tau, rv, was_clamped)``.

    The engine's invariant is ``rv + tau == u`` with ``0 <= tau <= u`` and
    ``rv < total``; a hand-built or corrupted trace can violate it, which
    would silently wrap downstream ring buffers.  This is the ONE clamp
    rule, shared by the trace expander (``obs.trace``) and
    :func:`async_metrics` so the surfaced ``staleness_clamped`` count always
    matches the exported events.
    """
    if rv + tau != u or rv >= total or tau < 0:
        tau = min(max(tau, 0), u)
        return tau, u - tau, True
    return tau, rv, False


def async_metrics(traces) -> dict:
    """Summarize realized ``AsyncTrace``s: staleness histogram, per-arrival
    latency percentiles, dropped-gradient totals, and the count of events
    clamped at the trace boundary (see :func:`clamp_async_event`)."""
    traces = [t for t in traces if t is not None]
    if not traces:
        return {}
    stale = Histogram()
    lat = Histogram()
    dropped = 0
    clamped = 0
    for t in traces:
        staleness = np.asarray(t.staleness, dtype=int)
        reads = np.asarray(t.read_versions, dtype=int)
        U = staleness.shape[0]
        for u in range(U):
            tau, _, was = clamp_async_event(u, int(staleness[u]),
                                            int(reads[u]), U)
            stale.observe(tau)
            clamped += was
        lat.observe_many(np.diff(np.asarray(t.times, dtype=float),
                                 prepend=0.0))
        dropped += int(t.dropped)
    return {
        "updates": stale.count,
        "workers": int(traces[0].m),
        "staleness": {**stale.summary(), "hist": stale.counts()},
        "update_latency_s": lat.summary(),
        "dropped": dropped,
        "staleness_clamped": clamped,
    }


def cell_summary(sources) -> dict:
    """Per-cell ``obs`` summary from a recorder's engine-artifact slice
    (``TraceRecorder.sources_since``): synchronous schedules and async
    traces summarized side by side."""
    scheds = [s.obj for s in sources if s.tag == "schedule"]
    asyncs = [s.obj for s in sources if s.tag == "async"]
    out: dict = {}
    sm = schedule_metrics(scheds)
    if sm:
        out["schedule"] = sm
    am = async_metrics(asyncs)
    if am:
        out["async"] = am
    return out
