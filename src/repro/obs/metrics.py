"""Lightweight metrics: counters/gauges/histograms + per-cell summaries.

Two layers (DESIGN.md §11):

  * a tiny process-local :class:`MetricsRegistry` (counter / gauge /
    histogram) for code that wants to count things as it goes — no
    background threads, no exporters, ``summary()`` renders the whole
    registry as a JSON-safe dict;
  * pure summarizers over the engine's realized artifacts —
    :func:`schedule_metrics` (per-worker miss-rate, active-set-size
    distribution, p50/p95/p99 step latency) and :func:`async_metrics`
    (staleness histogram, drop/clamp counts) — which
    ``repro.experiments.execute`` attaches to the canonical record as the
    ``obs`` key and ``write_metrics_csv`` flattens to the per-cell CSV.

Metric names are stable identifiers (the report CLI and tests key on
them): ``miss_rate``, ``active_size``, ``step_latency_s``, ``staleness``,
``staleness_clamped``, ``dropped``, ``delay_tail``, ``compile_s``,
``execute_s``, ``compiles``.
"""
from __future__ import annotations

import numpy as np

from .sketch import DelayTailEstimator, QuantileSketch

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "schedule_metrics", "async_metrics", "fault_metrics",
    "clamp_async_event", "cell_summary",
]


class Counter:
    """A monotonically increasing count."""

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """A last-write-wins scalar."""

    def __init__(self):
        self.value = None

    def set(self, v) -> None:
        self.value = v


class Histogram:
    """A bounded-memory histogram behind the historical raw-sample API.

    Up to ``buffer_size`` observations everything is exact (raw samples,
    ``np.percentile``) — a 200-step cell behaves bit-identically to the
    PR-6 implementation.  Beyond that the buffer seeds P² quantile
    markers (:class:`repro.obs.sketch.QuantileSketch`) and raw samples
    are dropped, so streaming workloads can observe forever at O(1)
    memory (the ``summary()`` then carries ``approx: True``).  The
    integer-bucket ``counts()`` view is kept exactly in a dict — its size
    is the number of DISTINCT integer values (worker counts, staleness
    bounds: small by construction), capped at ``max_buckets``.
    """

    MAX_BUCKETS = 4096

    def __init__(self, percentiles=(50, 95, 99), buffer_size: int = 4096,
                 max_buckets: int = MAX_BUCKETS):
        self._sketch = QuantileSketch(percentiles, buffer_size)
        self._counts: dict | None = {}
        self._max_buckets = int(max_buckets)

    def observe(self, v) -> None:
        self.observe_many([v])

    def observe_many(self, vs) -> None:
        a = np.asarray(vs, dtype=float).ravel()
        self._sketch.observe_many(a)
        if self._counts is not None:
            ints, cnts = np.unique(a.astype(int), return_counts=True)
            for v, c in zip(ints.tolist(), cnts.tolist()):
                self._counts[v] = self._counts.get(v, 0) + c
            if len(self._counts) > self._max_buckets:
                self._counts = None        # too many distinct values

    @property
    def count(self) -> int:
        return self._sketch.count

    @property
    def spilled(self) -> bool:
        """True once raw samples were folded into the P² sketch."""
        return self._sketch.spilled

    def summary(self, percentiles=(50, 95, 99)) -> dict:
        if self.count == 0:
            return {"count": 0}
        if not self._sketch.spilled:
            s = self._sketch.summary()
            for q in tuple(s):
                if isinstance(q, str) and q.startswith("p"):
                    del s[q]
            for q in percentiles:
                s[f"p{q}"] = self._sketch.quantile(q)
            return s
        if tuple(percentiles) != self._sketch.percentiles:
            raise ValueError(
                f"histogram spilled tracking {self._sketch.percentiles}; "
                f"cannot produce {tuple(percentiles)}")
        return self._sketch.summary()

    def counts(self) -> dict:
        """Integer-bucket view ``{str(value): occurrences}`` — the natural
        rendering for discrete quantities (active-set sizes, staleness);
        ``{}`` when the stream exceeded ``max_buckets`` distinct values."""
        if self._counts is None:
            return {}
        return {str(v): int(c) for v, c in sorted(self._counts.items())}


class MetricsRegistry:
    """Name -> metric map with one-line accessors; ``summary()`` is the
    JSON-safe snapshot every consumer (records, report CLI) reads."""

    def __init__(self):
        self._metrics: dict = {}

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls()
        elif not isinstance(m, cls):
            raise TypeError(f"metric '{name}' is {type(m).__name__}, "
                            f"not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def summary(self) -> dict:
        out = {}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Histogram):
                out[name] = m.summary()
            else:
                out[name] = m.value
        return out


# ---------------------------------------------------------------------------
# Engine-artifact summarizers
# ---------------------------------------------------------------------------

def fault_metrics(schedules, *, k: int | None = None) -> dict:
    """Summarize the fault side of realized ``Schedule``s: crash count,
    blackout seconds, per-kind failed-entry fractions and — given the
    decode threshold ``k`` — the fraction of iterations that committed
    below it (``subk_fraction``).  ``{}`` when no schedule carries a
    ``failed`` array (the delay-only cluster)."""
    from repro.runtime.faults import (FAULT_BLACKOUT, FAULT_CORRUPT,
                                      FAULT_CRASHED)
    rows = [s for s in schedules
            if getattr(s, "failed", None) is not None]
    if not rows:
        return {}
    failed = np.concatenate([np.asarray(s.failed) for s in rows], axis=0)
    crashes = blackouts = blackout_s = 0
    for s in rows:
        for fe in getattr(s, "fault_events", ()):
            if fe.kind == "crash":
                crashes += 1
            elif fe.kind == "blackout":
                blackouts += 1
                blackout_s += float(fe.duration)
    total = float(failed.size) or 1.0
    out = {
        "crashes": int(crashes),
        "blackouts": int(blackouts),
        "blackout_s": float(blackout_s),
        "crashed_frac": float((failed == FAULT_CRASHED).sum() / total),
        "blackout_frac": float((failed == FAULT_BLACKOUT).sum() / total),
        "corrupt_count": int((failed == FAULT_CORRUPT).sum()),
    }
    if k is not None:
        masks = np.concatenate([np.asarray(s.masks) for s in rows], axis=0)
        out["subk_fraction"] = float(
            (masks.sum(axis=1) < int(k)).mean())
    return out


def schedule_metrics(schedules, *, k: int | None = None) -> dict:
    """Summarize realized synchronous ``Schedule``s (one or many — batched
    cells pass all R realizations, chunked workloads every sub-solve).

    Returns per-worker ``miss_rate`` (fraction of iterations worker i was
    erased), the ``active_size`` distribution, per-iteration
    ``step_latency_s`` (commit-to-commit barrier time) percentiles, and
    the per-worker ``delay_tail`` snapshot (EWMA delay + p50/p95/p99 of
    each worker's arrival latency — the auto-tuner's sensing interface).
    Schedules realized under a fault model additionally carry a ``faults``
    block (:func:`fault_metrics`; ``k`` enables its ``subk_fraction``).
    Schedules whose worker count differs from the first are skipped (a
    matrix cell never mixes cluster sizes).
    """
    schedules = [s for s in schedules if s is not None]
    if not schedules:
        return {}
    m = schedules[0].m
    schedules = [s for s in schedules if s.m == m]
    masks = np.concatenate([np.asarray(s.masks, dtype=float)
                            for s in schedules], axis=0)
    lat = Histogram()
    active = Histogram()
    tail = DelayTailEstimator(m)
    for s in schedules:
        times = np.asarray(s.times, dtype=float)
        lat.observe_many(np.diff(times, prepend=0.0))
        active.observe_many(np.asarray(s.masks).sum(axis=1))
        tail.observe_schedule(s)
    miss = 1.0 - masks.mean(axis=0)
    out = {
        "iterations": int(masks.shape[0]),
        "workers": int(m),
        "miss_rate": [float(x) for x in miss],
        "mean_miss_rate": float(miss.mean()),
        "max_miss_rate": float(miss.max()),
        "active_size": {**active.summary(), "hist": active.counts()},
        "step_latency_s": lat.summary(),
        "delay_tail": tail.snapshot(),
    }
    fm = fault_metrics(schedules, k=k)
    if fm:
        out["faults"] = fm
    return out


def clamp_async_event(u: int, tau: int, rv: int, total: int) -> tuple:
    """Snap one async (update index, staleness, read_version) triple into
    range; returns ``(tau, rv, was_clamped)``.

    The engine's invariant is ``rv + tau == u`` with ``0 <= tau <= u`` and
    ``rv < total``; a hand-built or corrupted trace can violate it, which
    would silently wrap downstream ring buffers.  This is the ONE clamp
    rule, shared by the trace expander (``obs.trace``) and
    :func:`async_metrics` so the surfaced ``staleness_clamped`` count always
    matches the exported events.
    """
    if rv + tau != u or rv >= total or tau < 0:
        tau = min(max(tau, 0), u)
        return tau, u - tau, True
    return tau, rv, False


def async_metrics(traces) -> dict:
    """Summarize realized ``AsyncTrace``s: staleness histogram, per-arrival
    latency percentiles, dropped-gradient totals, and the count of events
    clamped at the trace boundary (see :func:`clamp_async_event`)."""
    traces = [t for t in traces if t is not None]
    if not traces:
        return {}
    stale = Histogram()
    lat = Histogram()
    tail = DelayTailEstimator(int(traces[0].m))
    dropped = 0
    clamped = 0
    corrupted = 0
    for t in traces:
        corrupted += int(getattr(t, "corrupted", 0))
        staleness = np.asarray(t.staleness, dtype=int)
        reads = np.asarray(t.read_versions, dtype=int)
        U = staleness.shape[0]
        for u in range(U):
            tau, _, was = clamp_async_event(u, int(staleness[u]),
                                            int(reads[u]), U)
            stale.observe(tau)
            clamped += was
        lat.observe_many(np.diff(np.asarray(t.times, dtype=float),
                                 prepend=0.0))
        if t.m == tail.m:
            tail.observe_async(t)
        dropped += int(t.dropped)
    out = {
        "updates": stale.count,
        "workers": int(traces[0].m),
        "staleness": {**stale.summary(), "hist": stale.counts()},
        "update_latency_s": lat.summary(),
        "dropped": dropped,
        "staleness_clamped": clamped,
        "delay_tail": tail.snapshot(),
    }
    if corrupted:
        out["corrupted"] = corrupted
    return out


def cell_summary(sources) -> dict:
    """Per-cell ``obs`` summary from a recorder's engine-artifact slice
    (``TraceRecorder.sources_since``): synchronous schedules and async
    traces summarized side by side."""
    scheds = [s.obj for s in sources if s.tag == "schedule"]
    asyncs = [s.obj for s in sources if s.tag == "async"]
    out: dict = {}
    sm = schedule_metrics(scheds)
    if sm:
        out["schedule"] = sm
    am = async_metrics(asyncs)
    if am:
        out["async"] = am
    return out
