"""Cross-run analytics: align two runs cell-by-cell, gate on regressions.

The comparison layer over :mod:`repro.obs.runstore` (DESIGN.md §13):

  * :func:`cell_key` / :func:`summarize_records` — the identity of one
    matrix cell (workload, preset, strategy, delay, problem shape,
    trials, seed) and the compact per-cell summary a manifest stores;
  * :func:`diff_manifests` — align two manifests (or raw record lists)
    by cell key and compute wall-clock ratios + convergence deltas;
  * :func:`diff_bench` — align two ``BENCH_*.json`` trees by path and
    compare every time-like leaf (``*_s``, ``us_*``, ``seconds*``);
  * :class:`DiffReport` — the result: per-cell :class:`CellDelta` rows,
    regression list, exit code (0 clean / 1 regression), text and HTML
    renderings.  ``python -m repro.obs.diff`` is the CLI front-end the
    CI bench-regression gate calls.

Gating semantics: a cell regresses when its wall-clock ratio
``b / a`` exceeds ``Thresholds.wallclock_ratio`` (and the absolute delta
exceeds ``min_seconds``, so micro-cells don't flag on timer noise), or
when ``final_objective`` — lower is better for every workload — worsens
by more than ``metric_rel`` relative.  ``final_metric`` deltas are
reported but never gated (metric direction is workload-specific).
"""
from __future__ import annotations

import dataclasses
import html as _html

__all__ = [
    "CELL_KEY_FIELDS", "cell_key", "summarize_records", "Thresholds",
    "CellDelta", "DiffReport", "diff_manifests", "diff_bench",
    "flatten_bench", "render_html_page",
]


CELL_KEY_FIELDS = ("workload", "preset", "strategy", "delay", "n", "p",
                   "m", "k", "trials", "seed")


def cell_key(rec: dict) -> tuple:
    """The alignment identity of one cell record/summary."""
    return tuple(rec.get(f) for f in CELL_KEY_FIELDS)


def _label(rec: dict) -> str:
    parts = []
    if rec.get("workload"):
        parts.append(str(rec["workload"]))
    parts.append(str(rec.get("strategy", "?")))
    parts.append(str(rec.get("delay", "?")))
    return "x".join(parts)


_SUMMARY_FIELDS = ("metric_name", "final_metric", "final_objective",
                   "wallclock_s", "host_s", "compile_s", "execute_s",
                   "compiles", "skipped")


def summarize_records(records) -> list[dict]:
    """Compact per-cell summaries for a manifest: the cell key fields plus
    wall-clock / convergence scalars — no traces (manifests stay small;
    artifact paths point at the full records)."""
    out = []
    for rec in records:
        row = {f: rec.get(f) for f in CELL_KEY_FIELDS if f in rec}
        row.update({f: rec[f] for f in _SUMMARY_FIELDS if f in rec})
        obs = rec.get("obs") or {}
        tail = (obs.get("schedule") or obs.get("async") or {}) \
            .get("delay_tail")
        if tail:
            row["delay_tail_p99_max"] = tail.get("p99_max")
        out.append(row)
    return out


@dataclasses.dataclass(frozen=True)
class Thresholds:
    """Regression gate configuration (all CLI-overridable)."""
    wallclock_ratio: float = 1.5   # flag when b/a exceeds this
    metric_rel: float = 0.25       # relative final_objective worsening
    min_seconds: float = 1e-3      # absolute slack below which time noise
    #                                never flags

    def validate(self) -> None:
        if self.wallclock_ratio <= 0:
            raise ValueError("wallclock_ratio must be > 0")


@dataclasses.dataclass
class CellDelta:
    """One aligned comparison row (a = reference, b = candidate)."""
    label: str
    key: tuple
    wallclock_a: float | None = None
    wallclock_b: float | None = None
    ratio: float | None = None
    objective_a: float | None = None
    objective_b: float | None = None
    objective_rel: float | None = None
    status: str = "ok"             # ok | regression | improved | skipped
    reasons: list = dataclasses.field(default_factory=list)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["key"] = list(self.key)
        return d


@dataclasses.dataclass
class DiffReport:
    """The aligned diff of two runs; exit-code gated for CI."""
    kind: str                      # "run" | "bench"
    a_label: str
    b_label: str
    thresholds: Thresholds
    deltas: list = dataclasses.field(default_factory=list)
    unmatched_a: list = dataclasses.field(default_factory=list)
    unmatched_b: list = dataclasses.field(default_factory=list)
    notes: list = dataclasses.field(default_factory=list)

    @property
    def regressions(self) -> list:
        return [d for d in self.deltas if d.status == "regression"]

    @property
    def exit_code(self) -> int:
        return 1 if self.regressions else 0

    def to_dict(self) -> dict:
        return {
            "kind": self.kind, "a": self.a_label, "b": self.b_label,
            "thresholds": dataclasses.asdict(self.thresholds),
            "deltas": [d.to_dict() for d in self.deltas],
            "unmatched_a": self.unmatched_a,
            "unmatched_b": self.unmatched_b,
            "notes": self.notes,
            "regressions": len(self.regressions),
            "exit_code": self.exit_code,
        }

    # -- renderings ------------------------------------------------------

    def render_text(self) -> str:
        out = [f"{self.kind} diff: {self.a_label} -> {self.b_label}"]
        out += [f"  note: {n}" for n in self.notes]
        if self.deltas:
            out.append(f"  {'cell':40s} {'a':>12s} {'b':>12s} "
                       f"{'ratio':>7s} {'obj delta':>10s} status")
        for d in self.deltas:
            ratio = f"{d.ratio:.2f}x" if d.ratio is not None else "-"
            rel = (f"{d.objective_rel:+.1%}"
                   if d.objective_rel is not None else "-")
            wa = f"{d.wallclock_a:.4g}" if d.wallclock_a is not None else "-"
            wb = f"{d.wallclock_b:.4g}" if d.wallclock_b is not None else "-"
            line = (f"  {d.label:40s} {wa:>12s} {wb:>12s} {ratio:>7s} "
                    f"{rel:>10s} {d.status}")
            if d.reasons:
                line += f"  ({'; '.join(d.reasons)})"
            out.append(line)
        for side, keys in (("a", self.unmatched_a), ("b", self.unmatched_b)):
            if keys:
                out.append(f"  only in {side}: "
                           + ", ".join(str(k) for k in keys))
        n = len(self.regressions)
        if n:
            out.append(f"RESULT: REGRESSION ({n} of {len(self.deltas)} "
                       f"compared)")
        else:
            out.append(f"RESULT: OK ({len(self.deltas)} compared, "
                       f"0 regressions)")
        return "\n".join(out)

    def render_html_section(self) -> str:
        rows = []
        for d in self.deltas:
            cls = {"regression": "bad", "improved": "good"}.get(d.status,
                                                                "")
            ratio = f"{d.ratio:.2f}x" if d.ratio is not None else "–"
            rel = (f"{d.objective_rel:+.1%}"
                   if d.objective_rel is not None else "–")
            wa = f"{d.wallclock_a:.4g}" if d.wallclock_a is not None else "–"
            wb = f"{d.wallclock_b:.4g}" if d.wallclock_b is not None else "–"
            rows.append(
                f"<tr class='{cls}'><td>{_html.escape(d.label)}</td>"
                f"<td>{wa}</td><td>{wb}</td><td>{ratio}</td><td>{rel}</td>"
                f"<td>{d.status}"
                + (f" <small>{_html.escape('; '.join(d.reasons))}</small>"
                   if d.reasons else "")
                + "</td></tr>")
        verdict = (f"<p class='bad'><b>REGRESSION</b>: "
                   f"{len(self.regressions)} cell(s)</p>"
                   if self.regressions else
                   "<p class='good'><b>OK</b>: no regressions</p>")
        notes = "".join(f"<p><small>{_html.escape(n)}</small></p>"
                        for n in self.notes)
        return (
            f"<h2>{self.kind} diff: {_html.escape(self.a_label)} &rarr; "
            f"{_html.escape(self.b_label)}</h2>{notes}{verdict}"
            "<table><tr><th>cell</th><th>a</th><th>b</th><th>ratio</th>"
            "<th>objective &Delta;</th><th>status</th></tr>"
            + "".join(rows) + "</table>")


# ---------------------------------------------------------------------------
# Run-vs-run (manifest / record-list) diff
# ---------------------------------------------------------------------------

def _as_cells(side) -> list[dict]:
    """Manifest dict -> its cell summaries; record list -> summarized."""
    if isinstance(side, dict):
        return list(side.get("cells") or [])
    return summarize_records(side)


def _diff_one(key, a: dict, b: dict, th: Thresholds) -> CellDelta:
    d = CellDelta(label=_label(a or b), key=key)
    if "skipped" in (a or {}) or "skipped" in (b or {}):
        d.status = "skipped"
        d.reasons.append(
            (a or {}).get("skipped") or (b or {}).get("skipped") or "")
        return d
    d.wallclock_a = a.get("wallclock_s")
    d.wallclock_b = b.get("wallclock_s")
    if d.wallclock_a and d.wallclock_b:
        d.ratio = d.wallclock_b / d.wallclock_a
        slow = d.wallclock_b - d.wallclock_a > th.min_seconds
        if d.ratio > th.wallclock_ratio and slow:
            d.status = "regression"
            d.reasons.append(
                f"wallclock {d.ratio:.2f}x > {th.wallclock_ratio:g}x")
        elif d.ratio < 1.0 / th.wallclock_ratio:
            d.status = "improved"
    d.objective_a = a.get("final_objective")
    d.objective_b = b.get("final_objective")
    if d.objective_a is not None and d.objective_b is not None:
        scale = max(abs(d.objective_a), 1e-12)
        d.objective_rel = (d.objective_b - d.objective_a) / scale
        if d.objective_rel > th.metric_rel:
            d.status = "regression"
            d.reasons.append(
                f"final_objective worsened {d.objective_rel:+.1%} "
                f"> {th.metric_rel:.0%}")
    return d


def diff_manifests(a, b, *, thresholds: Thresholds | None = None,
                   a_label: str = "a", b_label: str = "b") -> DiffReport:
    """Align run ``a`` (reference) and ``b`` (candidate) by cell key and
    gate.  Accepts store manifests or raw record lists on either side."""
    th = thresholds or Thresholds()
    th.validate()
    report = DiffReport(kind="run", a_label=a_label, b_label=b_label,
                        thresholds=th)
    if isinstance(a, dict) and isinstance(b, dict):
        ha, hb = a.get("spec_hash"), b.get("spec_hash")
        if ha and hb:
            if ha == hb:
                report.notes.append(f"spec hash match: {ha}")
            else:
                report.notes.append(
                    f"spec hash MISMATCH: {ha} vs {hb} — comparing "
                    f"overlapping cells only")
    cells_a = {cell_key(c): c for c in _as_cells(a)}
    cells_b = {cell_key(c): c for c in _as_cells(b)}
    for key, ca in cells_a.items():
        if key in cells_b:
            report.deltas.append(_diff_one(key, ca, cells_b[key], th))
        else:
            report.unmatched_a.append(_label(ca))
    report.unmatched_b = [_label(cb) for key, cb in cells_b.items()
                          if key not in cells_a]
    if not report.deltas:
        report.notes.append("no cells aligned — are these runs of the "
                            "same spec?")
    return report


# ---------------------------------------------------------------------------
# Bench-baseline diff (BENCH_*.json trees)
# ---------------------------------------------------------------------------

_ID_KEYS = ("case", "name", "placement")


def _time_like(key: str) -> bool:
    return (key.endswith("_s") or key.endswith("_us")
            or key.startswith("us_") or "seconds" in key)


def flatten_bench(doc, prefix: str = "") -> dict:
    """``{dotted.path: value}`` over every time-like numeric leaf of a
    BENCH json tree.  List elements are keyed by their ``case`` / ``name``
    / ``placement`` (+``R``) field when present, by index otherwise, so
    reordered suites still align.  ``meta`` subtrees (provenance stamps)
    are skipped."""
    out: dict = {}
    if isinstance(doc, dict):
        for k, v in doc.items():
            if k == "meta":
                continue
            path = f"{prefix}.{k}" if prefix else str(k)
            if isinstance(v, (dict, list)):
                out.update(flatten_bench(v, path))
            elif isinstance(v, (int, float)) and not isinstance(v, bool) \
                    and _time_like(str(k)):
                out[path] = float(v)
    elif isinstance(doc, list):
        for i, v in enumerate(doc):
            tag = str(i)
            if isinstance(v, dict):
                for idk in _ID_KEYS:
                    if idk in v:
                        tag = str(v[idk])
                        if "R" in v:
                            tag += f"[R{v['R']}]"
                        break
            out.update(flatten_bench(v, f"{prefix}[{tag}]"))
    return out


def diff_bench(a, b, *, thresholds: Thresholds | None = None,
               a_label: str = "baseline", b_label: str = "candidate"
               ) -> DiffReport:
    """Compare candidate ``b`` against baseline ``a``: every time-like
    leaf present in both trees is gated on its ratio (``b / a``)."""
    th = thresholds or Thresholds()
    th.validate()
    report = DiffReport(kind="bench", a_label=a_label, b_label=b_label,
                        thresholds=th)
    fa, fb = flatten_bench(a), flatten_bench(b)
    for path, va in fa.items():
        if path not in fb:
            report.unmatched_a.append(path)
            continue
        vb = fb[path]
        d = CellDelta(label=path, key=(path,), wallclock_a=va,
                      wallclock_b=vb)
        if va > 0:
            d.ratio = vb / va
            # per-leaf units vary (us vs s); min_seconds only guards
            # second-denominated leaves
            slack = th.min_seconds if path.endswith("_s") else 0.0
            if d.ratio > th.wallclock_ratio and vb - va > slack:
                d.status = "regression"
                d.reasons.append(
                    f"{d.ratio:.2f}x > {th.wallclock_ratio:g}x")
            elif d.ratio < 1.0 / th.wallclock_ratio:
                d.status = "improved"
        report.deltas.append(d)
    report.unmatched_b = [p for p in fb if p not in fa]
    if not report.deltas:
        report.notes.append("no overlapping time-like leaves")
    return report


# ---------------------------------------------------------------------------
# Shared HTML page scaffold
# ---------------------------------------------------------------------------

_CSS = """
body { font: 14px/1.45 system-ui, sans-serif; margin: 2em auto;
       max-width: 70em; color: #1a1a2e; padding: 0 1em; }
h1, h2 { font-weight: 600; }
table { border-collapse: collapse; margin: 1em 0; width: 100%; }
th, td { border: 1px solid #d8d8e0; padding: .3em .6em;
         text-align: left; font-variant-numeric: tabular-nums; }
th { background: #f2f2f7; }
tr.bad td { background: #fdecec; }
tr.good td { background: #ecf8ef; }
.bad { color: #b3261e; } .good { color: #1e7d32; }
pre.lanes { font: 12px/1.2 ui-monospace, monospace; background: #f7f7fa;
            padding: .8em; overflow-x: auto; }
.bar { display: inline-block; height: .75em; background: #5b72d8;
       vertical-align: baseline; }
.bar.miss { background: #d86a5b; }
small { color: #666; }
"""


def render_html_page(title: str, sections: list[str]) -> str:
    """One self-contained HTML document (inline CSS, no external
    assets) from pre-rendered body sections."""
    body = "\n".join(sections)
    return (f"<!doctype html><html><head><meta charset='utf-8'>"
            f"<title>{_html.escape(title)}</title>"
            f"<style>{_CSS}</style></head><body>"
            f"<h1>{_html.escape(title)}</h1>\n{body}</body></html>")
