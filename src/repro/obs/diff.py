"""Regression gate CLI: compare two stored runs or bench baselines.

Usage::

    python -m repro.obs.diff <run-a> <run-b> [--store DIR]
    python -m repro.obs.diff <run-b> --against-baseline BENCH_fused.json
    python -m repro.obs.diff BENCH_new.json --against-baseline BENCH_old.json

Run references are store run ids (or unique prefixes), ``latest`` /
``latest~N``, or paths to a manifest file / run directory.  A plain
``*.json`` positional that is not a manifest is treated as a bench
document (``BENCH_*.json``), so the CI gate can diff a fresh bench
output directly against the committed baseline.

Exit codes: ``0`` no regression, ``1`` at least one gated leaf/cell
regressed, ``2`` usage / resolution error.  Thresholds are configurable
(``--threshold`` wall-clock ratio, ``--metric-threshold`` relative
objective worsening); ``--json`` and ``--html`` write machine- and
human-readable reports alongside the text summary.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from .analyze import (Thresholds, diff_bench, diff_manifests,
                      render_html_page)
from .runstore import DEFAULT_ROOT, ENV_VAR, RunStore

__all__ = ["main"]


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.diff",
        description="Align two runs cell-by-cell and gate on wall-clock/"
                    "convergence regressions (exit 1 on regression).")
    ap.add_argument("run_a", help="reference run: store id / prefix / "
                    "'latest' / 'latest~N' / manifest path / BENCH json")
    ap.add_argument("run_b", nargs="?", default=None,
                    help="candidate run (omit with --against-baseline)")
    ap.add_argument("--against-baseline", metavar="BENCH_JSON",
                    help="compare run_a (a BENCH_*.json or stored run) "
                         "against this committed baseline json")
    ap.add_argument("--store", default=None,
                    help=f"run store root (default: ${ENV_VAR} or "
                         f"{DEFAULT_ROOT})")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="wall-clock ratio above which a cell regresses "
                         "(default 1.5)")
    ap.add_argument("--metric-threshold", type=float, default=0.25,
                    help="relative final-objective worsening above which "
                         "a cell regresses (default 0.25)")
    ap.add_argument("--min-seconds", type=float, default=1e-3,
                    help="absolute wall-clock slack below which timing "
                         "noise never flags (default 1e-3)")
    ap.add_argument("--json", metavar="PATH", dest="json_out",
                    help="write the full report as JSON")
    ap.add_argument("--html", metavar="PATH", dest="html_out",
                    help="write a self-contained HTML report")
    return ap


def _ensure_parent(path: str) -> None:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)


def _store(args) -> RunStore:
    root = args.store or os.environ.get(ENV_VAR) or DEFAULT_ROOT
    return RunStore(root)


def _is_bench_doc(doc: dict) -> bool:
    return "bench" in doc and "cells" not in doc


def _load_side(ref: str, store: RunStore):
    """Resolve one CLI reference to (doc, label, kind)."""
    if os.path.isfile(ref) and not os.path.isdir(ref):
        with open(ref) as f:
            doc = json.load(f)
        kind = "bench" if _is_bench_doc(doc) else "run"
        return doc, os.path.basename(ref), kind
    doc = store.resolve(ref)
    return doc, doc.get("run_id", ref), "run"


def main(argv=None) -> int:
    args = _build_parser().parse_args(argv)
    if (args.run_b is None) == (args.against_baseline is None):
        print("error: provide either <run-b> or --against-baseline, "
              "not both", file=sys.stderr)
        return 2
    store = _store(args)
    th = Thresholds(wallclock_ratio=args.threshold,
                    metric_rel=args.metric_threshold,
                    min_seconds=args.min_seconds)

    try:
        if args.against_baseline:
            # baseline is reference (a); the positional is the candidate
            cand, cand_label, cand_kind = _load_side(args.run_a, store)
            with open(args.against_baseline) as f:
                base = json.load(f)
            base_label = os.path.basename(args.against_baseline)
            if cand_kind == "run" and not _is_bench_doc(cand):
                report = diff_manifests(base, cand, thresholds=th,
                                        a_label=base_label,
                                        b_label=cand_label)
            else:
                report = diff_bench(base, cand, thresholds=th,
                                    a_label=base_label,
                                    b_label=cand_label)
        else:
            a, a_label, a_kind = _load_side(args.run_a, store)
            b, b_label, b_kind = _load_side(args.run_b, store)
            if "bench" in (a_kind, b_kind):
                report = diff_bench(a, b, thresholds=th, a_label=a_label,
                                    b_label=b_label)
            else:
                report = diff_manifests(a, b, thresholds=th,
                                        a_label=a_label, b_label=b_label)
    except (KeyError, OSError, json.JSONDecodeError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    print(report.render_text())
    if args.json_out:
        _ensure_parent(args.json_out)
        with open(args.json_out, "w") as f:
            json.dump(report.to_dict(), f, indent=1)
    if args.html_out:
        page = render_html_page(
            f"repro diff: {report.a_label} vs {report.b_label}",
            [report.render_html_section()])
        _ensure_parent(args.html_out)
        with open(args.html_out, "w") as f:
            f.write(page)
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
