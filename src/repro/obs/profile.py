"""Opt-in profiling hooks: ``jax.profiler`` capture + device-memory peaks.

Everything here degrades to a no-op when the backend (or jax build) does
not support it — CPU wheels often return ``None`` from
``Device.memory_stats()`` and some environments ship without the profiler
plugin; opt-in observability must never take a run down.

  * :func:`profile_region` — context manager starting/stopping a
    ``jax.profiler`` trace into a per-cell logdir (open the result in
    TensorBoard or https://ui.perfetto.dev);
  * :func:`memory_stats`    — per-device byte counters, normalized to
    ``{device: {bytes_in_use, peak_bytes_in_use, ...}}``;
  * :func:`memory_high_water` — the max ``peak_bytes_in_use`` across
    devices (or ``bytes_in_use`` where the backend tracks no peak), the
    single gauge attached to cell records.
"""
from __future__ import annotations

import contextlib

__all__ = ["profile_region", "memory_stats", "memory_high_water"]

_KEYS = ("bytes_in_use", "peak_bytes_in_use", "bytes_limit",
         "largest_alloc_size")


@contextlib.contextmanager
def profile_region(logdir: str | None):
    """Capture a ``jax.profiler`` trace of the block into ``logdir``
    (``None`` — and any profiler failure — makes this a plain no-op)."""
    started = False
    if logdir:
        try:
            import jax.profiler
            jax.profiler.start_trace(logdir)
            started = True
        except Exception as e:          # missing plugin / nested trace
            print(f"# obs: jax.profiler unavailable ({e}); skipping capture")
    try:
        yield
    finally:
        if started:
            try:
                import jax.profiler
                jax.profiler.stop_trace()
            except Exception:
                pass


def memory_stats() -> dict:
    """``{device_str: {counter: bytes}}`` for every local device; devices
    whose backend exposes no stats (CPU) are omitted."""
    try:
        import jax
        devices = jax.local_devices()
    except Exception:
        return {}
    out: dict = {}
    for d in devices:
        try:
            ms = d.memory_stats()
        except Exception:
            ms = None
        if not ms:
            continue
        out[str(d)] = {k: int(ms[k]) for k in _KEYS if k in ms}
    return out


def memory_high_water() -> int | None:
    """Max peak bytes in use across local devices (``None`` when no device
    reports memory counters — e.g. the CPU backend)."""
    stats = memory_stats()
    if not stats:
        return None
    return max(s.get("peak_bytes_in_use", s.get("bytes_in_use", 0))
               for s in stats.values())
