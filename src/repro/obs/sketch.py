"""Online sketches: P² quantiles, EWMA, per-worker delay-tail estimators.

The streaming layer of repro.obs (DESIGN.md §13).  PR 6's metrics kept
raw samples, which is fine for a 200-step cell but unbounded for the
streaming-serving scenario (ROADMAP) where observations arrive forever.
Everything here is O(1) memory per tracked quantity:

  * :class:`P2Quantile`      — the P² algorithm (Jain & Chlamtac 1985):
    one quantile from five markers, no samples retained;
  * :class:`QuantileSketch`  — several quantiles + running count/mean/
    min/max behind one ``observe`` API.  Exact (raw-sample) up to
    ``buffer_size`` observations, then the buffer seeds the P² markers
    and is dropped — small cells keep bit-exact percentiles, long
    streams get bounded memory;
  * :class:`Ewma`            — exponentially weighted moving average;
  * :class:`DelayTailEstimator` — per-worker EWMA delay + tail-quantile
    (p50/p95/p99) estimators fed from the engine's schedule / async
    event stream.  This is the sensing interface the adaptive-redundancy
    controller (Avestimehr et al., arXiv 1804.00217) consumes to adapt
    k and β mid-run, surfaced to records as the ``delay_tail_*``
    metrics.

Accuracy contract (tested): on 10⁶ i.i.d. samples the spilled sketch's
p50/p95/p99 are within 1% of exact ``np.percentile``.
"""
from __future__ import annotations

import math

import numpy as np

__all__ = ["P2Quantile", "QuantileSketch", "Ewma", "DelayTailEstimator"]


class P2Quantile:
    """Streaming estimate of one quantile via the P² algorithm.

    Five markers track (min, q/2, q, (1+q)/2, max); each observation
    adjusts the middle markers by a piecewise-parabolic update.  Below
    five observations the estimate is exact.  ``seed_sorted`` initializes
    the markers from a sorted sample instead of the first five points,
    which is how :class:`QuantileSketch` hands over its exact buffer.
    """

    def __init__(self, q: float):
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        self.q = float(q)
        self._fracs = (0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0)
        self._init: list | None = []    # first <5 observations
        self._heights: list | None = None
        self._pos: list | None = None       # integer marker positions
        self._want: list | None = None      # desired (fractional) positions
        self.count = 0

    # -- initialization ----------------------------------------------------

    def _start(self, sorted_vals: np.ndarray) -> None:
        n = int(sorted_vals.size)
        self._heights = [float(np.percentile(sorted_vals, f * 100.0))
                         for f in self._fracs]
        self._want = [1.0 + f * (n - 1) for f in self._fracs]
        pos = [int(round(w)) for w in self._want]
        # positions must be strictly increasing and span [1, n]
        pos[0], pos[4] = 1, n
        for i in range(1, 4):
            pos[i] = min(max(pos[i], pos[i - 1] + 1), n - (4 - i))
        self._pos = pos
        self.count = n
        self._init = None

    def seed_sorted(self, sorted_vals) -> None:
        """Initialize from an ascending array (>= 5 values) of past
        observations — more accurate than growing from the first five."""
        a = np.asarray(sorted_vals, dtype=float)
        if a.size < 5:
            raise ValueError("seed_sorted needs at least 5 values")
        if self.count:
            raise ValueError("P2Quantile already has observations")
        self._start(a)

    # -- update ------------------------------------------------------------

    def observe(self, x: float) -> None:
        x = float(x)
        if self._heights is None:
            self._init.append(x)
            self.count += 1
            if self.count == 5:
                self._start(np.sort(np.asarray(self._init)))
            return
        q, n = self._heights, self._pos
        if x < q[0]:
            q[0] = x
            k = 0
        elif x >= q[4]:
            q[4] = x
            k = 3
        else:
            k = 0
            while x >= q[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            n[i] += 1
        for i in range(5):
            self._want[i] += self._fracs[i]
        self.count += 1
        for i in range(1, 4):
            d = self._want[i] - n[i]
            if (d >= 1.0 and n[i + 1] - n[i] > 1) or \
                    (d <= -1.0 and n[i - 1] - n[i] < -1):
                s = 1 if d > 0 else -1
                # parabolic prediction; fall back to linear when it would
                # break marker monotonicity
                hp = q[i] + s / (n[i + 1] - n[i - 1]) * (
                    (n[i] - n[i - 1] + s) * (q[i + 1] - q[i])
                    / (n[i + 1] - n[i])
                    + (n[i + 1] - n[i] - s) * (q[i] - q[i - 1])
                    / (n[i] - n[i - 1]))
                if q[i - 1] < hp < q[i + 1]:
                    q[i] = hp
                else:
                    q[i] = q[i] + s * (q[i + s] - q[i]) / (n[i + s] - n[i])
                n[i] += s

    @property
    def value(self) -> float | None:
        """Current quantile estimate (None before any observation)."""
        if self._heights is not None:
            return self._heights[2]
        if not self._init:
            return None
        return float(np.percentile(np.asarray(self._init), self.q * 100.0))


class QuantileSketch:
    """Several streaming percentiles + running moments, one observe API.

    Exact up to ``buffer_size`` observations (``np.percentile`` over the
    raw buffer — identical to the historical raw-sample ``Histogram``),
    then the sorted buffer seeds one :class:`P2Quantile` per requested
    percentile and is dropped.  Memory after the spill is O(#percentiles),
    independent of the stream length.
    """

    def __init__(self, percentiles=(50, 95, 99), buffer_size: int = 4096):
        self.percentiles = tuple(percentiles)
        self.buffer_size = int(buffer_size)
        self._buf: list | None = []
        self._p2: dict | None = None
        self.count = 0
        self._mean = 0.0
        self._min = math.inf
        self._max = -math.inf

    @property
    def spilled(self) -> bool:
        """True once the raw buffer was folded into P² markers."""
        return self._buf is None

    def _track(self, a: np.ndarray) -> None:
        if a.size == 0:
            return
        total = self.count + a.size
        self._mean += (float(a.sum()) - a.size * self._mean) / total
        self.count = total
        self._min = min(self._min, float(a.min()))
        self._max = max(self._max, float(a.max()))

    def _spill(self) -> None:
        srt = np.sort(np.asarray(self._buf, dtype=float))
        self._p2 = {}
        for q in self.percentiles:
            est = P2Quantile(q / 100.0)
            est.seed_sorted(srt)
            self._p2[q] = est
        self._buf = None

    def observe(self, v) -> None:
        self.observe_many([v])

    def observe_many(self, vs) -> None:
        a = np.asarray(vs, dtype=float).ravel()
        self._track(a)
        if self._buf is not None:
            self._buf.extend(a.tolist())
            if len(self._buf) > self.buffer_size:
                self._spill()
            return
        vals = a.tolist()
        for est in self._p2.values():
            for x in vals:
                est.observe(x)

    def quantile(self, q: float) -> float | None:
        """Quantile estimate for percentile ``q`` (must be one of
        ``percentiles`` after the spill; arbitrary while exact)."""
        if self.count == 0:
            return None
        if self._buf is not None:
            return float(np.percentile(np.asarray(self._buf), q))
        if q not in self._p2:
            raise KeyError(f"percentile {q} not tracked after spill; have "
                           f"{self.percentiles}")
        return self._p2[q].value

    def summary(self) -> dict:
        """The same schema as the historical ``Histogram.summary``:
        count/mean/min/max + one ``p<q>`` key per tracked percentile."""
        if self.count == 0:
            return {"count": 0}
        out = {"count": int(self.count), "mean": float(self._mean),
               "min": float(self._min), "max": float(self._max)}
        for q in self.percentiles:
            out[f"p{q}"] = self.quantile(q)
        if self.spilled:
            out["approx"] = True
        return out


class Ewma:
    """Exponentially weighted moving average; ``value`` is None until the
    first observation (which initializes it exactly)."""

    def __init__(self, alpha: float = 0.2):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = float(alpha)
        self.value: float | None = None
        self.count = 0

    def update(self, x) -> float:
        x = float(x)
        self.value = x if self.value is None else \
            self.alpha * x + (1.0 - self.alpha) * self.value
        self.count += 1
        return self.value


class DelayTailEstimator:
    """Per-worker online delay-tail state: EWMA delay + tail quantiles.

    The sensing layer for adaptive redundancy: pass one to
    ``ClusterEngine(tail_estimator=...)`` and every sampled schedule /
    async trace updates it in-stream — a controller can then read
    ``snapshot()`` mid-run to adapt the active-set size k (or β) to the
    observed tail.  ``repro.obs.metrics`` uses the same class offline to
    attach ``delay_tail`` summaries to records.

    Per worker: one :class:`Ewma` over its per-iteration delay (arrival
    minus iteration start for synchronous schedules; inter-apply gap for
    async traces) and one :class:`QuantileSketch` (p50/p95/p99, small
    exact buffer) — O(1) memory per worker regardless of run length.
    """

    PERCENTILES = (50, 95, 99)

    def __init__(self, m: int, *, alpha: float = 0.2,
                 buffer_size: int = 128):
        self.m = int(m)
        self._ewma = [Ewma(alpha) for _ in range(self.m)]
        self._tail = [QuantileSketch(self.PERCENTILES, buffer_size)
                      for _ in range(self.m)]
        # fault sensing (PR 9 follow-up): counts from faulted schedules so
        # the adaptive-k controller can tell a fat delay tail from genuine
        # failures (a crash wants more redundancy, a tail wants a smaller k)
        self._fault_schedules = 0
        self._crashes = 0
        self._blackouts = 0
        self._blackout_s = 0.0
        self._corrupt = 0

    def observe(self, worker: int, delay: float) -> None:
        self._ewma[worker].update(delay)
        self._tail[worker].observe(delay)

    def observe_iteration(self, start: float, arrivals) -> None:
        """One synchronous barrier: every worker's arrival minus the
        iteration start (the realized compute+delay of that worker)."""
        a = np.asarray(arrivals, dtype=float)
        for i in range(min(self.m, a.shape[0])):
            self.observe(i, a[i] - float(start))

    def observe_schedule(self, sched) -> None:
        """Feed a realized ``runtime.engine.Schedule`` — delay tails from
        its barrier events plus, for faulted schedules, the realized
        crash/blackout/corrupt counts (``fault_metrics`` in-stream)."""
        for ev in sched.events:
            self.observe_iteration(ev.start, ev.arrivals)
        if getattr(sched, "failed", None) is not None:
            self._fault_schedules += 1
        for fe in getattr(sched, "fault_events", ()) or ():
            kind = getattr(fe, "kind", None)
            if kind == "crash":
                self._crashes += 1
            elif kind == "blackout":
                self._blackouts += 1
                self._blackout_s += float(getattr(fe, "duration", 0.0))
            elif kind == "corrupt":
                self._corrupt += 1

    def observe_async(self, trace) -> None:
        """Feed a realized ``runtime.engine.AsyncTrace``: each worker's
        delay proxy is the gap between its consecutive applied updates
        (its first update counts from t=0)."""
        workers = np.asarray(trace.workers, dtype=int)
        times = np.asarray(trace.times, dtype=float)
        last = np.zeros(self.m)
        for u in range(workers.shape[0]):
            w = int(workers[u])
            if w < self.m:
                self.observe(w, times[u] - last[w])
                last[w] = times[u]

    def snapshot(self) -> dict:
        """JSON-safe per-worker state: the ``delay_tail_*`` metric family.

        ``ewma``/``p50``/``p95``/``p99`` are per-worker lists (None for a
        worker with no observations); ``p99_max`` / ``p99_mean`` aggregate
        the slowest tail across workers — the scalars an auto-tuner (or
        the metrics CSV) keys on.
        """
        ewma = [e.value for e in self._ewma]
        out = {"workers": self.m,
               "count": [t.count for t in self._tail],
               "ewma": ewma}
        for q in self.PERCENTILES:
            out[f"p{q}"] = [t.quantile(q) if t.count else None
                            for t in self._tail]
        p99 = [v for v in out["p99"] if v is not None]
        out["p99_max"] = max(p99) if p99 else None
        out["p99_mean"] = float(np.mean(p99)) if p99 else None
        if self._fault_schedules:
            # gated: clean-path snapshots keep their historical key set
            out["faults"] = {"schedules": self._fault_schedules,
                             "crashes": self._crashes,
                             "blackouts": self._blackouts,
                             "blackout_s": self._blackout_s,
                             "corrupt": self._corrupt}
        return out
