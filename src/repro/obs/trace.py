"""Structured tracing: straggler timelines + phase spans (DESIGN.md §11).

A :class:`TraceRecorder` captures two clock domains into one event stream:

  * **simulated time** — per-iteration straggler timelines from the
    ``ClusterEngine``: one ``iter`` event per barrier on the master lane and
    one ``worker`` event per (iteration, worker) with its arrival and
    active/erased flag; asynchronous runs contribute one ``update`` event
    per applied gradient with its staleness.  Batched (Monte-Carlo) runs
    record one lane group per realization.
  * **host time** — ``span`` events around the phases of a cell (``encode``,
    ``sample-schedule``, ``solve``, ``chunk``, ...), relative to recorder
    creation.

Recording is cheap by construction: the engine hands the recorder the
``Schedule`` / ``AsyncTrace`` it already built and the recorder stores a
*reference* (one list append); expansion into per-worker events happens only
at export/inspection time.  With no active recorder every hook is a single
``is None`` check — the disabled path does no work at all.

Exports: JSONL (``to_jsonl`` / ``TraceRecorder.load`` round-trip) and
Chrome/Perfetto ``trace_event`` JSON (``to_perfetto``) that opens directly
in ``chrome://tracing`` / https://ui.perfetto.dev with one process per
(cell, realization) sim lane group and one thread per worker.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from typing import Any, Iterator

import numpy as np

__all__ = ["TraceEvent", "TraceRecorder", "current_recorder", "span"]


# kinds measured on the host clock; everything else is simulated seconds
HOST_KINDS = ("span", "mark")
SIM_KINDS = ("iter", "worker", "update", "instant")


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One event of a trace.  ``ts``/``dur`` are seconds in the clock domain
    of ``kind`` (host-relative for spans/marks, simulated for the rest)."""
    kind: str
    name: str
    ts: float
    dur: float = 0.0
    lane: str = ""
    realization: int = 0
    step: int | None = None
    cell: str | None = None
    args: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if not d["args"]:
            d.pop("args")
        if d["step"] is None:
            d.pop("step")
        if d["cell"] is None:
            d.pop("cell")
        return d

    @staticmethod
    def from_dict(d: dict) -> "TraceEvent":
        return TraceEvent(
            kind=d["kind"], name=d["name"], ts=float(d["ts"]),
            dur=float(d.get("dur", 0.0)), lane=d.get("lane", ""),
            realization=int(d.get("realization", 0)), step=d.get("step"),
            cell=d.get("cell"), args=d.get("args", {}))


@dataclasses.dataclass(frozen=True)
class _SimSource:
    """A lazily-expanded engine artifact: the recorder keeps the reference,
    per-event expansion happens at export time."""
    tag: str                 # "schedule" | "async"
    obj: Any                 # runtime.engine Schedule / AsyncTrace
    realization: int
    cell: str | None


# ---------------------------------------------------------------------------
# Active-recorder plumbing (module global; one None-check when disabled)
# ---------------------------------------------------------------------------

_ACTIVE: "TraceRecorder | None" = None


def current_recorder() -> "TraceRecorder | None":
    """The recorder instrumentation hooks should emit into (None = off)."""
    return _ACTIVE


def span(name: str, **args):
    """Context manager recording a host-clock span on the active recorder;
    a shared no-op when tracing is disabled."""
    rec = _ACTIVE
    if rec is None:
        return contextlib.nullcontext()
    return rec.span(name, **args)


class TraceRecorder:
    """Collects trace events; activate with ``with recorder.activate():``."""

    def __init__(self, meta: dict | None = None):
        self.meta = dict(meta or {})
        self._t0 = time.perf_counter()
        self._entries: list = []        # TraceEvent | _SimSource, in order
        self._cell: str | None = None
        self._cache: list | None = None

    # -- activation -------------------------------------------------------

    @contextlib.contextmanager
    def activate(self):
        """Make this the process-wide active recorder for the block."""
        global _ACTIVE
        prev = _ACTIVE
        _ACTIVE = self
        try:
            yield self
        finally:
            _ACTIVE = prev

    # -- scoping ----------------------------------------------------------

    @contextlib.contextmanager
    def cell(self, label: str):
        """Attach ``label`` as the cell of every event recorded inside."""
        prev = self._cell
        self._cell = label
        try:
            yield self
        finally:
            self._cell = prev

    def checkpoint(self) -> int:
        """Entry-count marker; pair with :meth:`sources_since`."""
        return len(self._entries)

    def sources_since(self, mark: int) -> list:
        """The engine artifacts recorded after ``mark`` — the per-cell
        slice the metrics layer summarizes."""
        return [e for e in self._entries[mark:] if isinstance(e, _SimSource)]

    # -- host-clock spans ---------------------------------------------------

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    @contextlib.contextmanager
    def span(self, name: str, **args):
        t0 = self._now()
        try:
            yield self
        finally:
            self._append(TraceEvent(kind="span", name=name, ts=t0,
                                    dur=self._now() - t0, lane="host",
                                    cell=self._cell, args=args))

    def instant(self, name: str, **args) -> None:
        self._append(TraceEvent(kind="mark", name=name, ts=self._now(),
                                lane="host", cell=self._cell, args=args))

    # -- engine streams (lazy; one append each) -----------------------------

    def record_schedule(self, sched, *, realization: int = 0,
                        cell: str | None = None) -> None:
        """Record a realized synchronous ``Schedule`` (per-iteration
        straggler timeline: master barrier lane + one lane per worker)."""
        self._append(_SimSource("schedule", sched, realization,
                                cell if cell is not None else self._cell))

    def record_async(self, trace, *, realization: int = 0,
                     cell: str | None = None) -> None:
        """Record a realized ``AsyncTrace`` (per-applied-update events with
        staleness, clamped at this boundary — see :func:`_expand_async`)."""
        self._append(_SimSource("async", trace, realization,
                                cell if cell is not None else self._cell))

    def _append(self, entry) -> None:
        self._entries.append(entry)
        self._cache = None

    # -- materialization -----------------------------------------------------

    def events(self) -> list:
        """Every event, sim sources expanded, in recording order (cached)."""
        if self._cache is None:
            out: list = []
            for e in self._entries:
                if isinstance(e, TraceEvent):
                    out.append(e)
                elif e.tag == "schedule":
                    out.extend(_expand_schedule(e))
                else:
                    out.extend(_expand_async(e))
            self._cache = out
        return self._cache

    def iteration_events(self) -> list:
        return [e for e in self.events() if e.kind == "iter"]

    def worker_events(self) -> list:
        return [e for e in self.events() if e.kind == "worker"]

    def spans(self) -> list:
        return [e for e in self.events() if e.kind == "span"]

    # -- I/O -------------------------------------------------------------

    def to_jsonl(self, path: str) -> None:
        """One JSON object per line; line 1 is the recorder meta."""
        with open(path, "w") as f:
            f.write(json.dumps({"kind": "meta", "meta": self.meta}) + "\n")
            for ev in self.events():
                f.write(json.dumps(ev.to_dict()) + "\n")

    @classmethod
    def load(cls, path: str) -> "TraceRecorder":
        """Inverse of :meth:`to_jsonl` (events come back materialized)."""
        rec = cls()
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                d = json.loads(line)
                if d.get("kind") == "meta":
                    rec.meta.update(d.get("meta", {}))
                    continue
                rec._append(TraceEvent.from_dict(d))
        return rec

    def to_perfetto(self, path: str) -> None:
        """Chrome ``trace_event`` JSON: open in ``chrome://tracing`` or
        https://ui.perfetto.dev.  Host spans live in pid 0; every
        (cell, realization) sim lane group gets its own process with the
        master barrier timeline on tid 0 and worker i on tid i+1 (erased
        workers are greyed out)."""
        tev: list[dict] = [
            {"ph": "M", "pid": 0, "tid": 0, "name": "process_name",
             "args": {"name": "host (phase spans)"}},
        ]
        groups: dict[tuple, int] = {}
        named_tids: set = set()

        def pid_for(cell, realization) -> int:
            key = (cell, realization)
            if key not in groups:
                pid = 1 + len(groups)
                groups[key] = pid
                label = f"sim {cell or 'run'} [r{realization}]"
                tev.append({"ph": "M", "pid": pid, "tid": 0,
                            "name": "process_name", "args": {"name": label}})
            return groups[key]

        def tid_for(pid: int, lane: str) -> int:
            if lane.startswith("worker:"):
                tid, tname = int(lane.split(":", 1)[1]) + 1, lane
            else:
                tid, tname = 0, "master"
            if (pid, tid) not in named_tids:
                named_tids.add((pid, tid))
                tev.append({"ph": "M", "pid": pid, "tid": tid,
                            "name": "thread_name", "args": {"name": tname}})
            return tid

        for ev in self.events():
            args = dict(ev.args)
            if ev.step is not None:
                args["step"] = ev.step
            if ev.kind in HOST_KINDS:
                pid, tid = 0, 0
                if ev.cell is not None:
                    args["cell"] = ev.cell
            else:
                pid = pid_for(ev.cell, ev.realization)
                tid = tid_for(pid, ev.lane)
            base = {"name": ev.name, "pid": pid, "tid": tid,
                    "ts": ev.ts * 1e6, "args": args}
            if ev.dur > 0.0:
                base.update(ph="X", dur=ev.dur * 1e6)
                if ev.kind == "worker" and not ev.args.get("active", True):
                    base["cname"] = "grey"
            else:
                base.update(ph="i", s="t")
            tev.append(base)

        with open(path, "w") as f:
            json.dump({"traceEvents": tev, "displayTimeUnit": "ms",
                       "otherData": self.meta}, f)


# ---------------------------------------------------------------------------
# Source expansion
# ---------------------------------------------------------------------------

def _expand_schedule(src: _SimSource) -> Iterator[TraceEvent]:
    from repro.runtime.faults import FAULT_KINDS
    sched, r, cell = src.obj, src.realization, src.cell
    masks = np.asarray(sched.masks)
    # fault-model schedules carry per-(iter, worker) failure codes and the
    # realized fault timeline (getattr: hand-built schedules predate them)
    failed = getattr(sched, "failed", None)
    if failed is not None:
        failed = np.asarray(failed)
    for ev in sched.events:
        arrivals = np.asarray(ev.arrivals)
        row = masks[ev.t]
        yield TraceEvent(
            kind="iter", name=f"iter {ev.t}", ts=float(ev.start),
            dur=float(ev.commit - ev.start), lane="master", realization=r,
            step=int(ev.t), cell=cell,
            args={"active": [int(a) for a in ev.active],
                  "active_size": int(len(ev.active))})
        for i in range(sched.m):
            args = {"active": bool(row[i])}
            if failed is not None and failed[ev.t, i]:
                args["failed"] = FAULT_KINDS.get(int(failed[ev.t, i]),
                                                 str(int(failed[ev.t, i])))
            # a crashed/blacked-out worker never arrives: clamp its lane
            # event to the barrier instead of an infinite bar
            dur = float(arrivals[i] - ev.start)
            if not np.isfinite(dur):
                dur = float(ev.commit - ev.start)
            yield TraceEvent(
                kind="worker", name="compute", ts=float(ev.start),
                dur=dur, lane=f"worker:{i}",
                realization=r, step=int(ev.t), cell=cell, args=args)
    for fe in getattr(sched, "fault_events", ()):
        args = {"fault": fe.kind}
        if fe.duration:
            args["duration_s"] = float(fe.duration)
        if fe.t >= 0:
            args["step"] = int(fe.t)
        yield TraceEvent(
            kind="instant", name=f"fault:{fe.kind}", ts=float(fe.time),
            lane=f"worker:{int(fe.worker)}", realization=r, cell=cell,
            args=args)


def _expand_async(src: _SimSource) -> Iterator[TraceEvent]:
    """Per-applied-update events.  Staleness accounting is CLAMPED at this
    trace boundary: an event whose (read_version, staleness) pair is
    inconsistent with its update index (it would reference an update beyond
    the recorded stream, e.g. a hand-built or corrupted trace) is snapped
    into range and counted, instead of silently wrapping downstream
    consumers' ring buffers; the count is surfaced on the trailing
    ``async-summary`` event and by ``repro.obs.metrics.async_metrics``."""
    from .metrics import clamp_async_event
    tr, r, cell = src.obj, src.realization, src.cell
    workers = np.asarray(tr.workers)
    staleness = np.asarray(tr.staleness)
    reads = np.asarray(tr.read_versions)
    times = np.asarray(tr.times)
    U = int(workers.shape[0])
    clamped = 0
    for u in range(U):
        tau, rv, was = clamp_async_event(u, int(staleness[u]),
                                         int(reads[u]), U)
        clamped += was
        yield TraceEvent(
            kind="update", name="apply", ts=float(times[u]), dur=0.0,
            lane=f"worker:{int(workers[u])}", realization=r, step=u,
            cell=cell, args={"staleness": tau, "read_version": rv})
    for fe in getattr(tr, "fault_events", ()):
        args = {"fault": fe.kind}
        if fe.duration:
            args["duration_s"] = float(fe.duration)
        yield TraceEvent(
            kind="instant", name=f"fault:{fe.kind}", ts=float(fe.time),
            lane=f"worker:{int(fe.worker)}", realization=r, cell=cell,
            args=args)
    summary = {"updates": U, "dropped": int(tr.dropped),
               "staleness_clamped": clamped}
    corrupted = int(getattr(tr, "corrupted", 0))
    if corrupted:
        summary["corrupted"] = corrupted
    yield TraceEvent(
        kind="instant", name="async-summary",
        ts=float(times[-1]) if U else 0.0, lane="master", realization=r,
        cell=cell, args=summary)
