"""Indexed run store: every executed matrix leaves a provenance manifest.

The paper's claims are wall-clock claims, so runs must be comparable
across time and machines (DESIGN.md §13).  Every
``repro.experiments.execute`` (and the ``benchmarks.run`` suite) records
one **manifest** — spec hash, git sha, backend, jax version, device
count, ISO timestamp, per-cell result summaries, artifact paths — into a
store laid out as::

    runs/store/
      index.jsonl                  # one line per run (the query index)
      <run_id>/manifest.json       # run_id = <UTC stamp>-<spec_hash[:8]>

``python -m repro.obs.diff`` aligns two manifests cell-by-cell and gates
on regressions.  The store root comes from the ``REPRO_RUNSTORE`` env
var: unset -> ``runs/store`` under the current directory, a path ->
that directory, ``0``/empty -> recording disabled (benchmark timing
loops disable it explicitly instead, via ``execute(record_to=False)``).

Resumable runs (DESIGN.md §14): ``execute`` opens its manifest with
``status: "running"`` BEFORE the first cell and streams each completed
cell record to ``<run_id>/cells/<index>.json``; ``--resume RUN_ID``
replays those files (after a spec-hash check) and only executes the
cells that never finished.  ``python -m repro.obs.runstore prune`` keeps
the store bounded (``--keep N`` / ``--older-than DAYS``) and repairs the
index if run directories and index lines have drifted apart.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import subprocess
from datetime import datetime, timezone

__all__ = [
    "RunStore", "default_store", "runstore_enabled", "provenance",
    "git_sha", "spec_signature", "spec_hash", "record_experiment",
    "begin_experiment", "finish_experiment", "record_cell",
    "completed_cells", "prune",
]

ENV_VAR = "REPRO_RUNSTORE"
DEFAULT_ROOT = os.path.join("runs", "store")


# ---------------------------------------------------------------------------
# Provenance
# ---------------------------------------------------------------------------

def git_sha(cwd: str | None = None) -> str:
    """The current commit sha (``unknown`` outside a git checkout)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=cwd, capture_output=True,
            text=True, timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except Exception:
        pass
    return "unknown"


def provenance() -> dict:
    """The environment stamp every manifest (and ``BENCH_*.json``, via
    ``benchmarks.common.bench_meta``) carries: git sha, backend, jax
    version, device count, ISO-8601 UTC timestamp."""
    meta = {
        "git_sha": git_sha(),
        "timestamp": datetime.now(timezone.utc).isoformat(
            timespec="seconds"),
    }
    try:
        import jax
        meta["backend"] = jax.default_backend()
        meta["jax_version"] = jax.__version__
        meta["device_count"] = jax.device_count()
    except Exception:
        meta.update(backend="unavailable", jax_version="unavailable",
                    device_count=0)
    return meta


# ---------------------------------------------------------------------------
# Spec hashing
# ---------------------------------------------------------------------------

def _sig_value(v):
    """A canonical JSON-able stand-in for one spec field value."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (list, tuple)):
        return [_sig_value(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _sig_value(x) for k, x in sorted(v.items())}
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        try:
            return {f.name: _sig_value(getattr(v, f.name))
                    for f in dataclasses.fields(v)}
        except Exception:
            pass
    # array-likes / ProblemSpec / policy instances: identify by type +
    # shape-ish attributes, never by contents (hashing a 16 GiB matrix
    # would defeat the point; same-shaped respins intentionally collide)
    tag = {"type": type(v).__name__}
    for attr in ("n", "p", "m", "k", "shape", "name"):
        try:
            a = getattr(v, attr)
        except Exception:
            continue
        if isinstance(a, (bool, int, float, str)):
            tag[attr] = a
        elif isinstance(a, tuple):
            tag[attr] = [int(x) for x in a]
    return tag


def spec_signature(spec) -> dict:
    """Canonical JSON-safe description of an ``ExperimentSpec`` — the
    dataclass tree with problem/policy objects reduced to type + shape."""
    return _sig_value(spec)


def spec_hash(spec) -> str:
    """Stable 16-hex-digit hash of :func:`spec_signature` — the key two
    runs of the same declared matrix share."""
    blob = json.dumps(spec_signature(spec), sort_keys=True,
                      separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# The store
# ---------------------------------------------------------------------------

def runstore_enabled() -> bool:
    """False iff ``REPRO_RUNSTORE`` is set to ``0``/empty."""
    v = os.environ.get(ENV_VAR)
    return v is None or v not in ("", "0", "off", "false")


def default_store() -> "RunStore | None":
    """The process-default store (None when recording is disabled)."""
    if not runstore_enabled():
        return None
    root = os.environ.get(ENV_VAR) or DEFAULT_ROOT
    return RunStore(root)


class RunStore:
    """An append-only directory of run manifests with a JSONL index."""

    def __init__(self, root: str):
        self.root = str(root)

    @property
    def index_path(self) -> str:
        return os.path.join(self.root, "index.jsonl")

    def manifest_path(self, run_id: str) -> str:
        return os.path.join(self.root, run_id, "manifest.json")

    def cells_dir(self, run_id: str) -> str:
        """Per-cell record directory of one run (resume granularity)."""
        return os.path.join(self.root, run_id, "cells")

    # -- write ----------------------------------------------------------

    def _new_run_id(self, manifest: dict) -> str:
        stamp = datetime.now(timezone.utc).strftime("%Y%m%dT%H%M%SZ")
        tag = (manifest.get("spec_hash") or manifest.get("kind")
               or "run")[:8]
        base = f"{stamp}-{tag}"
        run_id, n = base, 1
        while os.path.exists(os.path.join(self.root, run_id)):
            n += 1
            run_id = f"{base}.{n}"
        return run_id

    def record(self, manifest: dict) -> str:
        """Assign a run id, write ``<run_id>/manifest.json`` and append
        the index line; returns the run id."""
        os.makedirs(self.root, exist_ok=True)
        manifest = dict(manifest)
        manifest.setdefault("kind", "experiment")
        if "timestamp" not in manifest:
            manifest.update(provenance())
        run_id = manifest.get("run_id") or self._new_run_id(manifest)
        manifest["run_id"] = run_id
        os.makedirs(os.path.join(self.root, run_id), exist_ok=True)
        with open(self.manifest_path(run_id), "w") as f:
            json.dump(manifest, f, indent=1)
        entry = {k: manifest.get(k) for k in
                 ("run_id", "kind", "spec_hash", "timestamp", "git_sha",
                  "backend", "label")}
        with open(self.index_path, "a") as f:
            f.write(json.dumps(entry) + "\n")
        return run_id

    def attach_artifacts(self, run_id: str, artifacts: dict) -> None:
        """Merge artifact paths (records JSON, metrics CSV, trace, ...)
        into an existing manifest."""
        manifest = self.load(run_id)
        arts = dict(manifest.get("artifacts") or {})
        arts.update({k: str(v) for k, v in artifacts.items()
                     if v is not None})
        manifest["artifacts"] = arts
        with open(self.manifest_path(manifest["run_id"]), "w") as f:
            json.dump(manifest, f, indent=1)

    # -- query ----------------------------------------------------------

    def runs(self) -> list[dict]:
        """Index entries, oldest first (corrupt lines skipped)."""
        if not os.path.exists(self.index_path):
            return []
        out = []
        with open(self.index_path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    out.append(json.loads(line))
                except json.JSONDecodeError:
                    continue
        return out

    def load(self, ref: str) -> dict:
        """Load one manifest by run id (or unique prefix), or by path to
        a manifest file / run directory."""
        if os.path.isdir(ref):
            ref = os.path.join(ref, "manifest.json")
        if os.path.isfile(ref):
            with open(ref) as f:
                return json.load(f)
        path = self.manifest_path(ref)
        if os.path.isfile(path):
            with open(path) as f:
                return json.load(f)
        matches = [r["run_id"] for r in self.runs()
                   if r.get("run_id", "").startswith(ref)]
        if len(matches) == 1:
            return self.load(matches[0])
        if len(matches) > 1:
            raise KeyError(f"run ref '{ref}' is ambiguous: {matches}")
        raise KeyError(f"no run '{ref}' in store {self.root}")

    def latest(self, *, spec_hash: str | None = None,
               kind: str | None = None, offset: int = 0) -> dict | None:
        """The manifest of the most recent matching run (``offset`` steps
        back in time); None when nothing matches."""
        rows = [r for r in self.runs()
                if (spec_hash is None or r.get("spec_hash") == spec_hash)
                and (kind is None or r.get("kind") == kind)]
        if offset >= len(rows):
            return None
        return self.load(rows[-1 - offset]["run_id"])

    def resolve(self, ref: str) -> dict:
        """Resolve a CLI run reference: ``latest`` / ``latest~N`` (N runs
        back), a run id or unique prefix, or a path."""
        if ref == "latest":
            m = self.latest()
            if m is None:
                raise KeyError(f"store {self.root} is empty")
            return m
        if ref.startswith("latest~"):
            off = int(ref.split("~", 1)[1])
            m = self.latest(offset=off)
            if m is None:
                raise KeyError(f"store {self.root} has no run {ref}")
            return m
        return self.load(ref)


# ---------------------------------------------------------------------------
# Experiment wiring
# ---------------------------------------------------------------------------

def record_experiment(result, *, store: "RunStore | None" = None,
                      artifacts: dict | None = None) -> str | None:
    """Write the manifest of one ``ExperimentResult`` (see
    ``repro.experiments.execute``); returns the run id, or None when
    recording is disabled and no explicit store was given."""
    from .analyze import summarize_records
    if store is None:
        store = default_store()
        if store is None:
            return None
    spec = result.spec
    manifest = {
        "kind": "experiment",
        "spec_hash": spec_hash(spec),
        "spec": spec_signature(spec),
        **provenance(),
        "cells": summarize_records(result.records),
        "artifacts": {k: str(v) for k, v in (artifacts or {}).items()},
    }
    return store.record(manifest)


# ---------------------------------------------------------------------------
# Resumable runs: running manifest + streamed per-cell records
# ---------------------------------------------------------------------------

def begin_experiment(spec, *, store: "RunStore | None" = None,
                     total_cells: int = 0) -> str | None:
    """Open a ``status: "running"`` manifest BEFORE the first cell runs,
    so a killed matrix leaves a resumable run id behind.  Returns the run
    id (None when recording is disabled)."""
    if store is None:
        store = default_store()
        if store is None:
            return None
    manifest = {
        "kind": "experiment",
        "status": "running",
        "spec_hash": spec_hash(spec),
        "spec": spec_signature(spec),
        **provenance(),
        "total_cells": int(total_cells),
        "cells": [],
    }
    return store.record(manifest)


def record_cell(store: "RunStore", run_id: str, index: int,
                record: dict) -> None:
    """Stream one completed cell record to ``<run_id>/cells/<index>.json``
    (atomic rename so a kill mid-write never leaves a truncated record)."""
    d = store.cells_dir(run_id)
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{index:04d}.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(record, f)
    os.replace(tmp, path)


def completed_cells(store: "RunStore", run_id: str) -> dict:
    """The streamed cell records of one run, ``{cell index: record}``
    (corrupt/truncated files are treated as never-completed)."""
    d = store.cells_dir(run_id)
    if not os.path.isdir(d):
        return {}
    out: dict = {}
    for name in sorted(os.listdir(d)):
        if not name.endswith(".json"):
            continue
        try:
            with open(os.path.join(d, name)) as f:
                out[int(name[:-len(".json")])] = json.load(f)
        except (ValueError, json.JSONDecodeError, OSError):
            continue
    return out


def finish_experiment(result, store: "RunStore", run_id: str) -> str:
    """Finalize a :func:`begin_experiment` manifest: cell summaries in,
    ``status`` -> ``complete``."""
    from .analyze import summarize_records
    manifest = store.load(run_id)
    manifest["status"] = "complete"
    manifest["cells"] = summarize_records(result.records)
    with open(store.manifest_path(manifest["run_id"]), "w") as f:
        json.dump(manifest, f, indent=1)
    return run_id


# ---------------------------------------------------------------------------
# Store maintenance: prune + index consistency
# ---------------------------------------------------------------------------

def _run_dirs(store: "RunStore") -> list[str]:
    if not os.path.isdir(store.root):
        return []
    return sorted(d for d in os.listdir(store.root)
                  if os.path.isfile(store.manifest_path(d)))


def prune(store: "RunStore", *, keep: int | None = None,
          older_than_days: float | None = None,
          dry_run: bool = False) -> dict:
    """Bound the store: delete run directories beyond the newest ``keep``
    and/or older than ``older_than_days``, then rewrite ``index.jsonl`` to
    exactly match the surviving run directories (repairing any drift:
    index lines whose directory is gone, directories the index never
    heard of).  Returns ``{"kept": [...], "removed": [...], "repaired":
    n}``; ``dry_run`` reports without touching disk."""
    entries = {r["run_id"]: r for r in store.runs() if r.get("run_id")}
    dirs = _run_dirs(store)
    # timestamp per run: index entry if present, else the manifest's
    stamps = {}
    for rid in dirs:
        ts = (entries.get(rid) or {}).get("timestamp")
        if ts is None:
            try:
                ts = store.load(rid).get("timestamp")
            except Exception:
                ts = None
        stamps[rid] = ts or ""
    ordered = sorted(dirs, key=lambda rid: (stamps[rid], rid))
    removed = set()
    if older_than_days is not None:
        from datetime import timedelta
        cutoff = (datetime.now(timezone.utc)
                  - timedelta(days=float(older_than_days)))
        for rid in ordered:
            try:
                when = datetime.fromisoformat(stamps[rid])
            except ValueError:
                continue        # unparseable stamp: never age-prune it
            if when < cutoff:
                removed.add(rid)
    if keep is not None:
        survivors = [rid for rid in ordered if rid not in removed]
        if keep >= 0 and len(survivors) > keep:
            removed.update(survivors[:len(survivors) - keep])
    kept = [rid for rid in ordered if rid not in removed]
    # index repair: lines without a directory are drift either way
    orphan_lines = [rid for rid in entries if rid not in set(dirs)]
    orphan_dirs = [rid for rid in dirs if rid not in entries]
    repaired = len(orphan_lines) + len(orphan_dirs)
    if not dry_run:
        for rid in sorted(removed):
            shutil.rmtree(os.path.join(store.root, rid),
                          ignore_errors=True)
        lines = []
        for rid in kept:
            entry = entries.get(rid)
            if entry is None:      # directory the index never heard of
                m = store.load(rid)
                entry = {k: m.get(k) for k in
                         ("run_id", "kind", "spec_hash", "timestamp",
                          "git_sha", "backend", "label")}
            lines.append(json.dumps(entry))
        os.makedirs(store.root, exist_ok=True)
        tmp = store.index_path + ".tmp"
        with open(tmp, "w") as f:
            f.write("".join(line + "\n" for line in lines))
        os.replace(tmp, store.index_path)
    return {"kept": kept, "removed": sorted(removed), "repaired": repaired}


def main(argv=None) -> int:
    """``python -m repro.obs.runstore`` — store maintenance CLI."""
    import argparse
    ap = argparse.ArgumentParser(
        prog="repro.obs.runstore",
        description="run-store maintenance (REPRO_RUNSTORE or --store)")
    ap.add_argument("--store", default=None,
                    help="store root (default: REPRO_RUNSTORE / runs/store)")
    sub = ap.add_subparsers(dest="cmd", required=True)
    lp = sub.add_parser("list", help="print the index, oldest first")
    pp = sub.add_parser("prune",
                        help="bound the store and repair the index")
    pp.add_argument("--keep", type=int, default=None, metavar="N",
                    help="keep only the N newest runs")
    pp.add_argument("--older-than", type=float, default=None,
                    metavar="DAYS", help="drop runs older than DAYS days")
    pp.add_argument("--dry-run", action="store_true",
                    help="report what would be removed; touch nothing")
    del lp
    args = ap.parse_args(argv)
    store = (RunStore(args.store) if args.store is not None
             else default_store())
    if store is None:
        print("runstore: recording disabled (REPRO_RUNSTORE=0)")
        return 1
    if args.cmd == "list":
        for r in store.runs():
            print(json.dumps(r))
        return 0
    if args.keep is None and args.older_than is None:
        # a bare prune is still useful: it repairs index drift
        print("# no --keep/--older-than: repairing the index only")
    out = prune(store, keep=args.keep, older_than_days=args.older_than,
                dry_run=args.dry_run)
    tag = "would remove" if args.dry_run else "removed"
    print(f"runstore prune: kept {len(out['kept'])}, {tag} "
          f"{len(out['removed'])}, repaired {out['repaired']} index "
          f"entries in {store.root}")
    for rid in out["removed"]:
        print(f"  - {rid}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
