"""Declarative experiment axes (DESIGN.md §10).

An :class:`ExperimentSpec` is the single way to say "run this matrix": a
frozen dataclass tree naming every axis of the paper's §5 protocol —

  * :class:`ProblemAxis`    — WHAT is solved: a synthetic quadratic, a
    concrete ``ProblemSpec``, or a registered workload at a preset;
  * :class:`StrategyAxis`   — WHO solves it: registry strategy name (or the
    per-workload ``'coded'`` alias) + encoder + policy / async config;
  * :class:`DelayAxis`      — the simulated cluster: delay models, worker
    count, per-iteration compute time;
  * :class:`TrialsAxis`     — the Monte-Carlo axis: R delay realizations,
    objective record stride, master seed;
  * :class:`PlacementAxis`  — HOW the realization axis executes: one run
    per realization (``single``), one vmapped program (``vmap``), or
    ``shard_map`` over the device mesh (``sharded``).

Specs never execute anything themselves: ``plan(spec)`` compiles the axes
into an explicit cell list and ``execute(plan)`` runs it (see
``experiments.plan`` / ``experiments.execute``).
"""
from __future__ import annotations

import dataclasses
from typing import Any

__all__ = [
    "ProblemAxis", "StrategyAxis", "DelayAxis", "TrialsAxis",
    "PlacementAxis", "ObsAxis", "ExperimentSpec", "PLACEMENTS",
]


PLACEMENTS = ("single", "vmap", "sharded")


@dataclasses.dataclass(frozen=True)
class ProblemAxis:
    """One problem of the matrix.  Three variants, selected by ``kind``:

    * ``'synthetic'`` — the compare harness's quadratic:
      f(w) = 1/(2n)||Xw - y||^2 + lam h(w) on an lsq dataset of shape
      (n, p), built at plan time with the spec's master seed;
    * ``'spec'``      — a concrete, caller-built ``runtime.ProblemSpec``
      (arbitrary data) carried verbatim in ``problem``;
    * ``'workload'``  — a registered paper-§5 workload (ridge / lasso /
      logistic / mf) at one of its presets; the preset owns dims, cluster
      shape, step budget and the paper metric;
    * ``'train'``     — a neural LM from the model zoo trained with coded
      SGD (``repro.train.TrainProblem``; DESIGN §15): ``arch`` names the
      architecture, ``preset`` picks ``smoke``/``100m``, and the metric is
      the decoded training loss.
    """
    kind: str = "synthetic"
    # -- synthetic fields --
    n: int = 512
    p: int = 128
    noise: float = 0.5
    lam: float = 0.05
    h: str = "l2"
    seed: int | None = None        # None -> the spec's TrialsAxis seed
    # -- spec variant --
    problem: Any = None            # a runtime.ProblemSpec instance
    # -- workload variant --
    workload: str | None = None
    preset: str = "smoke"          # also the train-variant preset
    # -- train variant --
    arch: str | None = None
    seq_len: int = 64
    rows_per_worker: int = 1
    vocab: int = 512

    @staticmethod
    def synthetic(n: int = 512, p: int = 128, *, noise: float = 0.5,
                  lam: float = 0.05, h: str = "l2",
                  seed: int | None = None) -> "ProblemAxis":
        return ProblemAxis(kind="synthetic", n=n, p=p, noise=noise, lam=lam,
                           h=h, seed=seed)

    @staticmethod
    def from_spec(problem) -> "ProblemAxis":
        return ProblemAxis(kind="spec", problem=problem)

    @staticmethod
    def from_workload(name: str, preset: str = "smoke") -> "ProblemAxis":
        return ProblemAxis(kind="workload", workload=name, preset=preset)

    @staticmethod
    def train(arch: str = "deepseek-7b", *, preset: str = "smoke",
              seq_len: int = 64, rows_per_worker: int = 1,
              vocab: int = 512) -> "ProblemAxis":
        return ProblemAxis(kind="train", arch=arch, preset=preset,
                           seq_len=seq_len, rows_per_worker=rows_per_worker,
                           vocab=vocab)

    def validate(self) -> None:
        if self.kind not in ("synthetic", "spec", "workload", "train"):
            raise ValueError(f"unknown ProblemAxis kind '{self.kind}'")
        if self.kind == "workload" and not self.workload:
            raise ValueError("workload ProblemAxis needs a workload name")
        if self.kind == "spec" and self.problem is None:
            raise ValueError("spec ProblemAxis needs a ProblemSpec instance")
        if self.kind == "train" and not self.arch:
            raise ValueError("train ProblemAxis needs an arch name")


@dataclasses.dataclass(frozen=True)
class StrategyAxis:
    """One strategy column: registry name plus its per-strategy config.

    ``encoder=None`` keeps the strategy's own default; sync strategies read
    the policy fields, ``async`` reads ``staleness_bound`` /
    ``async_updates``.  ``options`` is an escape hatch of extra ``(key,
    value)`` pairs forwarded verbatim to the strategy/workload call
    (``step_size=``, ``memory=``, a prebuilt policy instance, ...).
    """
    name: str
    encoder: str | Any | None = None   # registry name or LinearEncoder
    policy: str | None = None          # None -> fastest-k
    k: int | None = None               # None -> 3m/4 (synthetic) / preset k
    deadline: float = 1.0              # --policy deadline budget
    policy_beta: float = 2.0           # --policy adaptive-k overlap beta
    staleness_bound: int | None = None   # async only
    async_updates: int | None = None     # async only
    # sub-k degradation policy (repro.runtime.faults.make_degrade spec:
    # 'renormalize' | 'hold[:shrink=..]' | 'backoff[:base=..,retries=..]');
    # None keeps the default renormalized decode weights
    degrade: str | None = None
    options: tuple = ()                # extra (key, value) cfg pairs

    def options_dict(self) -> dict:
        return dict(self.options)


@dataclasses.dataclass(frozen=True)
class DelayAxis:
    """The simulated cluster: which delay distributions, how many workers.

    ``delays=()`` means "each workload's native paper delay model" (only
    valid when every problem is a workload).  ``m=None`` defers to the
    workload preset (or the compare default of 16 for synthetic problems).
    """
    delays: tuple = ()
    m: int | None = None
    compute_time: float = 0.05
    # fault-injection spec (repro.runtime.faults.make_fault_model grammar,
    # e.g. 'crash:p=0.2,at=0.5;corrupt:p=0.05'); None = delay-only cluster
    faults: str | None = None

    @staticmethod
    def of(*delays: str, m: int | None = None,
           compute_time: float = 0.05,
           faults: str | None = None) -> "DelayAxis":
        return DelayAxis(delays=tuple(delays), m=m,
                         compute_time=compute_time, faults=faults)


@dataclasses.dataclass(frozen=True)
class TrialsAxis:
    """The Monte-Carlo axis: R delay realizations per cell, each seeded
    from the master ``seed`` via the ``(seed, r)`` child stream (DESIGN.md
    §9).  ``eval_every=s`` records the objective every s steps inside the
    compiled loop; ``eval_every=0`` records the final objective only."""
    trials: int = 1
    eval_every: int = 1
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class PlacementAxis:
    """How the realization axis is placed on hardware:

    * ``'single'``  — one run per realization, host loop (the pre-§9 path;
      also what non-batchable lowerings do regardless of placement);
    * ``'vmap'``    — all R realizations in ONE compiled program on one
      device (``jax.vmap`` over the leading axis, DESIGN.md §9);
    * ``'sharded'`` — R realizations ``shard_map``-ped across the local
      device mesh on a ``trials`` axis, vmapped within each shard; falls
      back to ``vmap`` when one device is present or R is not divisible
      by the device count.

    ``cell_batch=True`` (opt-in, ``mode='vmap'`` only) additionally stacks
    COMPATIBLE cells of the matrix — same problem, strategy, encoder
    config, worker count, step budget and trial count, differing only in
    delay model / policy / step size — into one compiled program along the
    realization axis (``Strategy.run_cellbatched``), so the matrix runs
    device-resident instead of re-entering jit per cell.  Incompatible
    cells and obs-enabled runs fall back to per-cell execution.
    """
    mode: str = "vmap"
    mesh_axis: str = "trials"
    cell_batch: bool = False

    def validate(self) -> None:
        if self.mode not in PLACEMENTS:
            raise ValueError(f"unknown placement '{self.mode}'; have "
                             f"{PLACEMENTS}")


@dataclasses.dataclass(frozen=True)
class ObsAxis:
    """The observability axis (DESIGN.md §11): what ``execute`` records
    about HOW the matrix ran, on top of what it computed.

    All fields default off, and the default path is bit-identical to a run
    without the axis — records only grow ``host_s``/``compile_s``/
    ``execute_s``/``obs`` keys when ``enabled``, so legacy comparisons
    (execute == compare/workloads.run) stay exact.

    * ``trace``   — path prefix; write ``<trace>.jsonl`` (the canonical
      event stream) and ``<trace>.perfetto.json`` (Chrome/Perfetto
      ``trace_event`` view) after the matrix;
    * ``profile`` — directory; capture a ``jax.profiler`` trace per cell
      under ``<profile>/<cell>/`` plus device-memory high-water marks;
    * ``metrics`` — attach per-cell straggler metrics (miss-rate,
      active-set distribution, staleness histogram, latency percentiles)
      and the compile/execute split to every record.

    ``trace``/``profile`` imply ``metrics``-grade recording: any enabled
    field activates the :class:`repro.obs.TraceRecorder` for the run.
    """
    trace: str | None = None
    profile: str | None = None
    metrics: bool = False

    @property
    def enabled(self) -> bool:
        return bool(self.trace or self.profile or self.metrics)


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """The full declarative experiment: problems x strategies x delays,
    run for R realizations under one placement.

    ``steps`` overrides every problem's iteration budget (synthetic
    default 200; workload presets own theirs).  Compile with
    ``experiments.plan``, run with ``experiments.execute``.
    """
    problems: tuple
    strategies: tuple
    delays: DelayAxis = DelayAxis()
    trials: TrialsAxis = TrialsAxis()
    placement: PlacementAxis = PlacementAxis()
    steps: int | None = None
    obs: ObsAxis = ObsAxis()

    def validate(self) -> None:
        if not self.problems:
            raise ValueError("ExperimentSpec needs at least one problem")
        if not self.strategies:
            raise ValueError("ExperimentSpec needs at least one strategy")
        for pr in self.problems:
            pr.validate()
        self.placement.validate()
        if self.trials.trials < 1:
            raise ValueError("trials must be >= 1")
        if not self.delays.delays:
            for pr in self.problems:
                if pr.kind != "workload":
                    raise ValueError(
                        "DelayAxis.delays may only be empty (= workload-"
                        "native delay models) when every problem is a "
                        "workload")
