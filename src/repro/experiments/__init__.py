"""repro.experiments — declarative spec -> plan -> execute (DESIGN.md §10).

ONE way to express every paper-§5 matrix: an :class:`ExperimentSpec`
(problems x strategies x delays x trials x placement) compiles to an
explicit :class:`ExperimentPlan` (skip-with-reason cells materialized up
front) and runs to an :class:`ExperimentResult` with one canonical record
per cell and shared JSON/CSV writers.

    from repro.experiments import (DelayAxis, ExperimentSpec, ProblemAxis,
                                   StrategyAxis, TrialsAxis, run)
    result = run(ExperimentSpec(
        problems=(ProblemAxis.from_workload("ridge"),),
        strategies=(StrategyAxis("coded"), StrategyAxis("uncoded")),
        trials=TrialsAxis(trials=8)))
    result.to_json("runs/ridge.json")

CLI:  PYTHONPATH=src python -m repro.experiments.run \\
          --workloads ridge --strategies coded,uncoded \\
          --trials 8 --placement sharded

The legacy ``runtime.compare`` and ``workloads.run`` CLIs are thin
front-ends over this path (see DESIGN.md §10 for the migration table).
"""
from .execute import (CellOutcome, ExperimentResult, cell_label, execute,
                      resolve_policy, run, trials_record)
from .io import (print_table, trace_rows, write_json, write_metrics_csv,
                 write_summary_csv, write_trace_csv)
from .plan import ExperimentPlan, PlannedCell, plan
from .spec import (PLACEMENTS, DelayAxis, ExperimentSpec, ObsAxis,
                   PlacementAxis, ProblemAxis, StrategyAxis, TrialsAxis)

__all__ = [
    "PLACEMENTS", "ProblemAxis", "StrategyAxis", "DelayAxis", "TrialsAxis",
    "PlacementAxis", "ObsAxis", "ExperimentSpec", "PlannedCell",
    "ExperimentPlan", "plan", "CellOutcome", "ExperimentResult", "execute",
    "run", "resolve_policy", "trials_record", "cell_label", "write_json",
    "write_trace_csv", "write_summary_csv", "write_metrics_csv",
    "trace_rows", "print_table",
]
