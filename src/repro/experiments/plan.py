"""Compile an :class:`ExperimentSpec` into an explicit cell list.

``plan(spec)`` resolves every axis product up front — one
:class:`PlannedCell` per (problem, delay, strategy) with its worker count,
fastest-k, step budget and placement already decided — so ``execute`` is a
dumb loop and callers can inspect/filter/price a matrix before running it.
Cells that can never run (unknown strategy for a workload, a strategy the
workload's lowering cannot express) are materialized as skip-with-reason
cells HERE, carrying the exact reason the record will report.

Harness misconfigurations that would poison every cell (an ``eval_every``
that does not divide the step budget, an empty delay axis for a synthetic
problem) raise at plan time instead of emitting a matrix of skips.
"""
from __future__ import annotations

import dataclasses

from .spec import ExperimentSpec, ProblemAxis, StrategyAxis

__all__ = ["PlannedCell", "ExperimentPlan", "plan"]

# compare-harness defaults for synthetic problems (workload presets own
# their own cluster shape and step budget)
SYNTHETIC_M = 16
SYNTHETIC_STEPS = 200

# train-kind defaults: a tiny coded-DP cluster and a step budget sized so a
# smoke LM cell stays in CI territory (the example/bench drive longer runs)
TRAIN_M = 8
TRAIN_STEPS = 12

# strategies a train-kind cell can lower to: coded-sgd natively; 'uncoded'
# maps onto the same trainer with the identity code (the no-redundancy
# baseline).  Everything else is a convex-problem scheme.
_TRAIN_STRATEGIES = ("coded-sgd", "uncoded")


def _default_k(m: int) -> int:
    return max(1, (3 * m) // 4)


@dataclasses.dataclass(frozen=True)
class PlannedCell:
    """One fully resolved cell of the matrix."""
    index: int
    problem: ProblemAxis
    strategy: StrategyAxis
    resolved_strategy: str       # 'coded' alias resolved per workload
    delay: str
    m: int                       # engine worker count
    k: int | None                # fastest-k (None -> workload preset's k)
    steps: int | None            # None -> workload preset's budget
    trials: int
    eval_every: int
    seed: int
    placement: str
    compute_time: float
    skip: str | None = None      # pre-materialized skip reason
    metric_name: str = "objective"
    faults: str | None = None    # DelayAxis fault-injection spec
    degrade: str | None = None   # StrategyAxis sub-k degradation spec

    @property
    def kind(self) -> str:
        return self.problem.kind


@dataclasses.dataclass(frozen=True)
class ExperimentPlan:
    """The compiled experiment: the spec plus its explicit cell list."""
    spec: ExperimentSpec
    cells: tuple

    def __len__(self) -> int:
        return len(self.cells)

    @property
    def skipped(self) -> tuple:
        return tuple(c for c in self.cells if c.skip is not None)

    def describe(self) -> str:
        lines = [f"ExperimentPlan: {len(self.cells)} cells "
                 f"({len(self.skipped)} pre-skipped), "
                 f"trials={self.spec.trials.trials}, "
                 f"placement={self.spec.placement.mode}"]
        for c in self.cells:
            tag = (f"  [{c.index:3d}] "
                   f"{c.problem.workload or c.problem.kind:10s} "
                   f"{c.resolved_strategy:14s} x {c.delay:12s} "
                   f"m={c.m}")
            if c.skip is not None:
                tag += f"  SKIP: {c.skip}"
            lines.append(tag)
        return "\n".join(lines)


def plan(spec: ExperimentSpec) -> ExperimentPlan:
    """Resolve the axis product into an explicit, validated cell list."""
    from repro.runtime.faults import make_degrade, make_fault_model
    from repro.runtime.strategies import check_trials, get_strategy
    from repro.workloads import get_workload

    spec.validate()
    # malformed fault / degrade specs poison every cell -> raise at plan time
    make_fault_model(spec.delays.faults)
    for st in spec.strategies:
        make_degrade(st.degrade)
    tr, pl = spec.trials, spec.placement
    cells: list[PlannedCell] = []
    for pr in spec.problems:
        if pr.kind == "workload":
            wl = get_workload(pr.workload)
            ps = wl.preset(pr.preset)
            check_trials(spec.steps if spec.steps is not None else ps.steps,
                         tr.trials, tr.eval_every)
            m = spec.delays.m if spec.delays.m is not None else ps.m
            delays = spec.delays.delays or (ps.delay,)
            for delay in delays:
                for st in spec.strategies:
                    resolved = wl.resolve_strategy(st.name)
                    cells.append(PlannedCell(
                        index=len(cells), problem=pr, strategy=st,
                        resolved_strategy=resolved, delay=delay, m=m,
                        k=st.k, steps=spec.steps, trials=tr.trials,
                        eval_every=tr.eval_every, seed=tr.seed,
                        placement=pl.mode,
                        compute_time=spec.delays.compute_time,
                        skip=wl.skip_reason(st.name),
                        metric_name=wl.metric_name,
                        faults=spec.delays.faults, degrade=st.degrade))
        elif pr.kind == "train":
            steps = spec.steps if spec.steps is not None else TRAIN_STEPS
            check_trials(steps, tr.trials, tr.eval_every)
            m = spec.delays.m if spec.delays.m is not None else TRAIN_M
            for delay in spec.delays.delays:
                for st in spec.strategies:
                    get_strategy(st.name)   # unknown name -> KeyError now
                    skip = (None if st.name in _TRAIN_STRATEGIES else
                            f"strategy '{st.name}' has no train-kind "
                            f"lowering (coded-sgd/uncoded only)")
                    cells.append(PlannedCell(
                        index=len(cells), problem=pr, strategy=st,
                        resolved_strategy=st.name, delay=delay, m=m,
                        k=st.k if st.k is not None else _default_k(m),
                        steps=steps, trials=tr.trials,
                        eval_every=tr.eval_every, seed=tr.seed,
                        placement=pl.mode,
                        compute_time=spec.delays.compute_time,
                        skip=skip, metric_name="loss",
                        faults=spec.delays.faults, degrade=st.degrade))
        else:
            steps = spec.steps if spec.steps is not None else SYNTHETIC_STEPS
            check_trials(steps, tr.trials, tr.eval_every)
            m = spec.delays.m if spec.delays.m is not None else SYNTHETIC_M
            for delay in spec.delays.delays:
                for st in spec.strategies:
                    get_strategy(st.name)   # unknown name -> KeyError now
                    cells.append(PlannedCell(
                        index=len(cells), problem=pr, strategy=st,
                        resolved_strategy=st.name, delay=delay, m=m,
                        k=st.k if st.k is not None else _default_k(m),
                        steps=steps, trials=tr.trials,
                        eval_every=tr.eval_every, seed=tr.seed,
                        placement=pl.mode,
                        compute_time=spec.delays.compute_time,
                        metric_name="objective",
                        faults=spec.delays.faults, degrade=st.degrade))
    return ExperimentPlan(spec=spec, cells=tuple(cells))
