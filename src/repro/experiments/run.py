"""``python -m repro.experiments.run`` — the unified experiment CLI.

One command for every strategy x delay x workload x trials x placement
cell the paper's §5 protocol needs:

    # synthetic quadratic (the old runtime.compare matrix)
    PYTHONPATH=src python -m repro.experiments.run \\
        --strategies coded-gd,uncoded,async --delays bimodal,power_law

    # workload matrix (the old workloads.run matrix)
    PYTHONPATH=src python -m repro.experiments.run \\
        --workloads ridge,logistic --strategies coded,uncoded \\
        --trials 8 --placement sharded

    # coded-SGD train matrix over the model zoo (DESIGN §15)
    PYTHONPATH=src python -m repro.experiments.run \\
        --train deepseek-7b --strategies coded-sgd,uncoded \\
        --delays bimodal --code cyclic --steps 3

Argv is parsed into an :class:`ExperimentSpec`, compiled with ``plan`` and
run with ``execute`` — exactly the path the legacy ``runtime.compare`` and
``workloads.run`` CLIs now delegate to.  ``--plan-only`` prints the
resolved cell list (including pre-materialized skips) without running.
"""
from __future__ import annotations

import argparse
import os
from typing import Sequence

from .execute import ExperimentResult, execute
from .plan import plan
from .spec import (DelayAxis, ExperimentSpec, ObsAxis, PlacementAxis,
                   ProblemAxis, StrategyAxis, TrialsAxis)

__all__ = ["build_spec", "main"]


def _csv_list(s: str | None) -> list[str]:
    return [x.strip() for x in (s or "").split(",") if x.strip()]


def build_spec(args: argparse.Namespace) -> ExperimentSpec:
    """An ``ExperimentSpec`` from parsed CLI args (shared by this CLI and
    the legacy front-ends)."""
    delays = tuple(_csv_list(args.delays))
    train = _csv_list(getattr(args, "train", None))
    if train:
        problems = tuple(
            ProblemAxis.train(a, preset=args.preset,
                              seq_len=getattr(args, "seq_len", 64))
            for a in train)
        if not delays:
            delays = ("bimodal",)     # train cells need an explicit model
    elif args.workloads:
        problems = tuple(ProblemAxis.from_workload(w, args.preset)
                         for w in _csv_list(args.workloads))
    else:
        problems = (ProblemAxis.synthetic(args.n, args.p, noise=args.noise,
                                          lam=args.lam, h=args.h),)
        if not delays:
            delays = ("bimodal", "power_law", "exponential")
    # --code only means something to train-kind coded-sgd cells; other
    # strategies would reject the unknown kwarg
    code_opts = ((("code", args.code),)
                 if train and getattr(args, "code", None) else ())
    strategies = tuple(
        StrategyAxis(name=s, encoder=args.encoder, policy=args.policy,
                     k=args.k, deadline=args.deadline,
                     policy_beta=args.policy_beta,
                     staleness_bound=args.staleness_bound,
                     async_updates=args.async_updates,
                     degrade=getattr(args, "degrade", None),
                     options=code_opts)
        for s in _csv_list(args.strategies))
    # the legacy front-ends share build_spec but not the obs flags, hence
    # getattr defaults — their specs get the all-off ObsAxis
    obs = ObsAxis(trace=getattr(args, "trace", None),
                  profile=getattr(args, "profile", None),
                  metrics=bool(getattr(args, "metrics_out", None)
                               or getattr(args, "metrics", False)))
    return ExperimentSpec(
        problems=problems, strategies=strategies,
        delays=DelayAxis(delays=delays, m=args.m,
                         compute_time=args.compute_time,
                         faults=getattr(args, "faults", None)),
        trials=TrialsAxis(trials=args.trials, eval_every=args.eval_every,
                          seed=args.seed),
        placement=PlacementAxis(mode=args.placement,
                                cell_batch=getattr(args, "cell_batch",
                                                   False)),
        steps=args.steps, obs=obs)


def add_axis_flags(ap: argparse.ArgumentParser, *,
                   strategies: str = "coded-gd,uncoded,replication,async",
                   delays: str | None = "bimodal,power_law,exponential",
                   encoder: str | None = None,
                   policy: str | None = None) -> None:
    """The axis flags shared by this CLI and the legacy front-ends (their
    historical defaults differ, hence the parameters)."""
    from repro.core.encoding import available_encoders
    from repro.runtime.strategies import available_strategies
    ap.add_argument("--strategies", default=strategies,
                    help=f"comma list from {available_strategies()}; with "
                         f"--workloads, 'coded' resolves per workload")
    ap.add_argument("--delays", default=delays,
                    help="comma list of delay models (empty with "
                         "--workloads: each workload's native model)")
    ap.add_argument("--n", type=int, default=512)
    ap.add_argument("--p", type=int, default=128)
    ap.add_argument("--noise", type=float, default=0.5)
    ap.add_argument("--lam", type=float, default=0.05)
    ap.add_argument("--h", default="l2", choices=["l2", "l1", "none"])
    ap.add_argument("--m", type=int, default=None,
                    help="workers (default 16; workload presets own this)")
    ap.add_argument("--k", type=int, default=None,
                    help="fastest-k (default 3m/4 / preset k)")
    ap.add_argument("--steps", type=int, default=None,
                    help="iteration budget (default 200; workload presets "
                         "own this)")
    ap.add_argument("--encoder", default=encoder,
                    help=f"encoder for coded strategies, from "
                         f"{available_encoders()} (operator encoders are "
                         f"matrix-free)")
    ap.add_argument("--policy", default=policy,
                    choices=["fastest-k", "adaptive-k", "deadline",
                             "adversarial"])
    ap.add_argument("--compute-time", type=float, default=0.05)
    ap.add_argument("--deadline", type=float, default=1.0,
                    help="time budget for --policy deadline (sim seconds)")
    ap.add_argument("--policy-beta", type=float, default=2.0,
                    help="overlap beta for --policy adaptive-k")
    ap.add_argument("--staleness-bound", type=int, default=None)
    ap.add_argument("--async-updates", type=int, default=None)
    ap.add_argument("--trials", type=int, default=1,
                    help="delay realizations per cell (the Monte-Carlo "
                         "axis)")
    ap.add_argument("--eval-every", type=int, default=1,
                    help="record the objective every s steps (s | steps); "
                         "0 records the final objective only")
    ap.add_argument("--placement", default="vmap",
                    choices=["single", "vmap", "sharded"],
                    help="how the realization axis executes: host loop / "
                         "one vmapped program / shard_map over the device "
                         "mesh")
    ap.add_argument("--seed", type=int, default=0)


def main(argv: Sequence[str] | None = None) -> ExperimentResult:
    ap = argparse.ArgumentParser(
        prog="repro.experiments.run",
        description="unified spec -> plan -> execute experiment harness")
    ap.add_argument("--workloads", default=None,
                    help="comma list of paper-§5 workloads "
                         "(ridge/lasso/logistic/mf); omit for the "
                         "synthetic quadratic")
    ap.add_argument("--train", default=None, metavar="ARCHS",
                    help="comma list of model-zoo architectures to train "
                         "with coded SGD (train-kind cells, e.g. "
                         "'deepseek-7b'); --strategies then picks from "
                         "coded-sgd/uncoded")
    ap.add_argument("--code", default=None,
                    help="gradient code for train-kind coded-sgd cells "
                         "(frc/cyclic/stochastic/uncoded; default frc)")
    ap.add_argument("--seq-len", type=int, default=64, dest="seq_len",
                    help="sequence length for train-kind cells")
    ap.add_argument("--preset", default="smoke",
                    choices=["smoke", "bench", "paper", "100m"],
                    help="workload scale preset (with --workloads), or the "
                         "train preset (smoke/100m) with --train")
    # --delays defaults to unset: synthetic matrices then get the compare
    # triple (in build_spec), workload matrices their native paper models —
    # while an EXPLICIT --delays always wins, workload or not
    add_axis_flags(ap, delays=None)
    ap.add_argument("--cell-batch", action="store_true",
                    help="stack compatible matrix cells (same problem/"
                         "strategy/shape, differing delay/policy/step size) "
                         "into one compiled program (vmap placement only)")
    from repro.runtime.faults import FAULT_PRESETS
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="fault-injection spec layered on every delay "
                         "model, e.g. 'crash:p=0.2,at=0.5;blackout:p=0.3,"
                         "dur=0.4;corrupt:p=0.05', or a named chaos "
                         f"preset from {sorted(FAULT_PRESETS)} as "
                         "'preset:<name>' (repro.runtime.faults)")
    ap.add_argument("--degrade", default=None, metavar="SPEC",
                    help="sub-k degradation policy: 'renormalize' | "
                         "'hold[:shrink=S,k_min=K]' | 'backoff[:base=B,"
                         "retries=R]' (default: renormalized decode "
                         "weights)")
    ap.add_argument("--retries", type=int, default=0,
                    help="re-run a cell whose execution RAISED up to N "
                         "extra times (capped exponential backoff)")
    ap.add_argument("--retry-base", type=float, default=0.5,
                    help="first retry backoff in seconds")
    ap.add_argument("--resume", default=None, metavar="RUN_ID",
                    help="resume a killed matrix: replay the run store's "
                         "streamed cell records (run id, unique prefix, or "
                         "'latest') and execute only unfinished cells")
    ap.add_argument("--plan-only", action="store_true",
                    help="print the resolved cell list and exit")
    ap.add_argument("--out", default="runs/experiments")
    ap.add_argument("--formats", default="json,csv,summary")
    ap.add_argument("--trace", default=None, metavar="PREFIX",
                    help="write <PREFIX>.jsonl + <PREFIX>.perfetto.json "
                         "straggler traces (view with repro.obs.report / "
                         "ui.perfetto.dev)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler trace per cell under DIR "
                         "plus device-memory high-water marks")
    ap.add_argument("--metrics-out", default=None, metavar="CSV",
                    help="write the per-cell obs metrics CSV (miss-rate, "
                         "active-set, latency percentiles, compile vs "
                         "execute split)")
    args = ap.parse_args(argv)

    spec = build_spec(args)
    pl = plan(spec)
    if args.plan_only:
        print(pl.describe())
        return ExperimentResult(plan=pl, outcomes=[])
    result = execute(pl, retries=args.retries, retry_base=args.retry_base,
                     resume=args.resume)

    os.makedirs(args.out, exist_ok=True)
    formats = {f.strip() for f in args.formats.split(",")}
    artifacts: dict = {}
    if "json" in formats:
        artifacts["records_json"] = os.path.join(args.out,
                                                 "experiments.json")
        result.to_json(artifacts["records_json"])
    if "csv" in formats:
        artifacts["trace_csv"] = os.path.join(args.out, "experiments.csv")
        result.to_csv(artifacts["trace_csv"])
    if "summary" in formats:
        artifacts["summary_csv"] = os.path.join(args.out, "summary.csv")
        result.to_summary_csv(artifacts["summary_csv"])
    if args.metrics_out:
        d = os.path.dirname(args.metrics_out)
        if d:
            os.makedirs(d, exist_ok=True)
        result.to_metrics_csv(args.metrics_out)
        artifacts["metrics_csv"] = args.metrics_out
        print(f"wrote obs metrics to {args.metrics_out}")
    if args.trace:
        artifacts["trace_jsonl"] = f"{args.trace}.jsonl"
        artifacts["trace_perfetto"] = f"{args.trace}.perfetto.json"
        print(f"wrote obs trace to {args.trace}.jsonl / "
              f"{args.trace}.perfetto.json")
    if result.run_id is not None and artifacts:
        from repro.obs.runstore import default_store
        store = default_store()
        if store is not None:
            store.attach_artifacts(result.run_id, artifacts)
    result.print_table()
    print(f"wrote {sorted(formats)} to {args.out}/")
    if result.run_id is not None:
        print(f"recorded run {result.run_id} "
              f"(diff with: python -m repro.obs.diff latest latest~1)")
    return result


if __name__ == "__main__":
    main()
