"""Run an :class:`ExperimentPlan` and collect canonical per-cell records.

This is the ONE place where "how a cell executes" is decided — every
harness (``repro.experiments.run``, the legacy ``runtime.compare`` and
``workloads.run`` CLIs, benchmarks, examples) funnels through
``execute(plan)``:

  * synthetic/spec problems run through the strategy registry
    (``Strategy.run`` / ``run_batched``), workload problems through
    ``Workload.run`` / ``run_trials`` — with the plan's placement deciding
    whether R realizations run as a host loop (``single``), one vmapped
    program (``vmap``) or ``shard_map``-ped across devices (``sharded``);
  * every cell yields one **canonical record** (see below) plus the raw
    result object for programmatic callers.

Canonical record schema (the union of the three legacy schemas; every
record carries the core keys, workload records add theirs):

  core:      strategy, delay, seed, metric_name, final_metric,
             final_objective, wallclock_s, times, objective, meta
  synthetic: n, p, m, k
  workload:  workload, preset, metric_times, metric, extras
  batched:   trials, summary {mean/p50/p95 wall-clock + finals}
  skipped:   the identifying keys + ``skipped`` (the reason) only
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import os
import time
from typing import Any

import numpy as np

from .io import (print_table, write_json, write_metrics_csv,
                 write_summary_csv, write_trace_csv)
from .plan import ExperimentPlan, PlannedCell
from .spec import ExperimentSpec, ObsAxis

__all__ = ["CellOutcome", "ExperimentResult", "execute", "run",
           "resolve_policy", "trials_record", "cell_label"]


def resolve_policy(name: str, m: int, k: int, *, deadline: float = 1.0,
                   beta: float = 2.0):
    """Build an active-set policy from its CLI name + cell shape."""
    from repro.runtime.engine import make_policy
    if name in ("fastest-k", "adversarial"):
        return make_policy(name, k=k)
    if name == "adaptive-k":
        # k acts as the floor; the policy grows the set per the overlap rule
        return make_policy(name, beta=beta, k_min=k)
    if name == "deadline":
        return make_policy(name, deadline=deadline, k_min=max(1, m // 4))
    raise KeyError(f"unknown policy '{name}'")


def trials_record(results: list, *, delay: str, seed: int) -> dict:
    """Aggregate R per-realization workload results into ONE JSON record:
    stacked per-realization traces plus mean/p50/p95 wall-clock and metric
    summaries.  Scalar ``final_metric`` / ``final_objective`` /
    ``wallclock_s`` are across-trial means, so batched records drop into
    every single-trial consumer (summary CSV, tables)."""
    from repro.runtime.strategies import json_safe_meta, summary_stats
    r0 = results[0]
    final_metric = [r.final_metric for r in results]
    final_obj = [r.final_objective for r in results]
    wallclock = [r.wallclock for r in results]
    return {
        "workload": r0.workload, "strategy": r0.strategy,
        "preset": r0.preset, "metric_name": r0.metric_name,
        "delay": delay, "seed": seed, "trials": len(results),
        "final_metric": float(np.mean(final_metric)),
        "final_objective": float(np.mean(final_obj)),
        "wallclock_s": float(np.mean(wallclock)),
        "summary": {"trials": len(results),
                    "wallclock_s": summary_stats(wallclock),
                    "final_metric": summary_stats(final_metric),
                    "final_objective": summary_stats(final_obj)},
        "times": [np.asarray(r.times, dtype=float).tolist()
                  for r in results],
        "objective": [np.asarray(r.objective, dtype=float).tolist()
                      for r in results],
        "metric_times": [np.asarray(r.metric_times, dtype=float).tolist()
                         for r in results],
        "metric": [np.asarray(r.metric, dtype=float).tolist()
                   for r in results],
        "extras": [r.extras for r in results],
        "meta": json_safe_meta(r0.meta),
    }


@dataclasses.dataclass
class CellOutcome:
    """One executed cell: the canonical record plus the raw result object
    (RunResult / TrialsResult / WorkloadRunResult / list of them; None for
    a skipped cell) for callers that need iterates or schedules."""
    cell: PlannedCell
    record: dict
    result: Any = None

    @property
    def skipped(self) -> bool:
        return "skipped" in self.record


@dataclasses.dataclass
class ExperimentResult:
    """Everything ``execute`` produced, with the shared writers attached.
    ``recorder`` is the run's :class:`repro.obs.TraceRecorder` when the
    spec's :class:`ObsAxis` was enabled, else None."""
    plan: ExperimentPlan
    outcomes: list
    recorder: Any = None
    run_id: str | None = None      # run-store id when the run was recorded

    @property
    def spec(self) -> ExperimentSpec:
        return self.plan.spec

    @property
    def records(self) -> list[dict]:
        return [o.record for o in self.outcomes]

    def to_json(self, path: str) -> None:
        write_json(self.records, path)

    def to_csv(self, path: str) -> None:
        write_trace_csv(self.records, path)

    def to_summary_csv(self, path: str) -> None:
        write_summary_csv(self.records, path)

    def print_table(self) -> None:
        print_table(self.records)

    def to_metrics_csv(self, path: str) -> None:
        write_metrics_csv(self.records, path)


def cell_label(cell: PlannedCell) -> str:
    """The stable human-readable id obs events carry for one cell."""
    if cell.kind == "workload":
        prefix = f"{cell.problem.workload}/"
    elif cell.kind == "train":
        prefix = f"{cell.problem.arch}/"
    else:
        prefix = ""
    return f"{prefix}{cell.resolved_strategy}x{cell.delay}"


def execute(plan: ExperimentPlan, *, record_to=None, retries: int = 0,
            retry_base: float = 0.5,
            resume: str | None = None) -> ExperimentResult:
    """Run every planned cell; never aborts mid-matrix for per-cell
    incompatibilities (those become skip-with-reason records).

    When the spec carries an enabled :class:`ObsAxis`, the whole matrix runs
    under an active :class:`repro.obs.TraceRecorder`: every record gains
    ``host_s``/``compile_s``/``execute_s``/``compiles`` (the CompileWatch
    split) plus an ``obs`` per-cell metrics summary, and ``obs.trace`` /
    ``obs.profile`` write the trace / profiler artifacts.  With the axis
    off (the default) records are bit-identical to pre-obs builds.

    Every run additionally leaves a provenance manifest in the run store
    (``repro.obs.runstore``) — ``record_to`` controls where: ``None`` uses
    the ``REPRO_RUNSTORE``-governed default store, ``False`` skips
    recording (benchmark timing loops), a :class:`RunStore` or path
    records there.  The manifest opens with ``status: "running"`` before
    the first cell and each completed cell record streams to
    ``<run_id>/cells/<index>.json``, so a killed matrix is resumable:
    ``resume="RUN_ID"`` (or ``latest``) replays the streamed records —
    after verifying the plan's spec hash matches the recorded run's — and
    executes only the cells that never finished.  Resumed outcomes carry
    the persisted record with ``result=None`` (raw result objects are not
    serialized).

    ``retries`` re-runs a cell whose execution RAISED (host crash, OOM —
    not the in-simulation faults, and not per-cell ``ValueError``
    incompatibilities, which are already skip records) up to that many
    extra times with capped exponential backoff (``retry_base * 2**i``
    seconds, ±25% deterministic jitter, 30 s cap); the last failure
    re-raises, and the streamed records make the partial matrix resumable.
    """
    obs = getattr(plan.spec, "obs", None)
    cell_batch = getattr(plan.spec.placement, "cell_batch", False)
    store, run_id, done = _open_run(plan, record_to, resume)
    runner = _CellRunner(retries=retries, retry_base=retry_base,
                         store=store, run_id=run_id, done=done)
    if obs is None or not obs.enabled:
        if cell_batch:
            result = ExperimentResult(
                plan=plan, outcomes=_execute_cellbatched(plan, runner))
        else:
            result = ExperimentResult(
                plan=plan,
                outcomes=[runner.run(cell) for cell in plan.cells])
    else:
        if cell_batch:
            # per-cell CompileWatch/metrics attribution needs one dispatch
            # per cell; keep the obs contract and run the matrix unbatched
            print("# obs axis enabled: cell batching falls back to "
                  "per-cell execution")
        result = _execute_observed(plan, obs, runner)
    _finish_run(result, store, run_id)
    return result


def _resolve_store(record_to):
    """The run store ``record_to`` selects (None when recording is off)."""
    if record_to is False:
        return None
    from repro.obs.runstore import RunStore, default_store
    if record_to is None:
        return default_store()
    if isinstance(record_to, RunStore):
        return record_to
    return RunStore(str(record_to))


def _open_run(plan: ExperimentPlan, record_to, resume):
    """Open the run-store side of one matrix: a fresh ``running`` manifest,
    or — with ``resume`` — the prior run's identity plus its streamed cell
    records.  Returns ``(store, run_id, {cell index: record})``."""
    store = _resolve_store(record_to)
    if resume is None:
        if store is None:
            return None, None, {}
        from repro.obs.runstore import begin_experiment
        try:
            run_id = begin_experiment(plan.spec, store=store,
                                      total_cells=len(plan.cells))
        except Exception as e:                    # noqa: BLE001
            # best-effort: a full store disk must never fail the experiment
            print(f"# runstore: manifest not recorded: {e}")
            return None, None, {}
        return store, run_id, {}
    if store is None:
        raise ValueError(
            "resume needs an enabled run store (REPRO_RUNSTORE, or an "
            "explicit record_to)")
    from repro.obs.runstore import completed_cells, spec_hash
    manifest = store.resolve(str(resume))
    want, got = spec_hash(plan.spec), manifest.get("spec_hash")
    if got != want:
        raise ValueError(
            f"resume {manifest.get('run_id')}: spec hash mismatch (run "
            f"{got}, plan {want}) — resuming would mix records from "
            f"different matrices")
    run_id = manifest["run_id"]
    done = completed_cells(store, run_id)
    print(f"# resuming {run_id}: {len(done)}/{len(plan.cells)} cells "
          f"already recorded")
    return store, run_id, done


def _finish_run(result: ExperimentResult, store, run_id) -> None:
    """Finalize the running manifest (best-effort, like _open_run)."""
    if store is None or run_id is None:
        return
    result.run_id = run_id
    from repro.obs.runstore import finish_experiment
    try:
        finish_experiment(result, store, run_id)
    except Exception as e:                        # noqa: BLE001
        print(f"# runstore: manifest not finalized: {e}")


def _retry_delay(base: float, attempt: int, index: int,
                 cap: float = 30.0) -> float:
    """Backoff before retry ``attempt`` (1-based) of one cell: capped
    exponential with ±25% jitter derived from (cell, attempt) — spreads
    concurrent harnesses without introducing host randomness."""
    d = base * (2.0 ** (attempt - 1))
    h = hashlib.sha256(f"{index}:{attempt}".encode()).digest()[0] / 255.0
    return min(cap, d * (0.75 + 0.5 * h))     # cap bounds the jittered wait


class _CellRunner:
    """Per-cell execution policy for one matrix: the shared problem/data
    caches, crash retry with capped exponential backoff, and the streamed
    run-store records that make a killed matrix resumable."""

    def __init__(self, *, retries: int = 0, retry_base: float = 0.5,
                 store=None, run_id=None, done=None):
        self.caches: dict = {}
        self.retries = max(0, int(retries))
        self.retry_base = float(retry_base)
        self.store = store
        self.run_id = run_id
        self.done = dict(done or {})

    def resumed(self, cell: PlannedCell) -> "CellOutcome | None":
        """The persisted outcome of an already-completed cell, or None."""
        if cell.index not in self.done:
            return None
        return CellOutcome(cell, self.done[cell.index])

    def run(self, cell: PlannedCell, *, persist: bool = True) -> "CellOutcome":
        oc = self.resumed(cell)
        if oc is not None:
            return oc
        oc = self._attempt(cell)
        if persist:
            self.persist(oc)
        return oc

    def _attempt(self, cell: PlannedCell) -> "CellOutcome":
        last: Exception | None = None
        for attempt in range(self.retries + 1):
            if attempt:
                delay = _retry_delay(self.retry_base, attempt, cell.index)
                print(f"# cell {cell.index} ({cell_label(cell)}) raised "
                      f"{type(last).__name__}: {last}; retry {attempt}/"
                      f"{self.retries} in {delay:.2f}s")
                time.sleep(delay)
            try:
                return _execute_cell(cell, self.caches)
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as e:                # noqa: BLE001
                last = e
        assert last is not None
        raise last

    def persist(self, oc: "CellOutcome") -> None:
        """Stream one finished cell record (best-effort; no-op for cells
        that were loaded from a resumed run)."""
        if (self.store is None or self.run_id is None
                or oc.cell.index in self.done):
            return
        from repro.obs.runstore import record_cell
        try:
            record_cell(self.store, self.run_id, oc.cell.index, oc.record)
        except Exception as e:                    # noqa: BLE001
            print(f"# runstore: cell {oc.cell.index} not recorded: {e}")


def _execute_observed(plan: ExperimentPlan, obs: ObsAxis,
                      runner: _CellRunner) -> ExperimentResult:
    from repro.obs import (CompileWatch, TraceRecorder, cell_summary,
                           memory_high_water, profile_region)
    rec = TraceRecorder(meta={"cells": len(plan.cells),
                              "trials": plan.spec.trials.trials,
                              "placement": plan.spec.placement.mode})
    outcomes: list = []
    with rec.activate():
        for cell in plan.cells:
            resumed = runner.resumed(cell)
            if resumed is not None:
                # a resumed record keeps its original obs attribution —
                # nothing ran here to watch
                outcomes.append(resumed)
                continue
            label = cell_label(cell)
            mark = rec.checkpoint()
            prof = (profile_region(os.path.join(obs.profile,
                                                f"cell{cell.index:03d}"))
                    if obs.profile and cell.skip is None
                    else contextlib.nullcontext())
            with rec.cell(label), prof, CompileWatch() as cw:
                outcome = runner.run(cell, persist=False)
            if not outcome.skipped:
                summary = cell_summary(rec.sources_since(mark))
                if obs.profile:
                    hwm = memory_high_water()
                    if hwm is not None:
                        summary["memory_high_water_bytes"] = int(hwm)
                outcome.record.update(
                    host_s=cw.total_s, compile_s=cw.compile_s,
                    execute_s=cw.execute_s, compiles=cw.compiles,
                    obs=summary)
            runner.persist(outcome)
            outcomes.append(outcome)
    if obs.trace:
        prefix = obs.trace[:-len(".jsonl")] \
            if obs.trace.endswith(".jsonl") else obs.trace
        d = os.path.dirname(prefix)
        if d:
            os.makedirs(d, exist_ok=True)
        rec.to_jsonl(prefix + ".jsonl")
        rec.to_perfetto(prefix + ".perfetto.json")
    return ExperimentResult(plan=plan, outcomes=outcomes, recorder=rec)


def run(spec: ExperimentSpec) -> ExperimentResult:
    """``execute(plan(spec))`` in one call."""
    from .plan import plan as _plan
    return execute(_plan(spec))


# ---------------------------------------------------------------------------
# Cell execution
# ---------------------------------------------------------------------------

def _engine(cell: PlannedCell):
    from repro.runtime.engine import ClusterEngine, make_delay_model
    return ClusterEngine(make_delay_model(cell.delay), cell.m,
                         compute_time=cell.compute_time, seed=cell.seed,
                         faults=cell.faults)


def _execute_cell(cell: PlannedCell, caches: dict) -> CellOutcome:
    if cell.kind == "workload":
        return _execute_workload_cell(cell, caches)
    if cell.kind == "train":
        return _execute_train_cell(cell, caches)
    return _execute_synthetic_cell(cell, caches)


def _train_problem(cell: PlannedCell, caches: dict):
    from repro.train.coded import TrainProblem
    key = ("train", id(cell.problem))
    if key not in caches:
        pr = cell.problem
        caches[key] = TrainProblem(
            arch=pr.arch, preset=pr.preset, seq_len=pr.seq_len,
            rows_per_worker=pr.rows_per_worker, vocab=pr.vocab)
    return caches[key]


def _execute_train_cell(cell: PlannedCell, caches: dict) -> CellOutcome:
    """One train-kind cell: a coded-SGD LM run through the strategy layer.

    ``'uncoded'`` cells dispatch the SAME ``coded-sgd`` strategy with the
    identity code forced — the no-redundancy baseline is the same trainer
    minus the code, so loss curves are directly comparable.
    """
    from repro.runtime.strategies import get_strategy
    pr, st = cell.problem, cell.strategy
    base = {"strategy": cell.resolved_strategy, "delay": cell.delay,
            "arch": pr.arch, "preset": pr.preset, "m": cell.m, "k": cell.k,
            "seed": cell.seed}
    if cell.skip is not None:
        return CellOutcome(cell, {**base, "skipped": cell.skip,
                                  "metric_name": "loss"})
    spec_ = _train_problem(cell, caches)
    engine = _engine(cell)
    cfg = st.options_dict()
    if cell.resolved_strategy == "uncoded":
        cfg["code"] = "uncoded"     # force over any --code option
    cfg.setdefault("policy", resolve_policy(
        st.policy or "fastest-k", cell.m, cell.k,
        deadline=st.deadline, beta=st.policy_beta))
    if cell.degrade is not None:
        cfg.setdefault("degrade", cell.degrade)
    strat = get_strategy("coded-sgd")
    try:
        if cell.trials > 1:
            result = strat.run_batched(
                spec_, engine, steps=cell.steps, trials=cell.trials,
                eval_every=cell.eval_every, placement=cell.placement, **cfg)
        else:
            result = strat.run(spec_, engine, steps=cell.steps, **cfg)
    except ValueError as e:
        print(f"# skipping {cell.resolved_strategy} x {cell.delay}: {e}")
        return CellOutcome(cell, {**base, "skipped": str(e),
                                  "metric_name": "loss"})
    rec = result.to_record()
    rec.update(base, metric_name="loss",
               final_metric=rec["final_objective"])
    return CellOutcome(cell, rec, result)


def _synthetic_problem(cell: PlannedCell, caches: dict):
    from repro.runtime.strategies import ProblemSpec
    key = ("problem", id(cell.problem))
    if key not in caches:
        pr = cell.problem
        if pr.kind == "spec":
            caches[key] = pr.problem
        else:
            seed = pr.seed if pr.seed is not None else cell.seed
            caches[key] = ProblemSpec.synthetic(
                pr.n, pr.p, noise=pr.noise, lam=pr.lam, h=pr.h, seed=seed)
    return caches[key]


def _execute_synthetic_cell(cell: PlannedCell, caches: dict) -> CellOutcome:
    from repro.runtime.strategies import get_strategy
    spec_ = _synthetic_problem(cell, caches)
    st = cell.strategy
    engine = _engine(cell)
    cfg = st.options_dict()
    if cell.resolved_strategy == "async":
        if st.staleness_bound is not None:
            cfg.setdefault("staleness_bound", st.staleness_bound)
        if st.async_updates is not None:
            cfg.setdefault("updates", st.async_updates)
    else:
        if cell.resolved_strategy.startswith("coded"):
            cfg.setdefault("encoder", st.encoder if st.encoder is not None
                           else "hadamard")
        cfg.setdefault("policy", resolve_policy(
            st.policy or "fastest-k", cell.m, cell.k,
            deadline=st.deadline, beta=st.policy_beta))
    if cell.degrade is not None:
        cfg.setdefault("degrade", cell.degrade)
    base = {"strategy": cell.resolved_strategy, "delay": cell.delay,
            "n": spec_.n, "p": spec_.p, "m": cell.m, "k": cell.k,
            "seed": cell.seed}
    try:
        if cell.trials > 1:
            result = get_strategy(cell.resolved_strategy).run_batched(
                spec_, engine, steps=cell.steps, trials=cell.trials,
                eval_every=cell.eval_every, placement=cell.placement, **cfg)
        else:
            result = get_strategy(cell.resolved_strategy).run(
                spec_, engine, steps=cell.steps, **cfg)
    except ValueError as e:
        print(f"# skipping {cell.resolved_strategy} x {cell.delay}: {e}")
        return CellOutcome(cell, {**base, "skipped": str(e),
                                  "metric_name": "objective"})
    rec = result.to_record()
    rec.update(base, metric_name="objective",
               final_metric=rec["final_objective"])
    return CellOutcome(cell, rec, result)


# ---------------------------------------------------------------------------
# Cell batching: compatible cells -> one compiled program (DESIGN.md §12)
# ---------------------------------------------------------------------------

# strategies whose hot path is the batched_scan_gd/prox runner — the only
# ones where stacking cells along the realization axis is a pure reshape
_CELLBATCH_STRATEGIES = ("coded-gd", "coded-prox", "uncoded", "replication")


def _freeze(v):
    try:
        hash(v)
    except TypeError:
        return id(v)
    return v


def _cellbatch_key(cell: PlannedCell):
    """Group key for one cell, or None when the cell must run on its own.

    Cells in one group share the compiled program, so everything that
    shapes or re-parameterizes it is in the key: problem identity, strategy,
    encoder config, m, steps, trials, eval_every, seed, extra options, and
    the fault/degrade specs (degrade is a static argument of the fused
    runners; ``run_cellbatched`` rejects mixed-degrade batches as a
    backstop).  Delay model / compute time / policy / k / step size are
    FREE axes — they only change the sampled schedules and the
    per-realization step vector.
    """
    if (cell.kind in ("workload", "train") or cell.skip is not None
            or cell.placement != "vmap"
            or cell.resolved_strategy not in _CELLBATCH_STRATEGIES):
        return None
    st = cell.strategy
    opts = tuple(sorted((k, _freeze(v)) for k, v in st.options
                        if k != "step_size"))
    return (cell.resolved_strategy, id(cell.problem), cell.m, cell.steps,
            cell.trials, cell.eval_every, cell.seed, _freeze(st.encoder),
            cell.faults, cell.degrade, opts)


def _cell_cfg(cell: PlannedCell) -> dict:
    """The per-cell strategy config, exactly as ``_execute_synthetic_cell``
    builds it for the sync-gradient family."""
    st = cell.strategy
    cfg = st.options_dict()
    if cell.resolved_strategy.startswith("coded"):
        cfg.setdefault("encoder", st.encoder if st.encoder is not None
                       else "hadamard")
    cfg.setdefault("policy", resolve_policy(
        st.policy or "fastest-k", cell.m, cell.k,
        deadline=st.deadline, beta=st.policy_beta))
    if cell.degrade is not None:
        cfg.setdefault("degrade", cell.degrade)
    return cfg


def _execute_cell_group(cells: list, runner: _CellRunner) -> list:
    """One compiled program for a group of compatible cells; any
    incompatibility the strategy detects at run time falls back to the
    per-cell path (same records, minus the sharing)."""
    from repro.runtime.strategies import get_strategy
    spec_ = _synthetic_problem(cells[0], runner.caches)
    engines = [_engine(cell) for cell in cells]
    cfgs = [_cell_cfg(cell) for cell in cells]
    strat = get_strategy(cells[0].resolved_strategy)
    try:
        results = strat.run_cellbatched(
            spec_, engines, steps=cells[0].steps, trials=cells[0].trials,
            eval_every=cells[0].eval_every, cfgs=cfgs)
    except ValueError as e:
        print(f"# cell batch of {len(cells)} "
              f"{cells[0].resolved_strategy} cells fell back to per-cell "
              f"execution: {e}")
        return [runner.run(cell, persist=False) for cell in cells]
    outcomes = []
    for cell, result in zip(cells, results):
        base = {"strategy": cell.resolved_strategy, "delay": cell.delay,
                "n": spec_.n, "p": spec_.p, "m": cell.m, "k": cell.k,
                "seed": cell.seed}
        if cell.trials == 1:
            # single-trial cells report the RunResult schema (scalar trace
            # rows), like the unbatched executor; the batching marker stays
            one = result.realization(0)
            for key in ("trials", "eval_every", "batched"):
                one.meta.pop(key, None)
            rec = one.to_record()
            result = one
        else:
            rec = result.to_record()
        rec.update(base, metric_name="objective",
                   final_metric=rec["final_objective"])
        outcomes.append(CellOutcome(cell, rec, result))
    return outcomes


def _execute_cellbatched(plan: ExperimentPlan, runner: _CellRunner) -> list:
    """Group compatible PENDING cells (resumed cells replay their streamed
    records), run each group as one program, and return outcomes in plan
    order."""
    groups: dict = {}
    by_index: dict = {}
    for cell in plan.cells:
        resumed = runner.resumed(cell)
        if resumed is not None:
            by_index[cell.index] = resumed
            continue
        groups.setdefault(_cellbatch_key(cell), []).append(cell)
    for key, cells in groups.items():
        if key is None or len(cells) == 1:
            for cell in cells:
                by_index[cell.index] = runner.run(cell)
        else:
            for cell, oc in zip(cells, _execute_cell_group(cells, runner)):
                runner.persist(oc)
                by_index[cell.index] = oc
    return [by_index[cell.index] for cell in plan.cells]


def _workload_data(cell: PlannedCell, wl, ps, caches: dict):
    key = ("data", cell.problem.workload, cell.problem.preset)
    if key not in caches:
        caches[key] = wl.build(ps)
    return caches[key]


def _execute_workload_cell(cell: PlannedCell, caches: dict) -> CellOutcome:
    from repro.workloads import UnsupportedStrategy, get_workload
    pr, st = cell.problem, cell.strategy
    wl = get_workload(pr.workload)
    ps = wl.preset(pr.preset)
    base = {"workload": wl.name, "strategy": cell.resolved_strategy,
            "delay": cell.delay, "preset": ps.name, "seed": cell.seed}
    if cell.skip is not None:
        return CellOutcome(cell, {**base, "skipped": cell.skip,
                                  "metric_name": wl.metric_name})
    data = _workload_data(cell, wl, ps, caches)
    engine = _engine(cell)
    cell_cfg = st.options_dict()
    if st.k is not None:
        cell_cfg.setdefault("k", st.k)
    if cell.steps is not None:
        cell_cfg.setdefault("steps", cell.steps)
    if st.encoder is not None:
        cell_cfg.setdefault("encoder", st.encoder)
    if not cell.resolved_strategy.startswith("coded"):
        # encoder targets the coded scheme; uncoded/replication keep their
        # defining encoders.
        cell_cfg.pop("encoder", None)
    # strategy-level config flows into the workload's strategy dispatch the
    # same way it does for synthetic cells — a StrategyAxis field the user
    # set must never be silently dropped
    if cell.resolved_strategy == "async":
        if st.staleness_bound is not None:
            cell_cfg.setdefault("staleness_bound", st.staleness_bound)
        if st.async_updates is not None:
            cell_cfg.setdefault("updates", st.async_updates)
    elif st.policy is not None:
        k = st.k if st.k is not None else ps.k
        cell_cfg.setdefault("policy", resolve_policy(
            st.policy, cell.m, k, deadline=st.deadline,
            beta=st.policy_beta))
    if cell.degrade is not None and cell.resolved_strategy != "async":
        # flows through the workload lowering into the registry strategy,
        # which pops it (async has no barrier to degrade)
        cell_cfg.setdefault("degrade", cell.degrade)
    try:
        if cell.trials > 1:
            results = wl.run_trials(st.name, engine, preset=ps, data=data,
                                    trials=cell.trials,
                                    eval_every=cell.eval_every,
                                    placement=cell.placement, **cell_cfg)
            return CellOutcome(
                cell, {**base, **trials_record(results, delay=cell.delay,
                                               seed=cell.seed)}, results)
        result = wl.run(st.name, engine, preset=ps, data=data, **cell_cfg)
    except ValueError as e:
        # UnsupportedStrategy (runtime-detected), or a config clash (e.g.
        # --m below the preset's k) — record the reason, keep the matrix
        # going (same contract as the synthetic path)
        if not isinstance(e, UnsupportedStrategy):
            print(f"# skipping {cell.resolved_strategy} x {cell.delay}: {e}")
        return CellOutcome(cell, {**base, "skipped": str(e),
                                  "metric_name": wl.metric_name})
    rec = result.to_record()
    rec.update(delay=cell.delay, seed=cell.seed)
    return CellOutcome(cell, rec, result)
